//! Offline stub of the `xla` crate (PJRT bindings) API surface used by
//! `uni_lora::runtime::executor`.
//!
//! The build environment cannot fetch the real crate (it links
//! `xla_extension` and needs network + a native library). This stub
//! keeps the `--features pjrt` code path *compiling* so the feature gate
//! is honest; every entry point fails at runtime with a clear message.
//! Deployments that have the real PJRT library swap this path
//! dependency for the published `xla` crate — no source changes needed.

use std::fmt;

/// Error returned by every stubbed operation.
#[derive(Debug)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable<T>(what: &str) -> Result<T, XlaError> {
    Err(XlaError(format!(
        "{what} unavailable — this build uses the offline xla stub; \
         replace vendor/xla-stub with the real `xla` crate to run the \
         PJRT backend"
    )))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[non_exhaustive]
pub enum ElementType {
    F32,
    F64,
    S32,
    S64,
    U32,
    U64,
    Pred,
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        unavailable("PJRT CPU client")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        unavailable("compile")
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        unavailable("HLO text parsing")
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1<T>(_v: &[T]) -> Literal {
        Literal
    }

    pub fn scalar<T>(_v: T) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        unavailable("reshape")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, XlaError> {
        unavailable("to_tuple")
    }

    pub fn ty(&self) -> Result<ElementType, XlaError> {
        unavailable("ty")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        unavailable("to_vec")
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unavailable("to_literal_sync")
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unavailable("execute")
    }
}
