//! Offline polyfill of the `anyhow` API surface this workspace uses.
//!
//! The build environment has no crates.io access, so the real crate
//! cannot be fetched; this drop-in implements the subset the codebase
//! relies on — `Error`, `Result`, `anyhow!`, `bail!`, `ensure!` and the
//! `Context` extension trait for `Result`/`Option` — with identical
//! calling conventions. Context is stored as a flattened `"ctx: cause"`
//! message chain, so `{}` and `{:#}` render the same string.

use std::fmt;

/// A string-backed error with context chaining.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from a pre-formatted message (used by the macros).
    pub fn from_msg(msg: String) -> Error {
        Error { msg }
    }

    /// `anyhow::Error::msg` compatibility constructor.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context layer.
    pub fn context<C: fmt::Display>(self, ctx: C) -> Error {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{:#}` prints the full chain in real anyhow; our chain is
        // already flattened into one message.
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like the real crate: any std error converts via `?`. `Error` itself
// deliberately does NOT implement `std::error::Error`, which keeps this
// blanket impl coherent with the reflexive `From<Error> for Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::from_msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from_msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::from_msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::from_msg(f().to_string()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::from_msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::from_msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        let _ = std::fs::read("/no/such/file/anywhere")?;
        Ok(())
    }

    #[test]
    fn macros_and_context() {
        let e: Error = anyhow!("plain {}", 7);
        assert_eq!(e.to_string(), "plain 7");
        let r: Result<()> = Err(anyhow!("inner")).context("outer");
        assert_eq!(r.unwrap_err().to_string(), "outer: inner");
        let o: Result<i32> = None.with_context(|| format!("missing {}", "x"));
        assert_eq!(o.unwrap_err().to_string(), "missing x");
        assert!(fails_io().is_err());
    }

    #[test]
    fn ensure_and_bail() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 10 {
                bail!("too big");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(-1).unwrap_err().to_string().contains("positive"));
        assert!(f(11).is_err());
    }
}
