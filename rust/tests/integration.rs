//! Integration: real artifacts through the PJRT runtime.
//!
//! These tests are skipped when `artifacts/` has not been built
//! (`make artifacts`); CI runs them after the AOT step.

use uni_lora::projection::statics::{gen_statics, init_array, init_theta};
use uni_lora::rng;
use uni_lora::runtime::{Executor, Manifest, TensorIn};

fn executor() -> Option<Executor> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Executor::new(Manifest::load(dir).unwrap()).unwrap())
}

/// Initialize the frozen backbone from the manifest's base segments.
fn init_base(exec: &Executor, name: &str, seed: u64) -> Vec<f32> {
    let meta = exec.manifest.get(name).unwrap();
    let mut w0 = Vec::with_capacity(meta.base_params);
    for (i, seg) in meta.base_segments.iter().enumerate() {
        let s = rng::child_seed(seed, rng::STREAM_BASE_INIT + 1000 * i as u64);
        w0.extend(init_array(&seg.init, seg.numel(), s).unwrap());
    }
    assert_eq!(w0.len(), meta.base_params);
    w0
}

#[test]
fn cls_train_step_runs_and_learns() {
    let Some(mut exec) = executor() else { return };
    let name = "glue_base_uni_c2_cls_train";
    let meta = exec.manifest.get(name).unwrap().clone();
    let cfg = meta.cfg.clone();
    let seed = 42u64;

    let mut theta = init_theta(&cfg, seed).unwrap();
    let mut m = vec![0f32; meta.d];
    let mut v = vec![0f32; meta.d];
    let mut head = vec![0f32; meta.head_params];
    let mut hm = vec![0f32; meta.head_params];
    let mut hv = vec![0f32; meta.head_params];
    let w0 = init_base(&exec, name, seed);
    let stats = gen_statics(&cfg, seed).unwrap();

    // learnable toy batch: label = parity of first token
    let (b, t) = (cfg.batch, cfg.seq);
    let tokens = rng::indices(7, b * t, cfg.vocab);
    let labels: Vec<i32> = (0..b).map(|i| tokens[i * t] % 2).collect();
    let attn_len = vec![t as i32; b];

    let mut losses = Vec::new();
    for step in 1..=10 {
        let mut inputs = vec![
            TensorIn::F32(theta.clone()),
            TensorIn::F32(m.clone()),
            TensorIn::F32(v.clone()),
            TensorIn::F32(head.clone()),
            TensorIn::F32(hm.clone()),
            TensorIn::F32(hv.clone()),
            TensorIn::ScalarI32(step),
            TensorIn::ScalarF32(5e-3),
            TensorIn::ScalarF32(5e-2),
            TensorIn::ScalarF32(0.0),
            TensorIn::F32(w0.clone()),
            TensorIn::I32(tokens.clone()),
            TensorIn::I32(attn_len.clone()),
            TensorIn::I32(labels.clone()),
        ];
        inputs.extend(stats.iter().map(TensorIn::from));
        let out = exec.run(name, &inputs).unwrap();
        theta = out[0].clone().f32().unwrap();
        m = out[1].clone().f32().unwrap();
        v = out[2].clone().f32().unwrap();
        head = out[3].clone().f32().unwrap();
        hm = out[4].clone().f32().unwrap();
        hv = out[5].clone().f32().unwrap();
        losses.push(out[6].scalar_f32().unwrap());
    }
    assert!(losses.iter().all(|l| l.is_finite()), "{losses:?}");
    assert!(losses[9] < losses[0], "loss did not decrease: {losses:?}");
}

#[test]
fn cls_eval_shapes() {
    let Some(mut exec) = executor() else { return };
    let name = "glue_base_uni_c2_cls_eval";
    let meta = exec.manifest.get(name).unwrap().clone();
    let cfg = meta.cfg.clone();
    let theta = init_theta(&cfg, 1).unwrap();
    let head = vec![0f32; meta.head_params];
    let w0 = init_base(&exec, name, 1);
    let stats = gen_statics(&cfg, 1).unwrap();
    let tokens = rng::indices(3, cfg.batch * cfg.seq, cfg.vocab);
    let attn_len = vec![cfg.seq as i32; cfg.batch];
    let mut inputs = vec![
        TensorIn::F32(theta),
        TensorIn::F32(head),
        TensorIn::F32(w0),
        TensorIn::I32(tokens),
        TensorIn::I32(attn_len),
    ];
    inputs.extend(stats.iter().map(TensorIn::from));
    let out = exec.run(name, &inputs).unwrap();
    assert_eq!(out.len(), 1);
    let logits = out[0].as_f32().unwrap();
    assert_eq!(logits.len(), cfg.batch * cfg.n_classes);
    assert!(logits.iter().all(|x| x.is_finite()));
}

#[test]
fn executor_input_validation() {
    let Some(mut exec) = executor() else { return };
    let err = exec
        .run("glue_base_uni_c2_cls_eval", &[TensorIn::F32(vec![0.0])])
        .unwrap_err();
    assert!(err.to_string().contains("inputs"), "{err}");
    assert!(exec.run("no_such_artifact", &[]).is_err());
}

#[test]
fn server_roundtrip_and_batching() {
    use std::sync::Arc;
    use uni_lora::adapters::{AdapterCheckpoint, Registry};
    use uni_lora::server::server::Client;
    use uni_lora::server::{serve, ServerConfig};

    let Some(mut exec) = executor() else { return };
    let art = "lm_uni_lm_logits";
    let meta = exec.manifest.get(art).unwrap().clone();
    let w0 = init_base(&exec, art, 42);
    exec.prepare(art).unwrap();

    let registry = Registry::new();
    for i in 0..3u64 {
        registry.insert(
            format!("a{i}"),
            AdapterCheckpoint {
                seed: i,
                method: "uni".into(),
                artifact: art.into(),
                theta: init_theta(&meta.cfg, i).unwrap(),
                head: vec![],
            },
        );
    }
    let handle = serve(
        ServerConfig { addr: "127.0.0.1:0".into(), art_logits: art.into() },
        exec,
        Arc::new(registry),
        meta.cfg.clone(),
        w0,
    )
    .unwrap();

    let mut client = Client::connect(handle.addr).unwrap();
    // adapters listing
    match client.call(&uni_lora::server::protocol::Request::Adapters).unwrap() {
        uni_lora::server::protocol::Response::Adapters(a) => {
            assert_eq!(a, vec!["a0", "a1", "a2"])
        }
        other => panic!("{other:?}"),
    }
    // generation returns tokens (untrained model: content arbitrary)
    let toks = client.generate("a1", vec![1, 21, 7, 14, 8, 17, 22], 3).unwrap();
    assert!(toks.len() <= 3);
    // determinism: same adapter+prompt -> same generation
    let toks2 = client.generate("a1", vec![1, 21, 7, 14, 8, 17, 22], 3).unwrap();
    assert_eq!(toks, toks2);
    // unknown adapter -> error response, connection stays usable
    assert!(client.generate("nope", vec![1], 2).is_err());
    let toks3 = client.generate("a0", vec![1, 21, 7], 2).unwrap();
    assert!(toks3.len() <= 2);
    // stats reflect the traffic
    let stats = client.stats().unwrap();
    assert!(stats.get("requests").unwrap().as_f64().unwrap() >= 3.0);
    handle.shutdown();
}

#[test]
fn lm_decode_respects_prompt_and_eos() {
    use uni_lora::coordinator::{init_base as ib, LmTrainer};
    let Some(mut exec) = executor() else { return };
    let meta = exec.manifest.get("lm_uni_lm_train").unwrap().clone();
    let w0 = ib(&meta, 42);
    let mut tr = LmTrainer::new(&exec, "lm_uni", 42, w0).unwrap();
    let prompts = vec![vec![1, 21, 7, 14, 8, 17, 22], vec![1, 21, 9, 16, 5, 17, 22]];
    let gens = tr.greedy_decode(&mut exec, &prompts, 5).unwrap();
    assert_eq!(gens.len(), 2);
    for g in &gens {
        assert!(g.len() <= 5);
        assert!(g.iter().all(|&t| t >= 0 && (t as usize) < meta.cfg.vocab));
    }
}
