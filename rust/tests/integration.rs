//! Integration: the real pipeline end-to-end on the native CPU backend
//! — no Python, no `artifacts/` directory, no PJRT library. This is
//! what `cargo test -q` exercises on every commit; the PJRT/artifact
//! equivalents live in tests/pjrt_artifacts.rs behind the `pjrt`
//! feature.

use std::sync::Arc;
use uni_lora::adapters::{AdapterCheckpoint, Registry};
use uni_lora::coordinator::{init_base, ClsTrainer, Hyper, LmTrainer};
use uni_lora::data::batcher::{cls_batches, lm_batches};
use uni_lora::data::{glue, math_tasks};
use uni_lora::projection::statics::init_theta;
use uni_lora::runtime::{Backend, NativeBackend};
use uni_lora::server::server::Client;
use uni_lora::server::{serve, ServerConfig};

fn backend() -> Box<dyn Backend> {
    Box::new(NativeBackend::new().unwrap())
}

#[test]
fn native_cls_train_steps_run_and_learn() {
    let mut exec = backend();
    let family = "glue_base_uni_c2";
    let meta = exec.meta(&format!("{family}_cls_train")).unwrap().clone();
    let w0 = init_base(&meta, 42);
    let mut tr = ClsTrainer::new(exec.as_ref(), family, 42, w0).unwrap();
    let split = glue::generate("sst2", 42, meta.cfg.seq, meta.cfg.vocab);
    let batch = &cls_batches(&split.train, meta.cfg.batch, 42, 0)[0];
    let hp = Hyper { lr_theta: 5e-3, lr_head: 5e-2, wd: 0.0, epochs: 1 };
    let mut losses = Vec::new();
    for _ in 0..8 {
        losses.push(tr.train_step(exec.as_mut(), batch, &hp).unwrap());
    }
    assert!(losses.iter().all(|l| l.is_finite()), "{losses:?}");
    assert!(
        losses[7] < losses[0],
        "loss did not decrease on repeated batch: {losses:?}"
    );
    // pinned frozen inputs must give the same execution path
    tr.pin_frozen(exec.as_mut()).unwrap();
    let pinned_loss = tr.train_step(exec.as_mut(), batch, &hp).unwrap();
    assert!(pinned_loss.is_finite() && pinned_loss < losses[0]);
    // eval produces one logits row per dev example
    let rows = tr.eval_logits(exec.as_mut(), &split.dev[..meta.cfg.batch + 3]).unwrap();
    assert_eq!(rows.len(), meta.cfg.batch + 3);
    assert!(rows.iter().all(|r| r.len() == meta.cfg.n_classes));
}

#[test]
fn native_training_is_deterministic() {
    let run = || {
        let mut exec = backend();
        let family = "glue_base_uni_c2";
        let meta = exec.meta(&format!("{family}_cls_train")).unwrap().clone();
        let w0 = init_base(&meta, 7);
        let mut tr = ClsTrainer::new(exec.as_ref(), family, 7, w0).unwrap();
        let split = glue::generate("sst2", 7, meta.cfg.seq, meta.cfg.vocab);
        let batch = &cls_batches(&split.train, meta.cfg.batch, 7, 0)[0];
        let hp = Hyper::default();
        let mut losses = Vec::new();
        for _ in 0..3 {
            losses.push(tr.train_step(exec.as_mut(), batch, &hp).unwrap());
        }
        (losses, tr.theta)
    };
    let (l1, t1) = run();
    let (l2, t2) = run();
    assert_eq!(l1, l2);
    assert_eq!(t1, t2);
}

/// The kernels-layer determinism contract, end to end: training is
/// bitwise identical at threads=1 and threads=4 (GEMM row panels and
/// attention tasks own disjoint output regions with a fixed
/// accumulation order), so the parallel kernels reproduce the
/// single-threaded losses exactly.
#[test]
fn native_training_is_thread_count_invariant() {
    let run = || {
        let mut exec = backend();
        let family = "glue_base_uni_c2";
        let meta = exec.meta(&format!("{family}_cls_train")).unwrap().clone();
        let w0 = init_base(&meta, 13);
        let mut tr = ClsTrainer::new(exec.as_ref(), family, 13, w0).unwrap();
        let split = glue::generate("sst2", 13, meta.cfg.seq, meta.cfg.vocab);
        let batch = &cls_batches(&split.train, meta.cfg.batch, 13, 0)[0];
        let hp = Hyper::default();
        let mut losses = Vec::new();
        for _ in 0..2 {
            losses.push(tr.train_step(exec.as_mut(), batch, &hp).unwrap());
        }
        (losses, tr.theta)
    };
    // RAII guard: the env-derived width comes back even if an assert
    // (or the run itself) panics mid-sweep, so a red run can't leave
    // the pinned width applied to every later test in the process
    let _threads = uni_lora::kernels::ThreadsGuard::new();
    uni_lora::kernels::set_threads(1);
    let (l1, t1) = run();
    uni_lora::kernels::set_threads(4);
    let (l4, t4) = run();
    assert_eq!(l1, l4, "losses must not depend on the thread count");
    assert_eq!(t1, t4, "trained theta must not depend on the thread count");
}

/// The `ProjectionOp` redesign's acceptance test: baselines that used
/// to bail with "eval/serve-only" on the native backend (vera's
/// diagonal scalings, fastfood's FWHT chain) now train end to end
/// through the registry vjp — >= 2 steps each with decreasing loss.
#[test]
fn native_trains_formerly_eval_only_baselines() {
    for (family, method) in [("glue_base_vera_c2", "vera"), ("glue_large_fastfood_c2", "fastfood")]
    {
        let mut exec = backend();
        let meta = exec.meta(&format!("{family}_cls_train")).unwrap().clone();
        assert_eq!(meta.cfg.method, method);
        let w0 = init_base(&meta, 21);
        let mut tr = ClsTrainer::new(exec.as_ref(), family, 21, w0).unwrap();
        let split = glue::generate("sst2", 21, meta.cfg.seq, meta.cfg.vocab);
        let batch = &cls_batches(&split.train, meta.cfg.batch, 21, 0)[0];
        let hp = Hyper { lr_theta: 5e-3, lr_head: 5e-2, wd: 0.0, epochs: 1 };
        let mut losses = Vec::new();
        for _ in 0..8 {
            losses.push(tr.train_step(exec.as_mut(), batch, &hp).unwrap());
        }
        assert!(losses.iter().all(|l| l.is_finite()), "{method}: {losses:?}");
        assert!(
            losses.last().unwrap() < &losses[0],
            "{method}: loss did not decrease on repeated batch: {losses:?}"
        );
        // the trainable vector itself moved (not just the cls head)
        let theta0 = uni_lora::projection::statics::init_theta(&meta.cfg, 21).unwrap();
        assert!(
            tr.theta.iter().zip(&theta0).any(|(a, b)| a != b),
            "{method}: theta untouched after 8 steps"
        );
    }
}

/// The acceptance-criteria smoke test: train a tiny `uni` config for
/// >= 2 steps on the native backend with decreasing loss, then serve a
/// decode request for the trained adapter through ServerHandle over TCP.
#[test]
fn native_train_then_serve_end_to_end() {
    let mut exec = backend();
    let base = "lm_uni";
    let meta = exec.meta(&format!("{base}_lm_train")).unwrap().clone();
    let w0 = init_base(&meta, 42);
    let mut tr = LmTrainer::new(exec.as_ref(), base, 11, w0.clone()).unwrap();
    let (split, _) = math_tasks::generate(11, meta.cfg.seq, 2 * meta.cfg.batch, 4);
    let batches = lm_batches(&split.train, meta.cfg.batch, 11, 0);
    let hp = Hyper { lr_theta: 2e-3, lr_head: 0.0, wd: 0.0, epochs: 1 };
    let mut losses = Vec::new();
    for _ in 0..4 {
        losses.push(tr.train_step(exec.as_mut(), &batches[0], &hp).unwrap());
    }
    assert!(losses.len() >= 2, "acceptance: at least 2 train steps");
    assert!(losses.iter().all(|l| l.is_finite()), "{losses:?}");
    assert!(losses.last().unwrap() < &losses[0], "loss did not decrease: {losses:?}");

    // register the trained adapter and serve it over TCP
    let registry = Registry::new();
    registry.insert(
        "math".into(),
        AdapterCheckpoint {
            seed: 11,
            method: "uni".into(),
            artifact: format!("{base}_lm_logits"),
            theta: tr.theta.clone(),
            head: vec![],
        },
    );
    let handle = serve(
        ServerConfig::new("127.0.0.1:0", format!("{base}_lm_logits")),
        exec,
        Arc::new(registry),
        meta.cfg.clone(),
        w0,
    )
    .unwrap();
    let mut client = Client::connect(handle.addr).unwrap();
    let toks = client.generate("math", vec![1, 21, 7, 14, 8, 17, 22], 3).unwrap();
    assert!(toks.len() <= 3);
    assert!(toks.iter().all(|&t| t >= 0 && (t as usize) < meta.cfg.vocab));
    let stats = client.stats().unwrap();
    assert!(stats.get("requests").unwrap().as_f64().unwrap() >= 1.0);
    handle.shutdown();
}

#[test]
fn native_server_roundtrip_and_batching() {
    let mut exec = backend();
    let art = "lm_uni_lm_logits";
    let meta = exec.meta(art).unwrap().clone();
    let w0 = init_base(&meta, 42);
    exec.prepare(art).unwrap();

    let registry = Registry::new();
    for i in 0..3u64 {
        registry.insert(
            format!("a{i}"),
            AdapterCheckpoint {
                seed: i,
                method: "uni".into(),
                artifact: art.into(),
                theta: init_theta(&meta.cfg, i).unwrap(),
                head: vec![],
            },
        );
    }
    let handle = serve(
        ServerConfig::new("127.0.0.1:0", art).with_workers(2),
        exec,
        Arc::new(registry),
        meta.cfg.clone(),
        w0,
    )
    .unwrap();

    let mut client = Client::connect(handle.addr).unwrap();
    // adapters listing
    match client.call(&uni_lora::server::protocol::Request::Adapters).unwrap() {
        uni_lora::server::protocol::Response::Adapters(a) => {
            assert_eq!(a, vec!["a0", "a1", "a2"])
        }
        other => panic!("{other:?}"),
    }
    // generation returns tokens (untrained model: content arbitrary)
    let toks = client.generate("a1", vec![1, 21, 7, 14, 8, 17, 22], 2).unwrap();
    assert!(toks.len() <= 2);
    // determinism: same adapter+prompt -> same generation
    let toks2 = client.generate("a1", vec![1, 21, 7, 14, 8, 17, 22], 2).unwrap();
    assert_eq!(toks, toks2);
    // unknown adapter -> error response, connection stays usable
    assert!(client.generate("nope", vec![1], 2).is_err());
    let toks3 = client.generate("a0", vec![1, 21, 7], 2).unwrap();
    assert!(toks3.len() <= 2);
    // stats reflect the traffic, including the serving-quality metrics
    // (tokens/s, TTFT, reconstruction-cache hit rate, slot occupancy)
    let stats = client.stats().unwrap();
    assert!(stats.get("requests").unwrap().as_f64().unwrap() >= 3.0);
    assert!(stats.get("steps").unwrap().as_f64().unwrap() >= 1.0);
    let generated = stats.get("generated_tokens").unwrap().as_f64().unwrap();
    if generated > 0.0 {
        assert!(stats.get("tokens_per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert!(stats.get("mean_ttft_ms").unwrap().as_f64().unwrap() > 0.0);
    }
    assert!(stats.get("mean_occupied_slots").unwrap().as_f64().unwrap() > 0.0);
    // a1 was decoded twice with the same theta: the second admission
    // must have hit the reconstruction cache
    let hit_rate = stats.get("recon_hit_rate").unwrap().as_f64().unwrap();
    assert!((0.0..=1.0).contains(&hit_rate), "{hit_rate}");
    assert!(hit_rate > 0.0, "repeat adapter must hit the reconstruction cache");
    // paged-K/V accounting is on the wire: nothing in flight once all
    // requests drained, and the retired sequences recycled their pages
    assert_eq!(stats.get("kv_bytes_in_flight").unwrap().as_f64().unwrap(), 0.0);
    assert!(stats.get("kv_page_churn").unwrap().as_f64().unwrap() >= 3.0);
    assert_eq!(stats.get("truncated_admits").unwrap().as_f64().unwrap(), 0.0);
    handle.shutdown();
}

#[test]
fn native_lm_decode_respects_prompt_and_eos() {
    let mut exec = backend();
    let meta = exec.meta("lm_uni_lm_train").unwrap().clone();
    let w0 = init_base(&meta, 42);
    let mut tr = LmTrainer::new(exec.as_ref(), "lm_uni", 42, w0).unwrap();
    let prompts = vec![vec![1, 21, 7, 14, 8, 17, 22], vec![1, 21, 9, 16, 5, 17, 22]];
    let gens = tr.greedy_decode(exec.as_mut(), &prompts, 3).unwrap();
    assert_eq!(gens.len(), 2);
    for g in &gens {
        assert!(g.len() <= 3);
        assert!(g.iter().all(|&t| t >= 0 && (t as usize) < meta.cfg.vocab));
    }
}

#[test]
fn native_pretrain_step_reduces_loss_over_steps() {
    use uni_lora::runtime::TensorIn;
    let mut exec = backend();
    let art = "pretrain_base_pretrain_lm";
    let meta = exec.meta(art).unwrap().clone();
    let cfg = meta.cfg.clone();
    let mut w0 = init_base(&meta, 3);
    let mut m = vec![0f32; meta.base_params];
    let mut v = vec![0f32; meta.base_params];
    let mut corpus =
        uni_lora::data::corpus::CorpusBatches::new(9, cfg.batch, cfg.seq, cfg.vocab);
    let (toks, labs) = corpus.next_batch();
    let mut losses = Vec::new();
    for step in 1..=6 {
        let out = exec
            .run(
                art,
                &[
                    TensorIn::F32(w0),
                    TensorIn::F32(m),
                    TensorIn::F32(v),
                    TensorIn::ScalarI32(step),
                    TensorIn::ScalarF32(1e-3),
                    TensorIn::ScalarF32(0.0),
                    TensorIn::I32(toks.clone()),
                    TensorIn::I32(labs.clone()),
                ],
            )
            .unwrap();
        let mut it = out.into_iter();
        w0 = it.next().unwrap().f32().unwrap();
        m = it.next().unwrap().f32().unwrap();
        v = it.next().unwrap().f32().unwrap();
        losses.push(it.next().unwrap().scalar_f32().unwrap());
    }
    assert!(losses.iter().all(|l| l.is_finite()), "{losses:?}");
    assert!(losses[5] < losses[0], "{losses:?}");
}
