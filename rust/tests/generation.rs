//! Generation-subsystem acceptance: beam search as an eval decode
//! mode (width 1 IS greedy), per-token streaming over a raw TCP
//! socket (the acceptance criterion: at least one frame arrives
//! before the sequence finishes), strict wire-level validation of
//! `generate` requests, and the sampled/greedy/stream stats counters
//! end to end through the server.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use uni_lora::adapters::{AdapterCheckpoint, Registry};
use uni_lora::coordinator::evaluator::{
    exact_match_accuracy, exact_match_accuracy_with, DecodeMode,
};
use uni_lora::coordinator::{init_base, LmTrainer};
use uni_lora::data::math_tasks;
use uni_lora::generation::SamplingParams;
use uni_lora::runtime::{Backend, NativeBackend};
use uni_lora::server::protocol::Response;
use uni_lora::server::server::Client;
use uni_lora::server::{serve, ServerConfig};

const ART: &str = "lm_uni_lm_logits";

fn backend() -> Box<dyn Backend> {
    Box::new(NativeBackend::new().unwrap())
}

/// Beam search with width 1 is exactly greedy decoding — same EOS,
/// window and budget rules in the same order — across the max_new
/// matrix; wider beams still obey the emission limits. The evaluator's
/// `DecodeMode` dispatch agrees: `Beam(1)` and temperature-0
/// `Sampled` score identically to the greedy harness.
#[test]
fn beam_width_one_is_exactly_greedy() {
    let mut exec = backend();
    let meta = exec.meta("lm_uni_lm_train").unwrap().clone();
    let w0 = init_base(&meta, 42);
    let mut tr = LmTrainer::new(exec.as_ref(), "lm_uni", 42, w0).unwrap();
    let t = meta.cfg.seq;
    let prompts = vec![
        vec![1, 21],
        vec![1, 21, 7, 14, 8, 17, 22],
        vec![5; t - 1], // fills the window on the first emission
        vec![6; t + 3], // prompt >= seq: stillborn
    ];
    for max_new in [0usize, 1, 8] {
        let greedy = tr.greedy_decode(exec.as_mut(), &prompts, max_new).unwrap();
        let beam1 = tr.beam_decode(exec.as_mut(), &prompts, max_new, 1).unwrap();
        assert_eq!(greedy, beam1, "width-1 beam must BE greedy, max_new = {max_new}");
    }
    let wide = tr.beam_decode(exec.as_mut(), &prompts, 6, 4).unwrap();
    assert_eq!(wide.len(), prompts.len());
    for (g, p) in wide.iter().zip(&prompts) {
        assert!(g.len() <= 6, "beam stream over budget: {g:?}");
        assert!(g.len() + p.len().min(t) <= t, "beam stream over the context window");
        assert!(g.iter().all(|&tok| tok >= 0 && (tok as usize) < meta.cfg.vocab));
    }
    assert!(wide.last().unwrap().is_empty(), "over-long prompt is stillborn at any width");

    // the eval harness dispatches all three modes to the same streams
    let (split, _) = math_tasks::generate(42, meta.cfg.seq, 2 * meta.cfg.batch, 4);
    let dev = &split.dev[..split.dev.len().min(4)];
    let base = exact_match_accuracy(&mut tr, exec.as_mut(), dev, 3).unwrap();
    let b1 = exact_match_accuracy_with(&mut tr, exec.as_mut(), dev, 3, &DecodeMode::Beam(1))
        .unwrap();
    let s0 = exact_match_accuracy_with(
        &mut tr,
        exec.as_mut(),
        dev,
        3,
        &DecodeMode::Sampled(SamplingParams::default()),
    )
    .unwrap();
    assert_eq!(base, b1, "Beam(1) eval must score exactly like greedy");
    assert_eq!(base, s0, "temperature-0 sampled eval must score exactly like greedy");
}

/// The streaming + stats acceptance test, against real wire bytes: a
/// raw TCP client sends `"stream":true` and receives one frame per
/// token BEFORE the terminal frame (EOS is biased out so the sequence
/// must run its full budget); strict parsing rejects unknown keys and
/// out-of-range fields with typed errors on a connection that stays
/// usable; and the sampled/greedy/stream counters come back through
/// `stats` with exact values for the traffic sent.
#[test]
fn streaming_over_raw_tcp_and_serving_stats_counters() {
    let mut exec = backend();
    let meta = exec.meta(ART).unwrap().clone();
    let w0 = init_base(&meta, 42);
    exec.prepare(ART).unwrap();
    let registry = Registry::new();
    registry.insert(
        "a0".into(),
        AdapterCheckpoint {
            seed: 5,
            method: "uni".into(),
            artifact: ART.into(),
            theta: uni_lora::projection::statics::init_theta(&meta.cfg, 5).unwrap(),
            head: vec![],
        },
    );
    let handle = serve(
        ServerConfig::new("127.0.0.1:0", ART).with_workers(1),
        exec,
        Arc::new(registry),
        meta.cfg.clone(),
        w0,
    )
    .unwrap();

    // --- raw socket: hand-written request line, frame-by-frame reads.
    // EOS (id 3) is biased far down so exactly max_new tokens stream.
    let stream = TcpStream::connect(handle.addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writeln!(
        writer,
        "{}",
        concat!(
            r#"{"op":"generate","adapter":"a0","prompt":[1,21,7],"max_new":4,"#,
            r#""sampling":{"logit_bias":[[3,-1000000000]]},"stream":true}"#
        )
    )
    .unwrap();
    let mut raw_streamed: Vec<i32> = Vec::new();
    let raw_final: Vec<i32> = loop {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        // pin the wire shape of the first per-token frame
        if raw_streamed.is_empty() && !line.contains(r#""done":true"#) {
            assert!(line.contains(r#""ok":true"#), "bad frame line: {line}");
            assert!(line.contains(r#""done":false"#), "bad frame line: {line}");
        }
        match Response::parse(&line).unwrap() {
            Response::Frame { token, done, tokens } => {
                if let Some(t) = token {
                    raw_streamed.push(t);
                }
                if done {
                    break tokens.unwrap_or_default();
                }
            }
            other => panic!("expected a frame, got {other:?}"),
        }
    };
    assert_eq!(raw_streamed.len(), 4, "EOS biased out: the budget must be the only limit");
    assert_eq!(raw_streamed, raw_final, "terminal frame must carry the streamed tokens");

    // --- strict parsing, over the same (still usable) connection
    let bad = [
        (
            r#"{"op":"generate","adapter":"a0","prompt":[1],"max_new":2,"bogus":1}"#,
            "unknown generate key",
        ),
        (r#"{"op":"generate","adapter":"a0","prompt":[1],"max_new":-3}"#, "max_new"),
        (r#"{"op":"generate","adapter":"a0","prompt":[1],"sampling":{"top_p":2.0}}"#, "top_p"),
    ];
    for (line, needle) in bad {
        writeln!(writer, "{line}").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        match Response::parse(&resp).unwrap() {
            Response::Error(e) => assert!(e.msg.contains(needle), "{needle}: {e}"),
            other => panic!("garbage must error, got {other:?}"),
        }
    }

    // --- client streaming equals buffered generation (greedy default)
    let mut client = Client::connect(handle.addr).unwrap();
    let prompt = vec![1, 21, 7, 14, 8, 17, 22];
    let (streamed, final_tokens) = client
        .generate_stream("a0", prompt.clone(), 3, SamplingParams::default())
        .unwrap();
    assert_eq!(streamed, final_tokens);
    let buffered = client.generate("a0", prompt.clone(), 3).unwrap();
    assert_eq!(streamed, buffered, "streaming must not change the tokens");

    // --- seeded sampling replays through the serving path
    let sampled = SamplingParams { temperature: 0.8, seed: 9, ..Default::default() };
    let s1 = client.generate_sampled("a0", prompt.clone(), 5, sampled.clone()).unwrap();
    let s2 = client.generate_sampled("a0", prompt.clone(), 5, sampled).unwrap();
    assert_eq!(s1, s2, "identical (request, seed) must replay identically over the wire");

    // --- counters: 3 greedy requests (raw stream, client stream,
    // buffered), 2 sampled, and one stream frame per streamed token
    let stats = client.stats().unwrap();
    let get = |k: &str| stats.get(k).unwrap().as_f64().unwrap();
    assert_eq!(get("greedy_requests"), 3.0);
    assert_eq!(get("sampled_requests"), 2.0);
    assert_eq!(get("stream_frames_sent"), (raw_streamed.len() + streamed.len()) as f64);
    if get("generated_tokens") > 0.0 {
        assert!(get("mean_ttft_ms") > 0.0, "streamed TTFT must be recorded");
    }
    handle.shutdown();
}
