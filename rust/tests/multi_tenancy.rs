//! Multi-tenant residency acceptance: 256 distinct adapters served
//! through one native session must stay factored end-to-end — no
//! densified reconstructions, and total adapter residency (registry
//! thetas + ReconCache dense entries) bounded by a handful of dense
//! reconstructions. This is the serving half of the paper's
//! one-vector-per-task storage story: resident cost scales with `d`
//! floats per tenant, not `2 * layers * h^2`.

use std::sync::Arc;
use uni_lora::adapters::{AdapterCheckpoint, Registry};
use uni_lora::projection::statics::{d_effective, gen_statics};
use uni_lora::runtime::{Backend, NativeBackend};
use uni_lora::session::{DecodeSession, SeqRequest, SessionOpts};

const ART: &str = "lm_uni_lm_logits";

#[test]
fn serves_256_adapters_within_factored_residency_budget() {
    let mut exec = NativeBackend::new().unwrap();
    let cache = exec.recon_cache();
    let meta = exec.meta(ART).unwrap().clone();
    let cfg = meta.cfg.clone();
    let w0 = Arc::new(uni_lora::coordinator::init_base(&meta, 7));
    let statics = Arc::new(gen_statics(&cfg, 7).unwrap());
    let d = d_effective(&cfg);

    // 256 distinct tenants: same projection statics, per-tenant theta
    let n_adapters = 256usize;
    let registry = Registry::new();
    for i in 0..n_adapters {
        let theta: Vec<f32> =
            uni_lora::rng::normals(i as u64, d).iter().map(|v| 0.05 * v).collect();
        registry.insert(
            format!("a{i}"),
            AdapterCheckpoint {
                seed: 7,
                method: cfg.method.clone(),
                artifact: ART.into(),
                theta,
                head: vec![],
            },
        );
    }
    assert_eq!(registry.len(), n_adapters);

    // round-robin all 256 tenants through a 16-slot session; every
    // arrival is a distinct adapter, so the default cost model keeps
    // every slot factored
    let opts = SessionOpts::with_slots(16);
    let mut sess = exec.begin_decode(ART, w0.clone(), &opts).unwrap();
    let mut pending: Vec<String> = registry.names();
    pending.reverse();
    let mut generated = 0usize;
    while sess.active() > 0 || !pending.is_empty() {
        while sess.free_slots() > 0 {
            let Some(name) = pending.pop() else { break };
            let ckpt = registry.get(&name).unwrap();
            sess.admit(SeqRequest {
                adapter: name,
                theta: Arc::new(ckpt.theta),
                statics: statics.clone(),
                prompt: vec![1, 2, 3],
                max_new: 2,
            })
            .unwrap();
        }
        for ev in sess.step(&mut exec).unwrap() {
            if ev.token.is_some() {
                generated += 1;
            }
        }
    }
    let st = sess.stats();
    sess.finish();

    assert_eq!(st.admitted, n_adapters as u64);
    assert_eq!(
        (st.factored_admits, st.dense_admits),
        (n_adapters as u64, 0),
        "distinct tenants must all admit factored under the default cost model"
    );
    assert_eq!(generated, n_adapters * 2, "every tenant decodes its budget");

    // residency budget: thetas + any dense reconstructions must fit in
    // ~4 dense reconstructions' worth of memory. One dense recon is
    // 2 * layers * h^2 floats (q and v deltas per layer).
    let dense_bytes = 2 * cfg.layers * cfg.hidden * cfg.hidden * std::mem::size_of::<f32>();
    assert_eq!(cache.len(), 0, "no adapter should have been densified");
    assert_eq!(cache.resident_bytes(), 0);
    let resident = registry.theta_bytes() + cache.resident_bytes();
    assert!(
        resident <= 4 * dense_bytes,
        "256 tenants resident in {resident} bytes exceeds 4 dense recons ({})",
        4 * dense_bytes
    );
}
