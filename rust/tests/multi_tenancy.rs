//! Multi-tenant residency acceptance: 256 distinct adapters served
//! through one native session must stay factored end-to-end — no
//! densified reconstructions, and total adapter residency (registry
//! thetas + ReconCache dense entries) bounded by a handful of dense
//! reconstructions. This is the serving half of the paper's
//! one-vector-per-task storage story: resident cost scales with `d`
//! floats per tenant, not `2 * layers * h^2`.

use std::sync::Arc;
use uni_lora::adapters::{AdapterCheckpoint, Registry};
use uni_lora::generation::SamplingParams;
use uni_lora::projection::statics::{d_effective, gen_statics};
use uni_lora::runtime::{Backend, NativeBackend};
use uni_lora::session::{DecodeSession, SeqRequest, SessionOpts};

const ART: &str = "lm_uni_lm_logits";

#[test]
fn serves_256_adapters_within_factored_residency_budget() {
    let mut exec = NativeBackend::new().unwrap();
    let cache = exec.recon_cache();
    let meta = exec.meta(ART).unwrap().clone();
    let cfg = meta.cfg.clone();
    let w0 = Arc::new(uni_lora::coordinator::init_base(&meta, 7));
    let statics = Arc::new(gen_statics(&cfg, 7).unwrap());
    let d = d_effective(&cfg);

    // 256 distinct tenants: same projection statics, per-tenant theta
    let n_adapters = 256usize;
    let registry = Registry::new();
    for i in 0..n_adapters {
        let theta: Vec<f32> =
            uni_lora::rng::normals(i as u64, d).iter().map(|v| 0.05 * v).collect();
        registry.insert(
            format!("a{i}"),
            AdapterCheckpoint {
                seed: 7,
                method: cfg.method.clone(),
                artifact: ART.into(),
                theta,
                head: vec![],
            },
        );
    }
    assert_eq!(registry.len(), n_adapters);

    // round-robin all 256 tenants through a 16-slot session; every
    // arrival is a distinct adapter, so the default cost model keeps
    // every slot factored
    let opts = SessionOpts::with_slots(16);
    let mut sess = exec.begin_decode(ART, w0.clone(), &opts).unwrap();
    let mut pending: Vec<String> = registry.names();
    pending.reverse();
    let mut generated = 0usize;
    while sess.active() > 0 || !pending.is_empty() {
        while sess.free_slots() > 0 {
            let Some(name) = pending.pop() else { break };
            let ckpt = registry.get(&name).unwrap();
            sess.admit(SeqRequest {
                request_id: 0,
                adapter: name,
                theta: Arc::new(ckpt.theta),
                statics: statics.clone(),
                prompt: vec![1, 2, 3],
                max_new: 2,
                sampling: SamplingParams::default(),
            })
            .unwrap();
        }
        for ev in sess.step(&mut exec).unwrap() {
            if ev.token.is_some() {
                generated += 1;
            }
        }
    }
    let st = sess.stats();
    sess.finish();

    assert_eq!(st.admitted, n_adapters as u64);
    assert_eq!(
        (st.factored_admits, st.dense_admits),
        (n_adapters as u64, 0),
        "distinct tenants must all admit factored under the default cost model"
    );
    assert_eq!(generated, n_adapters * 2, "every tenant decodes its budget");

    // residency budget: thetas + any dense reconstructions must fit in
    // ~4 dense reconstructions' worth of memory. One dense recon is
    // 2 * layers * h^2 floats (q and v deltas per layer).
    let dense_bytes = 2 * cfg.layers * cfg.hidden * cfg.hidden * std::mem::size_of::<f32>();
    assert_eq!(cache.len(), 0, "no adapter should have been densified");
    assert_eq!(cache.resident_bytes(), 0);
    let resident = registry.theta_bytes() + cache.resident_bytes();
    assert!(
        resident <= 4 * dense_bytes,
        "256 tenants resident in {resident} bytes exceeds 4 dense recons ({})",
        4 * dense_bytes
    );
}

/// Arena lifecycle fuzz: seeded-random admit/step interleavings of
/// heterogeneous adapters through a session whose K/V budget is
/// EXACTLY `slots` pages. Every sequence here fits one page, so any
/// leaked page or reservation makes a later admission fail, and any
/// page still held after the drain shows up in the session gauge.
#[test]
fn kv_arena_churn_fuzz_leaks_no_pages() {
    let mut exec = NativeBackend::new().unwrap();
    let meta = exec.meta(ART).unwrap().clone();
    let cfg = meta.cfg.clone();
    let w0 = Arc::new(uni_lora::coordinator::init_base(&meta, 19));
    let statics = Arc::new(gen_statics(&cfg, 19).unwrap());
    let d = d_effective(&cfg);
    let thetas: Vec<Arc<Vec<f32>>> = (0..3)
        .map(|i| Arc::new(uni_lora::rng::normals(300 + i, d).iter().map(|v| 0.05 * v).collect()))
        .collect();

    // prompt (1..=4) + max_new (0..=3) <= 7 tokens <= one page per
    // live sequence, so `slots` pages is the exact worst case
    let slots = 4usize;
    let opts = SessionOpts::with_slots(slots).with_kv_pages(slots);
    let mut sess = exec.begin_decode(ART, w0.clone(), &opts).unwrap();

    // deterministic LCG stand-in for an RNG: the point is interleaving
    // variety, not entropy
    let mut state = 0x2545f4914f6cdd1du64;
    let mut rnd = move |m: usize| {
        state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        ((state >> 33) as usize) % m
    };
    let total = 64usize;
    let mut admitted = 0usize;
    let mut one_page_seqs = 0u64; // non-stillborn => exactly one page
    while admitted < total || sess.active() > 0 {
        let can_admit = admitted < total && sess.free_slots() > 0;
        if can_admit && (sess.active() == 0 || rnd(2) == 0) {
            let plen = 1 + rnd(4);
            let max_new = rnd(4); // 0 => stillborn: reserves no pages
            let adm = sess
                .admit(SeqRequest {
                    request_id: 0,
                    adapter: format!("t{}", admitted % 3),
                    theta: thetas[admitted % 3].clone(),
                    statics: statics.clone(),
                    prompt: vec![(1 + (admitted % 7)) as i32; plen],
                    max_new,
                    sampling: SamplingParams::default(),
                })
                .expect("a free slot under an exact budget must admit; a failure is a page leak");
            assert!(!adm.truncated);
            if max_new > 0 {
                one_page_seqs += 1;
            }
            admitted += 1;
        } else {
            sess.step(&mut exec).unwrap();
        }
    }
    let st = sess.stats();
    assert_eq!(st.admitted, total as u64);
    assert_eq!(st.kv_bytes_in_flight, 0, "drained session must hold no pages");
    assert_eq!(
        st.kv_page_churn, one_page_seqs,
        "every retired non-stillborn sequence recycles exactly its one page"
    );

    // the budget is fully recoverable: a fresh full-occupancy wave
    // still admits after all that churn
    for k in 0..slots {
        sess.admit(SeqRequest {
            request_id: 0,
            adapter: format!("t{}", k % 3),
            theta: thetas[k % 3].clone(),
            statics: statics.clone(),
            prompt: vec![1, 2],
            max_new: 2,
            sampling: SamplingParams::default(),
        })
        .unwrap();
    }
    while sess.active() > 0 {
        sess.step(&mut exec).unwrap();
    }
    assert_eq!(sess.stats().kv_page_churn, one_page_seqs + slots as u64);
    sess.finish();
    assert_eq!(sess.stats().kv_bytes_in_flight, 0);
}

/// Admission fails with the typed budget error exactly when the token
/// budget runs out — not a slot earlier, not a slot later — and the
/// refused request fits again once a sequence retires.
#[test]
fn admission_rejects_exactly_at_kv_budget_exhaustion() {
    use uni_lora::runtime::native::kv_arena::KvBudgetExhausted;

    let mut exec = NativeBackend::new().unwrap();
    let meta = exec.meta(ART).unwrap().clone();
    let cfg = meta.cfg.clone();
    let w0 = Arc::new(uni_lora::coordinator::init_base(&meta, 23));
    let statics = Arc::new(gen_statics(&cfg, 23).unwrap());
    let d = d_effective(&cfg);
    let theta: Arc<Vec<f32>> =
        Arc::new(uni_lora::rng::normals(91, d).iter().map(|v| 0.05 * v).collect());
    let mk = |k: usize| SeqRequest {
        request_id: 0,
        adapter: format!("b{k}"),
        theta: theta.clone(),
        statics: statics.clone(),
        prompt: vec![1, 2, 3],
        max_new: 2,
        sampling: SamplingParams::default(),
    };

    // three slots but only two pages: the token budget, not the slot
    // count, is the binding constraint
    let opts = SessionOpts::with_slots(3).with_kv_pages(2);
    let mut sess = exec.begin_decode(ART, w0.clone(), &opts).unwrap();
    sess.admit(mk(0)).unwrap();
    sess.admit(mk(1)).unwrap();
    assert_eq!(sess.free_slots(), 1, "a slot is free; only the budget refuses");
    let err = sess.admit(mk(2)).unwrap_err();
    let b = err
        .downcast_ref::<KvBudgetExhausted>()
        .unwrap_or_else(|| panic!("expected KvBudgetExhausted, got: {err}"));
    assert_eq!((b.needed_pages, b.free_pages, b.budget_pages), (1, 0, 2));
    assert_eq!(sess.active(), 2, "the refused admission must not occupy a slot");

    // retirement returns the pages; the identical request now admits
    while sess.active() > 0 {
        sess.step(&mut exec).unwrap();
    }
    let adm = sess.admit(mk(2)).unwrap();
    assert!(!adm.truncated);
    while sess.active() > 0 {
        sess.step(&mut exec).unwrap();
    }
    let st = sess.stats();
    assert_eq!((st.admitted, st.kv_bytes_in_flight), (3, 0));
    sess.finish();
}
