//! Decode-session acceptance suite.
//!
//! Parity: the incremental KV-cache session AND the full-forward
//! fallback session must emit greedy token streams identical to the
//! legacy `decode_with` loop — across short, medium, window-filling
//! and over-long (prompt >= seq) prompts, and for max_new ∈ {0, 1, N}.
//! CI runs this file under both `UNI_LORA_KERNELS=scalar` (where the
//! per-element accumulation contract makes the streams bit-identical)
//! and `=simd` (argmax-equal: per-element k-order is row-count
//! independent within a tier, so the streams still match exactly).
//!
//! Continuous batching: per-request outputs are invariant to arrival
//! order, slot assignment and slot count.

use std::sync::Arc;
use uni_lora::coordinator::trainer::decode_with;
use uni_lora::generation::SamplingParams;
use uni_lora::projection::statics::{d_effective, gen_statics, Static};
use uni_lora::runtime::{Backend, NativeBackend};
use uni_lora::session::{
    decode_greedy, decode_sampled, drive_greedy, drive_sampled, DecodeSession, FallbackSession,
    SeqRequest, SessionOpts,
};

const ART: &str = "lm_uni_lm_logits";

struct Fixture {
    exec: Box<dyn Backend>,
    cfg: uni_lora::config::ModelCfg,
    theta: Vec<f32>,
    w0: Vec<f32>,
    statics: Vec<Static>,
}

fn fixture(seed: u64) -> Fixture {
    let exec: Box<dyn Backend> = Box::new(NativeBackend::new().unwrap());
    let meta = exec.meta(ART).unwrap().clone();
    let cfg = meta.cfg.clone();
    let w0 = uni_lora::coordinator::init_base(&meta, seed);
    // nonzero theta so the adapted q/v path is exercised
    let theta: Vec<f32> = uni_lora::rng::normals(seed.wrapping_add(13), d_effective(&cfg))
        .iter()
        .map(|v| 0.05 * v)
        .collect();
    let statics = gen_statics(&cfg, seed).unwrap();
    Fixture { exec, cfg, theta, w0, statics }
}

/// >= 3 prompt lengths, including window-filling and prompt >= seq.
fn parity_prompts(cfg: &uni_lora::config::ModelCfg) -> Vec<Vec<i32>> {
    let t = cfg.seq;
    vec![
        vec![1, 21],                                  // short
        vec![1, 21, 7, 14, 8, 17, 22],                // medium
        (0..(t as i32 - 2)).map(|i| 1 + (i % 9)).collect(), // nearly window-filling
        vec![5; t - 1],                               // fills on the first emission
        vec![6; t + 3],                               // prompt >= seq: no tokens
    ]
}

#[test]
fn incremental_session_matches_legacy_full_forward() {
    let mut fx = fixture(42);
    let prompts = parity_prompts(&fx.cfg);
    for max_new in [0usize, 1, 12] {
        let legacy = decode_with(
            fx.exec.as_mut(),
            ART,
            &fx.cfg,
            &fx.theta,
            &fx.w0,
            &fx.statics,
            &prompts,
            max_new,
        )
        .unwrap();
        let session = decode_greedy(
            fx.exec.as_mut(),
            ART,
            "parity",
            Arc::new(fx.theta.clone()),
            Arc::new(fx.w0.clone()),
            Arc::new(fx.statics.clone()),
            &prompts,
            max_new,
            &SessionOpts::from_env(),
        )
        .unwrap();
        assert_eq!(legacy, session, "max_new = {max_new}");
        if max_new == 0 {
            assert!(session.iter().all(|g| g.is_empty()));
        }
        if max_new >= 1 {
            // the over-long prompt generates nothing, ever
            assert!(session.last().unwrap().is_empty());
        }
    }
}

/// The session result must not depend on how the work is chunked into
/// slots (1 slot = fully serial, many slots = fully concurrent).
#[test]
fn incremental_session_is_slot_count_invariant() {
    let mut fx = fixture(11);
    let prompts = parity_prompts(&fx.cfg);
    let mut streams = Vec::new();
    for slots in [1usize, 2, 8] {
        let mut sess = fx
            .exec
            .begin_decode(ART, Arc::new(fx.w0.clone()), &SessionOpts::with_slots(slots))
            .unwrap();
        let out = drive_greedy(
            sess.as_mut(),
            fx.exec.as_mut(),
            "inv",
            Arc::new(fx.theta.clone()),
            Arc::new(fx.statics.clone()),
            &prompts,
            12,
        )
        .unwrap();
        sess.finish();
        streams.push(out);
    }
    assert_eq!(streams[0], streams[1]);
    assert_eq!(streams[0], streams[2]);
}

/// The full-forward fallback (what a PJRT backend would run through
/// the default `begin_decode`) emits the same streams too.
#[test]
fn fallback_session_matches_legacy_full_forward() {
    let mut fx = fixture(7);
    let prompts = parity_prompts(&fx.cfg);
    let legacy = decode_with(
        fx.exec.as_mut(),
        ART,
        &fx.cfg,
        &fx.theta,
        &fx.w0,
        &fx.statics,
        &prompts,
        6,
    )
    .unwrap();
    let meta = fx.exec.meta(ART).unwrap().clone();
    let mut sess =
        FallbackSession::new(meta, Arc::new(fx.w0.clone()), &SessionOpts::from_env()).unwrap();
    let out = drive_greedy(
        sess.as_mut(),
        fx.exec.as_mut(),
        "fb",
        Arc::new(fx.theta.clone()),
        Arc::new(fx.statics.clone()),
        &prompts,
        6,
    )
    .unwrap();
    assert_eq!(legacy, out);
}

/// Continuous-batching invariance: with a heterogeneous mix of
/// adapters, per-request outputs are independent of arrival order and
/// slot assignment. Expected streams come from decoding each request
/// alone through the legacy loop.
#[test]
fn continuous_batching_is_arrival_order_invariant() {
    let mut fx = fixture(3);
    let theta_a = fx.theta.clone();
    let theta_b: Vec<f32> =
        uni_lora::rng::normals(99, theta_a.len()).iter().map(|v| 0.05 * v).collect();
    let statics = Arc::new(fx.statics.clone());
    let prompts = parity_prompts(&fx.cfg);
    let max_new = 8usize;

    // request k uses adapter (k % 2) and prompt k
    let reqs: Vec<(String, Vec<f32>, Vec<i32>)> = prompts
        .iter()
        .enumerate()
        .map(|(k, p)| {
            let (name, th) =
                if k % 2 == 0 { ("a", theta_a.clone()) } else { ("b", theta_b.clone()) };
            (name.to_string(), th, p.clone())
        })
        .collect();

    // expected: each adapter's requests decoded through the legacy
    // loop, isolated from the other adapter (legacy rows are
    // independent, so one grouped call == each request decoded alone)
    let mut expected: Vec<Vec<i32>> = vec![Vec::new(); reqs.len()];
    for (name, th) in [("a", &theta_a), ("b", &theta_b)] {
        let idxs: Vec<usize> = (0..reqs.len()).filter(|&k| reqs[k].0 == name).collect();
        let subset: Vec<Vec<i32>> = idxs.iter().map(|&k| reqs[k].2.clone()).collect();
        let outs = decode_with(
            fx.exec.as_mut(),
            ART,
            &fx.cfg,
            th,
            &fx.w0,
            &fx.statics,
            &subset,
            max_new,
        )
        .unwrap();
        for (k, o) in idxs.into_iter().zip(outs) {
            expected[k] = o;
        }
    }

    // helper: run the mixed workload through one session with a given
    // admission order and staggering
    let mut run = |slots: usize, order: &[usize], stagger: bool| -> Vec<Vec<i32>> {
        let mut sess = fx
            .exec
            .begin_decode(ART, Arc::new(fx.w0.clone()), &SessionOpts::with_slots(slots))
            .unwrap();
        let mut out: Vec<Vec<i32>> = vec![Vec::new(); reqs.len()];
        let mut owner: Vec<Option<usize>> = vec![None; sess.slots()];
        let mut pending: Vec<usize> = order.to_vec();
        pending.reverse(); // pop from the back = admission order
        loop {
            // staggered arrivals: admit at most one request per step
            let quota = if stagger { 1 } else { usize::MAX };
            let mut admitted = 0;
            while sess.free_slots() > 0 && admitted < quota {
                let Some(k) = pending.pop() else { break };
                let (name, th, p) = &reqs[k];
                let slot = sess
                    .admit(SeqRequest {
                        request_id: 0,
                        adapter: name.clone(),
                        theta: Arc::new(th.clone()),
                        statics: statics.clone(),
                        prompt: p.clone(),
                        max_new,
                        sampling: SamplingParams::default(),
                    })
                    .unwrap()
                    .slot;
                owner[slot] = Some(k);
                admitted += 1;
            }
            if sess.active() == 0 {
                if pending.is_empty() {
                    break;
                }
                continue;
            }
            for ev in sess.step(fx.exec.as_mut()).unwrap() {
                let k = owner[ev.slot].unwrap();
                if let Some(t) = ev.token {
                    out[k].push(t);
                }
                if ev.done {
                    owner[ev.slot] = None;
                }
            }
        }
        sess.finish();
        out
    };

    let order_fwd: Vec<usize> = (0..reqs.len()).collect();
    let order_rev: Vec<usize> = (0..reqs.len()).rev().collect();
    assert_eq!(run(2, &order_fwd, false), expected, "slots=2, FIFO arrivals");
    assert_eq!(run(3, &order_rev, true), expected, "slots=3, reversed staggered arrivals");
    assert_eq!(run(reqs.len(), &order_rev, false), expected, "all-at-once, reversed");
}

/// Tentpole parity: the factored execution path (rank-r factor
/// application, no densified deltas) and the dense path both emit
/// token streams identical to the legacy loop across the full
/// prompt x max_new matrix. The execution mode is pinned through
/// `SessionOpts` (threshold 1 = always dense, usize::MAX = never),
/// so the test is env-free and runs under both kernel tiers in CI.
#[test]
fn factored_and_dense_pinned_sessions_match_legacy() {
    let mut fx = fixture(23);
    let prompts = parity_prompts(&fx.cfg);
    for max_new in [0usize, 1, 12] {
        let legacy = decode_with(
            fx.exec.as_mut(),
            ART,
            &fx.cfg,
            &fx.theta,
            &fx.w0,
            &fx.statics,
            &prompts,
            max_new,
        )
        .unwrap();
        for (mode, threshold) in [("factored", usize::MAX), ("dense", 1usize)] {
            let opts = SessionOpts::with_slots(0).with_dense_threshold(threshold);
            let mut sess =
                fx.exec.begin_decode(ART, Arc::new(fx.w0.clone()), &opts).unwrap();
            let out = drive_greedy(
                sess.as_mut(),
                fx.exec.as_mut(),
                mode,
                Arc::new(fx.theta.clone()),
                Arc::new(fx.statics.clone()),
                &prompts,
                max_new,
            )
            .unwrap();
            let st = sess.stats();
            sess.finish();
            assert_eq!(legacy, out, "{mode}, max_new = {max_new}");
            if mode == "factored" {
                assert_eq!(st.dense_admits, 0, "pinned factored must never densify");
                assert!(st.factored_admits > 0);
            } else {
                assert_eq!(st.factored_admits, 0, "pinned dense must never run factored");
                assert!(st.dense_admits > 0);
            }
        }
    }
}

/// Mixed-mode session: with the dense threshold at 2, a hot adapter's
/// later slots densify while its first slot and the cold adapter stay
/// factored — and every request still matches its adapter's legacy
/// stream even though the session mixes execution modes.
#[test]
fn heterogeneous_mixed_mode_session_matches_legacy() {
    let mut fx = fixture(31);
    let theta_x = fx.theta.clone();
    let theta_y: Vec<f32> =
        uni_lora::rng::normals(77, theta_x.len()).iter().map(|v| 0.05 * v).collect();
    let prompts = parity_prompts(&fx.cfg);
    let max_new = 8usize;
    // x is hot (3 concurrent slots), y is cold (1 slot)
    let reqs: Vec<(&str, &Vec<f32>, Vec<i32>)> = vec![
        ("x", &theta_x, prompts[0].clone()),
        ("x", &theta_x, prompts[1].clone()),
        ("x", &theta_x, prompts[2].clone()),
        ("y", &theta_y, prompts[0].clone()),
    ];

    // expected: each adapter's requests decoded alone via the legacy loop
    let mut expected: Vec<Vec<i32>> = vec![Vec::new(); reqs.len()];
    for (name, th) in [("x", &theta_x), ("y", &theta_y)] {
        let idxs: Vec<usize> = (0..reqs.len()).filter(|&k| reqs[k].0 == name).collect();
        let subset: Vec<Vec<i32>> = idxs.iter().map(|&k| reqs[k].2.clone()).collect();
        let outs = decode_with(
            fx.exec.as_mut(),
            ART,
            &fx.cfg,
            th,
            &fx.w0,
            &fx.statics,
            &subset,
            max_new,
        )
        .unwrap();
        for (k, o) in idxs.into_iter().zip(outs) {
            expected[k] = o;
        }
    }

    let opts = SessionOpts::with_slots(reqs.len()).with_dense_threshold(2);
    let mut sess = fx.exec.begin_decode(ART, Arc::new(fx.w0.clone()), &opts).unwrap();
    let statics = Arc::new(fx.statics.clone());
    let mut owner: Vec<Option<usize>> = vec![None; sess.slots()];
    let mut out: Vec<Vec<i32>> = vec![Vec::new(); reqs.len()];
    for (k, (name, th, p)) in reqs.iter().enumerate() {
        let slot = sess
            .admit(SeqRequest {
                request_id: 0,
                adapter: name.to_string(),
                theta: Arc::new((*th).clone()),
                statics: statics.clone(),
                prompt: p.clone(),
                max_new,
                sampling: SamplingParams::default(),
            })
            .unwrap()
            .slot;
        owner[slot] = Some(k);
    }
    while sess.active() > 0 {
        for ev in sess.step(fx.exec.as_mut()).unwrap() {
            let k = owner[ev.slot].unwrap();
            if let Some(t) = ev.token {
                out[k].push(t);
            }
            if ev.done {
                owner[ev.slot] = None;
            }
        }
    }
    let st = sess.stats();
    sess.finish();
    assert_eq!(out, expected);
    // admit order x,x,x,y with threshold 2: the first x slot admits
    // factored (0 active + 1 < 2), the 2nd and 3rd densify, y admits
    // factored again
    assert_eq!((st.factored_admits, st.dense_admits), (2, 2));
}

/// Tentpole determinism contract: the fused batched step (the
/// `UNI_LORA_FUSED_STEP` default) and per-slot stepping emit IDENTICAL
/// token streams across the whole prompt matrix — batching is
/// scheduling-only, never numeric. Run over a heterogeneous
/// two-adapter mix so the fused step really batches distinct execs.
#[test]
fn fused_step_streams_equal_per_slot_streams() {
    let mut fx = fixture(61);
    let theta_b: Vec<f32> =
        uni_lora::rng::normals(88, fx.theta.len()).iter().map(|v| 0.05 * v).collect();
    let prompts = parity_prompts(&fx.cfg);
    let statics = Arc::new(fx.statics.clone());
    let mut run = |fused: bool| -> Vec<Vec<i32>> {
        let opts = SessionOpts::with_slots(prompts.len()).with_fused_step(fused);
        let mut sess = fx.exec.begin_decode(ART, Arc::new(fx.w0.clone()), &opts).unwrap();
        let mut out: Vec<Vec<i32>> = vec![Vec::new(); prompts.len()];
        let mut owner: Vec<Option<usize>> = vec![None; sess.slots()];
        for (k, p) in prompts.iter().enumerate() {
            let (name, th) = if k % 2 == 0 { ("fa", &fx.theta) } else { ("fb", &theta_b) };
            let slot = sess
                .admit(SeqRequest {
                    request_id: 0,
                    adapter: name.into(),
                    theta: Arc::new(th.clone()),
                    statics: statics.clone(),
                    prompt: p.clone(),
                    max_new: 10,
                    sampling: SamplingParams::default(),
                })
                .unwrap()
                .slot;
            owner[slot] = Some(k);
        }
        while sess.active() > 0 {
            for ev in sess.step(fx.exec.as_mut()).unwrap() {
                let k = owner[ev.slot].unwrap();
                if let Some(t) = ev.token {
                    out[k].push(t);
                }
                if ev.done {
                    owner[ev.slot] = None;
                }
            }
        }
        sess.finish();
        out
    };
    assert_eq!(run(true), run(false));
}

/// Satellite: prompt truncation at admission is surfaced, not silent.
/// Boundary: `len == seq-1` and `len == seq` admit untruncated;
/// `len == seq+1` sets the flag (and the session counter). Over-window
/// prompts stay stillborn — admitted, one step, zero tokens — exactly
/// the legacy stream, so surfacing never changes decode behavior.
#[test]
fn admission_surfaces_prompt_truncation_at_the_window_boundary() {
    let mut fx = fixture(17);
    let t = fx.cfg.seq;
    let mut sess = fx
        .exec
        .begin_decode(ART, Arc::new(fx.w0.clone()), &SessionOpts::with_slots(3))
        .unwrap();
    let mk = |prompt: Vec<i32>| SeqRequest {
        request_id: 0,
        adapter: "tr".into(),
        theta: Arc::new(fx.theta.clone()),
        statics: Arc::new(fx.statics.clone()),
        prompt,
        max_new: 4,
        sampling: SamplingParams::default(),
    };
    let under = sess.admit(mk(vec![3; t - 1])).unwrap();
    assert!(!under.truncated, "len == seq-1 fits untruncated");
    let exact = sess.admit(mk(vec![3; t])).unwrap();
    assert!(!exact.truncated, "len == seq fills the window but loses nothing");
    let over = sess.admit(mk(vec![3; t + 1])).unwrap();
    assert!(over.truncated, "len == seq+1 must surface the cut");
    assert_eq!(sess.stats().truncated_admits, 1);

    let mut emitted: Vec<Vec<i32>> = vec![Vec::new(); 3];
    while sess.active() > 0 {
        for ev in sess.step(fx.exec.as_mut()).unwrap() {
            if let Some(tok) = ev.token {
                emitted[ev.slot].push(tok);
            }
        }
    }
    sess.finish();
    // window-filling and truncated sequences generate nothing (legacy
    // stillborn rows); the seq-1 prompt emits at most its window-
    // filling token (zero if the first argmax is EOS)
    assert!(emitted[under.slot].len() <= 1);
    assert!(emitted[exact.slot].is_empty());
    assert!(emitted[over.slot].is_empty());

    // the full-forward fallback surfaces the same flag and counter
    let meta = fx.exec.meta(ART).unwrap().clone();
    let mut fb =
        FallbackSession::new(meta, Arc::new(fx.w0.clone()), &SessionOpts::with_slots(2)).unwrap();
    assert!(!fb.admit(mk(vec![3; t])).unwrap().truncated);
    assert!(fb.admit(mk(vec![3; t + 1])).unwrap().truncated);
    assert_eq!(fb.stats().truncated_admits, 1);
    fb.finish();
}

/// Admission guards: empty prompts are rejected up front, full
/// sessions refuse instead of overwriting, and wrong-kind artifacts
/// can't open sessions.
#[test]
fn session_admission_guards() {
    let mut fx = fixture(5);
    let mut sess = fx
        .exec
        .begin_decode(ART, Arc::new(fx.w0.clone()), &SessionOpts::with_slots(1))
        .unwrap();
    let mk = |prompt: Vec<i32>| SeqRequest {
        request_id: 0,
        adapter: "g".into(),
        theta: Arc::new(fx.theta.clone()),
        statics: Arc::new(fx.statics.clone()),
        prompt,
        max_new: 4,
        sampling: SamplingParams::default(),
    };
    assert!(sess.admit(mk(vec![])).is_err(), "empty prompt must be rejected");
    assert_eq!(sess.active(), 0, "failed admission must not occupy a slot");
    sess.admit(mk(vec![1, 2])).unwrap();
    assert_eq!((sess.active(), sess.free_slots()), (1, 0));
    assert!(sess.admit(mk(vec![1, 2])).is_err(), "full session must refuse");
    sess.finish();
    assert_eq!(sess.active(), 0);

    // lm_train is not a decodable artifact kind
    let err = fx
        .exec
        .begin_decode("lm_uni_lm_train", Arc::new(fx.w0.clone()), &SessionOpts::from_env())
        .map(|_| ())
        .unwrap_err()
        .to_string();
    assert!(err.contains("lm_logits"), "{err}");
}

/// Satellite: temperature-0 sampling is bit-equal to the legacy greedy
/// decode across the full prompt x max_new matrix, on the incremental
/// session AND the full-forward fallback, regardless of the seed —
/// the greedy fast path consumes zero RNG draws, so the seed cannot
/// leak into the stream. CI repeats this under both kernel tiers and
/// with `UNI_LORA_FUSED_STEP=0`.
#[test]
fn temperature_zero_sampling_matches_legacy_greedy() {
    let mut fx = fixture(29);
    let prompts = parity_prompts(&fx.cfg);
    let sampling = SamplingParams { seed: 0xDEAD_BEEF, ..Default::default() };
    assert!(sampling.is_greedy());
    for max_new in [0usize, 1, 12] {
        let legacy = decode_with(
            fx.exec.as_mut(),
            ART,
            &fx.cfg,
            &fx.theta,
            &fx.w0,
            &fx.statics,
            &prompts,
            max_new,
        )
        .unwrap();
        let native = decode_sampled(
            fx.exec.as_mut(),
            ART,
            "t0",
            Arc::new(fx.theta.clone()),
            Arc::new(fx.w0.clone()),
            Arc::new(fx.statics.clone()),
            &prompts,
            max_new,
            &sampling,
            &SessionOpts::from_env(),
        )
        .unwrap();
        assert_eq!(legacy, native, "incremental session, max_new = {max_new}");
        let meta = fx.exec.meta(ART).unwrap().clone();
        let mut fb =
            FallbackSession::new(meta, Arc::new(fx.w0.clone()), &SessionOpts::from_env()).unwrap();
        let out = drive_sampled(
            fb.as_mut(),
            fx.exec.as_mut(),
            "t0",
            Arc::new(fx.theta.clone()),
            Arc::new(fx.statics.clone()),
            &prompts,
            max_new,
            &sampling,
        )
        .unwrap();
        fb.finish();
        assert_eq!(legacy, out, "fallback session, max_new = {max_new}");
    }
}

/// Tentpole determinism contract: an identical (request, seed) pair
/// replays a bit-identical token stream across runs AND thread counts
/// (pool width is scheduling-only, never numeric). Cross-seed
/// divergence is pinned at the sampler unit level
/// (`generation::tests::seeded_picks_replay_and_diverge_across_seeds`);
/// here a distinct-seed run only has to stay well-formed.
#[test]
fn seeded_sampling_replays_across_runs_and_thread_counts() {
    let mut fx = fixture(47);
    let prompts = parity_prompts(&fx.cfg);
    let params =
        |seed: u64| SamplingParams { temperature: 0.9, top_k: 12, seed, ..Default::default() };
    let mut run = |sampling: &SamplingParams| -> Vec<Vec<i32>> {
        decode_sampled(
            fx.exec.as_mut(),
            ART,
            "replay",
            Arc::new(fx.theta.clone()),
            Arc::new(fx.w0.clone()),
            Arc::new(fx.statics.clone()),
            &prompts,
            12,
            sampling,
            &SessionOpts::from_env(),
        )
        .unwrap()
    };
    let a = run(&params(7));
    assert_eq!(a, run(&params(7)), "same (request, seed) must replay bit-identically");
    // RAII guard: the env-derived pool width comes back even if an
    // assert below panics (see tests/integration.rs)
    let _threads = uni_lora::kernels::ThreadsGuard::new();
    uni_lora::kernels::set_threads(1);
    assert_eq!(a, run(&params(7)), "1-thread run must match");
    uni_lora::kernels::set_threads(4);
    assert_eq!(a, run(&params(7)), "4-thread run must match");
    // a different seed draws through the same rules: budget respected,
    // the over-long prompt stays stillborn
    let b = run(&params(8));
    assert!(b.iter().all(|g| g.len() <= 12));
    assert!(b.last().unwrap().is_empty(), "prompt >= seq generates nothing under any params");
}

/// Satellite: stop sequences truncate the stream exactly where the
/// emission rules say — including at the budget and context-window
/// boundaries. The expected streams are derived from a reference run
/// with EOS biased out (so budget/window are the only limits), then
/// replayed through a pure-code simulation of the stop rule ("the
/// sequence ends, without emitting, when the next pick would complete
/// a stop sequence"), so the asserts are self-calibrating against the
/// fixture's actual token streams.
#[test]
fn stop_sequences_truncate_at_window_and_budget_boundaries() {
    let mut fx = fixture(53);
    let eos = uni_lora::data::vocab::EOS;
    // bias EOS far down: picks stay deterministic (temperature 0) but
    // can never end the sequence early
    let no_eos = |stop: Vec<Vec<i32>>| SamplingParams {
        stop,
        logit_bias: vec![(eos, -1.0e9)],
        ..Default::default()
    };
    let mut run = |prompts: &[Vec<i32>], max_new: usize, sampling: &SamplingParams| -> Vec<i32> {
        decode_sampled(
            fx.exec.as_mut(),
            ART,
            "stop",
            Arc::new(fx.theta.clone()),
            Arc::new(fx.w0.clone()),
            Arc::new(fx.statics.clone()),
            prompts,
            max_new,
            sampling,
            &SessionOpts::from_env(),
        )
        .unwrap()
        .remove(0)
    };
    // stop params never change the picks, only where the stream ends,
    // so the stopped stream is a prefix of the reference computable in
    // plain code
    let expect = |r: &[i32], stop: &[i32], budget: usize| -> Vec<i32> {
        let mut out: Vec<i32> = Vec::new();
        for &tok in r.iter().take(budget) {
            let hit = stop.split_last().map_or(false, |(l, h)| *l == tok && out.ends_with(h));
            if hit {
                break;
            }
            out.push(tok);
        }
        out
    };
    let short = vec![vec![1, 21]];
    let r = run(&short, 6, &no_eos(vec![]));
    assert_eq!(r.len(), 6, "EOS biased out: the budget is the only limit, got {r:?}");
    // single-token stop on the first pick: ends before anything is out
    assert_eq!(run(&short, 6, &no_eos(vec![vec![r[0]]])), Vec::<i32>::new());
    // multi-token stop: earlier tokens of the match are already out,
    // the completing token is withheld
    let s01 = r[..2].to_vec();
    assert_eq!(run(&short, 6, &no_eos(vec![s01.clone()])), expect(&r, &s01, 6));
    // budget boundary: a stop completing on the final budget token
    // still withholds it...
    let s45 = r[4..6].to_vec();
    assert_eq!(run(&short, 6, &no_eos(vec![s45.clone()])), expect(&r, &s45, 6));
    // ...and a partial match cut off by the budget must NOT fire
    assert_eq!(run(&short, 5, &no_eos(vec![s45.clone()])), expect(&r, &s45, 5));
    // window boundary: a seq-1 prompt emits exactly its window-filling
    // token; a stop on that token means nothing is ever emitted
    let t = fx.cfg.seq;
    let fill = vec![vec![5; t - 1]];
    let w = run(&fill, 4, &no_eos(vec![]));
    assert_eq!(w.len(), 1, "seq-1 prompt fills the window on its first emission");
    assert_eq!(run(&fill, 4, &no_eos(vec![vec![w[0]]])), Vec::<i32>::new());
}
