//! Request-lifecycle acceptance: deadlines (queued and mid-flight),
//! cancellation on client disconnect, graceful drain vs hard stop,
//! connection/request-size bounds, and the seeded churn fuzz — ≥64
//! interleaved requests over heterogeneous adapters under an active
//! fault plan, where every request gets exactly one terminal reply, no
//! K/V page or slot leaks, and the whole run replays bit-identically
//! for a fixed fault seed.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};
use uni_lora::adapters::{AdapterCheckpoint, Registry};
use uni_lora::coordinator::init_base;
use uni_lora::generation::SamplingParams;
use uni_lora::projection::statics::{gen_statics, init_theta};
use uni_lora::runtime::{Backend, NativeBackend};
use uni_lora::server::protocol::{ErrCode, Request, Response};
use uni_lora::server::router::{GenEvent, PendingReq, Router};
use uni_lora::server::server::Client;
use uni_lora::server::{serve, Faults, RouterStats, ServerConfig, ServerHandle};
use uni_lora::session::{SeqRequest, SessionOpts};

const ART: &str = "lm_uni_lm_logits";
/// EOS token id — biased out wherever a test needs the full budget to
/// actually decode (an untrained model may emit EOS at any step).
const EOS_BIAS: &str = r#""logit_bias":[[3,-1000000000]]"#;

fn no_eos() -> SamplingParams {
    SamplingParams { logit_bias: vec![(3, -1e9)], ..SamplingParams::default() }
}

/// One-adapter server with one worker; every lifecycle knob the test
/// cares about is pinned through the config (never the environment).
fn start(cfgf: impl FnOnce(ServerConfig) -> ServerConfig) -> ServerHandle {
    let mut exec: Box<dyn Backend> = Box::new(NativeBackend::new().unwrap());
    let meta = exec.meta(ART).unwrap().clone();
    let w0 = init_base(&meta, 42);
    exec.prepare(ART).unwrap();
    let registry = Registry::new();
    registry.insert(
        "a0".into(),
        AdapterCheckpoint {
            seed: 5,
            method: "uni".into(),
            artifact: ART.into(),
            theta: init_theta(&meta.cfg, 5).unwrap(),
            head: vec![],
        },
    );
    let cfg = cfgf(ServerConfig::new("127.0.0.1:0", ART).with_workers(1));
    serve(cfg, exec, Arc::new(registry), meta.cfg.clone(), w0).unwrap()
}

/// Session-level cancel contract: pages and the slot free immediately,
/// the counter increments, cancelling a free slot is a no-op, and the
/// freed slot is re-admissible.
#[test]
fn session_cancel_frees_pages_and_slot() {
    let mut be = NativeBackend::new().unwrap();
    let meta = be.meta(ART).unwrap().clone();
    let cfg = meta.cfg.clone();
    let w0 = Arc::new(init_base(&meta, 7));
    let statics = Arc::new(gen_statics(&cfg, 7).unwrap());
    let theta = Arc::new(init_theta(&cfg, 5).unwrap());
    let req = |prompt: Vec<i32>| SeqRequest {
        request_id: 0,
        adapter: "a".into(),
        theta: theta.clone(),
        statics: statics.clone(),
        prompt,
        max_new: 4,
        sampling: no_eos(),
    };
    let opts = SessionOpts::with_slots(2);
    let mut sess = be.begin_decode(ART, w0.clone(), &opts).unwrap();
    let a1 = sess.admit(req(vec![1, 2, 3])).unwrap();
    let a2 = sess.admit(req(vec![4, 5])).unwrap();
    assert_eq!(sess.active(), 2);
    sess.step(&mut be).unwrap(); // prefill: K/V pages now hold tokens
    let live = sess.stats().kv_bytes_in_flight;
    assert!(live > 0, "prefilled sequences must hold K/V bytes");
    sess.cancel(a1.slot);
    assert_eq!(sess.active(), 1);
    assert_eq!(sess.stats().cancelled, 1);
    assert!(
        sess.stats().kv_bytes_in_flight < live,
        "cancel must release the sequence's pages immediately"
    );
    // cancelling a free slot is a no-op
    sess.cancel(a1.slot);
    assert_eq!(sess.stats().cancelled, 1);
    assert_eq!(sess.active(), 1);
    // the freed slot admits again and the session still decodes
    let a3 = sess.admit(req(vec![6, 7, 8])).unwrap();
    assert_eq!(a3.slot, a1.slot, "two slots, one live: cancel must have freed the other");
    for _ in 0..16 {
        if sess.active() == 0 {
            break;
        }
        sess.step(&mut be).unwrap();
    }
    assert_eq!(sess.active(), 0, "remaining sequences must run to completion");
    let _ = a2;
    sess.finish();
    assert_eq!(sess.stats().kv_bytes_in_flight, 0);
}

/// One request's full observable outcome, for bit-identical replay
/// comparison across runs.
fn churn_run() -> (Vec<String>, (u64, u64, u64, u64, u64), RouterStats) {
    let mut be = NativeBackend::new().unwrap();
    let meta = be.meta(ART).unwrap().clone();
    let cfg = meta.cfg.clone();
    let w0 = Arc::new(init_base(&meta, 9));
    let registry = Arc::new(Registry::new());
    for i in 0..3u64 {
        registry.insert(
            format!("a{i}"),
            AdapterCheckpoint {
                seed: 7,
                method: cfg.method.clone(),
                artifact: ART.into(),
                theta: init_theta(&cfg, 50 + i).unwrap(),
                head: vec![],
            },
        );
    }
    // every request is queued BEFORE the worker starts, so admission
    // order — and with it the fault plan's decision streams — is a
    // pure function of the request list and the seed
    let r = Router::new();
    let mut rxs = Vec::new();
    for i in 0..72usize {
        let (tx, rx) = mpsc::channel();
        let sampling = if i % 3 == 2 {
            SamplingParams {
                temperature: 0.8,
                seed: 100 + i as u64,
                ..SamplingParams::default()
            }
        } else {
            SamplingParams::default()
        };
        r.submit(PendingReq {
            id: 0,
            adapter: format!("a{}", i % 3),
            prompt: vec![1, 2, 1 + (i as i32 % 5)],
            max_new: 1 + i % 5,
            sampling,
            stream: i % 2 == 0,
            // a sprinkling of already-expired deadlines: these must
            // fail while queued, without ever occupying a slot
            deadline: (i % 16 == 7).then(|| Instant::now() - Duration::from_millis(1)),
            enqueued: Instant::now(),
            reply: tx,
        })
        .unwrap();
        rxs.push(rx);
    }
    // 4 slots over a 4-page budget: every sequence here reserves one
    // page, so a single leaked page shows up as a hang in the
    // full-budget wave below
    let opts = SessionOpts::with_slots(4).with_kv_pages(4);
    let worker = {
        let r = r.clone();
        let registry = registry.clone();
        let cfg = cfg.clone();
        let w0 = w0.clone();
        std::thread::spawn(move || {
            let faults =
                Faults::parse("1234:step=0.2,admit=0.1,slow=0.05@1,frame=0.15").unwrap();
            r.worker_loop(&mut be, &registry, ART, &cfg, &w0, &opts, &faults)
        })
    };
    let mut outcomes = Vec::new();
    for (i, rx) in rxs.into_iter().enumerate() {
        let mut frames = 0usize;
        let summary = loop {
            match rx.recv() {
                Ok(GenEvent::Token(_)) => frames += 1,
                Ok(GenEvent::Done(Ok(toks))) => break format!("ok:{toks:?}:frames={frames}"),
                Ok(GenEvent::Done(Err(e))) => break format!("err:{:?}:frames={frames}", e.code),
                Err(_) => break "dropped-without-terminal".to_string(),
            }
        };
        // exactly one terminal reply: the sender must be gone now
        assert!(rx.recv().is_err(), "request {i} got a second event after its terminal");
        outcomes.push(summary);
    }
    // deterministic snapshot: the worker is idle (blocked on the
    // queue) once every terminal reply has been received
    let mid = r.stats.lock().unwrap().clone();
    let key = (mid.requests, mid.generated_tokens, mid.faults_injected, mid.deadline_exceeded,
        mid.client_gone);
    // leak check: a full-budget wave — 4 concurrent single-page
    // admissions need all 4 pages free; a leaked page turns this into
    // a requeue-forever hang (caught by the harness timeout)
    let mut wave = Vec::new();
    for _ in 0..4 {
        let (tx, rx) = mpsc::channel();
        r.submit(PendingReq {
            id: 0,
            adapter: "a0".into(),
            prompt: vec![1, 2, 3],
            max_new: 4,
            sampling: SamplingParams::default(),
            stream: false,
            deadline: None,
            enqueued: Instant::now(),
            reply: tx,
        })
        .unwrap();
        wave.push(rx);
    }
    for (i, rx) in wave.into_iter().enumerate() {
        match rx.recv().unwrap() {
            GenEvent::Done(out) => {
                assert!(out.is_ok(), "post-fuzz full-budget admission {i} failed: {out:?}")
            }
            other => panic!("wave request {i} got a stream event: {other:?}"),
        }
    }
    r.stop();
    worker.join().unwrap();
    let fin = r.stats.lock().unwrap().clone();
    // span causality: every request's drained timeline is well-formed,
    // for the fuzz requests and the wave alike. Timelines carry
    // wall-clock micros, so they are checked here and kept OUT of the
    // replay-equality key.
    assert_span_causality(&r.tracer().drain(), 76);
    assert_eq!(r.tracer().dropped(), 0, "the default ring must hold the whole fuzz");
    (outcomes, key, fin)
}

/// Trace-span causality: group the drained ring by request id and
/// assert each accepted request's timeline starts at `enqueue`, ends
/// at exactly one `done`, never decodes (`prefill`/`step`/`frame`)
/// before an `admit`, and carries non-decreasing timestamps. Request
/// id 0 is the reserved id for worker-scoped fault events.
fn assert_span_causality(events: &[uni_lora::obs::SpanEvent], expect_reqs: u64) {
    use std::collections::BTreeMap;
    let mut by_req: BTreeMap<u64, Vec<&uni_lora::obs::SpanEvent>> = BTreeMap::new();
    for ev in events {
        by_req.entry(ev.req).or_default().push(ev);
    }
    let reqs = by_req.keys().filter(|&&r| r != 0).count() as u64;
    assert_eq!(reqs, expect_reqs, "every submitted request must leave a timeline");
    for (req, evs) in &by_req {
        if *req == 0 {
            for ev in evs {
                assert_eq!(ev.ev, "fault", "only fault events may carry the reserved id 0");
            }
            continue;
        }
        assert_eq!(evs[0].ev, "enqueue", "request {req} must start at enqueue: {evs:?}");
        let dones = evs.iter().filter(|e| e.ev == "done").count();
        assert_eq!(dones, 1, "request {req} must get exactly one terminal: {evs:?}");
        assert_eq!(evs.last().unwrap().ev, "done", "request {req}: done is terminal: {evs:?}");
        let admit_at = evs.iter().position(|e| e.ev == "admit");
        for (i, ev) in evs.iter().enumerate() {
            if matches!(ev.ev, "prefill" | "step" | "frame") {
                let at = admit_at.expect("decode events require an admission");
                assert!(at < i, "request {req}: {} before admit: {evs:?}", ev.ev);
            }
        }
        for w in evs.windows(2) {
            assert!(w[0].t_us <= w[1].t_us, "request {req}: time went backwards: {evs:?}");
        }
    }
}

/// Tentpole acceptance: the seeded churn fuzz. 72 interleaved
/// requests (3 adapters, mixed stream/buffered, mixed greedy/sampled,
/// a few pre-expired deadlines) under an active fault plan injecting
/// step failures, admission failures, slow steps and frame-write
/// failures. Every request gets exactly one terminal reply, nothing
/// leaks, and the entire run replays bit-identically.
#[test]
fn churn_fuzz_replays_bit_identically_with_no_leaks() {
    let (outcomes, key, fin) = churn_run();
    assert_eq!(outcomes.len(), 72);
    let expected_expired = (0..72).filter(|i| i % 16 == 7).count() as u64;
    assert_eq!(key.3, expected_expired, "every pre-expired deadline fails while queued");
    for (i, o) in outcomes.iter().enumerate() {
        if i % 16 == 7 {
            assert_eq!(o, "err:DeadlineExceeded:frames=0", "request {i}: {o}");
        }
        assert_ne!(o, "dropped-without-terminal", "request {i} never got a terminal reply");
    }
    assert!(key.2 > 0, "the fault plan must actually fire: {key:?}");
    assert!(key.4 >= 1, "frame faults must produce client_gone cancellations: {key:?}");
    // streamed requests that completed must have received every token
    // exactly once — replay after a step fault must not re-deliver
    for (i, o) in outcomes.iter().enumerate() {
        if i % 2 == 0 && i % 16 != 7 {
            if let Some(toks) = o.strip_prefix("ok:") {
                let n_tokens = toks.split(',').count() - usize::from(toks.starts_with("[]"));
                let frames: usize =
                    o.rsplit("frames=").next().unwrap().parse().unwrap();
                assert_eq!(frames, n_tokens, "request {i}: {o}");
            }
        }
    }
    // no K/V leak: the final fold (post-finish) must zero the gauge
    assert_eq!(fin.kv_bytes_in_flight, 0, "{fin:?}");
    assert_eq!(fin.requests, 76, "72 fuzz + 4 wave requests, one terminal each");

    // the replay: same seed, same request list -> same everything
    let (outcomes2, key2, _) = churn_run();
    assert_eq!(outcomes, outcomes2, "fixed fault seed must replay bit-identically");
    assert_eq!(key, key2, "lifecycle counters must replay exactly");
}

/// Graceful drain: in-flight streaming finishes (frames keep flowing
/// after shutdown begins), queued requests fail with a typed
/// shutting-down error, and the returned stats record the drain.
#[test]
fn graceful_drain_finishes_in_flight_and_fails_queued() {
    let handle = start(|c| {
        c.with_session(SessionOpts::with_slots(1))
            .with_faults(Arc::new(Faults::parse("5:slow=1@25").unwrap()))
            .with_drain_ms(10_000)
    });
    let addr = handle.addr;
    // A: streaming, EOS biased out -> exactly 8 frames, ~25ms apart
    let a = std::thread::spawn(move || {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        writeln!(
            writer,
            r#"{{"op":"generate","adapter":"a0","prompt":[1,21,7],"max_new":8,"sampling":{{{EOS_BIAS}}},"stream":true}}"#
        )
        .unwrap();
        let mut frames = 0usize;
        loop {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            match Response::parse(&line).unwrap() {
                Response::Frame { token, done, tokens } => {
                    if token.is_some() {
                        frames += 1;
                    }
                    if done {
                        return (frames, tokens.unwrap_or_default());
                    }
                }
                other => panic!("drained stream must complete, got {other:?}"),
            }
        }
    });
    std::thread::sleep(Duration::from_millis(60)); // A is mid-decode
    // B: buffered, queued behind A (1 slot) when shutdown begins
    let b = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        c.generate("a0", vec![1, 2], 2)
    });
    std::thread::sleep(Duration::from_millis(50));
    let t0 = Instant::now();
    let st = handle.shutdown();
    assert!(t0.elapsed() < Duration::from_secs(10), "drain must beat its deadline");
    let (frames, final_tokens) = a.join().unwrap();
    assert_eq!(frames, 8, "the in-flight stream must finish during the drain");
    assert_eq!(final_tokens.len(), 8);
    let b_err = b.join().unwrap().unwrap_err().to_string();
    assert!(b_err.contains("shutting down"), "queued request must fail typed: {b_err}");
    assert_eq!(st.drained_ok, 1, "{st:?}");
    assert_eq!(st.drained_aborted, 0, "{st:?}");
    assert_eq!(st.kv_bytes_in_flight, 0, "{st:?}");
}

/// Drain deadline of zero: shutdown hard-stops immediately, and the
/// in-flight streaming client gets a typed shutting-down error instead
/// of a hang.
#[test]
fn hard_stop_aborts_in_flight_past_drain_deadline() {
    let handle = start(|c| {
        c.with_session(SessionOpts::with_slots(1))
            .with_faults(Arc::new(Faults::parse("5:slow=1@25").unwrap()))
            .with_drain_ms(0)
    });
    let addr = handle.addr;
    let a = std::thread::spawn(move || {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        writeln!(
            writer,
            r#"{{"op":"generate","adapter":"a0","prompt":[1,21,7],"max_new":30,"sampling":{{{EOS_BIAS}}},"stream":true}}"#
        )
        .unwrap();
        loop {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            match Response::parse(&line).unwrap() {
                Response::Frame { done: false, .. } => continue,
                Response::Frame { done: true, .. } => panic!("30-token stream outran the abort"),
                Response::Error(e) => return e,
                other => panic!("unexpected response {other:?}"),
            }
        }
    });
    std::thread::sleep(Duration::from_millis(80)); // A is mid-decode
    let st = handle.shutdown();
    let e = a.join().unwrap();
    assert_eq!(e.code, ErrCode::ShuttingDown, "{e:?}");
    assert_eq!(st.drained_aborted, 1, "{st:?}");
    assert_eq!(st.kv_bytes_in_flight, 0, "aborted sequences must release K/V: {st:?}");
}

/// A mid-flight deadline cancels the sequence at a step boundary,
/// frees the slot for the next request, and surfaces the typed error
/// plus the deadline_exceeded / cancelled counters.
#[test]
fn deadline_expires_mid_flight_and_frees_the_slot() {
    let handle = start(|c| {
        c.with_session(SessionOpts::with_slots(1))
            .with_faults(Arc::new(Faults::parse("5:slow=1@15").unwrap()))
    });
    let mut client = Client::connect(handle.addr).unwrap();
    let req = Request::Generate {
        adapter: "a0".into(),
        prompt: vec![1, 21, 7],
        max_new: 50,
        sampling: no_eos(),
        stream: false,
        timeout_ms: 60,
    };
    let t0 = Instant::now();
    match client.call(&req).unwrap() {
        Response::Error(e) => {
            assert_eq!(e.code, ErrCode::DeadlineExceeded, "{e:?}");
        }
        other => panic!("a 50-token decode at 15ms/step must miss a 60ms deadline: {other:?}"),
    }
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "deadline must cut the request off, not let it run out its budget"
    );
    // the slot is free again: an undeadlined request completes
    let toks = client.generate("a0", vec![1, 2, 3], 2).unwrap();
    assert!(toks.len() <= 2);
    let stats = client.stats().unwrap();
    assert!(stats.get("deadline_exceeded").unwrap().as_f64().unwrap() >= 1.0);
    assert!(stats.get("cancelled").unwrap().as_f64().unwrap() >= 1.0);
    handle.shutdown();
}

/// Queue wait counts against the deadline: a request that expires
/// while queued fails with the typed error WITHOUT ever occupying a
/// decode slot (cancelled stays 0 — nothing was in flight to cancel).
#[test]
fn queued_request_expires_without_occupying_a_slot() {
    let handle = start(|c| {
        c.with_session(SessionOpts::with_slots(1))
            .with_faults(Arc::new(Faults::parse("5:slow=1@15").unwrap()))
    });
    let addr = handle.addr;
    // A occupies the only slot for ~40 steps x 15ms
    let a = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        c.generate_sampled("a0", vec![1, 21, 7], 40, no_eos())
    });
    std::thread::sleep(Duration::from_millis(50)); // A is admitted
    let mut client = Client::connect(addr).unwrap();
    let req = Request::Generate {
        adapter: "a0".into(),
        prompt: vec![1, 2],
        max_new: 2,
        sampling: SamplingParams::default(),
        stream: false,
        timeout_ms: 50,
    };
    let t0 = Instant::now();
    match client.call(&req).unwrap() {
        Response::Error(e) => {
            assert_eq!(e.code, ErrCode::DeadlineExceeded, "{e:?}");
            assert!(e.msg.contains("queued"), "must fail at admission, not mid-flight: {e:?}");
        }
        other => panic!("queued past its deadline must fail typed: {other:?}"),
    }
    assert!(t0.elapsed() < Duration::from_secs(5));
    assert!(a.join().unwrap().is_ok(), "the in-flight request is untouched");
    let stats = client.stats().unwrap();
    assert!(stats.get("deadline_exceeded").unwrap().as_f64().unwrap() >= 1.0);
    assert_eq!(
        stats.get("cancelled").unwrap().as_f64().unwrap(),
        0.0,
        "a queued expiry must never have occupied a slot"
    );
    handle.shutdown();
}

/// Satellite: a streaming client that disconnects mid-generation is
/// detected at the next frame write; the worker cancels the sequence,
/// recycles its pages, and the slot serves the next request.
#[test]
fn mid_stream_disconnect_cancels_the_sequence() {
    let handle = start(|c| {
        c.with_session(SessionOpts::with_slots(1))
            .with_faults(Arc::new(Faults::parse("5:slow=1@10").unwrap()))
    });
    let addr = handle.addr;
    {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        writeln!(
            writer,
            r#"{{"op":"generate","adapter":"a0","prompt":[1,21,7],"max_new":40,"sampling":{{{EOS_BIAS}}},"stream":true}}"#
        )
        .unwrap();
        for _ in 0..2 {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert!(line.contains(r#""ok":true"#), "expected a frame: {line}");
        }
        // drop both halves: FIN now — the server's next frame writes
        // start failing and the handler drops its reply receiver
    }
    // the worker notices at a step boundary and cancels
    let mut client = Client::connect(addr).unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let stats = client.stats().unwrap();
        if stats.get("client_gone").unwrap().as_f64().unwrap() >= 1.0
            && stats.get("cancelled").unwrap().as_f64().unwrap() >= 1.0
        {
            break;
        }
        assert!(Instant::now() < deadline, "disconnect was never detected: {stats:?}");
        std::thread::sleep(Duration::from_millis(20));
    }
    // the slot (and its pages) are free again
    let toks = client.generate("a0", vec![1, 2, 3], 2).unwrap();
    assert!(toks.len() <= 2);
    handle.shutdown();
}

/// Satellite: a client trickling a never-terminated request line is
/// cut off by the socket read timeout without blocking other clients.
#[test]
fn slow_loris_is_cut_off_by_the_read_timeout() {
    let handle = start(|c| c.with_sock_timeout_ms(200));
    let addr = handle.addr;
    let mut loris = TcpStream::connect(addr).unwrap();
    loris.write_all(b"{\"op\":").unwrap(); // partial line, no newline, then silence
    loris.flush().unwrap();
    // other clients are served while the loris connection idles
    let mut client = Client::connect(addr).unwrap();
    assert!(client.stats().is_ok());
    // past the read timeout the server closes the connection: the
    // loris sees EOF (or a reset), never its own read timeout
    loris.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut buf = [0u8; 64];
    match loris.read(&mut buf) {
        Ok(0) => {}                                                   // clean FIN
        Err(e) if e.kind() == std::io::ErrorKind::ConnectionReset => {} // RST
        other => panic!("server must hang up on a slow loris, got {other:?}"),
    }
    // and the server is still healthy
    assert!(client.stats().is_ok());
    handle.shutdown();
}

/// Satellite: past UNI_LORA_MAX_CONNS a connection gets one typed busy
/// line and a close — and the slot reopens when a connection ends.
#[test]
fn connection_cap_rejects_with_typed_busy() {
    let handle = start(|c| c.with_max_conns(1));
    let addr = handle.addr;
    let mut c1 = Client::connect(addr).unwrap();
    assert!(c1.stats().is_ok()); // c1's handler is live and counted
    {
        let over = TcpStream::connect(addr).unwrap();
        over.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut reader = BufReader::new(over);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        match Response::parse(&line).unwrap() {
            Response::Error(e) => {
                assert_eq!(e.code, ErrCode::Busy, "{e:?}");
                assert!(e.msg.contains("too many connections"), "{e:?}");
            }
            other => panic!("over-cap connection must get a typed busy line: {other:?}"),
        }
        let mut rest = String::new();
        assert_eq!(reader.read_line(&mut rest).unwrap(), 0, "then the close");
    }
    drop(c1); // the slot frees when the handler sees EOF
    let deadline = Instant::now() + Duration::from_secs(5);
    let stats = loop {
        let mut c = Client::connect(addr).unwrap();
        match c.stats() {
            Ok(s) => break s,
            Err(_) => {
                assert!(Instant::now() < deadline, "closed connection never freed the cap");
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    };
    assert!(stats.get("conns_rejected").unwrap().as_f64().unwrap() >= 1.0);
    handle.shutdown();
}

/// Satellite: a request line past UNI_LORA_MAX_REQUEST_BYTES gets a
/// typed error and the connection closes (there is no framing left to
/// resync on); the server stays healthy.
#[test]
fn oversized_request_line_gets_typed_error() {
    let handle = start(|c| c.with_max_request_bytes(64));
    let addr = handle.addr;
    {
        let big = TcpStream::connect(addr).unwrap();
        big.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut writer = big.try_clone().unwrap();
        let mut reader = BufReader::new(big);
        let huge = format!(r#"{{"op":"generate","adapter":"{}"}}"#, "a".repeat(200));
        writeln!(writer, "{huge}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        match Response::parse(&line).unwrap() {
            Response::Error(e) => {
                assert_eq!(e.code, ErrCode::RequestTooLarge, "{e:?}");
                assert!(e.msg.contains("64"), "the cap is named in the error: {e:?}");
            }
            other => panic!("oversized line must get a typed error: {other:?}"),
        }
        let mut rest = String::new();
        assert_eq!(reader.read_line(&mut rest).unwrap(), 0, "connection closes after");
    }
    // under the cap everything still works on a fresh connection
    let mut client = Client::connect(addr).unwrap();
    assert!(client.stats().is_ok());
    handle.shutdown();
}
