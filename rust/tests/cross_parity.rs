//! Cross-language parity: the Rust statics generators must reproduce
//! python/compile/methods.gen_statics bit-for-bit. The golden values
//! below were printed by the Python side (BASE config, seed 42); see
//! python/tests/test_methods.py::test_statics_deterministic_in_seed for
//! the Python half of the contract.

use uni_lora::config::ModelCfg;
use uni_lora::projection::statics::gen_statics;

fn assert_f32_prefix(got: &[f32], want: &[f32], what: &str) {
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        assert!((a - b).abs() < 1e-6, "{what}[{i}]: {a} vs {b}");
    }
}

fn sum_f32(v: &[f32]) -> f64 {
    v.iter().map(|&x| x as f64).sum()
}

fn sum_i32(v: &[i32]) -> f64 {
    v.iter().map(|&x| x as f64).sum()
}

#[test]
fn uni_statics_match_python_golden() {
    let s = gen_statics(&ModelCfg::test_base("uni"), 42).unwrap();
    assert_eq!(&s[0].as_i32()[..5], &[202, 247, 230, 159, 28]);
    assert_eq!(sum_i32(s[0].as_i32()), 262522.0);
    assert_f32_prefix(
        s[1].as_f32(),
        &[0.30151135, 0.37796447, 0.2773501, 0.33333334, 0.31622776],
        "nrm",
    );
    assert!((sum_f32(s[1].as_f32()) - 711.4678007811308).abs() < 1e-3);
}

#[test]
fn vera_statics_match_python_golden() {
    let s = gen_statics(&ModelCfg::test_base("vera"), 42).unwrap();
    assert_f32_prefix(
        s[0].as_f32(),
        &[0.03753513, 0.0749092, 0.05410943, 0.17175354, -0.05891167],
        "pa_t",
    );
    assert_f32_prefix(
        s[1].as_f32(),
        &[-0.010586159, -0.005263741, 0.012683991, -0.053174097, -0.012768381],
        "pb_t",
    );
    assert!((sum_f32(s[0].as_f32()) - -0.07502054052156382).abs() < 1e-4);
    assert!((sum_f32(s[1].as_f32()) - 0.4427085903007537).abs() < 1e-4);
}

#[test]
fn vb_statics_match_python_golden() {
    let s = gen_statics(&ModelCfg::test_base("vb"), 42).unwrap();
    assert_eq!(&s[0].as_i32()[..5], &[1, 16, 21, 0, 21]);
    assert_eq!(sum_i32(s[0].as_i32()), 716.0);
}

#[test]
fn lora_xs_statics_match_python_golden() {
    let s = gen_statics(&ModelCfg::test_base("lora_xs"), 42).unwrap();
    assert_f32_prefix(
        s[0].as_f32(),
        &[-0.043297932, 0.024219781, 0.016942367, -0.16729401, -0.005372011],
        "pa_t",
    );
    assert!((sum_f32(s[0].as_f32()) - -3.1627256906776893).abs() < 1e-3);
    assert_f32_prefix(
        s[1].as_f32(),
        &[-0.0786746, -0.020421462, -0.016240019, -0.13979605, -0.15243852],
        "pb_t",
    );
    assert!((sum_f32(s[1].as_f32()) - 5.656312849663664).abs() < 1e-3);
}

#[test]
fn lora_xs_bases_are_orthonormal() {
    let cfg = ModelCfg::test_base("lora_xs");
    let s = gen_statics(&cfg, 7).unwrap();
    let (h, r) = (cfg.hidden, cfg.rank);
    let pa = &s[0].as_f32()[..h * r]; // module 0, [h, r]
    for i in 0..r {
        for j in 0..r {
            let dot: f32 = (0..h).map(|k| pa[k * r + i] * pa[k * r + j]).sum();
            let want = if i == j { 1.0 } else { 0.0 };
            assert!((dot - want).abs() < 1e-5, "pa[{i}]·pa[{j}] = {dot}");
        }
    }
    let pb = &s[1].as_f32()[..r * h]; // module 0, [r, h] (orthonormal rows)
    for i in 0..r {
        for j in 0..r {
            let dot: f32 = (0..h).map(|k| pb[i * h + k] * pb[j * h + k]).sum();
            let want = if i == j { 1.0 } else { 0.0 };
            assert!((dot - want).abs() < 1e-5, "pb[{i}]·pb[{j}] = {dot}");
        }
    }
}

#[test]
fn fourierft_statics_match_python_golden() {
    let s = gen_statics(&ModelCfg::test_base("fourierft"), 42).unwrap();
    assert_eq!(&s[0].as_i32()[..5], &[23, 11, 12, 63, 63]);
    assert_eq!(sum_i32(s[0].as_i32()), 24630.0);
}

#[test]
fn fastfood_statics_match_python_golden() {
    // Golden values regenerated from python/compile/unirng.py after the
    // per-block seed derivation moved to nested child streams
    // (statics.rs::fastfood_block_seed).
    let s = gen_statics(&ModelCfg::test_base("fastfood"), 42).unwrap();
    assert_eq!(&s[0].as_f32()[..5], &[-1.0, 1.0, -1.0, 1.0, -1.0]);
    assert_eq!(sum_f32(s[0].as_f32()), -40.0);
    assert_f32_prefix(
        s[1].as_f32(),
        &[-0.15591085, 0.57788897, -1.3719796, -0.42424467, 1.2689098],
        "gauss",
    );
    assert!((sum_f32(s[1].as_f32()) - 33.80442157178186).abs() < 1e-3);
    assert_eq!(&s[2].as_i32()[..5], &[32, 3, 66, 128, 13]);
    assert_eq!(sum_i32(s[2].as_i32()), 261120.0);
    assert_eq!(&s[3].as_f32()[..5], &[-1.0, -1.0, -1.0, 1.0, 1.0]);
    assert_eq!(sum_f32(s[3].as_f32()), 62.0);
}

#[test]
fn low_ratio_patched_indices_match_python() {
    // D/d = 4 forces the patch_support path on both sides
    let mut cfg = ModelCfg::test_base("uni");
    cfg.d = 512;
    let s = gen_statics(&cfg, 3).unwrap();
    assert_eq!(&s[0].as_i32()[..8], &[485, 315, 445, 388, 56, 161, 247, 408]);
    assert_eq!(sum_i32(s[0].as_i32()), 527491.0);
    // full support after patching
    let mut cnt = vec![0u32; 512];
    for &i in s[0].as_i32() {
        cnt[i as usize] += 1;
    }
    assert!(cnt.iter().all(|&c| c > 0));
}
