//! Observability acceptance: the `metrics` op serves valid Prometheus
//! text whose counters agree with the `stats` op snapshot (≥5
//! histograms, `+Inf` buckets equal to `_count`), the
//! `UNI_LORA_PROFILE` stage attribution appears in the scrape when
//! enabled, and the `trace` op reconstructs full span timelines for a
//! streamed, a cancelled and a deadline-exceeded request.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};
use uni_lora::adapters::{AdapterCheckpoint, Registry};
use uni_lora::coordinator::init_base;
use uni_lora::generation::SamplingParams;
use uni_lora::obs::profile;
use uni_lora::projection::statics::init_theta;
use uni_lora::runtime::{Backend, NativeBackend};
use uni_lora::server::protocol::{ErrCode, Request, Response};
use uni_lora::server::server::Client;
use uni_lora::server::{serve, Faults, ServerConfig, ServerHandle};
use uni_lora::session::SessionOpts;
use uni_lora::util::json::Json;

const ART: &str = "lm_uni_lm_logits";
/// EOS token id, biased out where a test needs the full budget.
const EOS_BIAS: &str = r#""logit_bias":[[3,-1000000000]]"#;

fn no_eos() -> SamplingParams {
    SamplingParams { logit_bias: vec![(3, -1e9)], ..SamplingParams::default() }
}

/// One-adapter, one-worker server with every knob pinned through the
/// config (never the environment).
fn start(cfgf: impl FnOnce(ServerConfig) -> ServerConfig) -> ServerHandle {
    let mut exec: Box<dyn Backend> = Box::new(NativeBackend::new().unwrap());
    let meta = exec.meta(ART).unwrap().clone();
    let w0 = init_base(&meta, 42);
    exec.prepare(ART).unwrap();
    let registry = Registry::new();
    registry.insert(
        "a0".into(),
        AdapterCheckpoint {
            seed: 5,
            method: "uni".into(),
            artifact: ART.into(),
            theta: init_theta(&meta.cfg, 5).unwrap(),
            head: vec![],
        },
    );
    let cfg = cfgf(ServerConfig::new("127.0.0.1:0", ART).with_workers(1));
    serve(cfg, exec, Arc::new(registry), meta.cfg.clone(), w0).unwrap()
}

/// The value of the sample whose series name (labels included) is
/// exactly `series` — the text left of the sample's final space.
fn sample(text: &str, series: &str) -> f64 {
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix(series) {
            if let Some(v) = rest.strip_prefix(' ') {
                return v.parse().expect("sample value parses");
            }
        }
    }
    panic!("series {series:?} not found in scrape:\n{text}");
}

/// `(ev, req, note)` of one drained span event; `note` is empty when
/// the event carries none.
fn span(j: &Json) -> (String, u64, String) {
    let ev = j.req("ev").unwrap().as_str().unwrap().to_string();
    let req = j.req("req").unwrap().as_usize().unwrap() as u64;
    let note = match j.get("note") {
        Some(v) => v.as_str().unwrap().to_string(),
        None => String::new(),
    };
    (ev, req, note)
}

/// The `metrics` op serves well-formed Prometheus text: every sample
/// line parses, at least five histograms render with cumulative
/// buckets ending at a `+Inf` equal to `_count`, and the counters
/// agree with the `stats` op (same snapshot source).
#[test]
fn metrics_scrape_is_valid_prometheus_and_matches_stats() {
    let handle = start(|c| c);
    let mut client = Client::connect(handle.addr).unwrap();
    for _ in 0..2 {
        let toks = client.generate("a0", vec![1, 2, 3], 2).unwrap();
        assert!(toks.len() <= 2);
    }
    let text = client.metrics_text().unwrap();
    let stats = client.stats().unwrap();

    // every non-comment line is "series value" with a numeric value
    for line in text.lines() {
        if line.starts_with('#') || line.is_empty() {
            continue;
        }
        let (series, value) = line.rsplit_once(' ').expect("sample lines split on a space");
        assert!(!series.is_empty(), "unnamed sample: {line:?}");
        assert!(value.parse::<f64>().is_ok(), "non-numeric sample value: {line:?}");
    }

    // the acceptance floor: at least five histogram families
    let hist_count =
        text.lines().filter(|l| l.starts_with("# TYPE") && l.ends_with("histogram")).count();
    assert!(hist_count >= 5, "want >=5 histograms, got {hist_count}:\n{text}");
    for name in [
        "unilora_ttft_seconds",
        "unilora_queue_wait_seconds",
        "unilora_request_latency_seconds",
        "unilora_decode_step_seconds",
        "unilora_prompt_tokens",
    ] {
        assert!(text.contains(&format!("# TYPE {name} histogram")), "{name} missing:\n{text}");
        let count = sample(&text, &format!("{name}_count"));
        let inf = sample(&text, &format!("{name}_bucket{{le=\"+Inf\"}}"));
        assert_eq!(count, inf, "{name}: +Inf bucket must equal _count");
    }

    // counters mirror the stats op
    let stat = |k: &str| stats.get(k).unwrap().as_f64().unwrap();
    assert_eq!(sample(&text, "unilora_requests_total"), stat("requests"));
    assert_eq!(sample(&text, "unilora_generated_tokens_total"), stat("generated_tokens"));
    assert_eq!(sample(&text, "unilora_kv_bytes_in_flight"), stat("kv_bytes_in_flight"));
    assert_eq!(sample(&text, "unilora_workers"), 1.0);

    // the per-request distributions saw both requests
    assert_eq!(sample(&text, "unilora_request_latency_seconds_count"), 2.0);
    assert_eq!(sample(&text, "unilora_prompt_tokens_count"), 2.0);
    assert_eq!(sample(&text, "unilora_prompt_tokens_sum"), 6.0, "two 3-token prompts");

    // the busy-span union: positive, surfaced identically in both ops
    // (the worker has been idle since the scrape), and never larger
    // than the summed per-step CPU seconds
    let wall = stat("decode_wall_secs");
    assert!(wall > 0.0, "decode happened, busy time must be positive");
    let busy = sample(&text, "unilora_decode_busy_seconds_total");
    assert!((busy - wall).abs() < 1e-9, "busy seconds diverged: {busy} vs {wall}");
    assert!(busy <= sample(&text, "unilora_decode_cpu_seconds_total") + 1e-9);
    handle.shutdown();
}

/// With profiling pinned on, the scrape gains the per-stage
/// `unilora_profile_*` counters and decode work lands in them.
#[test]
fn profile_stage_attribution_lands_in_the_scrape() {
    profile::set_enabled(true);
    let handle = start(|c| c);
    let mut client = Client::connect(handle.addr).unwrap();
    let toks = client.generate_sampled("a0", vec![1, 2, 3], 4, no_eos()).unwrap();
    assert_eq!(toks.len(), 4);
    let text = client.metrics_text().unwrap();
    assert!(text.contains("# TYPE unilora_profile_seconds_total counter"), "{text}");
    assert!(text.contains("# TYPE unilora_profile_calls_total counter"), "{text}");
    for stage in
        ["base_gemm", "factored_apply", "dense_gemv", "attention", "logits", "sampling", "prefill"]
    {
        let series = format!("unilora_profile_seconds_total{{stage=\"{stage}\"}}");
        assert!(text.contains(&series), "stage {stage} missing:\n{text}");
    }
    // the decode above must have attributed work: one prefill per
    // admission, one sampling call per emitted row, and fused-step
    // stages for the single-position steps after the prefill
    let calls = |stage: &str| {
        sample(&text, &format!("unilora_profile_calls_total{{stage=\"{stage}\"}}"))
    };
    assert!(calls("prefill") >= 1.0, "prefill ran:\n{text}");
    assert!(calls("sampling") >= 4.0, "four emitted tokens:\n{text}");
    assert!(calls("base_gemm") >= 1.0, "fused steps ran base GEMMs:\n{text}");
    assert!(calls("attention") >= 1.0, "fused steps ran attention:\n{text}");
    handle.shutdown();
}

/// The `trace` op reconstructs a full per-request timeline for the
/// three lifecycle shapes the ISSUE names: a streamed request that
/// completes, a client that disconnects mid-stream, and a request
/// that outlives its deadline. The drain is destructive, so each
/// phase reads exactly its own events.
#[test]
fn trace_reconstructs_streamed_cancelled_and_deadline_timelines() {
    let handle = start(|c| {
        c.with_session(SessionOpts::with_slots(1))
            .with_faults(Arc::new(Faults::parse("5:slow=1@15").unwrap()))
            .with_trace_ring(4096)
    });
    let addr = handle.addr;
    let mut client = Client::connect(addr).unwrap();

    // --- phase 1: streamed request, runs to completion -------------
    {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        writeln!(
            writer,
            r#"{{"op":"generate","adapter":"a0","prompt":[1,21,7],"max_new":3,"sampling":{{{EOS_BIAS}}},"stream":true}}"#
        )
        .unwrap();
        loop {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            match Response::parse(&line).unwrap() {
                Response::Frame { done, .. } => {
                    if done {
                        break;
                    }
                }
                other => panic!("streamed request must stream: {other:?}"),
            }
        }
    }
    let spans: Vec<_> = client.trace_events().unwrap().iter().map(span).collect();
    let reqs: Vec<u64> = spans.iter().map(|s| s.1).filter(|&r| r != 0).collect();
    let id = reqs[0];
    assert!(reqs.iter().all(|&r| r == id), "one request, one id: {spans:?}");
    let kinds: Vec<&str> = spans.iter().filter(|s| s.1 == id).map(|s| s.0.as_str()).collect();
    assert_eq!(kinds[0], "enqueue", "{spans:?}");
    let pos = |k: &str| kinds.iter().position(|&e| e == k);
    let (admit, prefill) = (pos("admit").unwrap(), pos("prefill").unwrap());
    let (step, frame) = (pos("step").unwrap(), pos("frame").unwrap());
    assert!(admit < prefill && prefill <= step && step < frame, "{kinds:?}");
    assert_eq!(kinds.iter().filter(|&&e| e == "step").count(), 3, "{kinds:?}");
    assert_eq!(kinds.iter().filter(|&&e| e == "frame").count(), 3, "{kinds:?}");
    assert_eq!(*kinds.last().unwrap(), "done", "{kinds:?}");
    let done = spans.iter().find(|s| s.0 == "done").unwrap();
    assert_eq!(done.2, "ok", "completed request ends with done/ok: {spans:?}");
    let enq = spans.iter().find(|s| s.0 == "enqueue").unwrap();
    assert_eq!(enq.2, "a0", "enqueue notes the adapter: {spans:?}");

    // --- phase 2: client disconnects mid-stream --------------------
    {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        writeln!(
            writer,
            r#"{{"op":"generate","adapter":"a0","prompt":[1,21,7],"max_new":40,"sampling":{{{EOS_BIAS}}},"stream":true}}"#
        )
        .unwrap();
        for _ in 0..2 {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert!(line.contains(r#""ok":true"#), "expected a frame: {line}");
        }
        // drop both halves: the next frame write fails server-side
    }
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let stats = client.stats().unwrap();
        if stats.get("client_gone").unwrap().as_f64().unwrap() >= 1.0 {
            break;
        }
        assert!(Instant::now() < deadline, "disconnect was never detected");
        std::thread::sleep(Duration::from_millis(20));
    }
    let spans: Vec<_> = client.trace_events().unwrap().iter().map(span).collect();
    let cancel = spans.iter().find(|s| s.0 == "cancel").expect("cancel event");
    assert_eq!(cancel.2, "client_gone", "{spans:?}");
    let done = spans.iter().find(|s| s.0 == "done").expect("terminal event");
    assert_eq!(done.2, "client_gone", "{spans:?}");
    assert_eq!(done.1, cancel.1, "cancel and terminal belong to the same request");

    // --- phase 3: deadline exceeded mid-flight ---------------------
    let req = Request::Generate {
        adapter: "a0".into(),
        prompt: vec![1, 21, 7],
        max_new: 50,
        sampling: no_eos(),
        stream: false,
        timeout_ms: 60,
    };
    match client.call(&req).unwrap() {
        Response::Error(e) => assert_eq!(e.code, ErrCode::DeadlineExceeded, "{e:?}"),
        other => panic!("50 tokens at 15ms/step must miss a 60ms deadline: {other:?}"),
    }
    let spans: Vec<_> = client.trace_events().unwrap().iter().map(span).collect();
    let dl = spans.iter().find(|s| s.0 == "deadline").expect("deadline event");
    let done = spans.iter().find(|s| s.0 == "done").expect("terminal event");
    assert_eq!(done.2, "deadline_exceeded", "{spans:?}");
    assert_eq!(done.1, dl.1, "deadline and terminal belong to the same request");
    let kinds: Vec<&str> = spans.iter().filter(|s| s.1 == dl.1).map(|s| s.0.as_str()).collect();
    assert_eq!(kinds[0], "enqueue", "{kinds:?}");
    assert!(kinds.contains(&"admit"), "the request was decoding when it expired: {kinds:?}");
    assert_eq!(*kinds.last().unwrap(), "done", "{kinds:?}");
    handle.shutdown();
}

/// Draining is destructive and scoped to the ring: a second drain on
/// an idle server is empty, and a ring of zero capacity records
/// nothing at all.
#[test]
fn trace_drain_consumes_and_zero_ring_disables() {
    let handle = start(|c| c.with_trace_ring(0));
    let mut client = Client::connect(handle.addr).unwrap();
    client.generate("a0", vec![1, 2, 3], 1).unwrap();
    assert!(client.trace_events().unwrap().is_empty(), "zero ring records nothing");
    handle.shutdown();

    let handle = start(|c| c.with_trace_ring(64));
    let mut client = Client::connect(handle.addr).unwrap();
    client.generate("a0", vec![1, 2, 3], 1).unwrap();
    assert!(!client.trace_events().unwrap().is_empty(), "default path records spans");
    assert!(client.trace_events().unwrap().is_empty(), "drain consumes");
    handle.shutdown();
}
