//! PJRT/artifact integration: real AOT HLO artifacts through the PJRT
//! runtime. Only compiled with `--features pjrt`, and every test that
//! touches an executable artifact is `#[ignore]`d because it needs
//! `make artifacts` (Python + jax) and a real `xla` crate in place of
//! the offline stub. Plain `cargo test` exercises the same pipeline on
//! the native backend instead (tests/integration.rs).
#![cfg(feature = "pjrt")]

use uni_lora::projection::statics::{gen_statics, init_theta};
use uni_lora::rng;
use uni_lora::runtime::{Executor, Manifest, TensorIn};

fn executor() -> Option<Executor> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Executor::new(Manifest::load(dir).unwrap()).unwrap())
}

/// Initialize the frozen backbone from the manifest's base segments.
fn init_base(exec: &Executor, name: &str, seed: u64) -> Vec<f32> {
    uni_lora::coordinator::init_base(exec.manifest.get(name).unwrap(), seed)
}

#[test]
#[ignore = "requires AOT HLO artifacts (make artifacts) and a real xla crate in place of vendor/xla-stub"]
fn cls_train_step_runs_and_learns() {
    let Some(mut exec) = executor() else { return };
    let name = "glue_base_uni_c2_cls_train";
    let meta = exec.manifest.get(name).unwrap().clone();
    let cfg = meta.cfg.clone();
    let seed = 42u64;

    let mut theta = init_theta(&cfg, seed).unwrap();
    let mut m = vec![0f32; meta.d];
    let mut v = vec![0f32; meta.d];
    let mut head = vec![0f32; meta.head_params];
    let mut hm = vec![0f32; meta.head_params];
    let mut hv = vec![0f32; meta.head_params];
    let w0 = init_base(&exec, name, seed);
    let stats = gen_statics(&cfg, seed).unwrap();

    // learnable toy batch: label = parity of first token
    let (b, t) = (cfg.batch, cfg.seq);
    let tokens = rng::indices(7, b * t, cfg.vocab);
    let labels: Vec<i32> = (0..b).map(|i| tokens[i * t] % 2).collect();
    let attn_len = vec![t as i32; b];

    let mut losses = Vec::new();
    for step in 1..=10 {
        let mut inputs = vec![
            TensorIn::F32(theta.clone()),
            TensorIn::F32(m.clone()),
            TensorIn::F32(v.clone()),
            TensorIn::F32(head.clone()),
            TensorIn::F32(hm.clone()),
            TensorIn::F32(hv.clone()),
            TensorIn::ScalarI32(step),
            TensorIn::ScalarF32(5e-3),
            TensorIn::ScalarF32(5e-2),
            TensorIn::ScalarF32(0.0),
            TensorIn::F32(w0.clone()),
            TensorIn::I32(tokens.clone()),
            TensorIn::I32(attn_len.clone()),
            TensorIn::I32(labels.clone()),
        ];
        inputs.extend(stats.iter().map(TensorIn::from));
        let out = exec.run(name, &inputs).unwrap();
        theta = out[0].clone().f32().unwrap();
        m = out[1].clone().f32().unwrap();
        v = out[2].clone().f32().unwrap();
        head = out[3].clone().f32().unwrap();
        hm = out[4].clone().f32().unwrap();
        hv = out[5].clone().f32().unwrap();
        losses.push(out[6].scalar_f32().unwrap());
    }
    assert!(losses.iter().all(|l| l.is_finite()), "{losses:?}");
    assert!(losses[9] < losses[0], "loss did not decrease: {losses:?}");
}

#[test]
#[ignore = "requires AOT HLO artifacts (make artifacts) and a real xla crate in place of vendor/xla-stub"]
fn cls_eval_shapes() {
    let Some(mut exec) = executor() else { return };
    let name = "glue_base_uni_c2_cls_eval";
    let meta = exec.manifest.get(name).unwrap().clone();
    let cfg = meta.cfg.clone();
    let theta = init_theta(&cfg, 1).unwrap();
    let head = vec![0f32; meta.head_params];
    let w0 = init_base(&exec, name, 1);
    let stats = gen_statics(&cfg, 1).unwrap();
    let tokens = rng::indices(3, cfg.batch * cfg.seq, cfg.vocab);
    let attn_len = vec![cfg.seq as i32; cfg.batch];
    let mut inputs = vec![
        TensorIn::F32(theta),
        TensorIn::F32(head),
        TensorIn::F32(w0),
        TensorIn::I32(tokens),
        TensorIn::I32(attn_len),
    ];
    inputs.extend(stats.iter().map(TensorIn::from));
    let out = exec.run(name, &inputs).unwrap();
    assert_eq!(out.len(), 1);
    let logits = out[0].as_f32().unwrap();
    assert_eq!(logits.len(), cfg.batch * cfg.n_classes);
    assert!(logits.iter().all(|x| x.is_finite()));
}

#[test]
#[ignore = "requires AOT HLO artifacts (make artifacts) and a real xla crate in place of vendor/xla-stub"]
fn executor_input_validation() {
    let Some(mut exec) = executor() else { return };
    let err = exec
        .run("glue_base_uni_c2_cls_eval", &[TensorIn::F32(vec![0.0])])
        .unwrap_err();
    assert!(err.to_string().contains("inputs"), "{err}");
    assert!(exec.run("no_such_artifact", &[]).is_err());
}

#[test]
#[ignore = "requires AOT HLO artifacts (make artifacts) and a real xla crate in place of vendor/xla-stub"]
fn pjrt_manifest_matches_native_registry() {
    // When artifacts exist, the Python-lowered manifest and the Rust
    // native registry must agree on signatures — the cross-backend
    // contract behind `dyn Backend`.
    let Some(exec) = executor() else { return };
    let native = uni_lora::runtime::NativeBackend::new().unwrap();
    use uni_lora::runtime::Backend;
    for (name, a) in &exec.manifest.artifacts {
        let b = native.meta(name).expect("artifact missing from native registry");
        assert_eq!(a.kind, b.kind, "{name}");
        assert_eq!(a.d, b.d, "{name}");
        assert_eq!(a.big_d, b.big_d, "{name}");
        assert_eq!(a.base_params, b.base_params, "{name}");
        assert_eq!(a.head_params, b.head_params, "{name}");
        assert_eq!(a.inputs.len(), b.inputs.len(), "{name}");
        for (x, y) in a.inputs.iter().zip(&b.inputs) {
            assert_eq!(x.name, y.name, "{name}");
            assert_eq!(x.shape, y.shape, "{name}/{}", x.name);
        }
        assert_eq!(a.outputs, b.outputs, "{name}");
    }
}
