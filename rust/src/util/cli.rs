//! Tiny CLI argument parser (`--key value` / `--flag` style; clap is
//! unavailable in the offline vendor set).

use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Positional arguments, in order.
    pub positional: Vec<String>,
    /// `--key value` pairs; bare `--flag` maps to "true".
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|v| !v.starts_with("--")).unwrap_or(false) {
                    out.flags.insert(key.to_string(), it.next().unwrap());
                } else {
                    out.flags.insert(key.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn required(&self, key: &str) -> Result<&str> {
        self.get(key).ok_or_else(|| anyhow!("missing required --{key}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn kv_and_flags() {
        let a = parse(&["train", "--steps", "100", "--verbose", "--lr=0.01", "pos2"]);
        assert_eq!(a.positional, vec!["train", "pos2"]);
        assert_eq!(a.usize_or("steps", 0), 100);
        assert!(a.has("verbose"));
        assert_eq!(a.f32_or("lr", 0.0), 0.01);
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.usize_or("steps", 7), 7);
        assert!(a.required("x").is_err());
    }

    #[test]
    fn negative_number_value() {
        let a = parse(&["--bias", "-3"]);
        assert_eq!(a.get("bias"), Some("-3"));
    }
}
