//! Minimal JSON parser/writer (serde is unavailable in this offline
//! vendor set). Covers everything the artifact manifest and the server
//! protocol need: objects, arrays, strings (with escapes), numbers,
//! bools, null.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — for required fields.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        Ok(self.as_f64()? as i64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    /// Shape helper: `[2, 3]` -> `vec![2, 3]`.
    pub fn as_shape(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for building protocol messages.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn n(v: f64) -> Json {
    Json::Num(v)
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(txt.parse::<f64>().map_err(|e| anyhow!("bad number {txt:?}: {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // collect the full UTF-8 sequence
                    let len = match c {
                        0x00..=0x7f => 0,
                        0xc0..=0xdf => 1,
                        0xe0..=0xef => 2,
                        _ => 3,
                    };
                    let start = self.i - 1;
                    self.i += len;
                    out.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                c => bail!("expected , or ] got {:?}", c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                c => bail!("expected , or }} got {:?}", c as char),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let src = r#"{"a": [1, 2.5, -3], "b": {"c": "x\"y\n"}, "d": true, "e": null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str().unwrap(), "x\"y\n");
        assert_eq!(v.get("d").unwrap().as_bool().unwrap(), true);
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn parse_numbers() {
        assert_eq!(Json::parse("3.5e2").unwrap().as_f64().unwrap(), 350.0);
        assert_eq!(Json::parse("-7").unwrap().as_i64().unwrap(), -7);
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,").is_err());
    }

    #[test]
    fn unicode_string() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café ☕");
    }

    #[test]
    fn shape_helper() {
        let v = Json::parse("[2, 3, 4]").unwrap();
        assert_eq!(v.as_shape().unwrap(), vec![2, 3, 4]);
    }
}
