//! Small substrate utilities: JSON (offline — no serde), CLI argument
//! parsing (no clap), wall-clock timing and memory introspection.

pub mod cli;
pub mod json;

use std::time::Instant;

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// Peak resident set size of this process in MiB (Linux), for the
/// Table 12 "GPU memory" analogue.
pub fn peak_rss_mib() -> f64 {
    if let Ok(s) = std::fs::read_to_string("/proc/self/status") {
        for line in s.lines() {
            if let Some(rest) = line.strip_prefix("VmHWM:") {
                if let Some(kb) = rest.split_whitespace().next() {
                    if let Ok(kb) = kb.parse::<f64>() {
                        return kb / 1024.0;
                    }
                }
            }
        }
    }
    0.0
}

/// Median of a slice (sorted copy). Empty slice -> NaN.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Sample standard deviation. <2 samples -> 0.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = xs.iter().sum::<f64>() / xs.len() as f64;
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Human format for parameter counts, paper style: 524288 -> "0.52M".
pub fn fmt_params(n: usize) -> String {
    if n >= 100_000 {
        format!("{:.2}M", n as f64 / 1e6)
    } else if n >= 1000 {
        format!("{:.1}K", n as f64 / 1e3)
    } else {
        format!("{n}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_and_std() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert!(median(&[]).is_nan());
        assert_eq!(stddev(&[1.0]), 0.0);
        assert!((stddev(&[1.0, 3.0]) - std::f64::consts::SQRT_2).abs() < 1e-12);
    }

    #[test]
    fn fmt() {
        assert_eq!(fmt_params(532), "532");
        assert_eq!(fmt_params(2048), "2.0K");
        assert_eq!(fmt_params(524_288), "0.52M");
    }

    #[test]
    fn rss_positive() {
        assert!(peak_rss_mib() > 0.0);
    }
}
