//! L3 runtime: execution backends behind the `Backend` trait.
//!
//! - `native` (default): pure-Rust CPU forward/backward for every
//!   artifact kind — no Python, no artifacts, no PJRT.
//! - `executor` (`--features pjrt`): loads AOT HLO artifacts and runs
//!   them on the PJRT CPU client (the only place the `xla` crate is
//!   touched).
//!
//! Everything above works with plain `Vec<f32>`/`Vec<i32>` tensors and
//! `&mut dyn Backend`.

pub mod artifact;
pub mod backend;
pub mod native;
pub mod spec;
pub mod tensor;

#[cfg(feature = "pjrt")]
pub mod executor;

pub use artifact::{ArtifactMeta, InputSpec, Manifest, SegmentSpec};
pub use backend::Backend;
pub use native::NativeBackend;
pub use tensor::{ExecStats, TensorIn, TensorOut};

#[cfg(feature = "pjrt")]
pub use executor::{Executor, PjrtBackend};

use anyhow::Result;

/// Construct a backend by name: "native" or "pjrt".
pub fn backend_by_name(name: &str) -> Result<Box<dyn Backend>> {
    match name {
        "native" => Ok(Box::new(NativeBackend::new()?)),
        #[cfg(feature = "pjrt")]
        "pjrt" => Ok(Box::new(PjrtBackend::with_default_manifest()?)),
        #[cfg(not(feature = "pjrt"))]
        "pjrt" => anyhow::bail!(
            "this binary was built without the `pjrt` feature; rebuild with \
             `cargo build --features pjrt` (and AOT artifacts) to use the PJRT backend"
        ),
        other => anyhow::bail!("unknown backend {other:?} (expected \"native\" or \"pjrt\")"),
    }
}

/// The default backend: $UNI_LORA_BACKEND if set, else native.
pub fn default_backend() -> Result<Box<dyn Backend>> {
    let name = std::env::var("UNI_LORA_BACKEND").unwrap_or_else(|_| "native".to_string());
    backend_by_name(&name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_backend_is_native() {
        // (env override is exercised manually; tests must not depend on env)
        let be = backend_by_name("native").unwrap();
        assert_eq!(be.name(), "native");
        assert!(backend_by_name("bogus").is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_backend_requires_feature() {
        let err = backend_by_name("pjrt").unwrap_err().to_string();
        assert!(err.contains("pjrt"), "{err}");
    }
}
