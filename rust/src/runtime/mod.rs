//! L3 runtime: loads the AOT HLO artifacts and executes them on the
//! PJRT CPU client. This is the only place the `xla` crate is touched;
//! everything above works with plain `Vec<f32>`/`Vec<i32>` tensors.

pub mod artifact;
pub mod executor;

pub use artifact::{ArtifactMeta, InputSpec, Manifest, SegmentSpec};
pub use executor::{Executor, TensorIn, TensorOut};
