//! The execution backend abstraction (multi-backend architecture,
//! ROADMAP north star). Everything above the runtime — trainers,
//! evaluator, sweeps, the serving router, benches, examples — drives a
//! `dyn Backend` and never knows whether steps run on the pure-Rust CPU
//! executor or through PJRT-compiled HLO artifacts.
//!
//! Contract: an artifact name (e.g. `glue_base_uni_c2_cls_train`)
//! resolves to an `ArtifactMeta` describing a positional input
//! signature and output order; `run` executes one step. The signatures
//! are identical across backends (they mirror `python/compile/aot.py`),
//! so callers are backend-agnostic by construction.

use super::artifact::{ArtifactMeta, DType};
use super::tensor::{ExecStats, TensorIn, TensorOut};
use crate::session::{DecodeSession, FallbackSession, SessionOpts};
use anyhow::{bail, Result};
use std::path::PathBuf;
use std::sync::Arc;

pub trait Backend: Send {
    /// Short backend identifier ("native" | "pjrt").
    fn name(&self) -> &'static str;

    /// Metadata (signature, config, layouts) for an artifact.
    fn meta(&self, artifact: &str) -> Result<&ArtifactMeta>;

    /// All artifact names this backend can execute, sorted.
    fn artifact_names(&self) -> Vec<String>;

    /// Warm an artifact (compile for PJRT; no-op for native).
    fn prepare(&mut self, artifact: &str) -> Result<()> {
        self.meta(artifact).map(|_| ())
    }

    /// Clone this backend so another execution worker can own one (the
    /// serving worker pool). Backends wrapping non-replicable resources
    /// (e.g. a PJRT client) may refuse; callers must degrade to fewer
    /// workers, not fail the serve path.
    fn try_clone(&self) -> Result<Box<dyn Backend>> {
        bail!("backend {:?} does not support cloning", self.name())
    }

    /// Cache a frozen input so later `run` calls can pass
    /// `TensorIn::Pinned` instead of re-supplying the host vector.
    fn pin(&mut self, artifact: &str, input: &str, t: &TensorIn) -> Result<()>;

    /// Drop all pinned inputs.
    fn unpin_all(&mut self);

    /// Execute an artifact with positional inputs; returns the outputs
    /// in the artifact's declared order.
    fn run(&mut self, artifact: &str, inputs: &[TensorIn]) -> Result<Vec<TensorOut>>;

    /// Begin a stateful decode session over an `lm_logits`-kind
    /// artifact (see `crate::session` for the lifecycle:
    /// `begin_decode` → `admit`/`step` per token → `finish`). The
    /// default implementation is the full-forward fallback — it drives
    /// ordinary `run` calls and therefore works on ANY backend (PJRT
    /// keeps working with zero extra code); backends with real
    /// incremental state (native K/V caches) override it.
    fn begin_decode(
        &mut self,
        artifact: &str,
        w0: Arc<Vec<f32>>,
        opts: &SessionOpts,
    ) -> Result<Box<dyn DecodeSession>> {
        let meta = self.meta(artifact)?.clone();
        Ok(Box::new(FallbackSession::new(meta, w0, opts)?))
    }

    /// Cumulative execution statistics.
    fn stats(&self) -> ExecStats;

    fn reset_stats(&mut self);

    /// Directory for derived caches (pretrained backbones).
    fn cache_dir(&self) -> PathBuf;
}

/// Shared positional-input validation: count, element count and dtype
/// against the artifact signature. `Pinned` slots are skipped (the
/// backend resolves them against its pin cache).
pub fn check_inputs(meta: &ArtifactMeta, inputs: &[TensorIn]) -> Result<()> {
    if inputs.len() != meta.inputs.len() {
        bail!(
            "artifact {}: got {} inputs, signature has {}",
            meta.name,
            inputs.len(),
            meta.inputs.len()
        );
    }
    for (spec, t) in meta.inputs.iter().zip(inputs) {
        if matches!(t, TensorIn::Pinned) {
            continue;
        }
        if t.numel() != spec.numel() {
            bail!(
                "artifact {} input {}: got {} elements, want {} {:?}",
                meta.name,
                spec.name,
                t.numel(),
                spec.numel(),
                spec.shape
            );
        }
        match (&spec.dtype, t) {
            (DType::F32, TensorIn::F32(_) | TensorIn::SharedF32(_) | TensorIn::ScalarF32(_)) => {}
            (DType::I32, TensorIn::I32(_) | TensorIn::SharedI32(_) | TensorIn::ScalarI32(_)) => {}
            _ => bail!("artifact {} input {}: dtype mismatch", meta.name, spec.name),
        }
    }
    Ok(())
}
