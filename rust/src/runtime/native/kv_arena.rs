//! Block-paged K/V arena: the session-owned replacement for per-slot
//! `layers * 2 * seq * hidden` K/V preallocation.
//!
//! One *page* holds every layer's keys AND values for
//! [`crate::config::KV_PAGE_TOKENS`] consecutive positions, so a slot's
//! K/V state is a short page table instead of a pair of full-window
//! buffers. Pages are physical `Vec<f32>` blocks allocated lazily the
//! first time a position inside them is written, recycled through a
//! free list when a slot retires, and never handed to two slots at
//! once. Idle slots hold zero pages; a slot mid-decode holds exactly
//! `ceil(len / page_tokens)` pages — resident bytes track tokens
//! actually in flight, not worst-case windows.
//!
//! Admission becomes a *token budget*: [`KvArena::reserve`] accounts
//! (in page units) for the worst case a sequence can ever need —
//! `min(seq, prompt + max_new)` positions — and fails with the typed
//! [`KvBudgetExhausted`] error when the budget cannot cover it.
//! Reserving up front means a mid-decode `grow` can never fail: every
//! page a live slot will touch is already promised to it, so the
//! decode hot path stays infallible and the router can treat budget
//! exhaustion as a retryable admission condition (capacity frees when
//! slots retire), distinct from malformed-request errors.
//!
//! Numerics: the arena only changes WHERE K/V rows live, never their
//! values or the order attention reads them (positions ascend within
//! and across pages), so paged decode is bit-identical to the flat
//! cache on every kernel tier. Recycled pages are handed out dirty on
//! purpose — causal attention at position `p` reads only rows
//! `0..=p`, all written during the owning slot's lifetime.

use crate::config::{ModelCfg, KV_PAGE_TOKENS};
use anyhow::{ensure, Result};

/// Typed admission failure: the arena's token budget cannot cover a
/// reservation. Carries the page accounting so callers (the router)
/// can tell a transient condition (`needed_pages <= budget_pages`:
/// retry once slots retire) from an impossible one.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvBudgetExhausted {
    /// pages the admission needs reserved
    pub needed_pages: usize,
    /// pages not currently reserved by live slots
    pub free_pages: usize,
    /// total pages the arena may ever hand out
    pub budget_pages: usize,
}

impl std::fmt::Display for KvBudgetExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "kv token budget exhausted: admission needs {} pages, {} of {} free",
            self.needed_pages, self.free_pages, self.budget_pages
        )
    }
}

impl std::error::Error for KvBudgetExhausted {}

/// One sequence's view into the arena: a page table plus the same
/// `len`/`cap` cursor the flat cache kept. Created by
/// [`KvArena::reserve`], returned to the arena by [`KvArena::release`]
/// (dropping a slot without releasing it leaks its reservation — the
/// session owns that pairing, and the churn fuzz test enforces it).
#[derive(Debug, Default)]
pub struct KvSlot {
    /// physical page index per logical page, in position order;
    /// grows lazily via [`KvArena::grow`]
    page_ids: Vec<usize>,
    /// pages promised at reservation time (page table never outgrows this)
    reserved_pages: usize,
    /// positions already processed
    pub len: usize,
    /// reserved position capacity (`incr_forward`'s overflow bound)
    pub cap: usize,
}

/// Session-owned paged K/V storage shared by every decode slot.
pub struct KvArena {
    layers: usize,
    hidden: usize,
    /// f32 length of one physical page:
    /// `layers * 2 * KV_PAGE_TOKENS * hidden`
    page_floats: usize,
    budget_pages: usize,
    /// physical pages; allocated on first use, kept for reuse after
    pages: Vec<Vec<f32>>,
    /// recycled physical page indices available for reuse
    free: Vec<usize>,
    /// pages currently reserved by live slots (incl. unmaterialized)
    reserved: usize,
    /// physical pages currently held by live slots
    held: usize,
    /// pages recycled over the arena's lifetime (slot retirements)
    churn: u64,
}

/// Pages needed to hold `tokens` positions.
pub fn pages_for_tokens(tokens: usize) -> usize {
    tokens.div_ceil(KV_PAGE_TOKENS)
}

impl KvArena {
    /// An arena with a hard budget of `budget_pages` pages (0 is
    /// clamped to 1 so a session can always hold one page). See
    /// `SessionOpts::resolve_kv_pages` for the `UNI_LORA_KV_PAGES`
    /// knob and the worst-case default.
    pub fn new(cfg: &ModelCfg, budget_pages: usize) -> KvArena {
        KvArena {
            layers: cfg.layers,
            hidden: cfg.hidden,
            page_floats: cfg.layers * 2 * KV_PAGE_TOKENS * cfg.hidden,
            budget_pages: budget_pages.max(1),
            pages: Vec::new(),
            free: Vec::new(),
            reserved: 0,
            held: 0,
            churn: 0,
        }
    }

    pub fn layers(&self) -> usize {
        self.layers
    }

    pub fn budget_pages(&self) -> usize {
        self.budget_pages
    }

    /// Pages not reserved by any live slot.
    pub fn free_pages(&self) -> usize {
        self.budget_pages - self.reserved
    }

    /// Pages reserved by live slots (materialized or not).
    pub fn reserved_pages(&self) -> usize {
        self.reserved
    }

    /// Physical pages currently held by live slots.
    pub fn used_pages(&self) -> usize {
        self.held
    }

    /// Bytes held by live slots — actual tokens in flight rounded up
    /// to page granularity, NOT reserved capacity.
    pub fn bytes_in_flight(&self) -> usize {
        self.held * self.page_floats * std::mem::size_of::<f32>()
    }

    /// Pages recycled over the arena's lifetime.
    pub fn page_churn(&self) -> u64 {
        self.churn
    }

    /// Reserve capacity for a sequence that will occupy at most
    /// `tokens` positions. `tokens == 0` (stillborn admissions) holds
    /// nothing and always succeeds.
    pub fn reserve(&mut self, tokens: usize) -> Result<KvSlot, KvBudgetExhausted> {
        let needed = pages_for_tokens(tokens);
        if self.reserved + needed > self.budget_pages {
            return Err(KvBudgetExhausted {
                needed_pages: needed,
                free_pages: self.free_pages(),
                budget_pages: self.budget_pages,
            });
        }
        self.reserved += needed;
        Ok(KvSlot { page_ids: Vec::new(), reserved_pages: needed, len: 0, cap: tokens })
    }

    /// Return a slot's pages to the free list and drop its
    /// reservation. Idempotent: a released slot holds nothing.
    pub fn release(&mut self, slot: &mut KvSlot) {
        let recycled = slot.page_ids.len();
        for pid in slot.page_ids.drain(..) {
            self.free.push(pid);
        }
        self.held -= recycled;
        self.churn += recycled as u64;
        self.reserved -= slot.reserved_pages;
        slot.reserved_pages = 0;
        slot.len = 0;
        slot.cap = 0;
    }

    /// Materialize pages so positions `0..new_len` are addressable.
    /// Infallible within the slot's reservation (the point of
    /// reserving at admission); exceeding it is a caller bug.
    pub fn grow(&mut self, slot: &mut KvSlot, new_len: usize) -> Result<()> {
        let need = pages_for_tokens(new_len);
        ensure!(
            need <= slot.reserved_pages,
            "kv arena grow past reservation: {new_len} positions need {need} pages, \
             slot reserved {}",
            slot.reserved_pages
        );
        while slot.page_ids.len() < need {
            let pid = match self.free.pop() {
                // recycled pages are reused dirty (see module docs)
                Some(pid) => pid,
                None => {
                    self.pages.push(vec![0f32; self.page_floats]);
                    self.pages.len() - 1
                }
            };
            slot.page_ids.push(pid);
            self.held += 1;
        }
        Ok(())
    }

    /// Flat offset of row (layer `l`, k/v select `sel`, position
    /// `pos`) inside its page. Consecutive positions within a page are
    /// contiguous per (layer, k/v) so attention walks mostly-linear
    /// memory.
    #[inline]
    fn row_at(&self, slot: &KvSlot, l: usize, sel: usize, pos: usize) -> (usize, usize) {
        let pid = slot.page_ids[pos / KV_PAGE_TOKENS];
        let off = ((l * 2 + sel) * KV_PAGE_TOKENS + pos % KV_PAGE_TOKENS) * self.hidden;
        (pid, off)
    }

    #[inline]
    pub fn k_row(&self, slot: &KvSlot, l: usize, pos: usize) -> &[f32] {
        let (pid, off) = self.row_at(slot, l, 0, pos);
        &self.pages[pid][off..off + self.hidden]
    }

    #[inline]
    pub fn v_row(&self, slot: &KvSlot, l: usize, pos: usize) -> &[f32] {
        let (pid, off) = self.row_at(slot, l, 1, pos);
        &self.pages[pid][off..off + self.hidden]
    }

    #[inline]
    pub fn k_row_mut(&mut self, slot: &KvSlot, l: usize, pos: usize) -> &mut [f32] {
        let (pid, off) = self.row_at(slot, l, 0, pos);
        &mut self.pages[pid][off..off + self.hidden]
    }

    #[inline]
    pub fn v_row_mut(&mut self, slot: &KvSlot, l: usize, pos: usize) -> &mut [f32] {
        let (pid, off) = self.row_at(slot, l, 1, pos);
        &mut self.pages[pid][off..off + self.hidden]
    }
}

/// Single-sequence convenience over the arena — the shape
/// `incr_forward` and the model-level tests use: one private arena
/// with a full-window reservation, so standalone incremental decode
/// needs no session. `byte_size` reports pages actually materialized,
/// not the reservation.
pub struct KvCache {
    pub arena: KvArena,
    pub slot: KvSlot,
}

impl KvCache {
    pub fn new(cfg: &ModelCfg) -> KvCache {
        let mut arena = KvArena::new(cfg, pages_for_tokens(cfg.seq));
        let slot = arena.reserve(cfg.seq).expect("full-window reservation fits its own budget");
        KvCache { arena, slot }
    }

    /// Positions already processed.
    pub fn len(&self) -> usize {
        self.slot.len
    }

    pub fn is_empty(&self) -> bool {
        self.slot.len == 0
    }

    /// Resident bytes: pages this cache has materialized — zero until
    /// the first prefill writes a position.
    pub fn byte_size(&self) -> usize {
        self.arena.bytes_in_flight()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelCfg {
        let mut c = ModelCfg::test_base("uni");
        c.layers = 2;
        c.hidden = 8;
        c.seq = 3 * KV_PAGE_TOKENS + 5; // spans whole and partial pages
        c
    }

    #[test]
    fn reservation_accounting_and_exact_exhaustion() {
        let c = cfg();
        let mut a = KvArena::new(&c, 4);
        assert_eq!((a.budget_pages(), a.free_pages(), a.used_pages()), (4, 4, 0));

        // stillborn reservations hold nothing and always fit
        let mut zero = a.reserve(0).unwrap();
        assert_eq!((zero.cap, a.reserved_pages()), (0, 0));

        let mut s1 = a.reserve(KV_PAGE_TOKENS + 1).unwrap(); // 2 pages
        let mut s2 = a.reserve(2 * KV_PAGE_TOKENS).unwrap(); // 2 pages
        assert_eq!((a.reserved_pages(), a.free_pages()), (4, 0));
        // budget exhausted EXACTLY here: one more token needs a page
        let err = a.reserve(1).unwrap_err();
        assert_eq!(err, KvBudgetExhausted { needed_pages: 1, free_pages: 0, budget_pages: 4 });
        assert!(err.to_string().contains("kv token budget exhausted"), "{err}");

        // nothing is materialized until grow; bytes track used pages
        assert_eq!((a.used_pages(), a.bytes_in_flight()), (0, 0));
        a.grow(&mut s1, 1).unwrap();
        let page_bytes = c.layers * 2 * KV_PAGE_TOKENS * c.hidden * 4;
        assert_eq!((a.used_pages(), a.bytes_in_flight()), (1, page_bytes));
        // growing within the same page allocates nothing new
        a.grow(&mut s1, KV_PAGE_TOKENS).unwrap();
        assert_eq!(a.used_pages(), 1);
        a.grow(&mut s1, KV_PAGE_TOKENS + 1).unwrap();
        assert_eq!(a.used_pages(), 2);
        // growing past the reservation is a caller bug, not a budget miss
        assert!(a.grow(&mut s1, 2 * KV_PAGE_TOKENS + 1).is_err());

        // release returns capacity and counts churn
        a.release(&mut s1);
        assert_eq!((a.reserved_pages(), a.used_pages(), a.page_churn()), (2, 0, 2));
        a.release(&mut s2);
        a.release(&mut zero);
        assert_eq!((a.reserved_pages(), a.free_pages(), a.page_churn()), (0, 4, 2));
        // released slots are inert: releasing again changes nothing
        a.release(&mut s1);
        assert_eq!((a.reserved_pages(), a.page_churn()), (0, 2));
    }

    #[test]
    fn pages_are_recycled_not_reallocated() {
        let c = cfg();
        let mut a = KvArena::new(&c, 2);
        let mut s = a.reserve(KV_PAGE_TOKENS).unwrap();
        a.grow(&mut s, KV_PAGE_TOKENS).unwrap();
        assert_eq!(a.pages.len(), 1);
        a.release(&mut s);
        // the next slot reuses the physical page instead of growing the pool
        let mut s2 = a.reserve(KV_PAGE_TOKENS).unwrap();
        a.grow(&mut s2, 1).unwrap();
        assert_eq!((a.pages.len(), a.used_pages()), (1, 1));
        a.release(&mut s2);
        assert_eq!(a.page_churn(), 2);
    }

    #[test]
    fn rows_roundtrip_across_page_boundaries() {
        let c = cfg();
        let mut a = KvArena::new(&c, pages_for_tokens(c.seq));
        let mut s = a.reserve(c.seq).unwrap();
        a.grow(&mut s, c.seq).unwrap();
        // write a distinct signature into every (layer, k/v, pos) row
        for l in 0..c.layers {
            for pos in 0..c.seq {
                let kv = (1000 * l + pos) as f32;
                a.k_row_mut(&s, l, pos).fill(kv);
                a.v_row_mut(&s, l, pos).fill(-kv - 1.0);
            }
        }
        // reads see exactly what was written — no row aliases another,
        // including across the page boundary at pos = KV_PAGE_TOKENS
        for l in 0..c.layers {
            for pos in 0..c.seq {
                let kv = (1000 * l + pos) as f32;
                assert!(a.k_row(&s, l, pos).iter().all(|&x| x == kv), "k l={l} pos={pos}");
                assert!(a.v_row(&s, l, pos).iter().all(|&x| x == -kv - 1.0), "v l={l} pos={pos}");
                assert_eq!(a.k_row(&s, l, pos).len(), c.hidden);
            }
        }
        a.release(&mut s);
    }

    #[test]
    fn kv_cache_wrapper_reports_used_pages_only() {
        let c = cfg();
        let mut kv = KvCache::new(&c);
        // a fresh cache reserves the window but materializes nothing
        assert_eq!(kv.byte_size(), 0);
        assert!(kv.is_empty());
        kv.arena.grow(&mut kv.slot, 1).unwrap();
        let page_bytes = c.layers * 2 * KV_PAGE_TOKENS * c.hidden * 4;
        assert_eq!(kv.byte_size(), page_bytes);
        // a full window is still bounded by the page-rounded seq
        kv.arena.grow(&mut kv.slot, c.seq).unwrap();
        assert_eq!(kv.byte_size(), pages_for_tokens(c.seq) * page_bytes);
    }
}
