//! Pure-Rust MiniLM transformer: forward AND backward, mirroring
//! `python/compile/model.py` (same pre-LN architecture, same adapted
//! q/v matmuls, same pooling/losses) so the native backend can execute
//! the train/eval/logits artifact kinds with no Python, no HLO and no
//! PJRT on the path.
//!
//! Everything operates on flat row-major `&[f32]` buffers. All dense
//! math routes through the `crate::kernels` compute layer (blocked
//! multi-threaded GEMMs plus parallel drivers for the attention and
//! elementwise loops); nothing in this file owns a matmul loop nest
//! anymore, and the shared hot maps (GELU forward/grad, the LM-softmax
//! row max) come from the kernel-variant vtable (`kernels::dispatch`),
//! so `UNI_LORA_KERNELS` swaps the whole tier under this file without
//! touching it. Results are bitwise identical across runs and thread
//! counts for every tier — see the determinism contracts in
//! `kernels::pool` and `kernels::dispatch`. Backward is hand-written
//! (autodiff of the forward graph) and covered by finite-difference
//! tests below.

use crate::config::ModelCfg;
use crate::kernels::dispatch;
use crate::kernels::{gemm_nn, gemm_nt, gemm_tn, parallel_chunks, parallel_for_work, SendPtr};
use crate::obs::profile;
use crate::projection::reconstruct::ModuleDelta;
use crate::runtime::spec;
use anyhow::{ensure, Result};
use std::collections::BTreeMap;
use std::sync::Arc;

// ------------------------------------------------------------------
// frozen backbone layout

/// Precomputed `(offset, len)` spans of one transformer layer's
/// segments — resolved once when the [`BaseLayout`] is built, so the
/// per-token hot paths (`incr_forward`, `adapted_weights`,
/// `lm_logits_row`) never touch a `format!("wq{l}")` string key.
#[derive(Clone, Copy)]
pub struct LayerSegs {
    pub ln1_g: (usize, usize),
    pub ln1_b: (usize, usize),
    pub wq: (usize, usize),
    pub wk: (usize, usize),
    pub wv: (usize, usize),
    pub wo: (usize, usize),
    pub ln2_g: (usize, usize),
    pub ln2_b: (usize, usize),
    pub w1: (usize, usize),
    pub w2: (usize, usize),
}

/// Precomputed spans of the non-layer segments.
#[derive(Clone, Copy)]
pub struct FixedSegs {
    pub tok_emb: (usize, usize),
    pub pos_emb: (usize, usize),
    pub lnf_g: (usize, usize),
    pub lnf_b: (usize, usize),
    pub lm_head: (usize, usize),
}

/// Backbone layout table (segment name -> (offset, len)) decoupled
/// from any particular `w0` borrow: long-lived holders (the decode
/// session) build it once and `bind` it to the weights each step,
/// instead of re-deriving the per-segment name strings for every
/// generated token. Per-layer and fixed spans are additionally
/// resolved into index tables here, so the string map is only
/// consulted by the (cold) train/eval paths.
#[derive(Clone)]
pub struct BaseLayout {
    offs: Arc<BTreeMap<String, (usize, usize)>>,
    layers: Arc<Vec<LayerSegs>>,
    fixed: FixedSegs,
    total: usize,
}

impl BaseLayout {
    pub fn new(cfg: &ModelCfg) -> BaseLayout {
        let mut offs = BTreeMap::new();
        let mut off = 0usize;
        for s in spec::base_segments(cfg) {
            let n = s.numel();
            offs.insert(s.name.clone(), (off, n));
            off += n;
        }
        let at = |name: &str| offs[name];
        let layers: Vec<LayerSegs> = (0..cfg.layers)
            .map(|l| LayerSegs {
                ln1_g: at(&format!("ln1_g{l}")),
                ln1_b: at(&format!("ln1_b{l}")),
                wq: at(&format!("wq{l}")),
                wk: at(&format!("wk{l}")),
                wv: at(&format!("wv{l}")),
                wo: at(&format!("wo{l}")),
                ln2_g: at(&format!("ln2_g{l}")),
                ln2_b: at(&format!("ln2_b{l}")),
                w1: at(&format!("w1{l}")),
                w2: at(&format!("w2{l}")),
            })
            .collect();
        let fixed = FixedSegs {
            tok_emb: at("tok_emb"),
            pos_emb: at("pos_emb"),
            lnf_g: at("lnf_g"),
            lnf_b: at("lnf_b"),
            lm_head: at("lm_head"),
        };
        BaseLayout { offs: Arc::new(offs), layers: Arc::new(layers), fixed, total: off }
    }

    /// View `w0` through this layout (validating the length).
    pub fn bind<'a>(&self, w0: &'a [f32]) -> Result<BaseMap<'a>> {
        ensure!(
            w0.len() == self.total,
            "w0 has {} params, backbone layout needs {}",
            w0.len(),
            self.total
        );
        Ok(BaseMap {
            w0,
            offs: self.offs.clone(),
            layers: self.layers.clone(),
            fixed: self.fixed,
            total: self.total,
        })
    }
}

/// Named views into the flat w0 vector (layout = spec::base_segments).
pub struct BaseMap<'a> {
    w0: &'a [f32],
    offs: Arc<BTreeMap<String, (usize, usize)>>,
    layers: Arc<Vec<LayerSegs>>,
    fixed: FixedSegs,
    total: usize,
}

impl<'a> BaseMap<'a> {
    pub fn new(cfg: &ModelCfg, w0: &'a [f32]) -> Result<BaseMap<'a>> {
        BaseLayout::new(cfg).bind(w0)
    }

    pub fn seg(&self, name: &str) -> &'a [f32] {
        let (o, n) = self.offs[name];
        &self.w0[o..o + n]
    }

    /// Slice a precomputed `(offset, len)` span out of the backbone.
    pub fn at(&self, span: (usize, usize)) -> &'a [f32] {
        &self.w0[span.0..span.0 + span.1]
    }

    /// Precomputed spans for layer `l`.
    pub fn layer(&self, l: usize) -> &LayerSegs {
        &self.layers[l]
    }

    /// Precomputed spans for the non-layer segments.
    pub fn fixed(&self) -> &FixedSegs {
        &self.fixed
    }

    pub fn offset(&self, name: &str) -> (usize, usize) {
        self.offs[name]
    }

    pub fn total(&self) -> usize {
        self.total
    }
}

// ------------------------------------------------------------------
// primitives

pub struct LnCache {
    xhat: Vec<f32>,
    rstd: Vec<f32>,
}

fn layer_norm(x: &[f32], g: &[f32], b: &[f32], n: usize, h: usize) -> (Vec<f32>, LnCache) {
    let mut out = vec![0f32; n * h];
    let mut xhat = vec![0f32; n * h];
    let mut rstd = vec![0f32; n];
    for i in 0..n {
        let row = &x[i * h..(i + 1) * h];
        let mu = row.iter().map(|&v| v as f64).sum::<f64>() / h as f64;
        let var = row.iter().map(|&v| (v as f64 - mu) * (v as f64 - mu)).sum::<f64>() / h as f64;
        let rs = 1.0 / (var + 1e-5).sqrt();
        rstd[i] = rs as f32;
        for j in 0..h {
            let xh = ((row[j] as f64 - mu) * rs) as f32;
            xhat[i * h + j] = xh;
            out[i * h + j] = xh * g[j] + b[j];
        }
    }
    (out, LnCache { xhat, rstd })
}

/// Returns (d_input, d_gamma, d_beta).
fn layer_norm_backward(
    dy: &[f32],
    g: &[f32],
    c: &LnCache,
    n: usize,
    h: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut dx = vec![0f32; n * h];
    let mut dgamma = vec![0f32; h];
    let mut dbeta = vec![0f32; h];
    let hf = h as f64;
    for i in 0..n {
        let dyr = &dy[i * h..(i + 1) * h];
        let xhr = &c.xhat[i * h..(i + 1) * h];
        let mut s1 = 0f64;
        let mut s2 = 0f64;
        for j in 0..h {
            let dxh = (dyr[j] * g[j]) as f64;
            s1 += dxh;
            s2 += dxh * xhr[j] as f64;
        }
        let rs = c.rstd[i] as f64;
        for j in 0..h {
            let dxh = (dyr[j] * g[j]) as f64;
            dx[i * h + j] = (rs * (dxh - s1 / hf - xhr[j] as f64 * s2 / hf)) as f32;
            dgamma[j] += dyr[j] * xhr[j];
            dbeta[j] += dyr[j];
        }
    }
    (dx, dgamma, dbeta)
}

pub struct AttnCache {
    /// softmax probabilities [B, nh, T, T], zero above the diagonal
    att: Vec<f32>,
}

/// Causal multi-head attention. q/k/v: [B*T, h] -> out [B*T, h].
/// Parallelized over (batch, head) pairs on the kernel pool; each task
/// owns a disjoint slab of `att` and column stripe of `out`, and runs
/// the same per-query loop order as the single-threaded original, so
/// results are thread-count invariant. The tiny head-dim dots stay
/// inlined (NOT vtable-dispatched): an indirect call per (query, key)
/// pair would dominate a ~16-64 FLOP loop, and keeping the legacy
/// expressions preserves the scalar tier's bit-parity here.
fn attention(cfg: &ModelCfg, q: &[f32], k: &[f32], v: &[f32]) -> (Vec<f32>, AttnCache) {
    let (b, t, h, nh) = (cfg.batch, cfg.seq, cfg.hidden, cfg.heads);
    let hd = cfg.head_dim();
    let scale = 1.0 / (hd as f32).sqrt();
    let mut att = vec![0f32; b * nh * t * t];
    let mut out = vec![0f32; b * t * h];
    let att_ptr = SendPtr::new(&mut att);
    let out_ptr = SendPtr::new(&mut out);
    parallel_for_work(b * nh * t * t * hd, b * nh, |task| {
        let (bi, n) = (task / nh, task % nh);
        // SAFETY: task (bi, n) exclusively owns the (bi, n) slab of
        // `att` and the [n*hd, (n+1)*hd) stripe of rows bi*t..(bi+1)*t
        // of `out`; no two tasks overlap.
        let att_bn = unsafe { att_ptr.slice((bi * nh + n) * t * t, t * t) };
        let mut sc = vec![0f32; t];
        for i in 0..t {
            let qo = (bi * t + i) * h + n * hd;
            let orow = unsafe { out_ptr.slice(qo, hd) };
            let mut mx = f32::NEG_INFINITY;
            for j in 0..=i {
                let ko = (bi * t + j) * h + n * hd;
                let mut dot = 0f32;
                for dd in 0..hd {
                    dot += q[qo + dd] * k[ko + dd];
                }
                sc[j] = dot * scale;
                if sc[j] > mx {
                    mx = sc[j];
                }
            }
            let mut denom = 0f32;
            for j in 0..=i {
                sc[j] = (sc[j] - mx).exp();
                denom += sc[j];
            }
            for j in 0..=i {
                let w = sc[j] / denom;
                att_bn[i * t + j] = w;
                let vo = (bi * t + j) * h + n * hd;
                for dd in 0..hd {
                    orow[dd] += w * v[vo + dd];
                }
            }
        }
    });
    (out, AttnCache { att })
}

/// Returns (dq, dk, dv), each [B*T, h].
fn attention_backward(
    cfg: &ModelCfg,
    d_out: &[f32],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    cache: &AttnCache,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (b, t, h, nh) = (cfg.batch, cfg.seq, cfg.hidden, cfg.heads);
    let hd = cfg.head_dim();
    let scale = 1.0 / (hd as f32).sqrt();
    let mut dq = vec![0f32; b * t * h];
    let mut dk = vec![0f32; b * t * h];
    let mut dv = vec![0f32; b * t * h];
    let dq_ptr = SendPtr::new(&mut dq);
    let dk_ptr = SendPtr::new(&mut dk);
    let dv_ptr = SendPtr::new(&mut dv);
    parallel_for_work(b * nh * t * t * hd, b * nh, |task| {
        let (bi, n) = (task / nh, task % nh);
        let mut datt = vec![0f32; t];
        for i in 0..t {
            let qo = (bi * t + i) * h + n * hd;
            let ao = ((bi * nh + n) * t + i) * t;
            // SAFETY: dq/dk/dv writes stay inside the head-n stripe of
            // batch bi's rows — exclusively owned by task (bi, n); the
            // three buffers are separate allocations, so dqrow never
            // aliases dkrow/dvrow even when j == i.
            let dqrow = unsafe { dq_ptr.slice(qo, hd) };
            let mut ssum = 0f32;
            for j in 0..=i {
                let vo = (bi * t + j) * h + n * hd;
                let mut dot = 0f32;
                for dd in 0..hd {
                    dot += d_out[qo + dd] * v[vo + dd];
                }
                datt[j] = dot;
                ssum += dot * cache.att[ao + j];
            }
            for j in 0..=i {
                let a = cache.att[ao + j];
                let ds = a * (datt[j] - ssum) * scale;
                let ko = (bi * t + j) * h + n * hd;
                let dkrow = unsafe { dk_ptr.slice(ko, hd) };
                let dvrow = unsafe { dv_ptr.slice(ko, hd) };
                for dd in 0..hd {
                    dqrow[dd] += ds * k[ko + dd];
                    dkrow[dd] += ds * q[qo + dd];
                    dvrow[dd] += a * d_out[qo + dd];
                }
            }
        }
    });
    (dq, dk, dv)
}

/// Dense effective weight for one adapted module: W0 + scale * DeltaW.
fn effective_weight(w0: &[f32], delta: &ModuleDelta, h: usize, r: usize, scale: f32) -> Vec<f32> {
    let mut w = w0.to_vec();
    match delta {
        ModuleDelta::LowRank { a, b } => {
            // (scale * A) @ B accumulated onto the W0 copy
            let sa: Vec<f32> = a.iter().map(|&v| scale * v).collect();
            gemm_nn(&sa, b, &mut w, h, r, h, true);
        }
        ModuleDelta::Dense(dw) => {
            for (wi, di) in w.iter_mut().zip(dw) {
                *wi += scale * di;
            }
        }
    }
    w
}

// ------------------------------------------------------------------
// forward

struct LayerCache {
    ln1: LnCache,
    x2: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn: AttnCache,
    att_out: Vec<f32>,
    weff_q: Vec<f32>,
    weff_v: Vec<f32>,
    ln2: LnCache,
    x3: Vec<f32>,
    u: Vec<f32>,
    gelu: Vec<f32>,
}

/// Activations retained for one backward pass.
pub struct ForwardCache {
    layers: Vec<LayerCache>,
    lnf: LnCache,
    /// final layer-norm output [B*T, h]
    pub hidden: Vec<f32>,
}

/// Backbone forward: tokens [B*T] -> hidden states (after final LN).
pub fn forward(
    cfg: &ModelCfg,
    base: &BaseMap,
    deltas: &[ModuleDelta],
    tokens: &[i32],
) -> Result<ForwardCache> {
    let (b, t, h, f, r) = (cfg.batch, cfg.seq, cfg.hidden, cfg.ffn, cfg.rank);
    let bt = b * t;
    let kops = dispatch::ops();
    ensure!(tokens.len() == bt, "tokens: got {}, want {}", tokens.len(), bt);
    ensure!(
        deltas.len() == cfg.n_modules(),
        "deltas: got {}, want {}",
        deltas.len(),
        cfg.n_modules()
    );

    let tok_emb = base.seg("tok_emb");
    let pos_emb = base.seg("pos_emb");
    let mut x = vec![0f32; bt * h];
    for row in 0..bt {
        let tok = tokens[row];
        ensure!(
            tok >= 0 && (tok as usize) < cfg.vocab,
            "token id {tok} out of range for vocab {}",
            cfg.vocab
        );
        let te = &tok_emb[(tok as usize) * h..(tok as usize + 1) * h];
        let pe = &pos_emb[(row % t) * h..(row % t + 1) * h];
        let xr = &mut x[row * h..(row + 1) * h];
        for j in 0..h {
            xr[j] = te[j] + pe[j];
        }
    }

    let mut layers = Vec::with_capacity(cfg.layers);
    for l in 0..cfg.layers {
        let weff_q = effective_weight(base.seg(&format!("wq{l}")), &deltas[2 * l], h, r, cfg.scale);
        let weff_v =
            effective_weight(base.seg(&format!("wv{l}")), &deltas[2 * l + 1], h, r, cfg.scale);
        let (x2, ln1) =
            layer_norm(&x, base.seg(&format!("ln1_g{l}")), base.seg(&format!("ln1_b{l}")), bt, h);
        let mut q = vec![0f32; bt * h];
        let mut k = vec![0f32; bt * h];
        let mut v = vec![0f32; bt * h];
        gemm_nn(&x2, &weff_q, &mut q, bt, h, h, false);
        gemm_nn(&x2, base.seg(&format!("wk{l}")), &mut k, bt, h, h, false);
        gemm_nn(&x2, &weff_v, &mut v, bt, h, h, false);
        let (att_out, attn) = attention(cfg, &q, &k, &v);
        let mut x_mid = vec![0f32; bt * h];
        gemm_nn(&att_out, base.seg(&format!("wo{l}")), &mut x_mid, bt, h, h, false);
        for (xm, xi) in x_mid.iter_mut().zip(&x) {
            *xm += xi;
        }
        let (x3, ln2) = layer_norm(
            &x_mid,
            base.seg(&format!("ln2_g{l}")),
            base.seg(&format!("ln2_b{l}")),
            bt,
            h,
        );
        let mut u = vec![0f32; bt * f];
        gemm_nn(&x3, base.seg(&format!("w1{l}")), &mut u, bt, h, f, false);
        let mut gelu_v = vec![0f32; bt * f];
        {
            let dst = SendPtr::new(&mut gelu_v);
            let src = &u;
            parallel_chunks(bt * f, 4096, |s, e| {
                // SAFETY: chunks are disjoint
                let d = unsafe { dst.slice(s, e - s) };
                (kops.gelu_map)(d, &src[s..e]);
            });
        }
        let mut x_next = vec![0f32; bt * h];
        gemm_nn(&gelu_v, base.seg(&format!("w2{l}")), &mut x_next, bt, f, h, false);
        for (xn, xm) in x_next.iter_mut().zip(&x_mid) {
            *xn += xm;
        }
        layers.push(LayerCache {
            ln1,
            x2,
            q,
            k,
            v,
            attn,
            att_out,
            weff_q,
            weff_v,
            ln2,
            x3,
            u,
            gelu: gelu_v,
        });
        x = x_next;
    }

    let (hidden, lnf) = layer_norm(&x, base.seg("lnf_g"), base.seg("lnf_b"), bt, h);
    Ok(ForwardCache { layers, lnf, hidden })
}

// ------------------------------------------------------------------
// incremental decoding (the session subsystem's compute layer)

/// Dense adapted q/v projections for every layer — `W0 + scale*DeltaW`
/// materialized by the SAME `effective_weight` accumulation `forward`
/// uses (hence bit-identical to what a full forward would build), but
/// once per adapter instead of once per forward call. This is the
/// value `session::ReconCache` holds: an adapter checkpoint is one
/// tiny vector, its reconstruction is `2 * layers * h^2` floats.
pub struct AdaptedWeights {
    /// per layer: adapted q projection `[h, h]`
    pub wq: Vec<Vec<f32>>,
    /// per layer: adapted v projection `[h, h]`
    pub wv: Vec<Vec<f32>>,
}

impl AdaptedWeights {
    /// Resident bytes (reconstruction-cache footprint accounting).
    pub fn byte_size(&self) -> usize {
        let n: usize = self.wq.iter().chain(&self.wv).map(|w| w.len()).sum();
        n * std::mem::size_of::<f32>()
    }
}

/// Build the per-layer adapted weights from reconstructed deltas.
pub fn adapted_weights(
    cfg: &ModelCfg,
    base: &BaseMap,
    deltas: &[ModuleDelta],
) -> Result<AdaptedWeights> {
    ensure!(
        deltas.len() == cfg.n_modules(),
        "deltas: got {}, want {}",
        deltas.len(),
        cfg.n_modules()
    );
    let (h, r) = (cfg.hidden, cfg.rank);
    let mut wq = Vec::with_capacity(cfg.layers);
    let mut wv = Vec::with_capacity(cfg.layers);
    for l in 0..cfg.layers {
        let segs = base.layer(l);
        wq.push(effective_weight(base.at(segs.wq), &deltas[2 * l], h, r, cfg.scale));
        wv.push(effective_weight(base.at(segs.wv), &deltas[2 * l + 1], h, r, cfg.scale));
    }
    Ok(AdaptedWeights { wq, wv })
}

/// Rank-r factors for every adapted module, held exactly as
/// `reconstruct::ModuleDelta` produced them — never densified. This is
/// the paper's serving story made literal: per-adapter resident state
/// is `4 * layers * h * r` floats (the A/B factors for q and v per
/// layer) instead of the `2 * layers * h^2` a dense reconstruction
/// costs, so thousands of adapters fit where one dense reconstruction
/// used to.
pub struct FactoredWeights {
    /// per layer: q-projection factors (`a: [h, r]`, `b: [r, h]`)
    q: Vec<(Vec<f32>, Vec<f32>)>,
    /// per layer: v-projection factors
    v: Vec<(Vec<f32>, Vec<f32>)>,
    scale: f32,
    rank: usize,
}

impl FactoredWeights {
    /// Capture the rank-r factors from reconstructed deltas. Returns
    /// `None` when ANY module delta is `Dense` (FourierFT): a dense
    /// spectral delta has no factored form, so such adapters must run
    /// through [`AdapterExec::Dense`] — the session cost model owns
    /// that routing, not the call sites.
    pub fn from_deltas(cfg: &ModelCfg, deltas: &[ModuleDelta]) -> Option<FactoredWeights> {
        if deltas.len() != cfg.n_modules() {
            return None;
        }
        let mut q = Vec::with_capacity(cfg.layers);
        let mut v = Vec::with_capacity(cfg.layers);
        for l in 0..cfg.layers {
            for (dst, d) in [(&mut q, &deltas[2 * l]), (&mut v, &deltas[2 * l + 1])] {
                match d {
                    ModuleDelta::LowRank { a, b } => dst.push((a.clone(), b.clone())),
                    ModuleDelta::Dense(_) => return None,
                }
            }
        }
        Some(FactoredWeights { q, v, scale: cfg.scale, rank: cfg.rank })
    }

    /// Resident bytes (factored-mode footprint accounting).
    pub fn byte_size(&self) -> usize {
        let n: usize = self.q.iter().chain(&self.v).map(|(a, b)| a.len() + b.len()).sum();
        n * std::mem::size_of::<f32>()
    }
}

/// How a decode slot applies its adapter — the first-class execution
/// representation the session subsystem schedules:
///
/// - `Dense`: today's path — GEMV against `W0 + scale*DeltaW`
///   materialized once per adapter (via the `ReconCache`). Cheapest
///   per step, `2 * layers * h^2` floats resident per adapter.
/// - `Factored`: GEMV against the frozen `W0` plus `y += scale*B(A x)`
///   as two rank-r GEMVs — no `h×h` delta is ever built. Per-adapter
///   residency is just the rank-r factors, which is what lets a
///   session serve thousands of distinct one-vector adapters.
pub enum AdapterExec {
    Dense(Arc<AdaptedWeights>),
    Factored(FactoredWeights),
}

impl AdapterExec {
    pub fn is_dense(&self) -> bool {
        matches!(self, AdapterExec::Dense(_))
    }

    /// Resident bytes attributable to this exec form. `Dense` reports
    /// 0 here: the dense weights are owned (and counted) by the
    /// `ReconCache`, and the slot only holds a refcount.
    pub fn byte_size(&self) -> usize {
        match self {
            AdapterExec::Dense(_) => 0,
            AdapterExec::Factored(fw) => fw.byte_size(),
        }
    }
}

/// `y += scale * (x @ a) @ b` — the factored-mode adapter application:
/// two rank-r GEMVs through the kernels vtable instead of one h×h
/// GEMV against a densified delta. Accumulating the second GEMM
/// (`acc = true`) keeps the per-element k-ascending contract: each
/// output element is finished in one pass, exactly as the dense path's
/// single accumulation is.
fn apply_factored(
    x: &[f32],
    (a, b): &(Vec<f32>, Vec<f32>),
    scale: f32,
    r: usize,
    y: &mut [f32],
    n: usize,
    h: usize,
) {
    let mut t = vec![0f32; n * r];
    gemm_nn(x, a, &mut t, n, h, r, false);
    for v in t.iter_mut() {
        *v *= scale;
    }
    gemm_nn(&t, b, y, n, r, h, true);
}

// Per-sequence decode state lives in the block-paged arena now; the
// single-slot `KvCache` convenience and the session-shared `KvArena`
// are re-exported so existing call sites keep their import paths.
pub use super::kv_arena::{KvArena, KvBudgetExhausted, KvCache, KvSlot};

/// Incremental backbone forward for ONE sequence: process `toks` at
/// absolute positions `kv.len .. kv.len + toks.len()`, append their
/// keys/values to the cache, and return the final-layer-norm hidden
/// row of the LAST new position (`[h]`). With an empty cache and the
/// whole prompt in `toks` this is the prefill pass; with one token it
/// is a single decode step — per-token cost O(model) instead of the
/// full forward's O(seq * model).
///
/// Parity contract: causal attention makes position p depend only on
/// tokens `0..=p`, and every op here is per-row (LN, GELU, GEMM rows
/// with per-element k-ascending accumulation, the attention
/// expressions copied from `attention` verbatim), so the returned row
/// is bit-identical to the `[B, T]` `forward`'s row at the same
/// position — on every kernel tier — when `w` is `Dense`. The
/// `Factored` mode computes the SAME adapted projection as
/// `scale*B(A x)` added onto `x @ W0`, which associates the float sums
/// differently from densifying first: factored streams are held to
/// token-stream parity with dense (argmax-equal logits), not bit
/// parity — `tests/decode_parity.rs` asserts exactly that.
pub fn incr_forward(
    cfg: &ModelCfg,
    base: &BaseMap,
    w: &AdapterExec,
    kv: &mut KvCache,
    toks: &[i32],
) -> Result<Vec<f32>> {
    let KvCache { arena, slot } = kv;
    incr_forward_slot(cfg, base, w, arena, slot, toks)
}

/// [`incr_forward`] against a session-shared [`KvArena`]: the slot's
/// K/V rows live in arena pages instead of private buffers. Same
/// numerics row for row — the arena changes where rows are stored,
/// never their values or read order.
pub fn incr_forward_slot(
    cfg: &ModelCfg,
    base: &BaseMap,
    w: &AdapterExec,
    arena: &mut KvArena,
    kv: &mut KvSlot,
    toks: &[i32],
) -> Result<Vec<f32>> {
    let (h, f, nh) = (cfg.hidden, cfg.ffn, cfg.heads);
    let hd = cfg.head_dim();
    let scale = 1.0 / (hd as f32).sqrt();
    let kops = dispatch::ops();
    let start = kv.len;
    let n = toks.len();
    ensure!(n > 0, "incr_forward: empty token slice");
    ensure!(
        arena.layers() == cfg.layers,
        "kv arena has {} layers, want {}",
        arena.layers(),
        cfg.layers
    );
    ensure!(kv.cap <= cfg.seq, "kv reservation {} exceeds window {}", kv.cap, cfg.seq);
    ensure!(
        start + n <= kv.cap,
        "kv cache overflow: {start} processed + {n} new > window {}",
        kv.cap
    );
    match w {
        AdapterExec::Dense(aw) => {
            ensure!(aw.wq.len() == cfg.layers, "adapted weights have {} layers", aw.wq.len())
        }
        AdapterExec::Factored(fw) => {
            ensure!(fw.q.len() == cfg.layers, "factored weights have {} layers", fw.q.len())
        }
    }
    // materialize pages up front so the layer loop never allocates
    arena.grow(kv, start + n)?;

    // embeddings at the absolute positions
    let fixed = *base.fixed();
    let tok_emb = base.at(fixed.tok_emb);
    let pos_emb = base.at(fixed.pos_emb);
    let mut x = vec![0f32; n * h];
    for i in 0..n {
        let tok = toks[i];
        ensure!(
            tok >= 0 && (tok as usize) < cfg.vocab,
            "token id {tok} out of range for vocab {}",
            cfg.vocab
        );
        let te = &tok_emb[(tok as usize) * h..(tok as usize + 1) * h];
        let pe = &pos_emb[(start + i) * h..(start + i + 1) * h];
        let xr = &mut x[i * h..(i + 1) * h];
        for j in 0..h {
            xr[j] = te[j] + pe[j];
        }
    }

    for l in 0..cfg.layers {
        let segs = *base.layer(l);
        let (x2, _) = layer_norm(&x, base.at(segs.ln1_g), base.at(segs.ln1_b), n, h);
        // adapted q projection: dense GEMV, or base GEMV + rank-r update
        let mut q = vec![0f32; n * h];
        match w {
            AdapterExec::Dense(aw) => gemm_nn(&x2, &aw.wq[l], &mut q, n, h, h, false),
            AdapterExec::Factored(fw) => {
                gemm_nn(&x2, base.at(segs.wq), &mut q, n, h, h, false);
                apply_factored(&x2, &fw.q[l], fw.scale, fw.rank, &mut q, n, h);
            }
        }
        // new keys/values land directly in the slot's arena pages
        {
            let mut knew = vec![0f32; n * h];
            gemm_nn(&x2, base.at(segs.wk), &mut knew, n, h, h, false);
            let mut vnew = vec![0f32; n * h];
            match w {
                AdapterExec::Dense(aw) => gemm_nn(&x2, &aw.wv[l], &mut vnew, n, h, h, false),
                AdapterExec::Factored(fw) => {
                    gemm_nn(&x2, base.at(segs.wv), &mut vnew, n, h, h, false);
                    apply_factored(&x2, &fw.v[l], fw.scale, fw.rank, &mut vnew, n, h);
                }
            }
            for i in 0..n {
                arena.k_row_mut(kv, l, start + i).copy_from_slice(&knew[i * h..(i + 1) * h]);
                arena.v_row_mut(kv, l, start + i).copy_from_slice(&vnew[i * h..(i + 1) * h]);
            }
        }
        // causal attention: query at absolute position start+i over
        // cached keys 0..=start+i — the same expression order as
        // `attention` (running max, exp pass, weighted accumulate)
        let mut att_out = vec![0f32; n * h];
        let mut sc = vec![0f32; start + n];
        for head in 0..nh {
            for i in 0..n {
                let p = start + i;
                let qo = i * h + head * hd;
                let ko = head * hd;
                let mut mx = f32::NEG_INFINITY;
                for j in 0..=p {
                    let krow = arena.k_row(kv, l, j);
                    let mut dot = 0f32;
                    for dd in 0..hd {
                        dot += q[qo + dd] * krow[ko + dd];
                    }
                    sc[j] = dot * scale;
                    if sc[j] > mx {
                        mx = sc[j];
                    }
                }
                let mut denom = 0f32;
                for j in 0..=p {
                    sc[j] = (sc[j] - mx).exp();
                    denom += sc[j];
                }
                let orow = &mut att_out[qo..qo + hd];
                for j in 0..=p {
                    let wj = sc[j] / denom;
                    let vrow = arena.v_row(kv, l, j);
                    for dd in 0..hd {
                        orow[dd] += wj * vrow[ko + dd];
                    }
                }
            }
        }
        let mut x_mid = vec![0f32; n * h];
        gemm_nn(&att_out, base.at(segs.wo), &mut x_mid, n, h, h, false);
        for (xm, xi) in x_mid.iter_mut().zip(&x) {
            *xm += xi;
        }
        let (x3, _) = layer_norm(&x_mid, base.at(segs.ln2_g), base.at(segs.ln2_b), n, h);
        let mut u = vec![0f32; n * f];
        gemm_nn(&x3, base.at(segs.w1), &mut u, n, h, f, false);
        let mut gelu_v = vec![0f32; n * f];
        (kops.gelu_map)(&mut gelu_v, &u);
        let mut x_next = vec![0f32; n * h];
        gemm_nn(&gelu_v, base.at(segs.w2), &mut x_next, n, f, h, false);
        for (xn, xm) in x_next.iter_mut().zip(&x_mid) {
            *xn += xm;
        }
        x = x_next;
    }
    kv.len = start + n;

    // final layer norm on the LAST row only (LN is per-row)
    let last = &x[(n - 1) * h..n * h];
    let (hidden, _) = layer_norm(last, base.at(fixed.lnf_g), base.at(fixed.lnf_b), 1, h);
    Ok(hidden)
}

/// Next-token logits for one hidden row: `[vocab] = row @ lm_head` —
/// the incremental replacement for the full `[B*T, vocab]` lm head.
pub fn lm_logits_row(cfg: &ModelCfg, base: &BaseMap, hidden_row: &[f32]) -> Vec<f32> {
    let mut logits = vec![0f32; cfg.vocab];
    let head = base.at(base.fixed().lm_head);
    gemm_nn(hidden_row, head, &mut logits, 1, cfg.hidden, cfg.vocab, false);
    logits
}

/// Next-token logits for `m` stacked hidden rows: one `[m, vocab]`
/// GEMM against the shared lm head. Per-row results are bit-equal to
/// [`lm_logits_row`] on every tier (per-element k-ascending
/// accumulation is row-count invariant).
pub fn lm_logits_batch(cfg: &ModelCfg, base: &BaseMap, hidden: &[f32], m: usize) -> Vec<f32> {
    let mut logits = vec![0f32; m * cfg.vocab];
    let head = base.at(base.fixed().lm_head);
    gemm_nn(hidden, head, &mut logits, m, cfg.hidden, cfg.vocab, false);
    logits
}

/// One sequence's contribution to a fused decode step: its execution
/// form, its arena slot, and the single token to feed at position
/// `kv.len`.
pub struct BatchEntry<'a> {
    pub exec: &'a AdapterExec,
    pub kv: &'a mut KvSlot,
    pub tok: i32,
}

/// GEMM over a subset of a batch's rows: gather `rows` of `x`
/// (`[m, k]` row-major), multiply by `wmat` (`[k, nout]`), scatter the
/// products back into the same rows of `out`. Per-row results are
/// bit-equal to the all-rows GEMM — per-element k-ascending
/// accumulation does not depend on how many rows share the call — so
/// grouping rows by adapter never changes numerics. The all-rows case
/// skips the gather/scatter copies.
fn gemm_rows(
    x: &[f32],
    wmat: &[f32],
    out: &mut [f32],
    rows: &[usize],
    m: usize,
    k: usize,
    nout: usize,
) {
    if rows.len() == m {
        gemm_nn(x, wmat, out, m, k, nout, false);
        return;
    }
    if rows.is_empty() {
        return;
    }
    let g = rows.len();
    let mut xg = vec![0f32; g * k];
    for (gi, &ri) in rows.iter().enumerate() {
        xg[gi * k..(gi + 1) * k].copy_from_slice(&x[ri * k..(ri + 1) * k]);
    }
    let mut og = vec![0f32; g * nout];
    gemm_nn(&xg, wmat, &mut og, g, k, nout, false);
    for (gi, &ri) in rows.iter().enumerate() {
        out[ri * nout..(ri + 1) * nout].copy_from_slice(&og[gi * nout..(gi + 1) * nout]);
    }
}

/// Fused decode step: advance `m` sequences by ONE position each with
/// one `[m, h]` GEMM per layer weight instead of `m` row-sized GEMVs —
/// the layer weights (the dominant memory traffic of a decode step)
/// are read once per step, not once per slot.
///
/// Heterogeneous adapters batch naturally: every row shares the frozen
/// base `W0` GEMMs (wk/wo/w1/w2 unconditionally; wq/wv for factored
/// rows, which then add their private rank-r `scale·B(Aᵀx)` update per
/// row), while dense-exec rows group by reconstruction identity (the
/// shared `Arc` from the `ReconCache`) and run one grouped GEMM per
/// distinct adapter. Attention stays per-slot over that slot's page
/// list. Returns the `[m, h]` final-layer-norm hidden rows in entry
/// order.
///
/// Parity contract: every op is per-row (LN, GELU, residuals) or a
/// GEMM whose per-element k-ascending accumulation is row-count
/// invariant, and the attention expressions are shared with
/// [`incr_forward_slot`] verbatim — so row `i` here is bit-identical,
/// per kernel tier, to stepping entry `i` alone. The fused step can
/// therefore never change a token stream.
///
/// When `UNI_LORA_PROFILE=1`, scoped [`crate::obs::profile`] timers
/// attribute each region (base GEMM, factored apply, dense grouped
/// GEMV, attention) — clock reads only, never tensor reads, so the
/// parity contract holds with profiling on.
pub fn incr_forward_batch(
    cfg: &ModelCfg,
    base: &BaseMap,
    arena: &mut KvArena,
    entries: &mut [BatchEntry],
) -> Result<Vec<f32>> {
    let (h, f, nh) = (cfg.hidden, cfg.ffn, cfg.heads);
    let hd = cfg.head_dim();
    let scale = 1.0 / (hd as f32).sqrt();
    let kops = dispatch::ops();
    let m = entries.len();
    ensure!(m > 0, "incr_forward_batch: no entries");
    ensure!(
        arena.layers() == cfg.layers,
        "kv arena has {} layers, want {}",
        arena.layers(),
        cfg.layers
    );
    for e in entries.iter() {
        ensure!(e.kv.cap <= cfg.seq, "kv reservation {} exceeds window {}", e.kv.cap, cfg.seq);
        ensure!(
            e.kv.len + 1 <= e.kv.cap,
            "kv cache overflow: {} processed + 1 new > window {}",
            e.kv.len,
            e.kv.cap
        );
        let tok = e.tok;
        ensure!(
            tok >= 0 && (tok as usize) < cfg.vocab,
            "token id {tok} out of range for vocab {}",
            cfg.vocab
        );
        match e.exec {
            AdapterExec::Dense(aw) => {
                ensure!(aw.wq.len() == cfg.layers, "adapted weights have {} layers", aw.wq.len())
            }
            AdapterExec::Factored(fw) => {
                ensure!(fw.q.len() == cfg.layers, "factored weights have {} layers", fw.q.len())
            }
        }
    }
    // materialize pages up front so the layer loop never allocates
    for e in entries.iter_mut() {
        let upto = e.kv.len + 1;
        arena.grow(e.kv, upto)?;
    }

    // row partition, built once: factored rows all share the base
    // wq/wv GEMM; dense rows group by reconstruction identity
    let mut factored_rows: Vec<usize> = Vec::new();
    let mut dense_groups: Vec<(*const AdaptedWeights, Vec<usize>)> = Vec::new();
    for (ri, e) in entries.iter().enumerate() {
        match e.exec {
            AdapterExec::Factored(_) => factored_rows.push(ri),
            AdapterExec::Dense(aw) => {
                let key = Arc::as_ptr(aw);
                match dense_groups.iter_mut().find(|(k, _)| *k == key) {
                    Some((_, rows)) => rows.push(ri),
                    None => dense_groups.push((key, vec![ri])),
                }
            }
        }
    }

    // embeddings: each row at its own absolute position
    let fixed = *base.fixed();
    let tok_emb = base.at(fixed.tok_emb);
    let pos_emb = base.at(fixed.pos_emb);
    let mut x = vec![0f32; m * h];
    for (i, e) in entries.iter().enumerate() {
        let (tok, pos) = (e.tok as usize, e.kv.len);
        let te = &tok_emb[tok * h..(tok + 1) * h];
        let pe = &pos_emb[pos * h..(pos + 1) * h];
        let xr = &mut x[i * h..(i + 1) * h];
        for j in 0..h {
            xr[j] = te[j] + pe[j];
        }
    }

    for l in 0..cfg.layers {
        let segs = *base.layer(l);
        let (x2, _) = layer_norm(&x, base.at(segs.ln1_g), base.at(segs.ln1_b), m, h);
        // adapted q projection: factored rows share the base GEMM and
        // add their rank-r update per row (n = 1 keeps the exact
        // per-slot float order); dense rows run one GEMM per group
        let mut q = vec![0f32; m * h];
        {
            let _prof = profile::stage(profile::STAGE_BASE_GEMM);
            gemm_rows(&x2, base.at(segs.wq), &mut q, &factored_rows, m, h, h);
        }
        {
            let _prof = profile::stage(profile::STAGE_FACTORED_APPLY);
            for &ri in &factored_rows {
                if let AdapterExec::Factored(fw) = entries[ri].exec {
                    apply_factored(
                        &x2[ri * h..(ri + 1) * h],
                        &fw.q[l],
                        fw.scale,
                        fw.rank,
                        &mut q[ri * h..(ri + 1) * h],
                        1,
                        h,
                    );
                }
            }
        }
        {
            let _prof = profile::stage(profile::STAGE_DENSE_GEMV);
            for (_, rows) in &dense_groups {
                if let AdapterExec::Dense(aw) = entries[rows[0]].exec {
                    gemm_rows(&x2, &aw.wq[l], &mut q, rows, m, h, h);
                }
            }
        }
        // keys: every row shares the frozen base wk
        let mut knew = vec![0f32; m * h];
        {
            let _prof = profile::stage(profile::STAGE_BASE_GEMM);
            gemm_nn(&x2, base.at(segs.wk), &mut knew, m, h, h, false);
        }
        // values: same adapter split as q
        let mut vnew = vec![0f32; m * h];
        {
            let _prof = profile::stage(profile::STAGE_BASE_GEMM);
            gemm_rows(&x2, base.at(segs.wv), &mut vnew, &factored_rows, m, h, h);
        }
        {
            let _prof = profile::stage(profile::STAGE_FACTORED_APPLY);
            for &ri in &factored_rows {
                if let AdapterExec::Factored(fw) = entries[ri].exec {
                    apply_factored(
                        &x2[ri * h..(ri + 1) * h],
                        &fw.v[l],
                        fw.scale,
                        fw.rank,
                        &mut vnew[ri * h..(ri + 1) * h],
                        1,
                        h,
                    );
                }
            }
        }
        {
            let _prof = profile::stage(profile::STAGE_DENSE_GEMV);
            for (_, rows) in &dense_groups {
                if let AdapterExec::Dense(aw) = entries[rows[0]].exec {
                    gemm_rows(&x2, &aw.wv[l], &mut vnew, rows, m, h, h);
                }
            }
        }
        // new keys/values land in each slot's arena pages
        for (i, e) in entries.iter().enumerate() {
            arena.k_row_mut(e.kv, l, e.kv.len).copy_from_slice(&knew[i * h..(i + 1) * h]);
            arena.v_row_mut(e.kv, l, e.kv.len).copy_from_slice(&vnew[i * h..(i + 1) * h]);
        }
        // attention stays per-slot: each row attends over its own
        // slot's cached positions — the same expression order as
        // `incr_forward_slot` (running max, exp pass, accumulate)
        let mut att_out = vec![0f32; m * h];
        let max_pos = entries.iter().map(|e| e.kv.len + 1).max().unwrap_or(1);
        let mut sc = vec![0f32; max_pos];
        {
            let _prof = profile::stage(profile::STAGE_ATTENTION);
            for head in 0..nh {
                for (i, e) in entries.iter().enumerate() {
                    let p = e.kv.len;
                    let qo = i * h + head * hd;
                    let ko = head * hd;
                    let mut mx = f32::NEG_INFINITY;
                    for j in 0..=p {
                        let krow = arena.k_row(e.kv, l, j);
                        let mut dot = 0f32;
                        for dd in 0..hd {
                            dot += q[qo + dd] * krow[ko + dd];
                        }
                        sc[j] = dot * scale;
                        if sc[j] > mx {
                            mx = sc[j];
                        }
                    }
                    let mut denom = 0f32;
                    for j in 0..=p {
                        sc[j] = (sc[j] - mx).exp();
                        denom += sc[j];
                    }
                    let orow = &mut att_out[qo..qo + hd];
                    for j in 0..=p {
                        let wj = sc[j] / denom;
                        let vrow = arena.v_row(e.kv, l, j);
                        for dd in 0..hd {
                            orow[dd] += wj * vrow[ko + dd];
                        }
                    }
                }
            }
        }
        let mut x_mid = vec![0f32; m * h];
        {
            let _prof = profile::stage(profile::STAGE_BASE_GEMM);
            gemm_nn(&att_out, base.at(segs.wo), &mut x_mid, m, h, h, false);
        }
        for (xm, xi) in x_mid.iter_mut().zip(&x) {
            *xm += xi;
        }
        let (x3, _) = layer_norm(&x_mid, base.at(segs.ln2_g), base.at(segs.ln2_b), m, h);
        let mut u = vec![0f32; m * f];
        {
            let _prof = profile::stage(profile::STAGE_BASE_GEMM);
            gemm_nn(&x3, base.at(segs.w1), &mut u, m, h, f, false);
        }
        let mut gelu_v = vec![0f32; m * f];
        (kops.gelu_map)(&mut gelu_v, &u);
        let mut x_next = vec![0f32; m * h];
        {
            let _prof = profile::stage(profile::STAGE_BASE_GEMM);
            gemm_nn(&gelu_v, base.at(segs.w2), &mut x_next, m, f, h, false);
        }
        for (xn, xm) in x_next.iter_mut().zip(&x_mid) {
            *xn += xm;
        }
        x = x_next;
    }
    for e in entries.iter_mut() {
        e.kv.len += 1;
    }

    // final layer norm on every row (LN is per-row)
    let (hidden, _) = layer_norm(&x, base.at(fixed.lnf_g), base.at(fixed.lnf_b), m, h);
    Ok(hidden)
}

// ------------------------------------------------------------------
// backward

pub struct Gradients {
    /// Per adapted module, in module order (q0, v0, q1, v1, ...) —
    /// factor cotangents in the SAME geometry as the deltas themselves
    /// (`LowRank` da/db for factored methods, `Dense` d(DeltaW) for
    /// FourierFT), scale included. This is exactly the shape
    /// `projection::op::ProjectionOp::vjp` pulls back onto theta.
    pub modules: Vec<ModuleDelta>,
    /// gradient of the flat frozen-backbone vector, when requested
    pub w0: Option<Vec<f32>>,
}

fn module_grad(
    cfg: &ModelCfg,
    x2: &[f32],
    dy: &[f32],
    delta: &ModuleDelta,
    bt: usize,
) -> ModuleDelta {
    let (h, r, sc) = (cfg.hidden, cfg.rank, cfg.scale);
    match delta {
        ModuleDelta::LowRank { a, b } => {
            // da = sc * x2^T @ (dy @ b^T)    [h, r]
            let mut t1 = vec![0f32; bt * r];
            gemm_nt(dy, b, &mut t1, bt, r, h, false);
            let mut da = vec![0f32; h * r];
            gemm_tn(x2, &t1, &mut da, bt, h, r, false);
            // db = sc * (x2 @ a)^T @ dy      [r, h]
            let mut t2 = vec![0f32; bt * r];
            gemm_nn(x2, a, &mut t2, bt, h, r, false);
            let mut db = vec![0f32; r * h];
            gemm_tn(&t2, dy, &mut db, bt, r, h, false);
            for g in da.iter_mut() {
                *g *= sc;
            }
            for g in db.iter_mut() {
                *g *= sc;
            }
            ModuleDelta::LowRank { a: da, b: db }
        }
        ModuleDelta::Dense(_) => {
            // forward adds sc * DeltaW onto W0: d(DeltaW) = sc * x2^T @ dy
            let mut ddw = vec![0f32; h * h];
            gemm_tn(x2, dy, &mut ddw, bt, h, h, false);
            for g in ddw.iter_mut() {
                *g *= sc;
            }
            ModuleDelta::Dense(ddw)
        }
    }
}

/// `dst += src` — the residual / gradient accumulate. Routed through
/// the lane-chunked `axpy8` with `a = 1.0`: `1.0 * x == x` exactly and
/// the update is element-wise, so this is bit-identical to the plain
/// add loop on every tier while vectorizing cleanly.
fn add_into(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    crate::kernels::simd::axpy8(dst, src, 1.0);
}

/// Backprop from `d_hidden` (gradient at the final layer-norm output)
/// down to the adapted modules (always) and the frozen backbone
/// (when `want_w0`).
pub fn backward(
    cfg: &ModelCfg,
    base: &BaseMap,
    deltas: &[ModuleDelta],
    tokens: &[i32],
    cache: &ForwardCache,
    d_hidden: &[f32],
    want_w0: bool,
) -> Result<Gradients> {
    let (b, t, h, f) = (cfg.batch, cfg.seq, cfg.hidden, cfg.ffn);
    let bt = b * t;
    let kops = dispatch::ops();
    ensure!(d_hidden.len() == bt * h, "d_hidden size mismatch");
    let mut w0g = if want_w0 { Some(vec![0f32; base.total()]) } else { None };
    let mut modules: Vec<Option<ModuleDelta>> = (0..cfg.n_modules()).map(|_| None).collect();

    let seg_add = |w0g: &mut Option<Vec<f32>>, name: &str, g: &[f32]| {
        if let Some(buf) = w0g {
            let (o, n) = base.offset(name);
            add_into(&mut buf[o..o + n], g);
        }
    };

    // final layer norm
    let (mut d, dg, db) = layer_norm_backward(d_hidden, base.seg("lnf_g"), &cache.lnf, bt, h);
    seg_add(&mut w0g, "lnf_g", &dg);
    seg_add(&mut w0g, "lnf_b", &db);

    for l in (0..cfg.layers).rev() {
        let lc = &cache.layers[l];

        // ---- FFN branch: x_out = x_mid + gelu(x3 @ w1) @ w2 ----
        let mut d_gelu = vec![0f32; bt * f];
        gemm_nt(&d, base.seg(&format!("w2{l}")), &mut d_gelu, bt, f, h, false);
        if let Some(buf) = &mut w0g {
            let (o, n) = base.offset(&format!("w2{l}"));
            gemm_tn(&lc.gelu, &d, &mut buf[o..o + n], bt, f, h, true);
        }
        let mut d_u = d_gelu;
        {
            let dst = SendPtr::new(&mut d_u);
            let src = &lc.u;
            parallel_chunks(bt * f, 4096, |s, e| {
                // SAFETY: chunks are disjoint
                let dd = unsafe { dst.slice(s, e - s) };
                (kops.gelu_grad_mul)(dd, &src[s..e]);
            });
        }
        let mut d_x3 = vec![0f32; bt * h];
        gemm_nt(&d_u, base.seg(&format!("w1{l}")), &mut d_x3, bt, h, f, false);
        if let Some(buf) = &mut w0g {
            let (o, n) = base.offset(&format!("w1{l}"));
            gemm_tn(&lc.x3, &d_u, &mut buf[o..o + n], bt, h, f, true);
        }
        let (d_ln2_in, dg2, db2) =
            layer_norm_backward(&d_x3, base.seg(&format!("ln2_g{l}")), &lc.ln2, bt, h);
        seg_add(&mut w0g, &format!("ln2_g{l}"), &dg2);
        seg_add(&mut w0g, &format!("ln2_b{l}"), &db2);
        // gradient at x_mid: residual + through LN2
        let mut d_mid = d;
        add_into(&mut d_mid, &d_ln2_in);

        // ---- attention branch: x_mid = x_in + att_out @ wo ----
        let mut d_attout = vec![0f32; bt * h];
        gemm_nt(&d_mid, base.seg(&format!("wo{l}")), &mut d_attout, bt, h, h, false);
        if let Some(buf) = &mut w0g {
            let (o, n) = base.offset(&format!("wo{l}"));
            gemm_tn(&lc.att_out, &d_mid, &mut buf[o..o + n], bt, h, h, true);
        }
        let (dq, dk, dv) = attention_backward(cfg, &d_attout, &lc.q, &lc.k, &lc.v, &lc.attn);

        // module factor grads (q = module 2l, v = module 2l+1)
        modules[2 * l] = Some(module_grad(cfg, &lc.x2, &dq, &deltas[2 * l], bt));
        modules[2 * l + 1] = Some(module_grad(cfg, &lc.x2, &dv, &deltas[2 * l + 1], bt));

        // gradient into x2 through the three projections
        let mut d_x2 = vec![0f32; bt * h];
        gemm_nt(&dq, &lc.weff_q, &mut d_x2, bt, h, h, false);
        gemm_nt(&dk, base.seg(&format!("wk{l}")), &mut d_x2, bt, h, h, true);
        gemm_nt(&dv, &lc.weff_v, &mut d_x2, bt, h, h, true);
        if let Some(buf) = &mut w0g {
            let (o, n) = base.offset(&format!("wq{l}"));
            gemm_tn(&lc.x2, &dq, &mut buf[o..o + n], bt, h, h, true);
            let (o, n) = base.offset(&format!("wk{l}"));
            gemm_tn(&lc.x2, &dk, &mut buf[o..o + n], bt, h, h, true);
            let (o, n) = base.offset(&format!("wv{l}"));
            gemm_tn(&lc.x2, &dv, &mut buf[o..o + n], bt, h, h, true);
        }
        let (d_ln1_in, dg1, db1) =
            layer_norm_backward(&d_x2, base.seg(&format!("ln1_g{l}")), &lc.ln1, bt, h);
        seg_add(&mut w0g, &format!("ln1_g{l}"), &dg1);
        seg_add(&mut w0g, &format!("ln1_b{l}"), &db1);

        // gradient at the layer input: residual + through LN1
        let mut d_in = d_mid;
        add_into(&mut d_in, &d_ln1_in);
        d = d_in;
    }

    // embeddings
    if let Some(buf) = &mut w0g {
        let (to, _) = base.offset("tok_emb");
        let (po, _) = base.offset("pos_emb");
        for row in 0..bt {
            let tok = tokens[row] as usize;
            let drow = &d[row * h..(row + 1) * h];
            let tdst = to + tok * h;
            let pdst = po + (row % t) * h;
            for j in 0..h {
                buf[tdst + j] += drow[j];
                buf[pdst + j] += drow[j];
            }
        }
    }

    Ok(Gradients {
        modules: modules.into_iter().map(|m| m.expect("all modules visited")).collect(),
        w0: w0g,
    })
}

// ------------------------------------------------------------------
// heads and losses (mirror model.cls_output / lm_logits / losses)

pub struct ClsHead {
    pub pooled: Vec<f32>, // [B, h]
    pub logits: Vec<f32>, // [B, C]
    mask: Vec<f32>,       // [B, T]
    denom: Vec<f32>,      // [B]
}

/// Mean-pooled classification output (mirror of model.cls_output).
pub fn cls_head_forward(cfg: &ModelCfg, hidden: &[f32], head: &[f32], attn_len: &[i32]) -> ClsHead {
    let (b, t, h) = (cfg.batch, cfg.seq, cfg.hidden);
    let c = cfg.n_classes.max(1);
    let mut mask = vec![0f32; b * t];
    let mut denom = vec![0f32; b];
    for bi in 0..b {
        let n = (attn_len[bi].max(0) as usize).min(t);
        for pos in 0..n {
            mask[bi * t + pos] = 1.0;
        }
        denom[bi] = (n as f32).max(1.0);
    }
    let mut pooled = vec![0f32; b * h];
    for bi in 0..b {
        for pos in 0..t {
            if mask[bi * t + pos] == 0.0 {
                continue;
            }
            let hrow = &hidden[(bi * t + pos) * h..(bi * t + pos + 1) * h];
            let prow = &mut pooled[bi * h..(bi + 1) * h];
            for j in 0..h {
                prow[j] += hrow[j];
            }
        }
        for j in 0..h {
            pooled[bi * h + j] /= denom[bi];
        }
    }
    let wh = &head[..h * c];
    let bh = &head[h * c..];
    let mut logits = vec![0f32; b * c];
    gemm_nn(&pooled, wh, &mut logits, b, h, c, false);
    for bi in 0..b {
        for j in 0..c {
            logits[bi * c + j] += bh[j];
        }
    }
    ClsHead { pooled, logits, mask, denom }
}

/// Returns (d_head, d_hidden) given d_logits.
pub fn cls_head_backward(
    cfg: &ModelCfg,
    ch: &ClsHead,
    head: &[f32],
    d_logits: &[f32],
) -> (Vec<f32>, Vec<f32>) {
    let (b, t, h) = (cfg.batch, cfg.seq, cfg.hidden);
    let c = cfg.n_classes.max(1);
    let wh = &head[..h * c];
    let mut d_head = vec![0f32; h * c + c];
    gemm_tn(&ch.pooled, d_logits, &mut d_head[..h * c], b, h, c, false);
    for bi in 0..b {
        for j in 0..c {
            d_head[h * c + j] += d_logits[bi * c + j];
        }
    }
    let mut d_pooled = vec![0f32; b * h];
    gemm_nt(d_logits, wh, &mut d_pooled, b, h, c, false);
    let mut d_hidden = vec![0f32; b * t * h];
    for bi in 0..b {
        let prow = &d_pooled[bi * h..(bi + 1) * h];
        for pos in 0..t {
            if ch.mask[bi * t + pos] == 0.0 {
                continue;
            }
            let drow = &mut d_hidden[(bi * t + pos) * h..(bi * t + pos + 1) * h];
            for j in 0..h {
                drow[j] = prow[j] / ch.denom[bi];
            }
        }
    }
    (d_head, d_hidden)
}

/// Mean cross-entropy over rows; returns (loss, d_logits).
pub fn softmax_xent_mean(
    logits: &[f32],
    labels: &[i32],
    rows: usize,
    c: usize,
) -> Result<(f32, Vec<f32>)> {
    let kops = dispatch::ops();
    let mut d = vec![0f32; rows * c];
    let mut loss = 0f64;
    for i in 0..rows {
        let row = &logits[i * c..(i + 1) * c];
        let lab = labels[i];
        ensure!(lab >= 0 && (lab as usize) < c, "label {lab} out of range for C = {c}");
        let mx = (kops.row_max)(row);
        let mut denom = 0f64;
        for &x in row {
            denom += ((x - mx) as f64).exp();
        }
        loss -= (row[lab as usize] - mx) as f64 - denom.ln();
        for j in 0..c {
            let p = (((row[j] - mx) as f64).exp() / denom) as f32;
            let onehot = if j == lab as usize { 1.0 } else { 0.0 };
            d[i * c + j] = (p - onehot) / rows as f32;
        }
    }
    Ok(((loss / rows as f64) as f32, d))
}

/// Mean squared error for regression heads (C == 1).
pub fn mse_mean(logits: &[f32], targets: &[f32], rows: usize) -> (f32, Vec<f32>) {
    let mut d = vec![0f32; rows];
    let mut loss = 0f64;
    for i in 0..rows {
        let e = logits[i] - targets[i];
        loss += (e as f64) * (e as f64);
        d[i] = 2.0 * e / rows as f32;
    }
    ((loss / rows as f64) as f32, d)
}

/// Next-token logits [B*T, V] = hidden @ lm_head.
pub fn lm_head_forward(cfg: &ModelCfg, base: &BaseMap, hidden: &[f32]) -> Vec<f32> {
    let bt = cfg.batch * cfg.seq;
    let mut logits = vec![0f32; bt * cfg.vocab];
    gemm_nn(hidden, base.seg("lm_head"), &mut logits, bt, cfg.hidden, cfg.vocab, false);
    logits
}

/// Masked next-token CE (labels < 0 masked); returns (loss, d_logits).
/// The per-row softmax (the [B*T, V] hot loop of the LM paths) fans out
/// over the kernel pool; the final loss reduction is a sequential sum
/// in row order, so the result is thread-count invariant.
pub fn lm_xent_masked(
    logits: &[f32],
    labels: &[i32],
    rows: usize,
    vocab: usize,
) -> Result<(f32, Vec<f32>)> {
    ensure!(logits.len() == rows * vocab, "lm_xent: logits size mismatch");
    ensure!(labels.len() == rows, "lm_xent: labels size mismatch");
    // validate up front so the parallel sweep is infallible
    for &lab in labels {
        ensure!(lab < vocab as i32, "label {lab} out of range for vocab {vocab}");
    }
    let kops = dispatch::ops();
    let msum = labels.iter().filter(|&&l| l >= 0).count().max(1) as f64;
    let mut d = vec![0f32; rows * vocab];
    let mut row_loss = vec![0f64; rows];
    {
        let dptr = SendPtr::new(&mut d);
        let lptr = SendPtr::new(&mut row_loss);
        const GRAIN: usize = 16;
        let tasks = (rows + GRAIN - 1) / GRAIN;
        parallel_for_work(rows * vocab, tasks, |tsk| {
            let r0 = tsk * GRAIN;
            let r1 = (r0 + GRAIN).min(rows);
            for i in r0..r1 {
                let lab = labels[i];
                if lab < 0 {
                    continue;
                }
                let row = &logits[i * vocab..(i + 1) * vocab];
                // SAFETY: row i of `d`/`row_loss` belongs to this task only
                let drow = unsafe { dptr.slice(i * vocab, vocab) };
                let lrow = unsafe { lptr.slice(i, 1) };
                let mx = (kops.row_max)(row);
                let mut denom = 0f64;
                for &x in row {
                    denom += ((x - mx) as f64).exp();
                }
                lrow[0] = -((row[lab as usize] - mx) as f64 - denom.ln());
                for j in 0..vocab {
                    let p = (((row[j] - mx) as f64).exp() / denom) as f32;
                    let onehot = if j == lab as usize { 1.0 } else { 0.0 };
                    drow[j] = ((p - onehot) as f64 / msum) as f32;
                }
            }
        });
    }
    let loss: f64 = row_loss.iter().sum();
    Ok(((loss / msum) as f32, d))
}

/// One AdamW update over a flat parameter vector — mirror of optim.adamw
/// (beta1 = 0.9, beta2 = 0.999, eps = 1e-8, bias-corrected, decoupled wd).
pub fn adamw(p: &mut [f32], g: &[f32], m: &mut [f32], v: &mut [f32], step: i32, lr: f32, wd: f32) {
    let t = step as f32;
    let bc1 = 1.0 - 0.9f32.powf(t);
    let bc2 = 1.0 - 0.999f32.powf(t);
    for i in 0..p.len() {
        m[i] = 0.9 * m[i] + 0.1 * g[i];
        v[i] = 0.999 * v[i] + 0.001 * g[i] * g[i];
        let mhat = m[i] / bc1;
        let vhat = v[i] / bc2;
        p[i] -= lr * (mhat / (vhat.sqrt() + 1e-8) + wd * p[i]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projection::reconstruct::reconstruct_with_statics;
    use crate::projection::statics::{gen_statics, init_array, init_theta, Static};
    use crate::rng;

    fn tiny_cfg() -> ModelCfg {
        ModelCfg {
            name: "tiny".into(),
            vocab: 32,
            seq: 4,
            hidden: 8,
            layers: 2,
            heads: 2,
            ffn: 16,
            method: "uni".into(),
            rank: 2,
            d: 8,
            scale: 2.0,
            n_classes: 2,
            batch: 2,
            vb_b: 8,
            vb_k: 2,
            vb_bank: 4,
            n_coef: 4,
        }
    }

    fn init_w0(cfg: &ModelCfg, seed: u64) -> Vec<f32> {
        let mut w0 = Vec::new();
        for (i, s) in spec::base_segments(cfg).iter().enumerate() {
            let sd = rng::child_seed(seed, rng::STREAM_BASE_INIT + 1000 * i as u64);
            w0.extend(init_array(&s.init, s.numel(), sd).unwrap());
        }
        w0
    }

    fn tokens_for(cfg: &ModelCfg, seed: u64) -> Vec<i32> {
        rng::indices(seed, cfg.batch * cfg.seq, cfg.vocab)
    }

    #[test]
    fn layer_norm_backward_matches_finite_difference() {
        let (n, h) = (2, 6);
        let x = rng::normals(3, n * h);
        let g: Vec<f32> = rng::normals(4, h).iter().map(|v| 1.0 + 0.1 * v).collect();
        let b = rng::normals(5, h);
        let dy = rng::normals(6, n * h);
        let loss = |x: &[f32]| -> f64 {
            let (y, _) = layer_norm(x, &g, &b, n, h);
            y.iter().zip(&dy).map(|(a, c)| (a * c) as f64).sum()
        };
        let (_, cache) = layer_norm(&x, &g, &b, n, h);
        let (dx, _, _) = layer_norm_backward(&dy, &g, &cache, n, h);
        let eps = 1e-3f32;
        for i in [0usize, 3, 7, 11] {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let num = ((loss(&xp) - loss(&xm)) / (2.0 * eps as f64)) as f32;
            assert!(
                (num - dx[i]).abs() < 2e-2 * dx[i].abs().max(0.1),
                "dx[{i}]: fd {num} vs analytic {}",
                dx[i]
            );
        }
    }

    #[test]
    fn attention_backward_matches_finite_difference() {
        let cfg = tiny_cfg();
        let bt = cfg.batch * cfg.seq;
        let h = cfg.hidden;
        let q = rng::normals(11, bt * h);
        let k = rng::normals(12, bt * h);
        let v = rng::normals(13, bt * h);
        let dy = rng::normals(14, bt * h);
        let loss = |q: &[f32], k: &[f32], v: &[f32]| -> f64 {
            let (o, _) = attention(&cfg, q, k, v);
            o.iter().zip(&dy).map(|(a, c)| (a * c) as f64).sum()
        };
        let (_, cache) = attention(&cfg, &q, &k, &v);
        let (dq, dk, dv) = attention_backward(&cfg, &dy, &q, &k, &v, &cache);
        let eps = 1e-3f32;
        for i in [0usize, 5, 17, 40, 63] {
            for (buf, grad, which) in
                [(&q, &dq, "q"), (&k, &dk, "k"), (&v, &dv, "v")]
            {
                let mut p = (*buf).clone();
                p[i] += eps;
                let mut m = (*buf).clone();
                m[i] -= eps;
                let (lp, lm) = match which {
                    "q" => (loss(&p, &k, &v), loss(&m, &k, &v)),
                    "k" => (loss(&q, &p, &v), loss(&q, &m, &v)),
                    _ => (loss(&q, &k, &p), loss(&q, &k, &m)),
                };
                let num = ((lp - lm) / (2.0 * eps as f64)) as f32;
                assert!(
                    (num - grad[i]).abs() < 3e-2 * grad[i].abs().max(0.1),
                    "d{which}[{i}]: fd {num} vs analytic {}",
                    grad[i]
                );
            }
        }
    }

    /// End-to-end gradient check: d loss / d theta through the full
    /// transformer + uni projection, against central differences.
    #[test]
    fn theta_gradient_matches_finite_difference() {
        let cfg = tiny_cfg();
        let seed = 42;
        let w0 = init_w0(&cfg, seed);
        let base = BaseMap::new(&cfg, &w0).unwrap();
        let stats = gen_statics(&cfg, seed).unwrap();
        // non-zero theta so the delta path is active
        let theta: Vec<f32> = rng::normals(9, cfg.d).iter().map(|v| 0.1 * v).collect();
        let head: Vec<f32> = rng::normals(10, spec::head_param_count(&cfg))
            .iter()
            .map(|v| 0.1 * v)
            .collect();
        let tokens = tokens_for(&cfg, 7);
        let attn_len = vec![cfg.seq as i32; cfg.batch];
        let labels: Vec<i32> = (0..cfg.batch as i32).map(|i| i % 2).collect();
        let c = cfg.n_classes;

        let loss_of = |th: &[f32]| -> f32 {
            let deltas = reconstruct_with_statics(&cfg, &stats, th).unwrap();
            let fc = forward(&cfg, &base, &deltas, &tokens).unwrap();
            let ch = cls_head_forward(&cfg, &fc.hidden, &head, &attn_len);
            softmax_xent_mean(&ch.logits, &labels, cfg.batch, c).unwrap().0
        };

        // analytic gradient
        let deltas = reconstruct_with_statics(&cfg, &stats, &theta).unwrap();
        let fc = forward(&cfg, &base, &deltas, &tokens).unwrap();
        let ch = cls_head_forward(&cfg, &fc.hidden, &head, &attn_len);
        let (_, d_logits) = softmax_xent_mean(&ch.logits, &labels, cfg.batch, c).unwrap();
        let (_, d_hidden) = cls_head_backward(&cfg, &ch, &head, &d_logits);
        let grads = backward(&cfg, &base, &deltas, &tokens, &fc, &d_hidden, false).unwrap();
        // pull the factor cotangents back onto theta through the
        // registry op — the exact path the native train kinds use
        let g_theta = crate::projection::op::resolve(&cfg.method)
            .unwrap()
            .vjp(&cfg, &stats, &theta, &grads.modules)
            .unwrap();

        let eps = 3e-3f32;
        for j in 0..cfg.d {
            let mut tp = theta.clone();
            tp[j] += eps;
            let mut tm = theta.clone();
            tm[j] -= eps;
            let num = (loss_of(&tp) - loss_of(&tm)) / (2.0 * eps);
            assert!(
                (num - g_theta[j]).abs() < 5e-2 * g_theta[j].abs().max(0.02),
                "g_theta[{j}]: fd {num} vs analytic {}",
                g_theta[j]
            );
        }
    }

    /// Same end-to-end check through a DENSE delta (FourierFT): the
    /// d(DeltaW) = sc * x2^T @ dy path in module_grad, pulled back
    /// through the spectral vjp.
    #[test]
    fn fourierft_theta_gradient_matches_finite_difference() {
        let cfg = {
            let mut c = tiny_cfg();
            c.method = "fourierft".into();
            c
        };
        let seed = 17;
        let w0 = init_w0(&cfg, seed);
        let base = BaseMap::new(&cfg, &w0).unwrap();
        let stats = gen_statics(&cfg, seed).unwrap();
        let d = crate::projection::statics::d_effective(&cfg);
        let theta: Vec<f32> = rng::normals(19, d).iter().map(|v| 0.1 * v).collect();
        let head: Vec<f32> = rng::normals(20, spec::head_param_count(&cfg))
            .iter()
            .map(|v| 0.1 * v)
            .collect();
        let tokens = tokens_for(&cfg, 21);
        let attn_len = vec![cfg.seq as i32; cfg.batch];
        let labels: Vec<i32> = (0..cfg.batch as i32).map(|i| i % 2).collect();
        let c = cfg.n_classes;

        let loss_of = |th: &[f32]| -> f32 {
            let deltas = reconstruct_with_statics(&cfg, &stats, th).unwrap();
            let fc = forward(&cfg, &base, &deltas, &tokens).unwrap();
            let ch = cls_head_forward(&cfg, &fc.hidden, &head, &attn_len);
            softmax_xent_mean(&ch.logits, &labels, cfg.batch, c).unwrap().0
        };

        let deltas = reconstruct_with_statics(&cfg, &stats, &theta).unwrap();
        assert!(matches!(deltas[0], ModuleDelta::Dense(_)));
        let fc = forward(&cfg, &base, &deltas, &tokens).unwrap();
        let ch = cls_head_forward(&cfg, &fc.hidden, &head, &attn_len);
        let (_, d_logits) = softmax_xent_mean(&ch.logits, &labels, cfg.batch, c).unwrap();
        let (_, d_hidden) = cls_head_backward(&cfg, &ch, &head, &d_logits);
        let grads = backward(&cfg, &base, &deltas, &tokens, &fc, &d_hidden, false).unwrap();
        assert!(grads.modules.iter().all(|g| matches!(g, ModuleDelta::Dense(_))));
        let g_theta = crate::projection::op::resolve(&cfg.method)
            .unwrap()
            .vjp(&cfg, &stats, &theta, &grads.modules)
            .unwrap();

        let eps = 3e-3f32;
        for j in 0..d {
            let mut tp = theta.clone();
            tp[j] += eps;
            let mut tm = theta.clone();
            tm[j] -= eps;
            let num = (loss_of(&tp) - loss_of(&tm)) / (2.0 * eps);
            assert!(
                (num - g_theta[j]).abs() < 5e-2 * g_theta[j].abs().max(0.02),
                "g_theta[{j}]: fd {num} vs analytic {}",
                g_theta[j]
            );
        }
    }

    /// Head gradient check through pooling.
    #[test]
    fn head_gradient_matches_finite_difference() {
        let cfg = tiny_cfg();
        let w0 = init_w0(&cfg, 1);
        let base = BaseMap::new(&cfg, &w0).unwrap();
        let theta = init_theta(&cfg, 1).unwrap();
        let stats = gen_statics(&cfg, 1).unwrap();
        let deltas = reconstruct_with_statics(&cfg, &stats, &theta).unwrap();
        let tokens = tokens_for(&cfg, 3);
        let attn_len = vec![3i32; cfg.batch]; // partial mask exercised
        let labels = vec![1i32, 0];
        let head: Vec<f32> = rng::normals(8, spec::head_param_count(&cfg))
            .iter()
            .map(|v| 0.1 * v)
            .collect();
        let fc = forward(&cfg, &base, &deltas, &tokens).unwrap();

        let loss_of = |hd: &[f32]| -> f32 {
            let ch = cls_head_forward(&cfg, &fc.hidden, hd, &attn_len);
            softmax_xent_mean(&ch.logits, &labels, cfg.batch, cfg.n_classes).unwrap().0
        };
        let ch = cls_head_forward(&cfg, &fc.hidden, &head, &attn_len);
        let (_, d_logits) =
            softmax_xent_mean(&ch.logits, &labels, cfg.batch, cfg.n_classes).unwrap();
        let (d_head, _) = cls_head_backward(&cfg, &ch, &head, &d_logits);
        let eps = 1e-3f32;
        for j in 0..head.len() {
            let mut hp = head.clone();
            hp[j] += eps;
            let mut hm = head.clone();
            hm[j] -= eps;
            let num = (loss_of(&hp) - loss_of(&hm)) / (2.0 * eps);
            assert!(
                (num - d_head[j]).abs() < 5e-2 * d_head[j].abs().max(0.02),
                "d_head[{j}]: fd {num} vs analytic {}",
                d_head[j]
            );
        }
    }

    /// Backbone (w0) gradient spot-check through the LM loss — the
    /// pretrain path (embeddings, all matrices, layer norms, lm_head).
    #[test]
    fn w0_gradient_matches_finite_difference() {
        let cfg = {
            let mut c = tiny_cfg();
            c.method = "none".into();
            c.n_classes = 0;
            c
        };
        let w0 = init_w0(&cfg, 5);
        let tokens = tokens_for(&cfg, 6);
        let mut labels = tokens.clone();
        labels.rotate_left(1);
        for i in 0..cfg.batch {
            labels[(i + 1) * cfg.seq - 1] = -1; // mask final position
        }
        let deltas: Vec<ModuleDelta> = (0..cfg.n_modules())
            .map(|_| ModuleDelta::LowRank {
                a: vec![0.0; cfg.hidden * cfg.rank],
                b: vec![0.0; cfg.rank * cfg.hidden],
            })
            .collect();
        let bt = cfg.batch * cfg.seq;

        let loss_of = |w: &[f32]| -> f32 {
            let base = BaseMap::new(&cfg, w).unwrap();
            let fc = forward(&cfg, &base, &deltas, &tokens).unwrap();
            let logits = lm_head_forward(&cfg, &base, &fc.hidden);
            lm_xent_masked(&logits, &labels, bt, cfg.vocab).unwrap().0
        };

        let base = BaseMap::new(&cfg, &w0).unwrap();
        let fc = forward(&cfg, &base, &deltas, &tokens).unwrap();
        let logits = lm_head_forward(&cfg, &base, &fc.hidden);
        let (_, d_logits) = lm_xent_masked(&logits, &labels, bt, cfg.vocab).unwrap();
        let mut d_hidden = vec![0f32; bt * cfg.hidden];
        gemm_nt(&d_logits, base.seg("lm_head"), &mut d_hidden, bt, cfg.hidden, cfg.vocab, false);
        let grads = backward(&cfg, &base, &deltas, &tokens, &fc, &d_hidden, true).unwrap();
        let mut gw0 = grads.w0.unwrap();
        // lm_head gradient is accumulated outside backward()
        let (o, n) = base.offset("lm_head");
        gemm_tn(&fc.hidden, &d_logits, &mut gw0[o..o + n], bt, cfg.hidden, cfg.vocab, true);

        let eps = 1e-2f32;
        let mut probe = Vec::new();
        for name in ["tok_emb", "pos_emb", "wq0", "wk1", "wo0", "ln1_g0", "ln2_b1",
                     "w10", "w21", "lnf_g", "lm_head"] {
            let (o, nseg) = base.offset(name);
            probe.push(o + nseg / 2);
            probe.push(o + nseg - 1);
        }
        // tok_emb row actually used by the batch
        probe.push(base.offset("tok_emb").0 + tokens[0] as usize * cfg.hidden);
        for &j in &probe {
            let mut wp = w0.clone();
            wp[j] += eps;
            let mut wm = w0.clone();
            wm[j] -= eps;
            let num = (loss_of(&wp) - loss_of(&wm)) / (2.0 * eps);
            assert!(
                (num - gw0[j]).abs() < 6e-2 * gw0[j].abs().max(0.02),
                "gw0[{j}]: fd {num} vs analytic {}",
                gw0[j]
            );
        }
    }

    #[test]
    fn adamw_matches_python_semantics() {
        // one step from zero state: mhat = g, vhat = g^2 -> update
        // ~= lr * sign(g) (+ wd * p)
        let mut p = vec![1.0f32, -2.0];
        let g = vec![0.5f32, -0.25];
        let mut m = vec![0f32; 2];
        let mut v = vec![0f32; 2];
        adamw(&mut p, &g, &mut m, &mut v, 1, 0.1, 0.0);
        assert!((p[0] - (1.0 - 0.1)).abs() < 1e-3, "{}", p[0]);
        assert!((p[1] - (-2.0 + 0.1)).abs() < 1e-3, "{}", p[1]);
        // decoupled weight decay pulls toward zero
        let mut p2 = vec![1.0f32];
        let mut m2 = vec![0f32];
        let mut v2 = vec![0f32];
        adamw(&mut p2, &[0.0], &mut m2, &mut v2, 1, 0.1, 0.5);
        assert!(p2[0] < 1.0 && p2[0] > 0.9, "{}", p2[0]);
    }

    /// Incremental (KV-cache) forward == batch forward at the same
    /// positions: a prefill over a prefix followed by single-token
    /// steps must reproduce the `[B, T]` forward's per-position hidden
    /// rows — bit-exact on the scalar tier, tolerance + lm-argmax
    /// agreement on whatever tier is active.
    #[test]
    fn incremental_forward_matches_full_forward() {
        let cfg = tiny_cfg();
        let w0 = init_w0(&cfg, 2);
        let base = BaseMap::new(&cfg, &w0).unwrap();
        let stats = gen_statics(&cfg, 2).unwrap();
        // nonzero theta so the adapted-weight path is active
        let theta: Vec<f32> = rng::normals(9, cfg.d).iter().map(|v| 0.1 * v).collect();
        let deltas = reconstruct_with_statics(&cfg, &stats, &theta).unwrap();
        let w = AdapterExec::Dense(Arc::new(adapted_weights(&cfg, &base, &deltas).unwrap()));
        let tokens = tokens_for(&cfg, 4);
        let fc = forward(&cfg, &base, &deltas, &tokens).unwrap();

        for row in 0..cfg.batch {
            let seq = &tokens[row * cfg.seq..(row + 1) * cfg.seq];
            let mut kv = KvCache::new(&cfg);
            // paged cache: nothing materialized before the prefill
            assert_eq!(kv.byte_size(), 0);
            // prefill the first two positions, then step one at a time
            let mut rows = vec![incr_forward(&cfg, &base, &w, &mut kv, &seq[..2]).unwrap()];
            assert!(kv.byte_size() > 0);
            for p in 2..cfg.seq {
                rows.push(incr_forward(&cfg, &base, &w, &mut kv, &seq[p..p + 1]).unwrap());
            }
            assert_eq!(kv.len(), cfg.seq);
            let full_logits = lm_head_forward(&cfg, &base, &fc.hidden);
            for (step, pos) in (1..cfg.seq).enumerate() {
                let o = (row * cfg.seq + pos) * cfg.hidden;
                let want = &fc.hidden[o..o + cfg.hidden];
                let got = &rows[step];
                if crate::kernels::dispatch::path() == "scalar" {
                    assert_eq!(got.as_slice(), want, "row {row} pos {pos}");
                } else {
                    for (g, wv) in got.iter().zip(want) {
                        assert!(
                            (g - wv).abs() <= 1e-4 * wv.abs().max(1.0),
                            "row {row} pos {pos}: {g} vs {wv}"
                        );
                    }
                }
                // the decision that matters: identical next-token argmax
                let fo = (row * cfg.seq + pos) * cfg.vocab;
                let incr_logits = lm_logits_row(&cfg, &base, got);
                assert_eq!(
                    crate::metrics::argmax(&incr_logits),
                    crate::metrics::argmax(&full_logits[fo..fo + cfg.vocab]),
                    "row {row} pos {pos}"
                );
            }
        }
        // cache overflow and bad tokens are rejected
        let mut kv = KvCache::new(&cfg);
        let too_long = vec![1i32; cfg.seq + 1];
        assert!(incr_forward(&cfg, &base, &w, &mut kv, &too_long).is_err());
        assert!(incr_forward(&cfg, &base, &w, &mut kv, &[]).is_err());
        assert!(incr_forward(&cfg, &base, &w, &mut kv, &[cfg.vocab as i32]).is_err());
    }

    /// The fused batched step is bit-identical, per kernel tier, to
    /// stepping each slot alone: a heterogeneous batch (two slots
    /// sharing one dense reconstruction `Arc`, a factored slot, and a
    /// second distinct dense slot) at staggered positions produces
    /// exactly the same hidden rows and logits as four per-slot steps.
    #[test]
    fn batched_step_matches_per_slot_bitwise() {
        let mut cfg = tiny_cfg();
        cfg.seq = 12;
        let w0 = init_w0(&cfg, 11);
        let base = BaseMap::new(&cfg, &w0).unwrap();
        let stats = gen_statics(&cfg, 11).unwrap();
        let th_a: Vec<f32> = rng::normals(21, cfg.d).iter().map(|v| 0.1 * v).collect();
        let th_b: Vec<f32> = rng::normals(22, cfg.d).iter().map(|v| 0.1 * v).collect();
        let da = reconstruct_with_statics(&cfg, &stats, &th_a).unwrap();
        let db = reconstruct_with_statics(&cfg, &stats, &th_b).unwrap();
        let dense_a = AdapterExec::Dense(Arc::new(adapted_weights(&cfg, &base, &da).unwrap()));
        let dense_b = AdapterExec::Dense(Arc::new(adapted_weights(&cfg, &base, &db).unwrap()));
        let factored =
            AdapterExec::Factored(FactoredWeights::from_deltas(&cfg, &da).expect("low-rank"));
        // slots 0 and 2 share ONE reconstruction Arc (one dense group);
        // slot 3 is a distinct dense group; slot 1 is factored
        let execs: [&AdapterExec; 4] = [&dense_a, &factored, &dense_a, &dense_b];

        let toks = rng::indices(33, 64, cfg.vocab);
        let mut arena_a = KvArena::new(&cfg, 64); // per-slot reference
        let mut arena_b = KvArena::new(&cfg, 64); // fused stepping
        let mut slots_a: Vec<KvSlot> = Vec::new();
        let mut slots_b: Vec<KvSlot> = Vec::new();
        // staggered prefills: prompt lengths 2..=5, so every batched
        // row attends over a different number of cached positions
        for i in 0..4 {
            let prompt = &toks[i * 8..i * 8 + 2 + i];
            let mut sa = arena_a.reserve(cfg.seq).unwrap();
            let mut sb = arena_b.reserve(cfg.seq).unwrap();
            let ra = incr_forward_slot(&cfg, &base, execs[i], &mut arena_a, &mut sa, prompt);
            let rb = incr_forward_slot(&cfg, &base, execs[i], &mut arena_b, &mut sb, prompt);
            assert_eq!(ra.unwrap(), rb.unwrap(), "prefill {i}");
            slots_a.push(sa);
            slots_b.push(sb);
        }
        let h = cfg.hidden;
        for step in 0..4 {
            let feed: Vec<i32> = (0..4).map(|i| toks[32 + step * 4 + i]).collect();
            let mut want_rows = Vec::new();
            for i in 0..4 {
                want_rows.push(
                    incr_forward_slot(
                        &cfg,
                        &base,
                        execs[i],
                        &mut arena_a,
                        &mut slots_a[i],
                        &[feed[i]],
                    )
                    .unwrap(),
                );
            }
            let mut entries: Vec<BatchEntry> = slots_b
                .iter_mut()
                .enumerate()
                .map(|(i, kv)| BatchEntry { exec: execs[i], kv, tok: feed[i] })
                .collect();
            let got = incr_forward_batch(&cfg, &base, &mut arena_b, &mut entries).unwrap();
            for i in 0..4 {
                assert_eq!(
                    &got[i * h..(i + 1) * h],
                    want_rows[i].as_slice(),
                    "step {step} row {i}"
                );
            }
            // batched logits are bit-equal to per-row logits
            let lg = lm_logits_batch(&cfg, &base, &got, 4);
            for i in 0..4 {
                let one = lm_logits_row(&cfg, &base, &want_rows[i]);
                assert_eq!(
                    &lg[i * cfg.vocab..(i + 1) * cfg.vocab],
                    one.as_slice(),
                    "step {step} row {i}"
                );
            }
        }
        for i in 0..4 {
            assert_eq!(slots_a[i].len, slots_b[i].len);
            arena_a.release(&mut slots_a[i]);
            arena_b.release(&mut slots_b[i]);
        }
        assert_eq!((arena_a.used_pages(), arena_b.used_pages()), (0, 0));
    }

    /// The factored execution mode (`y += scale*B(A x)` on top of the
    /// frozen W0 projection) computes the same adapted forward as the
    /// densified mode up to float re-association: hidden rows agree to
    /// tolerance and the next-token argmax is identical.
    #[test]
    fn factored_incremental_forward_matches_dense() {
        let cfg = tiny_cfg();
        let w0 = init_w0(&cfg, 5);
        let base = BaseMap::new(&cfg, &w0).unwrap();
        let stats = gen_statics(&cfg, 5).unwrap();
        let theta: Vec<f32> = rng::normals(17, cfg.d).iter().map(|v| 0.1 * v).collect();
        let deltas = reconstruct_with_statics(&cfg, &stats, &theta).unwrap();
        let dense = AdapterExec::Dense(Arc::new(adapted_weights(&cfg, &base, &deltas).unwrap()));
        let fw = FactoredWeights::from_deltas(&cfg, &deltas).expect("uni deltas are low-rank");
        // factored residency really is the rank-r factors, not h^2
        assert_eq!(
            fw.byte_size(),
            4 * cfg.layers * cfg.hidden * cfg.rank * std::mem::size_of::<f32>()
        );
        let factored = AdapterExec::Factored(fw);
        assert!(dense.is_dense() && !factored.is_dense());
        assert_eq!(dense.byte_size(), 0);

        let tokens = tokens_for(&cfg, 6);
        let seq = &tokens[..cfg.seq];
        let mut kv_d = KvCache::new(&cfg);
        let mut kv_f = KvCache::new(&cfg);
        let mut rows_d = vec![incr_forward(&cfg, &base, &dense, &mut kv_d, &seq[..2]).unwrap()];
        let mut rows_f = vec![incr_forward(&cfg, &base, &factored, &mut kv_f, &seq[..2]).unwrap()];
        for p in 2..cfg.seq {
            rows_d.push(incr_forward(&cfg, &base, &dense, &mut kv_d, &seq[p..p + 1]).unwrap());
            rows_f.push(incr_forward(&cfg, &base, &factored, &mut kv_f, &seq[p..p + 1]).unwrap());
        }
        for (step, (got, want)) in rows_f.iter().zip(&rows_d).enumerate() {
            for (g, wv) in got.iter().zip(want) {
                assert!((g - wv).abs() <= 1e-4 * wv.abs().max(1.0), "step {step}: {g} vs {wv}");
            }
            let lf = lm_logits_row(&cfg, &base, got);
            let ld = lm_logits_row(&cfg, &base, want);
            assert_eq!(crate::metrics::argmax(&lf), crate::metrics::argmax(&ld), "step {step}");
        }

        // a Dense (FourierFT-style) module delta has no factored form
        let mut spectral = deltas.clone();
        spectral[0] = ModuleDelta::Dense(vec![0.0; cfg.hidden * cfg.hidden]);
        assert!(FactoredWeights::from_deltas(&cfg, &spectral).is_none());
    }

    #[test]
    fn forward_deterministic_and_finite() {
        let cfg = tiny_cfg();
        let w0 = init_w0(&cfg, 2);
        let base = BaseMap::new(&cfg, &w0).unwrap();
        let theta = init_theta(&cfg, 2).unwrap();
        let stats = gen_statics(&cfg, 2).unwrap();
        let deltas = reconstruct_with_statics(&cfg, &stats, &theta).unwrap();
        let tokens = tokens_for(&cfg, 4);
        let a = forward(&cfg, &base, &deltas, &tokens).unwrap();
        let b = forward(&cfg, &base, &deltas, &tokens).unwrap();
        assert_eq!(a.hidden, b.hidden);
        assert!(a.hidden.iter().all(|x| x.is_finite()));
        // out-of-range token rejected
        let mut bad = tokens.clone();
        bad[0] = cfg.vocab as i32;
        assert!(forward(&cfg, &base, &deltas, &bad).is_err());
    }

    #[test]
    fn statics_inputs_roundtrip_through_reconstruct() {
        // parity: deltas from gen_statics == deltas from Static structs
        // rebuilt the way the native backend does from artifact inputs
        let cfg = tiny_cfg();
        let theta = init_theta(&cfg, 3).unwrap();
        let stats = gen_statics(&cfg, 3).unwrap();
        let rebuilt: Vec<Static> = stats.to_vec();
        let a = reconstruct_with_statics(&cfg, &stats, &theta).unwrap();
        let b = reconstruct_with_statics(&cfg, &rebuilt, &theta).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_dense(cfg.hidden, cfg.rank), y.to_dense(cfg.hidden, cfg.rank));
        }
    }
}
