//! NativeBackend: the pure-Rust CPU execution backend. Implements every
//! artifact kind the coordinator drives — `cls_train`, `cls_eval`,
//! `lm_train`, `lm_logits`, `pretrain_lm`, `full_cls_train` — with the
//! exact positional signatures the PJRT artifacts expose, so trainers,
//! the serving router, benches and examples run end-to-end with zero
//! external dependencies (no Python, no HLO artifacts, no PJRT).
//!
//! Method support: every registered PEFT method runs end to end here,
//! both eval AND train. The delta expansion is
//! `projection::op::ProjectionOp::apply` (via `reconstruct`), and the
//! gradient route back onto the trainable vector is the matching
//! `vjp` — one projection API for all ten methods, resolved through
//! `projection::op::resolve`. No per-method dispatch lives in this
//! file anymore.
//!
//! Compute tier: all dense math below this file runs on the kernel
//! variant `kernels::dispatch` resolved from `UNI_LORA_KERNELS`
//! (scalar golden reference, or the register-tiled simd tier). Every
//! tier is bitwise-deterministic across runs and thread counts, so the
//! backend's reproducibility guarantees hold for each tier; switching
//! tiers changes results only within the documented ULP tolerance.

pub mod kv_arena;
pub mod model;

use super::artifact::ArtifactMeta;
use super::backend::{check_inputs, Backend};
use super::spec;
use super::tensor::{ExecStats, TensorIn, TensorOut};
use crate::config::{ModelCfg, RuntimeOpts};
use crate::kernels;
use crate::projection::op as projop;
use crate::projection::reconstruct::{reconstruct_with_statics, ModuleDelta};
use crate::projection::statics::{Static, StaticData};
use crate::session::{DecodeSession, NativeDecodeSession, ReconCache, SessionOpts};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

pub struct NativeBackend {
    manifest: BTreeMap<String, ArtifactMeta>,
    pinned: HashMap<String, TensorIn>,
    stats: ExecStats,
    /// Adapter-reconstruction cache for decode sessions — shared with
    /// every `try_clone` of this backend, so the serving worker pool
    /// reconstructs each adapter once per FLEET, not once per worker
    /// (the same Arc pattern as the router's statics cache).
    recon: Arc<ReconCache>,
}

impl NativeBackend {
    pub fn new() -> Result<NativeBackend> {
        Ok(NativeBackend {
            manifest: spec::native_manifest()?,
            pinned: HashMap::new(),
            stats: ExecStats::default(),
            recon: Arc::new(ReconCache::new(RuntimeOpts::from_env().recon_cache)),
        })
    }

    /// A backend with an explicitly-sized reconstruction cache (tests
    /// forcing eviction churn; benches pinning residency).
    pub fn with_recon_cache(cap: usize) -> Result<NativeBackend> {
        let mut be = NativeBackend::new()?;
        be.recon = Arc::new(ReconCache::new(cap));
        Ok(be)
    }

    /// The shared adapter-reconstruction cache (stats surface for the
    /// server and tests).
    pub fn recon_cache(&self) -> Arc<ReconCache> {
        self.recon.clone()
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    /// Native state is a registry plus host vectors: cheap to
    /// replicate, so the serving worker pool can give every worker its
    /// own backend over shared `Arc` backbone weights.
    fn try_clone(&self) -> Result<Box<dyn Backend>> {
        Ok(Box::new(NativeBackend {
            manifest: self.manifest.clone(),
            pinned: self.pinned.clone(),
            stats: ExecStats::default(),
            recon: self.recon.clone(),
        }))
    }

    /// Native sessions run true incremental decoding: per-layer K/V
    /// caches (`model::incr_forward`) + the shared reconstruction
    /// cache — O(model) per token instead of the fallback's
    /// O(seq · model).
    fn begin_decode(
        &mut self,
        artifact: &str,
        w0: Arc<Vec<f32>>,
        opts: &SessionOpts,
    ) -> Result<Box<dyn DecodeSession>> {
        let meta = self.meta(artifact)?;
        Ok(Box::new(NativeDecodeSession::new(meta, w0, self.recon.clone(), opts)?))
    }

    fn meta(&self, artifact: &str) -> Result<&ArtifactMeta> {
        self.manifest.get(artifact).ok_or_else(|| {
            anyhow!(
                "no artifact {artifact:?} in native registry ({} entries)",
                self.manifest.len()
            )
        })
    }

    fn artifact_names(&self) -> Vec<String> {
        self.manifest.keys().cloned().collect()
    }

    fn pin(&mut self, artifact: &str, input: &str, t: &TensorIn) -> Result<()> {
        use super::artifact::DType;
        let (expected, dtype) = {
            let meta = self.meta(artifact)?;
            let i = meta.input_index(input)?;
            (meta.inputs[i].numel(), meta.inputs[i].dtype.clone())
        };
        anyhow::ensure!(
            t.numel() == expected,
            "pin {artifact}/{input}: got {} elements, want {expected}",
            t.numel()
        );
        match (&dtype, t) {
            (DType::F32, TensorIn::F32(_) | TensorIn::SharedF32(_) | TensorIn::ScalarF32(_)) => {}
            (DType::I32, TensorIn::I32(_) | TensorIn::SharedI32(_) | TensorIn::ScalarI32(_)) => {}
            _ => bail!("pin {artifact}/{input}: dtype mismatch"),
        }
        self.pinned.insert(format!("{artifact}/{input}"), t.clone());
        Ok(())
    }

    fn unpin_all(&mut self) {
        self.pinned.clear();
    }

    fn run(&mut self, name: &str, inputs: &[TensorIn]) -> Result<Vec<TensorOut>> {
        let t0 = Instant::now();
        let meta = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("no artifact {name:?} in native registry"))?;
        check_inputs(meta, inputs)?;
        let mut resolved: Vec<&TensorIn> = Vec::with_capacity(inputs.len());
        for (spec_in, t) in meta.inputs.iter().zip(inputs) {
            if matches!(t, TensorIn::Pinned) {
                let key = format!("{name}/{}", spec_in.name);
                let p = self.pinned.get(&key).ok_or_else(|| {
                    anyhow!("artifact {name} input {}: Pinned but never pin()ed", spec_in.name)
                })?;
                resolved.push(p);
            } else {
                resolved.push(t);
            }
        }
        let out = execute(meta, &resolved).with_context(|| format!("native execution of {name}"))?;
        self.stats.execute_secs += t0.elapsed().as_secs_f64();
        self.stats.executions += 1;
        Ok(out)
    }

    fn stats(&self) -> ExecStats {
        self.stats.clone()
    }

    fn reset_stats(&mut self) {
        self.stats = ExecStats::default();
    }

    fn cache_dir(&self) -> PathBuf {
        std::env::var("UNI_LORA_CACHE")
            .map(PathBuf::from)
            .unwrap_or_else(|_| std::env::temp_dir().join("uni_lora_native_cache"))
    }
}

// ------------------------------------------------------------------
// dispatch

fn execute(meta: &ArtifactMeta, ins: &[&TensorIn]) -> Result<Vec<TensorOut>> {
    match meta.kind.as_str() {
        "cls_train" => cls_train(meta, ins),
        "cls_eval" => cls_eval(meta, ins),
        "lm_train" => lm_train(meta, ins),
        "lm_logits" => lm_logits(meta, ins),
        "pretrain_lm" => pretrain_lm(meta, ins),
        "full_cls_train" => full_cls_train(meta, ins),
        other => bail!("native backend: unsupported artifact kind {other:?}"),
    }
}

/// Rebuild `Static` structs from the trailing statics inputs.
fn parse_statics(meta: &ArtifactMeta, ins: &[&TensorIn], start: usize) -> Result<Vec<Static>> {
    let mut out = Vec::with_capacity(meta.inputs.len() - start);
    for (spec_in, t) in meta.inputs[start..].iter().zip(&ins[start..]) {
        let data = match t {
            TensorIn::F32(v) => StaticData::F32(v.clone()),
            TensorIn::I32(v) => StaticData::I32(v.clone()),
            _ => bail!("static input {} must be a full tensor", spec_in.name),
        };
        out.push(Static { name: spec_in.name.clone(), shape: spec_in.shape.clone(), data });
    }
    Ok(out)
}

/// Whether the native backend can run the train artifact kinds for a
/// method. Derived from the `projection::op` registry — every
/// registered method carries its own `vjp`, so ALL of them train
/// natively; only unknown method strings are rejected.
pub fn can_train(method: &str) -> bool {
    projop::resolve(method).is_ok()
}

/// Registered method names, for callers enumerating the training
/// surface (README matrix, examples/paper_tables).
pub fn trainable_methods() -> Vec<&'static str> {
    projop::method_names()
}

fn ensure_trainable(cfg: &ModelCfg) -> Result<()> {
    projop::resolve(&cfg.method).map(|_| ())
}

/// Map per-module factor cotangents back onto the trainable vector —
/// the registry op's reverse-mode pullback at theta (exact for linear
/// methods and for the bilinear tied/vb maps).
fn theta_grad(
    cfg: &ModelCfg,
    stats: &[Static],
    theta: &[f32],
    grads: &model::Gradients,
) -> Result<Vec<f32>> {
    projop::resolve(&cfg.method)?
        .vjp(cfg, stats, theta, &grads.modules)
        .with_context(|| format!("theta pullback for method {:?}", cfg.method))
}

fn zero_deltas(cfg: &ModelCfg) -> Vec<ModuleDelta> {
    let ar = cfg.hidden * cfg.rank;
    (0..cfg.n_modules())
        .map(|_| ModuleDelta::LowRank { a: vec![0.0; ar], b: vec![0.0; ar] })
        .collect()
}

// ------------------------------------------------------------------
// artifact kinds

fn cls_train(meta: &ArtifactMeta, ins: &[&TensorIn]) -> Result<Vec<TensorOut>> {
    let cfg = &meta.cfg;
    ensure_trainable(cfg)?;
    let mut theta = ins[0].as_f32()?.to_vec();
    let mut m = ins[1].as_f32()?.to_vec();
    let mut v = ins[2].as_f32()?.to_vec();
    let mut head = ins[3].as_f32()?.to_vec();
    let mut hm = ins[4].as_f32()?.to_vec();
    let mut hv = ins[5].as_f32()?.to_vec();
    let step = ins[6].scalar_i32()?;
    let lr_t = ins[7].scalar_f32()?;
    let lr_h = ins[8].scalar_f32()?;
    let wd = ins[9].scalar_f32()?;
    let w0 = ins[10].as_f32()?;
    let tokens = ins[11].as_i32()?;
    let attn_len = ins[12].as_i32()?;
    let stats = parse_statics(meta, ins, 14)?;

    let base = model::BaseMap::new(cfg, w0)?;
    let deltas = reconstruct_with_statics(cfg, &stats, &theta)?;
    let fc = model::forward(cfg, &base, &deltas, tokens)?;
    let ch = model::cls_head_forward(cfg, &fc.hidden, &head, attn_len);
    let c = cfg.n_classes.max(1);
    let (loss, d_logits) = if cfg.n_classes == 1 {
        model::mse_mean(&ch.logits, ins[13].as_f32()?, cfg.batch)
    } else {
        model::softmax_xent_mean(&ch.logits, ins[13].as_i32()?, cfg.batch, c)?
    };
    let (g_head, d_hidden) = model::cls_head_backward(cfg, &ch, &head, &d_logits);
    let grads = model::backward(cfg, &base, &deltas, tokens, &fc, &d_hidden, false)?;
    let g_theta = theta_grad(cfg, &stats, &theta, &grads)?;
    model::adamw(&mut theta, &g_theta, &mut m, &mut v, step, lr_t, wd);
    model::adamw(&mut head, &g_head, &mut hm, &mut hv, step, lr_h, 0.0);
    Ok(vec![
        TensorOut::F32(theta),
        TensorOut::F32(m),
        TensorOut::F32(v),
        TensorOut::F32(head),
        TensorOut::F32(hm),
        TensorOut::F32(hv),
        TensorOut::F32(vec![loss]),
    ])
}

fn cls_eval(meta: &ArtifactMeta, ins: &[&TensorIn]) -> Result<Vec<TensorOut>> {
    let cfg = &meta.cfg;
    let theta = ins[0].as_f32()?;
    let head = ins[1].as_f32()?;
    let w0 = ins[2].as_f32()?;
    let tokens = ins[3].as_i32()?;
    let attn_len = ins[4].as_i32()?;
    let stats = parse_statics(meta, ins, 5)?;
    let base = model::BaseMap::new(cfg, w0)?;
    let deltas = reconstruct_with_statics(cfg, &stats, theta)?;
    let fc = model::forward(cfg, &base, &deltas, tokens)?;
    let ch = model::cls_head_forward(cfg, &fc.hidden, head, attn_len);
    Ok(vec![TensorOut::F32(ch.logits)])
}

fn lm_train(meta: &ArtifactMeta, ins: &[&TensorIn]) -> Result<Vec<TensorOut>> {
    let cfg = &meta.cfg;
    ensure_trainable(cfg)?;
    let mut theta = ins[0].as_f32()?.to_vec();
    let mut m = ins[1].as_f32()?.to_vec();
    let mut v = ins[2].as_f32()?.to_vec();
    let step = ins[3].scalar_i32()?;
    let lr_t = ins[4].scalar_f32()?;
    let wd = ins[5].scalar_f32()?;
    let w0 = ins[6].as_f32()?;
    let tokens = ins[7].as_i32()?;
    let labels = ins[8].as_i32()?;
    let stats = parse_statics(meta, ins, 9)?;
    let bt = cfg.batch * cfg.seq;

    let base = model::BaseMap::new(cfg, w0)?;
    let deltas = reconstruct_with_statics(cfg, &stats, &theta)?;
    let fc = model::forward(cfg, &base, &deltas, tokens)?;
    let logits = model::lm_head_forward(cfg, &base, &fc.hidden);
    let (loss, d_logits) = model::lm_xent_masked(&logits, labels, bt, cfg.vocab)?;
    let mut d_hidden = vec![0f32; bt * cfg.hidden];
    let (h, vc) = (cfg.hidden, cfg.vocab);
    kernels::gemm_nt(&d_logits, base.seg("lm_head"), &mut d_hidden, bt, h, vc, false);
    let grads = model::backward(cfg, &base, &deltas, tokens, &fc, &d_hidden, false)?;
    let g_theta = theta_grad(cfg, &stats, &theta, &grads)?;
    model::adamw(&mut theta, &g_theta, &mut m, &mut v, step, lr_t, wd);
    Ok(vec![
        TensorOut::F32(theta),
        TensorOut::F32(m),
        TensorOut::F32(v),
        TensorOut::F32(vec![loss]),
    ])
}

fn lm_logits(meta: &ArtifactMeta, ins: &[&TensorIn]) -> Result<Vec<TensorOut>> {
    let cfg = &meta.cfg;
    let theta = ins[0].as_f32()?;
    let w0 = ins[1].as_f32()?;
    let tokens = ins[2].as_i32()?;
    let stats = parse_statics(meta, ins, 3)?;
    let base = model::BaseMap::new(cfg, w0)?;
    let deltas = reconstruct_with_statics(cfg, &stats, theta)?;
    let fc = model::forward(cfg, &base, &deltas, tokens)?;
    let logits = model::lm_head_forward(cfg, &base, &fc.hidden);
    Ok(vec![TensorOut::F32(logits)])
}

fn pretrain_lm(meta: &ArtifactMeta, ins: &[&TensorIn]) -> Result<Vec<TensorOut>> {
    let cfg = &meta.cfg;
    let mut w0 = ins[0].as_f32()?.to_vec();
    let mut m = ins[1].as_f32()?.to_vec();
    let mut v = ins[2].as_f32()?.to_vec();
    let step = ins[3].scalar_i32()?;
    let lr = ins[4].scalar_f32()?;
    let wd = ins[5].scalar_f32()?;
    let tokens = ins[6].as_i32()?;
    let labels = ins[7].as_i32()?;
    let bt = cfg.batch * cfg.seq;
    let deltas = zero_deltas(cfg);

    let (loss, gw0) = {
        let base = model::BaseMap::new(cfg, &w0)?;
        let fc = model::forward(cfg, &base, &deltas, tokens)?;
        let logits = model::lm_head_forward(cfg, &base, &fc.hidden);
        let (loss, d_logits) = model::lm_xent_masked(&logits, labels, bt, cfg.vocab)?;
        let mut d_hidden = vec![0f32; bt * cfg.hidden];
        kernels::gemm_nt(
            &d_logits,
            base.seg("lm_head"),
            &mut d_hidden,
            bt,
            cfg.hidden,
            cfg.vocab,
            false,
        );
        let grads = model::backward(cfg, &base, &deltas, tokens, &fc, &d_hidden, true)?;
        let mut gw0 = grads.w0.expect("w0 gradients requested");
        // lm_head is part of w0 but applied outside forward(); add here
        let (o, n) = base.offset("lm_head");
        let (h, vc) = (cfg.hidden, cfg.vocab);
        kernels::gemm_tn(&fc.hidden, &d_logits, &mut gw0[o..o + n], bt, h, vc, true);
        (loss, gw0)
    };
    model::adamw(&mut w0, &gw0, &mut m, &mut v, step, lr, wd);
    Ok(vec![
        TensorOut::F32(w0),
        TensorOut::F32(m),
        TensorOut::F32(v),
        TensorOut::F32(vec![loss]),
    ])
}

fn full_cls_train(meta: &ArtifactMeta, ins: &[&TensorIn]) -> Result<Vec<TensorOut>> {
    let cfg = &meta.cfg;
    let mut w0 = ins[0].as_f32()?.to_vec();
    let mut m = ins[1].as_f32()?.to_vec();
    let mut v = ins[2].as_f32()?.to_vec();
    let mut head = ins[3].as_f32()?.to_vec();
    let mut hm = ins[4].as_f32()?.to_vec();
    let mut hv = ins[5].as_f32()?.to_vec();
    let step = ins[6].scalar_i32()?;
    let lr_t = ins[7].scalar_f32()?;
    let lr_h = ins[8].scalar_f32()?;
    let wd = ins[9].scalar_f32()?;
    let tokens = ins[10].as_i32()?;
    let attn_len = ins[11].as_i32()?;
    let deltas = zero_deltas(cfg);
    let c = cfg.n_classes.max(1);

    let (loss, gw0, g_head) = {
        let base = model::BaseMap::new(cfg, &w0)?;
        let fc = model::forward(cfg, &base, &deltas, tokens)?;
        let ch = model::cls_head_forward(cfg, &fc.hidden, &head, attn_len);
        let (loss, d_logits) = if cfg.n_classes == 1 {
            model::mse_mean(&ch.logits, ins[12].as_f32()?, cfg.batch)
        } else {
            model::softmax_xent_mean(&ch.logits, ins[12].as_i32()?, cfg.batch, c)?
        };
        let (g_head, d_hidden) = model::cls_head_backward(cfg, &ch, &head, &d_logits);
        let grads = model::backward(cfg, &base, &deltas, tokens, &fc, &d_hidden, true)?;
        (loss, grads.w0.expect("w0 gradients requested"), g_head)
    };
    model::adamw(&mut w0, &gw0, &mut m, &mut v, step, lr_t, wd);
    model::adamw(&mut head, &g_head, &mut hm, &mut hv, step, lr_h, 0.0);
    Ok(vec![
        TensorOut::F32(w0),
        TensorOut::F32(m),
        TensorOut::F32(v),
        TensorOut::F32(head),
        TensorOut::F32(hm),
        TensorOut::F32(hv),
        TensorOut::F32(vec![loss]),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projection::statics::{gen_statics, init_theta};
    use crate::rng;

    fn backend() -> NativeBackend {
        NativeBackend::new().unwrap()
    }

    fn init_base_for(be: &NativeBackend, art: &str, seed: u64) -> Vec<f32> {
        crate::coordinator::init_base(be.meta(art).unwrap(), seed)
    }

    #[test]
    fn try_clone_yields_independent_working_backend() {
        let be = backend();
        let mut cl = be.try_clone().unwrap();
        assert_eq!(cl.name(), "native");
        assert_eq!(cl.artifact_names(), be.artifact_names());
        assert_eq!(cl.stats().executions, 0);
        assert!(cl.run("no_such_artifact", &[]).is_err());
    }

    #[test]
    fn rejects_bad_input_counts_and_unknown_artifacts() {
        let mut be = backend();
        let err = be
            .run("glue_base_uni_c2_cls_eval", &[TensorIn::F32(vec![0.0])])
            .unwrap_err()
            .to_string();
        assert!(err.contains("inputs"), "{err}");
        assert!(be.run("no_such_artifact", &[]).is_err());
        assert!(be.meta("nope").is_err());
        assert!(be.artifact_names().len() >= 100);
    }

    #[test]
    fn cls_eval_produces_finite_logits() {
        let mut be = backend();
        let art = "glue_base_uni_c2_cls_eval";
        let meta = be.meta(art).unwrap().clone();
        let cfg = meta.cfg.clone();
        let theta = init_theta(&cfg, 1).unwrap();
        let head = vec![0f32; meta.head_params];
        let w0 = init_base_for(&be, art, 1);
        let stats = gen_statics(&cfg, 1).unwrap();
        let tokens = rng::indices(3, cfg.batch * cfg.seq, cfg.vocab);
        let attn_len = vec![cfg.seq as i32; cfg.batch];
        let mut inputs = vec![
            TensorIn::F32(theta),
            TensorIn::F32(head),
            TensorIn::F32(w0),
            TensorIn::I32(tokens),
            TensorIn::I32(attn_len),
        ];
        inputs.extend(stats.iter().map(TensorIn::from));
        let out = be.run(art, &inputs).unwrap();
        assert_eq!(out.len(), 1);
        let logits = out[0].as_f32().unwrap();
        assert_eq!(logits.len(), cfg.batch * cfg.n_classes);
        assert!(logits.iter().all(|x| x.is_finite()));
        assert_eq!(be.stats().executions, 1);
    }

    /// The registry closes the old trainability gap: methods that used
    /// to be eval/serve-only here (vera, the bilinear vb, the dense
    /// fourierft, ...) now run their train artifact kinds natively.
    #[test]
    fn every_registered_method_is_trainable_and_vera_trains() {
        assert!(crate::projection::op::registry()
            .iter()
            .all(|op| can_train(op.method())));
        assert_eq!(trainable_methods(), crate::projection::op::method_names());
        assert!(!can_train("nope"));

        let mut be = backend();
        let art = "glue_base_vera_c2_cls_eval";
        let meta = be.meta(art).unwrap().clone();
        let cfg = meta.cfg.clone();
        let theta = init_theta(&cfg, 2).unwrap();
        let stats = gen_statics(&cfg, 2).unwrap();
        let w0 = init_base_for(&be, art, 2);
        let mut inputs = vec![
            TensorIn::F32(theta.clone()),
            TensorIn::F32(vec![0f32; meta.head_params]),
            TensorIn::F32(w0.clone()),
            TensorIn::I32(rng::indices(5, cfg.batch * cfg.seq, cfg.vocab)),
            TensorIn::I32(vec![cfg.seq as i32; cfg.batch]),
        ];
        inputs.extend(stats.iter().map(TensorIn::from));
        assert!(be.run(art, &inputs).is_ok());

        // the formerly-bailing train kind now executes and returns the
        // full (theta, m, v, head, hm, hv, loss) update
        let train = "glue_base_vera_c2_cls_train";
        let tmeta = be.meta(train).unwrap().clone();
        // nonzero head so gradient reaches the adapted modules at step 1
        let head: Vec<f32> =
            rng::normals(77, tmeta.head_params).iter().map(|v| 0.1 * v).collect();
        let mut tin = vec![
            TensorIn::F32(theta.clone()),
            TensorIn::F32(vec![0f32; theta.len()]),
            TensorIn::F32(vec![0f32; theta.len()]),
            TensorIn::F32(head),
            TensorIn::F32(vec![0f32; tmeta.head_params]),
            TensorIn::F32(vec![0f32; tmeta.head_params]),
            TensorIn::ScalarI32(1),
            TensorIn::ScalarF32(1e-3),
            TensorIn::ScalarF32(1e-2),
            TensorIn::ScalarF32(0.0),
            TensorIn::F32(w0),
            TensorIn::I32(rng::indices(5, cfg.batch * cfg.seq, cfg.vocab)),
            TensorIn::I32(vec![cfg.seq as i32; cfg.batch]),
            TensorIn::I32(vec![0; cfg.batch]),
        ];
        tin.extend(stats.iter().map(TensorIn::from));
        let out = be.run(train, &tin).unwrap();
        assert_eq!(out.len(), 7);
        assert!(out[6].scalar_f32().unwrap().is_finite());
        let new_theta = out[0].as_f32().unwrap();
        assert_eq!(new_theta.len(), theta.len());
        // lamb_b receives gradient through b = pb * lamb_b's bilinear
        // partner, so at least part of theta must have moved
        assert!(new_theta.iter().zip(&theta).any(|(a, b)| a != b));
    }

    #[test]
    fn pinning_validates_and_resolves() {
        let mut be = backend();
        let art = "glue_base_uni_c2_cls_train";
        // wrong size rejected
        assert!(be.pin(art, "w0", &TensorIn::F32(vec![0.0])).is_err());
        // unknown input rejected
        assert!(be.pin(art, "nope", &TensorIn::F32(vec![0.0])).is_err());
        // Pinned without pin() rejected at run time
        let meta = be.meta(art).unwrap().clone();
        let cfg = meta.cfg.clone();
        // right size, wrong dtype rejected (tokens is i32)
        assert!(be
            .pin(art, "tokens", &TensorIn::F32(vec![0.0; cfg.batch * cfg.seq]))
            .is_err());
        let theta = init_theta(&cfg, 1).unwrap();
        let stats = gen_statics(&cfg, 1).unwrap();
        let mut inputs = vec![
            TensorIn::F32(theta.clone()),
            TensorIn::F32(vec![0f32; theta.len()]),
            TensorIn::F32(vec![0f32; theta.len()]),
            TensorIn::F32(vec![0f32; meta.head_params]),
            TensorIn::F32(vec![0f32; meta.head_params]),
            TensorIn::F32(vec![0f32; meta.head_params]),
            TensorIn::ScalarI32(1),
            TensorIn::ScalarF32(5e-3),
            TensorIn::ScalarF32(5e-2),
            TensorIn::ScalarF32(0.0),
            TensorIn::Pinned,
            TensorIn::I32(rng::indices(7, cfg.batch * cfg.seq, cfg.vocab)),
            TensorIn::I32(vec![cfg.seq as i32; cfg.batch]),
            TensorIn::I32(vec![0; cfg.batch]),
        ];
        inputs.extend(stats.iter().map(TensorIn::from));
        let err = be.run(art, &inputs).unwrap_err().to_string();
        assert!(err.contains("pin"), "{err}");
        // after pinning, the same call succeeds
        let w0 = init_base_for(&be, art, 1);
        be.pin(art, "w0", &TensorIn::F32(w0)).unwrap();
        let out = be.run(art, &inputs).unwrap();
        assert_eq!(out.len(), 7);
        assert!(out[6].scalar_f32().unwrap().is_finite());
        be.unpin_all();
        assert!(be.run(art, &inputs).is_err());
    }
}
