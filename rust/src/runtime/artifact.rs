//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust coordinator. One `manifest.json` describes every artifact's
//! positional input signature, theta/base layouts (with init specs) and
//! method config.

use crate::config::ModelCfg;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => Err(anyhow!("unknown dtype {other:?}")),
        }
    }
}

/// One positional input of an artifact.
#[derive(Debug, Clone)]
pub struct InputSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl InputSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One named segment of a flat parameter vector (theta or base).
#[derive(Debug, Clone)]
pub struct SegmentSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: String,
}

impl SegmentSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub kind: String,
    pub cfg: ModelCfg,
    pub d: usize,
    pub big_d: usize,
    pub base_params: usize,
    pub head_params: usize,
    pub theta_segments: Vec<SegmentSpec>,
    pub base_segments: Vec<SegmentSpec>,
    pub inputs: Vec<InputSpec>,
    pub outputs: Vec<String>,
    pub hlo_path: PathBuf,
}

impl ArtifactMeta {
    fn from_json(dir: &Path, j: &Json) -> Result<ArtifactMeta> {
        let segs = |key: &str| -> Result<Vec<SegmentSpec>> {
            j.req(key)?
                .as_arr()?
                .iter()
                .map(|s| {
                    Ok(SegmentSpec {
                        name: s.req("name")?.as_str()?.to_string(),
                        shape: s.req("shape")?.as_shape()?,
                        init: s.req("init")?.as_str()?.to_string(),
                    })
                })
                .collect()
        };
        Ok(ArtifactMeta {
            name: j.req("name")?.as_str()?.to_string(),
            kind: j.req("kind")?.as_str()?.to_string(),
            cfg: ModelCfg::from_json(j.req("cfg")?)?,
            d: j.req("d")?.as_usize()?,
            big_d: j.req("D")?.as_usize()?,
            base_params: j.req("base_params")?.as_usize()?,
            head_params: j.req("head_params")?.as_usize()?,
            theta_segments: segs("theta_segments")?,
            base_segments: segs("base_segments")?,
            inputs: j
                .req("inputs")?
                .as_arr()?
                .iter()
                .map(|s| {
                    Ok(InputSpec {
                        name: s.req("name")?.as_str()?.to_string(),
                        dtype: DType::parse(s.req("dtype")?.as_str()?)?,
                        shape: s.req("shape")?.as_shape()?,
                    })
                })
                .collect::<Result<_>>()?,
            outputs: j
                .req("outputs")?
                .as_arr()?
                .iter()
                .map(|s| Ok(s.as_str()?.to_string()))
                .collect::<Result<_>>()?,
            hlo_path: dir.join(j.req("hlo")?.as_str()?),
        })
    }

    /// Index of a named input in the positional signature.
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|i| i.name == name)
            .ok_or_else(|| anyhow!("artifact {} has no input {name:?}", self.name))
    }

    /// Index of a named output.
    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|o| o == name)
            .ok_or_else(|| anyhow!("artifact {} has no output {name:?}", self.name))
    }
}

/// The full artifact directory.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text)?;
        let mut artifacts = BTreeMap::new();
        for (name, meta) in j.as_obj()? {
            artifacts.insert(name.clone(), ArtifactMeta::from_json(&dir, meta)?);
        }
        Ok(Manifest { dir, artifacts })
    }

    /// Locate the artifacts directory: $UNI_LORA_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("UNI_LORA_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("no artifact {name:?} in manifest ({} entries)", self.artifacts.len()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Option<Manifest> {
        let dir = Manifest::default_dir();
        if dir.join("manifest.json").exists() {
            Some(Manifest::load(dir).unwrap())
        } else {
            None
        }
    }

    #[test]
    fn manifest_loads_and_is_complete() {
        let Some(m) = manifest() else { return };
        assert!(m.artifacts.len() >= 100, "{}", m.artifacts.len());
        for (name, a) in &m.artifacts {
            assert!(a.hlo_path.exists(), "{name} missing hlo file");
            assert!(!a.inputs.is_empty(), "{name}");
            assert!(!a.outputs.is_empty(), "{name}");
        }
    }

    #[test]
    fn meta_consistency_with_cfg() {
        let Some(m) = manifest() else { return };
        let a = m.get("glue_base_uni_c2_cls_train").unwrap();
        assert_eq!(a.cfg.method, "uni");
        assert_eq!(a.d, a.cfg.d);
        assert_eq!(a.big_d, a.cfg.d_full());
        assert_eq!(a.input_index("theta").unwrap(), 0);
        let ti = a.input_index("tokens").unwrap();
        assert_eq!(a.inputs[ti].shape, vec![a.cfg.batch, a.cfg.seq]);
        // theta segment total == d
        let total: usize = a.theta_segments.iter().map(|s| s.numel()).sum();
        assert_eq!(total.max(1), a.d);
    }

    #[test]
    fn rust_statics_match_manifest_shapes() {
        let Some(m) = manifest() else { return };
        for name in ["glue_base_uni_c2_cls_train", "glue_base_vera_c2_cls_train",
                     "glue_base_vb_c2_cls_train", "glue_base_lora_xs_c2_cls_train",
                     "glue_base_fourierft_c2_cls_train", "glue_large_fastfood_c2_cls_train"] {
            let a = m.get(name).unwrap();
            let stats = crate::projection::statics::gen_statics(&a.cfg, 1).unwrap();
            // the final `stats.len()` inputs of the artifact are the statics
            let n_in = a.inputs.len();
            for (k, s) in stats.iter().enumerate() {
                let spec = &a.inputs[n_in - stats.len() + k];
                assert_eq!(spec.name, s.name, "{name}");
                assert_eq!(spec.numel(), s.len(), "{name}/{}", s.name);
            }
        }
    }
}
