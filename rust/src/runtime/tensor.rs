//! Backend-agnostic host tensors: the value types that cross the
//! `Backend::run` boundary. Both the native CPU executor and the PJRT
//! executor speak only these.

use crate::projection::statics::{Static, StaticData};
use anyhow::{bail, Result};
use std::sync::Arc;

/// Host-side input tensor (flat, row-major; shape from the artifact spec).
#[derive(Debug, Clone)]
pub enum TensorIn {
    F32(Vec<f32>),
    I32(Vec<i32>),
    /// Shared (refcounted) f32 tensor: hoists a frozen host vector —
    /// theta, w0, f32 statics — out of per-step `run` calls. Cloning is
    /// an `Arc` bump, not a buffer copy (the decode hot loop used to
    /// re-clone theta and the whole backbone every generated token).
    SharedF32(Arc<Vec<f32>>),
    /// Shared i32 tensor (the integer statics, e.g. uni's `idx`).
    SharedI32(Arc<Vec<i32>>),
    ScalarF32(f32),
    ScalarI32(i32),
    /// Placeholder for an input previously uploaded via `Backend::pin`.
    Pinned,
}

impl TensorIn {
    pub fn numel(&self) -> usize {
        match self {
            TensorIn::F32(v) => v.len(),
            TensorIn::I32(v) => v.len(),
            TensorIn::SharedF32(v) => v.len(),
            TensorIn::SharedI32(v) => v.len(),
            _ => 1,
        }
    }

    /// View as f32 data (scalars included).
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            TensorIn::F32(v) => Ok(v),
            TensorIn::SharedF32(v) => Ok(v),
            TensorIn::ScalarF32(x) => Ok(std::slice::from_ref(x)),
            _ => bail!("expected f32 input"),
        }
    }

    /// View as i32 data (scalars included).
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            TensorIn::I32(v) => Ok(v),
            TensorIn::SharedI32(v) => Ok(v),
            TensorIn::ScalarI32(x) => Ok(std::slice::from_ref(x)),
            _ => bail!("expected i32 input"),
        }
    }

    /// A shared (Arc-backed) copy of a frozen `Static`: the data is
    /// copied ONCE here; every later `clone()` of the result is a
    /// refcount bump. Decode paths build these per batch/admission
    /// instead of deep-cloning statics every step.
    pub fn shared_from(s: &Static) -> TensorIn {
        match &s.data {
            StaticData::F32(v) => TensorIn::SharedF32(Arc::new(v.clone())),
            StaticData::I32(v) => TensorIn::SharedI32(Arc::new(v.clone())),
        }
    }

    pub fn scalar_f32(&self) -> Result<f32> {
        match self {
            TensorIn::ScalarF32(x) => Ok(*x),
            TensorIn::F32(v) if v.len() == 1 => Ok(v[0]),
            _ => bail!("expected scalar f32 input"),
        }
    }

    pub fn scalar_i32(&self) -> Result<i32> {
        match self {
            TensorIn::ScalarI32(x) => Ok(*x),
            TensorIn::I32(v) if v.len() == 1 => Ok(v[0]),
            _ => bail!("expected scalar i32 input"),
        }
    }
}

impl From<&Static> for TensorIn {
    fn from(s: &Static) -> TensorIn {
        match &s.data {
            StaticData::F32(v) => TensorIn::F32(v.clone()),
            StaticData::I32(v) => TensorIn::I32(v.clone()),
        }
    }
}

/// Host-side output tensor.
#[derive(Debug, Clone)]
pub enum TensorOut {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl TensorOut {
    pub fn f32(self) -> Result<Vec<f32>> {
        match self {
            TensorOut::F32(v) => Ok(v),
            _ => bail!("expected f32 output"),
        }
    }

    pub fn scalar_f32(&self) -> Result<f32> {
        match self {
            TensorOut::F32(v) if !v.is_empty() => Ok(v[0]),
            _ => bail!("expected non-empty f32 output"),
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            TensorOut::F32(v) => Ok(v),
            _ => bail!("expected f32 output"),
        }
    }
}

/// Cumulative execution statistics (perf accounting, EXPERIMENTS.md §Perf).
#[derive(Debug, Default, Clone)]
pub struct ExecStats {
    pub compile_secs: f64,
    pub execute_secs: f64,
    pub transfer_secs: f64,
    pub executions: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_in_views() {
        assert_eq!(TensorIn::F32(vec![1.0, 2.0]).numel(), 2);
        assert_eq!(TensorIn::ScalarI32(7).numel(), 1);
        assert_eq!(TensorIn::ScalarF32(0.5).scalar_f32().unwrap(), 0.5);
        assert_eq!(TensorIn::ScalarI32(3).scalar_i32().unwrap(), 3);
        assert!(TensorIn::I32(vec![1, 2]).as_f32().is_err());
        assert_eq!(TensorIn::I32(vec![1, 2]).as_i32().unwrap(), &[1, 2]);
    }

    #[test]
    fn shared_tensors_view_like_owned_and_clone_by_refcount() {
        let f = TensorIn::SharedF32(Arc::new(vec![1.0, 2.0, 3.0]));
        assert_eq!(f.numel(), 3);
        assert_eq!(f.as_f32().unwrap(), &[1.0, 2.0, 3.0]);
        assert!(f.as_i32().is_err());
        let i = TensorIn::SharedI32(Arc::new(vec![4, 5]));
        assert_eq!(i.numel(), 2);
        assert_eq!(i.as_i32().unwrap(), &[4, 5]);
        // clone shares the allocation (no deep copy)
        if let (TensorIn::SharedF32(a), TensorIn::SharedF32(b)) = (&f, &f.clone()) {
            assert!(Arc::ptr_eq(a, b));
        } else {
            panic!("clone changed variant");
        }
        // statics convert to the shared variants
        use crate::projection::statics::{Static, StaticData};
        let s = Static { name: "idx".into(), shape: vec![2], data: StaticData::I32(vec![7, 9]) };
        assert_eq!(TensorIn::shared_from(&s).as_i32().unwrap(), &[7, 9]);
    }

    #[test]
    fn tensor_out_views() {
        let t = TensorOut::F32(vec![4.0, 5.0]);
        assert_eq!(t.scalar_f32().unwrap(), 4.0);
        assert_eq!(t.as_f32().unwrap(), &[4.0, 5.0]);
        assert_eq!(t.f32().unwrap(), vec![4.0, 5.0]);
        assert!(TensorOut::I32(vec![1]).as_f32().is_err());
    }
}
