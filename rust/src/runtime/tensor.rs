//! Backend-agnostic host tensors: the value types that cross the
//! `Backend::run` boundary. Both the native CPU executor and the PJRT
//! executor speak only these.

use crate::projection::statics::{Static, StaticData};
use anyhow::{bail, Result};

/// Host-side input tensor (flat, row-major; shape from the artifact spec).
#[derive(Debug, Clone)]
pub enum TensorIn {
    F32(Vec<f32>),
    I32(Vec<i32>),
    ScalarF32(f32),
    ScalarI32(i32),
    /// Placeholder for an input previously uploaded via `Backend::pin`.
    Pinned,
}

impl TensorIn {
    pub fn numel(&self) -> usize {
        match self {
            TensorIn::F32(v) => v.len(),
            TensorIn::I32(v) => v.len(),
            _ => 1,
        }
    }

    /// View as f32 data (scalars included).
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            TensorIn::F32(v) => Ok(v),
            TensorIn::ScalarF32(x) => Ok(std::slice::from_ref(x)),
            _ => bail!("expected f32 input"),
        }
    }

    /// View as i32 data (scalars included).
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            TensorIn::I32(v) => Ok(v),
            TensorIn::ScalarI32(x) => Ok(std::slice::from_ref(x)),
            _ => bail!("expected i32 input"),
        }
    }

    pub fn scalar_f32(&self) -> Result<f32> {
        match self {
            TensorIn::ScalarF32(x) => Ok(*x),
            TensorIn::F32(v) if v.len() == 1 => Ok(v[0]),
            _ => bail!("expected scalar f32 input"),
        }
    }

    pub fn scalar_i32(&self) -> Result<i32> {
        match self {
            TensorIn::ScalarI32(x) => Ok(*x),
            TensorIn::I32(v) if v.len() == 1 => Ok(v[0]),
            _ => bail!("expected scalar i32 input"),
        }
    }
}

impl From<&Static> for TensorIn {
    fn from(s: &Static) -> TensorIn {
        match &s.data {
            StaticData::F32(v) => TensorIn::F32(v.clone()),
            StaticData::I32(v) => TensorIn::I32(v.clone()),
        }
    }
}

/// Host-side output tensor.
#[derive(Debug, Clone)]
pub enum TensorOut {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl TensorOut {
    pub fn f32(self) -> Result<Vec<f32>> {
        match self {
            TensorOut::F32(v) => Ok(v),
            _ => bail!("expected f32 output"),
        }
    }

    pub fn scalar_f32(&self) -> Result<f32> {
        match self {
            TensorOut::F32(v) if !v.is_empty() => Ok(v[0]),
            _ => bail!("expected non-empty f32 output"),
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            TensorOut::F32(v) => Ok(v),
            _ => bail!("expected f32 output"),
        }
    }
}

/// Cumulative execution statistics (perf accounting, EXPERIMENTS.md §Perf).
#[derive(Debug, Default, Clone)]
pub struct ExecStats {
    pub compile_secs: f64,
    pub execute_secs: f64,
    pub transfer_secs: f64,
    pub executions: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_in_views() {
        assert_eq!(TensorIn::F32(vec![1.0, 2.0]).numel(), 2);
        assert_eq!(TensorIn::ScalarI32(7).numel(), 1);
        assert_eq!(TensorIn::ScalarF32(0.5).scalar_f32().unwrap(), 0.5);
        assert_eq!(TensorIn::ScalarI32(3).scalar_i32().unwrap(), 3);
        assert!(TensorIn::I32(vec![1, 2]).as_f32().is_err());
        assert_eq!(TensorIn::I32(vec![1, 2]).as_i32().unwrap(), &[1, 2]);
    }

    #[test]
    fn tensor_out_views() {
        let t = TensorOut::F32(vec![4.0, 5.0]);
        assert_eq!(t.scalar_f32().unwrap(), 4.0);
        assert_eq!(t.as_f32().unwrap(), &[4.0, 5.0]);
        assert_eq!(t.f32().unwrap(), vec![4.0, 5.0]);
        assert!(TensorOut::I32(vec![1]).as_f32().is_err());
    }
}
