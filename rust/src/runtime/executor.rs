//! PJRT execution: compile-once, run-many. Wraps the `xla` crate so the
//! rest of the system deals only in `TensorIn`/`TensorOut`. Compiled
//! only with `--features pjrt`; the default build uses the pure-Rust
//! `NativeBackend` instead.

use super::artifact::{ArtifactMeta, DType, Manifest};
use super::backend::Backend;
use super::tensor::{ExecStats, TensorIn, TensorOut};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::time::Instant;

/// Compile-once executable cache over the PJRT CPU client.
pub struct Executor {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// pinned frozen inputs, keyed "artifact/input_name" (§Perf: the
    /// trainer passes `TensorIn::Pinned` so frozen vectors (w0, statics)
    /// are not cloned on every step; true device residency via
    /// execute_b was measured to SIGSEGV in xla 0.1.6 — the crate's
    /// buffer execute appears to donate inputs — so pinning caches the
    /// prepared Literal host-side instead).
    pinned: HashMap<String, xla::Literal>,
    pub stats: ExecStats,
}

impl Executor {
    pub fn new(manifest: Manifest) -> Result<Executor> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Executor {
            client,
            manifest,
            cache: HashMap::new(),
            pinned: HashMap::new(),
            stats: ExecStats::default(),
        })
    }

    /// Upload an input to the device once; subsequent `run` calls for
    /// this artifact pass the resident buffer instead of re-transferring
    /// the host vector. Intended for frozen inputs (w0, statics).
    pub fn pin(&mut self, artifact: &str, input: &str, t: &TensorIn) -> Result<()> {
        let meta = self.manifest.get(artifact)?;
        let i = meta.input_index(input)?;
        let lit = Self::literal(&meta.inputs[i].shape, t)?;
        self.pinned.insert(format!("{artifact}/{input}"), lit);
        Ok(())
    }

    pub fn unpin_all(&mut self) {
        self.pinned.clear();
    }

    pub fn with_default_manifest() -> Result<Executor> {
        Executor::new(Manifest::load(Manifest::default_dir())?)
    }

    /// Compile (or fetch the cached) executable for an artifact.
    pub fn prepare(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let meta = self.manifest.get(name)?.clone();
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            meta.hlo_path
                .to_str()
                .context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO for {name}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        self.stats.compile_secs += t0.elapsed().as_secs_f64();
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    fn literal(spec_dims: &[usize], t: &TensorIn) -> Result<xla::Literal> {
        let dims: Vec<i64> = spec_dims.iter().map(|&d| d as i64).collect();
        Ok(match t {
            TensorIn::F32(v) => xla::Literal::vec1(v).reshape(&dims)?,
            TensorIn::I32(v) => xla::Literal::vec1(v).reshape(&dims)?,
            TensorIn::SharedF32(v) => xla::Literal::vec1(v.as_slice()).reshape(&dims)?,
            TensorIn::SharedI32(v) => xla::Literal::vec1(v.as_slice()).reshape(&dims)?,
            TensorIn::ScalarF32(x) => xla::Literal::scalar(*x),
            TensorIn::ScalarI32(x) => xla::Literal::scalar(*x),
            TensorIn::Pinned => bail!("Pinned tensor has no literal form"),
        })
    }

    /// Execute an artifact with positional inputs; returns the decomposed
    /// output tuple in the artifact's declared output order.
    pub fn run(&mut self, name: &str, inputs: &[TensorIn]) -> Result<Vec<TensorOut>> {
        self.prepare(name)?;
        let meta = self.manifest.get(name)?.clone();
        let meta = &meta;
        if inputs.len() != meta.inputs.len() {
            bail!(
                "artifact {name}: got {} inputs, signature has {}",
                inputs.len(),
                meta.inputs.len()
            );
        }
        let t0 = Instant::now();
        let mut literals: Vec<xla::Literal> = Vec::with_capacity(inputs.len());
        let mut pinned_slots: Vec<Option<String>> = Vec::with_capacity(inputs.len());
        for (spec, t) in meta.inputs.iter().zip(inputs) {
            if matches!(t, TensorIn::Pinned) {
                let key = format!("{name}/{}", spec.name);
                if !self.pinned.contains_key(&key) {
                    bail!("artifact {name} input {}: Pinned but never pin()ed", spec.name);
                }
                pinned_slots.push(Some(key));
                continue;
            }
            if t.numel() != spec.numel() {
                bail!(
                    "artifact {name} input {}: got {} elements, want {} {:?}",
                    spec.name,
                    t.numel(),
                    spec.numel(),
                    spec.shape
                );
            }
            match (&spec.dtype, t) {
                (
                    DType::F32,
                    TensorIn::F32(_) | TensorIn::SharedF32(_) | TensorIn::ScalarF32(_),
                ) => {}
                (
                    DType::I32,
                    TensorIn::I32(_) | TensorIn::SharedI32(_) | TensorIn::ScalarI32(_),
                ) => {}
                _ => bail!("artifact {name} input {}: dtype mismatch", spec.name),
            }
            pinned_slots.push(None);
            literals.push(Self::literal(&spec.shape, t)?);
        }
        self.stats.transfer_secs += t0.elapsed().as_secs_f64();

        let exe = self.cache.get(name).unwrap();
        let t1 = Instant::now();
        let result = {
            // interleave owned fresh literals with pinned references
            let mut refs: Vec<&xla::Literal> = Vec::with_capacity(inputs.len());
            let mut fresh_it = literals.iter();
            for slot in &pinned_slots {
                match slot {
                    Some(key) => refs.push(&self.pinned[key]),
                    None => refs.push(fresh_it.next().unwrap()),
                }
            }
            let bufs = exe.execute::<&xla::Literal>(&refs)?;
            bufs[0][0].to_literal_sync()?
        };
        self.stats.execute_secs += t1.elapsed().as_secs_f64();
        self.stats.executions += 1;

        let t2 = Instant::now();
        let parts = result.to_tuple()?;
        let meta = self.manifest.get(name)?;
        if parts.len() != meta.outputs.len() {
            bail!(
                "artifact {name}: {} outputs, expected {}",
                parts.len(),
                meta.outputs.len()
            );
        }
        let mut outs = Vec::with_capacity(parts.len());
        for p in parts {
            let ty = p.ty()?;
            outs.push(match ty {
                xla::ElementType::F32 => TensorOut::F32(p.to_vec::<f32>()?),
                xla::ElementType::S32 => TensorOut::I32(p.to_vec::<i32>()?),
                other => bail!("unsupported output element type {other:?}"),
            });
        }
        self.stats.transfer_secs += t2.elapsed().as_secs_f64();
        Ok(outs)
    }

    /// Number of compiled executables held.
    pub fn compiled_count(&self) -> usize {
        self.cache.len()
    }
}

/// `Backend` adapter over the PJRT executor.
///
/// The `xla` crate's client holds `Rc`/raw pointers, so `Executor` is
/// not auto-Send. The serving stack moves the *whole* backend into
/// exactly one worker thread and never touches it from another, which
/// makes the transfer sound: the non-Send internals are never aliased
/// across threads.
pub struct PjrtBackend {
    pub exec: Executor,
}

// SAFETY: see above — single-owner move, no cross-thread aliasing.
unsafe impl Send for PjrtBackend {}

impl PjrtBackend {
    pub fn new(exec: Executor) -> PjrtBackend {
        PjrtBackend { exec }
    }

    pub fn with_default_manifest() -> Result<PjrtBackend> {
        Ok(PjrtBackend { exec: Executor::with_default_manifest()? })
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn meta(&self, artifact: &str) -> Result<&ArtifactMeta> {
        self.exec.manifest.get(artifact)
    }

    fn artifact_names(&self) -> Vec<String> {
        self.exec.manifest.artifacts.keys().cloned().collect()
    }

    fn prepare(&mut self, artifact: &str) -> Result<()> {
        self.exec.prepare(artifact)
    }

    fn pin(&mut self, artifact: &str, input: &str, t: &TensorIn) -> Result<()> {
        self.exec.pin(artifact, input, t)
    }

    fn unpin_all(&mut self) {
        self.exec.unpin_all();
    }

    fn run(&mut self, artifact: &str, inputs: &[TensorIn]) -> Result<Vec<TensorOut>> {
        self.exec.run(artifact, inputs)
    }

    fn stats(&self) -> ExecStats {
        self.exec.stats.clone()
    }

    fn reset_stats(&mut self) {
        self.exec.stats = ExecStats::default();
    }

    fn cache_dir(&self) -> PathBuf {
        self.exec.manifest.dir.clone()
    }
}
