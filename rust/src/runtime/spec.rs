//! Rust-side artifact specifications: the same registry, layouts and
//! positional signatures that `python/compile/aot.py` lowers to HLO,
//! declared natively so the pure-Rust backend needs neither Python nor
//! an `artifacts/` directory. `python/compile/{model,methods,aot}.py`
//! remain the executable documentation; the shapes here MUST stay in
//! sync with them (the pjrt-gated manifest tests cross-check when
//! artifacts are present).

use super::artifact::{ArtifactMeta, DType, InputSpec, SegmentSpec};
use crate::config::ModelCfg;
use crate::projection::op;
use crate::projection::statics::{d_effective, theta_segments};
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::path::PathBuf;

/// Flat layout of the frozen backbone — mirror of model.base_segments.
pub fn base_segments(cfg: &ModelCfg) -> Vec<SegmentSpec> {
    let (h, f, v, t) = (cfg.hidden, cfg.ffn, cfg.vocab, cfg.seq);
    let seg = |name: String, shape: Vec<usize>, init: &str| SegmentSpec {
        name,
        shape,
        init: init.into(),
    };
    let mut out = vec![
        seg("tok_emb".into(), vec![v, h], "normal:0.02"),
        seg("pos_emb".into(), vec![t, h], "normal:0.02"),
    ];
    for l in 0..cfg.layers {
        out.push(seg(format!("ln1_g{l}"), vec![h], "ones"));
        out.push(seg(format!("ln1_b{l}"), vec![h], "zeros"));
        out.push(seg(format!("wq{l}"), vec![h, h], "normal:0.02"));
        out.push(seg(format!("wk{l}"), vec![h, h], "normal:0.02"));
        out.push(seg(format!("wv{l}"), vec![h, h], "normal:0.02"));
        out.push(seg(format!("wo{l}"), vec![h, h], "normal:0.02"));
        out.push(seg(format!("ln2_g{l}"), vec![h], "ones"));
        out.push(seg(format!("ln2_b{l}"), vec![h], "zeros"));
        out.push(seg(format!("w1{l}"), vec![h, f], "normal:0.02"));
        out.push(seg(format!("w2{l}"), vec![f, h], "normal:0.02"));
    }
    out.push(seg("lnf_g".into(), vec![h], "ones"));
    out.push(seg("lnf_b".into(), vec![h], "zeros"));
    out.push(seg("lm_head".into(), vec![h, v], "normal:0.02"));
    out
}

/// Total frozen-backbone parameter count — mirror of model.base_param_count.
pub fn base_param_count(cfg: &ModelCfg) -> usize {
    base_segments(cfg).iter().map(|s| s.numel()).sum()
}

/// Classification head parameter count — mirror of model.head_param_count.
pub fn head_param_count(cfg: &ModelCfg) -> usize {
    let c = cfg.n_classes.max(1);
    cfg.hidden * c + c
}

/// Frozen side-input signature — mirror of methods.statics_spec,
/// mapped from the `projection::op` registry's declared statics layout
/// (unknown methods have no statics, matching the historical
/// fall-through; `artifact_meta` rejects them via `cfg.validate` +
/// statics generation anyway).
pub fn statics_spec(cfg: &ModelCfg) -> Vec<InputSpec> {
    match op::resolve(&cfg.method) {
        Ok(proj) => proj
            .statics_spec(cfg)
            .into_iter()
            .map(|s| InputSpec {
                name: s.name.to_string(),
                dtype: if s.is_i32 { DType::I32 } else { DType::F32 },
                shape: s.shape,
            })
            .collect(),
        Err(_) => Vec::new(),
    }
}

/// Positional input signature + output order — mirror of aot.signature.
pub fn signature(cfg: &ModelCfg, kind: &str) -> Result<(Vec<InputSpec>, Vec<String>)> {
    let d = d_effective(cfg);
    let dh = head_param_count(cfg);
    let p = base_param_count(cfg);
    let (b, t) = (cfg.batch, cfg.seq);
    let lab_dt = if cfg.n_classes == 1 { DType::F32 } else { DType::I32 };
    let f32s = |name: &str, shape: Vec<usize>| InputSpec {
        name: name.into(),
        dtype: DType::F32,
        shape,
    };
    let i32s = |name: &str, shape: Vec<usize>| InputSpec {
        name: name.into(),
        dtype: DType::I32,
        shape,
    };
    let strs = |names: &[&str]| names.iter().map(|s| s.to_string()).collect::<Vec<_>>();
    let stat = statics_spec(cfg);
    Ok(match kind {
        "cls_train" => {
            let mut sig = vec![
                f32s("theta", vec![d]),
                f32s("m", vec![d]),
                f32s("v", vec![d]),
                f32s("head", vec![dh]),
                f32s("hm", vec![dh]),
                f32s("hv", vec![dh]),
                i32s("step", vec![]),
                f32s("lr_t", vec![]),
                f32s("lr_h", vec![]),
                f32s("wd", vec![]),
                f32s("w0", vec![p]),
                i32s("tokens", vec![b, t]),
                i32s("attn_len", vec![b]),
                InputSpec { name: "labels".into(), dtype: lab_dt, shape: vec![b] },
            ];
            sig.extend(stat);
            (sig, strs(&["theta", "m", "v", "head", "hm", "hv", "loss"]))
        }
        "cls_eval" => {
            let mut sig = vec![
                f32s("theta", vec![d]),
                f32s("head", vec![dh]),
                f32s("w0", vec![p]),
                i32s("tokens", vec![b, t]),
                i32s("attn_len", vec![b]),
            ];
            sig.extend(stat);
            (sig, strs(&["logits"]))
        }
        "lm_train" => {
            let mut sig = vec![
                f32s("theta", vec![d]),
                f32s("m", vec![d]),
                f32s("v", vec![d]),
                i32s("step", vec![]),
                f32s("lr_t", vec![]),
                f32s("wd", vec![]),
                f32s("w0", vec![p]),
                i32s("tokens", vec![b, t]),
                i32s("labels", vec![b, t]),
            ];
            sig.extend(stat);
            (sig, strs(&["theta", "m", "v", "loss"]))
        }
        "lm_logits" => {
            let mut sig = vec![
                f32s("theta", vec![d]),
                f32s("w0", vec![p]),
                i32s("tokens", vec![b, t]),
            ];
            sig.extend(stat);
            (sig, strs(&["logits"]))
        }
        "pretrain_lm" => (
            vec![
                f32s("w0", vec![p]),
                f32s("m", vec![p]),
                f32s("v", vec![p]),
                i32s("step", vec![]),
                f32s("lr", vec![]),
                f32s("wd", vec![]),
                i32s("tokens", vec![b, t]),
                i32s("labels", vec![b, t]),
            ],
            strs(&["w0", "m", "v", "loss"]),
        ),
        "full_cls_train" => (
            vec![
                f32s("w0", vec![p]),
                f32s("m", vec![p]),
                f32s("v", vec![p]),
                f32s("head", vec![dh]),
                f32s("hm", vec![dh]),
                f32s("hv", vec![dh]),
                i32s("step", vec![]),
                f32s("lr_t", vec![]),
                f32s("lr_h", vec![]),
                f32s("wd", vec![]),
                i32s("tokens", vec![b, t]),
                i32s("attn_len", vec![b]),
                InputSpec { name: "labels".into(), dtype: lab_dt, shape: vec![b] },
            ],
            strs(&["w0", "m", "v", "head", "hm", "hv", "loss"]),
        ),
        other => bail!("unknown artifact kind {other:?}"),
    })
}

/// Build the full metadata record for one (name, cfg, kind).
pub fn artifact_meta(name: &str, cfg: &ModelCfg, kind: &str) -> Result<ArtifactMeta> {
    cfg.validate()?;
    let (inputs, outputs) = signature(cfg, kind)?;
    let theta_segs = theta_segments(cfg)
        .into_iter()
        .map(|(n, shape, init)| SegmentSpec { name: n, shape, init })
        .collect();
    Ok(ArtifactMeta {
        name: name.to_string(),
        kind: kind.to_string(),
        cfg: cfg.clone(),
        d: d_effective(cfg),
        big_d: cfg.d_full(),
        base_params: base_param_count(cfg),
        head_params: head_param_count(cfg),
        theta_segments: theta_segs,
        base_segments: base_segments(cfg),
        inputs,
        outputs,
        hlo_path: PathBuf::from("native").join(format!("{name}.hlo.txt")),
    })
}

/// Methods in the GLUE suite (Table 2) — mirror of aot.GLUE_METHODS.
pub const GLUE_METHODS: [&str; 7] = ["lora", "vera", "tied", "vb", "lora_xs", "fourierft", "uni"];
/// Table 6/7 ablations — mirror of aot.ABLATION_METHODS.
pub const ABLATION_METHODS: [&str; 3] = ["local", "nonuniform", "fastfood"];
/// LM fine-tuning methods (Tables 3/4/12) — mirror of aot.LM_METHODS.
pub const LM_METHODS: [&str; 6] = ["lora", "vera", "vb", "lora_xs", "fourierft", "uni"];

/// The full artifact registry — mirror of aot.registry().
pub fn native_manifest() -> Result<BTreeMap<String, ArtifactMeta>> {
    fn add(
        arts: &mut BTreeMap<String, ArtifactMeta>,
        name: &str,
        cfg: &ModelCfg,
        kinds: &[&str],
    ) -> Result<()> {
        for k in kinds {
            let full = format!("{name}_{k}");
            arts.insert(full.clone(), artifact_meta(&full, cfg, k)?);
        }
        Ok(())
    }
    let mut arts = BTreeMap::new();

    // Table 2 (GLUE): 2 scales x 7 methods x {cls C=2, reg C=1}
    for size in [ModelCfg::base(), ModelCfg::large()] {
        for meth in GLUE_METHODS {
            for c in [2usize, 1] {
                let cfg = size.with_method(meth).with_classes(c);
                add(
                    &mut arts,
                    &format!("glue_{}_{meth}_c{c}", size.name),
                    &cfg,
                    &["cls_train", "cls_eval"],
                )?;
            }
        }
    }

    // Tables 6/7 ablations on the large backbone, classification head
    for meth in ABLATION_METHODS {
        let cfg = ModelCfg::large().with_method(meth).with_classes(2);
        add(&mut arts, &format!("glue_large_{meth}_c2"), &cfg, &["cls_train", "cls_eval"])?;
    }

    // Figure 3: d-sweep (uni, base backbone)
    for dv in [16usize, 64, 1024] {
        let cfg = ModelCfg::base().with_method("uni").with_classes(2).with_d(dv);
        add(&mut arts, &format!("fig3_base_uni_d{dv}"), &cfg, &["cls_train", "cls_eval"])?;
    }

    // Figure 4: rank sweep (uni, base backbone), d = 128 for all points
    for rv in [1usize, 2, 4, 8] {
        let cfg = ModelCfg::base().with_method("uni").with_classes(2).with_rank(rv).with_d(128);
        add(&mut arts, &format!("fig4_base_uni_r{rv}"), &cfg, &["cls_train", "cls_eval"])?;
    }

    // Tables 3/4/12: LM fine-tuning (math reasoning + instruction tuning)
    for meth in LM_METHODS {
        let cfg = ModelCfg::lm().with_method(meth);
        add(&mut arts, &format!("lm_{meth}"), &cfg, &["lm_train", "lm_logits"])?;
    }
    add(
        &mut arts,
        "lm_lora_r64",
        &ModelCfg::lm().with_method("lora").with_rank(64),
        &["lm_train", "lm_logits"],
    )?;
    for dv in [256usize, 4096] {
        add(
            &mut arts,
            &format!("fig3_lm_uni_d{dv}"),
            &ModelCfg::lm().with_method("uni").with_d(dv),
            &["lm_train", "lm_logits"],
        )?;
    }

    // Table 5 (vision): C=10 heads; LP = none, FF = full fine-tune
    for size in [ModelCfg::base(), ModelCfg::large()] {
        for meth in ["uni", "fourierft", "none"] {
            let cfg = size.with_method(meth).with_classes(10);
            add(&mut arts, &format!("vit_{}_{meth}", size.name), &cfg, &["cls_train", "cls_eval"])?;
        }
        let cfg = size.with_method("none").with_classes(10);
        add(&mut arts, &format!("vit_{}_full", size.name), &cfg, &["full_cls_train"])?;
    }

    // Pretraining (the in-system "foundation models") + e2e driver
    for size in [ModelCfg::base(), ModelCfg::large(), ModelCfg::lm(), ModelCfg::e2e()] {
        let cfg = size.with_method("none").with_classes(0);
        add(&mut arts, &format!("pretrain_{}", size.name), &cfg, &["pretrain_lm"])?;
    }
    add(&mut arts, "e2e_uni", &ModelCfg::e2e().with_method("uni"), &["lm_train", "lm_logits"])?;

    Ok(arts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_mirrors_aot_families() {
        let m = native_manifest().unwrap();
        // the python registry lowers > 100 artifacts; ours must match
        assert!(m.len() >= 100, "{}", m.len());
        for name in [
            "glue_base_uni_c2_cls_train",
            "glue_base_uni_c2_cls_eval",
            "glue_large_fastfood_c2_cls_train",
            "fig3_base_uni_d16_cls_train",
            "fig4_base_uni_r1_cls_eval",
            "lm_uni_lm_train",
            "lm_uni_lm_logits",
            "lm_lora_r64_lm_train",
            "vit_base_full_full_cls_train",
            "pretrain_lm_pretrain_lm",
            "e2e_uni_lm_train",
        ] {
            assert!(m.contains_key(name), "missing {name}");
        }
    }

    #[test]
    fn cls_train_signature_layout() {
        let m = native_manifest().unwrap();
        let a = m.get("glue_base_uni_c2_cls_train").unwrap();
        assert_eq!(a.cfg.method, "uni");
        assert_eq!(a.d, a.cfg.d);
        assert_eq!(a.big_d, a.cfg.d_full());
        assert_eq!(a.input_index("theta").unwrap(), 0);
        assert_eq!(a.input_index("w0").unwrap(), 10);
        let ti = a.input_index("tokens").unwrap();
        assert_eq!(a.inputs[ti].shape, vec![a.cfg.batch, a.cfg.seq]);
        // the final statics inputs are idx + nrm for uni
        let n = a.inputs.len();
        assert_eq!(a.inputs[n - 2].name, "idx");
        assert_eq!(a.inputs[n - 1].name, "nrm");
        assert_eq!(a.outputs.last().unwrap(), "loss");
        // theta segment total == d
        let total: usize = a.theta_segments.iter().map(|s| s.numel()).sum();
        assert_eq!(total.max(1), a.d);
    }

    #[test]
    fn statics_specs_match_generated_statics() {
        use crate::projection::statics::gen_statics;
        for meth in ["uni", "local", "nonuniform", "fastfood", "vera", "vb",
                     "lora_xs", "fourierft", "lora", "tied", "none"] {
            let cfg = ModelCfg::test_base(meth);
            let spec = statics_spec(&cfg);
            let gen = gen_statics(&cfg, 1).unwrap();
            assert_eq!(spec.len(), gen.len(), "{meth}");
            for (s, g) in spec.iter().zip(&gen) {
                assert_eq!(s.name, g.name, "{meth}");
                assert_eq!(s.numel(), g.len(), "{meth}/{}", s.name);
            }
        }
    }

    #[test]
    fn regression_label_dtype_is_f32() {
        let m = native_manifest().unwrap();
        let a = m.get("glue_base_uni_c1_cls_train").unwrap();
        let li = a.input_index("labels").unwrap();
        assert_eq!(a.inputs[li].dtype, DType::F32);
        let b = m.get("glue_base_uni_c2_cls_train").unwrap();
        let li = b.input_index("labels").unwrap();
        assert_eq!(b.inputs[li].dtype, DType::I32);
    }

    #[test]
    fn base_param_count_is_consistent() {
        let cfg = ModelCfg::base();
        let segs = base_segments(&cfg);
        assert_eq!(segs[0].name, "tok_emb");
        assert_eq!(segs.last().unwrap().name, "lm_head");
        let total: usize = segs.iter().map(|s| s.numel()).sum();
        assert_eq!(total, base_param_count(&cfg));
        // head: hidden * C + C
        assert_eq!(head_param_count(&cfg), 64 * 2 + 2);
        assert_eq!(head_param_count(&ModelCfg::lm()), 128 + 1);
    }
}
