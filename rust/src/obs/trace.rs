//! Per-request span tracing: a bounded in-memory ring of lifecycle
//! events, drainable over the wire (`trace` op) as JSONL and
//! optionally appended to a file (`UNI_LORA_TRACE=<path>`).
//!
//! Every router-visible milestone of a request — enqueue, admission,
//! prefill, each emitted token, each streamed frame, cancellation,
//! deadline expiry, injected faults, the terminal reply — records one
//! [`SpanEvent`] keyed by the request id the router assigned at
//! submit. A failing lifecycle-fuzz run is then reconstructable
//! per-request: filter the drained events by `req` and read the
//! timeline.
//!
//! Recording is observation-only by design: events capture ids,
//! counts and wall-clock micros, never logits or sampler state, so an
//! enabled tracer cannot perturb decode numerics (the parity suites
//! run with it on to prove it). The ring is bounded
//! (`UNI_LORA_TRACE_RING`, default [`crate::config::DEFAULT_TRACE_RING`];
//! `0` disables the ring) and drops oldest-first under pressure,
//! counting what it dropped.

use crate::util::json::{n, obj, s, Json};
use std::collections::VecDeque;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// One traced lifecycle milestone. Serialized as one JSONL object:
/// `{"ev":"step","n":42,"req":7,"slot":1,"t_us":1234}` — `slot`, `n`
/// and `note` appear only when meaningful for the event kind.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanEvent {
    /// Router-assigned request id (ids start at 1; 0 = unassigned).
    pub req: u64,
    /// Micros since the tracer's epoch (its construction instant) —
    /// relative so traces are comparable without wall-clock sync.
    pub t_us: u64,
    /// Event kind: `enqueue`, `reject`, `admit`, `requeue`, `fault`,
    /// `prefill`, `step`, `frame`, `deadline`, `cancel`, `replay`,
    /// `done`.
    pub ev: &'static str,
    /// Decode slot the sequence occupies, where one is bound.
    pub slot: Option<usize>,
    /// Event-kind-specific count: prompt length for `enqueue`/`admit`,
    /// the token id for `step`/`frame`, generated-token count for
    /// `deadline`/`done`.
    pub n: Option<i64>,
    /// Event-kind-specific annotation: the adapter for `enqueue`, the
    /// fault site for `fault`, the terminal error code (or `ok`) for
    /// `done`.
    pub note: Option<String>,
}

impl SpanEvent {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("ev", s(self.ev)),
            ("req", n(self.req as f64)),
            ("t_us", n(self.t_us as f64)),
        ];
        if let Some(slot) = self.slot {
            pairs.push(("slot", n(slot as f64)));
        }
        if let Some(v) = self.n {
            pairs.push(("n", n(v as f64)));
        }
        if let Some(note) = &self.note {
            pairs.push(("note", s(note)));
        }
        obj(pairs)
    }
}

/// The bounded event sink shared by the router and its workers. Cheap
/// enough to leave on: recording is one short mutex push per
/// milestone (milestones are per-token at worst, and a token costs a
/// full model forward).
pub struct Tracer {
    epoch: Instant,
    cap: usize,
    ring: Mutex<VecDeque<SpanEvent>>,
    file: Option<Mutex<File>>,
    dropped: AtomicU64,
}

impl std::fmt::Debug for Tracer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Tracer")
            .field("cap", &self.cap)
            .field("file", &self.file.is_some())
            .finish()
    }
}

impl Tracer {
    /// Ring-only tracer (no file sink) of the given capacity; `0`
    /// disables recording entirely.
    pub fn ring_only(cap: usize) -> Tracer {
        Tracer {
            epoch: Instant::now(),
            cap,
            ring: Mutex::new(VecDeque::new()),
            file: None,
            dropped: AtomicU64::new(0),
        }
    }

    /// Tracer from resolved config: ring capacity plus an optional
    /// JSONL append path. A path that cannot be opened warns and
    /// disables the file sink rather than failing the server — the
    /// same fail-safe contract as a malformed fault plan.
    pub fn from_cfg(cap: usize, path: Option<&str>) -> Tracer {
        let mut t = Tracer::ring_only(cap);
        if let Some(p) = path {
            match OpenOptions::new().create(true).append(true).open(p) {
                Ok(f) => t.file = Some(Mutex::new(f)),
                Err(e) => {
                    eprintln!(
                        "warning: UNI_LORA_TRACE={p:?} cannot be opened ({e}); \
                         tracing to the ring only"
                    );
                }
            }
        }
        t
    }

    /// Whether recording does anything at all (ring or file enabled).
    pub fn enabled(&self) -> bool {
        self.cap > 0 || self.file.is_some()
    }

    /// Record one milestone. Oldest events are evicted (and counted)
    /// once the ring is full; the file sink, when configured, gets
    /// every event regardless.
    pub fn rec(
        &self,
        req: u64,
        ev: &'static str,
        slot: Option<usize>,
        nv: Option<i64>,
        note: Option<&str>,
    ) {
        if !self.enabled() {
            return;
        }
        let event = SpanEvent {
            req,
            t_us: self.epoch.elapsed().as_micros() as u64,
            ev,
            slot,
            n: nv,
            note: note.map(str::to_string),
        };
        if let Some(f) = &self.file {
            let line = event.to_json().to_string();
            if let Ok(mut f) = f.lock() {
                let _ = writeln!(f, "{line}");
            }
        }
        if self.cap > 0 {
            let mut ring = self.ring.lock().unwrap_or_else(|p| p.into_inner());
            while ring.len() >= self.cap {
                ring.pop_front();
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
            ring.push_back(event);
        }
    }

    /// Take every ringed event, oldest first. Draining empties the
    /// ring (the `trace` op is a consuming read, so repeated drains
    /// see disjoint windows); the file sink is unaffected.
    pub fn drain(&self) -> Vec<SpanEvent> {
        let mut ring = self.ring.lock().unwrap_or_else(|p| p.into_inner());
        ring.drain(..).collect()
    }

    /// Events evicted from the ring before anyone drained them.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_records_in_order_and_bounds() {
        let t = Tracer::ring_only(3);
        assert!(t.enabled());
        t.rec(1, "enqueue", None, Some(4), Some("a"));
        t.rec(1, "admit", Some(0), Some(4), None);
        let evs = t.drain();
        assert_eq!(evs.len(), 2);
        assert_eq!((evs[0].req, evs[0].ev), (1, "enqueue"));
        assert_eq!(evs[1].slot, Some(0));
        assert!(evs[0].t_us <= evs[1].t_us, "timestamps must be monotone");
        assert!(t.drain().is_empty(), "drain consumes");

        // past capacity the oldest events fall out, counted
        for i in 0..5 {
            t.rec(i, "step", None, None, None);
        }
        let evs = t.drain();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].req, 2, "oldest evicted first");
        assert_eq!(t.dropped(), 2);
    }

    #[test]
    fn zero_capacity_disables() {
        let t = Tracer::ring_only(0);
        assert!(!t.enabled());
        t.rec(1, "enqueue", None, None, None);
        assert!(t.drain().is_empty());
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn span_event_json_shape() {
        let ev =
            SpanEvent { req: 7, t_us: 1234, ev: "step", slot: Some(1), n: Some(42), note: None };
        assert_eq!(
            ev.to_json().to_string(),
            r#"{"ev":"step","n":42,"req":7,"slot":1,"t_us":1234}"#
        );
        let done = SpanEvent {
            req: 7,
            t_us: 2000,
            ev: "done",
            slot: None,
            n: Some(3),
            note: Some("ok".into()),
        };
        assert_eq!(
            done.to_json().to_string(),
            r#"{"ev":"done","n":3,"note":"ok","req":7,"t_us":2000}"#
        );
    }

    #[test]
    fn bad_file_path_degrades_to_ring() {
        let t = Tracer::from_cfg(8, Some("/nonexistent-dir-xyz/trace.jsonl"));
        t.rec(1, "enqueue", None, None, None);
        assert_eq!(t.drain().len(), 1, "ring keeps working without the file sink");
    }

    #[test]
    fn file_sink_appends_jsonl() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("uni_lora_trace_test_{}.jsonl", std::process::id()));
        let p = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);
        {
            let t = Tracer::from_cfg(4, Some(&p));
            t.rec(1, "enqueue", None, Some(2), None);
            t.rec(1, "done", None, Some(0), Some("ok"));
        }
        let body = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = body.lines().collect();
        assert_eq!(lines.len(), 2);
        for l in &lines {
            let j = Json::parse(l).unwrap();
            assert_eq!(j.req("req").unwrap().as_usize().unwrap(), 1);
            assert!(j.req("t_us").is_ok());
        }
        assert_eq!(Json::parse(lines[1]).unwrap().req("note").unwrap().as_str().unwrap(), "ok");
        let _ = std::fs::remove_file(&path);
    }
}
