//! Observability subsystem: zero-dependency metrics, tracing and
//! profiling for the serving stack.
//!
//! Three legs, all observation-only (none may perturb decode
//! numerics — the parity suites run with everything enabled):
//!
//! - **Metrics** — [`hist`] fixed-bucket histograms with exact shard
//!   merge and bucket-derived quantiles (the primitive under the
//!   router's TTFT / queue-wait / latency / step-time / prompt-length
//!   distributions), rendered by [`registry`] as Prometheus text and
//!   served by the `metrics` protocol op.
//! - **Tracing** — [`trace`] per-request span timelines ([`Tracer`])
//!   in a bounded ring, drained by the `trace` op as JSONL and
//!   optionally appended to `UNI_LORA_TRACE=<path>`.
//! - **Profiling** — [`profile`] scoped decode-stage timers behind
//!   `UNI_LORA_PROFILE=1` (zero-cost when off, resolved once like
//!   the kernel vtable), surfaced in the metrics scrape.
//!
//! [`RouterStats`]: crate::server::RouterStats

pub mod hist;
pub mod profile;
pub mod registry;
pub mod trace;

pub use hist::Hist;
pub use registry::MetricsRegistry;
pub use trace::{SpanEvent, Tracer};
