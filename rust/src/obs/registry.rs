//! Prometheus text exposition over the crate's own metric primitives.
//!
//! [`MetricsRegistry`] is a render-time builder, not a store: the
//! server snapshots its [`RouterStats`] under the stats mutex, then
//! walks the snapshot through `counter`/`gauge`/`histogram` calls and
//! ships the rendered text. Keeping the registry stateless means there
//! is exactly one source of truth (the router's merged stats) and the
//! `stats` and `metrics` ops can never disagree.
//!
//! The output follows the Prometheus text exposition format (version
//! 0.0.4): `# HELP` / `# TYPE` headers, cumulative `le`-labeled
//! histogram buckets ending in `+Inf`, and `_sum` / `_count` series.
//!
//! [`RouterStats`]: crate::server::RouterStats

use super::hist::Hist;
use std::fmt::Write;

/// Builds one Prometheus text scrape. Metrics render in call order;
/// callers keep that order stable so scrapes diff cleanly.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    out: String,
}

/// Prometheus sample values: integers render bare (`17`, not `17.0`),
/// everything else uses shortest-roundtrip float formatting.
fn write_val(out: &mut String, v: f64) {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        let _ = write!(out, "{}", v as i64);
    } else {
        let _ = write!(out, "{v}");
    }
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    fn header(&mut self, name: &str, help: &str, kind: &str) {
        let _ = writeln!(self.out, "# HELP {name} {help}");
        let _ = writeln!(self.out, "# TYPE {name} {kind}");
    }

    fn sample(&mut self, name: &str, v: f64) {
        self.out.push_str(name);
        self.out.push(' ');
        write_val(&mut self.out, v);
        self.out.push('\n');
    }

    /// One unlabeled counter sample.
    pub fn counter(&mut self, name: &str, help: &str, v: f64) {
        self.header(name, help, "counter");
        self.sample(name, v);
    }

    /// One unlabeled gauge sample.
    pub fn gauge(&mut self, name: &str, help: &str, v: f64) {
        self.header(name, help, "gauge");
        self.sample(name, v);
    }

    /// A counter family over one label key: one header, one sample per
    /// `(label value, sample)` pair, in the given order.
    pub fn counter_vec(&mut self, name: &str, help: &str, key: &str, series: &[(&str, f64)]) {
        self.header(name, help, "counter");
        for (lv, v) in series {
            let _ = write!(self.out, "{name}{{{key}=\"{lv}\"}} ");
            write_val(&mut self.out, *v);
            self.out.push('\n');
        }
    }

    /// A full histogram: cumulative `le` buckets (ending `+Inf`),
    /// `_sum`, and `_count`.
    pub fn histogram(&mut self, name: &str, help: &str, h: &Hist) {
        self.header(name, help, "histogram");
        let mut cum = 0u64;
        for (i, &c) in h.counts().iter().enumerate() {
            cum += c;
            if i < h.bounds().len() {
                let b = h.bounds()[i];
                let _ = writeln!(self.out, "{name}_bucket{{le=\"{b}\"}} {cum}");
            } else {
                let _ = writeln!(self.out, "{name}_bucket{{le=\"+Inf\"}} {cum}");
            }
        }
        let _ = write!(self.out, "{name}_sum ");
        write_val(&mut self.out, h.sum());
        self.out.push('\n');
        let _ = writeln!(self.out, "{name}_count {}", h.count());
    }

    /// The rendered scrape.
    pub fn render(self) -> String {
        self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exposition_golden() {
        let mut h = Hist::with_bounds(&[0.0001, 0.0002, 0.0004]);
        h.observe(0.00005);
        h.observe(0.00015);
        h.observe(0.00015);
        h.observe(9.0);

        let mut reg = MetricsRegistry::new();
        reg.counter("t_requests_total", "requests accepted", 17.0);
        reg.gauge("t_kv_bytes", "resident kv bytes", 4096.0);
        reg.counter_vec(
            "t_profile_seconds_total",
            "per-stage seconds",
            "stage",
            &[("base_gemm", 1.5), ("attention", 0.25)],
        );
        reg.histogram("t_ttft_seconds", "time to first token", &h);

        let want = "\
# HELP t_requests_total requests accepted
# TYPE t_requests_total counter
t_requests_total 17
# HELP t_kv_bytes resident kv bytes
# TYPE t_kv_bytes gauge
t_kv_bytes 4096
# HELP t_profile_seconds_total per-stage seconds
# TYPE t_profile_seconds_total counter
t_profile_seconds_total{stage=\"base_gemm\"} 1.5
t_profile_seconds_total{stage=\"attention\"} 0.25
# HELP t_ttft_seconds time to first token
# TYPE t_ttft_seconds histogram
t_ttft_seconds_bucket{le=\"0.0001\"} 1
t_ttft_seconds_bucket{le=\"0.0002\"} 3
t_ttft_seconds_bucket{le=\"0.0004\"} 3
t_ttft_seconds_bucket{le=\"+Inf\"} 4
t_ttft_seconds_sum 9.00035
t_ttft_seconds_count 4
";
        assert_eq!(reg.render(), want);
    }

    #[test]
    fn latency_bounds_render_without_exponents() {
        let mut reg = MetricsRegistry::new();
        reg.histogram("t_lat_seconds", "latency", &Hist::latency());
        let text = reg.render();
        for line in text.lines().filter(|l| l.contains("_bucket{le=\"")) {
            let label = line.split('"').nth(1).unwrap();
            assert!(
                label == "+Inf" || label.chars().all(|c| c.is_ascii_digit() || c == '.'),
                "le label {label:?} must be a plain decimal"
            );
        }
        assert!(text.contains("le=\"0.0001\""));
        assert!(text.contains("le=\"26.2144\""));
        assert!(text.contains("le=\"+Inf\""));
    }
}
