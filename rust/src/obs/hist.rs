//! Fixed-bucket histograms: the deterministic primitive under every
//! latency/size distribution the server exports.
//!
//! Buckets are a fixed, compile-time bound set (log-spaced for
//! latencies, power-of-two for token counts), so merging shards is
//! exact integer addition — no sketch, no sampling, no dependence on
//! observation order or worker count. Two properties the serving tests
//! lean on:
//!
//! - **Merge is associative and commutative**: per-worker histograms
//!   folded in any grouping produce identical bucket counts, so stats
//!   are thread-count-invariant by construction.
//! - **Quantiles derive from bucket counts alone** (linear
//!   interpolation inside the containing bucket), so p50/p95/p99 are a
//!   pure function of the merged counts — deterministic across runs
//!   that observe the same values.

/// Log-spaced latency bounds, seconds: `0.1ms · 2^k` for k = 0..19.
/// Doubling keeps successive bounds exact in binary (each is the
/// previous mantissa with a bumped exponent), so the rendered `le`
/// labels stay short and stable. Covers 0.1 ms .. ~26 s; anything
/// slower lands in the overflow bucket.
pub const LATENCY_BOUNDS: [f64; 19] = [
    0.0001, 0.0002, 0.0004, 0.0008, 0.0016, 0.0032, 0.0064, 0.0128, 0.0256, 0.0512, 0.1024,
    0.2048, 0.4096, 0.8192, 1.6384, 3.2768, 6.5536, 13.1072, 26.2144,
];

/// Power-of-two token-count bounds: 1 .. 8192 positions.
pub const TOKEN_BOUNDS: [f64; 14] = [
    1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0, 1024.0, 2048.0, 4096.0, 8192.0,
];

/// One fixed-bucket histogram. `counts` has one slot per bound plus a
/// trailing overflow bucket; `sum`/`count` track the raw observations
/// so means stay exact even though individual values are bucketed.
#[derive(Debug, Clone, PartialEq)]
pub struct Hist {
    bounds: &'static [f64],
    counts: Vec<u64>,
    sum: f64,
    count: u64,
}

impl Hist {
    /// A histogram over an explicit bound set (ascending, non-empty).
    pub fn with_bounds(bounds: &'static [f64]) -> Hist {
        debug_assert!(!bounds.is_empty());
        debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds must ascend");
        Hist { bounds, counts: vec![0; bounds.len() + 1], sum: 0.0, count: 0 }
    }

    /// The standard latency histogram ([`LATENCY_BOUNDS`], seconds).
    pub fn latency() -> Hist {
        Hist::with_bounds(&LATENCY_BOUNDS)
    }

    /// The standard size histogram ([`TOKEN_BOUNDS`], token counts).
    pub fn tokens() -> Hist {
        Hist::with_bounds(&TOKEN_BOUNDS)
    }

    /// Record one observation. Values past the last bound land in the
    /// overflow bucket; negative values clamp into the first.
    pub fn observe(&mut self, v: f64) {
        let i = self.bounds.iter().position(|&b| v <= b).unwrap_or(self.bounds.len());
        self.counts[i] += 1;
        self.sum += v;
        self.count += 1;
    }

    /// Fold another shard in. Exact (integer bucket adds), so any
    /// merge order over any shard partition yields the same result.
    /// Both histograms must share a bound set.
    pub fn merge(&mut self, other: &Hist) {
        assert_eq!(self.bounds, other.bounds, "merging histograms with different bounds");
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.sum += other.sum;
        self.count += other.count;
    }

    /// Quantile estimate from bucket counts alone: find the bucket
    /// holding the rank-`q` observation and interpolate linearly
    /// inside it (the first bucket's lower edge is 0). Empty
    /// histograms report 0; ranks landing in the overflow bucket
    /// report the highest finite bound (the histogram cannot know
    /// more).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = (q.clamp(0.0, 1.0) * self.count as f64).max(1.0);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let prev = cum;
            cum += c;
            if (cum as f64) >= rank {
                if i == self.bounds.len() {
                    return *self.bounds.last().expect("bounds are non-empty");
                }
                let lo = if i == 0 { 0.0 } else { self.bounds[i - 1] };
                let hi = self.bounds[i];
                let frac = (rank - prev as f64) / c as f64;
                return lo + frac * (hi - lo);
            }
        }
        *self.bounds.last().expect("bounds are non-empty")
    }

    pub fn bounds(&self) -> &'static [f64] {
        self.bounds
    }

    /// Per-bucket counts (non-cumulative); the last entry is the
    /// overflow bucket.
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled(values: &[f64]) -> Hist {
        let mut h = Hist::latency();
        for &v in values {
            h.observe(v);
        }
        h
    }

    #[test]
    fn observe_buckets_and_totals() {
        let h = filled(&[0.00005, 0.0001, 0.00015, 1.0, 100.0]);
        // 0.00005 and 0.0001 share the first bucket (le = 0.0001)
        assert_eq!(h.counts()[0], 2);
        assert_eq!(h.counts()[1], 1); // 0.00015 <= 0.0002
        assert_eq!(*h.counts().last().unwrap(), 1, "100s lands in overflow");
        assert_eq!(h.count(), 5);
        assert!((h.sum() - 101.10025).abs() < 1e-9);
    }

    #[test]
    fn merge_is_associative_and_commutative() {
        let a = filled(&[0.001, 0.002, 5.0]);
        let b = filled(&[0.0001, 0.3]);
        let c = filled(&[40.0, 0.01]);

        let mut ab_c = a.clone();
        ab_c.merge(&b);
        ab_c.merge(&c);

        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);

        let mut cba = c.clone();
        cba.merge(&b);
        cba.merge(&a);

        assert_eq!(ab_c, a_bc, "merge grouping must not matter");
        assert_eq!(ab_c, cba, "merge order must not matter");
    }

    #[test]
    fn shard_merge_matches_single_shard() {
        // the same observations split across N worker shards merge to
        // exactly the single-shard histogram, for any N
        let values: Vec<f64> = (0..100).map(|i| 0.0001 * (i as f64 + 0.5)).collect();
        let single = {
            let mut h = Hist::latency();
            for &v in &values {
                h.observe(v);
            }
            h
        };
        for shards in [1usize, 2, 3, 7] {
            let mut parts: Vec<Hist> = (0..shards).map(|_| Hist::latency()).collect();
            for (i, &v) in values.iter().enumerate() {
                parts[i % shards].observe(v);
            }
            let mut merged = Hist::latency();
            for p in &parts {
                merged.merge(p);
            }
            assert_eq!(merged, single, "{shards}-way shard merge diverged");
        }
    }

    #[test]
    fn quantile_edge_cases() {
        // empty: no data, report 0
        assert_eq!(Hist::latency().quantile(0.5), 0.0);
        assert_eq!(Hist::latency().quantile(0.99), 0.0);

        // single bucket: all mass in one bucket interpolates inside it
        let mut h = Hist::latency();
        for _ in 0..10 {
            h.observe(0.15); // bucket (0.1024, 0.2048]
        }
        let p50 = h.quantile(0.5);
        assert!(p50 > 0.1024 && p50 <= 0.2048, "p50 {p50} outside its bucket");
        assert!(h.quantile(0.1) < h.quantile(0.9), "interpolation must be monotone");

        // first bucket interpolates from 0
        let mut h = Hist::latency();
        h.observe(0.00005);
        let q = h.quantile(0.5);
        assert!(q > 0.0 && q <= 0.0001, "first-bucket quantile {q}");

        // overflow bucket saturates at the highest finite bound
        let mut h = Hist::latency();
        h.observe(1e9);
        assert_eq!(h.quantile(0.99), *LATENCY_BOUNDS.last().unwrap());

        // single observation: every quantile lands in its bucket
        let mut h = Hist::latency();
        h.observe(0.003);
        for q in [0.0, 0.5, 0.99, 1.0] {
            let v = h.quantile(q);
            assert!(v > 0.0016 && v <= 0.0032, "q={q} gave {v}");
        }
    }

    #[test]
    fn quantiles_order_and_bracket() {
        let mut h = Hist::latency();
        for i in 1..=1000 {
            h.observe(i as f64 * 0.001); // 1ms .. 1s uniform
        }
        let (p50, p95, p99) = (h.quantile(0.5), h.quantile(0.95), h.quantile(0.99));
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        // true p50 = 0.5s: bucketed estimate must land in its bucket
        assert!(p50 > 0.4096 && p50 <= 0.8192, "p50 {p50}");
        assert!(p99 > 0.8192 && p99 <= 1.6384, "p99 {p99}");
    }

    #[test]
    fn token_bounds_cover_counts() {
        let mut h = Hist::tokens();
        h.observe(1.0);
        h.observe(3.0);
        h.observe(8192.0);
        h.observe(9000.0);
        assert_eq!(h.counts()[0], 1);
        assert_eq!(h.counts()[2], 1); // 3 <= 4
        assert_eq!(h.counts()[13], 1); // 8192 is the last finite bound
        assert_eq!(*h.counts().last().unwrap(), 1);
    }
}
