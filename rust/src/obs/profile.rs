//! Decode profiling hooks: scoped wall-clock timers attributing fused
//! decode-step time to its stages (base GEMM, factored rank-r apply,
//! dense grouped GEMV, attention, logits, sampling, prefill).
//!
//! Off by default and resolved ONCE from `UNI_LORA_PROFILE` — the
//! same latch-on-first-use scheme as the kernel vtable
//! (`kernels::dispatch::ops`) — so the disabled cost of a hook is one
//! relaxed atomic load and a branch, paid a handful of times per
//! decode step next to whole-layer GEMMs. Timers never touch the data
//! path (they read the clock, not the tensors), so enabling profiling
//! cannot perturb decode numerics; the parity suites run with it on
//! to hold that line.
//!
//! Accumulation is process-global: relaxed `fetch_add` of elapsed
//! nanos and call counts per stage, exact under any worker
//! interleaving (integer adds commute). The server surfaces
//! [`snapshot`] as the `unilora_profile_*` section of the `metrics`
//! scrape.

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::time::Instant;

pub const STAGE_BASE_GEMM: usize = 0;
pub const STAGE_FACTORED_APPLY: usize = 1;
pub const STAGE_DENSE_GEMV: usize = 2;
pub const STAGE_ATTENTION: usize = 3;
pub const STAGE_LOGITS: usize = 4;
pub const STAGE_SAMPLING: usize = 5;
pub const STAGE_PREFILL: usize = 6;

/// Stage labels, indexed by the `STAGE_*` constants; these are the
/// stable `stage` label values of `unilora_profile_seconds_total`.
pub const STAGE_NAMES: [&str; 7] =
    ["base_gemm", "factored_apply", "dense_gemv", "attention", "logits", "sampling", "prefill"];

const STATE_UNSET: u8 = 0xff;

/// 0 = off, 1 = on, `STATE_UNSET` = not yet resolved from the env.
static STATE: AtomicU8 = AtomicU8::new(STATE_UNSET);

static NANOS: [AtomicU64; 7] = [
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
];

static CALLS: [AtomicU64; 7] = [
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
];

/// Whether profiling is on. First call resolves `UNI_LORA_PROFILE`
/// and latches the answer (the dispatch-vtable pattern): later env
/// changes are ignored, so the hot path never re-reads the
/// environment.
pub fn enabled() -> bool {
    let mut s = STATE.load(Ordering::Relaxed);
    if s == STATE_UNSET {
        s = u8::from(crate::config::parse_profile(
            std::env::var("UNI_LORA_PROFILE").ok().as_deref(),
        ));
        STATE.store(s, Ordering::Relaxed);
    }
    s == 1
}

/// Pin profiling on or off, overriding the env latch (tests, benches;
/// single-flow callers only — the same caveat as
/// `kernels::dispatch::set_choice`).
pub fn set_enabled(on: bool) {
    STATE.store(u8::from(on), Ordering::Relaxed);
}

/// RAII stage timer: created cheap when profiling is off (no clock
/// read), accumulates elapsed nanos + one call on drop when on. Bind
/// it (`let _p = profile::stage(...)`) — an unbound guard drops
/// immediately and times nothing.
pub struct ScopedStage {
    start: Option<(usize, Instant)>,
}

/// Open a scoped timer for `STAGE_*` index `idx`.
#[inline]
pub fn stage(idx: usize) -> ScopedStage {
    ScopedStage { start: enabled().then(|| (idx, Instant::now())) }
}

impl Drop for ScopedStage {
    fn drop(&mut self) {
        if let Some((idx, t0)) = self.start.take() {
            NANOS[idx].fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            CALLS[idx].fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Per-stage `(label, seconds, calls)` in stage-index order.
pub fn snapshot() -> Vec<(&'static str, f64, u64)> {
    (0..STAGE_NAMES.len())
        .map(|i| {
            (
                STAGE_NAMES[i],
                NANOS[i].load(Ordering::Relaxed) as f64 * 1e-9,
                CALLS[i].load(Ordering::Relaxed),
            )
        })
        .collect()
}

/// Zero every accumulator (tests; the counters are otherwise
/// monotone for the life of the process).
pub fn reset() {
    for i in 0..STAGE_NAMES.len() {
        NANOS[i].store(0, Ordering::Relaxed);
        CALLS[i].store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One test owns the global profile state end to end — parallel
    /// sub-tests poking `set_enabled` would race each other, so the
    /// scenarios run sequentially here.
    #[test]
    fn profile_accumulates_only_when_enabled() {
        set_enabled(false);
        reset();
        {
            let _p = stage(STAGE_ATTENTION);
            std::hint::black_box(1 + 1);
        }
        let snap = snapshot();
        assert_eq!(snap[STAGE_ATTENTION].2, 0, "disabled hooks must not count");

        set_enabled(true);
        {
            let _p = stage(STAGE_ATTENTION);
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        {
            let _p = stage(STAGE_LOGITS);
        }
        let snap = snapshot();
        assert_eq!(snap[STAGE_ATTENTION].0, "attention");
        assert_eq!(snap[STAGE_ATTENTION].2, 1);
        assert!(snap[STAGE_ATTENTION].1 > 0.0, "elapsed time must accumulate");
        assert_eq!(snap[STAGE_LOGITS].2, 1);
        assert_eq!(snap[STAGE_BASE_GEMM].2, 0);

        // counts merge exactly across threads (relaxed adds commute)
        reset();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                std::thread::spawn(|| {
                    for _ in 0..25 {
                        let _p = stage(STAGE_BASE_GEMM);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(snapshot()[STAGE_BASE_GEMM].2, 100);

        set_enabled(false);
        reset();
    }
}
