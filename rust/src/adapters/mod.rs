//! Adapter checkpoints and registry — the paper's §3.4 storage claim
//! made concrete: a trained adapter is stored as *seed + theta_d*
//! (d+1 numbers) and everything else (projection indices, norms, frozen
//! bases) is regenerated from the seed at load time.

pub mod checkpoint;
pub mod registry;

pub use checkpoint::AdapterCheckpoint;
pub use registry::Registry;
