//! `UNI1` adapter file format.
//!
//! Layout (little-endian):
//!   magic   b"UNI1"
//!   u32     version (1)
//!   u64     seed
//!   u32     method name length, then UTF-8 method name
//!   u32     artifact name length, then UTF-8 artifact name
//!   u32     d  (theta length)
//!   u32     head length (0 for LM adapters)
//!   f32*d   theta
//!   f32*h   head
//!
//! For Uni-LoRA the payload really is "one vector plus a seed": the
//! projection (idx, nrm) is regenerated via projection::statics. The
//! same container stores every baseline method's theta, which is what
//! makes the Table-2 storage comparison a one-liner.

use crate::config::ModelCfg;
use crate::projection::reconstruct::{reconstruct, ModuleDelta};
use anyhow::{bail, Context, Result};
use std::io::Read;
use std::path::Path;

const MAGIC: &[u8; 4] = b"UNI1";

#[derive(Debug, Clone, PartialEq)]
pub struct AdapterCheckpoint {
    pub seed: u64,
    pub method: String,
    /// eval artifact this adapter pairs with (binds the ModelCfg)
    pub artifact: String,
    pub theta: Vec<f32>,
    pub head: Vec<f32>,
}

impl AdapterCheckpoint {
    pub fn d(&self) -> usize {
        self.theta.len()
    }

    /// Serialized size in bytes — asserted small in tests (§3.4).
    pub fn byte_size(&self) -> usize {
        4 + 4 + 8 + 4 + self.method.len() + 4 + self.artifact.len() + 4 + 4
            + 4 * self.theta.len()
            + 4 * self.head.len()
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut w = Vec::with_capacity(self.byte_size());
        w.extend_from_slice(MAGIC);
        w.extend_from_slice(&1u32.to_le_bytes());
        w.extend_from_slice(&self.seed.to_le_bytes());
        w.extend_from_slice(&(self.method.len() as u32).to_le_bytes());
        w.extend_from_slice(self.method.as_bytes());
        w.extend_from_slice(&(self.artifact.len() as u32).to_le_bytes());
        w.extend_from_slice(self.artifact.as_bytes());
        w.extend_from_slice(&(self.theta.len() as u32).to_le_bytes());
        w.extend_from_slice(&(self.head.len() as u32).to_le_bytes());
        for x in &self.theta {
            w.extend_from_slice(&x.to_le_bytes());
        }
        for x in &self.head {
            w.extend_from_slice(&x.to_le_bytes());
        }
        std::fs::write(path.as_ref(), w)
            .with_context(|| format!("writing adapter {:?}", path.as_ref()))
    }

    pub fn load(path: impl AsRef<Path>) -> Result<AdapterCheckpoint> {
        let mut f = std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening adapter {:?}", path.as_ref()))?;
        let mut buf = Vec::new();
        f.read_to_end(&mut buf)?;
        Self::from_bytes(&buf)
    }

    pub fn from_bytes(buf: &[u8]) -> Result<AdapterCheckpoint> {
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if *pos + n > buf.len() {
                bail!("truncated adapter file");
            }
            let s = &buf[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        if take(&mut pos, 4)? != MAGIC {
            bail!("bad magic (not a UNI1 adapter)");
        }
        let ver = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?);
        if ver != 1 {
            bail!("unsupported adapter version {ver}");
        }
        let seed = u64::from_le_bytes(take(&mut pos, 8)?.try_into()?);
        let mlen = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
        let method = String::from_utf8(take(&mut pos, mlen)?.to_vec())?;
        let alen = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
        let artifact = String::from_utf8(take(&mut pos, alen)?.to_vec())?;
        let d = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
        let h = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
        let mut theta = Vec::with_capacity(d);
        for _ in 0..d {
            theta.push(f32::from_le_bytes(take(&mut pos, 4)?.try_into()?));
        }
        let mut head = Vec::with_capacity(h);
        for _ in 0..h {
            head.push(f32::from_le_bytes(take(&mut pos, 4)?.try_into()?));
        }
        if pos != buf.len() {
            bail!("trailing bytes in adapter file");
        }
        Ok(AdapterCheckpoint { seed, method, artifact, theta, head })
    }

    /// Expand to per-module weight increments (self-contained: only the
    /// checkpoint + cfg are needed, no artifacts, no Python).
    pub fn expand(&self, cfg: &ModelCfg) -> Result<Vec<ModuleDelta>> {
        reconstruct(cfg, self.seed, &self.theta)
    }

    /// Merge into dense per-module weights: W_i = W0_i + scale * DeltaW_i.
    pub fn merge_into(&self, cfg: &ModelCfg, w0_modules: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let deltas = self.expand(cfg)?;
        if deltas.len() != w0_modules.len() {
            bail!("module count mismatch");
        }
        Ok(deltas
            .iter()
            .zip(w0_modules)
            .map(|(d, w)| {
                let dw = d.to_dense(cfg.hidden, cfg.rank);
                w.iter()
                    .zip(dw.iter())
                    .map(|(a, b)| a + cfg.scale * b)
                    .collect()
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projection::statics::init_theta;

    fn ckpt() -> AdapterCheckpoint {
        let cfg = ModelCfg::test_base("uni");
        AdapterCheckpoint {
            seed: 42,
            method: "uni".into(),
            artifact: "glue_base_uni_c2_cls_eval".into(),
            theta: init_theta(&cfg, 42).unwrap(),
            head: vec![0.5; 130],
        }
    }

    #[test]
    fn roundtrip_bytes() {
        let c = ckpt();
        let tmp = std::env::temp_dir().join("unilora_test_adapter.uni1");
        c.save(&tmp).unwrap();
        let back = AdapterCheckpoint::load(&tmp).unwrap();
        assert_eq!(c, back);
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn storage_is_d_plus_seed_sized() {
        // §3.4: ~ d+1 numbers. Allow a small fixed header + the head.
        let c = ckpt();
        let payload = 4 * (c.theta.len() + c.head.len());
        assert!(c.byte_size() <= payload + 128, "{}", c.byte_size());
    }

    #[test]
    fn rejects_corrupt() {
        let c = ckpt();
        let tmp = std::env::temp_dir().join("unilora_test_corrupt.uni1");
        c.save(&tmp).unwrap();
        let mut bytes = std::fs::read(&tmp).unwrap();
        bytes[0] = b'X';
        assert!(AdapterCheckpoint::from_bytes(&bytes).is_err());
        let truncated = &std::fs::read(&tmp).unwrap()[..20];
        assert!(AdapterCheckpoint::from_bytes(truncated).is_err());
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn expand_is_deterministic_from_seed() {
        let cfg = ModelCfg::test_base("uni");
        let c = ckpt();
        let d1 = c.expand(&cfg).unwrap();
        let d2 = c.expand(&cfg).unwrap();
        let a1 = d1[0].to_dense(cfg.hidden, cfg.rank);
        let a2 = d2[0].to_dense(cfg.hidden, cfg.rank);
        assert_eq!(a1, a2);
        assert!(a1.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn merge_adds_scaled_delta() {
        let cfg = ModelCfg::test_base("uni");
        let c = ckpt();
        let w0: Vec<Vec<f32>> =
            (0..cfg.n_modules()).map(|_| vec![1.0; cfg.hidden * cfg.hidden]).collect();
        let merged = c.merge_into(&cfg, &w0).unwrap();
        let deltas = c.expand(&cfg).unwrap();
        let dw = deltas[0].to_dense(cfg.hidden, cfg.rank);
        for (m, d) in merged[0].iter().zip(dw.iter()) {
            assert!((m - (1.0 + cfg.scale * d)).abs() < 1e-6);
        }
    }
}
