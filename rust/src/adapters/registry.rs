//! In-memory + on-disk adapter registry for the multi-adapter server.
//! Adapters are tiny (seed + one vector), so the registry keeps every
//! loaded adapter resident — the deployment story the paper's storage
//! complexity enables. Under factored serving the theta vectors ARE
//! the unit of residency: a registered adapter costs its `d` floats
//! here plus transient rank-r factors per active slot, and only the
//! few adapters the session cost model densifies ever occupy
//! `2 * layers * h^2`-float reconstructions (in the `ReconCache`,
//! not here).

use super::checkpoint::AdapterCheckpoint;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::RwLock;

#[derive(Default)]
pub struct Registry {
    inner: RwLock<BTreeMap<String, AdapterCheckpoint>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Load every *.uni1 file in a directory; adapter name = file stem.
    ///
    /// A missing directory yields an empty registry (serving with no
    /// pre-loaded adapters is a normal deployment). Any OTHER I/O
    /// failure — the path exists but is not a directory, permissions,
    /// an entry that cannot be statted mid-iteration — propagates:
    /// silently serving an empty registry from an unreadable directory
    /// is how adapters "disappear" in production.
    pub fn load_dir(dir: impl AsRef<Path>) -> Result<Registry> {
        let dir = dir.as_ref();
        let reg = Registry::new();
        let rd = match std::fs::read_dir(dir) {
            Ok(rd) => rd,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(reg),
            Err(e) => {
                return Err(anyhow!("reading adapter dir {dir:?}: {e}"));
            }
        };
        for entry in rd {
            let entry = entry.with_context(|| format!("reading adapter dir {dir:?}"))?;
            let p: PathBuf = entry.path();
            if p.extension().map(|e| e == "uni1").unwrap_or(false) {
                let name = p
                    .file_stem()
                    .and_then(|s| s.to_str())
                    .ok_or_else(|| anyhow!("bad adapter filename {p:?}"))?
                    .to_string();
                reg.insert(name, AdapterCheckpoint::load(&p)?);
            }
        }
        Ok(reg)
    }

    pub fn insert(&self, name: String, ckpt: AdapterCheckpoint) {
        self.inner.write().unwrap().insert(name, ckpt);
    }

    pub fn get(&self, name: &str) -> Option<AdapterCheckpoint> {
        self.inner.read().unwrap().get(name).cloned()
    }

    pub fn names(&self) -> Vec<String> {
        self.inner.read().unwrap().keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.inner.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total resident bytes across all adapters — the multi-tenant
    /// footprint number for the serving bench.
    pub fn resident_bytes(&self) -> usize {
        self.inner.read().unwrap().values().map(|c| c.byte_size()).sum()
    }

    /// Bytes held by the theta vectors alone — the factored-serving
    /// residency unit (the multi-tenancy acceptance test budgets
    /// `theta_bytes + ReconCache::resident_bytes` against a handful of
    /// dense reconstructions).
    pub fn theta_bytes(&self) -> usize {
        let m = self.inner.read().unwrap();
        m.values().map(|c| c.theta.len() * std::mem::size_of::<f32>()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ckpt(seed: u64) -> AdapterCheckpoint {
        AdapterCheckpoint {
            seed,
            method: "uni".into(),
            artifact: "a".into(),
            theta: vec![seed as f32; 16],
            head: vec![],
        }
    }

    #[test]
    fn insert_get_names() {
        let r = Registry::new();
        r.insert("x".into(), ckpt(1));
        r.insert("y".into(), ckpt(2));
        assert_eq!(r.len(), 2);
        assert_eq!(r.get("x").unwrap().seed, 1);
        assert!(r.get("z").is_none());
        assert_eq!(r.names(), vec!["x", "y"]);
    }

    #[test]
    fn dir_roundtrip() {
        let dir = std::env::temp_dir().join("unilora_registry_test");
        std::fs::create_dir_all(&dir).unwrap();
        ckpt(7).save(dir.join("seven.uni1")).unwrap();
        ckpt(8).save(dir.join("eight.uni1")).unwrap();
        std::fs::write(dir.join("ignore.txt"), b"x").unwrap();
        let r = Registry::load_dir(&dir).unwrap();
        assert_eq!(r.len(), 2);
        assert_eq!(r.get("seven").unwrap().seed, 7);
        assert!(r.resident_bytes() > 0);
        // two 16-float thetas; theta_bytes counts exactly those
        assert_eq!(r.theta_bytes(), 2 * 16 * std::mem::size_of::<f32>());
        assert!(r.theta_bytes() <= r.resident_bytes());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_dir_is_empty() {
        let r = Registry::load_dir("/no/such/dir/unilora").unwrap();
        assert!(r.is_empty());
    }

    /// Satellite regression: a path that exists but cannot be iterated
    /// must ERROR, not silently yield an empty registry (the old
    /// `if let Ok(rd)` swallowed everything but missing-dir).
    #[test]
    fn unreadable_dir_errors_instead_of_empty() {
        let f = std::env::temp_dir().join("unilora_registry_not_a_dir");
        std::fs::write(&f, b"i am a file, not a directory").unwrap();
        let err = Registry::load_dir(&f).unwrap_err().to_string();
        assert!(err.contains("adapter dir"), "{err}");
        std::fs::remove_file(&f).ok();
    }

    /// A corrupt adapter file inside the directory also propagates.
    #[test]
    fn corrupt_adapter_file_errors() {
        let dir = std::env::temp_dir().join("unilora_registry_corrupt_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("bad.uni1"), b"not an adapter").unwrap();
        assert!(Registry::load_dir(&dir).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
