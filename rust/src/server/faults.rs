//! Seeded fault injection for the serving stack.
//!
//! `UNI_LORA_FAULTS=<seed>:<site>=<rate>[@ms][,<site>=<rate>...]`
//! deterministically injects failures into the request lifecycle so
//! the recovery paths (session reopen + replay, requeue-at-head,
//! cancellation, drain-with-errors) are exercised by replayable tests
//! instead of contrived backends. Sites:
//!
//! - `step`  — a decode step fails; the worker reopens the session and
//!   replays the in-flight sequences (decode is deterministic, so the
//!   re-derived streams match and already-delivered tokens are
//!   suppressed).
//! - `admit` — an admission attempt reports transient resource
//!   pressure; the request is requeued and retried.
//! - `slow`  — a decode step sleeps `@ms` first (default
//!   [`DEFAULT_SLOW_MS`]), forcing deadline/drain interleavings.
//! - `frame` — a streamed frame write "fails", standing in for a
//!   client that disconnected mid-stream; the sequence is cancelled.
//!
//! Rates are probabilities in `[0, 1]` evaluated per decision point.
//! All injected faults are recoverable by design: under any plan the
//! server still gives every request exactly one terminal reply (the
//! exception is `step` at rate 1.0, where every step fails and no
//! sequence can ever progress).
//!
//! Each site draws from its own counter-based SplitMix64 stream
//! ([`crate::rng::value`] over [`crate::rng::child_seed`]), so the
//! decision sequence depends only on the seed and the number of prior
//! decisions at that site — single-worker runs replay bit-identically.
//! With several workers sharing the plan the per-site counters
//! interleave across threads; the fault mix stays seeded but the
//! assignment of faults to requests is no longer reproducible.
//!
//! Off by default and zero-cost when disabled: every hook is a
//! [`Faults::fire`] call that returns after one branch on a plain
//! bool.

use crate::rng;
use anyhow::{anyhow, bail, ensure, Result};
use std::sync::atomic::{AtomicU64, Ordering};

/// Site index: a decode step fails.
pub const SITE_STEP: usize = 0;
/// Site index: an admission attempt reports transient pressure.
pub const SITE_ADMIT: usize = 1;
/// Site index: a decode step is delayed by `slow_ms`.
pub const SITE_SLOW: usize = 2;
/// Site index: a streamed frame write fails (client gone).
pub const SITE_FRAME: usize = 3;
const N_SITES: usize = 4;
const SITE_NAMES: [&str; N_SITES] = ["step", "admit", "slow", "frame"];

/// Default injected latency for `slow` faults, milliseconds. Small on
/// purpose: big enough to reorder step boundaries against deadlines,
/// small enough that fault-lane CI runs stay fast. Override per-plan
/// with `slow=<rate>@<ms>`.
pub const DEFAULT_SLOW_MS: u64 = 2;

/// A parsed fault plan. Shared read-only across workers; the per-site
/// draw counters are atomics so `fire` takes `&self`.
#[derive(Debug)]
pub struct Faults {
    enabled: bool,
    rates: [f64; N_SITES],
    seeds: [u64; N_SITES],
    draws: [AtomicU64; N_SITES],
    injected: AtomicU64,
    slow_ms: u64,
}

impl Faults {
    /// The no-faults plan: every `fire` is false after one branch.
    pub fn off() -> Faults {
        Faults {
            enabled: false,
            rates: [0.0; N_SITES],
            seeds: [0; N_SITES],
            draws: std::array::from_fn(|_| AtomicU64::new(0)),
            injected: AtomicU64::new(0),
            slow_ms: DEFAULT_SLOW_MS,
        }
    }

    /// Parse `<seed>:<site>=<rate>[@ms][,...]`. Strict: unknown sites,
    /// out-of-range rates and misplaced `@ms` are errors — a typo'd
    /// fault plan silently not injecting would make a red test green.
    pub fn parse(spec: &str) -> Result<Faults> {
        let (seed_s, plan) = spec
            .split_once(':')
            .ok_or_else(|| anyhow!("want <seed>:<site>=<rate>[@ms],..., got {spec:?}"))?;
        let seed: u64 = seed_s
            .trim()
            .parse()
            .map_err(|_| anyhow!("fault seed must be a non-negative integer, got {seed_s:?}"))?;
        let mut rates = [0.0f64; N_SITES];
        let mut slow_ms = DEFAULT_SLOW_MS;
        for part in plan.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (name, val) = part
                .split_once('=')
                .ok_or_else(|| anyhow!("want <site>=<rate>, got {part:?}"))?;
            let site = SITE_NAMES
                .iter()
                .position(|&n| n == name.trim())
                .ok_or_else(|| anyhow!("unknown fault site {:?} (want step|admit|slow|frame)", name.trim()))?;
            let (rate_s, ms_s) = match val.split_once('@') {
                Some((r, m)) => (r, Some(m)),
                None => (val, None),
            };
            let rate: f64 = rate_s
                .trim()
                .parse()
                .map_err(|_| anyhow!("fault rate must be a number, got {rate_s:?}"))?;
            ensure!(
                rate.is_finite() && (0.0..=1.0).contains(&rate),
                "fault rate must be in [0, 1], got {rate}"
            );
            if let Some(ms) = ms_s {
                if site != SITE_SLOW {
                    bail!("@ms only applies to the slow site, got {part:?}");
                }
                slow_ms = ms
                    .trim()
                    .parse()
                    .map_err(|_| anyhow!("slow @ms must be a non-negative integer, got {ms:?}"))?;
            }
            rates[site] = rate;
        }
        Ok(Faults {
            enabled: rates.iter().any(|&r| r > 0.0),
            rates,
            // one independent child stream per site, so changing one
            // site's rate never shifts another site's decision sequence
            seeds: std::array::from_fn(|i| rng::child_seed(seed, 0xFA00 + i as u64)),
            draws: std::array::from_fn(|_| AtomicU64::new(0)),
            injected: AtomicU64::new(0),
            slow_ms,
        })
    }

    /// The `UNI_LORA_FAULTS` plan; unset/empty = off. A malformed spec
    /// warns and disables injection (fail-safe: a production server
    /// must not crash — or inject — over a typo'd debug knob).
    pub fn from_env() -> Faults {
        match std::env::var("UNI_LORA_FAULTS") {
            Err(_) => Faults::off(),
            Ok(s) if s.trim().is_empty() => Faults::off(),
            Ok(s) => Faults::parse(&s).unwrap_or_else(|e| {
                eprintln!("warning: UNI_LORA_FAULTS: {e}; fault injection disabled");
                Faults::off()
            }),
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// One seeded decision at `site`: true = inject. Consumes one draw
    /// from the site's counter stream iff the plan is enabled and the
    /// site's rate is positive, so disabled sites never perturb the
    /// sequence of enabled ones.
    #[inline]
    pub fn fire(&self, site: usize) -> bool {
        if !self.enabled {
            return false;
        }
        let rate = self.rates[site];
        if rate <= 0.0 {
            return false;
        }
        let i = self.draws[site].fetch_add(1, Ordering::Relaxed);
        // top 53 bits -> uniform f64 in [0, 1)
        let u = (rng::value(self.seeds[site], i) >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        let hit = u < rate;
        if hit {
            self.injected.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Total decisions that injected a fault, across all sites.
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    /// Injected latency for `slow` faults, milliseconds.
    pub fn slow_ms(&self) -> u64 {
        self.slow_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_never_fires() {
        let f = Faults::off();
        assert!(!f.enabled());
        for site in 0..N_SITES {
            for _ in 0..50 {
                assert!(!f.fire(site));
            }
        }
        assert_eq!(f.injected(), 0);
    }

    #[test]
    fn parse_is_strict() {
        for (spec, needle) in [
            ("no-colon", "<seed>:"),
            ("x:step=0.5", "seed"),
            ("1:boom=0.5", "unknown fault site"),
            ("1:step", "<site>=<rate>"),
            ("1:step=1.5", "[0, 1]"),
            ("1:step=-0.1", "[0, 1]"),
            ("1:step=nan", "[0, 1]"),
            ("1:step=0.5@3", "slow"),
            ("1:slow=0.5@fast", "@ms"),
        ] {
            let err = Faults::parse(spec).unwrap_err().to_string();
            assert!(err.contains(needle), "{spec}: {err}");
        }
    }

    #[test]
    fn plan_parses_rates_and_slow_ms() {
        let f = Faults::parse(" 7 : step=0.25, slow=0.5@9 , frame=1 ").unwrap();
        assert!(f.enabled());
        assert_eq!(f.slow_ms(), 9);
        assert_eq!(f.rates[SITE_STEP], 0.25);
        assert_eq!(f.rates[SITE_ADMIT], 0.0);
        assert_eq!(f.rates[SITE_SLOW], 0.5);
        assert_eq!(f.rates[SITE_FRAME], 1.0);
        // rate 1 always fires, rate 0 never draws
        assert!(f.fire(SITE_FRAME) && f.fire(SITE_FRAME));
        assert!(!f.fire(SITE_ADMIT));
        assert_eq!(f.draws[SITE_ADMIT].load(Ordering::Relaxed), 0);
        // all-zero plans are enabled=false (zero-cost)
        assert!(!Faults::parse("7:step=0").unwrap().enabled());
    }

    /// The replay contract: two plans from the same spec produce the
    /// same decision sequence per site, decisions at one site don't
    /// shift another site's stream, and a different seed diverges.
    #[test]
    fn decision_streams_are_seeded_and_independent() {
        let spec = "42:step=0.3,admit=0.3,frame=0.3";
        let a = Faults::parse(spec).unwrap();
        let b = Faults::parse(spec).unwrap();
        // interleave a's sites; b consumes step-only first — the step
        // stream must come out identical either way
        let mut a_step = Vec::new();
        for _ in 0..200 {
            a_step.push(a.fire(SITE_STEP));
            a.fire(SITE_ADMIT);
            a.fire(SITE_FRAME);
        }
        let b_step: Vec<bool> = (0..200).map(|_| b.fire(SITE_STEP)).collect();
        assert_eq!(a_step, b_step);
        assert!(a_step.iter().any(|&h| h), "rate 0.3 over 200 draws must fire");
        assert!(a_step.iter().any(|&h| !h), "rate 0.3 over 200 draws must also pass");
        // each site draws its own stream, so only a lower bound holds
        assert!(a.injected() >= a_step.iter().filter(|&&h| h).count() as u64);
        let c = Faults::parse("43:step=0.3,admit=0.3,frame=0.3").unwrap();
        let c_step: Vec<bool> = (0..200).map(|_| c.fire(SITE_STEP)).collect();
        assert_ne!(a_step, c_step, "different seed must reshuffle decisions");
    }
}
