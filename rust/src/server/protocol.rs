//! Wire protocol: one JSON object per line.
//!
//! Requests:
//!
//! ```text
//! {"op":"generate","adapter":"<name>","prompt":[ids],"max_new":N}
//! {"op":"adapters"}
//! {"op":"stats"}
//! ```
//!
//! Responses:
//!
//! ```text
//! {"ok":true,"tokens":[ids]}
//! {"ok":true,"adapters":[names]}
//! {"ok":true,"stats":{...}}
//! {"ok":false,"error":"..."}
//! ```
//!
//! The `stats` object carries the serving-quality counters aggregated
//! across workers: `requests`, `rejected`, `workers`, `steps`,
//! `generated_tokens`, `tokens_per_sec`, `mean_ttft_ms`
//! (time-to-first-token), `recon_hit_rate` and `recon_evictions`
//! (adapter-reconstruction cache), `factored_admits` / `dense_admits`
//! (execution-mode mix the admission cost model picked),
//! `mean_occupied_slots` (continuous-batching occupancy),
//! `mean_latency_ms`, `truncated_admits` (prompts cut to the context
//! window at admission), and the paged-K/V pair `kv_bytes_in_flight`
//! (resident arena bytes — a gauge tracking tokens actually decoding,
//! not reserved capacity) / `kv_page_churn` (pages recycled through
//! arena free lists over the server's lifetime).

use crate::util::json::{n, obj, s, Json};
use anyhow::{anyhow, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Generate { adapter: String, prompt: Vec<i32>, max_new: usize },
    Adapters,
    Stats,
}

impl Request {
    pub fn parse(line: &str) -> Result<Request> {
        let j = Json::parse(line)?;
        match j.req("op")?.as_str()? {
            "generate" => Ok(Request::Generate {
                adapter: j.req("adapter")?.as_str()?.to_string(),
                prompt: j
                    .req("prompt")?
                    .as_arr()?
                    .iter()
                    .map(|v| Ok(v.as_i64()? as i32))
                    .collect::<Result<_>>()?,
                max_new: j.get("max_new").map(|v| v.as_usize()).transpose()?.unwrap_or(8),
            }),
            "adapters" => Ok(Request::Adapters),
            "stats" => Ok(Request::Stats),
            other => Err(anyhow!("unknown op {other:?}")),
        }
    }

    pub fn to_json(&self) -> String {
        match self {
            Request::Generate { adapter, prompt, max_new } => obj(vec![
                ("op", s("generate")),
                ("adapter", s(adapter)),
                ("prompt", Json::Arr(prompt.iter().map(|&t| n(t as f64)).collect())),
                ("max_new", n(*max_new as f64)),
            ])
            .to_string(),
            Request::Adapters => obj(vec![("op", s("adapters"))]).to_string(),
            Request::Stats => obj(vec![("op", s("stats"))]).to_string(),
        }
    }
}

#[derive(Debug, Clone)]
pub enum Response {
    Tokens(Vec<i32>),
    Adapters(Vec<String>),
    Stats(Json),
    Error(String),
}

impl Response {
    pub fn to_json(&self) -> String {
        match self {
            Response::Tokens(t) => obj(vec![
                ("ok", Json::Bool(true)),
                ("tokens", Json::Arr(t.iter().map(|&x| n(x as f64)).collect())),
            ])
            .to_string(),
            Response::Adapters(a) => obj(vec![
                ("ok", Json::Bool(true)),
                ("adapters", Json::Arr(a.iter().map(|x| s(x)).collect())),
            ])
            .to_string(),
            Response::Stats(j) => {
                obj(vec![("ok", Json::Bool(true)), ("stats", j.clone())]).to_string()
            }
            Response::Error(e) => {
                obj(vec![("ok", Json::Bool(false)), ("error", s(e))]).to_string()
            }
        }
    }

    pub fn parse(line: &str) -> Result<Response> {
        let j = Json::parse(line)?;
        if !j.req("ok")?.as_bool()? {
            return Ok(Response::Error(j.req("error")?.as_str()?.to_string()));
        }
        if let Some(t) = j.get("tokens") {
            return Ok(Response::Tokens(
                t.as_arr()?.iter().map(|v| Ok(v.as_i64()? as i32)).collect::<Result<_>>()?,
            ));
        }
        if let Some(a) = j.get("adapters") {
            return Ok(Response::Adapters(
                a.as_arr()?
                    .iter()
                    .map(|v| Ok(v.as_str()?.to_string()))
                    .collect::<Result<_>>()?,
            ));
        }
        if let Some(st) = j.get("stats") {
            return Ok(Response::Stats(st.clone()));
        }
        Err(anyhow!("unrecognized response {line:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let r = Request::Generate { adapter: "math".into(), prompt: vec![1, 5, 9], max_new: 4 };
        let back = Request::parse(&r.to_json()).unwrap();
        assert_eq!(r, back);
        assert_eq!(Request::parse(r#"{"op":"adapters"}"#).unwrap(), Request::Adapters);
    }

    #[test]
    fn response_roundtrip() {
        let r = Response::Tokens(vec![4, 5, 6]);
        match Response::parse(&r.to_json()).unwrap() {
            Response::Tokens(t) => assert_eq!(t, vec![4, 5, 6]),
            other => panic!("{other:?}"),
        }
        match Response::parse(&Response::Error("boom".into()).to_json()).unwrap() {
            Response::Error(e) => assert_eq!(e, "boom"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn default_max_new() {
        match Request::parse(r#"{"op":"generate","adapter":"a","prompt":[1]}"#).unwrap() {
            Request::Generate { max_new, .. } => assert_eq!(max_new, 8),
            other => panic!("{other:?}"),
        }
    }
}
