//! Wire protocol: one JSON object per line. (Objects serialize with
//! keys in lexicographic order; clients must not rely on key order.)
//!
//! Requests:
//!
//! ```text
//! {"op":"generate","adapter":"<name>","prompt":[ids],"max_new":N,
//!  "sampling":{...},"stream":true|false}
//! {"op":"adapters"}
//! {"op":"stats"}
//! ```
//!
//! `generate` parsing is strict: unknown keys are an error, `max_new`
//! must be a non-negative integer (absent = 8, the historical
//! default), and the optional `sampling` object is range-validated
//! field by field (see [`SamplingParams`]): `temperature` finite and
//! >= 0 (0 = greedy, the default), `top_k` a non-negative integer
//! (0 = off), `top_p` in (0, 1] (1 = off), `repetition_penalty`
//! finite and > 0 (1 = off), `seed` a non-negative integer, `stop` an
//! array of non-empty token arrays, `logit_bias` an array of
//! `[token, bias]` pairs. `stream` (default false) switches the
//! response to per-token frames.
//!
//! Responses (buffered, i.e. `"stream":false`):
//!
//! ```text
//! {"ok":true,"tokens":[ids]}
//! {"ok":true,"adapters":[names]}
//! {"ok":true,"stats":{...}}
//! {"ok":false,"error":"..."}
//! ```
//!
//! Streamed generation instead answers with one frame per emitted
//! token, then a final frame carrying the full token list for
//! backward compatibility:
//!
//! ```text
//! {"frame":{"done":false,"token":id},"ok":true}
//! ...
//! {"frame":{"done":true},"ok":true,"tokens":[ids]}
//! ```
//!
//! The `stats` object carries the serving-quality counters aggregated
//! across workers: `requests`, `rejected`, `workers`, `steps`,
//! `generated_tokens`, `tokens_per_sec`, `mean_ttft_ms`
//! (time-to-first-token; for streamed requests this is measured at
//! the first frame dispatch, i.e. real time-to-first-byte),
//! `recon_hit_rate` and `recon_evictions` (adapter-reconstruction
//! cache), `factored_admits` / `dense_admits` (execution-mode mix the
//! admission cost model picked), `sampled_requests` /
//! `greedy_requests` (decode-policy mix: temperature > 0 vs 0),
//! `stream_frames_sent` (per-token frames written to streaming
//! clients), `mean_occupied_slots` (continuous-batching occupancy),
//! `mean_latency_ms`, `truncated_admits` (prompts cut to the context
//! window at admission), and the paged-K/V pair `kv_bytes_in_flight`
//! (resident arena bytes — a gauge tracking tokens actually decoding,
//! not reserved capacity) / `kv_page_churn` (pages recycled through
//! arena free lists over the server's lifetime).

use crate::generation::SamplingParams;
use crate::util::json::{n, obj, s, Json};
use anyhow::{anyhow, ensure, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Generate {
        adapter: String,
        prompt: Vec<i32>,
        max_new: usize,
        sampling: SamplingParams,
        /// reply with per-token frames instead of one buffered line
        stream: bool,
    },
    Adapters,
    Stats,
}

impl Request {
    pub fn parse(line: &str) -> Result<Request> {
        let j = Json::parse(line)?;
        match j.req("op")?.as_str()? {
            "generate" => {
                const ALLOWED: [&str; 6] =
                    ["op", "adapter", "prompt", "max_new", "sampling", "stream"];
                for k in j.as_obj()?.keys() {
                    ensure!(ALLOWED.contains(&k.as_str()), "unknown generate key {k:?}");
                }
                let max_new = match j.get("max_new") {
                    None => 8,
                    Some(v) => {
                        let f = v.as_f64()?;
                        ensure!(
                            f.fract() == 0.0 && (0.0..=1e9).contains(&f),
                            "max_new must be a non-negative integer, got {f}"
                        );
                        f as usize
                    }
                };
                Ok(Request::Generate {
                    adapter: j.req("adapter")?.as_str()?.to_string(),
                    prompt: j
                        .req("prompt")?
                        .as_arr()?
                        .iter()
                        .map(|v| Ok(v.as_i64()? as i32))
                        .collect::<Result<_>>()?,
                    max_new,
                    sampling: match j.get("sampling") {
                        Some(v) => SamplingParams::from_json(v)?,
                        None => SamplingParams::default(),
                    },
                    stream: j.get("stream").map(|v| v.as_bool()).transpose()?.unwrap_or(false),
                })
            }
            "adapters" => Ok(Request::Adapters),
            "stats" => Ok(Request::Stats),
            other => Err(anyhow!("unknown op {other:?}")),
        }
    }

    pub fn to_json(&self) -> String {
        match self {
            Request::Generate { adapter, prompt, max_new, sampling, stream } => {
                let mut pairs = vec![
                    ("op", s("generate")),
                    ("adapter", s(adapter)),
                    ("prompt", Json::Arr(prompt.iter().map(|&t| n(t as f64)).collect())),
                    ("max_new", n(*max_new as f64)),
                ];
                if *sampling != SamplingParams::default() {
                    pairs.push(("sampling", sampling.to_json()));
                }
                if *stream {
                    pairs.push(("stream", Json::Bool(true)));
                }
                obj(pairs).to_string()
            }
            Request::Adapters => obj(vec![("op", s("adapters"))]).to_string(),
            Request::Stats => obj(vec![("op", s("stats"))]).to_string(),
        }
    }
}

#[derive(Debug, Clone)]
pub enum Response {
    Tokens(Vec<i32>),
    /// One streamed generation event: a per-token frame
    /// (`token: Some, done: false`) or the terminal frame
    /// (`done: true`, `tokens` carrying the full list).
    Frame { token: Option<i32>, done: bool, tokens: Option<Vec<i32>> },
    Adapters(Vec<String>),
    Stats(Json),
    Error(String),
}

impl Response {
    pub fn to_json(&self) -> String {
        match self {
            Response::Tokens(t) => obj(vec![
                ("ok", Json::Bool(true)),
                ("tokens", Json::Arr(t.iter().map(|&x| n(x as f64)).collect())),
            ])
            .to_string(),
            Response::Frame { token, done, tokens } => {
                let mut frame = vec![("done", Json::Bool(*done))];
                if let Some(t) = token {
                    frame.push(("token", n(*t as f64)));
                }
                let mut top = vec![("ok", Json::Bool(true)), ("frame", obj(frame))];
                if let Some(ts) = tokens {
                    top.push(("tokens", Json::Arr(ts.iter().map(|&x| n(x as f64)).collect())));
                }
                obj(top).to_string()
            }
            Response::Adapters(a) => obj(vec![
                ("ok", Json::Bool(true)),
                ("adapters", Json::Arr(a.iter().map(|x| s(x)).collect())),
            ])
            .to_string(),
            Response::Stats(j) => {
                obj(vec![("ok", Json::Bool(true)), ("stats", j.clone())]).to_string()
            }
            Response::Error(e) => {
                obj(vec![("ok", Json::Bool(false)), ("error", s(e))]).to_string()
            }
        }
    }

    pub fn parse(line: &str) -> Result<Response> {
        let j = Json::parse(line)?;
        if !j.req("ok")?.as_bool()? {
            return Ok(Response::Error(j.req("error")?.as_str()?.to_string()));
        }
        // frames first: the terminal frame also carries "tokens"
        if let Some(f) = j.get("frame") {
            return Ok(Response::Frame {
                token: f.get("token").map(|v| Ok(v.as_i64()? as i32)).transpose()?,
                done: f.req("done")?.as_bool()?,
                tokens: j
                    .get("tokens")
                    .map(|t| {
                        t.as_arr()?.iter().map(|v| Ok(v.as_i64()? as i32)).collect::<Result<_>>()
                    })
                    .transpose()?,
            });
        }
        if let Some(t) = j.get("tokens") {
            return Ok(Response::Tokens(
                t.as_arr()?.iter().map(|v| Ok(v.as_i64()? as i32)).collect::<Result<_>>()?,
            ));
        }
        if let Some(a) = j.get("adapters") {
            return Ok(Response::Adapters(
                a.as_arr()?
                    .iter()
                    .map(|v| Ok(v.as_str()?.to_string()))
                    .collect::<Result<_>>()?,
            ));
        }
        if let Some(st) = j.get("stats") {
            return Ok(Response::Stats(st.clone()));
        }
        Err(anyhow!("unrecognized response {line:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn greedy_gen(adapter: &str, prompt: Vec<i32>, max_new: usize) -> Request {
        Request::Generate {
            adapter: adapter.into(),
            prompt,
            max_new,
            sampling: SamplingParams::default(),
            stream: false,
        }
    }

    #[test]
    fn request_roundtrip() {
        let r = greedy_gen("math", vec![1, 5, 9], 4);
        let back = Request::parse(&r.to_json()).unwrap();
        assert_eq!(r, back);
        assert_eq!(Request::parse(r#"{"op":"adapters"}"#).unwrap(), Request::Adapters);
        // non-default sampling and stream survive the roundtrip
        let r = Request::Generate {
            adapter: "math".into(),
            prompt: vec![1],
            max_new: 4,
            sampling: SamplingParams {
                temperature: 0.7,
                top_k: 3,
                seed: 11,
                stop: vec![vec![2, 2]],
                ..Default::default()
            },
            stream: true,
        };
        assert_eq!(Request::parse(&r.to_json()).unwrap(), r);
        // default sampling serializes without a sampling key at all
        assert!(!greedy_gen("a", vec![1], 2).to_json().contains("sampling"));
    }

    #[test]
    fn response_roundtrip() {
        let r = Response::Tokens(vec![4, 5, 6]);
        match Response::parse(&r.to_json()).unwrap() {
            Response::Tokens(t) => assert_eq!(t, vec![4, 5, 6]),
            other => panic!("{other:?}"),
        }
        match Response::parse(&Response::Error("boom".into()).to_json()).unwrap() {
            Response::Error(e) => assert_eq!(e, "boom"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn frame_roundtrip() {
        let per_token = Response::Frame { token: Some(7), done: false, tokens: None };
        match Response::parse(&per_token.to_json()).unwrap() {
            Response::Frame { token, done, tokens } => {
                assert_eq!((token, done, tokens), (Some(7), false, None));
            }
            other => panic!("{other:?}"),
        }
        let terminal = Response::Frame { token: None, done: true, tokens: Some(vec![7, 9]) };
        match Response::parse(&terminal.to_json()).unwrap() {
            Response::Frame { token, done, tokens } => {
                assert_eq!((token, done, tokens), (None, true, Some(vec![7, 9])));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn default_max_new() {
        match Request::parse(r#"{"op":"generate","adapter":"a","prompt":[1]}"#).unwrap() {
            Request::Generate { max_new, sampling, stream, .. } => {
                assert_eq!(max_new, 8);
                assert_eq!(sampling, SamplingParams::default());
                assert!(!stream);
            }
            other => panic!("{other:?}"),
        }
    }

    /// Satellite: `generate` no longer accepts garbage — unknown keys
    /// and out-of-range fields are typed errors, not silent defaults.
    #[test]
    fn generate_parse_is_strict() {
        let cases = [
            (r#"{"op":"generate","adapter":"a","prompt":[1],"maxnew":4}"#, "unknown generate key"),
            (r#"{"op":"generate","adapter":"a","prompt":[1],"max_new":-3}"#, "non-negative"),
            (r#"{"op":"generate","adapter":"a","prompt":[1],"max_new":2.5}"#, "non-negative"),
            (
                r#"{"op":"generate","adapter":"a","prompt":[1],"sampling":{"temperature":-1}}"#,
                "temperature",
            ),
            (
                r#"{"op":"generate","adapter":"a","prompt":[1],"sampling":{"top_p":0}}"#,
                "top_p",
            ),
            (
                r#"{"op":"generate","adapter":"a","prompt":[1],"sampling":{"beam":2}}"#,
                "unknown sampling key",
            ),
            (r#"{"op":"generate","adapter":"a","prompt":[1],"stream":1}"#, "expected bool"),
        ];
        for (line, what) in cases {
            let err = Request::parse(line).unwrap_err().to_string();
            assert!(err.contains(what), "{line}: {err}");
        }
        // unknown keys on OTHER ops stay tolerated (only generate is
        // strict — the op with silently-misinterpreted fields)
        assert_eq!(Request::parse(r#"{"op":"stats","extra":1}"#).unwrap(), Request::Stats);
    }
}
