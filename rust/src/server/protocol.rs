//! Wire protocol: one JSON object per line. (Objects serialize with
//! keys in lexicographic order; clients must not rely on key order.)
//!
//! Requests:
//!
//! ```text
//! {"op":"generate","adapter":"<name>","prompt":[ids],"max_new":N,
//!  "sampling":{...},"stream":true|false,"timeout_ms":N}
//! {"op":"adapters"}
//! {"op":"stats"}
//! {"op":"metrics"}
//! {"op":"trace"}
//! ```
//!
//! `generate` parsing is strict: unknown keys are an error, `max_new`
//! must be a non-negative integer (absent = 8, the historical
//! default), and the optional `sampling` object is range-validated
//! field by field (see [`SamplingParams`]): `temperature` finite and
//! >= 0 (0 = greedy, the default), `top_k` a non-negative integer
//! (0 = off), `top_p` in (0, 1] (1 = off), `repetition_penalty`
//! finite and > 0 (1 = off), `seed` a non-negative integer, `stop` an
//! array of non-empty token arrays, `logit_bias` an array of
//! `[token, bias]` pairs. `stream` (default false) switches the
//! response to per-token frames. `timeout_ms` (default 0 = inherit
//! the server's `UNI_LORA_REQUEST_TIMEOUT_MS`) is a per-request
//! deadline measured from arrival — queue wait counts against it, and
//! an expired sequence is retired at the next step boundary with a
//! `deadline_exceeded` error.
//!
//! Responses (buffered, i.e. `"stream":false`):
//!
//! ```text
//! {"ok":true,"tokens":[ids]}
//! {"ok":true,"adapters":[names]}
//! {"ok":true,"stats":{...}}
//! {"ok":true,"metrics":"<prometheus text>"}
//! {"events":[{span},...],"ok":true}
//! {"ok":false,"code":"<err-code>","error":"..."}
//! ```
//!
//! Error replies carry a machine-readable `code` from the closed
//! vocabulary in [`ErrCode`] (`parse`, `busy`, `unknown_adapter`,
//! `deadline_exceeded`, `shutting_down`, `request_too_large`,
//! `client_gone`, `internal`) next to the human-readable `error`
//! message. Clients route on the code — retry `busy`, fail over on
//! `shutting_down`, surface the rest — and must tolerate codes they
//! do not know (treat as `internal`). Pre-code servers omit the key;
//! [`Response::parse`] maps that to `internal` too.
//!
//! Streamed generation instead answers with one frame per emitted
//! token, then a final frame carrying the full token list for
//! backward compatibility:
//!
//! ```text
//! {"frame":{"done":false,"token":id},"ok":true}
//! ...
//! {"frame":{"done":true},"ok":true,"tokens":[ids]}
//! ```
//!
//! The `stats` object carries the serving-quality counters aggregated
//! across workers: `requests`, `rejected`, `workers`, `steps`,
//! `generated_tokens`, `tokens_per_sec`, `mean_ttft_ms`
//! (time-to-first-token; for streamed requests this is measured at
//! the first frame dispatch, i.e. real time-to-first-byte),
//! `recon_hit_rate` and `recon_evictions` (adapter-reconstruction
//! cache), `factored_admits` / `dense_admits` (execution-mode mix the
//! admission cost model picked), `sampled_requests` /
//! `greedy_requests` (decode-policy mix: temperature > 0 vs 0),
//! `stream_frames_sent` (per-token frames written to streaming
//! clients), `mean_occupied_slots` (continuous-batching occupancy),
//! `mean_latency_ms`, `truncated_admits` (prompts cut to the context
//! window at admission), and the paged-K/V pair `kv_bytes_in_flight`
//! (resident arena bytes — a gauge tracking tokens actually decoding,
//! not reserved capacity) / `kv_page_churn` (pages recycled through
//! arena free lists over the server's lifetime).
//!
//! The request-lifecycle counters ride in the same object:
//! `deadline_exceeded` (requests that ran out of wall-clock, queued or
//! decoding), `cancelled` (sequences retired mid-flight before
//! finishing — deadline expiries and client disconnects), `client_gone`
//! (streaming clients that vanished mid-generation), `conns_rejected`
//! (connections turned away at the `UNI_LORA_MAX_CONNS` cap),
//! `drained_ok` / `drained_aborted` (in-flight requests that finished
//! inside vs. were cut at the shutdown drain deadline),
//! `faults_injected` (decisions taken by the seeded `UNI_LORA_FAULTS`
//! plan; always 0 in production), and `decode_wall_secs` (wall-clock
//! seconds with at least one decode step in flight — the union of step
//! intervals, i.e. the denominator of `tokens_per_sec`).
//!
//! `metrics` answers with the same telemetry — plus the latency/size
//! histograms the scalar stats cannot carry — as one Prometheus text
//! exposition (format 0.0.4) string in the `metrics` key: `unilora_*`
//! counters and gauges mirror the stats fields, and five histograms
//! (`unilora_ttft_seconds`, `unilora_queue_wait_seconds`,
//! `unilora_request_latency_seconds`, `unilora_decode_step_seconds`,
//! `unilora_prompt_tokens`) expose cumulative `_bucket{le=...}`
//! series with exact cross-worker counts. When the server runs with
//! `UNI_LORA_PROFILE=1`, `unilora_profile_seconds_total` /
//! `unilora_profile_calls_total{stage=...}` attribute fused decode
//! time to base GEMM, factored rank-r apply, dense GEMV, attention,
//! logits, sampling and prefill. Pipe the string to a file and any
//! Prometheus scraper ingests it.
//!
//! `trace` drains the in-memory span-event ring (destructive: each
//! event is returned once) as the `events` array. Every event is one
//! object: `ev` (vocabulary: `enqueue`, `reject`, `admit`, `requeue`,
//! `fault`, `prefill`, `step`, `frame`, `deadline`, `cancel`,
//! `replay`, `done`), `req` (the router-assigned request id; 0 =
//! worker-scoped), `t_us` (microseconds since the tracer's epoch),
//! plus optional `slot`, `n` (a small integer payload: prompt/token
//! counts or the token id) and `note` (adapter name, fault site, or
//! terminal error code — `"ok"` on success). A request's timeline is
//! the `req`-filtered, `t_us`-ordered subsequence, ending in exactly
//! one `done` (admitted) or `reject` (never queued). Both ops tolerate
//! unknown extra keys, like `stats`.

use crate::generation::SamplingParams;
use crate::util::json::{n, obj, s, Json};
use anyhow::{anyhow, ensure, Result};
use std::fmt;

/// Machine-readable error classes for the `code` field of error
/// replies. The set is closed and additive-only: removing or renaming
/// a code breaks clients that route on it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrCode {
    /// the request line was malformed or failed strict validation
    Parse,
    /// transient saturation: queue full or connection cap hit — retry
    Busy,
    /// the request named an adapter the registry does not hold
    UnknownAdapter,
    /// the per-request / server-default deadline expired (queue wait
    /// counts against it)
    DeadlineExceeded,
    /// the server is draining; the request was failed without decoding
    ShuttingDown,
    /// the request line exceeded `UNI_LORA_MAX_REQUEST_BYTES`
    RequestTooLarge,
    /// the client disconnected mid-stream; the sequence was cancelled
    ClientGone,
    /// a session/decode failure the client cannot fix by retrying as-is
    Internal,
}

impl ErrCode {
    pub fn as_str(self) -> &'static str {
        match self {
            ErrCode::Parse => "parse",
            ErrCode::Busy => "busy",
            ErrCode::UnknownAdapter => "unknown_adapter",
            ErrCode::DeadlineExceeded => "deadline_exceeded",
            ErrCode::ShuttingDown => "shutting_down",
            ErrCode::RequestTooLarge => "request_too_large",
            ErrCode::ClientGone => "client_gone",
            ErrCode::Internal => "internal",
        }
    }

    /// Wire-name lookup. Unknown names resolve to [`ErrCode::Internal`]
    /// — a client must not crash on a code minted by a newer server.
    pub fn from_wire(s: &str) -> ErrCode {
        match s {
            "parse" => ErrCode::Parse,
            "busy" => ErrCode::Busy,
            "unknown_adapter" => ErrCode::UnknownAdapter,
            "deadline_exceeded" => ErrCode::DeadlineExceeded,
            "shutting_down" => ErrCode::ShuttingDown,
            "request_too_large" => ErrCode::RequestTooLarge,
            "client_gone" => ErrCode::ClientGone,
            _ => ErrCode::Internal,
        }
    }
}

/// A typed serving error: a routing [`ErrCode`] plus the
/// human-readable message. `Display` prints only the message, so
/// callers that format errors into logs keep their historical text;
/// route on `code`, not on message substrings.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeError {
    pub code: ErrCode,
    pub msg: String,
}

impl ServeError {
    pub fn new(code: ErrCode, msg: impl Into<String>) -> ServeError {
        ServeError { code, msg: msg.into() }
    }
    pub fn parse(msg: impl Into<String>) -> ServeError {
        ServeError::new(ErrCode::Parse, msg)
    }
    pub fn busy(msg: impl Into<String>) -> ServeError {
        ServeError::new(ErrCode::Busy, msg)
    }
    pub fn unknown_adapter(msg: impl Into<String>) -> ServeError {
        ServeError::new(ErrCode::UnknownAdapter, msg)
    }
    pub fn deadline_exceeded(msg: impl Into<String>) -> ServeError {
        ServeError::new(ErrCode::DeadlineExceeded, msg)
    }
    pub fn shutting_down(msg: impl Into<String>) -> ServeError {
        ServeError::new(ErrCode::ShuttingDown, msg)
    }
    pub fn too_large(msg: impl Into<String>) -> ServeError {
        ServeError::new(ErrCode::RequestTooLarge, msg)
    }
    pub fn client_gone(msg: impl Into<String>) -> ServeError {
        ServeError::new(ErrCode::ClientGone, msg)
    }
    pub fn internal(msg: impl Into<String>) -> ServeError {
        ServeError::new(ErrCode::Internal, msg)
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for ServeError {}

#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Generate {
        adapter: String,
        prompt: Vec<i32>,
        max_new: usize,
        sampling: SamplingParams,
        /// reply with per-token frames instead of one buffered line
        stream: bool,
        /// per-request deadline in milliseconds, measured from arrival;
        /// 0 = inherit the server default (`UNI_LORA_REQUEST_TIMEOUT_MS`)
        timeout_ms: u64,
    },
    Adapters,
    Stats,
    /// Prometheus text scrape: counters, gauges and histograms (plus
    /// the profiling section when `UNI_LORA_PROFILE=1`).
    Metrics,
    /// Destructive drain of the span-event ring: each recorded event
    /// is returned exactly once.
    Trace,
}

impl Request {
    pub fn parse(line: &str) -> Result<Request> {
        let j = Json::parse(line)?;
        match j.req("op")?.as_str()? {
            "generate" => {
                const ALLOWED: [&str; 7] =
                    ["op", "adapter", "prompt", "max_new", "sampling", "stream", "timeout_ms"];
                for k in j.as_obj()?.keys() {
                    ensure!(ALLOWED.contains(&k.as_str()), "unknown generate key {k:?}");
                }
                let max_new = match j.get("max_new") {
                    None => 8,
                    Some(v) => {
                        let f = v.as_f64()?;
                        ensure!(
                            f.fract() == 0.0 && (0.0..=1e9).contains(&f),
                            "max_new must be a non-negative integer, got {f}"
                        );
                        f as usize
                    }
                };
                let timeout_ms = match j.get("timeout_ms") {
                    None => 0,
                    Some(v) => {
                        let f = v.as_f64()?;
                        ensure!(
                            f.fract() == 0.0 && (0.0..=1e12).contains(&f),
                            "timeout_ms must be a non-negative integer, got {f}"
                        );
                        f as u64
                    }
                };
                Ok(Request::Generate {
                    adapter: j.req("adapter")?.as_str()?.to_string(),
                    prompt: j
                        .req("prompt")?
                        .as_arr()?
                        .iter()
                        .map(|v| Ok(v.as_i64()? as i32))
                        .collect::<Result<_>>()?,
                    max_new,
                    sampling: match j.get("sampling") {
                        Some(v) => SamplingParams::from_json(v)?,
                        None => SamplingParams::default(),
                    },
                    stream: j.get("stream").map(|v| v.as_bool()).transpose()?.unwrap_or(false),
                    timeout_ms,
                })
            }
            "adapters" => Ok(Request::Adapters),
            "stats" => Ok(Request::Stats),
            "metrics" => Ok(Request::Metrics),
            "trace" => Ok(Request::Trace),
            other => Err(anyhow!("unknown op {other:?}")),
        }
    }

    pub fn to_json(&self) -> String {
        match self {
            Request::Generate { adapter, prompt, max_new, sampling, stream, timeout_ms } => {
                let mut pairs = vec![
                    ("op", s("generate")),
                    ("adapter", s(adapter)),
                    ("prompt", Json::Arr(prompt.iter().map(|&t| n(t as f64)).collect())),
                    ("max_new", n(*max_new as f64)),
                ];
                if *sampling != SamplingParams::default() {
                    pairs.push(("sampling", sampling.to_json()));
                }
                if *stream {
                    pairs.push(("stream", Json::Bool(true)));
                }
                if *timeout_ms > 0 {
                    pairs.push(("timeout_ms", n(*timeout_ms as f64)));
                }
                obj(pairs).to_string()
            }
            Request::Adapters => obj(vec![("op", s("adapters"))]).to_string(),
            Request::Stats => obj(vec![("op", s("stats"))]).to_string(),
            Request::Metrics => obj(vec![("op", s("metrics"))]).to_string(),
            Request::Trace => obj(vec![("op", s("trace"))]).to_string(),
        }
    }
}

#[derive(Debug, Clone)]
pub enum Response {
    Tokens(Vec<i32>),
    /// One streamed generation event: a per-token frame
    /// (`token: Some, done: false`) or the terminal frame
    /// (`done: true`, `tokens` carrying the full list).
    Frame { token: Option<i32>, done: bool, tokens: Option<Vec<i32>> },
    Adapters(Vec<String>),
    Stats(Json),
    /// The Prometheus text exposition, verbatim (newlines escaped on
    /// the wire by JSON string encoding).
    Metrics(String),
    /// Drained span events, oldest first; each is the JSON object
    /// documented in the module header.
    Trace(Vec<Json>),
    Error(ServeError),
}

impl Response {
    pub fn to_json(&self) -> String {
        match self {
            Response::Tokens(t) => obj(vec![
                ("ok", Json::Bool(true)),
                ("tokens", Json::Arr(t.iter().map(|&x| n(x as f64)).collect())),
            ])
            .to_string(),
            Response::Frame { token, done, tokens } => {
                let mut frame = vec![("done", Json::Bool(*done))];
                if let Some(t) = token {
                    frame.push(("token", n(*t as f64)));
                }
                let mut top = vec![("ok", Json::Bool(true)), ("frame", obj(frame))];
                if let Some(ts) = tokens {
                    top.push(("tokens", Json::Arr(ts.iter().map(|&x| n(x as f64)).collect())));
                }
                obj(top).to_string()
            }
            Response::Adapters(a) => obj(vec![
                ("ok", Json::Bool(true)),
                ("adapters", Json::Arr(a.iter().map(|x| s(x)).collect())),
            ])
            .to_string(),
            Response::Stats(j) => {
                obj(vec![("ok", Json::Bool(true)), ("stats", j.clone())]).to_string()
            }
            Response::Metrics(text) => {
                obj(vec![("ok", Json::Bool(true)), ("metrics", s(text))]).to_string()
            }
            Response::Trace(events) => {
                obj(vec![("ok", Json::Bool(true)), ("events", Json::Arr(events.clone()))])
                    .to_string()
            }
            Response::Error(e) => obj(vec![
                ("ok", Json::Bool(false)),
                ("code", s(e.code.as_str())),
                ("error", s(&e.msg)),
            ])
            .to_string(),
        }
    }

    pub fn parse(line: &str) -> Result<Response> {
        let j = Json::parse(line)?;
        if !j.req("ok")?.as_bool()? {
            // "code" is optional on the wire: pre-code servers (and
            // proxies that strip unknown keys) degrade to `internal`
            let code = match j.get("code") {
                Some(c) => ErrCode::from_wire(c.as_str()?),
                None => ErrCode::Internal,
            };
            return Ok(Response::Error(ServeError::new(code, j.req("error")?.as_str()?)));
        }
        // frames first: the terminal frame also carries "tokens"
        if let Some(f) = j.get("frame") {
            return Ok(Response::Frame {
                token: f.get("token").map(|v| Ok(v.as_i64()? as i32)).transpose()?,
                done: f.req("done")?.as_bool()?,
                tokens: j
                    .get("tokens")
                    .map(|t| {
                        t.as_arr()?.iter().map(|v| Ok(v.as_i64()? as i32)).collect::<Result<_>>()
                    })
                    .transpose()?,
            });
        }
        if let Some(t) = j.get("tokens") {
            return Ok(Response::Tokens(
                t.as_arr()?.iter().map(|v| Ok(v.as_i64()? as i32)).collect::<Result<_>>()?,
            ));
        }
        if let Some(a) = j.get("adapters") {
            return Ok(Response::Adapters(
                a.as_arr()?
                    .iter()
                    .map(|v| Ok(v.as_str()?.to_string()))
                    .collect::<Result<_>>()?,
            ));
        }
        if let Some(st) = j.get("stats") {
            return Ok(Response::Stats(st.clone()));
        }
        if let Some(m) = j.get("metrics") {
            return Ok(Response::Metrics(m.as_str()?.to_string()));
        }
        if let Some(ev) = j.get("events") {
            return Ok(Response::Trace(ev.as_arr()?.to_vec()));
        }
        Err(anyhow!("unrecognized response {line:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn greedy_gen(adapter: &str, prompt: Vec<i32>, max_new: usize) -> Request {
        Request::Generate {
            adapter: adapter.into(),
            prompt,
            max_new,
            sampling: SamplingParams::default(),
            stream: false,
            timeout_ms: 0,
        }
    }

    #[test]
    fn request_roundtrip() {
        let r = greedy_gen("math", vec![1, 5, 9], 4);
        let back = Request::parse(&r.to_json()).unwrap();
        assert_eq!(r, back);
        assert_eq!(Request::parse(r#"{"op":"adapters"}"#).unwrap(), Request::Adapters);
        // non-default sampling, stream and timeout survive the roundtrip
        let r = Request::Generate {
            adapter: "math".into(),
            prompt: vec![1],
            max_new: 4,
            sampling: SamplingParams {
                temperature: 0.7,
                top_k: 3,
                seed: 11,
                stop: vec![vec![2, 2]],
                ..Default::default()
            },
            stream: true,
            timeout_ms: 1500,
        };
        assert_eq!(Request::parse(&r.to_json()).unwrap(), r);
        // default sampling serializes without a sampling key at all,
        // and timeout 0 (= inherit the server default) stays off-wire
        let plain = greedy_gen("a", vec![1], 2).to_json();
        assert!(!plain.contains("sampling"));
        assert!(!plain.contains("timeout_ms"));
    }

    #[test]
    fn response_roundtrip() {
        let r = Response::Tokens(vec![4, 5, 6]);
        match Response::parse(&r.to_json()).unwrap() {
            Response::Tokens(t) => assert_eq!(t, vec![4, 5, 6]),
            other => panic!("{other:?}"),
        }
        let boom = ServeError::internal("boom");
        match Response::parse(&Response::Error(boom.clone()).to_json()).unwrap() {
            Response::Error(e) => assert_eq!(e, boom),
            other => panic!("{other:?}"),
        }
    }

    /// Typed errors on the wire: every code roundtrips, the legacy
    /// code-less shape degrades to `internal`, and unknown codes from
    /// a newer server do too instead of failing the parse.
    #[test]
    fn error_codes_roundtrip_and_degrade() {
        let all = [
            ErrCode::Parse,
            ErrCode::Busy,
            ErrCode::UnknownAdapter,
            ErrCode::DeadlineExceeded,
            ErrCode::ShuttingDown,
            ErrCode::RequestTooLarge,
            ErrCode::ClientGone,
            ErrCode::Internal,
        ];
        for code in all {
            let line = Response::Error(ServeError::new(code, "msg")).to_json();
            assert!(line.contains(&format!(r#""code":"{}""#, code.as_str())), "{line}");
            match Response::parse(&line).unwrap() {
                Response::Error(e) => assert_eq!(e.code, code),
                other => panic!("{other:?}"),
            }
        }
        // Display is the bare message — log lines keep their old text
        assert_eq!(ServeError::busy("busy: queue full").to_string(), "busy: queue full");
        match Response::parse(r#"{"ok":false,"error":"old server"}"#).unwrap() {
            Response::Error(e) => assert_eq!(e.code, ErrCode::Internal),
            other => panic!("{other:?}"),
        }
        match Response::parse(r#"{"ok":false,"code":"from_the_future","error":"x"}"#).unwrap() {
            Response::Error(e) => assert_eq!(e.code, ErrCode::Internal),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn frame_roundtrip() {
        let per_token = Response::Frame { token: Some(7), done: false, tokens: None };
        match Response::parse(&per_token.to_json()).unwrap() {
            Response::Frame { token, done, tokens } => {
                assert_eq!((token, done, tokens), (Some(7), false, None));
            }
            other => panic!("{other:?}"),
        }
        let terminal = Response::Frame { token: None, done: true, tokens: Some(vec![7, 9]) };
        match Response::parse(&terminal.to_json()).unwrap() {
            Response::Frame { token, done, tokens } => {
                assert_eq!((token, done, tokens), (None, true, Some(vec![7, 9])));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn default_max_new() {
        match Request::parse(r#"{"op":"generate","adapter":"a","prompt":[1]}"#).unwrap() {
            Request::Generate { max_new, sampling, stream, .. } => {
                assert_eq!(max_new, 8);
                assert_eq!(sampling, SamplingParams::default());
                assert!(!stream);
            }
            other => panic!("{other:?}"),
        }
    }

    /// Satellite: `generate` no longer accepts garbage — unknown keys
    /// and out-of-range fields are typed errors, not silent defaults.
    #[test]
    fn generate_parse_is_strict() {
        let cases = [
            (r#"{"op":"generate","adapter":"a","prompt":[1],"maxnew":4}"#, "unknown generate key"),
            (r#"{"op":"generate","adapter":"a","prompt":[1],"max_new":-3}"#, "non-negative"),
            (r#"{"op":"generate","adapter":"a","prompt":[1],"max_new":2.5}"#, "non-negative"),
            (
                r#"{"op":"generate","adapter":"a","prompt":[1],"sampling":{"temperature":-1}}"#,
                "temperature",
            ),
            (
                r#"{"op":"generate","adapter":"a","prompt":[1],"sampling":{"top_p":0}}"#,
                "top_p",
            ),
            (
                r#"{"op":"generate","adapter":"a","prompt":[1],"sampling":{"beam":2}}"#,
                "unknown sampling key",
            ),
            (r#"{"op":"generate","adapter":"a","prompt":[1],"stream":1}"#, "expected bool"),
            (
                r#"{"op":"generate","adapter":"a","prompt":[1],"timeout_ms":-5}"#,
                "timeout_ms",
            ),
            (
                r#"{"op":"generate","adapter":"a","prompt":[1],"timeout_ms":0.5}"#,
                "timeout_ms",
            ),
        ];
        for (line, what) in cases {
            let err = Request::parse(line).unwrap_err().to_string();
            assert!(err.contains(what), "{line}: {err}");
        }
        // unknown keys on OTHER ops stay tolerated (only generate is
        // strict — the op with silently-misinterpreted fields)
        assert_eq!(Request::parse(r#"{"op":"stats","extra":1}"#).unwrap(), Request::Stats);
    }

    /// Satellite: the observability ops — requests roundtrip, tolerate
    /// extra keys like `stats`, and the scrape/drain responses carry
    /// their payloads through JSON intact (the Prometheus text embeds
    /// newlines; JSON string escaping must preserve them exactly).
    #[test]
    fn metrics_and_trace_ops_roundtrip() {
        assert_eq!(Request::Metrics.to_json(), r#"{"op":"metrics"}"#);
        assert_eq!(Request::Trace.to_json(), r#"{"op":"trace"}"#);
        assert_eq!(Request::parse(r#"{"op":"metrics"}"#).unwrap(), Request::Metrics);
        assert_eq!(Request::parse(r#"{"op":"trace","extra":1}"#).unwrap(), Request::Trace);

        let text = "# HELP t_x_total helps\n# TYPE t_x_total counter\nt_x_total 3\n";
        let line = Response::Metrics(text.to_string()).to_json();
        assert!(line.contains(r#""ok":true"#), "{line}");
        match Response::parse(&line).unwrap() {
            Response::Metrics(back) => assert_eq!(back, text),
            other => panic!("{other:?}"),
        }

        let ev = Json::parse(r#"{"ev":"done","note":"ok","req":3,"t_us":12}"#).unwrap();
        let line = Response::Trace(vec![ev]).to_json();
        assert_eq!(line, r#"{"events":[{"ev":"done","note":"ok","req":3,"t_us":12}],"ok":true}"#);
        match Response::parse(&line).unwrap() {
            Response::Trace(events) => {
                assert_eq!(events.len(), 1);
                assert_eq!(events[0].req("ev").unwrap().as_str().unwrap(), "done");
                assert_eq!(events[0].req("req").unwrap().as_i64().unwrap(), 3);
            }
            other => panic!("{other:?}"),
        }
        // an empty drain is a valid, parseable response
        match Response::parse(&Response::Trace(vec![]).to_json()).unwrap() {
            Response::Trace(events) => assert!(events.is_empty()),
            other => panic!("{other:?}"),
        }
    }
}
