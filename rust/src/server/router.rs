//! Adapter-aware request router: forms batches of requests that share an
//! adapter (so one decode pass serves the whole batch), hot-swapping the
//! per-batch theta vector. The batching policy is greedy same-adapter
//! coalescing up to the artifact batch size — the policy knob the
//! serving bench sweeps.

use crate::adapters::Registry;
use crate::config::ModelCfg;
use crate::coordinator::trainer::decode_with;
use crate::projection::statics::{gen_statics, Static};
use crate::runtime::Backend;
use anyhow::Result;
use std::collections::{HashMap, VecDeque};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

pub struct PendingReq {
    pub adapter: String,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<Result<Vec<i32>, String>>,
}

#[derive(Debug, Default, Clone)]
pub struct RouterStats {
    pub requests: u64,
    pub batches: u64,
    pub batched_requests: u64,
    pub total_latency_secs: f64,
    pub total_queue_secs: f64,
}

impl RouterStats {
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_requests as f64 / self.batches as f64
        }
    }

    pub fn mean_latency_ms(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            1000.0 * self.total_latency_secs / self.requests as f64
        }
    }
}

struct Shared {
    queue: Mutex<VecDeque<PendingReq>>,
    cv: Condvar,
    stopped: Mutex<bool>,
}

/// The router owns the queue; `worker_loop` owns the execution backend.
pub struct Router {
    shared: Arc<Shared>,
    pub stats: Arc<Mutex<RouterStats>>,
}

impl Clone for Router {
    fn clone(&self) -> Router {
        Router { shared: self.shared.clone(), stats: self.stats.clone() }
    }
}

impl Router {
    pub fn new() -> Router {
        Router {
            shared: Arc::new(Shared {
                queue: Mutex::new(VecDeque::new()),
                cv: Condvar::new(),
                stopped: Mutex::new(false),
            }),
            stats: Arc::new(Mutex::new(RouterStats::default())),
        }
    }

    pub fn submit(&self, req: PendingReq) {
        self.shared.queue.lock().unwrap().push_back(req);
        self.shared.cv.notify_one();
    }

    /// Synchronous convenience: submit and wait for the generation.
    pub fn generate(&self, adapter: &str, prompt: Vec<i32>, max_new: usize) -> Result<Vec<i32>, String> {
        let (tx, rx) = mpsc::channel();
        self.submit(PendingReq {
            adapter: adapter.to_string(),
            prompt,
            max_new,
            enqueued: Instant::now(),
            reply: tx,
        });
        rx.recv().map_err(|e| e.to_string())?
    }

    pub fn stop(&self) {
        *self.shared.stopped.lock().unwrap() = true;
        self.shared.cv.notify_all();
    }

    /// Pop the next same-adapter batch (blocks; None on stop).
    fn next_batch(&self, max_batch: usize) -> Option<Vec<PendingReq>> {
        let mut q = self.shared.queue.lock().unwrap();
        loop {
            if *self.shared.stopped.lock().unwrap() && q.is_empty() {
                return None;
            }
            if let Some(first) = q.front() {
                let adapter = first.adapter.clone();
                let mut batch = vec![q.pop_front().unwrap()];
                let mut i = 0;
                while i < q.len() && batch.len() < max_batch {
                    if q[i].adapter == adapter {
                        batch.push(q.remove(i).unwrap());
                    } else {
                        i += 1;
                    }
                }
                return Some(batch);
            }
            q = self.shared.cv.wait(q).unwrap();
        }
    }

    /// Worker: runs until stop(). Owns the backend, backbone weights
    /// and the statics cache (statics are per-(method, seed), generated
    /// once per adapter and reused across batches).
    pub fn worker_loop(
        &self,
        exec: &mut dyn Backend,
        registry: &Registry,
        art_logits: &str,
        cfg: &ModelCfg,
        w0: &[f32],
    ) {
        let mut statics_cache: HashMap<String, Vec<Static>> = HashMap::new();
        while let Some(batch) = self.next_batch(cfg.batch) {
            let adapter_name = batch[0].adapter.clone();
            let queue_wait: f64 = batch
                .iter()
                .map(|r| r.enqueued.elapsed().as_secs_f64())
                .sum();
            let result = (|| -> Result<Vec<Vec<i32>>, String> {
                let ckpt = registry
                    .get(&adapter_name)
                    .ok_or_else(|| format!("unknown adapter {adapter_name:?}"))?;
                let stats = statics_cache
                    .entry(adapter_name.clone())
                    .or_insert_with(|| gen_statics(cfg, ckpt.seed).expect("statics"));
                let prompts: Vec<Vec<i32>> = batch.iter().map(|r| r.prompt.clone()).collect();
                let max_new = batch.iter().map(|r| r.max_new).max().unwrap_or(8);
                decode_with(exec, art_logits, cfg, &ckpt.theta, w0, stats, &prompts, max_new)
                    .map_err(|e| e.to_string())
            })();
            let mut st = self.stats.lock().unwrap();
            st.batches += 1;
            st.batched_requests += batch.len() as u64;
            st.requests += batch.len() as u64;
            st.total_queue_secs += queue_wait;
            for (k, req) in batch.into_iter().enumerate() {
                st.total_latency_secs += req.enqueued.elapsed().as_secs_f64();
                let reply = match &result {
                    Ok(gens) => Ok(gens[k].clone()),
                    Err(e) => Err(e.clone()),
                };
                let _ = req.reply.send(reply);
            }
        }
    }
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_coalesce_same_adapter() {
        let r = Router::new();
        let (tx, _rx) = mpsc::channel();
        for a in ["x", "y", "x", "x", "y"] {
            r.submit(PendingReq {
                adapter: a.into(),
                prompt: vec![1],
                max_new: 1,
                enqueued: Instant::now(),
                reply: tx.clone(),
            });
        }
        let b1 = r.next_batch(8).unwrap();
        assert_eq!(b1.len(), 3);
        assert!(b1.iter().all(|q| q.adapter == "x"));
        let b2 = r.next_batch(8).unwrap();
        assert_eq!(b2.len(), 2);
        assert!(b2.iter().all(|q| q.adapter == "y"));
    }

    #[test]
    fn batch_size_cap() {
        let r = Router::new();
        let (tx, _rx) = mpsc::channel();
        for _ in 0..10 {
            r.submit(PendingReq {
                adapter: "x".into(),
                prompt: vec![1],
                max_new: 1,
                enqueued: Instant::now(),
                reply: tx.clone(),
            });
        }
        assert_eq!(r.next_batch(4).unwrap().len(), 4);
        assert_eq!(r.next_batch(4).unwrap().len(), 4);
        assert_eq!(r.next_batch(4).unwrap().len(), 2);
    }

    #[test]
    fn stop_unblocks() {
        let r = Router::new();
        let r2 = r.clone();
        let h = std::thread::spawn(move || r2.next_batch(4));
        std::thread::sleep(std::time::Duration::from_millis(30));
        r.stop();
        assert!(h.join().unwrap().is_none());
    }
}
