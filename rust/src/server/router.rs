//! Adapter-aware request router with **continuous batching**: each
//! worker owns a decode session (`Backend::begin_decode`) and admits
//! queued requests into free decode slots at step boundaries, retiring
//! finished sequences per step — no request waits for a whole greedy
//! batch to drain, and slots can hold a heterogeneous mix of adapters
//! (the native session decodes each slot with its own reconstructed
//! weights from the shared reconstruction cache).
//!
//! The queue is bounded: past `capacity` pending requests, `submit`
//! rejects immediately and `generate` surfaces a typed `busy` error
//! instead of letting the backlog (and client latency) grow without
//! limit. Any number of worker threads may drain the queue concurrently
//! (`server::serve` runs one `worker_loop` per execution worker, each
//! owning a backend clone and its own session).
//!
//! Requests carry a per-request [`SamplingParams`] (temperature 0 —
//! exact greedy — by default) and may opt into **streaming**: the
//! worker dispatches one [`GenEvent::Token`] per emitted token at the
//! step boundary that produced it, so a streaming client's first byte
//! arrives mid-decode instead of after the sequence finishes.
//!
//! The request lifecycle is bounded end to end. An optional per-request
//! **deadline** is enforced at step boundaries: queue wait counts
//! against it (a stale queued request fails without ever occupying a
//! slot), and an expired in-flight sequence is cancelled — K/V pages
//! and slot recycled immediately — with a typed `deadline_exceeded`
//! reply. A streaming client that disconnects mid-generation is
//! detected at its next frame dispatch and its sequence is
//! **cancelled** the same way instead of decoding tokens nobody will
//! read. On shutdown the router **drains**: new submissions fail with
//! `shutting_down`, queued requests are failed in bulk, in-flight
//! sequences run to completion until the drain deadline, then
//! [`Router::hard_stop`] aborts the stragglers at the next step
//! boundary.
//!
//! Failure recovery is deterministic under the seeded fault plan
//! ([`Faults`]): an injected (or real) step failure reopens the session
//! and **replays** the in-flight sequences — decode is deterministic,
//! so the re-derived streams match and `SlotBook::replay_skip`
//! suppresses re-delivery of tokens the client already holds.
//!
//! Serving-quality accounting lives in [`RouterStats`]: tokens/s,
//! time-to-first-token (measured at first-frame dispatch for streamed
//! requests), reconstruction-cache hit rate, decode-policy mix,
//! decode-slot occupancy and the lifecycle counters, all surfaced
//! through the protocol `stats` op.

use super::faults::{Faults, SITE_ADMIT, SITE_FRAME, SITE_SLOW, SITE_STEP};
use super::protocol::{ErrCode, ServeError};
use crate::adapters::Registry;
use crate::config::{self, ModelCfg};
use crate::generation::SamplingParams;
use crate::obs::{Hist, Tracer};
use crate::projection::statics::{gen_statics, Static};
use crate::runtime::native::kv_arena::KvBudgetExhausted;
use crate::runtime::Backend;
use crate::session::{Admission, DecodeSession, SeqRequest, SessionOpts, SessionStats};
use anyhow::Result;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

/// Lock a mutex, recovering the data from a poisoned one. The mutexes
/// this guards (stats, queue, statics, stop flag) hold monotone
/// counters and plain queue state with no invariant that spans the
/// panic point, so recovery is safe — and the alternative is a worker
/// panic cascading through every later `lock().unwrap()` in the pool
/// until shutdown itself deadlocks.
pub(crate) fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// One reply-channel event for a pending request. Buffered requests
/// receive a single `Done`; streaming requests (`PendingReq::stream`)
/// additionally receive one `Token` per emitted token, dispatched at
/// the step boundary that produced it — the worker never buffers a
/// finished token, which is what lets `mean_ttft_ms` measure real
/// time-to-first-byte.
#[derive(Debug)]
pub enum GenEvent {
    Token(i32),
    Done(Result<Vec<i32>, ServeError>),
}

#[derive(Debug)]
pub struct PendingReq {
    /// Trace identity: router-assigned at [`Router::submit`] (ids
    /// start at 1). Callers construct requests with `id: 0` =
    /// unassigned; every span event for this request carries the
    /// assigned id, and it threads through [`SeqRequest::request_id`]
    /// into the decode sessions so a session-level event is
    /// attributable to its request.
    pub id: u64,
    pub adapter: String,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    pub sampling: SamplingParams,
    /// deliver per-token `GenEvent::Token`s ahead of `Done`
    pub stream: bool,
    /// absolute deadline (queue wait included); `None` = no limit
    pub deadline: Option<Instant>,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<GenEvent>,
}

/// Serving-quality counters, aggregated across all workers.
#[derive(Debug, Default, Clone)]
pub struct RouterStats {
    /// requests completed (replied to), success or error
    pub requests: u64,
    /// requests rejected at submit time because the queue was full
    pub rejected: u64,
    /// decode step boundaries executed
    pub steps: u64,
    /// sum of occupied slots over steps (the occupancy integral)
    pub slot_steps: u64,
    /// tokens emitted across all sequences
    pub generated_tokens: u64,
    /// cumulative time inside `DecodeSession::step`, summed across
    /// workers (per-worker decode effort; NOT wall time)
    pub decode_secs: f64,
    /// wall-clock seconds with at least one decode step in flight: the
    /// exact union of the step intervals, so idle gaps between bursts
    /// never dilute [`RouterStats::tokens_per_sec`] while concurrent
    /// workers still add throughput instead of dividing it away
    pub decode_wall_secs: f64,
    /// high-water mark of the busy span: end of the latest step
    /// interval folded into `decode_wall_secs` so far
    busy_until: Option<Instant>,
    /// latency/size distributions (TTFT, queue wait, end-to-end
    /// latency, step time, prompt length) backing the `metrics` op
    pub hists: RouterHists,
    /// enqueue → first emitted token, summed over `ttft_count` requests
    pub ttft_secs: f64,
    pub ttft_count: u64,
    /// adapter-reconstruction cache hits/misses (native sessions)
    pub recon_hits: u64,
    pub recon_misses: u64,
    /// dense reconstructions evicted from the shared cache on behalf
    /// of this router's admissions
    pub recon_evictions: u64,
    /// admissions run on the factored rank-r path vs densified — the
    /// execution-mode mix the session cost model picked
    pub factored_admits: u64,
    pub dense_admits: u64,
    /// admissions whose prompt was silently-no-more truncated to the
    /// context window (surfaced per admission, not hidden)
    pub truncated_admits: u64,
    /// K/V bytes currently resident across all workers' arenas — a
    /// gauge tracking tokens actually in flight, not reserved capacity
    pub kv_bytes_in_flight: u64,
    /// K/V pages recycled through arena free lists (counter)
    pub kv_page_churn: u64,
    /// decode-policy mix: admissions with temperature > 0 vs the
    /// temperature-0 greedy default
    pub sampled_requests: u64,
    pub greedy_requests: u64,
    /// per-token frames actually dispatched to streaming clients
    pub stream_frames_sent: u64,
    /// requests that ran out of wall-clock — failed while queued or
    /// cancelled mid-decode (`timeout_ms` / UNI_LORA_REQUEST_TIMEOUT_MS)
    pub deadline_exceeded: u64,
    /// sequences retired mid-flight via `DecodeSession::cancel`, for
    /// any reason; `deadline_exceeded` and `client_gone` break down the
    /// causes
    pub cancelled: u64,
    /// streaming clients that disconnected mid-generation (their
    /// sequences were cancelled at the next step boundary)
    pub client_gone: u64,
    /// connections rejected at the UNI_LORA_MAX_CONNS accept cap
    pub conns_rejected: u64,
    /// in-flight requests that completed inside the shutdown drain
    /// window vs aborted at its deadline
    pub drained_ok: u64,
    pub drained_aborted: u64,
    /// fault-plan decisions that injected a failure (UNI_LORA_FAULTS;
    /// always 0 in production)
    pub faults_injected: u64,
    pub total_latency_secs: f64,
    pub total_queue_secs: f64,
}

/// Fixed-bucket latency/size histograms carried inside [`RouterStats`].
/// Workers observe under the shared stats mutex, so the bucket counts
/// here are already the exact cross-worker merge ([`Hist::merge`] is
/// plain integer addition — the same totals any per-shard split would
/// merge to).
#[derive(Debug, Clone)]
pub struct RouterHists {
    /// enqueue → first emitted token, seconds
    pub ttft: Hist,
    /// enqueue → admission outcome (admitted or terminally failed at
    /// admit), seconds
    pub queue_wait: Hist,
    /// enqueue → terminal reply, seconds, success or error
    pub latency: Hist,
    /// one fused decode step, seconds
    pub step: Hist,
    /// admitted prompt length, tokens (post-truncation input length)
    pub prompt_tokens: Hist,
}

impl Default for RouterHists {
    fn default() -> RouterHists {
        RouterHists {
            ttft: Hist::latency(),
            queue_wait: Hist::latency(),
            latency: Hist::latency(),
            step: Hist::latency(),
            prompt_tokens: Hist::tokens(),
        }
    }
}

impl RouterStats {
    /// Mean decode slots occupied per step — how full the continuous
    /// batch runs.
    pub fn mean_occupied_slots(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.slot_steps as f64 / self.steps as f64
        }
    }

    /// Record one decode step for throughput accounting. Steps are
    /// noted at completion under one mutex, so `busy_until` sees their
    /// intervals in end-time order and a single watermark computes the
    /// exact union: an interval past the watermark opens a new busy
    /// span, one straddling it extends the span by the uncovered tail,
    /// one fully under it adds nothing.
    pub fn note_decode(&mut self, started: Instant, secs: f64) {
        self.decode_secs += secs;
        self.hists.step.observe(secs);
        let end = started + Duration::from_secs_f64(secs.max(0.0));
        match self.busy_until {
            Some(busy) if started < busy => {
                if end > busy {
                    self.decode_wall_secs += (end - busy).as_secs_f64();
                    self.busy_until = Some(end);
                }
            }
            _ => {
                self.decode_wall_secs += secs.max(0.0);
                self.busy_until = Some(end);
            }
        }
    }

    /// Generated tokens per second of busy decode wall-clock (the
    /// union of step intervals across all workers). Idle stretches
    /// between request bursts are excluded from the denominator — a
    /// long-lived server reports its decode throughput, not its
    /// request arrival rate.
    pub fn tokens_per_sec(&self) -> f64 {
        if self.decode_wall_secs > 0.0 {
            self.generated_tokens as f64 / self.decode_wall_secs
        } else {
            0.0
        }
    }

    /// Mean time-to-first-token, milliseconds.
    pub fn mean_ttft_ms(&self) -> f64 {
        if self.ttft_count == 0 {
            0.0
        } else {
            1000.0 * self.ttft_secs / self.ttft_count as f64
        }
    }

    /// Reconstruction-cache hit rate in [0, 1] (0 when unused).
    pub fn recon_hit_rate(&self) -> f64 {
        let total = self.recon_hits + self.recon_misses;
        if total == 0 {
            0.0
        } else {
            self.recon_hits as f64 / total as f64
        }
    }

    pub fn mean_latency_ms(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            1000.0 * self.total_latency_secs / self.requests as f64
        }
    }
}

/// Fold one worker's session-stat deltas into the router-wide stats.
/// `last` is the worker's previous session snapshot; counters fold as
/// differences, the K/V gauge folds so the router-wide value sums live
/// arenas across workers.
fn fold_deltas(st: &mut RouterStats, now: &SessionStats, last: &mut SessionStats) {
    st.recon_hits += now.recon_hits - last.recon_hits;
    st.recon_misses += now.recon_misses - last.recon_misses;
    st.recon_evictions += now.recon_evictions - last.recon_evictions;
    st.factored_admits += now.factored_admits - last.factored_admits;
    st.dense_admits += now.dense_admits - last.dense_admits;
    st.sampled_requests += now.sampled_admits - last.sampled_admits;
    st.greedy_requests += now.greedy_admits - last.greedy_admits;
    st.cancelled += now.cancelled - last.cancelled;
    st.kv_page_churn += now.kv_page_churn - last.kv_page_churn;
    st.kv_bytes_in_flight =
        (st.kv_bytes_in_flight + now.kv_bytes_in_flight).saturating_sub(last.kv_bytes_in_flight);
    *last = *now;
}

struct Shared {
    queue: Mutex<VecDeque<PendingReq>>,
    cv: Condvar,
    stopped: Mutex<bool>,
    capacity: usize,
    /// drain mode: submissions fail typed, workers stop admitting from
    /// the queue, in-flight sequences keep decoding
    draining: AtomicBool,
    /// the drain deadline expired: workers abort remaining in-flight
    /// sequences at the next step boundary
    hard_stop: AtomicBool,
    /// sequences admitted into a slot but not yet terminally replied to
    in_flight: AtomicUsize,
    /// request-id source: `submit` hands out ids starting at 1, so a
    /// trace consumer can treat 0 as "unassigned"
    next_id: AtomicU64,
}

/// Default pending-request cap (`Router::new`); servers override it via
/// `ServerConfig::with_queue_depth`.
pub const DEFAULT_QUEUE_DEPTH: usize = 256;

/// Retries a sequence gets after a REAL (non-injected) step failure
/// before its request is failed. Injected step faults replay without
/// limit — they are probes of the recovery path, not real failures.
const STEP_RETRIES: u32 = 1;

/// The router owns the queue; each `worker_loop` owns one execution
/// backend plus one decode session. The statics cache is shared across
/// all workers (statics are per-(method, seed): generating and holding
/// them once per adapter, not once per adapter per worker, keeps the
/// multi-adapter residency footprint independent of the pool width) —
/// as is, on the native backend, the adapter-reconstruction cache
/// inside the cloned backends.
pub struct Router {
    shared: Arc<Shared>,
    pub stats: Arc<Mutex<RouterStats>>,
    /// statics keyed by (adapter name, seed): a re-registered adapter
    /// with a new seed generates fresh statics instead of silently
    /// reusing the old seed's (the same staleness class the
    /// reconstruction cache's theta fingerprint guards against)
    statics: Arc<Mutex<HashMap<(String, u64), Arc<Vec<Static>>>>>,
    /// span-event sink shared by every clone; ring-only with the
    /// default capacity unless built via [`Router::with_tracer`]
    trace: Arc<Tracer>,
}

impl Clone for Router {
    fn clone(&self) -> Router {
        Router {
            shared: self.shared.clone(),
            stats: self.stats.clone(),
            statics: self.statics.clone(),
            trace: self.trace.clone(),
        }
    }
}

/// Per-slot bookkeeping a worker keeps alongside its session.
struct SlotBook {
    req: PendingReq,
    tokens: Vec<i32>,
    got_first: bool,
    /// tokens at the head of the re-derived stream to swallow after a
    /// step-failure replay: the client already holds them
    replay_skip: usize,
    /// real step failures this sequence may still absorb
    retries: u32,
}

impl Router {
    pub fn new() -> Router {
        Router::with_capacity(DEFAULT_QUEUE_DEPTH)
    }

    /// A router whose queue holds at most `capacity` pending requests,
    /// tracing into a default ring-only [`Tracer`].
    pub fn with_capacity(capacity: usize) -> Router {
        Router::with_tracer(capacity, Arc::new(Tracer::ring_only(config::DEFAULT_TRACE_RING)))
    }

    /// [`Router::with_capacity`] with an explicit span-event sink —
    /// how `serve` wires `UNI_LORA_TRACE_RING` / `UNI_LORA_TRACE` in.
    pub fn with_tracer(capacity: usize, trace: Arc<Tracer>) -> Router {
        Router {
            shared: Arc::new(Shared {
                queue: Mutex::new(VecDeque::new()),
                cv: Condvar::new(),
                stopped: Mutex::new(false),
                capacity: capacity.max(1),
                draining: AtomicBool::new(false),
                hard_stop: AtomicBool::new(false),
                in_flight: AtomicUsize::new(0),
                next_id: AtomicU64::new(0),
            }),
            stats: Arc::new(Mutex::new(RouterStats::default())),
            statics: Arc::new(Mutex::new(HashMap::new())),
            trace,
        }
    }

    /// The span-event sink this router (and all its clones) records to.
    pub fn tracer(&self) -> &Arc<Tracer> {
        &self.trace
    }

    pub fn capacity(&self) -> usize {
        self.shared.capacity
    }

    /// Enqueue a request. Assigns the request's trace id (ids start at
    /// 1; an id a caller pre-set is kept) and records its `enqueue`
    /// span event. Rejections hand the request back unchanged alongside
    /// the typed error the caller should reply with — and record a
    /// terminal `reject` span event: `busy` when the queue is at
    /// capacity (backpressure instead of unbounded backlog),
    /// `shutting_down` once the router is draining.
    pub fn submit(&self, mut req: PendingReq) -> Result<(), (PendingReq, ServeError)> {
        if req.id == 0 {
            req.id = self.shared.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        }
        self.trace.rec(
            req.id,
            "enqueue",
            None,
            Some(req.prompt.len() as i64),
            Some(req.adapter.as_str()),
        );
        if self.draining() {
            let e = ServeError::shutting_down("server is shutting down");
            self.trace.rec(req.id, "reject", None, None, Some(e.code.as_str()));
            return Err((req, e));
        }
        {
            let mut q = lock_recover(&self.shared.queue);
            if q.len() >= self.shared.capacity {
                drop(q);
                lock_recover(&self.stats).rejected += 1;
                let e = ServeError::busy(format!(
                    "busy: request queue full (depth {})",
                    self.shared.capacity
                ));
                self.trace.rec(req.id, "reject", None, None, Some(e.code.as_str()));
                return Err((req, e));
            }
            q.push_back(req);
        }
        self.shared.cv.notify_one();
        Ok(())
    }

    /// Synchronous convenience: submit and wait for the generation
    /// (greedy — the default sampling policy).
    pub fn generate(
        &self,
        adapter: &str,
        prompt: Vec<i32>,
        max_new: usize,
    ) -> Result<Vec<i32>, ServeError> {
        self.generate_with(adapter, prompt, max_new, SamplingParams::default())
    }

    /// Synchronous convenience: submit with an explicit sampling policy
    /// and wait for the full generation (no streaming — per-token
    /// delivery goes through `submit` with `stream: true`).
    pub fn generate_with(
        &self,
        adapter: &str,
        prompt: Vec<i32>,
        max_new: usize,
        sampling: SamplingParams,
    ) -> Result<Vec<i32>, ServeError> {
        self.generate_deadline(adapter, prompt, max_new, sampling, None)
    }

    /// [`Router::generate_with`] plus an absolute deadline (queue wait
    /// counts against it; `None` = no limit).
    pub fn generate_deadline(
        &self,
        adapter: &str,
        prompt: Vec<i32>,
        max_new: usize,
        sampling: SamplingParams,
        deadline: Option<Instant>,
    ) -> Result<Vec<i32>, ServeError> {
        let (tx, rx) = mpsc::channel();
        let req = PendingReq {
            id: 0,
            adapter: adapter.to_string(),
            prompt,
            max_new,
            sampling,
            stream: false,
            deadline,
            enqueued: Instant::now(),
            reply: tx,
        };
        if let Err((_, e)) = self.submit(req) {
            return Err(e);
        }
        loop {
            match rx.recv() {
                Err(_) => return Err(ServeError::internal("worker dropped the request")),
                Ok(GenEvent::Token(_)) => continue, // defensive: non-stream requests get none
                Ok(GenEvent::Done(out)) => return out,
            }
        }
    }

    pub fn stop(&self) {
        *lock_recover(&self.shared.stopped) = true;
        // hold the condvar's mutex while notifying: a worker between its
        // stopped-check and cv.wait holds this lock for that whole
        // window, so it cannot miss the wakeup (with N workers a missed
        // wakeup would hang shutdown's join)
        let _q = lock_recover(&self.shared.queue);
        self.shared.cv.notify_all();
    }

    /// Enter drain mode: new submissions fail with `shutting_down` and
    /// workers stop admitting queued requests, while in-flight
    /// sequences keep decoding. Irreversible.
    pub fn drain(&self) {
        self.shared.draining.store(true, Ordering::SeqCst);
    }

    pub fn draining(&self) -> bool {
        self.shared.draining.load(Ordering::SeqCst)
    }

    /// Fail every queued (never admitted) request with a typed
    /// `shutting_down` error. Returns how many were failed. Called by
    /// shutdown after [`Router::drain`]; a request a worker popped in
    /// the handoff window is simply treated as in-flight instead.
    pub fn fail_queued(&self) -> usize {
        let drained: Vec<PendingReq> = lock_recover(&self.shared.queue).drain(..).collect();
        let n = drained.len();
        let mut st = lock_recover(&self.stats);
        for req in drained {
            st.requests += 1;
            let lat = req.enqueued.elapsed().as_secs_f64();
            st.total_latency_secs += lat;
            st.hists.latency.observe(lat);
            let code = ErrCode::ShuttingDown.as_str();
            self.trace.rec(req.id, "done", None, Some(0), Some(code));
            let _ = req.reply.send(GenEvent::Done(Err(ServeError::shutting_down(
                "server shutting down: request was queued, not started",
            ))));
        }
        n
    }

    /// Sequences admitted into a slot but not yet terminally replied
    /// to — what a draining shutdown waits on.
    pub fn in_flight(&self) -> usize {
        self.shared.in_flight.load(Ordering::SeqCst)
    }

    /// The drain deadline expired: workers abort their remaining
    /// in-flight sequences (typed `shutting_down` reply) at the next
    /// step boundary.
    pub fn hard_stop(&self) {
        self.shared.hard_stop.store(true, Ordering::SeqCst);
    }

    /// Non-blocking pop — admission at a step boundary while the
    /// session is busy.
    fn try_pop(&self) -> Option<PendingReq> {
        lock_recover(&self.shared.queue).pop_front()
    }

    /// Put a request back at the HEAD of the queue: admission hit a
    /// transient resource limit (K/V token budget, injected admission
    /// fault), so it retries in FIFO position once capacity frees.
    /// Bypasses the capacity check — the request already held its
    /// queue place.
    fn requeue_front(&self, req: PendingReq) {
        lock_recover(&self.shared.queue).push_front(req);
        self.shared.cv.notify_one();
    }

    /// Blocking pop for an idle worker: waits until a request arrives,
    /// or returns None once the router is stopped AND drained.
    fn pop_blocking(&self) -> Option<PendingReq> {
        let mut q = lock_recover(&self.shared.queue);
        loop {
            if let Some(r) = q.pop_front() {
                return Some(r);
            }
            if *lock_recover(&self.shared.stopped) {
                return None;
            }
            q = self.shared.cv.wait(q).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Get-or-generate the statics for an adapter from the cache all
    /// workers share. Generation runs OUTSIDE the cache lock so a
    /// first-touch adapter never stalls workers serving cached ones;
    /// racing workers may generate the same statics once each, and the
    /// first insert wins (gen_statics is deterministic per seed).
    fn statics_for(
        &self,
        name: &str,
        cfg: &ModelCfg,
        seed: u64,
    ) -> Result<Arc<Vec<Static>>, String> {
        let key = (name.to_string(), seed);
        if let Some(s) = lock_recover(&self.statics).get(&key) {
            return Ok(s.clone());
        }
        let fresh = Arc::new(gen_statics(cfg, seed).map_err(|e| e.to_string())?);
        let mut cache = lock_recover(&self.statics);
        Ok(cache.entry(key).or_insert(fresh).clone())
    }

    /// Terminal drain: when a worker cannot decode at all (no session
    /// at startup, or recovery after a poisoned step also fails), it
    /// keeps answering the queue with errors until stop() — exiting
    /// silently would leave queued clients blocked on replies forever.
    fn drain_with_errors(&self, err: &ServeError) {
        while let Some(req) = self.pop_blocking() {
            let mut st = lock_recover(&self.stats);
            st.requests += 1;
            let lat = req.enqueued.elapsed().as_secs_f64();
            st.total_latency_secs += lat;
            st.hists.latency.observe(lat);
            self.trace.rec(req.id, "done", None, Some(0), Some(err.code.as_str()));
            let _ = req.reply.send(GenEvent::Done(Err(err.clone())));
        }
    }

    /// The single terminal-reply point for an ADMITTED sequence:
    /// exactly one `Done` per request, with latency, drain accounting
    /// and the in-flight gauge updated where the reply leaves. Callers
    /// hold the stats lock (`st`) and have already removed the book.
    fn conclude(
        &self,
        st: &mut RouterStats,
        book: SlotBook,
        out: Result<Vec<i32>, ServeError>,
    ) {
        st.requests += 1;
        let lat = book.req.enqueued.elapsed().as_secs_f64();
        st.total_latency_secs += lat;
        st.hists.latency.observe(lat);
        if self.draining() && out.is_ok() {
            st.drained_ok += 1;
        }
        let (nn, note) = match &out {
            Ok(toks) => (toks.len() as i64, "ok"),
            Err(e) => (book.tokens.len() as i64, e.code.as_str()),
        };
        self.trace.rec(book.req.id, "done", None, Some(nn), Some(note));
        self.shared.in_flight.fetch_sub(1, Ordering::SeqCst);
        let _ = book.req.reply.send(GenEvent::Done(out));
    }

    /// Resolve one queued request against the registry and admit it
    /// into a session slot. Failures (unknown adapter, expired
    /// deadline, empty prompt, reconstruction error, oversized K/V
    /// reservation) reply immediately with a typed error — they never
    /// occupy a slot or poison the session. A *transient* K/V-budget
    /// miss (the reservation would fit an empty arena, but live
    /// sequences hold the pages) requeues the request at the queue
    /// head instead, when `can_requeue`; returns `false` in that case
    /// so the caller stops admitting this round (re-popping the same
    /// request would spin). Injected admission faults requeue
    /// unconditionally — they model transient pressure.
    fn admit_req(
        &self,
        sess: &mut dyn DecodeSession,
        books: &mut HashMap<usize, SlotBook>,
        registry: &Registry,
        cfg: &ModelCfg,
        req: PendingReq,
        can_requeue: bool,
        faults: &Faults,
    ) -> bool {
        enum Outcome {
            Admitted(Admission),
            /// payload: requeue cause, recorded as the trace note
            Requeue(&'static str),
            Fail(ServeError),
        }
        let queue_wait = req.enqueued.elapsed().as_secs_f64();
        let outcome = (|| {
            // deadline first: a stale queued request must fail without
            // ever occupying a slot (its wait already exceeded what the
            // client gave the whole request)
            if req.deadline.is_some_and(|d| Instant::now() >= d) {
                return Outcome::Fail(ServeError::deadline_exceeded(
                    "deadline exceeded while queued",
                ));
            }
            if faults.fire(SITE_ADMIT) {
                lock_recover(&self.stats).faults_injected += 1;
                self.trace.rec(req.id, "fault", None, None, Some("admit"));
                return Outcome::Requeue("fault");
            }
            let ckpt = match registry.get(&req.adapter) {
                Some(c) => c,
                None => {
                    return Outcome::Fail(ServeError::unknown_adapter(format!(
                        "unknown adapter {:?}",
                        req.adapter
                    )))
                }
            };
            let statics = match self.statics_for(&req.adapter, cfg, ckpt.seed) {
                Ok(s) => s,
                Err(e) => return Outcome::Fail(ServeError::internal(e)),
            };
            match sess.admit(SeqRequest {
                request_id: req.id,
                adapter: req.adapter.clone(),
                theta: Arc::new(ckpt.theta),
                statics,
                prompt: req.prompt.clone(),
                max_new: req.max_new,
                sampling: req.sampling.clone(),
            }) {
                Ok(adm) => Outcome::Admitted(adm),
                Err(e) => match e.downcast_ref::<KvBudgetExhausted>() {
                    // pages free when live sequences retire; an
                    // admission that can never fit fails permanently
                    Some(b) if can_requeue && b.needed_pages <= b.budget_pages => {
                        Outcome::Requeue("kv_budget")
                    }
                    _ => Outcome::Fail(ServeError::internal(e.to_string())),
                },
            }
        })();
        match outcome {
            Outcome::Admitted(adm) => {
                let plen = req.prompt.len() as i64;
                self.trace.rec(req.id, "admit", Some(adm.slot), Some(plen), None);
                let mut st = lock_recover(&self.stats);
                st.total_queue_secs += queue_wait;
                st.hists.queue_wait.observe(queue_wait);
                st.hists.prompt_tokens.observe(req.prompt.len() as f64);
                if adm.truncated {
                    st.truncated_admits += 1;
                }
                self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
                books.insert(
                    adm.slot,
                    SlotBook {
                        req,
                        tokens: Vec::new(),
                        got_first: false,
                        replay_skip: 0,
                        retries: STEP_RETRIES,
                    },
                );
                true
            }
            Outcome::Requeue(why) => {
                // queue wait keeps accruing from the original enqueue
                self.trace.rec(req.id, "requeue", None, None, Some(why));
                self.requeue_front(req);
                false
            }
            Outcome::Fail(e) => {
                let mut st = lock_recover(&self.stats);
                st.total_queue_secs += queue_wait;
                st.hists.queue_wait.observe(queue_wait);
                st.requests += 1;
                let lat = req.enqueued.elapsed().as_secs_f64();
                st.total_latency_secs += lat;
                st.hists.latency.observe(lat);
                if e.code == ErrCode::DeadlineExceeded {
                    st.deadline_exceeded += 1;
                }
                self.trace.rec(req.id, "done", None, Some(0), Some(e.code.as_str()));
                let _ = req.reply.send(GenEvent::Done(Err(e)));
                true
            }
        }
    }

    /// Re-admit a book into a fresh session after a step failure and
    /// REPLAY it: decode is deterministic, so replaying from the prompt
    /// re-derives the same stream, and `replay_skip` suppresses
    /// re-delivery (and re-counting) of tokens the client already
    /// holds. Re-admission failures conclude the request with a typed
    /// error.
    fn readmit_book(
        &self,
        sess: &mut dyn DecodeSession,
        books: &mut HashMap<usize, SlotBook>,
        registry: &Registry,
        cfg: &ModelCfg,
        mut book: SlotBook,
    ) {
        book.replay_skip = book.tokens.len();
        let skip = book.replay_skip as i64;
        self.trace.rec(book.req.id, "replay", None, Some(skip), None);
        let outcome = (|| {
            let ckpt = registry.get(&book.req.adapter).ok_or_else(|| {
                ServeError::unknown_adapter(format!("unknown adapter {:?}", book.req.adapter))
            })?;
            let statics =
                self.statics_for(&book.req.adapter, cfg, ckpt.seed).map_err(ServeError::internal)?;
            sess.admit(SeqRequest {
                request_id: book.req.id,
                adapter: book.req.adapter.clone(),
                theta: Arc::new(ckpt.theta),
                statics,
                prompt: book.req.prompt.clone(),
                max_new: book.req.max_new,
                sampling: book.req.sampling.clone(),
            })
            .map_err(|e| ServeError::internal(format!("replay re-admission failed: {e}")))
        })();
        match outcome {
            Ok(adm) => {
                let mut st = lock_recover(&self.stats);
                // the replayed admission re-increments the session's
                // per-REQUEST decode-policy counters; it is the same
                // request, so cancel the double count (the original
                // admission was already folded before this replay)
                if book.req.sampling.is_greedy() {
                    st.greedy_requests = st.greedy_requests.saturating_sub(1);
                } else {
                    st.sampled_requests = st.sampled_requests.saturating_sub(1);
                }
                drop(st);
                books.insert(adm.slot, book);
            }
            Err(e) => {
                let mut st = lock_recover(&self.stats);
                self.conclude(&mut st, book, Err(e));
            }
        }
    }

    /// Worker: runs until stop() with the queue drained and no active
    /// sequences. Owns one execution backend and one decode session;
    /// shares the backbone weights, the statics cache and (native) the
    /// reconstruction cache with the other workers. `faults` is the
    /// seeded injection plan ([`Faults::off`] in production).
    pub fn worker_loop(
        &self,
        exec: &mut dyn Backend,
        registry: &Registry,
        art_logits: &str,
        cfg: &ModelCfg,
        w0: &Arc<Vec<f32>>,
        opts: &SessionOpts,
        faults: &Faults,
    ) {
        let mut sess = match exec.begin_decode(art_logits, w0.clone(), opts) {
            Ok(s) => s,
            Err(e) => {
                self.drain_with_errors(&ServeError::internal(format!(
                    "decode session unavailable: {e}"
                )));
                return;
            }
        };
        let mut books: HashMap<usize, SlotBook> = HashMap::new();
        let mut last = sess.stats();
        loop {
            // the drain deadline expired: abort whatever is still in
            // flight with a typed error and exit
            if self.shared.hard_stop.load(Ordering::SeqCst) {
                let mut st = lock_recover(&self.stats);
                let mut slots: Vec<usize> = books.keys().copied().collect();
                slots.sort_unstable();
                for si in slots {
                    sess.cancel(si);
                    let book = books.remove(&si).expect("aborting a live book");
                    st.drained_aborted += 1;
                    self.trace.rec(book.req.id, "cancel", Some(si), None, Some("hard_stop"));
                    self.conclude(
                        &mut st,
                        book,
                        Err(ServeError::shutting_down(
                            "server shutting down: drain deadline expired",
                        )),
                    );
                }
                fold_deltas(&mut st, &sess.stats(), &mut last);
                break;
            }
            // deadline sweep at the step boundary: expired sequences
            // retire immediately — pages recycled, slot reopened —
            // instead of decoding to the end of their budget
            if !books.is_empty() {
                let now = Instant::now();
                let mut expired: Vec<usize> = books
                    .iter()
                    .filter(|(_, b)| b.req.deadline.is_some_and(|d| now >= d))
                    .map(|(&s, _)| s)
                    .collect();
                if !expired.is_empty() {
                    expired.sort_unstable();
                    let mut st = lock_recover(&self.stats);
                    for si in expired {
                        sess.cancel(si);
                        let book = books.remove(&si).expect("expiring a live book");
                        st.deadline_exceeded += 1;
                        let done = book.tokens.len() as i64;
                        self.trace.rec(book.req.id, "deadline", Some(si), Some(done), None);
                        let msg = format!(
                            "deadline exceeded after {} generated token(s)",
                            book.tokens.len()
                        );
                        self.conclude(&mut st, book, Err(ServeError::deadline_exceeded(msg)));
                    }
                    fold_deltas(&mut st, &sess.stats(), &mut last);
                }
            }
            // admission at the step boundary: fill free slots from the
            // queue, blocking only when the session is idle
            if sess.active() == 0 {
                match self.pop_blocking() {
                    None => break, // stopped and drained
                    // an idle session's arena is all free, so a budget
                    // miss here can never be transient: no requeue
                    Some(req) => {
                        self.admit_req(sess.as_mut(), &mut books, registry, cfg, req, false, faults);
                    }
                }
            }
            // while draining, the queue belongs to fail_queued():
            // workers only finish what they already admitted
            if !self.draining() {
                while sess.free_slots() > 0 {
                    match self.try_pop() {
                        Some(req) => {
                            if !self.admit_req(
                                sess.as_mut(),
                                &mut books,
                                registry,
                                cfg,
                                req,
                                true,
                                faults,
                            ) {
                                break; // requeued at the head; step to free pages
                            }
                        }
                        None => break,
                    }
                }
            }
            if sess.active() == 0 {
                continue; // every admission this round failed
            }
            let occupied = sess.active() as u64;
            if faults.fire(SITE_SLOW) {
                lock_recover(&self.stats).faults_injected += 1;
                // worker-scoped events (no single owning request) carry
                // the reserved request id 0
                self.trace.rec(0, "fault", None, None, Some("slow"));
                std::thread::sleep(Duration::from_millis(faults.slow_ms()));
            }
            let injected_step = faults.fire(SITE_STEP);
            if injected_step {
                lock_recover(&self.stats).faults_injected += 1;
                self.trace.rec(0, "fault", None, None, Some("step"));
            }
            let t0 = Instant::now();
            let step_result = if injected_step {
                // the session itself is untouched, but recovery runs
                // the full real path: finish, reopen, replay
                Err(anyhow::anyhow!("injected step fault (UNI_LORA_FAULTS)"))
            } else {
                sess.step(exec)
            };
            let events = match step_result {
                Ok(ev) => ev,
                Err(e) => {
                    // one poisoned step must not take the worker down:
                    // reopen a fresh session and replay the in-flight
                    // sequences into it
                    sess.finish();
                    // post-finish sample: the arena released everything,
                    // so the gauge zeroes and churn counts the releases
                    let fin = sess.stats();
                    {
                        let mut st = lock_recover(&self.stats);
                        fold_deltas(&mut st, &fin, &mut last);
                    }
                    match exec.begin_decode(art_logits, w0.clone(), opts) {
                        Ok(s) => {
                            sess = s;
                            last = sess.stats();
                        }
                        Err(e2) => {
                            // recovery failed too: fail the in-flight
                            // sequences, then keep serving errors rather
                            // than abandoning queued clients
                            let err = ServeError::internal(format!(
                                "decode session unavailable: {e2}"
                            ));
                            let mut st = lock_recover(&self.stats);
                            let mut slots: Vec<usize> = books.keys().copied().collect();
                            slots.sort_unstable();
                            for si in slots {
                                let book = books.remove(&si).expect("failing a live book");
                                self.conclude(&mut st, book, Err(err.clone()));
                            }
                            drop(st);
                            self.drain_with_errors(&err);
                            return;
                        }
                    }
                    // replay in slot order — HashMap order would
                    // reshuffle slot assignment (and the fault plan's
                    // frame-decision stream) across runs
                    let mut old: Vec<(usize, SlotBook)> = books.drain().collect();
                    old.sort_unstable_by_key(|(si, _)| *si);
                    for (_, mut book) in old {
                        if !injected_step {
                            if book.retries == 0 {
                                let mut st = lock_recover(&self.stats);
                                self.conclude(
                                    &mut st,
                                    book,
                                    Err(ServeError::internal(format!("decode step failed: {e}"))),
                                );
                                continue;
                            }
                            book.retries -= 1;
                        }
                        self.readmit_book(sess.as_mut(), &mut books, registry, cfg, book);
                    }
                    continue;
                }
            };
            let step_secs = t0.elapsed().as_secs_f64();
            let snow = sess.stats();
            let mut st = lock_recover(&self.stats);
            st.steps += 1;
            st.slot_steps += occupied;
            st.note_decode(t0, step_secs);
            fold_deltas(&mut st, &snow, &mut last);
            for ev in events {
                let Some(book) = books.get_mut(&ev.slot) else { continue };
                // the id threaded through SeqRequest::request_id must
                // come back on this slot's events — a mismatch means the
                // session reassigned a slot without the router noticing
                debug_assert_eq!(ev.req, book.req.id, "session event on the wrong request");
                let mut lost_client = false;
                if let Some(tok) = ev.token {
                    if book.replay_skip > 0 {
                        // replayed token: the client already holds it —
                        // no frame, no TTFT, no recount
                        book.replay_skip -= 1;
                    } else {
                        if !book.got_first {
                            // for streaming requests the frame dispatch
                            // is the next statement, so this ttft IS
                            // time-to-first-byte
                            book.got_first = true;
                            let ttft = book.req.enqueued.elapsed().as_secs_f64();
                            st.ttft_secs += ttft;
                            st.ttft_count += 1;
                            st.hists.ttft.observe(ttft);
                            self.trace.rec(book.req.id, "prefill", Some(ev.slot), None, None);
                        }
                        self.trace.rec(book.req.id, "step", Some(ev.slot), Some(tok as i64), None);
                        if book.req.stream {
                            if faults.fire(SITE_FRAME) {
                                // injected "client disconnected": the
                                // frame write failed
                                st.faults_injected += 1;
                                let id = book.req.id;
                                self.trace.rec(id, "fault", Some(ev.slot), None, Some("frame"));
                                lost_client = true;
                            } else if book.req.reply.send(GenEvent::Token(tok)).is_ok() {
                                st.stream_frames_sent += 1;
                                let id = book.req.id;
                                self.trace.rec(id, "frame", Some(ev.slot), Some(tok as i64), None);
                            } else {
                                // the stream handler dropped its
                                // receiver: the TCP client is gone
                                lost_client = true;
                            }
                        }
                        book.tokens.push(tok);
                        st.generated_tokens += 1;
                    }
                }
                if lost_client {
                    if !ev.done {
                        sess.cancel(ev.slot);
                    }
                    let book = books.remove(&ev.slot).expect("cancelling a live book");
                    st.client_gone += 1;
                    let id = book.req.id;
                    self.trace.rec(id, "cancel", Some(ev.slot), None, Some("client_gone"));
                    self.conclude(
                        &mut st,
                        book,
                        Err(ServeError::client_gone("client disconnected mid-stream")),
                    );
                    continue;
                }
                if ev.done {
                    let mut book = books.remove(&ev.slot).expect("book exists for finished slot");
                    let tokens = std::mem::take(&mut book.tokens);
                    self.conclude(&mut st, book, Ok(tokens));
                }
            }
        }
        sess.finish();
        // trailing fold: cancels from the final iterations and the
        // finish() releases zero the gauge and land the last counters
        let fin = sess.stats();
        let mut st = lock_recover(&self.stats);
        fold_deltas(&mut st, &fin, &mut last);
    }
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(adapter: &str, tx: &mpsc::Sender<GenEvent>) -> PendingReq {
        PendingReq {
            id: 0,
            adapter: adapter.into(),
            prompt: vec![1],
            max_new: 1,
            sampling: SamplingParams::default(),
            stream: false,
            deadline: None,
            enqueued: Instant::now(),
            reply: tx.clone(),
        }
    }

    #[test]
    fn queue_pops_fifo_across_adapters() {
        let r = Router::new();
        let (tx, _rx) = mpsc::channel();
        for a in ["x", "y", "x", "z"] {
            r.submit(req(a, &tx)).unwrap();
        }
        // continuous batching admits strictly FIFO — no adapter
        // coalescing reordering (slots hold heterogeneous adapters)
        let order: Vec<String> = (0..4).map(|_| r.try_pop().unwrap().adapter).collect();
        assert_eq!(order, ["x", "y", "x", "z"]);
        assert!(r.try_pop().is_none());
    }

    /// Satellite: saturate the bounded queue — submits past capacity
    /// are rejected with a protocol-visible typed `busy` error and
    /// counted.
    #[test]
    fn bounded_queue_rejects_when_saturated() {
        let r = Router::with_capacity(2);
        assert_eq!(r.capacity(), 2);
        let (tx, _rx) = mpsc::channel();
        assert!(r.submit(req("x", &tx)).is_ok());
        assert!(r.submit(req("x", &tx)).is_ok());
        // full: the request comes back unchanged, with the typed error
        let (back, err) = r.submit(req("y", &tx)).unwrap_err();
        assert_eq!(back.adapter, "y");
        assert_eq!(err.code, ErrCode::Busy);
        // the sync API surfaces the same typed rejection
        let err = r.generate("z", vec![1], 1).unwrap_err();
        assert_eq!(err.code, ErrCode::Busy);
        assert!(err.msg.starts_with("busy"), "{err}");
        assert_eq!(r.stats.lock().unwrap().rejected, 2);
        // draining the queue frees capacity again
        assert!(r.try_pop().is_some());
        assert!(r.try_pop().is_some());
        assert!(r.submit(req("x", &tx)).is_ok());
    }

    #[test]
    fn stop_unblocks_idle_workers() {
        let r = Router::new();
        let r2 = r.clone();
        let h = std::thread::spawn(move || r2.pop_blocking());
        std::thread::sleep(std::time::Duration::from_millis(30));
        r.stop();
        assert!(h.join().unwrap().is_none());
    }

    /// Draining flips submissions to typed `shutting_down` rejections
    /// (NOT counted as busy) and `fail_queued` answers everything
    /// already queued.
    #[test]
    fn drain_fails_queued_and_rejects_new_submissions() {
        let r = Router::new();
        let (tx, rx) = mpsc::channel();
        r.submit(req("x", &tx)).unwrap();
        assert!(!r.draining());
        r.drain();
        assert!(r.draining());
        let (_, e) = r.submit(req("y", &tx)).unwrap_err();
        assert_eq!(e.code, ErrCode::ShuttingDown);
        assert_eq!(r.fail_queued(), 1);
        match rx.recv().unwrap() {
            GenEvent::Done(Err(e)) => assert_eq!(e.code, ErrCode::ShuttingDown),
            other => panic!("queued request must fail typed: {other:?}"),
        }
        let st = r.stats.lock().unwrap();
        assert_eq!(st.requests, 1, "the failed request still counts as replied");
        assert_eq!(st.rejected, 0, "rejected counts backpressure, not shutdown");
    }

    /// Satellite: a worker panicking while holding the stats lock must
    /// not wedge the router — `lock_recover` adopts the poisoned state
    /// and every router operation keeps working.
    #[test]
    fn stats_lock_recovers_after_poisoning_panic() {
        let r = Router::new();
        let r2 = r.clone();
        let joined = std::thread::spawn(move || {
            let _g = r2.stats.lock().unwrap();
            panic!("poison the stats lock");
        })
        .join();
        assert!(joined.is_err(), "the poisoning thread must have panicked");
        assert!(r.stats.lock().is_err(), "the lock must actually be poisoned");
        lock_recover(&r.stats).rejected += 1;
        assert_eq!(lock_recover(&r.stats).rejected, 1, "counters survive the panic");
        // the full submit path crosses the poisoned stats mutex when
        // it rejects; exercise accept + pop too
        let (tx, _rx) = mpsc::channel();
        r.submit(req("x", &tx)).unwrap();
        assert!(r.try_pop().is_some());
        let rr = Router::with_capacity(1);
        let _ = std::thread::spawn({
            let rr = rr.clone();
            move || {
                let _g = rr.stats.lock().unwrap();
                panic!("poison");
            }
        })
        .join();
        rr.submit(req("a", &tx)).unwrap();
        let (_, e) = rr.submit(req("b", &tx)).unwrap_err();
        assert_eq!(e.code, ErrCode::Busy, "rejection path survives poisoning");
        assert_eq!(lock_recover(&rr.stats).rejected, 1);
    }

    /// A re-registered adapter (same name, new seed) must get fresh
    /// statics — the cache validates the seed, not just the name.
    #[test]
    fn statics_cache_keys_on_seed() {
        let r = Router::new();
        let cfg = ModelCfg::test_base("uni");
        let s1 = r.statics_for("a", &cfg, 1).unwrap();
        let s1b = r.statics_for("a", &cfg, 1).unwrap();
        assert!(Arc::ptr_eq(&s1, &s1b), "same (name, seed) must share");
        let s2 = r.statics_for("a", &cfg, 2).unwrap();
        assert!(!Arc::ptr_eq(&s1, &s2), "new seed must regenerate");
    }

    #[test]
    fn pop_blocking_drains_before_stopping() {
        let r = Router::new();
        let (tx, _rx) = mpsc::channel();
        r.submit(req("x", &tx)).unwrap();
        r.stop();
        // a queued request still comes out after stop; then None
        assert!(r.pop_blocking().is_some());
        assert!(r.pop_blocking().is_none());
    }

    /// Force eviction churn through a worker: a 1-entry recon cache
    /// serving 3 adapters pinned dense (threshold 1) must surface
    /// evictions and an all-dense admission mix in `RouterStats`; the
    /// same workload pinned factored surfaces the opposite mix and
    /// never touches the dense cache.
    #[test]
    fn worker_surfaces_eviction_churn_and_mode_mix() {
        use crate::adapters::AdapterCheckpoint;
        use crate::runtime::NativeBackend;

        const ART: &str = "lm_uni_lm_logits";
        let run = |opts: SessionOpts| -> (RouterStats, u64) {
            let mut be = NativeBackend::with_recon_cache(1).unwrap();
            let cache = be.recon_cache();
            let meta = be.meta(ART).unwrap().clone();
            let cfg = meta.cfg.clone();
            let w0 = Arc::new(crate::coordinator::init_base(&meta, 9));
            let registry = Arc::new(Registry::new());
            for i in 0..3u64 {
                let theta: Vec<f32> =
                    crate::rng::normals(100 + i, crate::projection::statics::d_effective(&cfg))
                        .iter()
                        .map(|v| 0.05 * v)
                        .collect();
                registry.insert(
                    format!("a{i}"),
                    AdapterCheckpoint {
                        seed: 7,
                        method: cfg.method.clone(),
                        artifact: ART.into(),
                        theta,
                        head: vec![],
                    },
                );
            }
            let r = Router::new();
            let worker = {
                let r = r.clone();
                let registry = registry.clone();
                let cfg = cfg.clone();
                let w0 = w0.clone();
                std::thread::spawn(move || {
                    r.worker_loop(&mut be, &registry, ART, &cfg, &w0, &opts, &Faults::off())
                })
            };
            for round in 0..2 {
                for i in 0..3 {
                    let out = r.generate(&format!("a{i}"), vec![1, 2, 3], 2);
                    assert!(out.is_ok(), "round {round} adapter a{i}: {out:?}");
                }
            }
            r.stop();
            worker.join().unwrap();
            let st = r.stats.lock().unwrap().clone();
            (st, cache.evictions())
        };

        // pinned dense: every admission densifies; cycling 3 adapters
        // through a 1-entry cache evicts on every adapter switch
        let (st, cache_evictions) = run(SessionOpts::with_slots(1).with_dense_threshold(1));
        assert_eq!(st.requests, 6);
        assert_eq!((st.dense_admits, st.factored_admits), (6, 0));
        // decode-policy mix: everything above ran the greedy default
        assert_eq!((st.greedy_requests, st.sampled_requests), (6, 0));
        assert_eq!(st.stream_frames_sent, 0, "no streaming clients here");
        assert!(st.recon_evictions >= 1, "cycling adapters must evict: {st:?}");
        assert_eq!(st.recon_evictions, cache_evictions);
        assert_eq!(st.recon_hits, 0, "a 1-entry cache cycling 3 adapters never hits");
        // paged K/V accounting: every retired sequence recycled its
        // pages, and nothing is in flight once the worker drains
        assert!(st.kv_page_churn >= 6, "6 retirements must churn pages: {st:?}");
        assert_eq!(st.kv_bytes_in_flight, 0, "drained worker holds no K/V: {st:?}");
        assert_eq!(st.truncated_admits, 0);
        // lifecycle counters stay untouched on the clean path
        assert_eq!(st.faults_injected, 0);
        assert_eq!((st.deadline_exceeded, st.cancelled, st.client_gone), (0, 0, 0));

        // pinned factored: no admission ever touches the dense cache
        let factored_opts = SessionOpts::with_slots(1).with_dense_threshold(usize::MAX);
        let (st, cache_evictions) = run(factored_opts);
        assert_eq!(st.requests, 6);
        assert_eq!((st.dense_admits, st.factored_admits), (0, 6));
        assert_eq!((st.recon_evictions, cache_evictions), (0, 0));
        assert_eq!((st.recon_hits, st.recon_misses), (0, 0));
    }

    /// A K/V token budget of one page under two decode slots turns the
    /// second concurrent admission into backpressure, not failure: the
    /// request requeues at the queue head until pages free, and every
    /// request still completes in order.
    #[test]
    fn worker_requeues_on_transient_kv_budget_exhaustion() {
        use crate::adapters::AdapterCheckpoint;
        use crate::runtime::NativeBackend;

        const ART: &str = "lm_uni_lm_logits";
        let mut be = NativeBackend::new().unwrap();
        let meta = be.meta(ART).unwrap().clone();
        let cfg = meta.cfg.clone();
        let w0 = Arc::new(crate::coordinator::init_base(&meta, 9));
        let registry = Arc::new(Registry::new());
        let theta: Vec<f32> =
            crate::rng::normals(55, crate::projection::statics::d_effective(&cfg))
                .iter()
                .map(|v| 0.05 * v)
                .collect();
        registry.insert(
            "a".to_string(),
            AdapterCheckpoint {
                seed: 7,
                method: cfg.method.clone(),
                artifact: ART.into(),
                theta,
                head: vec![],
            },
        );
        // queue three requests BEFORE the worker starts, so the second
        // admission deterministically hits the exhausted budget while
        // the first sequence is live
        let r = Router::new();
        let mut rxs = Vec::new();
        for _ in 0..3 {
            let (tx, rx) = mpsc::channel();
            r.submit(PendingReq {
                id: 0,
                adapter: "a".into(),
                prompt: vec![1, 2, 3],
                max_new: 2,
                sampling: SamplingParams::default(),
                stream: false,
                deadline: None,
                enqueued: Instant::now(),
                reply: tx,
            })
            .unwrap();
            rxs.push(rx);
        }
        let opts = SessionOpts::with_slots(2).with_kv_pages(1);
        let worker = {
            let r = r.clone();
            let registry = registry.clone();
            let cfg = cfg.clone();
            let w0 = w0.clone();
            std::thread::spawn(move || {
                r.worker_loop(&mut be, &registry, ART, &cfg, &w0, &opts, &Faults::off())
            })
        };
        for rx in rxs {
            match rx.recv().unwrap() {
                GenEvent::Done(out) => {
                    assert!(out.is_ok(), "budget pressure must delay, not fail: {out:?}");
                }
                other => panic!("buffered request got a stream event: {other:?}"),
            }
        }
        r.stop();
        worker.join().unwrap();
        let st = r.stats.lock().unwrap().clone();
        assert_eq!(st.requests, 3);
        assert_eq!(st.kv_bytes_in_flight, 0, "{st:?}");
        assert!(st.kv_page_churn >= 3, "{st:?}");
    }

    /// Tentpole: injected step faults are recovered by session replay —
    /// the fault plan trips repeatedly (seed 7 fires the step site on
    /// its very first draws at rate 0.2), yet every request completes
    /// with EXACTLY the tokens a fault-free run produces, because
    /// decode re-derives the same streams and `replay_skip` suppresses
    /// re-delivery.
    #[test]
    fn worker_replays_after_injected_step_faults() {
        use crate::adapters::AdapterCheckpoint;
        use crate::runtime::NativeBackend;

        const ART: &str = "lm_uni_lm_logits";
        let run = |spec: Option<&'static str>| -> (Vec<Vec<i32>>, RouterStats) {
            let mut be = NativeBackend::new().unwrap();
            let meta = be.meta(ART).unwrap().clone();
            let cfg = meta.cfg.clone();
            let w0 = Arc::new(crate::coordinator::init_base(&meta, 9));
            let registry = Arc::new(Registry::new());
            let theta: Vec<f32> =
                crate::rng::normals(55, crate::projection::statics::d_effective(&cfg))
                    .iter()
                    .map(|v| 0.05 * v)
                    .collect();
            registry.insert(
                "a".to_string(),
                AdapterCheckpoint {
                    seed: 7,
                    method: cfg.method.clone(),
                    artifact: ART.into(),
                    theta,
                    head: vec![],
                },
            );
            // pre-queue everything so admission order (and thus the
            // fault-decision stream) is identical across runs
            let r = Router::new();
            let mut rxs = Vec::new();
            for i in 0..6i32 {
                let (tx, rx) = mpsc::channel();
                r.submit(PendingReq {
                    id: 0,
                    adapter: "a".into(),
                    prompt: vec![1, 2, 3 + (i % 3)],
                    max_new: 1 + (i as usize % 3),
                    sampling: SamplingParams::default(),
                    stream: false,
                    deadline: None,
                    enqueued: Instant::now(),
                    reply: tx,
                })
                .unwrap();
                rxs.push(rx);
            }
            let opts = SessionOpts::with_slots(2);
            let worker = {
                let r = r.clone();
                let registry = registry.clone();
                let cfg = cfg.clone();
                let w0 = w0.clone();
                std::thread::spawn(move || {
                    let faults = match spec {
                        Some(s) => Faults::parse(s).unwrap(),
                        None => Faults::off(),
                    };
                    r.worker_loop(&mut be, &registry, ART, &cfg, &w0, &opts, &faults)
                })
            };
            let mut outs = Vec::new();
            for rx in rxs {
                match rx.recv().unwrap() {
                    GenEvent::Done(out) => {
                        outs.push(out.expect("injected faults must be recovered, not surfaced"))
                    }
                    other => panic!("buffered request got a stream event: {other:?}"),
                }
            }
            r.stop();
            worker.join().unwrap();
            let st = r.stats.lock().unwrap().clone();
            (outs, st)
        };

        let (clean, clean_st) = run(None);
        assert_eq!(clean_st.faults_injected, 0);
        let (faulted, st) = run(Some("7:step=0.2"));
        assert!(st.faults_injected >= 1, "seed 7 fires the step site early: {st:?}");
        assert_eq!(st.requests, 6);
        assert_eq!(
            clean, faulted,
            "replay must reproduce the fault-free streams bit-identically"
        );
        assert_eq!(st.kv_bytes_in_flight, 0, "replayed arenas drain too: {st:?}");
    }

    #[test]
    fn stats_derived_metrics() {
        let mut st = RouterStats::default();
        // zero denominators are all defined
        assert_eq!(st.mean_occupied_slots(), 0.0);
        assert_eq!(st.tokens_per_sec(), 0.0);
        assert_eq!(st.mean_ttft_ms(), 0.0);
        assert_eq!(st.recon_hit_rate(), 0.0);
        assert_eq!(st.mean_latency_ms(), 0.0);
        st.steps = 4;
        st.slot_steps = 10;
        st.generated_tokens = 50;
        st.ttft_count = 2;
        st.ttft_secs = 0.5;
        st.recon_hits = 3;
        st.recon_misses = 1;
        st.requests = 5;
        st.total_latency_secs = 1.0;
        assert!((st.mean_occupied_slots() - 2.5).abs() < 1e-12);
        assert!((st.mean_ttft_ms() - 250.0).abs() < 1e-12);
        assert!((st.recon_hit_rate() - 0.75).abs() < 1e-12);
        assert!((st.mean_latency_ms() - 200.0).abs() < 1e-12);
        // throughput uses the WALL span of decode activity, so two
        // workers decoding concurrently (overlapping steps) add
        // throughput instead of halving it
        let t0 = Instant::now();
        st.note_decode(t0, 2.0); // worker A: [0, 2]
        st.note_decode(t0, 2.0); // worker B: [0, 2], concurrent
        assert!((st.decode_secs - 4.0).abs() < 1e-9, "summed effort");
        assert!((st.decode_wall_secs - 2.0).abs() < 1e-9, "overlap counts once");
        assert!((st.tokens_per_sec() - 25.0).abs() < 1e-6, "50 tok over a 2s wall span");
        assert_eq!(st.hists.step.count(), 2, "note_decode feeds the step histogram");
    }

    /// Satellite: idle stretches between decode bursts must not dilute
    /// throughput — `decode_wall_secs` is the union of step intervals,
    /// not first-step..last-step (which on a long-lived server would
    /// grow with uptime and drive tokens/s toward the arrival rate).
    #[test]
    fn tokens_per_sec_ignores_idle_gaps() {
        let mut st = RouterStats::default();
        st.generated_tokens = 30;
        let t0 = Instant::now();
        st.note_decode(t0, 1.0); // [0, 1]
        st.note_decode(t0 + Duration::from_secs(10), 2.0); // [10, 12]: 9s idle gap
        assert!((st.decode_wall_secs - 3.0).abs() < 1e-9, "gap excluded: {st:?}");
        assert!((st.tokens_per_sec() - 10.0).abs() < 1e-6);
        // straddling the watermark adds only the uncovered tail
        st.note_decode(t0 + Duration::from_secs(11), 2.0); // [11, 13]
        assert!((st.decode_wall_secs - 4.0).abs() < 1e-9, "tail only: {st:?}");
        // an interval fully under the watermark adds nothing
        st.note_decode(t0 + Duration::from_secs(11), 1.0); // [11, 12]
        assert!((st.decode_wall_secs - 4.0).abs() < 1e-9, "covered: {st:?}");
        assert!((st.decode_secs - 6.0).abs() < 1e-9, "effort still sums every step");
    }
}
