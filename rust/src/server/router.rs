//! Adapter-aware request router: forms batches of requests that share an
//! adapter (so one decode pass serves the whole batch), hot-swapping the
//! per-batch theta vector. The batching policy is greedy same-adapter
//! coalescing up to the artifact batch size — the policy knob the
//! serving bench sweeps.
//!
//! The queue is bounded: past `capacity` pending requests, `submit`
//! rejects immediately and `generate` surfaces a protocol-level
//! "busy: ..." error instead of letting the backlog (and client
//! latency) grow without limit. Any number of worker threads may drain
//! the queue concurrently (`server::serve` runs one `worker_loop` per
//! execution worker, each owning a backend clone).

use crate::adapters::Registry;
use crate::config::ModelCfg;
use crate::coordinator::trainer::decode_with;
use crate::projection::statics::{gen_statics, Static};
use crate::runtime::Backend;
use anyhow::Result;
use std::collections::{HashMap, VecDeque};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

#[derive(Debug)]
pub struct PendingReq {
    pub adapter: String,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<Result<Vec<i32>, String>>,
}

#[derive(Debug, Default, Clone)]
pub struct RouterStats {
    pub requests: u64,
    pub batches: u64,
    pub batched_requests: u64,
    /// requests rejected at submit time because the queue was full
    pub rejected: u64,
    pub total_latency_secs: f64,
    pub total_queue_secs: f64,
}

impl RouterStats {
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_requests as f64 / self.batches as f64
        }
    }

    pub fn mean_latency_ms(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            1000.0 * self.total_latency_secs / self.requests as f64
        }
    }
}

struct Shared {
    queue: Mutex<VecDeque<PendingReq>>,
    cv: Condvar,
    stopped: Mutex<bool>,
    capacity: usize,
}

/// Default pending-request cap (`Router::new`); servers override it via
/// `ServerConfig::with_queue_depth`.
pub const DEFAULT_QUEUE_DEPTH: usize = 256;

/// The router owns the queue; each `worker_loop` owns one execution
/// backend. The statics cache is shared across all workers (statics
/// are per-(method, seed): generating and holding them once per
/// adapter, not once per adapter per worker, keeps the multi-adapter
/// residency footprint independent of the pool width).
pub struct Router {
    shared: Arc<Shared>,
    pub stats: Arc<Mutex<RouterStats>>,
    statics: Arc<Mutex<HashMap<String, Arc<Vec<Static>>>>>,
}

impl Clone for Router {
    fn clone(&self) -> Router {
        Router {
            shared: self.shared.clone(),
            stats: self.stats.clone(),
            statics: self.statics.clone(),
        }
    }
}

impl Router {
    pub fn new() -> Router {
        Router::with_capacity(DEFAULT_QUEUE_DEPTH)
    }

    /// A router whose queue holds at most `capacity` pending requests.
    pub fn with_capacity(capacity: usize) -> Router {
        Router {
            shared: Arc::new(Shared {
                queue: Mutex::new(VecDeque::new()),
                cv: Condvar::new(),
                stopped: Mutex::new(false),
                capacity: capacity.max(1),
            }),
            stats: Arc::new(Mutex::new(RouterStats::default())),
            statics: Arc::new(Mutex::new(HashMap::new())),
        }
    }

    pub fn capacity(&self) -> usize {
        self.shared.capacity
    }

    /// Enqueue a request. When the queue is at capacity the request is
    /// handed back unchanged (backpressure: the caller replies "busy"
    /// instead of the backlog growing without bound).
    pub fn submit(&self, req: PendingReq) -> Result<(), PendingReq> {
        {
            let mut q = self.shared.queue.lock().unwrap();
            if q.len() >= self.shared.capacity {
                drop(q);
                self.stats.lock().unwrap().rejected += 1;
                return Err(req);
            }
            q.push_back(req);
        }
        self.shared.cv.notify_one();
        Ok(())
    }

    /// Synchronous convenience: submit and wait for the generation.
    pub fn generate(
        &self,
        adapter: &str,
        prompt: Vec<i32>,
        max_new: usize,
    ) -> Result<Vec<i32>, String> {
        let (tx, rx) = mpsc::channel();
        let req = PendingReq {
            adapter: adapter.to_string(),
            prompt,
            max_new,
            enqueued: Instant::now(),
            reply: tx,
        };
        if self.submit(req).is_err() {
            return Err(format!("busy: request queue full (depth {})", self.shared.capacity));
        }
        rx.recv().map_err(|e| e.to_string())?
    }

    pub fn stop(&self) {
        *self.shared.stopped.lock().unwrap() = true;
        // hold the condvar's mutex while notifying: a worker between its
        // stopped-check and cv.wait holds this lock for that whole
        // window, so it cannot miss the wakeup (with N workers a missed
        // wakeup would hang shutdown's join)
        let _q = self.shared.queue.lock().unwrap();
        self.shared.cv.notify_all();
    }

    /// Pop the next same-adapter batch (blocks; None on stop).
    fn next_batch(&self, max_batch: usize) -> Option<Vec<PendingReq>> {
        let mut q = self.shared.queue.lock().unwrap();
        loop {
            if *self.shared.stopped.lock().unwrap() && q.is_empty() {
                return None;
            }
            if let Some(first) = q.front() {
                let adapter = first.adapter.clone();
                let mut batch = vec![q.pop_front().unwrap()];
                let mut i = 0;
                while i < q.len() && batch.len() < max_batch {
                    if q[i].adapter == adapter {
                        batch.push(q.remove(i).unwrap());
                    } else {
                        i += 1;
                    }
                }
                return Some(batch);
            }
            q = self.shared.cv.wait(q).unwrap();
        }
    }

    /// Get-or-generate the statics for an adapter from the cache all
    /// workers share. Generation runs OUTSIDE the cache lock so a
    /// first-touch adapter never stalls workers serving cached ones;
    /// racing workers may generate the same statics once each, and the
    /// first insert wins (gen_statics is deterministic per seed).
    fn statics_for(
        &self,
        name: &str,
        cfg: &ModelCfg,
        seed: u64,
    ) -> Result<Arc<Vec<Static>>, String> {
        if let Some(s) = self.statics.lock().unwrap().get(name) {
            return Ok(s.clone());
        }
        let fresh = Arc::new(gen_statics(cfg, seed).map_err(|e| e.to_string())?);
        let mut cache = self.statics.lock().unwrap();
        Ok(cache.entry(name.to_string()).or_insert(fresh).clone())
    }

    /// Worker: runs until stop(). Owns one execution backend; shares
    /// the backbone weights and statics cache with the other workers.
    pub fn worker_loop(
        &self,
        exec: &mut dyn Backend,
        registry: &Registry,
        art_logits: &str,
        cfg: &ModelCfg,
        w0: &[f32],
    ) {
        while let Some(batch) = self.next_batch(cfg.batch) {
            let adapter_name = batch[0].adapter.clone();
            let queue_wait: f64 = batch
                .iter()
                .map(|r| r.enqueued.elapsed().as_secs_f64())
                .sum();
            let result = (|| -> Result<Vec<Vec<i32>>, String> {
                let ckpt = registry
                    .get(&adapter_name)
                    .ok_or_else(|| format!("unknown adapter {adapter_name:?}"))?;
                let stats = self.statics_for(&adapter_name, cfg, ckpt.seed)?;
                let prompts: Vec<Vec<i32>> = batch.iter().map(|r| r.prompt.clone()).collect();
                let max_new = batch.iter().map(|r| r.max_new).max().unwrap_or(8);
                decode_with(exec, art_logits, cfg, &ckpt.theta, w0, &stats, &prompts, max_new)
                    .map_err(|e| e.to_string())
            })();
            let mut st = self.stats.lock().unwrap();
            st.batches += 1;
            st.batched_requests += batch.len() as u64;
            st.requests += batch.len() as u64;
            st.total_queue_secs += queue_wait;
            for (k, req) in batch.into_iter().enumerate() {
                st.total_latency_secs += req.enqueued.elapsed().as_secs_f64();
                let reply = match &result {
                    Ok(gens) => Ok(gens[k].clone()),
                    Err(e) => Err(e.clone()),
                };
                let _ = req.reply.send(reply);
            }
        }
    }
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(adapter: &str, tx: &mpsc::Sender<Result<Vec<i32>, String>>) -> PendingReq {
        PendingReq {
            adapter: adapter.into(),
            prompt: vec![1],
            max_new: 1,
            enqueued: Instant::now(),
            reply: tx.clone(),
        }
    }

    #[test]
    fn batches_coalesce_same_adapter() {
        let r = Router::new();
        let (tx, _rx) = mpsc::channel();
        for a in ["x", "y", "x", "x", "y"] {
            r.submit(req(a, &tx)).unwrap();
        }
        let b1 = r.next_batch(8).unwrap();
        assert_eq!(b1.len(), 3);
        assert!(b1.iter().all(|q| q.adapter == "x"));
        let b2 = r.next_batch(8).unwrap();
        assert_eq!(b2.len(), 2);
        assert!(b2.iter().all(|q| q.adapter == "y"));
    }

    #[test]
    fn batch_size_cap() {
        let r = Router::new();
        let (tx, _rx) = mpsc::channel();
        for _ in 0..10 {
            r.submit(req("x", &tx)).unwrap();
        }
        assert_eq!(r.next_batch(4).unwrap().len(), 4);
        assert_eq!(r.next_batch(4).unwrap().len(), 4);
        assert_eq!(r.next_batch(4).unwrap().len(), 2);
    }

    /// Satellite: saturate the bounded queue — submits past capacity
    /// are rejected with a protocol-visible "busy" error and counted.
    #[test]
    fn bounded_queue_rejects_when_saturated() {
        let r = Router::with_capacity(2);
        assert_eq!(r.capacity(), 2);
        let (tx, _rx) = mpsc::channel();
        assert!(r.submit(req("x", &tx)).is_ok());
        assert!(r.submit(req("x", &tx)).is_ok());
        // full: the request comes back unchanged
        let back = r.submit(req("y", &tx)).unwrap_err();
        assert_eq!(back.adapter, "y");
        // the sync API maps the rejection to a "busy" error string
        let err = r.generate("z", vec![1], 1).unwrap_err();
        assert!(err.starts_with("busy"), "{err}");
        assert_eq!(r.stats.lock().unwrap().rejected, 2);
        // draining the queue frees capacity again
        assert_eq!(r.next_batch(8).unwrap().len(), 2);
        assert!(r.submit(req("x", &tx)).is_ok());
    }

    #[test]
    fn stop_unblocks() {
        let r = Router::new();
        let r2 = r.clone();
        let h = std::thread::spawn(move || r2.next_batch(4));
        std::thread::sleep(std::time::Duration::from_millis(30));
        r.stop();
        assert!(h.join().unwrap().is_none());
    }
}
