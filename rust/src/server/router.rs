//! Adapter-aware request router with **continuous batching**: each
//! worker owns a decode session (`Backend::begin_decode`) and admits
//! queued requests into free decode slots at step boundaries, retiring
//! finished sequences per step — no request waits for a whole greedy
//! batch to drain, and slots can hold a heterogeneous mix of adapters
//! (the native session decodes each slot with its own reconstructed
//! weights from the shared reconstruction cache).
//!
//! The queue is bounded: past `capacity` pending requests, `submit`
//! rejects immediately and `generate` surfaces a protocol-level
//! "busy: ..." error instead of letting the backlog (and client
//! latency) grow without limit. Any number of worker threads may drain
//! the queue concurrently (`server::serve` runs one `worker_loop` per
//! execution worker, each owning a backend clone and its own session).
//!
//! Requests carry a per-request [`SamplingParams`] (temperature 0 —
//! exact greedy — by default) and may opt into **streaming**: the
//! worker dispatches one [`GenEvent::Token`] per emitted token at the
//! step boundary that produced it, so a streaming client's first byte
//! arrives mid-decode instead of after the sequence finishes.
//!
//! Serving-quality accounting lives in [`RouterStats`]: tokens/s,
//! time-to-first-token (measured at first-frame dispatch for streamed
//! requests), reconstruction-cache hit rate, decode-policy mix and
//! decode-slot occupancy, all surfaced through the protocol `stats` op.

use crate::adapters::Registry;
use crate::config::ModelCfg;
use crate::generation::SamplingParams;
use crate::projection::statics::{gen_statics, Static};
use crate::runtime::Backend;
use crate::runtime::native::kv_arena::KvBudgetExhausted;
use crate::session::{Admission, DecodeSession, SeqRequest, SessionOpts};
use anyhow::Result;
use std::collections::{HashMap, VecDeque};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// One reply-channel event for a pending request. Buffered requests
/// receive a single `Done`; streaming requests (`PendingReq::stream`)
/// additionally receive one `Token` per emitted token, dispatched at
/// the step boundary that produced it — the worker never buffers a
/// finished token, which is what lets `mean_ttft_ms` measure real
/// time-to-first-byte.
#[derive(Debug)]
pub enum GenEvent {
    Token(i32),
    Done(Result<Vec<i32>, String>),
}

#[derive(Debug)]
pub struct PendingReq {
    pub adapter: String,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    pub sampling: SamplingParams,
    /// deliver per-token `GenEvent::Token`s ahead of `Done`
    pub stream: bool,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<GenEvent>,
}

/// Serving-quality counters, aggregated across all workers.
#[derive(Debug, Default, Clone)]
pub struct RouterStats {
    /// requests completed (replied to), success or error
    pub requests: u64,
    /// requests rejected at submit time because the queue was full
    pub rejected: u64,
    /// decode step boundaries executed
    pub steps: u64,
    /// sum of occupied slots over steps (the occupancy integral)
    pub slot_steps: u64,
    /// tokens emitted across all sequences
    pub generated_tokens: u64,
    /// cumulative time inside `DecodeSession::step`, summed across
    /// workers (per-worker decode effort; NOT wall time)
    pub decode_secs: f64,
    /// wall-clock span of decode activity (first step start .. last
    /// step end, across all workers) — the denominator of
    /// [`RouterStats::tokens_per_sec`], so concurrent workers add
    /// throughput instead of dividing it away
    first_step: Option<Instant>,
    last_step: Option<Instant>,
    /// enqueue → first emitted token, summed over `ttft_count` requests
    pub ttft_secs: f64,
    pub ttft_count: u64,
    /// adapter-reconstruction cache hits/misses (native sessions)
    pub recon_hits: u64,
    pub recon_misses: u64,
    /// dense reconstructions evicted from the shared cache on behalf
    /// of this router's admissions
    pub recon_evictions: u64,
    /// admissions run on the factored rank-r path vs densified — the
    /// execution-mode mix the session cost model picked
    pub factored_admits: u64,
    pub dense_admits: u64,
    /// admissions whose prompt was silently-no-more truncated to the
    /// context window (surfaced per admission, not hidden)
    pub truncated_admits: u64,
    /// K/V bytes currently resident across all workers' arenas — a
    /// gauge tracking tokens actually in flight, not reserved capacity
    pub kv_bytes_in_flight: u64,
    /// K/V pages recycled through arena free lists (counter)
    pub kv_page_churn: u64,
    /// decode-policy mix: admissions with temperature > 0 vs the
    /// temperature-0 greedy default
    pub sampled_requests: u64,
    pub greedy_requests: u64,
    /// per-token frames actually dispatched to streaming clients
    pub stream_frames_sent: u64,
    pub total_latency_secs: f64,
    pub total_queue_secs: f64,
}

impl RouterStats {
    /// Mean decode slots occupied per step — how full the continuous
    /// batch runs.
    pub fn mean_occupied_slots(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.slot_steps as f64 / self.steps as f64
        }
    }

    /// Record one decode step for throughput accounting.
    pub fn note_decode(&mut self, started: Instant, secs: f64) {
        self.decode_secs += secs;
        let end = started + std::time::Duration::from_secs_f64(secs.max(0.0));
        if self.first_step.map_or(true, |f| started < f) {
            self.first_step = Some(started);
        }
        if self.last_step.map_or(true, |l| end > l) {
            self.last_step = Some(end);
        }
    }

    /// Generated tokens per second of wall-clock decode activity
    /// (first step start to last step end, across all workers).
    pub fn tokens_per_sec(&self) -> f64 {
        match (self.first_step, self.last_step) {
            (Some(a), Some(b)) if b > a => self.generated_tokens as f64 / (b - a).as_secs_f64(),
            _ => 0.0,
        }
    }

    /// Mean time-to-first-token, milliseconds.
    pub fn mean_ttft_ms(&self) -> f64 {
        if self.ttft_count == 0 {
            0.0
        } else {
            1000.0 * self.ttft_secs / self.ttft_count as f64
        }
    }

    /// Reconstruction-cache hit rate in [0, 1] (0 when unused).
    pub fn recon_hit_rate(&self) -> f64 {
        let total = self.recon_hits + self.recon_misses;
        if total == 0 {
            0.0
        } else {
            self.recon_hits as f64 / total as f64
        }
    }

    pub fn mean_latency_ms(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            1000.0 * self.total_latency_secs / self.requests as f64
        }
    }
}

struct Shared {
    queue: Mutex<VecDeque<PendingReq>>,
    cv: Condvar,
    stopped: Mutex<bool>,
    capacity: usize,
}

/// Default pending-request cap (`Router::new`); servers override it via
/// `ServerConfig::with_queue_depth`.
pub const DEFAULT_QUEUE_DEPTH: usize = 256;

/// The router owns the queue; each `worker_loop` owns one execution
/// backend plus one decode session. The statics cache is shared across
/// all workers (statics are per-(method, seed): generating and holding
/// them once per adapter, not once per adapter per worker, keeps the
/// multi-adapter residency footprint independent of the pool width) —
/// as is, on the native backend, the adapter-reconstruction cache
/// inside the cloned backends.
pub struct Router {
    shared: Arc<Shared>,
    pub stats: Arc<Mutex<RouterStats>>,
    /// statics keyed by (adapter name, seed): a re-registered adapter
    /// with a new seed generates fresh statics instead of silently
    /// reusing the old seed's (the same staleness class the
    /// reconstruction cache's theta fingerprint guards against)
    statics: Arc<Mutex<HashMap<(String, u64), Arc<Vec<Static>>>>>,
}

impl Clone for Router {
    fn clone(&self) -> Router {
        Router {
            shared: self.shared.clone(),
            stats: self.stats.clone(),
            statics: self.statics.clone(),
        }
    }
}

/// Per-slot bookkeeping a worker keeps alongside its session.
struct SlotBook {
    req: PendingReq,
    tokens: Vec<i32>,
    got_first: bool,
}

impl Router {
    pub fn new() -> Router {
        Router::with_capacity(DEFAULT_QUEUE_DEPTH)
    }

    /// A router whose queue holds at most `capacity` pending requests.
    pub fn with_capacity(capacity: usize) -> Router {
        Router {
            shared: Arc::new(Shared {
                queue: Mutex::new(VecDeque::new()),
                cv: Condvar::new(),
                stopped: Mutex::new(false),
                capacity: capacity.max(1),
            }),
            stats: Arc::new(Mutex::new(RouterStats::default())),
            statics: Arc::new(Mutex::new(HashMap::new())),
        }
    }

    pub fn capacity(&self) -> usize {
        self.shared.capacity
    }

    /// Enqueue a request. When the queue is at capacity the request is
    /// handed back unchanged (backpressure: the caller replies "busy"
    /// instead of the backlog growing without bound).
    pub fn submit(&self, req: PendingReq) -> Result<(), PendingReq> {
        {
            let mut q = self.shared.queue.lock().unwrap();
            if q.len() >= self.shared.capacity {
                drop(q);
                self.stats.lock().unwrap().rejected += 1;
                return Err(req);
            }
            q.push_back(req);
        }
        self.shared.cv.notify_one();
        Ok(())
    }

    /// Synchronous convenience: submit and wait for the generation
    /// (greedy — the default sampling policy).
    pub fn generate(
        &self,
        adapter: &str,
        prompt: Vec<i32>,
        max_new: usize,
    ) -> Result<Vec<i32>, String> {
        self.generate_with(adapter, prompt, max_new, SamplingParams::default())
    }

    /// Synchronous convenience: submit with an explicit sampling policy
    /// and wait for the full generation (no streaming — per-token
    /// delivery goes through `submit` with `stream: true`).
    pub fn generate_with(
        &self,
        adapter: &str,
        prompt: Vec<i32>,
        max_new: usize,
        sampling: SamplingParams,
    ) -> Result<Vec<i32>, String> {
        let (tx, rx) = mpsc::channel();
        let req = PendingReq {
            adapter: adapter.to_string(),
            prompt,
            max_new,
            sampling,
            stream: false,
            enqueued: Instant::now(),
            reply: tx,
        };
        if self.submit(req).is_err() {
            return Err(format!("busy: request queue full (depth {})", self.shared.capacity));
        }
        loop {
            match rx.recv().map_err(|e| e.to_string())? {
                GenEvent::Token(_) => continue, // defensive: non-stream requests get none
                GenEvent::Done(out) => return out,
            }
        }
    }

    pub fn stop(&self) {
        *self.shared.stopped.lock().unwrap() = true;
        // hold the condvar's mutex while notifying: a worker between its
        // stopped-check and cv.wait holds this lock for that whole
        // window, so it cannot miss the wakeup (with N workers a missed
        // wakeup would hang shutdown's join)
        let _q = self.shared.queue.lock().unwrap();
        self.shared.cv.notify_all();
    }

    /// Non-blocking pop — admission at a step boundary while the
    /// session is busy.
    fn try_pop(&self) -> Option<PendingReq> {
        self.shared.queue.lock().unwrap().pop_front()
    }

    /// Put a request back at the HEAD of the queue: admission hit a
    /// transient resource limit (K/V token budget), so it retries in
    /// FIFO position once capacity frees. Bypasses the capacity check —
    /// the request already held its queue place.
    fn requeue_front(&self, req: PendingReq) {
        self.shared.queue.lock().unwrap().push_front(req);
        self.shared.cv.notify_one();
    }

    /// Blocking pop for an idle worker: waits until a request arrives,
    /// or returns None once the router is stopped AND drained.
    fn pop_blocking(&self) -> Option<PendingReq> {
        let mut q = self.shared.queue.lock().unwrap();
        loop {
            if let Some(r) = q.pop_front() {
                return Some(r);
            }
            if *self.shared.stopped.lock().unwrap() {
                return None;
            }
            q = self.shared.cv.wait(q).unwrap();
        }
    }

    /// Get-or-generate the statics for an adapter from the cache all
    /// workers share. Generation runs OUTSIDE the cache lock so a
    /// first-touch adapter never stalls workers serving cached ones;
    /// racing workers may generate the same statics once each, and the
    /// first insert wins (gen_statics is deterministic per seed).
    fn statics_for(
        &self,
        name: &str,
        cfg: &ModelCfg,
        seed: u64,
    ) -> Result<Arc<Vec<Static>>, String> {
        let key = (name.to_string(), seed);
        if let Some(s) = self.statics.lock().unwrap().get(&key) {
            return Ok(s.clone());
        }
        let fresh = Arc::new(gen_statics(cfg, seed).map_err(|e| e.to_string())?);
        let mut cache = self.statics.lock().unwrap();
        Ok(cache.entry(key).or_insert(fresh).clone())
    }

    /// Terminal drain: when a worker cannot decode at all (no session
    /// at startup, or recovery after a poisoned step also fails), it
    /// keeps answering the queue with errors until stop() — exiting
    /// silently would leave queued clients blocked on replies forever.
    fn drain_with_errors(&self, msg: &str) {
        while let Some(req) = self.pop_blocking() {
            let mut st = self.stats.lock().unwrap();
            st.requests += 1;
            st.total_latency_secs += req.enqueued.elapsed().as_secs_f64();
            let _ = req.reply.send(GenEvent::Done(Err(msg.to_string())));
        }
    }

    /// Resolve one queued request against the registry and admit it
    /// into a session slot. Failures (unknown adapter, empty prompt,
    /// reconstruction error, oversized K/V reservation) reply
    /// immediately — they never occupy a slot or poison the session.
    /// A *transient* K/V-budget miss (the reservation would fit an
    /// empty arena, but live sequences hold the pages) requeues the
    /// request at the queue head instead, when `can_requeue`; returns
    /// `false` in that case so the caller stops admitting this round
    /// (re-popping the same request would spin).
    fn admit_req(
        &self,
        sess: &mut dyn DecodeSession,
        books: &mut HashMap<usize, SlotBook>,
        registry: &Registry,
        cfg: &ModelCfg,
        req: PendingReq,
        can_requeue: bool,
    ) -> bool {
        enum Outcome {
            Admitted(Admission),
            Requeue,
            Fail(String),
        }
        let queue_wait = req.enqueued.elapsed().as_secs_f64();
        let outcome = (|| {
            let ckpt = match registry.get(&req.adapter) {
                Some(c) => c,
                None => return Outcome::Fail(format!("unknown adapter {:?}", req.adapter)),
            };
            let statics = match self.statics_for(&req.adapter, cfg, ckpt.seed) {
                Ok(s) => s,
                Err(e) => return Outcome::Fail(e),
            };
            match sess.admit(SeqRequest {
                adapter: req.adapter.clone(),
                theta: Arc::new(ckpt.theta),
                statics,
                prompt: req.prompt.clone(),
                max_new: req.max_new,
                sampling: req.sampling.clone(),
            }) {
                Ok(adm) => Outcome::Admitted(adm),
                Err(e) => match e.downcast_ref::<KvBudgetExhausted>() {
                    // pages free when live sequences retire; an
                    // admission that can never fit fails permanently
                    Some(b) if can_requeue && b.needed_pages <= b.budget_pages => Outcome::Requeue,
                    _ => Outcome::Fail(e.to_string()),
                },
            }
        })();
        match outcome {
            Outcome::Admitted(adm) => {
                let mut st = self.stats.lock().unwrap();
                st.total_queue_secs += queue_wait;
                if adm.truncated {
                    st.truncated_admits += 1;
                }
                books.insert(adm.slot, SlotBook { req, tokens: Vec::new(), got_first: false });
                true
            }
            Outcome::Requeue => {
                // queue wait keeps accruing from the original enqueue
                self.requeue_front(req);
                false
            }
            Outcome::Fail(e) => {
                let mut st = self.stats.lock().unwrap();
                st.total_queue_secs += queue_wait;
                st.requests += 1;
                st.total_latency_secs += req.enqueued.elapsed().as_secs_f64();
                let _ = req.reply.send(GenEvent::Done(Err(e)));
                true
            }
        }
    }

    /// Worker: runs until stop() with the queue drained and no active
    /// sequences. Owns one execution backend and one decode session;
    /// shares the backbone weights, the statics cache and (native) the
    /// reconstruction cache with the other workers.
    pub fn worker_loop(
        &self,
        exec: &mut dyn Backend,
        registry: &Registry,
        art_logits: &str,
        cfg: &ModelCfg,
        w0: &Arc<Vec<f32>>,
        opts: &SessionOpts,
    ) {
        let mut sess = match exec.begin_decode(art_logits, w0.clone(), opts) {
            Ok(s) => s,
            Err(e) => {
                self.drain_with_errors(&format!("decode session unavailable: {e}"));
                return;
            }
        };
        let mut books: HashMap<usize, SlotBook> = HashMap::new();
        let mut last = sess.stats();
        loop {
            // admission at the step boundary: fill free slots from the
            // queue, blocking only when the session is idle
            if sess.active() == 0 {
                match self.pop_blocking() {
                    None => break, // stopped and drained
                    // an idle session's arena is all free, so a budget
                    // miss here can never be transient: no requeue
                    Some(req) => {
                        self.admit_req(sess.as_mut(), &mut books, registry, cfg, req, false);
                    }
                }
            }
            while sess.free_slots() > 0 {
                match self.try_pop() {
                    Some(req) => {
                        if !self.admit_req(sess.as_mut(), &mut books, registry, cfg, req, true) {
                            break; // requeued at the head; step to free pages
                        }
                    }
                    None => break,
                }
            }
            if sess.active() == 0 {
                continue; // every admission this round failed
            }
            let occupied = sess.active() as u64;
            let t0 = Instant::now();
            let events = match sess.step(exec) {
                Ok(ev) => ev,
                Err(e) => {
                    // fail every in-flight sequence, then restart with
                    // a fresh session — one poisoned step must not
                    // take the worker down
                    let msg = format!("decode step failed: {e}");
                    sess.finish();
                    // post-finish sample: the arena released everything,
                    // so the gauge zeroes and churn counts the releases
                    let fin = sess.stats();
                    {
                        let mut st = self.stats.lock().unwrap();
                        for (_, book) in books.drain() {
                            st.requests += 1;
                            st.total_latency_secs += book.req.enqueued.elapsed().as_secs_f64();
                            let _ = book.req.reply.send(GenEvent::Done(Err(msg.clone())));
                        }
                        st.sampled_requests += fin.sampled_admits - last.sampled_admits;
                        st.greedy_requests += fin.greedy_admits - last.greedy_admits;
                        st.kv_page_churn += fin.kv_page_churn - last.kv_page_churn;
                        st.kv_bytes_in_flight = (st.kv_bytes_in_flight + fin.kv_bytes_in_flight)
                            .saturating_sub(last.kv_bytes_in_flight);
                    }
                    match exec.begin_decode(art_logits, w0.clone(), opts) {
                        Ok(s) => {
                            sess = s;
                            last = sess.stats();
                            continue;
                        }
                        Err(e) => {
                            // recovery failed too: keep serving errors
                            // rather than abandoning queued clients
                            self.drain_with_errors(&format!("decode session unavailable: {e}"));
                            return;
                        }
                    }
                }
            };
            let step_secs = t0.elapsed().as_secs_f64();
            let snow = sess.stats();
            let mut st = self.stats.lock().unwrap();
            st.steps += 1;
            st.slot_steps += occupied;
            st.note_decode(t0, step_secs);
            st.recon_hits += snow.recon_hits - last.recon_hits;
            st.recon_misses += snow.recon_misses - last.recon_misses;
            st.recon_evictions += snow.recon_evictions - last.recon_evictions;
            st.factored_admits += snow.factored_admits - last.factored_admits;
            st.dense_admits += snow.dense_admits - last.dense_admits;
            st.sampled_requests += snow.sampled_admits - last.sampled_admits;
            st.greedy_requests += snow.greedy_admits - last.greedy_admits;
            st.kv_page_churn += snow.kv_page_churn - last.kv_page_churn;
            // gauge, not counter: fold this worker's delta so the
            // router-wide value sums live arenas across workers
            st.kv_bytes_in_flight = (st.kv_bytes_in_flight + snow.kv_bytes_in_flight)
                .saturating_sub(last.kv_bytes_in_flight);
            last = snow;
            for ev in events {
                let Some(book) = books.get_mut(&ev.slot) else { continue };
                if let Some(tok) = ev.token {
                    if !book.got_first {
                        // for streaming requests the frame dispatch is
                        // the next statement, so this ttft IS
                        // time-to-first-byte
                        book.got_first = true;
                        st.ttft_secs += book.req.enqueued.elapsed().as_secs_f64();
                        st.ttft_count += 1;
                    }
                    if book.req.stream && book.req.reply.send(GenEvent::Token(tok)).is_ok() {
                        st.stream_frames_sent += 1;
                    }
                    book.tokens.push(tok);
                    st.generated_tokens += 1;
                }
                if ev.done {
                    let book = books.remove(&ev.slot).expect("book exists for finished slot");
                    st.requests += 1;
                    st.total_latency_secs += book.req.enqueued.elapsed().as_secs_f64();
                    let _ = book.req.reply.send(GenEvent::Done(Ok(book.tokens)));
                }
            }
        }
        sess.finish();
    }
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(adapter: &str, tx: &mpsc::Sender<GenEvent>) -> PendingReq {
        PendingReq {
            adapter: adapter.into(),
            prompt: vec![1],
            max_new: 1,
            sampling: SamplingParams::default(),
            stream: false,
            enqueued: Instant::now(),
            reply: tx.clone(),
        }
    }

    #[test]
    fn queue_pops_fifo_across_adapters() {
        let r = Router::new();
        let (tx, _rx) = mpsc::channel();
        for a in ["x", "y", "x", "z"] {
            r.submit(req(a, &tx)).unwrap();
        }
        // continuous batching admits strictly FIFO — no adapter
        // coalescing reordering (slots hold heterogeneous adapters)
        let order: Vec<String> = (0..4).map(|_| r.try_pop().unwrap().adapter).collect();
        assert_eq!(order, ["x", "y", "x", "z"]);
        assert!(r.try_pop().is_none());
    }

    /// Satellite: saturate the bounded queue — submits past capacity
    /// are rejected with a protocol-visible "busy" error and counted.
    #[test]
    fn bounded_queue_rejects_when_saturated() {
        let r = Router::with_capacity(2);
        assert_eq!(r.capacity(), 2);
        let (tx, _rx) = mpsc::channel();
        assert!(r.submit(req("x", &tx)).is_ok());
        assert!(r.submit(req("x", &tx)).is_ok());
        // full: the request comes back unchanged
        let back = r.submit(req("y", &tx)).unwrap_err();
        assert_eq!(back.adapter, "y");
        // the sync API maps the rejection to a "busy" error string
        let err = r.generate("z", vec![1], 1).unwrap_err();
        assert!(err.starts_with("busy"), "{err}");
        assert_eq!(r.stats.lock().unwrap().rejected, 2);
        // draining the queue frees capacity again
        assert!(r.try_pop().is_some());
        assert!(r.try_pop().is_some());
        assert!(r.submit(req("x", &tx)).is_ok());
    }

    #[test]
    fn stop_unblocks_idle_workers() {
        let r = Router::new();
        let r2 = r.clone();
        let h = std::thread::spawn(move || r2.pop_blocking());
        std::thread::sleep(std::time::Duration::from_millis(30));
        r.stop();
        assert!(h.join().unwrap().is_none());
    }

    /// A re-registered adapter (same name, new seed) must get fresh
    /// statics — the cache validates the seed, not just the name.
    #[test]
    fn statics_cache_keys_on_seed() {
        let r = Router::new();
        let cfg = ModelCfg::test_base("uni");
        let s1 = r.statics_for("a", &cfg, 1).unwrap();
        let s1b = r.statics_for("a", &cfg, 1).unwrap();
        assert!(Arc::ptr_eq(&s1, &s1b), "same (name, seed) must share");
        let s2 = r.statics_for("a", &cfg, 2).unwrap();
        assert!(!Arc::ptr_eq(&s1, &s2), "new seed must regenerate");
    }

    #[test]
    fn pop_blocking_drains_before_stopping() {
        let r = Router::new();
        let (tx, _rx) = mpsc::channel();
        r.submit(req("x", &tx)).unwrap();
        r.stop();
        // a queued request still comes out after stop; then None
        assert!(r.pop_blocking().is_some());
        assert!(r.pop_blocking().is_none());
    }

    /// Force eviction churn through a worker: a 1-entry recon cache
    /// serving 3 adapters pinned dense (threshold 1) must surface
    /// evictions and an all-dense admission mix in `RouterStats`; the
    /// same workload pinned factored surfaces the opposite mix and
    /// never touches the dense cache.
    #[test]
    fn worker_surfaces_eviction_churn_and_mode_mix() {
        use crate::adapters::AdapterCheckpoint;
        use crate::runtime::NativeBackend;

        const ART: &str = "lm_uni_lm_logits";
        let run = |opts: SessionOpts| -> (RouterStats, u64) {
            let mut be = NativeBackend::with_recon_cache(1).unwrap();
            let cache = be.recon_cache();
            let meta = be.meta(ART).unwrap().clone();
            let cfg = meta.cfg.clone();
            let w0 = Arc::new(crate::coordinator::init_base(&meta, 9));
            let registry = Arc::new(Registry::new());
            for i in 0..3u64 {
                let theta: Vec<f32> =
                    crate::rng::normals(100 + i, crate::projection::statics::d_effective(&cfg))
                        .iter()
                        .map(|v| 0.05 * v)
                        .collect();
                registry.insert(
                    format!("a{i}"),
                    AdapterCheckpoint {
                        seed: 7,
                        method: cfg.method.clone(),
                        artifact: ART.into(),
                        theta,
                        head: vec![],
                    },
                );
            }
            let r = Router::new();
            let worker = {
                let r = r.clone();
                let registry = registry.clone();
                let cfg = cfg.clone();
                let w0 = w0.clone();
                std::thread::spawn(move || {
                    r.worker_loop(&mut be, &registry, ART, &cfg, &w0, &opts)
                })
            };
            for round in 0..2 {
                for i in 0..3 {
                    let out = r.generate(&format!("a{i}"), vec![1, 2, 3], 2);
                    assert!(out.is_ok(), "round {round} adapter a{i}: {out:?}");
                }
            }
            r.stop();
            worker.join().unwrap();
            let st = r.stats.lock().unwrap().clone();
            (st, cache.evictions())
        };

        // pinned dense: every admission densifies; cycling 3 adapters
        // through a 1-entry cache evicts on every adapter switch
        let (st, cache_evictions) = run(SessionOpts::with_slots(1).with_dense_threshold(1));
        assert_eq!(st.requests, 6);
        assert_eq!((st.dense_admits, st.factored_admits), (6, 0));
        // decode-policy mix: everything above ran the greedy default
        assert_eq!((st.greedy_requests, st.sampled_requests), (6, 0));
        assert_eq!(st.stream_frames_sent, 0, "no streaming clients here");
        assert!(st.recon_evictions >= 1, "cycling adapters must evict: {st:?}");
        assert_eq!(st.recon_evictions, cache_evictions);
        assert_eq!(st.recon_hits, 0, "a 1-entry cache cycling 3 adapters never hits");
        // paged K/V accounting: every retired sequence recycled its
        // pages, and nothing is in flight once the worker drains
        assert!(st.kv_page_churn >= 6, "6 retirements must churn pages: {st:?}");
        assert_eq!(st.kv_bytes_in_flight, 0, "drained worker holds no K/V: {st:?}");
        assert_eq!(st.truncated_admits, 0);

        // pinned factored: no admission ever touches the dense cache
        let factored_opts = SessionOpts::with_slots(1).with_dense_threshold(usize::MAX);
        let (st, cache_evictions) = run(factored_opts);
        assert_eq!(st.requests, 6);
        assert_eq!((st.dense_admits, st.factored_admits), (0, 6));
        assert_eq!((st.recon_evictions, cache_evictions), (0, 0));
        assert_eq!((st.recon_hits, st.recon_misses), (0, 0));
    }

    /// A K/V token budget of one page under two decode slots turns the
    /// second concurrent admission into backpressure, not failure: the
    /// request requeues at the queue head until pages free, and every
    /// request still completes in order.
    #[test]
    fn worker_requeues_on_transient_kv_budget_exhaustion() {
        use crate::adapters::AdapterCheckpoint;
        use crate::runtime::NativeBackend;

        const ART: &str = "lm_uni_lm_logits";
        let mut be = NativeBackend::new().unwrap();
        let meta = be.meta(ART).unwrap().clone();
        let cfg = meta.cfg.clone();
        let w0 = Arc::new(crate::coordinator::init_base(&meta, 9));
        let registry = Arc::new(Registry::new());
        let theta: Vec<f32> =
            crate::rng::normals(55, crate::projection::statics::d_effective(&cfg))
                .iter()
                .map(|v| 0.05 * v)
                .collect();
        registry.insert(
            "a".to_string(),
            AdapterCheckpoint {
                seed: 7,
                method: cfg.method.clone(),
                artifact: ART.into(),
                theta,
                head: vec![],
            },
        );
        // queue three requests BEFORE the worker starts, so the second
        // admission deterministically hits the exhausted budget while
        // the first sequence is live
        let r = Router::new();
        let mut rxs = Vec::new();
        for _ in 0..3 {
            let (tx, rx) = mpsc::channel();
            r.submit(PendingReq {
                adapter: "a".into(),
                prompt: vec![1, 2, 3],
                max_new: 2,
                sampling: SamplingParams::default(),
                stream: false,
                enqueued: Instant::now(),
                reply: tx,
            })
            .unwrap();
            rxs.push(rx);
        }
        let opts = SessionOpts::with_slots(2).with_kv_pages(1);
        let worker = {
            let r = r.clone();
            let registry = registry.clone();
            let cfg = cfg.clone();
            let w0 = w0.clone();
            std::thread::spawn(move || r.worker_loop(&mut be, &registry, ART, &cfg, &w0, &opts))
        };
        for rx in rxs {
            match rx.recv().unwrap() {
                GenEvent::Done(out) => {
                    assert!(out.is_ok(), "budget pressure must delay, not fail: {out:?}");
                }
                other => panic!("buffered request got a stream event: {other:?}"),
            }
        }
        r.stop();
        worker.join().unwrap();
        let st = r.stats.lock().unwrap().clone();
        assert_eq!(st.requests, 3);
        assert_eq!(st.kv_bytes_in_flight, 0, "{st:?}");
        assert!(st.kv_page_churn >= 3, "{st:?}");
    }

    #[test]
    fn stats_derived_metrics() {
        let mut st = RouterStats::default();
        // zero denominators are all defined
        assert_eq!(st.mean_occupied_slots(), 0.0);
        assert_eq!(st.tokens_per_sec(), 0.0);
        assert_eq!(st.mean_ttft_ms(), 0.0);
        assert_eq!(st.recon_hit_rate(), 0.0);
        assert_eq!(st.mean_latency_ms(), 0.0);
        st.steps = 4;
        st.slot_steps = 10;
        st.generated_tokens = 50;
        st.ttft_count = 2;
        st.ttft_secs = 0.5;
        st.recon_hits = 3;
        st.recon_misses = 1;
        st.requests = 5;
        st.total_latency_secs = 1.0;
        assert!((st.mean_occupied_slots() - 2.5).abs() < 1e-12);
        assert!((st.mean_ttft_ms() - 250.0).abs() < 1e-12);
        assert!((st.recon_hit_rate() - 0.75).abs() < 1e-12);
        assert!((st.mean_latency_ms() - 200.0).abs() < 1e-12);
        // throughput uses the WALL span of decode activity, so two
        // workers decoding concurrently (overlapping steps) add
        // throughput instead of halving it
        let t0 = Instant::now();
        st.note_decode(t0, 2.0); // worker A: [0, 2]
        st.note_decode(t0, 2.0); // worker B: [0, 2], concurrent
        assert!((st.decode_secs - 4.0).abs() < 1e-9, "summed effort");
        assert!((st.tokens_per_sec() - 25.0).abs() < 1e-6, "50 tok over a 2s wall span");
    }
}
