//! Multi-adapter serving — the deployment story the paper's storage
//! complexity enables: thousands of adapters are resident at once
//! because each is a seed plus one vector, and the router hot-swaps
//! them per batch.
//!
//! Architecture (vLLM-router flavored, std::net — tokio is unavailable
//! in the offline vendor set):
//!   client (JSON lines over TCP)
//!     -> server::serve accept loop (thread per connection)
//!     -> router::Router bounded queue (adapter-aware batch former,
//!        "busy" rejection past the depth cap)
//!     -> N worker threads, each owning a Backend clone over shared
//!        Arc backbone weights (ServerConfig::workers, default = cores)
//!     -> greedy decode via the lm_logits entry point
//!
//! The request lifecycle is hardened end to end: per-request deadlines
//! (`timeout_ms` / `UNI_LORA_REQUEST_TIMEOUT_MS`) enforced at step
//! boundaries, cancellation when a streaming client disconnects,
//! graceful drain on shutdown (`UNI_LORA_DRAIN_MS`), bounded accepts
//! (`UNI_LORA_MAX_CONNS`) with socket timeouts, capped request lines
//! (`UNI_LORA_MAX_REQUEST_BYTES`), and a seeded fault-injection layer
//! (`UNI_LORA_FAULTS`, see [`faults`]) that makes every recovery path
//! deterministically testable.

pub mod faults;
pub mod protocol;
pub mod router;
pub mod server;

pub use faults::Faults;
pub use protocol::{ErrCode, ServeError};
pub use router::{Router, RouterStats};
pub use server::{serve, ServerConfig, ServerHandle};
