//! TCP front end: JSON-lines protocol over std::net, one reader thread
//! per connection, N execution workers behind the router (each owning
//! a backend clone over shared `Arc` backbone weights and one decode
//! session doing continuous batching — see `server::router` and
//! `crate::session`), so serve throughput scales with cores.
//! `generate` requests carry an optional per-request sampling policy
//! and may opt into per-token streaming (`"stream":true`): frames are
//! relayed to the socket at the decode-step boundary that produced
//! them, so the first byte leaves mid-decode.

use super::protocol::{Request, Response};
use super::router::{DEFAULT_QUEUE_DEPTH, GenEvent, PendingReq, Router};
use crate::adapters::Registry;
use crate::config::{ModelCfg, RuntimeOpts};
use crate::generation::SamplingParams;
use crate::runtime::Backend;
use crate::session::SessionOpts;
use crate::util::json::{n, obj, Json};
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Instant;

#[derive(Clone)]
pub struct ServerConfig {
    /// bind address, e.g. "127.0.0.1:0" (0 = ephemeral port for tests)
    pub addr: String,
    /// lm_logits artifact the workers decode with
    pub art_logits: String,
    /// execution workers; 0 = auto (`UNI_LORA_THREADS` / available
    /// parallelism). Clamped down if the backend refuses `try_clone`.
    pub workers: usize,
    /// pending-request cap before "busy" rejection (router backpressure)
    pub queue_depth: usize,
}

impl ServerConfig {
    pub fn new(addr: impl Into<String>, art_logits: impl Into<String>) -> ServerConfig {
        ServerConfig {
            addr: addr.into(),
            art_logits: art_logits.into(),
            workers: 0,
            queue_depth: DEFAULT_QUEUE_DEPTH,
        }
    }

    pub fn with_workers(mut self, workers: usize) -> ServerConfig {
        self.workers = workers;
        self
    }

    pub fn with_queue_depth(mut self, depth: usize) -> ServerConfig {
        self.queue_depth = depth;
        self
    }
}

pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    pub router: Router,
    /// execution workers actually running (can be fewer than requested
    /// when the backend refuses to clone)
    pub workers: usize,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    worker_threads: Vec<JoinHandle<()>>,
}

impl ServerHandle {
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.router.stop();
        // poke the accept loop so it notices the stop flag
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        for t in self.worker_threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Start the server; the backend (and backbone weights) move into the
/// worker pool. Returns once the socket is bound. `Backend: Send` by
/// construction (the PJRT backend wraps its non-Send client with a
/// single-owner-move justification in runtime::executor).
///
/// Worker pool: `cfg.workers` (0 = auto) backends drain the router
/// queue concurrently — the moved-in backend plus `try_clone`s of it.
/// A backend that refuses to clone (PJRT) degrades to one worker
/// rather than failing the serve path; every worker shares one `Arc`d
/// copy of the backbone weights.
pub fn serve(
    cfg: ServerConfig,
    backend: Box<dyn Backend>,
    registry: Arc<Registry>,
    model_cfg: ModelCfg,
    w0: Vec<f32>,
) -> Result<ServerHandle> {
    let listener = TcpListener::bind(&cfg.addr).context("binding server socket")?;
    let addr = listener.local_addr()?;
    let router = Router::with_capacity(cfg.queue_depth);
    let stop = Arc::new(AtomicBool::new(false));
    let w0 = Arc::new(w0);

    let wanted = if cfg.workers == 0 { RuntimeOpts::from_env().threads } else { cfg.workers };
    let mut backends: Vec<Box<dyn Backend>> = vec![backend];
    for _ in 1..wanted.max(1) {
        match backends[0].try_clone() {
            Ok(b) => backends.push(b),
            Err(e) => {
                eprintln!(
                    "serve: backend does not clone ({e}); running {} worker(s)",
                    backends.len()
                );
                break;
            }
        }
    }
    let workers = backends.len();
    // one env read for the whole pool; every worker session gets the
    // same slot count and dense-threshold cost model
    let opts = SessionOpts::from_env();

    let worker_threads: Vec<JoinHandle<()>> = backends
        .into_iter()
        .map(|mut be| {
            let router = router.clone();
            let registry = registry.clone();
            let art = cfg.art_logits.clone();
            let model_cfg = model_cfg.clone();
            let w0 = w0.clone();
            std::thread::spawn(move || {
                router.worker_loop(be.as_mut(), &registry, &art, &model_cfg, &w0, &opts);
            })
        })
        .collect();

    let accept = {
        let router = router.clone();
        let stop = stop.clone();
        let registry = registry.clone();
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                let router = router.clone();
                let registry = registry.clone();
                std::thread::spawn(move || handle_conn(stream, router, registry, workers));
            }
        })
    };

    Ok(ServerHandle {
        addr,
        router,
        workers,
        stop,
        accept_thread: Some(accept),
        worker_threads,
    })
}

fn handle_conn(stream: TcpStream, router: Router, registry: Arc<Registry>, workers: usize) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let resp = match Request::parse(&line) {
            Err(e) => Response::Error(e.to_string()),
            Ok(Request::Adapters) => Response::Adapters(registry.names()),
            Ok(Request::Stats) => {
                let st = router.stats.lock().unwrap().clone();
                Response::Stats(obj(vec![
                    ("requests", n(st.requests as f64)),
                    ("rejected", n(st.rejected as f64)),
                    ("workers", n(workers as f64)),
                    ("steps", n(st.steps as f64)),
                    ("generated_tokens", n(st.generated_tokens as f64)),
                    ("tokens_per_sec", n(st.tokens_per_sec())),
                    ("mean_ttft_ms", n(st.mean_ttft_ms())),
                    ("recon_hit_rate", n(st.recon_hit_rate())),
                    ("recon_evictions", n(st.recon_evictions as f64)),
                    ("factored_admits", n(st.factored_admits as f64)),
                    ("dense_admits", n(st.dense_admits as f64)),
                    ("sampled_requests", n(st.sampled_requests as f64)),
                    ("greedy_requests", n(st.greedy_requests as f64)),
                    ("stream_frames_sent", n(st.stream_frames_sent as f64)),
                    ("mean_occupied_slots", n(st.mean_occupied_slots())),
                    ("mean_latency_ms", n(st.mean_latency_ms())),
                    ("truncated_admits", n(st.truncated_admits as f64)),
                    ("kv_bytes_in_flight", n(st.kv_bytes_in_flight as f64)),
                    ("kv_page_churn", n(st.kv_page_churn as f64)),
                ]))
            }
            Ok(Request::Generate { adapter, prompt, max_new, sampling, stream }) => {
                if stream {
                    // frames are written inline as the worker emits
                    // them; a write failure means the client went away
                    match stream_generate(&mut writer, &router, &adapter, prompt, max_new, sampling)
                    {
                        Ok(()) => continue,
                        Err(_) => break,
                    }
                }
                match router.generate_with(&adapter, prompt, max_new, sampling) {
                    Ok(tokens) => Response::Tokens(tokens),
                    Err(e) => Response::Error(e),
                }
            }
        };
        if writeln!(writer, "{}", resp.to_json()).is_err() {
            break;
        }
    }
}

/// Stream one generation: submit with `stream: true`, then relay each
/// [`GenEvent`] to the socket the moment it arrives — one frame line
/// per token, then the terminal frame carrying the full token list.
/// Failures that precede any frame (busy queue, unknown adapter) are
/// written as ordinary error responses. `Err` only on socket write
/// failure.
fn stream_generate(
    writer: &mut TcpStream,
    router: &Router,
    adapter: &str,
    prompt: Vec<i32>,
    max_new: usize,
    sampling: SamplingParams,
) -> std::io::Result<()> {
    let (tx, rx) = mpsc::channel();
    let req = PendingReq {
        adapter: adapter.to_string(),
        prompt,
        max_new,
        sampling,
        stream: true,
        enqueued: Instant::now(),
        reply: tx,
    };
    if router.submit(req).is_err() {
        let msg = format!("busy: request queue full (depth {})", router.capacity());
        return writeln!(writer, "{}", Response::Error(msg).to_json());
    }
    loop {
        let ev = rx
            .recv()
            .unwrap_or_else(|_| GenEvent::Done(Err("worker dropped the request".to_string())));
        match ev {
            GenEvent::Token(tok) => {
                let f = Response::Frame { token: Some(tok), done: false, tokens: None };
                writeln!(writer, "{}", f.to_json())?;
            }
            GenEvent::Done(Ok(tokens)) => {
                let f = Response::Frame { token: None, done: true, tokens: Some(tokens) };
                return writeln!(writer, "{}", f.to_json());
            }
            GenEvent::Done(Err(e)) => {
                return writeln!(writer, "{}", Response::Error(e).to_json());
            }
        }
    }
}

/// Minimal blocking client for tests, examples and benches.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    pub fn call(&mut self, req: &Request) -> Result<Response> {
        writeln!(self.writer, "{}", req.to_json())?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Response::parse(&line)
    }

    pub fn generate(
        &mut self,
        adapter: &str,
        prompt: Vec<i32>,
        max_new: usize,
    ) -> Result<Vec<i32>> {
        self.generate_sampled(adapter, prompt, max_new, SamplingParams::default())
    }

    /// Buffered generation with an explicit sampling policy.
    pub fn generate_sampled(
        &mut self,
        adapter: &str,
        prompt: Vec<i32>,
        max_new: usize,
        sampling: SamplingParams,
    ) -> Result<Vec<i32>> {
        let req = Request::Generate {
            adapter: adapter.into(),
            prompt,
            max_new,
            sampling,
            stream: false,
        };
        match self.call(&req)? {
            Response::Tokens(t) => Ok(t),
            Response::Error(e) => anyhow::bail!("server error: {e}"),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    /// Streamed generation: reads frame lines until the terminal frame.
    /// Returns the per-frame tokens in arrival order plus the terminal
    /// frame's full token list (the two must agree — asserted by the
    /// serving tests).
    pub fn generate_stream(
        &mut self,
        adapter: &str,
        prompt: Vec<i32>,
        max_new: usize,
        sampling: SamplingParams,
    ) -> Result<(Vec<i32>, Vec<i32>)> {
        let req = Request::Generate {
            adapter: adapter.into(),
            prompt,
            max_new,
            sampling,
            stream: true,
        };
        writeln!(self.writer, "{}", req.to_json())?;
        let mut streamed = Vec::new();
        loop {
            let mut line = String::new();
            self.reader.read_line(&mut line)?;
            match Response::parse(&line)? {
                Response::Frame { token, done, tokens } => {
                    if let Some(t) = token {
                        streamed.push(t);
                    }
                    if done {
                        return Ok((streamed, tokens.unwrap_or_default()));
                    }
                }
                Response::Error(e) => anyhow::bail!("server error: {e}"),
                other => anyhow::bail!("unexpected response {other:?}"),
            }
        }
    }

    pub fn stats(&mut self) -> Result<Json> {
        match self.call(&Request::Stats)? {
            Response::Stats(j) => Ok(j),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }
}
