//! TCP front end: JSON-lines protocol over std::net, one reader thread
//! per connection, N execution workers behind the router (each owning
//! a backend clone over shared `Arc` backbone weights and one decode
//! session doing continuous batching — see `server::router` and
//! `crate::session`), so serve throughput scales with cores.
//! `generate` requests carry an optional per-request sampling policy
//! and may opt into per-token streaming (`"stream":true`): frames are
//! relayed to the socket at the decode-step boundary that produced
//! them, so the first byte leaves mid-decode.
//!
//! The front end enforces the request-lifecycle bounds:
//!
//! - **Deadlines** — `"timeout_ms"` on the request, defaulted by
//!   `UNI_LORA_REQUEST_TIMEOUT_MS`, enforced by the router at step
//!   boundaries (queue wait included).
//! - **Bounded request lines** — a line past
//!   `UNI_LORA_MAX_REQUEST_BYTES` (default 1 MiB) gets a typed
//!   `request_too_large` error and the connection closes (there is no
//!   way to resync mid-line).
//! - **Bounded connections** — past `UNI_LORA_MAX_CONNS` (0 = off)
//!   a new connection gets one typed `busy` line and is closed;
//!   accepted sockets carry `UNI_LORA_SOCK_TIMEOUT_MS` read/write
//!   timeouts, so a client trickling bytes forever (slow loris) is
//!   disconnected instead of pinning a reader thread.
//! - **Graceful drain** — `shutdown` stops accepting, fails queued
//!   requests with `shutting_down`, lets in-flight sequences finish
//!   inside `UNI_LORA_DRAIN_MS`, then hard-stops the stragglers, and
//!   returns the final [`RouterStats`].
//!
//! Observability rides on the same socket: the `metrics` op renders
//! the router's counters and latency histograms as one Prometheus
//! text scrape ([`Client::metrics_text`]), and the `trace` op drains
//! the per-request span-event ring (`UNI_LORA_TRACE_RING` entries,
//! optionally tee'd to a `UNI_LORA_TRACE=<path>` JSONL file).

use super::faults::Faults;
use super::protocol::{Request, Response, ServeError};
use super::router::{lock_recover, DEFAULT_QUEUE_DEPTH, GenEvent, PendingReq, Router, RouterStats};
use crate::adapters::Registry;
use crate::config::{self, ModelCfg, RuntimeOpts};
use crate::generation::SamplingParams;
use crate::obs::{profile, MetricsRegistry, Tracer};
use crate::runtime::Backend;
use crate::session::SessionOpts;
use crate::util::json::{n, obj, Json};
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

#[derive(Clone)]
pub struct ServerConfig {
    /// bind address, e.g. "127.0.0.1:0" (0 = ephemeral port for tests)
    pub addr: String,
    /// lm_logits artifact the workers decode with
    pub art_logits: String,
    /// execution workers; 0 = auto (`UNI_LORA_THREADS` / available
    /// parallelism). Clamped down if the backend refuses `try_clone`.
    pub workers: usize,
    /// pending-request cap before "busy" rejection (router backpressure)
    pub queue_depth: usize,
    /// default per-request deadline for requests that don't carry
    /// `timeout_ms`; 0 = none (`UNI_LORA_REQUEST_TIMEOUT_MS`)
    pub request_timeout_ms: u64,
    /// how long shutdown lets in-flight sequences finish before the
    /// hard stop; 0 = abort immediately (`UNI_LORA_DRAIN_MS`)
    pub drain_ms: u64,
    /// concurrent-connection cap; 0 = unlimited (`UNI_LORA_MAX_CONNS`)
    pub max_conns: usize,
    /// request-line byte cap (`UNI_LORA_MAX_REQUEST_BYTES`)
    pub max_request_bytes: usize,
    /// per-socket read/write timeout; 0 = none
    /// (`UNI_LORA_SOCK_TIMEOUT_MS`)
    pub sock_timeout_ms: u64,
    /// session knobs for the worker pool; None = read the
    /// `UNI_LORA_DECODE_SLOTS`-family env once at serve time. Tests
    /// pin this instead of mutating the environment.
    pub session: Option<SessionOpts>,
    /// fault-injection plan; None = `UNI_LORA_FAULTS` (off when
    /// unset). Tests pin this instead of mutating the environment.
    pub faults: Option<Arc<Faults>>,
    /// span-event ring capacity; 0 disables the in-memory ring
    /// (`UNI_LORA_TRACE_RING`)
    pub trace_ring: usize,
    /// JSONL trace sink appended to as events are recorded; None = ring
    /// only (`UNI_LORA_TRACE`)
    pub trace_path: Option<String>,
}

impl ServerConfig {
    pub fn new(addr: impl Into<String>, art_logits: impl Into<String>) -> ServerConfig {
        let env = |k: &str| std::env::var(k).ok();
        ServerConfig {
            addr: addr.into(),
            art_logits: art_logits.into(),
            workers: 0,
            queue_depth: DEFAULT_QUEUE_DEPTH,
            request_timeout_ms: config::parse_request_timeout_ms(
                env("UNI_LORA_REQUEST_TIMEOUT_MS").as_deref(),
            ),
            drain_ms: config::parse_drain_ms(env("UNI_LORA_DRAIN_MS").as_deref()),
            max_conns: config::parse_max_conns(env("UNI_LORA_MAX_CONNS").as_deref()),
            max_request_bytes: config::parse_max_request_bytes(
                env("UNI_LORA_MAX_REQUEST_BYTES").as_deref(),
            ),
            sock_timeout_ms: config::parse_sock_timeout_ms(
                env("UNI_LORA_SOCK_TIMEOUT_MS").as_deref(),
            ),
            session: None,
            faults: None,
            trace_ring: config::parse_trace_ring(env("UNI_LORA_TRACE_RING").as_deref()),
            trace_path: config::parse_trace_path(env("UNI_LORA_TRACE").as_deref()),
        }
    }

    pub fn with_workers(mut self, workers: usize) -> ServerConfig {
        self.workers = workers;
        self
    }

    pub fn with_queue_depth(mut self, depth: usize) -> ServerConfig {
        self.queue_depth = depth;
        self
    }

    pub fn with_request_timeout_ms(mut self, ms: u64) -> ServerConfig {
        self.request_timeout_ms = ms;
        self
    }

    pub fn with_drain_ms(mut self, ms: u64) -> ServerConfig {
        self.drain_ms = ms;
        self
    }

    pub fn with_max_conns(mut self, cap: usize) -> ServerConfig {
        self.max_conns = cap;
        self
    }

    pub fn with_max_request_bytes(mut self, cap: usize) -> ServerConfig {
        self.max_request_bytes = cap.max(1);
        self
    }

    pub fn with_sock_timeout_ms(mut self, ms: u64) -> ServerConfig {
        self.sock_timeout_ms = ms;
        self
    }

    /// Pin the worker sessions' knobs (tests; production reads env).
    pub fn with_session(mut self, opts: SessionOpts) -> ServerConfig {
        self.session = Some(opts);
        self
    }

    /// Pin the fault-injection plan (tests; production reads env).
    pub fn with_faults(mut self, faults: Arc<Faults>) -> ServerConfig {
        self.faults = Some(faults);
        self
    }

    /// Pin the span-event ring capacity (tests; production reads env).
    pub fn with_trace_ring(mut self, cap: usize) -> ServerConfig {
        self.trace_ring = cap;
        self
    }

    /// Pin the JSONL trace sink path (tests; production reads env).
    pub fn with_trace_path(mut self, path: impl Into<String>) -> ServerConfig {
        self.trace_path = Some(path.into());
        self
    }
}

pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    pub router: Router,
    /// execution workers actually running (can be fewer than requested
    /// when the backend refuses to clone)
    pub workers: usize,
    drain_ms: u64,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    worker_threads: Vec<JoinHandle<()>>,
}

/// The ephemeral-port poke `shutdown` uses to unblock the accept loop
/// must target an address a client can actually dial: a wildcard bind
/// (0.0.0.0 / ::) is not connectable on every platform, so route the
/// poke through the matching loopback instead. (The old
/// `connect(self.addr)` failed silently for wildcard binds, leaving
/// shutdown to hang on the accept join.)
fn poke_addr(addr: SocketAddr) -> SocketAddr {
    let mut poke = addr;
    if poke.ip().is_unspecified() {
        poke.set_ip(match poke {
            SocketAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
            SocketAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
        });
    }
    poke
}

/// Join with an upper bound: shutdown must never hang on a thread that
/// is itself blocked on I/O. On timeout the watcher thread (and the
/// joined thread) are leaked — the process is exiting anyway, and a
/// bounded leak beats an unbounded hang.
fn join_timeout(handle: JoinHandle<()>, timeout: Duration) {
    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || {
        let _ = handle.join();
        let _ = tx.send(());
    });
    let _ = rx.recv_timeout(timeout);
}

impl ServerHandle {
    /// Graceful shutdown: stop accepting, fail everything still
    /// queued with a typed `shutting_down` error, let in-flight
    /// sequences finish for up to `drain_ms` (streaming clients keep
    /// receiving frames), then hard-stop the stragglers. Returns the
    /// final serving stats (drained_ok / drained_aborted record how
    /// the drain went).
    pub fn shutdown(mut self) -> RouterStats {
        self.stop.store(true, Ordering::SeqCst);
        // stop admitting new work before poking the accept loop: a
        // connection racing the poke sees typed shutdown errors
        self.router.drain();
        let _ = TcpStream::connect_timeout(&poke_addr(self.addr), Duration::from_millis(250));
        if let Some(t) = self.accept_thread.take() {
            join_timeout(t, Duration::from_millis(1_000));
        }
        let _ = self.router.fail_queued();
        let deadline = Instant::now() + Duration::from_millis(self.drain_ms);
        while self.router.in_flight() > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(2));
        }
        if self.router.in_flight() > 0 {
            self.router.hard_stop();
        }
        self.router.stop();
        for t in self.worker_threads.drain(..) {
            let _ = t.join();
        }
        lock_recover(&self.router.stats).clone()
    }
}

/// Start the server; the backend (and backbone weights) move into the
/// worker pool. Returns once the socket is bound. `Backend: Send` by
/// construction (the PJRT backend wraps its non-Send client with a
/// single-owner-move justification in runtime::executor).
///
/// Worker pool: `cfg.workers` (0 = auto) backends drain the router
/// queue concurrently — the moved-in backend plus `try_clone`s of it.
/// A backend that refuses to clone (PJRT) degrades to one worker
/// rather than failing the serve path; every worker shares one `Arc`d
/// copy of the backbone weights.
pub fn serve(
    cfg: ServerConfig,
    backend: Box<dyn Backend>,
    registry: Arc<Registry>,
    model_cfg: ModelCfg,
    w0: Vec<f32>,
) -> Result<ServerHandle> {
    let listener = TcpListener::bind(&cfg.addr).context("binding server socket")?;
    let addr = listener.local_addr()?;
    let tracer = Arc::new(Tracer::from_cfg(cfg.trace_ring, cfg.trace_path.as_deref()));
    let router = Router::with_tracer(cfg.queue_depth, tracer);
    let stop = Arc::new(AtomicBool::new(false));
    let w0 = Arc::new(w0);

    let wanted = if cfg.workers == 0 { RuntimeOpts::from_env().threads } else { cfg.workers };
    let mut backends: Vec<Box<dyn Backend>> = vec![backend];
    for _ in 1..wanted.max(1) {
        match backends[0].try_clone() {
            Ok(b) => backends.push(b),
            Err(e) => {
                eprintln!(
                    "serve: backend does not clone ({e}); running {} worker(s)",
                    backends.len()
                );
                break;
            }
        }
    }
    let workers = backends.len();
    // one env read for the whole pool (unless the config pinned the
    // knobs); every worker session gets the same slot count and
    // dense-threshold cost model, and every worker shares one seeded
    // fault plan
    let opts = cfg.session.unwrap_or_else(SessionOpts::from_env);
    let faults = cfg.faults.clone().unwrap_or_else(|| Arc::new(Faults::from_env()));

    let worker_threads: Vec<JoinHandle<()>> = backends
        .into_iter()
        .map(|mut be| {
            let router = router.clone();
            let registry = registry.clone();
            let art = cfg.art_logits.clone();
            let model_cfg = model_cfg.clone();
            let w0 = w0.clone();
            let faults = faults.clone();
            std::thread::spawn(move || {
                router.worker_loop(be.as_mut(), &registry, &art, &model_cfg, &w0, &opts, &faults);
            })
        })
        .collect();

    let ctx = ConnCtx {
        router: router.clone(),
        registry,
        workers,
        max_request_bytes: cfg.max_request_bytes,
        request_timeout_ms: cfg.request_timeout_ms,
    };
    let max_conns = cfg.max_conns;
    let sock_timeout_ms = cfg.sock_timeout_ms;
    let accept = {
        let stop = stop.clone();
        std::thread::spawn(move || {
            let live = Arc::new(AtomicUsize::new(0));
            for stream in listener.incoming() {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(mut stream) = stream else { continue };
                if max_conns > 0 && live.load(Ordering::SeqCst) >= max_conns {
                    // one typed busy line, then close — never a silent
                    // drop, never an unbounded handler thread
                    lock_recover(&ctx.router.stats).conns_rejected += 1;
                    let _ = stream.set_write_timeout(Some(Duration::from_millis(250)));
                    let e = ServeError::busy(format!(
                        "busy: too many connections (max {max_conns})"
                    ));
                    let _ = writeln!(stream, "{}", Response::Error(e).to_json());
                    continue;
                }
                if sock_timeout_ms > 0 {
                    let t = Some(Duration::from_millis(sock_timeout_ms));
                    let _ = stream.set_read_timeout(t);
                    let _ = stream.set_write_timeout(t);
                }
                live.fetch_add(1, Ordering::SeqCst);
                let guard = ConnGuard(live.clone());
                let ctx = ctx.clone();
                std::thread::spawn(move || {
                    let _guard = guard;
                    handle_conn(stream, ctx);
                });
            }
        })
    };

    Ok(ServerHandle {
        addr,
        router,
        workers,
        drain_ms: cfg.drain_ms,
        stop,
        accept_thread: Some(accept),
        worker_threads,
    })
}

/// Everything a connection handler needs, cloned per connection.
#[derive(Clone)]
struct ConnCtx {
    router: Router,
    registry: Arc<Registry>,
    workers: usize,
    max_request_bytes: usize,
    request_timeout_ms: u64,
}

/// Decrements the live-connection gauge when the handler exits — by
/// any path, including a panic.
struct ConnGuard(Arc<AtomicUsize>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

enum LineRead {
    Line(String),
    TooLarge,
    Eof,
}

/// Read one `\n`-terminated line, refusing to buffer more than `cap`
/// bytes of it — the unbounded `BufRead::lines` alternative lets one
/// client allocate without limit. Errors surface the socket state
/// (closed, reset, or read-timeout — the slow-loris kill).
fn read_bounded_line(r: &mut impl BufRead, cap: usize) -> std::io::Result<LineRead> {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let chunk = r.fill_buf()?;
        if chunk.is_empty() {
            // EOF mid-line: surface what arrived so a sender that
            // forgot the trailing newline still gets parsed
            return Ok(if buf.is_empty() {
                LineRead::Eof
            } else {
                LineRead::Line(String::from_utf8_lossy(&buf).into_owned())
            });
        }
        match chunk.iter().position(|&b| b == b'\n') {
            Some(pos) => {
                if buf.len() + pos > cap {
                    r.consume(pos + 1);
                    return Ok(LineRead::TooLarge);
                }
                buf.extend_from_slice(&chunk[..pos]);
                r.consume(pos + 1);
                return Ok(LineRead::Line(String::from_utf8_lossy(&buf).into_owned()));
            }
            None => {
                let len = chunk.len();
                if buf.len() + len > cap {
                    r.consume(len);
                    return Ok(LineRead::TooLarge);
                }
                buf.extend_from_slice(chunk);
                r.consume(len);
            }
        }
    }
}

/// The effective deadline for one request: its own `timeout_ms` wins,
/// else the server default; 0 everywhere = unbounded.
fn request_deadline(req_ms: u64, default_ms: u64) -> Option<Instant> {
    let ms = if req_ms > 0 { req_ms } else { default_ms };
    (ms > 0).then(|| Instant::now() + Duration::from_millis(ms))
}

fn handle_conn(stream: TcpStream, ctx: ConnCtx) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    loop {
        let line = match read_bounded_line(&mut reader, ctx.max_request_bytes) {
            // closed, reset, or read-timeout: either way this
            // connection is done (the timeout is the slow-loris bound)
            Err(_) => break,
            Ok(LineRead::Eof) => break,
            Ok(LineRead::TooLarge) => {
                let e = ServeError::too_large(format!(
                    "request too large: line exceeds {} bytes",
                    ctx.max_request_bytes
                ));
                let _ = writeln!(writer, "{}", Response::Error(e).to_json());
                break; // the rest of the oversized line is unframed
            }
            Ok(LineRead::Line(l)) => l,
        };
        if line.trim().is_empty() {
            continue;
        }
        let resp = match Request::parse(&line) {
            Err(e) => Response::Error(ServeError::parse(e.to_string())),
            Ok(Request::Adapters) => Response::Adapters(ctx.registry.names()),
            Ok(Request::Stats) => stats_response(&ctx),
            Ok(Request::Metrics) => {
                let st = lock_recover(&ctx.router.stats).clone();
                Response::Metrics(render_metrics(&st, ctx.workers))
            }
            Ok(Request::Trace) => {
                let events = ctx.router.tracer().drain();
                Response::Trace(events.iter().map(|e| e.to_json()).collect())
            }
            Ok(Request::Generate { adapter, prompt, max_new, sampling, stream, timeout_ms }) => {
                let deadline = request_deadline(timeout_ms, ctx.request_timeout_ms);
                if stream {
                    // frames are written inline as the worker emits
                    // them; a write failure means the client went away
                    match stream_generate(
                        &mut writer,
                        &ctx.router,
                        &adapter,
                        prompt,
                        max_new,
                        sampling,
                        deadline,
                    ) {
                        Ok(()) => continue,
                        Err(_) => break,
                    }
                }
                match ctx.router.generate_deadline(&adapter, prompt, max_new, sampling, deadline) {
                    Ok(tokens) => Response::Tokens(tokens),
                    Err(e) => Response::Error(e),
                }
            }
        };
        if writeln!(writer, "{}", resp.to_json()).is_err() {
            break;
        }
    }
}

fn stats_response(ctx: &ConnCtx) -> Response {
    let st = lock_recover(&ctx.router.stats).clone();
    Response::Stats(obj(vec![
        ("requests", n(st.requests as f64)),
        ("rejected", n(st.rejected as f64)),
        ("workers", n(ctx.workers as f64)),
        ("steps", n(st.steps as f64)),
        ("generated_tokens", n(st.generated_tokens as f64)),
        ("tokens_per_sec", n(st.tokens_per_sec())),
        ("mean_ttft_ms", n(st.mean_ttft_ms())),
        ("recon_hit_rate", n(st.recon_hit_rate())),
        ("recon_evictions", n(st.recon_evictions as f64)),
        ("factored_admits", n(st.factored_admits as f64)),
        ("dense_admits", n(st.dense_admits as f64)),
        ("sampled_requests", n(st.sampled_requests as f64)),
        ("greedy_requests", n(st.greedy_requests as f64)),
        ("stream_frames_sent", n(st.stream_frames_sent as f64)),
        ("mean_occupied_slots", n(st.mean_occupied_slots())),
        ("mean_latency_ms", n(st.mean_latency_ms())),
        ("truncated_admits", n(st.truncated_admits as f64)),
        ("kv_bytes_in_flight", n(st.kv_bytes_in_flight as f64)),
        ("kv_page_churn", n(st.kv_page_churn as f64)),
        ("deadline_exceeded", n(st.deadline_exceeded as f64)),
        ("cancelled", n(st.cancelled as f64)),
        ("client_gone", n(st.client_gone as f64)),
        ("conns_rejected", n(st.conns_rejected as f64)),
        ("drained_ok", n(st.drained_ok as f64)),
        ("drained_aborted", n(st.drained_aborted as f64)),
        ("faults_injected", n(st.faults_injected as f64)),
        ("decode_wall_secs", n(st.decode_wall_secs)),
    ]))
}

/// Render one Prometheus text scrape from a stats snapshot. Counters
/// and gauges mirror the `stats` op (same snapshot, so the two ops can
/// never disagree); the histograms and the `UNI_LORA_PROFILE=1` stage
/// attribution exist only here. Metric order is fixed so consecutive
/// scrapes diff cleanly.
fn render_metrics(st: &RouterStats, workers: usize) -> String {
    let mut reg = MetricsRegistry::new();
    let c = |v: u64| v as f64;
    reg.counter("unilora_requests_total", "requests replied to, success or error", c(st.requests));
    reg.counter("unilora_rejected_total", "submits rejected at the queue cap", c(st.rejected));
    reg.counter("unilora_steps_total", "fused decode step boundaries", c(st.steps));
    reg.counter("unilora_slot_steps_total", "occupied slots summed over steps", c(st.slot_steps));
    reg.counter("unilora_generated_tokens_total", "tokens emitted", c(st.generated_tokens));
    reg.counter(
        "unilora_decode_cpu_seconds_total",
        "seconds inside DecodeSession::step, summed across workers",
        st.decode_secs,
    );
    reg.counter(
        "unilora_decode_busy_seconds_total",
        "wall-clock seconds with at least one decode step in flight",
        st.decode_wall_secs,
    );
    reg.counter(
        "unilora_recon_evictions_total",
        "dense reconstructions evicted from the shared cache",
        c(st.recon_evictions),
    );
    reg.counter_vec(
        "unilora_admits_total",
        "admissions by execution mode the session cost model picked",
        "mode",
        &[("factored", c(st.factored_admits)), ("dense", c(st.dense_admits))],
    );
    reg.counter_vec(
        "unilora_requests_by_policy_total",
        "admissions by decode policy (temperature > 0 vs greedy)",
        "policy",
        &[("sampled", c(st.sampled_requests)), ("greedy", c(st.greedy_requests))],
    );
    reg.counter(
        "unilora_truncated_admits_total",
        "prompts truncated to the context window at admission",
        c(st.truncated_admits),
    );
    reg.counter(
        "unilora_stream_frames_sent_total",
        "per-token frames written to streaming clients",
        c(st.stream_frames_sent),
    );
    reg.counter(
        "unilora_deadline_exceeded_total",
        "requests that ran out of wall-clock, queued or decoding",
        c(st.deadline_exceeded),
    );
    reg.counter(
        "unilora_cancelled_total",
        "sequences retired mid-flight via cancel",
        c(st.cancelled),
    );
    reg.counter(
        "unilora_client_gone_total",
        "streaming clients that disconnected mid-generation",
        c(st.client_gone),
    );
    reg.counter(
        "unilora_conns_rejected_total",
        "connections rejected at the accept cap",
        c(st.conns_rejected),
    );
    reg.counter_vec(
        "unilora_drained_total",
        "in-flight requests finished inside vs aborted at the drain deadline",
        "outcome",
        &[("ok", c(st.drained_ok)), ("aborted", c(st.drained_aborted))],
    );
    reg.counter(
        "unilora_faults_injected_total",
        "seeded fault-plan decisions that injected a failure",
        c(st.faults_injected),
    );
    reg.counter(
        "unilora_kv_page_churn_total",
        "K/V pages recycled through arena free lists",
        c(st.kv_page_churn),
    );
    reg.gauge(
        "unilora_kv_bytes_in_flight",
        "K/V bytes resident across all workers' arenas",
        c(st.kv_bytes_in_flight),
    );
    reg.gauge("unilora_workers", "execution workers running", workers as f64);
    reg.histogram(
        "unilora_ttft_seconds",
        "enqueue to first emitted token (streamed: first frame dispatch)",
        &st.hists.ttft,
    );
    reg.histogram(
        "unilora_queue_wait_seconds",
        "enqueue to admission outcome",
        &st.hists.queue_wait,
    );
    reg.histogram(
        "unilora_request_latency_seconds",
        "enqueue to terminal reply, success or error",
        &st.hists.latency,
    );
    reg.histogram("unilora_decode_step_seconds", "one fused decode step", &st.hists.step);
    reg.histogram(
        "unilora_prompt_tokens",
        "admitted prompt length after truncation",
        &st.hists.prompt_tokens,
    );
    if profile::enabled() {
        let snap = profile::snapshot();
        let secs: Vec<(&str, f64)> = snap.iter().map(|&(name, s, _)| (name, s)).collect();
        let calls: Vec<(&str, f64)> = snap.iter().map(|&(name, _, k)| (name, k as f64)).collect();
        reg.counter_vec(
            "unilora_profile_seconds_total",
            "fused decode time attributed per stage (UNI_LORA_PROFILE=1)",
            "stage",
            &secs,
        );
        reg.counter_vec(
            "unilora_profile_calls_total",
            "scoped-timer entries per stage (UNI_LORA_PROFILE=1)",
            "stage",
            &calls,
        );
    }
    reg.render()
}

/// Stream one generation: submit with `stream: true`, then relay each
/// [`GenEvent`] to the socket the moment it arrives — one frame line
/// per token, then the terminal frame carrying the full token list.
/// Failures that precede any frame (busy queue, unknown adapter,
/// draining server) are written as ordinary typed error responses.
/// `Err` only on socket write failure; dropping the receiver after
/// that is what tells the worker the client is gone (it cancels the
/// sequence at the next step boundary).
fn stream_generate(
    writer: &mut TcpStream,
    router: &Router,
    adapter: &str,
    prompt: Vec<i32>,
    max_new: usize,
    sampling: SamplingParams,
    deadline: Option<Instant>,
) -> std::io::Result<()> {
    let (tx, rx) = mpsc::channel();
    let req = PendingReq {
        id: 0,
        adapter: adapter.to_string(),
        prompt,
        max_new,
        sampling,
        stream: true,
        deadline,
        enqueued: Instant::now(),
        reply: tx,
    };
    if let Err((_, e)) = router.submit(req) {
        return writeln!(writer, "{}", Response::Error(e).to_json());
    }
    loop {
        let ev = rx.recv().unwrap_or_else(|_| {
            GenEvent::Done(Err(ServeError::internal("worker dropped the request")))
        });
        match ev {
            GenEvent::Token(tok) => {
                let f = Response::Frame { token: Some(tok), done: false, tokens: None };
                writeln!(writer, "{}", f.to_json())?;
            }
            GenEvent::Done(Ok(tokens)) => {
                let f = Response::Frame { token: None, done: true, tokens: Some(tokens) };
                return writeln!(writer, "{}", f.to_json());
            }
            GenEvent::Done(Err(e)) => {
                return writeln!(writer, "{}", Response::Error(e).to_json());
            }
        }
    }
}

/// Minimal blocking client for tests, examples and benches.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    pub fn call(&mut self, req: &Request) -> Result<Response> {
        writeln!(self.writer, "{}", req.to_json())?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Response::parse(&line)
    }

    pub fn generate(
        &mut self,
        adapter: &str,
        prompt: Vec<i32>,
        max_new: usize,
    ) -> Result<Vec<i32>> {
        self.generate_sampled(adapter, prompt, max_new, SamplingParams::default())
    }

    /// Buffered generation with an explicit sampling policy.
    pub fn generate_sampled(
        &mut self,
        adapter: &str,
        prompt: Vec<i32>,
        max_new: usize,
        sampling: SamplingParams,
    ) -> Result<Vec<i32>> {
        let req = Request::Generate {
            adapter: adapter.into(),
            prompt,
            max_new,
            sampling,
            stream: false,
            timeout_ms: 0,
        };
        match self.call(&req)? {
            Response::Tokens(t) => Ok(t),
            Response::Error(e) => anyhow::bail!("server error: {e}"),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    /// Streamed generation: reads frame lines until the terminal frame.
    /// Returns the per-frame tokens in arrival order plus the terminal
    /// frame's full token list (the two must agree — asserted by the
    /// serving tests).
    pub fn generate_stream(
        &mut self,
        adapter: &str,
        prompt: Vec<i32>,
        max_new: usize,
        sampling: SamplingParams,
    ) -> Result<(Vec<i32>, Vec<i32>)> {
        let req = Request::Generate {
            adapter: adapter.into(),
            prompt,
            max_new,
            sampling,
            stream: true,
            timeout_ms: 0,
        };
        writeln!(self.writer, "{}", req.to_json())?;
        let mut streamed = Vec::new();
        loop {
            let mut line = String::new();
            self.reader.read_line(&mut line)?;
            match Response::parse(&line)? {
                Response::Frame { token, done, tokens } => {
                    if let Some(t) = token {
                        streamed.push(t);
                    }
                    if done {
                        return Ok((streamed, tokens.unwrap_or_default()));
                    }
                }
                Response::Error(e) => anyhow::bail!("server error: {e}"),
                other => anyhow::bail!("unexpected response {other:?}"),
            }
        }
    }

    pub fn stats(&mut self) -> Result<Json> {
        match self.call(&Request::Stats)? {
            Response::Stats(j) => Ok(j),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    /// One Prometheus text scrape (the `metrics` op's payload).
    pub fn metrics_text(&mut self) -> Result<String> {
        match self.call(&Request::Metrics)? {
            Response::Metrics(text) => Ok(text),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    /// Drain the server's span-event ring (destructive — each event
    /// arrives exactly once), oldest first.
    pub fn trace_events(&mut self) -> Result<Vec<Json>> {
        match self.call(&Request::Trace)? {
            Response::Trace(events) => Ok(events),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }
}
