//! Frozen method statics: the seed-deterministic "implicit P" of every
//! method, regenerated on the Rust side so that artifacts (and adapter
//! checkpoints) never need to store it.
//!
//! The per-method generation/layout logic lives on each
//! `projection::op::ProjectionOp`; this module keeps the `Static`
//! container plus the validating wrappers every caller goes through
//! (`gen_statics`, `theta_segments`, `init_theta`, `d_effective`).
//! MUST stay bit-identical with python/compile/methods.gen_statics —
//! same child streams, same ordering. Cross-language goldens live in
//! rust/tests/cross_parity.rs.

use crate::config::ModelCfg;
use crate::projection::op;
use crate::rng;
use anyhow::{bail, Result};

#[derive(Debug, Clone)]
pub enum StaticData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

#[derive(Debug, Clone)]
pub struct Static {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: StaticData,
}

impl Static {
    pub(crate) fn f32(name: &str, shape: Vec<usize>, data: Vec<f32>) -> Static {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Static { name: name.into(), shape, data: StaticData::F32(data) }
    }

    pub(crate) fn i32(name: &str, shape: Vec<usize>, data: Vec<i32>) -> Static {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Static { name: name.into(), shape, data: StaticData::I32(data) }
    }

    pub fn len(&self) -> usize {
        match &self.data {
            StaticData::F32(v) => v.len(),
            StaticData::I32(v) => v.len(),
        }
    }

    pub fn as_f32(&self) -> &[f32] {
        match &self.data {
            StaticData::F32(v) => v,
            _ => panic!("{} is not f32", self.name),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match &self.data {
            StaticData::I32(v) => v,
            _ => panic!("{} is not i32", self.name),
        }
    }
}

/// Blocks per module for the fastfood method.
pub fn fastfood_blocks(cfg: &ModelCfg) -> usize {
    (cfg.module_len() + cfg.d - 1) / cfg.d
}

/// Per-(module, block) fastfood seed, derived by nesting child streams
/// so no two (i, j) pairs can collide. The old flat derivation
/// `STREAM_FASTFOOD + 16*i + j` collided across modules whenever the
/// blocks-per-module count exceeded 16 (e.g. long modules with small d),
/// silently correlating blocks of different modules.
/// MUST match python methods.gen_statics.
pub fn fastfood_block_seed(seed: u64, module: usize, block: usize) -> u64 {
    let ff = rng::child_seed(seed, rng::STREAM_FASTFOOD);
    rng::child_seed(rng::child_seed(ff, module as u64), block as u64)
}

/// Generate the frozen statics for `cfg.method`, in the same order as
/// python's statics_spec (which is the artifact input order). Validates
/// the cfg, then dispatches through the `projection::op` registry.
pub fn gen_statics(cfg: &ModelCfg, seed: u64) -> Result<Vec<Static>> {
    cfg.validate()?;
    op::resolve(&cfg.method)?.gen_statics(cfg, seed)
}

/// Theta layout mirror of methods.theta_segments (init specs
/// included), from the registry; unknown methods have no trainable
/// segments (matching the historical fall-through).
pub fn theta_segments(cfg: &ModelCfg) -> Vec<(String, Vec<usize>, String)> {
    op::resolve(&cfg.method).map(|o| o.theta_segments(cfg)).unwrap_or_default()
}

/// Materialize an init spec string — mirror of methods.init_array.
pub fn init_array(init: &str, n: usize, seed: u64) -> Result<Vec<f32>> {
    Ok(if init == "zeros" {
        vec![0f32; n]
    } else if init == "ones" {
        vec![1f32; n]
    } else if let Some(s) = init.strip_prefix("normal:") {
        let sigma: f32 = s.parse()?;
        rng::normals(seed, n).iter().map(|x| x * sigma).collect()
    } else if let Some(s) = init.strip_prefix("uniform:") {
        let a: f32 = s.parse()?;
        rng::uniform_range(seed, n, -a, a)
    } else if let Some(s) = init.strip_prefix("const:") {
        vec![s.parse()?; n]
    } else {
        bail!("unknown init {init:?}")
    })
}

/// Build the initial trainable vector — mirror of methods.init_theta.
pub fn init_theta(cfg: &ModelCfg, seed: u64) -> Result<Vec<f32>> {
    let segs = theta_segments(cfg);
    if segs.is_empty() {
        return Ok(vec![0f32; 1]);
    }
    let mut out = Vec::new();
    for (i, (_name, shape, init)) in segs.iter().enumerate() {
        let n: usize = shape.iter().product();
        let s = rng::child_seed(seed, rng::STREAM_THETA_INIT + 1000 * i as u64);
        out.extend(init_array(init, n, s)?);
    }
    Ok(out)
}

/// Number of trainable adapter parameters (= python d_effective).
pub fn d_effective(cfg: &ModelCfg) -> usize {
    let total: usize = theta_segments(cfg)
        .iter()
        .map(|(_, s, _)| s.iter().product::<usize>())
        .sum();
    total.max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_orders_match_python() {
        // names + shapes for each method, in artifact input order
        let cases = [
            ("uni", vec!["idx", "nrm"]),
            ("fastfood", vec!["sgn_b", "gauss", "perm", "sgn_s"]),
            ("vera", vec!["pa_t", "pb_t"]),
            ("vb", vec!["top_idx"]),
            ("lora_xs", vec!["pa_t", "pb_t"]),
            ("fourierft", vec!["freq"]),
            ("lora", vec![]),
            ("tied", vec![]),
        ];
        for (m, want) in cases {
            let cfg = ModelCfg::test_base(m);
            let got: Vec<String> = gen_statics(&cfg, 1)
                .unwrap()
                .into_iter()
                .map(|s| s.name)
                .collect();
            assert_eq!(got, want, "method {m}");
        }
    }

    #[test]
    fn d_effective_matches_python_values() {
        // values asserted in python/tests/test_methods.py
        let d_of = |m: &str| d_effective(&ModelCfg::test_base(m));
        assert_eq!(d_of("lora"), 2048);
        assert_eq!(d_of("uni"), 256);
        assert_eq!(d_of("vera"), 4 * (64 + 4));
        assert_eq!(d_of("lora_xs"), 4 * 16);
        assert_eq!(d_of("fourierft"), 4 * 96);
        assert_eq!(d_of("none"), 1);
    }

    #[test]
    fn statics_deterministic() {
        let cfg = ModelCfg::test_base("uni");
        let a = gen_statics(&cfg, 9).unwrap();
        let b = gen_statics(&cfg, 9).unwrap();
        assert_eq!(a[0].as_i32(), b[0].as_i32());
        let c = gen_statics(&cfg, 10).unwrap();
        assert_ne!(a[0].as_i32(), c[0].as_i32());
    }

    #[test]
    fn init_theta_vera_structure() {
        let cfg = ModelCfg::test_base("vera");
        let th = init_theta(&cfg, 11).unwrap();
        let nm_h = cfg.n_modules() * cfg.hidden;
        assert!(th[..nm_h].iter().all(|&x| x == 0.0));
        assert!(th[nm_h..].iter().all(|&x| (x - 0.1).abs() < 1e-7));
    }

    #[test]
    fn fastfood_block_seeds_do_not_collide_when_nb_gt_16() {
        // module_len = 512, d = 16 -> nb = 32 > 16: under the old flat
        // derivation (STREAM_FASTFOOD + 16*i + j) block (0, 16) and
        // block (1, 0) shared a seed and were bit-identical.
        let mut cfg = ModelCfg::test_base("fastfood");
        cfg.d = 16;
        let nb = fastfood_blocks(&cfg);
        assert!(nb > 16, "test config must exercise nb > 16, got {nb}");
        let st = gen_statics(&cfg, 5).unwrap();
        let d = cfg.d;
        let g = st[1].as_f32(); // gauss, [nm, nb, d]
        let blk = |i: usize, j: usize| &g[(i * nb + j) * d..(i * nb + j + 1) * d];
        assert_ne!(blk(0, 16), blk(1, 0));
        assert_ne!(fastfood_block_seed(5, 0, 16), fastfood_block_seed(5, 1, 0));
        // all block seeds pairwise distinct across the whole grid
        let mut seen = std::collections::HashSet::new();
        for i in 0..cfg.n_modules() {
            for j in 0..nb {
                assert!(seen.insert(fastfood_block_seed(5, i, j)), "collision at ({i},{j})");
            }
        }
    }

    #[test]
    fn gen_statics_rejects_d_larger_than_full() {
        // d > D means full column support is impossible; must bail
        // instead of looping forever in patch_support.
        let mut cfg = ModelCfg::test_base("uni");
        cfg.d = cfg.d_full() + 1;
        let err = gen_statics(&cfg, 1).unwrap_err().to_string();
        assert!(err.contains("exceeds"), "{err}");
    }

    #[test]
    fn fastfood_statics_shapes() {
        let cfg = ModelCfg::test_base("fastfood");
        let st = gen_statics(&cfg, 3).unwrap();
        let nb = fastfood_blocks(&cfg);
        assert_eq!(nb, 2); // module_len 512 / d 256
        for s in &st {
            assert_eq!(s.shape, vec![cfg.n_modules(), nb, cfg.d]);
            assert_eq!(s.len(), cfg.n_modules() * nb * cfg.d);
        }
    }
}
