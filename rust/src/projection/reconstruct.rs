//! theta_d -> LoRA factors, for every method, in pure Rust.
//!
//! This is what makes an adapter checkpoint self-contained: given
//! (cfg, seed, theta_d) the coordinator can expand the full DeltaW
//! without any artifact or Python — used for adapter export/merging
//! (adapters::expand) and for the Table-1 projection analysis
//! (properties.rs builds P as the Jacobian of this map).
//!
//! The per-method expansion logic itself lives on
//! `projection::op::ProjectionOp::apply`; this module keeps the
//! `ModuleDelta` factor type and the seed/statics convenience wrappers
//! every caller goes through.

use crate::config::ModelCfg;
use crate::kernels;
use crate::projection::op;
use crate::projection::statics::{gen_statics, Static};
use anyhow::Result;
use std::borrow::Cow;

/// Per-module weight increment, before the alpha/r scale.
#[derive(Debug, Clone)]
pub enum ModuleDelta {
    /// DeltaW^T = A @ B with A [h, r] row-major, B [r, h] row-major
    /// (the row convention of Alg. 1: y = x@W0 + scale*(x@A)@B).
    LowRank { a: Vec<f32>, b: Vec<f32> },
    /// Dense [h, h] increment (FourierFT).
    Dense(Vec<f32>),
}

impl ModuleDelta {
    /// Materialize the dense [h, h] increment (row-major). The
    /// low-rank product routes through the blocked `kernels::gemm_nn`
    /// — this is the hot path of adapter export/merge and of the
    /// Table-1 Jacobian probes. `Dense` variants (FourierFT) borrow
    /// their existing buffer instead of cloning `h*h` floats the
    /// callers only read.
    pub fn to_dense(&self, h: usize, r: usize) -> Cow<'_, [f32]> {
        match self {
            ModuleDelta::Dense(dw) => Cow::Borrowed(dw.as_slice()),
            ModuleDelta::LowRank { a, b } => {
                let mut dw = vec![0f32; h * h];
                kernels::gemm_nn(a, b, &mut dw, h, r, h, false);
                Cow::Owned(dw)
            }
        }
    }
}

/// Expand theta_d into the per-module weight increments, regenerating
/// the frozen statics from the seed.
pub fn reconstruct(cfg: &ModelCfg, seed: u64, theta: &[f32]) -> Result<Vec<ModuleDelta>> {
    let stats = gen_statics(cfg, seed)?;
    reconstruct_with_statics(cfg, &stats, theta)
}

/// Expand theta_d given pre-generated statics (the form the runtime
/// backends use: statics arrive as artifact inputs, no seed in sight).
/// Pure registry dispatch: `resolve(method).apply(..)`.
pub fn reconstruct_with_statics(
    cfg: &ModelCfg,
    stats: &[Static],
    theta: &[f32],
) -> Result<Vec<ModuleDelta>> {
    op::resolve(&cfg.method)?.apply(cfg, stats, theta)
}

/// Flatten the reconstruction into the paper's theta_D vector:
/// per module, vec(A) then vec(B) (dense modules contribute vec(DeltaW)).
pub fn theta_big(_cfg: &ModelCfg, deltas: &[ModuleDelta]) -> Vec<f32> {
    let mut out = Vec::new();
    for d in deltas {
        match d {
            ModuleDelta::LowRank { a, b } => {
                out.extend_from_slice(a);
                out.extend_from_slice(b);
            }
            ModuleDelta::Dense(dw) => out.extend_from_slice(dw),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projection::statics::{d_effective, init_theta};

    fn small(method: &str) -> ModelCfg {
        let mut c = ModelCfg::test_base(method);
        c.hidden = 16;
        c.layers = 2;
        c.rank = 2;
        c.d = 32;
        c.vb_b = 16;
        c.vb_bank = 8;
        c.n_coef = 12;
        c
    }

    #[test]
    fn all_methods_reconstruct_finite() {
        for m in ["lora", "uni", "local", "nonuniform", "fastfood", "vera",
                  "tied", "vb", "lora_xs", "fourierft", "none"] {
            let cfg = small(m);
            let th = init_theta(&cfg, 5).unwrap();
            assert_eq!(th.len(), d_effective(&cfg), "{m}");
            let ds = reconstruct(&cfg, 5, &th).unwrap();
            assert_eq!(ds.len(), cfg.n_modules(), "{m}");
            for d in &ds {
                let dense = d.to_dense(cfg.hidden, cfg.rank);
                assert_eq!(dense.len(), cfg.hidden * cfg.hidden);
                assert!(dense.iter().all(|x| x.is_finite()), "{m}");
            }
        }
    }

    #[test]
    fn zero_init_methods_reconstruct_zero() {
        for m in ["lora", "vera", "lora_xs", "fourierft"] {
            let cfg = small(m);
            let th = init_theta(&cfg, 7).unwrap();
            let ds = reconstruct(&cfg, 7, &th).unwrap();
            for d in &ds {
                let dense = d.to_dense(cfg.hidden, cfg.rank);
                assert!(dense.iter().all(|&x| x.abs() < 1e-7), "{m}");
            }
        }
    }

    #[test]
    fn uni_reconstruct_matches_manual_gather() {
        let cfg = small("uni");
        let th = init_theta(&cfg, 3).unwrap();
        let stats = gen_statics(&cfg, 3).unwrap();
        let (idx, nrm) = (stats[0].as_i32(), stats[1].as_f32());
        let ds = reconstruct(&cfg, 3, &th).unwrap();
        let ar = cfg.hidden * cfg.rank;
        if let ModuleDelta::LowRank { a, .. } = &ds[1] {
            let o = cfg.module_len(); // module 1 offset
            for k in 0..ar {
                let want = th[idx[o + k] as usize] * nrm[o + k];
                assert!((a[k] - want).abs() < 1e-7);
            }
        } else {
            panic!("expected low-rank");
        }
    }

    #[test]
    fn with_statics_matches_seeded_reconstruct() {
        for m in ["uni", "fastfood", "vb", "vera", "lora_xs", "fourierft"] {
            let cfg = small(m);
            let th = init_theta(&cfg, 4).unwrap();
            let stats = gen_statics(&cfg, 4).unwrap();
            let a = theta_big(&cfg, &reconstruct(&cfg, 4, &th).unwrap());
            let b = theta_big(&cfg, &reconstruct_with_statics(&cfg, &stats, &th).unwrap());
            assert_eq!(a, b, "{m}");
        }
    }

    #[test]
    fn theta_big_layout() {
        let cfg = small("uni");
        let th = init_theta(&cfg, 3).unwrap();
        let ds = reconstruct(&cfg, 3, &th).unwrap();
        let big = theta_big(&cfg, &ds);
        assert_eq!(big.len(), cfg.d_full());
    }

    #[test]
    fn linearity_of_linear_methods() {
        // reconstruct(2*theta) == 2*reconstruct(theta) for linear P
        for m in ["uni", "fastfood", "vb", "fourierft", "lora"] {
            let cfg = small(m);
            let th = init_theta(&cfg, 9).unwrap();
            // vb is linear in bank only with coef fixed; perturb bank only
            let th2: Vec<f32> = th.iter().map(|x| x * 2.0).collect();
            if m == "vb" {
                continue; // bilinear in (bank, coef) jointly — skip
            }
            let b1 = theta_big(&cfg, &reconstruct(&cfg, 9, &th).unwrap());
            let b2 = theta_big(&cfg, &reconstruct(&cfg, 9, &th2).unwrap());
            for (x, y) in b1.iter().zip(&b2) {
                assert!((2.0 * x - y).abs() < 1e-4, "{m}: {x} {y}");
            }
        }
    }

    #[test]
    fn to_dense_matches_reference_triple_loop() {
        // the SCALAR-tier gemm must equal the naive i-k-j accumulation
        // bit for bit (that tier's determinism contract); to_dense
        // itself runs on whatever tier UNI_LORA_KERNELS selected, which
        // is only tolerance-equal to scalar (kernels::dispatch)
        let (h, r) = (16, 2);
        let a = crate::rng::normals(1, h * r);
        let b = crate::rng::normals(2, r * h);
        let d = ModuleDelta::LowRank { a: a.clone(), b: b.clone() };
        let mut want = vec![0f32; h * h];
        for i in 0..h {
            for k in 0..r {
                for j in 0..h {
                    want[i * h + j] += a[i * r + k] * b[k * h + j];
                }
            }
        }
        let mut scalar = vec![0f32; h * h];
        kernels::gemm_nn_with(&kernels::dispatch::SCALAR, &a, &b, &mut scalar, h, r, h, false);
        assert_eq!(scalar, want);
        let got = d.to_dense(h, r);
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() <= 1e-5 * w.abs().max(1.0),
                "to_dense[{i}] = {g} vs reference {w} (active tier {})",
                kernels::dispatch::path()
            );
        }
    }
}
