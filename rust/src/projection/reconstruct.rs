//! theta_d -> LoRA factors, for every method, in pure Rust.
//!
//! This is what makes an adapter checkpoint self-contained: given
//! (cfg, seed, theta_d) the coordinator can expand the full DeltaW
//! without any artifact or Python — used for adapter export/merging
//! (adapters::expand) and for the Table-1 projection analysis
//! (properties.rs builds P as the Jacobian of this map).

use crate::config::ModelCfg;
use crate::projection::fastfood::FastfoodBlock;
use crate::projection::statics::{gen_statics, theta_segments, Static};
use crate::projection::uni;
use anyhow::{bail, Result};

/// Per-module weight increment, before the alpha/r scale.
#[derive(Debug, Clone)]
pub enum ModuleDelta {
    /// DeltaW^T = A @ B with A [h, r] row-major, B [r, h] row-major
    /// (the row convention of Alg. 1: y = x@W0 + scale*(x@A)@B).
    LowRank { a: Vec<f32>, b: Vec<f32> },
    /// Dense [h, h] increment (FourierFT).
    Dense(Vec<f32>),
}

impl ModuleDelta {
    /// Materialize the dense [h, h] increment (row-major).
    pub fn to_dense(&self, h: usize, r: usize) -> Vec<f32> {
        match self {
            ModuleDelta::Dense(dw) => dw.clone(),
            ModuleDelta::LowRank { a, b } => {
                let mut dw = vec![0f32; h * h];
                for i in 0..h {
                    for k in 0..r {
                        let aik = a[i * r + k];
                        if aik == 0.0 {
                            continue;
                        }
                        for j in 0..h {
                            dw[i * h + j] += aik * b[k * h + j];
                        }
                    }
                }
                dw
            }
        }
    }
}

fn seg_slices<'t>(cfg: &ModelCfg, theta: &'t [f32]) -> Vec<(String, &'t [f32])> {
    let mut out = Vec::new();
    let mut off = 0;
    for (name, shape, _init) in theta_segments(cfg) {
        let n: usize = shape.iter().product();
        out.push((name, &theta[off..off + n]));
        off += n;
    }
    out
}

fn find<'a>(segs: &'a [(String, &'a [f32])], name: &str) -> &'a [f32] {
    segs.iter().find(|(n, _)| n == name).map(|(_, s)| *s).unwrap()
}

/// Expand theta_d into the per-module weight increments, regenerating
/// the frozen statics from the seed.
pub fn reconstruct(cfg: &ModelCfg, seed: u64, theta: &[f32]) -> Result<Vec<ModuleDelta>> {
    let stats = gen_statics(cfg, seed)?;
    reconstruct_with_statics(cfg, &stats, theta)
}

/// Expand theta_d given pre-generated statics (the form the runtime
/// backends use: statics arrive as artifact inputs, no seed in sight).
pub fn reconstruct_with_statics(
    cfg: &ModelCfg,
    stats: &[Static],
    theta: &[f32],
) -> Result<Vec<ModuleDelta>> {
    let (h, r, nm) = (cfg.hidden, cfg.rank, cfg.n_modules());
    let (ml, ar) = (cfg.module_len(), h * r);
    let segs = seg_slices(cfg, theta);
    let m = cfg.method.as_str();

    let lowrank_from_flat = |flat: &[f32]| -> Vec<ModuleDelta> {
        (0..nm)
            .map(|i| {
                let o = i * ml;
                ModuleDelta::LowRank {
                    a: flat[o..o + ar].to_vec(),
                    b: flat[o + ar..o + ml].to_vec(),
                }
            })
            .collect()
    };

    Ok(match m {
        "none" => (0..nm)
            .map(|_| ModuleDelta::LowRank { a: vec![0.0; ar], b: vec![0.0; ar] })
            .collect(),
        "lora" => (0..nm)
            .map(|i| ModuleDelta::LowRank {
                a: find(&segs, &format!("A{i}")).to_vec(),
                b: find(&segs, &format!("B{i}")).to_vec(),
            })
            .collect(),
        "uni" | "local" | "nonuniform" => {
            let idx = stats[0].as_i32();
            let nrm = stats[1].as_f32();
            let th = find(&segs, "theta");
            let mut flat = vec![0f32; idx.len()];
            uni::project(th, idx, nrm, &mut flat);
            lowrank_from_flat(&flat)
        }
        "fastfood" => {
            let th = find(&segs, "theta");
            let nb = (ml + cfg.d - 1) / cfg.d;
            let d = cfg.d;
            // statics arrays are [nm, nb, d] — slice out each block
            let (sb, g, pm, ss) =
                (stats[0].as_f32(), stats[1].as_f32(), stats[2].as_i32(), stats[3].as_f32());
            // full-P isometry normalization (mirrors methods.apply)
            let norm = 1.0 / ((nm * nb) as f32).sqrt();
            let mut flat = Vec::with_capacity(nm * ml);
            for i in 0..nm {
                let blocks: Vec<FastfoodBlock> = (0..nb)
                    .map(|j| {
                        let o = (i * nb + j) * d;
                        FastfoodBlock {
                            sgn_b: sb[o..o + d].to_vec(),
                            gauss: g[o..o + d].to_vec(),
                            perm: pm[o..o + d].to_vec(),
                            sgn_s: ss[o..o + d].to_vec(),
                        }
                    })
                    .collect();
                flat.extend(
                    crate::projection::fastfood::project(&blocks, th, ml)
                        .iter()
                        .map(|x| x * norm),
                );
            }
            lowrank_from_flat(&flat)
        }
        "vera" | "tied" => {
            let (pa, pb) = if m == "tied" {
                (find(&segs, "pa_t"), find(&segs, "pb_t"))
            } else {
                (stats[0].as_f32(), stats[1].as_f32())
            };
            let lamb_b = find(&segs, "lamb_b"); // [nm, h]
            let lamb_d = find(&segs, "lamb_d"); // [nm, r]
            (0..nm)
                .map(|i| {
                    let lb = &lamb_b[i * h..(i + 1) * h];
                    let ld = &lamb_d[i * r..(i + 1) * r];
                    // a[p, j] = pa[p, j] * ld[j]; b[j, k] = pb[j, k] * lb[k]
                    let mut a = vec![0f32; h * r];
                    for p in 0..h {
                        for j in 0..r {
                            a[p * r + j] = pa[p * r + j] * ld[j];
                        }
                    }
                    let mut b = vec![0f32; r * h];
                    for j in 0..r {
                        for k in 0..h {
                            b[j * h + k] = pb[j * h + k] * lb[k];
                        }
                    }
                    ModuleDelta::LowRank { a, b }
                })
                .collect()
        }
        "vb" => {
            let top_idx = stats[0].as_i32(); // [n_sub, K]
            let bank = find(&segs, "bank"); // [h_bank, b]
            let coef = find(&segs, "coef"); // [n_sub, K]
            let (bb, kk) = (cfg.vb_b, cfg.vb_k);
            let n_sub = cfg.d_full() / bb;
            let mut flat = vec![0f32; cfg.d_full()];
            for sv in 0..n_sub {
                for k in 0..kk {
                    let c = coef[sv * kk + k];
                    let row = top_idx[sv * kk + k] as usize;
                    for p in 0..bb {
                        flat[sv * bb + p] += c * bank[row * bb + p];
                    }
                }
            }
            lowrank_from_flat(&flat)
        }
        "lora_xs" => {
            let pa = stats[0].as_f32(); // [nm, h, r]
            let pb = stats[1].as_f32(); // [nm, r, h]
            (0..nm)
                .map(|i| {
                    let rr = find(&segs, &format!("R{i}")); // [r, r]
                    let pai = &pa[i * h * r..(i + 1) * h * r];
                    let pbi = &pb[i * r * h..(i + 1) * r * h];
                    // effective A' = pa_t @ R^T: a[p, j] = sum_q pa[p, q] R[j, q]
                    let mut a = vec![0f32; h * r];
                    for p in 0..h {
                        for j in 0..r {
                            let mut acc = 0f32;
                            for q in 0..r {
                                acc += pai[p * r + q] * rr[j * r + q];
                            }
                            a[p * r + j] = acc;
                        }
                    }
                    ModuleDelta::LowRank { a, b: pbi.to_vec() }
                })
                .collect()
        }
        "fourierft" => {
            let freq = stats[0].as_i32(); // [nm, n_coef, 2]
            let coef = find(&segs, "coef"); // [nm, n_coef]
            let nc = cfg.n_coef;
            let norm = 1.0 / (nc as f32).sqrt();
            (0..nm)
                .map(|mi| {
                    let mut dw = vec![0f32; h * h];
                    for k in 0..nc {
                        let c = coef[mi * nc + k];
                        if c == 0.0 {
                            continue;
                        }
                        let f1 = freq[(mi * nc + k) * 2] as f32;
                        let f2 = freq[(mi * nc + k) * 2 + 1] as f32;
                        for i in 0..h {
                            let a1 = 2.0 * std::f32::consts::PI * f1 * i as f32 / h as f32;
                            for j in 0..h {
                                let a2 =
                                    2.0 * std::f32::consts::PI * f2 * j as f32 / h as f32;
                                dw[i * h + j] += c * (a1 + a2).cos() * norm;
                            }
                        }
                    }
                    ModuleDelta::Dense(dw)
                })
                .collect()
        }
        other => bail!("unknown method {other:?}"),
    })
}

/// Flatten the reconstruction into the paper's theta_D vector:
/// per module, vec(A) then vec(B) (dense modules contribute vec(DeltaW)).
pub fn theta_big(_cfg: &ModelCfg, deltas: &[ModuleDelta]) -> Vec<f32> {
    let mut out = Vec::new();
    for d in deltas {
        match d {
            ModuleDelta::LowRank { a, b } => {
                out.extend_from_slice(a);
                out.extend_from_slice(b);
            }
            ModuleDelta::Dense(dw) => out.extend_from_slice(dw),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projection::statics::{d_effective, init_theta};

    fn small(method: &str) -> ModelCfg {
        let mut c = ModelCfg::test_base(method);
        c.hidden = 16;
        c.layers = 2;
        c.rank = 2;
        c.d = 32;
        c.vb_b = 16;
        c.vb_bank = 8;
        c.n_coef = 12;
        c
    }

    #[test]
    fn all_methods_reconstruct_finite() {
        for m in ["lora", "uni", "local", "nonuniform", "fastfood", "vera",
                  "tied", "vb", "lora_xs", "fourierft", "none"] {
            let cfg = small(m);
            let th = init_theta(&cfg, 5).unwrap();
            assert_eq!(th.len(), d_effective(&cfg), "{m}");
            let ds = reconstruct(&cfg, 5, &th).unwrap();
            assert_eq!(ds.len(), cfg.n_modules(), "{m}");
            for d in &ds {
                let dense = d.to_dense(cfg.hidden, cfg.rank);
                assert_eq!(dense.len(), cfg.hidden * cfg.hidden);
                assert!(dense.iter().all(|x| x.is_finite()), "{m}");
            }
        }
    }

    #[test]
    fn zero_init_methods_reconstruct_zero() {
        for m in ["lora", "vera", "lora_xs", "fourierft"] {
            let cfg = small(m);
            let th = init_theta(&cfg, 7).unwrap();
            let ds = reconstruct(&cfg, 7, &th).unwrap();
            for d in &ds {
                let dense = d.to_dense(cfg.hidden, cfg.rank);
                assert!(dense.iter().all(|&x| x.abs() < 1e-7), "{m}");
            }
        }
    }

    #[test]
    fn uni_reconstruct_matches_manual_gather() {
        let cfg = small("uni");
        let th = init_theta(&cfg, 3).unwrap();
        let stats = gen_statics(&cfg, 3).unwrap();
        let (idx, nrm) = (stats[0].as_i32(), stats[1].as_f32());
        let ds = reconstruct(&cfg, 3, &th).unwrap();
        let ar = cfg.hidden * cfg.rank;
        if let ModuleDelta::LowRank { a, .. } = &ds[1] {
            let o = cfg.module_len(); // module 1 offset
            for k in 0..ar {
                let want = th[idx[o + k] as usize] * nrm[o + k];
                assert!((a[k] - want).abs() < 1e-7);
            }
        } else {
            panic!("expected low-rank");
        }
    }

    #[test]
    fn with_statics_matches_seeded_reconstruct() {
        for m in ["uni", "fastfood", "vb", "vera", "lora_xs", "fourierft"] {
            let cfg = small(m);
            let th = init_theta(&cfg, 4).unwrap();
            let stats = gen_statics(&cfg, 4).unwrap();
            let a = theta_big(&cfg, &reconstruct(&cfg, 4, &th).unwrap());
            let b = theta_big(&cfg, &reconstruct_with_statics(&cfg, &stats, &th).unwrap());
            assert_eq!(a, b, "{m}");
        }
    }

    #[test]
    fn theta_big_layout() {
        let cfg = small("uni");
        let th = init_theta(&cfg, 3).unwrap();
        let ds = reconstruct(&cfg, 3, &th).unwrap();
        let big = theta_big(&cfg, &ds);
        assert_eq!(big.len(), cfg.d_full());
    }

    #[test]
    fn linearity_of_linear_methods() {
        // reconstruct(2*theta) == 2*reconstruct(theta) for linear P
        for m in ["uni", "fastfood", "vb", "fourierft", "lora"] {
            let cfg = small(m);
            let th = init_theta(&cfg, 9).unwrap();
            // vb is linear in bank only with coef fixed; perturb bank only
            let th2: Vec<f32> = th.iter().map(|x| x * 2.0).collect();
            if m == "vb" {
                continue; // bilinear in (bank, coef) jointly — skip
            }
            let b1 = theta_big(&cfg, &reconstruct(&cfg, 9, &th).unwrap());
            let b2 = theta_big(&cfg, &reconstruct(&cfg, 9, &th2).unwrap());
            for (x, y) in b1.iter().zip(&b2) {
                assert!((2.0 * x - y).abs() < 1e-4, "{m}: {x} {y}");
            }
        }
    }
}
