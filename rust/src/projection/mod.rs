//! The projection substrate: everything about P.
//!
//! - `op`         — the `ProjectionOp` trait + method registry: apply
//!                  (theta_d -> factors), vjp (the reverse-mode
//!                  pullback), statics/theta layouts — ONE projection
//!                  API for every method, and the single dispatch
//!                  point (`op::resolve`) the rest of the system uses
//! - `uni`        — the paper's O(D) one-hot projection (gather/scatter,
//!                  index generation for the uni/local/nonuniform variants)
//! - `fastfood`   — the O(D log d) structured baseline (FWHT chain,
//!                  forward + adjoint)
//! - `gaussian`   — the O(D d) dense Gaussian baseline
//! - `statics`    — the `Static` container + validating wrappers over
//!                  the registry, bit-identical with
//!                  python/compile/methods.gen_statics
//! - `reconstruct`— `ModuleDelta` + theta_d -> factors convenience
//!                  wrappers (adapter expansion, Table 1 Jacobians)
//! - `properties` — numeric globality/uniformity/isometry checks (Table 1)

pub mod fastfood;
pub mod gaussian;
pub mod op;
pub mod properties;
pub mod reconstruct;
pub mod statics;
pub mod uni;
