//! The projection substrate: everything about P.
//!
//! - `uni`        — the paper's O(D) one-hot projection (gather/scatter,
//!                  index generation for the uni/local/nonuniform variants)
//! - `fastfood`   — the O(D log d) structured baseline (FWHT chain)
//! - `gaussian`   — the O(D d) dense Gaussian baseline
//! - `statics`    — seed -> frozen method statics, bit-identical with
//!                  python/compile/methods.gen_statics
//! - `reconstruct`— theta_d -> per-module LoRA factors for *every*
//!                  method (adapter expansion, Table 1 Jacobians)
//! - `properties` — numeric globality/uniformity/isometry checks (Table 1)

pub mod fastfood;
pub mod gaussian;
pub mod properties;
pub mod reconstruct;
pub mod statics;
pub mod uni;
