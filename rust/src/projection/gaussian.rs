//! Dense Gaussian projection — the classical O(D d) intrinsic-dimension
//! baseline [Li et al. 2018]. Rows are generated on the fly from the
//! PRNG (never stored), which keeps the *space* at O(1) but leaves the
//! time at O(D d): exactly the complexity row the paper's §3.4 compares
//! against.

use crate::rng;

/// y = (1/sqrt(d)) G theta with G_ij ~ N(0, 1), G generated row-streamed.
pub fn project(seed: u64, theta: &[f32], out_len: usize) -> Vec<f32> {
    let d = theta.len();
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = vec![0f32; out_len];
    for (i, o) in out.iter_mut().enumerate() {
        let row = rng::normals(rng::child_seed(seed, i as u64 + 1), d);
        let mut acc = 0f32;
        for j in 0..d {
            acc += row[j] * theta[j];
        }
        *o = acc * scale;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let th = rng::normals(1, 32);
        assert_eq!(project(7, &th, 64), project(7, &th, 64));
    }

    #[test]
    fn approximately_isometric_in_expectation() {
        // E||Gx/sqrt(d)||^2 per output dim = ||x||^2/d; over out_len=4096
        // outputs the norm ratio concentrates around out/d... we check
        // the JL-style concentration of <Px, Py> ~ <x, y> * (out/d)
        let d = 64;
        let out_len = 4096;
        let x = rng::normals(2, d);
        let px = project(9, &x, out_len);
        let nx: f64 = x.iter().map(|a| (a * a) as f64).sum();
        let npx: f64 = px.iter().map(|a| (a * a) as f64).sum();
        let ratio = npx / (nx * out_len as f64 / d as f64);
        assert!((0.7..1.3).contains(&ratio), "ratio {ratio}");
    }
}
