//! `ProjectionOp`: one projection API for every method.
//!
//! The paper's framing is that every PEFT baseline is just a choice of
//! projection `P` from one trainable vector `theta_d` into the flattened
//! LoRA-parameter space `theta_D` (Uni-LoRA Table 1). This module makes
//! that framing executable: each method is a [`ProjectionOp`] — the
//! theta-to-factors map (`apply`), its reverse-mode pullback (`vjp`,
//! exact for the linear methods and for the bilinear Tied-LoRA / VB-LoRA
//! maps), plus the method's frozen-statics layout and trainable-vector
//! layout — and [`resolve`] is the single registry every layer
//! dispatches through. Nothing above this module matches on a method
//! name anymore: `reconstruct` calls `apply`, the native backend's
//! gradient route calls `vjp`, artifact signatures come from
//! `statics_spec`/`theta_segments`, and Table-1 analysis pushes basis
//! vectors through `apply`.
//!
//! Every `vjp` is validated against central-difference Jacobians of its
//! `apply` in the tests below, for every registered method.

use crate::config::ModelCfg;
use crate::projection::fastfood::{self, FastfoodBlock};
use crate::projection::reconstruct::ModuleDelta;
use crate::projection::statics::{fastfood_block_seed, fastfood_blocks, Static};
use crate::projection::uni::{self, Variant};
use crate::rng;
use anyhow::{bail, ensure, Result};

/// Declared spec of one frozen static input: name, shape and dtype
/// (`is_i32` = integer tensor, else f32). The runtime layer maps these
/// onto its artifact `InputSpec`s; keeping the type here avoids a
/// projection-to-runtime dependency.
#[derive(Debug, Clone)]
pub struct StaticSpec {
    pub name: &'static str,
    pub shape: Vec<usize>,
    pub is_i32: bool,
}

impl StaticSpec {
    fn f32(name: &'static str, shape: Vec<usize>) -> StaticSpec {
        StaticSpec { name, shape, is_i32: false }
    }

    fn i32(name: &'static str, shape: Vec<usize>) -> StaticSpec {
        StaticSpec { name, shape, is_i32: true }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One PEFT method's projection: the map from the trainable vector to
/// per-module LoRA factors, together with everything the rest of the
/// system needs to train, serve and analyze it.
///
/// Implementations must keep `apply` bit-identical with the Python
/// reference (`python/compile/methods.py`) and `vjp` the exact adjoint
/// of `apply` at the evaluation point: linear methods ignore `theta`,
/// the bilinear ones (tied, vb) read the co-factor from it.
pub trait ProjectionOp: Sync {
    /// The `cfg.method` string this op registers under.
    fn method(&self) -> &'static str;

    /// Whether P itself contains trainable parameters (Table 1 col 1).
    fn learned_p(&self) -> bool {
        false
    }

    /// Flattened per-module length of the `apply` output (`theta_D`
    /// rows contributed by one adapted module).
    fn flat_module_len(&self, cfg: &ModelCfg) -> usize {
        cfg.module_len()
    }

    /// Trainable-vector layout: (name, shape, init spec) per segment,
    /// in the order the flat theta vector concatenates them. Empty for
    /// methods with no trainable adapter parameters ("none").
    fn theta_segments(&self, cfg: &ModelCfg) -> Vec<(String, Vec<usize>, String)> {
        let _ = cfg;
        Vec::new()
    }

    /// Shapes/dtypes of the frozen statics, in artifact input order.
    fn statics_spec(&self, cfg: &ModelCfg) -> Vec<StaticSpec> {
        let _ = cfg;
        Vec::new()
    }

    /// Seed -> frozen statics, bit-identical with
    /// `python/compile/methods.gen_statics` (cross-language goldens in
    /// `rust/tests/cross_parity.rs`). Prefer the validating wrapper
    /// `projection::statics::gen_statics` at call sites.
    fn gen_statics(&self, cfg: &ModelCfg, seed: u64) -> Result<Vec<Static>> {
        let _ = (cfg, seed);
        Ok(Vec::new())
    }

    /// The projection itself: theta_d -> per-module weight increments.
    fn apply(&self, cfg: &ModelCfg, stats: &[Static], theta: &[f32]) -> Result<Vec<ModuleDelta>>;

    /// Reverse-mode pullback of `apply` at `theta`: factor cotangents
    /// (same geometry as the `apply` output) -> theta cotangent. Exact
    /// for linear methods (where it is independent of `theta`) and for
    /// the bilinear tied/vb maps (the true reverse-mode derivative at
    /// the point). This is what makes every method natively trainable.
    fn vjp(
        &self,
        cfg: &ModelCfg,
        stats: &[Static],
        theta: &[f32],
        factor_grads: &[ModuleDelta],
    ) -> Result<Vec<f32>>;
}

// ------------------------------------------------------------------
// registry

static UNI_OP: UniOp = UniOp(Variant::Uni);
static LOCAL_OP: UniOp = UniOp(Variant::Local);
static NONUNIFORM_OP: UniOp = UniOp(Variant::NonUniform);
static FASTFOOD_OP: FastfoodOp = FastfoodOp;
static LORA_OP: LoraOp = LoraOp;
static VERA_OP: VeraOp = VeraOp;
static TIED_OP: TiedOp = TiedOp;
static VB_OP: VbOp = VbOp;
static LORA_XS_OP: LoraXsOp = LoraXsOp;
static FOURIERFT_OP: FourierFtOp = FourierFtOp;
static NONE_OP: NoneOp = NoneOp;

/// Every registered projection, in paper order (Table 1/2 then
/// ablations then the no-adapter baseline). Adding a method means
/// implementing [`ProjectionOp`] and listing it here — benches, docs
/// and the trainability surface all follow from this array.
static REGISTRY: [&dyn ProjectionOp; 11] = [
    &UNI_OP,
    &LOCAL_OP,
    &NONUNIFORM_OP,
    &FASTFOOD_OP,
    &LORA_OP,
    &VERA_OP,
    &TIED_OP,
    &VB_OP,
    &LORA_XS_OP,
    &FOURIERFT_OP,
    &NONE_OP,
];

/// The full method registry, in presentation order.
pub fn registry() -> &'static [&'static dyn ProjectionOp] {
    &REGISTRY
}

/// Registered method names, in presentation order.
pub fn method_names() -> Vec<&'static str> {
    REGISTRY.iter().map(|op| op.method()).collect()
}

/// Look a method up by its `cfg.method` string — the single dispatch
/// point for every projection consumer.
pub fn resolve(method: &str) -> Result<&'static dyn ProjectionOp> {
    for op in REGISTRY {
        if op.method() == method {
            return Ok(op);
        }
    }
    bail!("unknown method {method:?} (registered: {})", method_names().join("/"))
}

// ------------------------------------------------------------------
// shared plumbing

/// Split a flat `theta_D` buffer into per-module low-rank factors
/// (A then B per module, the Alg. 1 row convention).
fn lowrank_from_flat(cfg: &ModelCfg, flat: &[f32]) -> Vec<ModuleDelta> {
    let (ml, ar) = (cfg.module_len(), cfg.hidden * cfg.rank);
    (0..cfg.n_modules())
        .map(|i| {
            let o = i * ml;
            ModuleDelta::LowRank {
                a: flat[o..o + ar].to_vec(),
                b: flat[o + ar..o + ml].to_vec(),
            }
        })
        .collect()
}

/// Concatenate low-rank factor cotangents back into the flat `theta_D`
/// layout (the adjoint of `lowrank_from_flat`).
fn flat_from_lowrank_grads(cfg: &ModelCfg, grads: &[ModuleDelta]) -> Result<Vec<f32>> {
    let (ar, nm) = (cfg.hidden * cfg.rank, cfg.n_modules());
    ensure!(grads.len() == nm, "factor grads: got {} modules, want {nm}", grads.len());
    let mut flat = Vec::with_capacity(cfg.d_full());
    for g in grads {
        match g {
            ModuleDelta::LowRank { a, b } => {
                ensure!(a.len() == ar && b.len() == ar, "factor grad shape mismatch");
                flat.extend_from_slice(a);
                flat.extend_from_slice(b);
            }
            ModuleDelta::Dense(_) => bail!("expected low-rank factor grads, got dense"),
        }
    }
    Ok(flat)
}

fn lowrank_grad(g: &ModuleDelta) -> Result<(&[f32], &[f32])> {
    match g {
        ModuleDelta::LowRank { a, b } => Ok((a, b)),
        ModuleDelta::Dense(_) => bail!("expected low-rank factor grads, got dense"),
    }
}

fn check_theta(op: &dyn ProjectionOp, cfg: &ModelCfg, theta: &[f32], want: usize) -> Result<()> {
    ensure!(
        theta.len() == want,
        "method {:?} (cfg {}): theta has {} params, want {want}",
        op.method(),
        cfg.name,
        theta.len()
    );
    Ok(())
}

fn check_stats(op: &dyn ProjectionOp, stats: &[Static], want: usize) -> Result<()> {
    ensure!(
        stats.len() == want,
        "method {:?}: got {} statics, want {want}",
        op.method(),
        stats.len()
    );
    Ok(())
}

/// Modified Gram-Schmidt column orthonormalization of a row-major
/// [h, r] matrix (float64 accumulation — mirrors methods._mgs_columns).
fn mgs_columns(a_f32: &[f32], h: usize, r: usize) -> Vec<f32> {
    let mut a: Vec<f64> = a_f32.iter().map(|&x| x as f64).collect();
    for j in 0..r {
        for i in 0..j {
            let mut dot = 0f64;
            for k in 0..h {
                dot += a[k * r + i] * a[k * r + j];
            }
            for k in 0..h {
                a[k * r + j] -= dot * a[k * r + i];
            }
        }
        let mut nrm = 0f64;
        for k in 0..h {
            nrm += a[k * r + j] * a[k * r + j];
        }
        let nrm = nrm.sqrt();
        for k in 0..h {
            a[k * r + j] /= nrm;
        }
    }
    a.iter().map(|&x| x as f32).collect()
}

// ------------------------------------------------------------------
// uni / local / nonuniform — the paper's one-hot isometry family

/// The paper's O(D) one-hot projection, in its three index variants.
struct UniOp(Variant);

impl ProjectionOp for UniOp {
    fn method(&self) -> &'static str {
        match self.0 {
            Variant::Uni => "uni",
            Variant::Local => "local",
            Variant::NonUniform => "nonuniform",
        }
    }

    fn theta_segments(&self, cfg: &ModelCfg) -> Vec<(String, Vec<usize>, String)> {
        vec![("theta".into(), vec![cfg.d], "uniform:0.02".into())]
    }

    fn statics_spec(&self, cfg: &ModelCfg) -> Vec<StaticSpec> {
        vec![
            StaticSpec::i32("idx", vec![cfg.d_full()]),
            StaticSpec::f32("nrm", vec![cfg.d_full()]),
        ]
    }

    fn gen_statics(&self, cfg: &ModelCfg, seed: u64) -> Result<Vec<Static>> {
        let big_d = cfg.d_full();
        let idx = uni::gen_indices(cfg, seed, self.0);
        let nrm = uni::counts_to_nrm(&idx, cfg.d);
        Ok(vec![Static::i32("idx", vec![big_d], idx), Static::f32("nrm", vec![big_d], nrm)])
    }

    fn apply(&self, cfg: &ModelCfg, stats: &[Static], theta: &[f32]) -> Result<Vec<ModuleDelta>> {
        check_theta(self, cfg, theta, cfg.d)?;
        check_stats(self, stats, 2)?;
        let (idx, nrm) = (stats[0].as_i32(), stats[1].as_f32());
        let mut flat = vec![0f32; idx.len()];
        uni::project(theta, idx, nrm, &mut flat);
        Ok(lowrank_from_flat(cfg, &flat))
    }

    fn vjp(
        &self,
        cfg: &ModelCfg,
        stats: &[Static],
        theta: &[f32],
        factor_grads: &[ModuleDelta],
    ) -> Result<Vec<f32>> {
        check_theta(self, cfg, theta, cfg.d)?;
        check_stats(self, stats, 2)?;
        let flat = flat_from_lowrank_grads(cfg, factor_grads)?;
        Ok(uni::project_t(&flat, stats[0].as_i32(), stats[1].as_f32(), cfg.d))
    }
}

// ------------------------------------------------------------------
// fastfood — the O(D log d) structured baseline

struct FastfoodOp;

impl FastfoodOp {
    /// Slice module `i`'s per-block statics out of the [nm, nb, d]
    /// arrays (`sgn_b`, `gauss`, `perm`, `sgn_s` in artifact order).
    fn module_blocks(&self, cfg: &ModelCfg, stats: &[Static], i: usize) -> Vec<FastfoodBlock> {
        let (nb, d) = (fastfood_blocks(cfg), cfg.d);
        let (sb, g, pm, ss) =
            (stats[0].as_f32(), stats[1].as_f32(), stats[2].as_i32(), stats[3].as_f32());
        (0..nb)
            .map(|j| {
                let o = (i * nb + j) * d;
                FastfoodBlock {
                    sgn_b: sb[o..o + d].to_vec(),
                    gauss: g[o..o + d].to_vec(),
                    perm: pm[o..o + d].to_vec(),
                    sgn_s: ss[o..o + d].to_vec(),
                }
            })
            .collect()
    }

    /// Full-P isometry normalization (mirrors methods.apply).
    fn norm(&self, cfg: &ModelCfg) -> f32 {
        1.0 / ((cfg.n_modules() * fastfood_blocks(cfg)) as f32).sqrt()
    }
}

impl ProjectionOp for FastfoodOp {
    fn method(&self) -> &'static str {
        "fastfood"
    }

    fn theta_segments(&self, cfg: &ModelCfg) -> Vec<(String, Vec<usize>, String)> {
        vec![("theta".into(), vec![cfg.d], "uniform:0.02".into())]
    }

    fn statics_spec(&self, cfg: &ModelCfg) -> Vec<StaticSpec> {
        let shape = vec![cfg.n_modules(), fastfood_blocks(cfg), cfg.d];
        vec![
            StaticSpec::f32("sgn_b", shape.clone()),
            StaticSpec::f32("gauss", shape.clone()),
            StaticSpec::i32("perm", shape.clone()),
            StaticSpec::f32("sgn_s", shape),
        ]
    }

    fn gen_statics(&self, cfg: &ModelCfg, seed: u64) -> Result<Vec<Static>> {
        let (nm, nb, d) = (cfg.n_modules(), fastfood_blocks(cfg), cfg.d);
        let (mut sb, mut g, mut pm, mut ss) = (Vec::new(), Vec::new(), Vec::new(), Vec::new());
        for i in 0..nm {
            for j in 0..nb {
                let base = fastfood_block_seed(seed, i, j);
                sb.extend(rng::signs(rng::child_seed(base, 1), d));
                g.extend(rng::normals(rng::child_seed(base, 2), d));
                pm.extend(rng::permutation(rng::child_seed(base, 3), d));
                ss.extend(rng::signs(rng::child_seed(base, 4), d));
            }
        }
        Ok(vec![
            Static::f32("sgn_b", vec![nm, nb, d], sb),
            Static::f32("gauss", vec![nm, nb, d], g),
            Static::i32("perm", vec![nm, nb, d], pm),
            Static::f32("sgn_s", vec![nm, nb, d], ss),
        ])
    }

    fn apply(&self, cfg: &ModelCfg, stats: &[Static], theta: &[f32]) -> Result<Vec<ModuleDelta>> {
        check_theta(self, cfg, theta, cfg.d)?;
        check_stats(self, stats, 4)?;
        let (nm, ml) = (cfg.n_modules(), cfg.module_len());
        let norm = self.norm(cfg);
        let mut flat = Vec::with_capacity(nm * ml);
        for i in 0..nm {
            let blocks = self.module_blocks(cfg, stats, i);
            flat.extend(fastfood::project(&blocks, theta, ml).iter().map(|x| x * norm));
        }
        Ok(lowrank_from_flat(cfg, &flat))
    }

    fn vjp(
        &self,
        cfg: &ModelCfg,
        stats: &[Static],
        theta: &[f32],
        factor_grads: &[ModuleDelta],
    ) -> Result<Vec<f32>> {
        check_theta(self, cfg, theta, cfg.d)?;
        check_stats(self, stats, 4)?;
        let flat = flat_from_lowrank_grads(cfg, factor_grads)?;
        let (nm, ml) = (cfg.n_modules(), cfg.module_len());
        let norm = self.norm(cfg);
        let mut dtheta = vec![0f32; cfg.d];
        for i in 0..nm {
            let blocks = self.module_blocks(cfg, stats, i);
            let gi: Vec<f32> = flat[i * ml..(i + 1) * ml].iter().map(|x| x * norm).collect();
            for (o, x) in dtheta.iter_mut().zip(fastfood::project_t(&blocks, &gi, cfg.d)) {
                *o += x;
            }
        }
        Ok(dtheta)
    }
}

// ------------------------------------------------------------------
// lora — theta IS the per-module (A, B) stack

struct LoraOp;

impl ProjectionOp for LoraOp {
    fn method(&self) -> &'static str {
        "lora"
    }

    fn learned_p(&self) -> bool {
        true
    }

    fn theta_segments(&self, cfg: &ModelCfg) -> Vec<(String, Vec<usize>, String)> {
        let (h, r) = (cfg.hidden, cfg.rank);
        let mut v = Vec::new();
        for i in 0..cfg.n_modules() {
            v.push((format!("A{i}"), vec![h, r], "normal:0.02".into()));
            v.push((format!("B{i}"), vec![r, h], "zeros".into()));
        }
        v
    }

    fn apply(&self, cfg: &ModelCfg, stats: &[Static], theta: &[f32]) -> Result<Vec<ModuleDelta>> {
        check_theta(self, cfg, theta, cfg.d_full())?;
        check_stats(self, stats, 0)?;
        Ok(lowrank_from_flat(cfg, theta))
    }

    fn vjp(
        &self,
        cfg: &ModelCfg,
        stats: &[Static],
        theta: &[f32],
        factor_grads: &[ModuleDelta],
    ) -> Result<Vec<f32>> {
        check_theta(self, cfg, theta, cfg.d_full())?;
        check_stats(self, stats, 0)?;
        // identity adjoint: the factor cotangents ARE the theta cotangent
        flat_from_lowrank_grads(cfg, factor_grads)
    }
}

// ------------------------------------------------------------------
// vera — frozen shared (pa, pb), trainable diagonal scalings

struct VeraOp;

impl ProjectionOp for VeraOp {
    fn method(&self) -> &'static str {
        "vera"
    }

    fn theta_segments(&self, cfg: &ModelCfg) -> Vec<(String, Vec<usize>, String)> {
        let (h, r, nm) = (cfg.hidden, cfg.rank, cfg.n_modules());
        vec![
            ("lamb_b".into(), vec![nm, h], "zeros".into()),
            ("lamb_d".into(), vec![nm, r], "const:0.1".into()),
        ]
    }

    fn statics_spec(&self, cfg: &ModelCfg) -> Vec<StaticSpec> {
        let (h, r) = (cfg.hidden, cfg.rank);
        vec![StaticSpec::f32("pa_t", vec![h, r]), StaticSpec::f32("pb_t", vec![r, h])]
    }

    fn gen_statics(&self, cfg: &ModelCfg, seed: u64) -> Result<Vec<Static>> {
        let (h, r) = (cfg.hidden, cfg.rank);
        let s = 1.0 / (h as f32).sqrt();
        let pa: Vec<f32> = rng::normals(rng::child_seed(seed, rng::STREAM_VERA_PA), h * r)
            .iter()
            .map(|x| x * s)
            .collect();
        let pb: Vec<f32> = rng::normals(rng::child_seed(seed, rng::STREAM_VERA_PB), r * h)
            .iter()
            .map(|x| x * s)
            .collect();
        Ok(vec![Static::f32("pa_t", vec![h, r], pa), Static::f32("pb_t", vec![r, h], pb)])
    }

    fn apply(&self, cfg: &ModelCfg, stats: &[Static], theta: &[f32]) -> Result<Vec<ModuleDelta>> {
        let (h, r, nm) = (cfg.hidden, cfg.rank, cfg.n_modules());
        check_theta(self, cfg, theta, nm * (h + r))?;
        check_stats(self, stats, 2)?;
        let (pa, pb) = (stats[0].as_f32(), stats[1].as_f32());
        let (lamb_b, lamb_d) = theta.split_at(nm * h);
        Ok(scaled_factors(h, r, nm, pa, pb, lamb_b, lamb_d))
    }

    fn vjp(
        &self,
        cfg: &ModelCfg,
        stats: &[Static],
        theta: &[f32],
        factor_grads: &[ModuleDelta],
    ) -> Result<Vec<f32>> {
        let (h, r, nm) = (cfg.hidden, cfg.rank, cfg.n_modules());
        check_theta(self, cfg, theta, nm * (h + r))?;
        check_stats(self, stats, 2)?;
        ensure!(factor_grads.len() == nm, "factor grads: got {}, want {nm}", factor_grads.len());
        let (pa, pb) = (stats[0].as_f32(), stats[1].as_f32());
        let mut out = vec![0f32; nm * (h + r)];
        let ld_off = nm * h;
        for (i, g) in factor_grads.iter().enumerate() {
            let (ga, gb) = lowrank_grad(g)?;
            // a[p, j] = pa[p, j] * ld[j]  =>  d_ld[j] = sum_p pa[p, j] ga[p, j]
            for p in 0..h {
                for j in 0..r {
                    out[ld_off + i * r + j] += pa[p * r + j] * ga[p * r + j];
                }
            }
            // b[j, k] = pb[j, k] * lb[k]  =>  d_lb[k] = sum_j pb[j, k] gb[j, k]
            for j in 0..r {
                for k in 0..h {
                    out[i * h + k] += pb[j * h + k] * gb[j * h + k];
                }
            }
        }
        Ok(out)
    }
}

/// Shared vera/tied forward: diagonal scalings of the (pa, pb) pair.
fn scaled_factors(
    h: usize,
    r: usize,
    nm: usize,
    pa: &[f32],
    pb: &[f32],
    lamb_b: &[f32],
    lamb_d: &[f32],
) -> Vec<ModuleDelta> {
    (0..nm)
        .map(|i| {
            let lb = &lamb_b[i * h..(i + 1) * h];
            let ld = &lamb_d[i * r..(i + 1) * r];
            // a[p, j] = pa[p, j] * ld[j]; b[j, k] = pb[j, k] * lb[k]
            let mut a = vec![0f32; h * r];
            for p in 0..h {
                for j in 0..r {
                    a[p * r + j] = pa[p * r + j] * ld[j];
                }
            }
            let mut b = vec![0f32; r * h];
            for j in 0..r {
                for k in 0..h {
                    b[j * h + k] = pb[j * h + k] * lb[k];
                }
            }
            ModuleDelta::LowRank { a, b }
        })
        .collect()
}

// ------------------------------------------------------------------
// tied — vera with the (pa, pb) pair itself trainable (bilinear map)

struct TiedOp;

impl TiedOp {
    fn d(&self, cfg: &ModelCfg) -> usize {
        let (h, r, nm) = (cfg.hidden, cfg.rank, cfg.n_modules());
        2 * h * r + nm * (h + r)
    }
}

impl ProjectionOp for TiedOp {
    fn method(&self) -> &'static str {
        "tied"
    }

    fn learned_p(&self) -> bool {
        true
    }

    fn theta_segments(&self, cfg: &ModelCfg) -> Vec<(String, Vec<usize>, String)> {
        let (h, r, nm) = (cfg.hidden, cfg.rank, cfg.n_modules());
        vec![
            ("pa_t".into(), vec![h, r], "normal:0.02".into()),
            ("pb_t".into(), vec![r, h], "normal:0.02".into()),
            ("lamb_b".into(), vec![nm, h], "zeros".into()),
            ("lamb_d".into(), vec![nm, r], "const:0.1".into()),
        ]
    }

    fn apply(&self, cfg: &ModelCfg, stats: &[Static], theta: &[f32]) -> Result<Vec<ModuleDelta>> {
        let (h, r, nm) = (cfg.hidden, cfg.rank, cfg.n_modules());
        check_theta(self, cfg, theta, self.d(cfg))?;
        check_stats(self, stats, 0)?;
        let hr = h * r;
        let (pa, pb) = (&theta[0..hr], &theta[hr..2 * hr]);
        let lamb_b = &theta[2 * hr..2 * hr + nm * h];
        let lamb_d = &theta[2 * hr + nm * h..];
        Ok(scaled_factors(h, r, nm, pa, pb, lamb_b, lamb_d))
    }

    fn vjp(
        &self,
        cfg: &ModelCfg,
        stats: &[Static],
        theta: &[f32],
        factor_grads: &[ModuleDelta],
    ) -> Result<Vec<f32>> {
        let (h, r, nm) = (cfg.hidden, cfg.rank, cfg.n_modules());
        check_theta(self, cfg, theta, self.d(cfg))?;
        check_stats(self, stats, 0)?;
        ensure!(factor_grads.len() == nm, "factor grads: got {}, want {nm}", factor_grads.len());
        let hr = h * r;
        let (pa, pb) = (&theta[0..hr], &theta[hr..2 * hr]);
        let (lb_off, ld_off) = (2 * hr, 2 * hr + nm * h);
        let mut out = vec![0f32; self.d(cfg)];
        for (i, g) in factor_grads.iter().enumerate() {
            let (ga, gb) = lowrank_grad(g)?;
            let lb = &theta[lb_off + i * h..lb_off + (i + 1) * h];
            let ld = &theta[ld_off + i * r..ld_off + (i + 1) * r];
            // bilinear a[p, j] = pa[p, j] * ld[j]: both factors get grads
            for p in 0..h {
                for j in 0..r {
                    let gaij = ga[p * r + j];
                    out[p * r + j] += gaij * ld[j];
                    out[ld_off + i * r + j] += pa[p * r + j] * gaij;
                }
            }
            // bilinear b[j, k] = pb[j, k] * lb[k]
            for j in 0..r {
                for k in 0..h {
                    let gbjk = gb[j * h + k];
                    out[hr + j * h + k] += gbjk * lb[k];
                    out[lb_off + i * h + k] += pb[j * h + k] * gbjk;
                }
            }
        }
        Ok(out)
    }
}

// ------------------------------------------------------------------
// vb — shared vector bank with per-subvector top-K mixing (bilinear)

struct VbOp;

impl VbOp {
    fn n_sub(&self, cfg: &ModelCfg) -> usize {
        cfg.d_full() / cfg.vb_b
    }

    fn d(&self, cfg: &ModelCfg) -> usize {
        cfg.vb_bank * cfg.vb_b + self.n_sub(cfg) * cfg.vb_k
    }
}

impl ProjectionOp for VbOp {
    fn method(&self) -> &'static str {
        "vb"
    }

    fn learned_p(&self) -> bool {
        true
    }

    fn theta_segments(&self, cfg: &ModelCfg) -> Vec<(String, Vec<usize>, String)> {
        vec![
            ("bank".into(), vec![cfg.vb_bank, cfg.vb_b], "uniform:0.02".into()),
            ("coef".into(), vec![self.n_sub(cfg), cfg.vb_k], "const:0.5".into()),
        ]
    }

    fn statics_spec(&self, cfg: &ModelCfg) -> Vec<StaticSpec> {
        vec![StaticSpec::i32("top_idx", vec![self.n_sub(cfg), cfg.vb_k])]
    }

    fn gen_statics(&self, cfg: &ModelCfg, seed: u64) -> Result<Vec<Static>> {
        let n_sub = self.n_sub(cfg);
        let s = rng::child_seed(seed, rng::STREAM_VB_TOPIDX);
        Ok(vec![Static::i32(
            "top_idx",
            vec![n_sub, cfg.vb_k],
            rng::indices(s, n_sub * cfg.vb_k, cfg.vb_bank),
        )])
    }

    fn apply(&self, cfg: &ModelCfg, stats: &[Static], theta: &[f32]) -> Result<Vec<ModuleDelta>> {
        check_theta(self, cfg, theta, self.d(cfg))?;
        check_stats(self, stats, 1)?;
        let top_idx = stats[0].as_i32();
        let (bb, kk) = (cfg.vb_b, cfg.vb_k);
        let n_sub = self.n_sub(cfg);
        let (bank, coef) = theta.split_at(cfg.vb_bank * bb);
        let mut flat = vec![0f32; cfg.d_full()];
        for sv in 0..n_sub {
            for k in 0..kk {
                let c = coef[sv * kk + k];
                let row = top_idx[sv * kk + k] as usize;
                for p in 0..bb {
                    flat[sv * bb + p] += c * bank[row * bb + p];
                }
            }
        }
        Ok(lowrank_from_flat(cfg, &flat))
    }

    fn vjp(
        &self,
        cfg: &ModelCfg,
        stats: &[Static],
        theta: &[f32],
        factor_grads: &[ModuleDelta],
    ) -> Result<Vec<f32>> {
        check_theta(self, cfg, theta, self.d(cfg))?;
        check_stats(self, stats, 1)?;
        let flat = flat_from_lowrank_grads(cfg, factor_grads)?;
        let top_idx = stats[0].as_i32();
        let (bb, kk) = (cfg.vb_b, cfg.vb_k);
        let n_sub = self.n_sub(cfg);
        let bank_len = cfg.vb_bank * bb;
        let (bank, coef) = theta.split_at(bank_len);
        let mut out = vec![0f32; self.d(cfg)];
        for sv in 0..n_sub {
            for k in 0..kk {
                let row = top_idx[sv * kk + k] as usize;
                let c = coef[sv * kk + k];
                let mut dc = 0f32;
                for p in 0..bb {
                    let g = flat[sv * bb + p];
                    out[row * bb + p] += c * g;
                    dc += bank[row * bb + p] * g;
                }
                out[bank_len + sv * kk + k] = dc;
            }
        }
        Ok(out)
    }
}

// ------------------------------------------------------------------
// lora_xs — frozen orthonormal bases, tiny trainable r x r core

struct LoraXsOp;

impl ProjectionOp for LoraXsOp {
    fn method(&self) -> &'static str {
        "lora_xs"
    }

    fn theta_segments(&self, cfg: &ModelCfg) -> Vec<(String, Vec<usize>, String)> {
        let r = cfg.rank;
        (0..cfg.n_modules())
            .map(|i| (format!("R{i}"), vec![r, r], "zeros".into()))
            .collect()
    }

    fn statics_spec(&self, cfg: &ModelCfg) -> Vec<StaticSpec> {
        let (h, r, nm) = (cfg.hidden, cfg.rank, cfg.n_modules());
        vec![StaticSpec::f32("pa_t", vec![nm, h, r]), StaticSpec::f32("pb_t", vec![nm, r, h])]
    }

    fn gen_statics(&self, cfg: &ModelCfg, seed: u64) -> Result<Vec<Static>> {
        // Orthonormal frozen bases (SVD stand-in — orthonormality is
        // what makes LoRA-XS isometric in Table 1). Mirrors the
        // float64 modified Gram-Schmidt in methods.gen_statics.
        let (h, r, nm) = (cfg.hidden, cfg.rank, cfg.n_modules());
        let (mut pa, mut pb) = (Vec::new(), Vec::new());
        for i in 0..nm {
            let base = rng::child_seed(seed, rng::STREAM_XS_BASES + i as u64);
            let ra = rng::normals(rng::child_seed(base, 1), h * r);
            let rb = rng::normals(rng::child_seed(base, 2), r * h);
            pa.extend(mgs_columns(&ra, h, r));
            // pb rows orthonormal = columns of its transpose
            let rb_t: Vec<f32> = (0..h * r)
                .map(|k| rb[(k % r) * h + k / r]) // [r,h] -> [h,r] transpose
                .collect();
            let qt = mgs_columns(&rb_t, h, r); // [h, r] orthonormal cols
            // transpose back to [r, h]
            pb.extend((0..r * h).map(|k| qt[(k % h) * r + k / h]));
        }
        Ok(vec![
            Static::f32("pa_t", vec![nm, h, r], pa),
            Static::f32("pb_t", vec![nm, r, h], pb),
        ])
    }

    fn apply(&self, cfg: &ModelCfg, stats: &[Static], theta: &[f32]) -> Result<Vec<ModuleDelta>> {
        let (h, r, nm) = (cfg.hidden, cfg.rank, cfg.n_modules());
        check_theta(self, cfg, theta, nm * r * r)?;
        check_stats(self, stats, 2)?;
        let (pa, pb) = (stats[0].as_f32(), stats[1].as_f32());
        Ok((0..nm)
            .map(|i| {
                let rr = &theta[i * r * r..(i + 1) * r * r];
                let pai = &pa[i * h * r..(i + 1) * h * r];
                let pbi = &pb[i * r * h..(i + 1) * r * h];
                // effective A' = pa_t @ R^T: a[p, j] = sum_q pa[p, q] R[j, q]
                let mut a = vec![0f32; h * r];
                for p in 0..h {
                    for j in 0..r {
                        let mut acc = 0f32;
                        for q in 0..r {
                            acc += pai[p * r + q] * rr[j * r + q];
                        }
                        a[p * r + j] = acc;
                    }
                }
                ModuleDelta::LowRank { a, b: pbi.to_vec() }
            })
            .collect())
    }

    fn vjp(
        &self,
        cfg: &ModelCfg,
        stats: &[Static],
        theta: &[f32],
        factor_grads: &[ModuleDelta],
    ) -> Result<Vec<f32>> {
        let (h, r, nm) = (cfg.hidden, cfg.rank, cfg.n_modules());
        check_theta(self, cfg, theta, nm * r * r)?;
        check_stats(self, stats, 2)?;
        ensure!(factor_grads.len() == nm, "factor grads: got {}, want {nm}", factor_grads.len());
        let pa = stats[0].as_f32();
        let mut out = vec![0f32; nm * r * r];
        for (i, g) in factor_grads.iter().enumerate() {
            // b is frozen (pb_t): only the A' = pa @ R^T path carries
            // gradient into theta, so the b cotangent is dropped.
            let (ga, _gb) = lowrank_grad(g)?;
            let pai = &pa[i * h * r..(i + 1) * h * r];
            for j in 0..r {
                for q in 0..r {
                    let mut acc = 0f32;
                    for p in 0..h {
                        acc += pai[p * r + q] * ga[p * r + j];
                    }
                    out[i * r * r + j * r + q] = acc;
                }
            }
        }
        Ok(out)
    }
}

// ------------------------------------------------------------------
// fourierft — sparse spectral coefficients, dense DeltaW

struct FourierFtOp;

impl ProjectionOp for FourierFtOp {
    fn method(&self) -> &'static str {
        "fourierft"
    }

    fn flat_module_len(&self, cfg: &ModelCfg) -> usize {
        cfg.hidden * cfg.hidden
    }

    fn theta_segments(&self, cfg: &ModelCfg) -> Vec<(String, Vec<usize>, String)> {
        vec![("coef".into(), vec![cfg.n_modules(), cfg.n_coef], "zeros".into())]
    }

    fn statics_spec(&self, cfg: &ModelCfg) -> Vec<StaticSpec> {
        vec![StaticSpec::i32("freq", vec![cfg.n_modules(), cfg.n_coef, 2])]
    }

    fn gen_statics(&self, cfg: &ModelCfg, seed: u64) -> Result<Vec<Static>> {
        let (h, nm, nc) = (cfg.hidden, cfg.n_modules(), cfg.n_coef);
        let mut f = Vec::with_capacity(nm * nc * 2);
        for i in 0..nm {
            let base = rng::child_seed(seed, rng::STREAM_FOURIER_FREQ + i as u64);
            let f0 = rng::indices(rng::child_seed(base, 1), nc, h);
            let f1 = rng::indices(rng::child_seed(base, 2), nc, h);
            for k in 0..nc {
                f.push(f0[k]);
                f.push(f1[k]);
            }
        }
        Ok(vec![Static::i32("freq", vec![nm, nc, 2], f)])
    }

    fn apply(&self, cfg: &ModelCfg, stats: &[Static], theta: &[f32]) -> Result<Vec<ModuleDelta>> {
        let (h, nm, nc) = (cfg.hidden, cfg.n_modules(), cfg.n_coef);
        check_theta(self, cfg, theta, nm * nc)?;
        check_stats(self, stats, 1)?;
        let freq = stats[0].as_i32();
        let norm = 1.0 / (nc as f32).sqrt();
        Ok((0..nm)
            .map(|mi| {
                let mut dw = vec![0f32; h * h];
                for k in 0..nc {
                    let c = theta[mi * nc + k];
                    if c == 0.0 {
                        continue;
                    }
                    let f1 = freq[(mi * nc + k) * 2] as f32;
                    let f2 = freq[(mi * nc + k) * 2 + 1] as f32;
                    for i in 0..h {
                        let a1 = 2.0 * std::f32::consts::PI * f1 * i as f32 / h as f32;
                        for j in 0..h {
                            let a2 = 2.0 * std::f32::consts::PI * f2 * j as f32 / h as f32;
                            dw[i * h + j] += c * (a1 + a2).cos() * norm;
                        }
                    }
                }
                ModuleDelta::Dense(dw)
            })
            .collect())
    }

    fn vjp(
        &self,
        cfg: &ModelCfg,
        stats: &[Static],
        theta: &[f32],
        factor_grads: &[ModuleDelta],
    ) -> Result<Vec<f32>> {
        let (h, nm, nc) = (cfg.hidden, cfg.n_modules(), cfg.n_coef);
        check_theta(self, cfg, theta, nm * nc)?;
        check_stats(self, stats, 1)?;
        ensure!(factor_grads.len() == nm, "factor grads: got {}, want {nm}", factor_grads.len());
        let freq = stats[0].as_i32();
        let norm = 1.0 / (nc as f32).sqrt();
        let mut out = vec![0f32; nm * nc];
        for (mi, g) in factor_grads.iter().enumerate() {
            let gdw = match g {
                ModuleDelta::Dense(gdw) => gdw,
                ModuleDelta::LowRank { .. } => {
                    bail!("fourierft expects dense factor grads, got low-rank")
                }
            };
            ensure!(gdw.len() == h * h, "dense factor grad shape mismatch");
            for k in 0..nc {
                let f1 = freq[(mi * nc + k) * 2] as f32;
                let f2 = freq[(mi * nc + k) * 2 + 1] as f32;
                let mut acc = 0f32;
                for i in 0..h {
                    let a1 = 2.0 * std::f32::consts::PI * f1 * i as f32 / h as f32;
                    for j in 0..h {
                        let a2 = 2.0 * std::f32::consts::PI * f2 * j as f32 / h as f32;
                        acc += gdw[i * h + j] * (a1 + a2).cos();
                    }
                }
                out[mi * nc + k] = acc * norm;
            }
        }
        Ok(out)
    }
}

// ------------------------------------------------------------------
// none — no adapter (zero deltas; full fine-tuning drives w0 instead)

struct NoneOp;

impl ProjectionOp for NoneOp {
    fn method(&self) -> &'static str {
        "none"
    }

    fn apply(&self, cfg: &ModelCfg, stats: &[Static], theta: &[f32]) -> Result<Vec<ModuleDelta>> {
        let _ = theta; // a 1-element placeholder by the d_effective contract
        check_stats(self, stats, 0)?;
        let ar = cfg.hidden * cfg.rank;
        Ok((0..cfg.n_modules())
            .map(|_| ModuleDelta::LowRank { a: vec![0.0; ar], b: vec![0.0; ar] })
            .collect())
    }

    fn vjp(
        &self,
        cfg: &ModelCfg,
        stats: &[Static],
        theta: &[f32],
        factor_grads: &[ModuleDelta],
    ) -> Result<Vec<f32>> {
        let _ = (cfg, factor_grads);
        check_stats(self, stats, 0)?;
        Ok(vec![0f32; theta.len().max(1)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projection::reconstruct::theta_big;
    use crate::projection::statics::{d_effective, gen_statics};

    fn small(method: &str) -> ModelCfg {
        let mut c = ModelCfg::test_base(method);
        c.hidden = 16;
        c.layers = 2;
        c.rank = 2;
        c.d = 32;
        c.vb_b = 16;
        c.vb_bank = 8;
        c.n_coef = 12;
        c
    }

    #[test]
    fn resolve_covers_every_method_and_rejects_unknown() {
        for m in ["uni", "local", "nonuniform", "fastfood", "lora", "vera",
                  "tied", "vb", "lora_xs", "fourierft", "none"] {
            assert_eq!(resolve(m).unwrap().method(), m);
        }
        assert_eq!(registry().len(), 11);
        let err = resolve("nope").unwrap_err().to_string();
        assert!(err.contains("unknown method"), "{err}");
        assert!(err.contains("uni"), "{err}");
    }

    #[test]
    fn registry_layouts_are_self_consistent() {
        for op in registry() {
            let cfg = small(op.method());
            // theta segment totals match d_effective
            let seg_total: usize = op
                .theta_segments(&cfg)
                .iter()
                .map(|(_, s, _)| s.iter().product::<usize>())
                .sum();
            assert_eq!(seg_total.max(1), d_effective(&cfg), "{}", op.method());
            // generated statics match the declared spec, name for name
            let spec = op.statics_spec(&cfg);
            let gen = op.gen_statics(&cfg, 1).unwrap();
            assert_eq!(spec.len(), gen.len(), "{}", op.method());
            for (s, g) in spec.iter().zip(&gen) {
                assert_eq!(s.name, g.name, "{}", op.method());
                assert_eq!(s.shape, g.shape, "{}/{}", op.method(), s.name);
                assert_eq!(s.numel(), g.len(), "{}/{}", op.method(), s.name);
            }
            // flat_module_len matches what apply actually produces
            let th = crate::projection::statics::init_theta(&cfg, 1).unwrap();
            let ds = op.apply(&cfg, &gen, &th).unwrap();
            assert_eq!(ds.len(), cfg.n_modules(), "{}", op.method());
            let per: usize = match &ds[0] {
                ModuleDelta::LowRank { a, b } => a.len() + b.len(),
                ModuleDelta::Dense(dw) => dw.len(),
            };
            assert_eq!(per, op.flat_module_len(&cfg), "{}", op.method());
        }
    }

    /// The satellite gradient-check harness: `vjp` against a central
    /// finite-difference of `apply`, contracted with a random cotangent,
    /// for EVERY registered method. apply is (at most) bilinear in
    /// theta, so central differences are exact up to f32 rounding.
    fn fd_gradient_check(method: &str) {
        let cfg = small(method);
        let op = resolve(method).unwrap();
        let stats = gen_statics(&cfg, 11).unwrap();
        let d = d_effective(&cfg);
        // a generic (non-init) base point so bilinear terms are active
        let theta = rng::uniform_range(rng::child_seed(100, 1), d, -0.5, 0.5);
        let base = op.apply(&cfg, &stats, &theta).unwrap();
        // random cotangent with the same per-module geometry as apply
        let cot: Vec<ModuleDelta> = base
            .iter()
            .enumerate()
            .map(|(i, m)| match m {
                ModuleDelta::LowRank { a, b } => ModuleDelta::LowRank {
                    a: rng::normals(200 + i as u64, a.len()),
                    b: rng::normals(300 + i as u64, b.len()),
                },
                ModuleDelta::Dense(dw) => ModuleDelta::Dense(rng::normals(400 + i as u64, dw.len())),
            })
            .collect();
        let cot_flat = theta_big(&cfg, &cot);
        let g = op.vjp(&cfg, &stats, &theta, &cot).unwrap();
        assert_eq!(g.len(), d, "{method}: vjp length");
        let eps = 1e-2f32;
        for j in 0..d {
            let mut tp = theta.clone();
            tp[j] += eps;
            let mut tm = theta.clone();
            tm[j] -= eps;
            let fp = theta_big(&cfg, &op.apply(&cfg, &stats, &tp).unwrap());
            let fm = theta_big(&cfg, &op.apply(&cfg, &stats, &tm).unwrap());
            let fd: f64 = fp
                .iter()
                .zip(&fm)
                .zip(&cot_flat)
                .map(|((p, m), c)| ((p - m) as f64 / (2.0 * eps as f64)) * *c as f64)
                .sum();
            let got = g[j] as f64;
            let tol = 1e-2 * (1.0 + fd.abs().max(got.abs()));
            assert!(
                (fd - got).abs() < tol,
                "{method}: dtheta[{j}] fd {fd} vs vjp {got}"
            );
        }
    }

    #[test]
    fn vjp_matches_finite_difference_for_every_method() {
        for op in registry() {
            fd_gradient_check(op.method());
        }
    }

    /// `<P x, y> == <x, P^T y>` on random probes, for the (at most
    /// affine) methods where vjp must be theta-independent; the affine
    /// offset — lora_xs's frozen `b = pb_t` — is subtracted out so the
    /// identity applies to the linear part (sanity beyond the FD check).
    #[test]
    fn vjp_is_adjoint_of_apply_for_linear_methods() {
        for m in ["uni", "local", "nonuniform", "fastfood", "lora", "fourierft", "lora_xs"] {
            let cfg = small(m);
            let op = resolve(m).unwrap();
            let stats = gen_statics(&cfg, 4).unwrap();
            let d = d_effective(&cfg);
            let x = rng::normals(71, d);
            let shape = op.apply(&cfg, &stats, &x).unwrap();
            let p0 = theta_big(&cfg, &op.apply(&cfg, &stats, &vec![0f32; d]).unwrap());
            let px: Vec<f32> = theta_big(&cfg, &shape)
                .iter()
                .zip(&p0)
                .map(|(a, b)| a - b)
                .collect();
            let y: Vec<ModuleDelta> = shape
                .iter()
                .enumerate()
                .map(|(i, md)| match md {
                    ModuleDelta::LowRank { a, b } => ModuleDelta::LowRank {
                        a: rng::normals(500 + i as u64, a.len()),
                        b: rng::normals(600 + i as u64, b.len()),
                    },
                    ModuleDelta::Dense(dw) => {
                        ModuleDelta::Dense(rng::normals(700 + i as u64, dw.len()))
                    }
                })
                .collect();
            let y_flat = theta_big(&cfg, &y);
            let pty = op.vjp(&cfg, &stats, &x, &y).unwrap();
            let lhs: f64 = px.iter().zip(&y_flat).map(|(a, b)| (a * b) as f64).sum();
            let rhs: f64 = x.iter().zip(&pty).map(|(a, b)| (a * b) as f64).sum();
            assert!(
                (lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0),
                "{m}: <Px,y> {lhs} vs <x,P^T y> {rhs}"
            );
        }
    }

    #[test]
    fn learned_p_flags_match_table1() {
        assert!(resolve("tied").unwrap().learned_p());
        assert!(resolve("vb").unwrap().learned_p());
        assert!(resolve("lora").unwrap().learned_p());
        for m in ["uni", "local", "nonuniform", "fastfood", "vera", "lora_xs",
                  "fourierft", "none"] {
            assert!(!resolve(m).unwrap().learned_p(), "{m}");
        }
    }

    #[test]
    fn apply_rejects_wrong_theta_or_statics() {
        let cfg = small("uni");
        let op = resolve("uni").unwrap();
        let stats = gen_statics(&cfg, 1).unwrap();
        // wrong theta length
        assert!(op.apply(&cfg, &stats, &[0.0; 3]).is_err());
        // wrong statics count
        let th = vec![0f32; cfg.d];
        assert!(op.apply(&cfg, &stats[..1], &th).is_err());
        // wrong cotangent geometry for the vjp
        let dense = vec![ModuleDelta::Dense(vec![0.0; cfg.hidden * cfg.hidden]); cfg.n_modules()];
        assert!(op.vjp(&cfg, &stats, &th, &dense).is_err());
    }
}
