//! The paper's projection: each row of P one-hot at a uniformly random
//! column, columns normalized to 1/sqrt(n_j). Never materialized —
//! represented as (idx, nrm) and applied as an O(D) gather
//! (`project`) / scatter (`project_t`).

use crate::config::ModelCfg;
use crate::rng;

/// Index variant: which slots each flattened LoRA coordinate may map to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// Global uniform sharing (the paper's Uni-LoRA).
    Uni,
    /// Per-layer subspace slices of size d/L (Table 7 "Local").
    Local,
    /// A matrices -> first 2d/3 slots, B -> last d/3 (Table 7 "Non-uniform").
    NonUniform,
}

impl Variant {
    pub fn from_method(m: &str) -> Option<Variant> {
        match m {
            "uni" => Some(Variant::Uni),
            "local" => Some(Variant::Local),
            "nonuniform" => Some(Variant::NonUniform),
            _ => None,
        }
    }
}

/// Generate the row->column map. Bit-identical with
/// methods.gen_statics (same STREAM_IDX child stream, same resampling
/// loop — paper footnote 1: re-sample while any used column is empty so
/// the n_j > 0 assumption of Theorem 1 always holds).
pub fn gen_indices(cfg: &ModelCfg, seed: u64, variant: Variant) -> Vec<i32> {
    let d = cfg.d;
    // Guarded by ModelCfg::validate (gen_statics bails before reaching
    // here); assert for direct callers — with d > D the support loop
    // below could never finish.
    assert!(
        d <= cfg.d_full(),
        "gen_indices: d = {d} exceeds D = {} (cfg {})",
        cfg.d_full(),
        cfg.name
    );
    let used = match variant {
        Variant::Local => (d / cfg.layers) * cfg.layers,
        _ => d,
    };
    let s = rng::child_seed(seed, rng::STREAM_IDX);
    let mut idx = Vec::new();
    for attempt in 0..32 {
        idx = gen_indices_attempt(cfg, rng::child_seed(s, attempt), variant);
        let cnt = column_counts(&idx, d);
        if cnt[..used].iter().all(|&c| c > 0) {
            return idx;
        }
    }
    // Low D/d ratio: resampling alone may never find full support.
    // Deterministic patch (mirrors methods._patch_support): give each
    // empty column a row stolen from a column with occupancy >= 2.
    patch_support(&mut idx, d, used, rng::child_seed(s, 999_983));
    idx
}

fn patch_support(idx: &mut [i32], d: usize, used: usize, patch_seed: u64) {
    let mut cnt = column_counts(idx, d);
    let mut pos = 0u64;
    'cols: for j in 0..used {
        if cnt[j] > 0 {
            continue;
        }
        // Rejection-sample a donor row from a column with occupancy >= 2
        // (the common case terminates in a handful of draws). Bounded:
        // past the cap, fall back to a deterministic scan so a skewed
        // occupancy distribution can never hang index generation.
        for _ in 0..10_000 {
            let row = (rng::value(patch_seed, pos) % idx.len() as u64) as usize;
            pos += 1;
            if cnt[idx[row] as usize] >= 2 {
                cnt[idx[row] as usize] -= 1;
                idx[row] = j as i32;
                cnt[j] = 1;
                continue 'cols;
            }
        }
        let row = (0..idx.len())
            .find(|&k| cnt[idx[k] as usize] >= 2)
            .expect("d <= D guarantees a donor column with occupancy >= 2");
        cnt[idx[row] as usize] -= 1;
        idx[row] = j as i32;
        cnt[j] = 1;
    }
}

fn gen_indices_attempt(cfg: &ModelCfg, attempt_seed: u64, variant: Variant) -> Vec<i32> {
    let d = cfg.d;
    let big_d = cfg.d_full();
    let raw = rng::u64_stream(attempt_seed, big_d);
    match variant {
        Variant::Uni => raw.iter().map(|&v| (v % d as u64) as i32).collect(),
        Variant::Local => {
            let dl = d / cfg.layers;
            let per_layer = 2 * cfg.module_len();
            let mut idx = vec![0i32; big_d];
            for l in 0..cfg.layers {
                let (lo, hi) = (l * per_layer, (l + 1) * per_layer);
                for k in lo..hi {
                    idx[k] = (l * dl) as i32 + (raw[k] % dl as u64) as i32;
                }
            }
            idx
        }
        Variant::NonUniform => {
            let da = 2 * d / 3;
            let db = d - da;
            let (ml, ar) = (cfg.module_len(), cfg.hidden * cfg.rank);
            let mut idx = vec![0i32; big_d];
            for i in 0..cfg.n_modules() {
                let o = i * ml;
                for k in o..o + ar {
                    idx[k] = (raw[k] % da as u64) as i32;
                }
                for k in o + ar..o + ml {
                    idx[k] = da as i32 + (raw[k] % db as u64) as i32;
                }
            }
            idx
        }
    }
}

/// Column occupancy counts n_j.
pub fn column_counts(idx: &[i32], d: usize) -> Vec<u32> {
    let mut cnt = vec![0u32; d];
    for &i in idx {
        cnt[i as usize] += 1;
    }
    cnt
}

/// `nrm[k] = 1/sqrt(n_{idx[k]})` — the column normalization of Theorem 1.
pub fn counts_to_nrm(idx: &[i32], d: usize) -> Vec<f32> {
    let cnt = column_counts(idx, d);
    idx.iter()
        .map(|&i| 1.0 / (cnt[i as usize].max(1) as f32).sqrt())
        .collect()
}

/// theta_D = P theta_d: the O(D) gather. `out` has idx.len() entries.
pub fn project(theta: &[f32], idx: &[i32], nrm: &[f32], out: &mut [f32]) {
    debug_assert_eq!(idx.len(), nrm.len());
    debug_assert_eq!(idx.len(), out.len());
    for k in 0..idx.len() {
        out[k] = theta[idx[k] as usize] * nrm[k];
    }
}

/// P^T g: the O(D) scatter-add (gradient route back into theta_d).
pub fn project_t(g: &[f32], idx: &[i32], nrm: &[f32], d: usize) -> Vec<f32> {
    let mut out = vec![0f32; d];
    for k in 0..idx.len() {
        out[idx[k] as usize] += g[k] * nrm[k];
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base(method: &str) -> ModelCfg {
        ModelCfg::test_base(method)
    }

    #[test]
    fn uni_indices_in_range_all_seeds() {
        let cfg = base("uni");
        for seed in 0..20 {
            let idx = gen_indices(&cfg, seed, Variant::Uni);
            assert_eq!(idx.len(), cfg.d_full());
            assert!(idx.iter().all(|&i| (i as usize) < cfg.d));
        }
    }

    #[test]
    fn local_indices_layerwise() {
        let cfg = base("local");
        let idx = gen_indices(&cfg, 3, Variant::Local);
        let per_layer = 2 * cfg.module_len();
        let dl = cfg.d / cfg.layers;
        for l in 0..cfg.layers {
            let chunk = &idx[l * per_layer..(l + 1) * per_layer];
            assert!(chunk.iter().all(|&i| {
                (i as usize) >= l * dl && (i as usize) < (l + 1) * dl
            }));
        }
    }

    #[test]
    fn nonuniform_split() {
        let cfg = base("nonuniform");
        let idx = gen_indices(&cfg, 3, Variant::NonUniform);
        let da = 2 * cfg.d / 3;
        let (ml, ar) = (cfg.module_len(), cfg.hidden * cfg.rank);
        for i in 0..cfg.n_modules() {
            let o = i * ml;
            assert!(idx[o..o + ar].iter().all(|&v| (v as usize) < da));
            assert!(idx[o + ar..o + ml].iter().all(|&v| (v as usize) >= da));
        }
    }

    /// Property sweep: P^T P = I for many random seeds (Theorem 1).
    #[test]
    fn isometry_property_sweep() {
        let cfg = base("uni");
        for seed in 0..12u64 {
            let idx = gen_indices(&cfg, seed, Variant::Uni);
            let nrm = counts_to_nrm(&idx, cfg.d);
            // <P x, P y> == <x, y> for random x, y
            let x = rng::normals(seed * 2 + 1, cfg.d);
            let y = rng::normals(seed * 2 + 2, cfg.d);
            let mut px = vec![0f32; idx.len()];
            let mut py = vec![0f32; idx.len()];
            project(&x, &idx, &nrm, &mut px);
            project(&y, &idx, &nrm, &mut py);
            let dot_sub: f64 = x.iter().zip(&y).map(|(a, b)| (a * b) as f64).sum();
            let dot_full: f64 = px.iter().zip(&py).map(|(a, b)| (a * b) as f64).sum();
            assert!(
                (dot_sub - dot_full).abs() < 1e-3 * dot_sub.abs().max(1.0),
                "seed {seed}: {dot_sub} vs {dot_full}"
            );
        }
    }

    /// Adjoint property sweep: <P x, y> == <x, P^T y>.
    #[test]
    fn transpose_is_adjoint_sweep() {
        let cfg = base("uni");
        for seed in 0..12u64 {
            let idx = gen_indices(&cfg, seed, Variant::Uni);
            let nrm = counts_to_nrm(&idx, cfg.d);
            let x = rng::normals(seed + 100, cfg.d);
            let y = rng::normals(seed + 200, idx.len());
            let mut px = vec![0f32; idx.len()];
            project(&x, &idx, &nrm, &mut px);
            let pty = project_t(&y, &idx, &nrm, cfg.d);
            let lhs: f64 = px.iter().zip(&y).map(|(a, b)| (a * b) as f64).sum();
            let rhs: f64 = x.iter().zip(&pty).map(|(a, b)| (a * b) as f64).sum();
            assert!((lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0));
        }
    }

    #[test]
    fn load_balance_band() {
        let cfg = base("uni");
        let idx = gen_indices(&cfg, 7, Variant::Uni);
        let cnt = column_counts(&idx, cfg.d);
        let mean = cfg.d_full() as f64 / cfg.d as f64;
        let max = *cnt.iter().max().unwrap() as f64;
        let min = *cnt.iter().min().unwrap() as f64;
        assert!(max < 3.0 * mean, "max load {max} vs mean {mean}");
        assert!(min > 0.2 * mean, "min load {min} vs mean {mean}");
    }

    #[test]
    fn project_roundtrip_identity_when_d_equals_rows() {
        // When every row maps to a distinct column, P is a signed
        // permutation-like isometry and P^T P x == x exactly.
        let d = 64;
        let idx: Vec<i32> = (0..d as i32).collect();
        let nrm = counts_to_nrm(&idx, d);
        let x = rng::normals(5, d);
        let mut px = vec![0f32; d];
        project(&x, &idx, &nrm, &mut px);
        let back = project_t(&px, &idx, &nrm, d);
        for (a, b) in x.iter().zip(&back) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
