//! Numeric analysis of each method's projection matrix P (paper Table 1).
//!
//! P is built as the Jacobian of the reconstruct map theta_d -> theta_D
//! at the method's initialization (exact for the linear methods; for the
//! bilinear ones — VeRA/Tied-LoRA, VB-LoRA — this is the Jacobian at
//! init, which is also how the paper's Figure 1 linearizes them).
//!
//! Checks:
//!   globality   — fraction of subspace dims whose support spans >1
//!                 adapted module
//!   uniformity  — max/min column load ratio within a band
//!   isometry    — ||P x|| == ||x|| on random probes

use crate::config::ModelCfg;
use crate::projection::op;
use crate::projection::reconstruct::theta_big;
use crate::projection::statics::{d_effective, gen_statics, init_theta};
use crate::rng;
use anyhow::Result;

#[derive(Debug, Clone)]
pub struct Props {
    pub method: String,
    pub d: usize,
    pub big_d: usize,
    pub learned_p: bool,
    pub globality: bool,
    pub uniformity: bool,
    pub isometry: bool,
    /// max over probes of |(||Px|| - ||x||)| / ||x||
    pub isometry_err: f64,
    /// max/min nonzero-column load ratio (inf if some column is empty)
    pub load_ratio: f64,
    /// fraction of subspace dims touching more than one module
    pub cross_module_frac: f64,
}

/// Whether P itself contains trainable parameters (paper Table 1 col 1)
/// — the registry's `learned_p` flag; unknown methods report false.
pub fn p_is_learned(method: &str) -> bool {
    op::resolve(method).map(|o| o.learned_p()).unwrap_or(false)
}

/// Build the explicit D x d Jacobian of the projection at init, fully
/// generically: push each basis direction of theta_d through the
/// registry's `apply` and difference against the base point. No
/// per-method dispatch — any registered method is analyzable.
pub fn jacobian(cfg: &ModelCfg, seed: u64) -> Result<(Vec<Vec<f32>>, usize)> {
    let proj = op::resolve(&cfg.method)?;
    let stats = gen_statics(cfg, seed)?;
    let d = d_effective(cfg);
    let th0 = init_theta(cfg, seed)?;
    let base = theta_big(cfg, &proj.apply(cfg, &stats, &th0)?);
    let big_d = base.len();
    let eps = 1e-2f32;
    let mut cols: Vec<Vec<f32>> = Vec::with_capacity(d);
    for j in 0..d {
        let mut th = th0.clone();
        th[j] += eps;
        let out = theta_big(cfg, &proj.apply(cfg, &stats, &th)?);
        cols.push(
            out.iter()
                .zip(&base)
                .map(|(a, b)| (a - b) / eps)
                .collect(),
        );
    }
    Ok((cols, big_d))
}

pub fn analyze(cfg: &ModelCfg, seed: u64) -> Result<Props> {
    let (cols, big_d) = jacobian(cfg, seed)?;
    let d = cols.len();
    let tol = 1e-5f32;
    // Row index -> *layer* index, per the theta_D layout. Globality is
    // a cross-layer sharing property (paper §3.3: "local with
    // layer-wise projection"), so bucket at layer granularity
    // (2 modules/layer); the per-module row count comes from the
    // registry (dense methods contribute h*h rows, low-rank 2hr).
    let per_layer = 2 * op::resolve(&cfg.method)?.flat_module_len(cfg);

    // column loads + module support
    let mut loads = Vec::with_capacity(d);
    let mut cross = 0usize;
    let mut active_cols = 0usize;
    for col in &cols {
        let nnz = col.iter().filter(|x| x.abs() > tol).count();
        if nnz == 0 {
            continue;
        }
        active_cols += 1;
        loads.push(nnz as f64);
        let mut layers = std::collections::HashSet::new();
        for (row, v) in col.iter().enumerate() {
            if v.abs() > tol {
                layers.insert(row / per_layer);
            }
        }
        if layers.len() > 1 {
            cross += 1;
        }
    }
    let load_max = loads.iter().cloned().fold(0.0f64, f64::max);
    let load_min = loads.iter().cloned().fold(f64::INFINITY, f64::min);
    let load_mean = loads.iter().sum::<f64>() / loads.len().max(1) as f64;
    let load_ratio = if loads.is_empty() { f64::INFINITY } else { load_max / load_min };
    let cross_module_frac = if active_cols == 0 {
        0.0
    } else {
        cross as f64 / active_cols as f64
    };

    // isometry on random probes through the Jacobian
    let mut iso_err = 0f64;
    for t in 0..8u64 {
        let x = rng::normals(1000 + t, d);
        let mut px = vec![0f64; big_d];
        for (j, col) in cols.iter().enumerate() {
            let xj = x[j] as f64;
            if xj == 0.0 {
                continue;
            }
            for (i, v) in col.iter().enumerate() {
                px[i] += *v as f64 * xj;
            }
        }
        let nx = x.iter().map(|a| (*a as f64).powi(2)).sum::<f64>().sqrt();
        let npx = px.iter().map(|a| a * a).sum::<f64>().sqrt();
        iso_err = iso_err.max(((npx - nx) / nx).abs());
    }

    Ok(Props {
        method: cfg.method.clone(),
        d,
        big_d,
        learned_p: p_is_learned(&cfg.method),
        globality: cross_module_frac > 0.5,
        // statistical balance band: no systematic disparity beyond what
        // balls-in-bins produces (vera's h-vs-r split blows max/mean)
        uniformity: load_min >= load_mean / 8.0 && load_max <= 3.0 * load_mean,
        // 0.1 band: exact for Uni-LoRA (err ~ 1e-6); admits Fastfood's
        // JL-style approximate isometry; excludes vera/tied/vb (err >> 1)
        isometry: iso_err < 0.1,
        isometry_err: iso_err,
        load_ratio,
        cross_module_frac,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small(method: &str) -> ModelCfg {
        let mut c = ModelCfg::test_base(method);
        c.hidden = 16;
        c.layers = 2;
        c.rank = 2;
        c.d = 32;
        c.vb_b = 16;
        c.vb_bank = 8;
        c.n_coef = 12;
        c
    }

    #[test]
    fn uni_has_all_three_properties() {
        let p = analyze(&small("uni"), 42).unwrap();
        assert!(p.globality, "{p:?}");
        assert!(p.uniformity, "{p:?}");
        assert!(p.isometry, "isometry err {}", p.isometry_err);
        assert!(!p.learned_p);
    }

    #[test]
    fn fastfood_is_global_and_isometric() {
        let p = analyze(&small("fastfood"), 42).unwrap();
        assert!(p.globality, "{p:?}");
        assert!(p.isometry, "isometry err {}", p.isometry_err);
    }

    #[test]
    fn vera_is_local_nonuniform_nonisometric() {
        let p = analyze(&small("vera"), 42).unwrap();
        assert!(!p.globality, "{p:?}");
        assert!(!p.uniformity, "load ratio {}", p.load_ratio);
        assert!(!p.isometry, "{p:?}");
        assert!(!p.learned_p);
    }

    #[test]
    fn tied_projection_is_learned() {
        assert!(p_is_learned("tied"));
        assert!(p_is_learned("vb"));
        assert!(!p_is_learned("uni"));
        assert!(!p_is_learned("vera"));
        assert!(!p_is_learned("lora_xs"));
        assert!(!p_is_learned("fastfood"));
    }

    #[test]
    fn local_variant_loses_globality_keeps_isometry() {
        let p = analyze(&small("local"), 42).unwrap();
        assert!(!p.globality, "{p:?}");
        assert!(p.isometry, "{p:?}");
    }

    #[test]
    fn vb_is_global_not_isometric() {
        let p = analyze(&small("vb"), 42).unwrap();
        assert!(p.globality, "{p:?}");
        assert!(!p.isometry, "{p:?}");
    }
}
