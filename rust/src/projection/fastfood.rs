//! Fastfood projection baseline — O(D log d) against Uni-LoRA's O(D)
//! (paper §3.4 and Table 6). Forward chain: S * H(G_hat * Pi(H(B*x))).

use crate::rng;

/// In-place orthonormal fast Walsh-Hadamard transform (len power of 2).
///
/// The butterfly chain is a kernel-layer hot loop: the body comes from
/// the active kernel-variant vtable (`kernels::dispatch`). Every tier
/// keeps the identical per-element `(a + b, a - b)` arithmetic — the
/// lane tier only chunks the stage sweep for the vectorizer — so the
/// transform is bit-identical across tiers and the fastfood statics /
/// reconstruction goldens never depend on `UNI_LORA_KERNELS`.
pub fn fwht(v: &mut [f32]) {
    (crate::kernels::dispatch::ops().fwht)(v)
}

/// Frozen per-block statics for one Fastfood block.
#[derive(Debug, Clone)]
pub struct FastfoodBlock {
    pub sgn_b: Vec<f32>,
    pub gauss: Vec<f32>,
    pub perm: Vec<i32>,
    pub sgn_s: Vec<f32>,
}

impl FastfoodBlock {
    /// Same stream derivation as methods.gen_statics: base seed is the
    /// per-(module, block) child; components are children 1..4 of it.
    pub fn generate(base_seed: u64, d: usize) -> FastfoodBlock {
        FastfoodBlock {
            sgn_b: rng::signs(rng::child_seed(base_seed, 1), d),
            gauss: rng::normals(rng::child_seed(base_seed, 2), d),
            perm: rng::permutation(rng::child_seed(base_seed, 3), d),
            sgn_s: rng::signs(rng::child_seed(base_seed, 4), d),
        }
    }

    /// Apply the block: theta `[d]` -> out `[d]`. O(d log d).
    pub fn apply(&self, theta: &[f32]) -> Vec<f32> {
        let d = theta.len();
        let norm: f32 = self.gauss.iter().map(|g| g * g).sum::<f32>().sqrt();
        let gscale = (d as f32).sqrt() / norm;
        let mut v: Vec<f32> = theta
            .iter()
            .zip(&self.sgn_b)
            .map(|(t, s)| t * s)
            .collect();
        fwht(&mut v);
        let mut w = vec![0f32; d];
        for i in 0..d {
            w[i] = v[self.perm[i] as usize] * self.gauss[i] * gscale;
        }
        fwht(&mut w);
        for i in 0..d {
            w[i] *= self.sgn_s[i];
        }
        w
    }

    /// Adjoint of [`FastfoodBlock::apply`]: cotangent g `[d]` ->
    /// dtheta `[d]`. Every stage is linear — the sign/Gauss diagonals
    /// are self-adjoint, the orthonormal FWHT is symmetric, and the
    /// permutation gather transposes to a scatter — so the chain just
    /// runs backwards. O(d log d), the gradient-path complexity the
    /// paper's Table 6 row implies.
    pub fn apply_t(&self, g: &[f32]) -> Vec<f32> {
        let d = g.len();
        let norm: f32 = self.gauss.iter().map(|g| g * g).sum::<f32>().sqrt();
        let gscale = (d as f32).sqrt() / norm;
        let mut w: Vec<f32> = g.iter().zip(&self.sgn_s).map(|(x, s)| x * s).collect();
        fwht(&mut w);
        let mut v = vec![0f32; d];
        for i in 0..d {
            // forward gathered v[perm[i]] into slot i; scatter back
            v[self.perm[i] as usize] += w[i] * self.gauss[i] * gscale;
        }
        fwht(&mut v);
        for i in 0..d {
            v[i] *= self.sgn_b[i];
        }
        v
    }
}

/// Full Fastfood projection R^d -> R^out_len: ceil(out_len/d) blocks.
pub fn project(blocks: &[FastfoodBlock], theta: &[f32], out_len: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(out_len);
    for b in blocks {
        out.extend(b.apply(theta));
        if out.len() >= out_len {
            break;
        }
    }
    out.truncate(out_len);
    out
}

/// Adjoint of [`project`]: cotangent g (`project`'s out_len entries)
/// -> dtheta `[d]`, summed over blocks. The truncated tail of the last
/// block is zero-padded — the transpose of `project`'s truncation.
pub fn project_t(blocks: &[FastfoodBlock], g: &[f32], d: usize) -> Vec<f32> {
    let mut out = vec![0f32; d];
    for (j, b) in blocks.iter().enumerate() {
        let lo = j * d;
        if lo >= g.len() {
            break;
        }
        let hi = (lo + d).min(g.len());
        let mut gb = vec![0f32; d];
        gb[..hi - lo].copy_from_slice(&g[lo..hi]);
        for (o, x) in out.iter_mut().zip(b.apply_t(&gb)) {
            *o += x;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fwht_involution_isometry() {
        for seed in 0..8u64 {
            let x = rng::normals(seed, 128);
            let mut v = x.clone();
            fwht(&mut v);
            let n0: f64 = x.iter().map(|a| (a * a) as f64).sum();
            let n1: f64 = v.iter().map(|a| (a * a) as f64).sum();
            assert!((n0 - n1).abs() < 1e-3 * n0, "isometry {n0} {n1}");
            fwht(&mut v);
            for (a, b) in x.iter().zip(&v) {
                assert!((a - b).abs() < 1e-4, "involution");
            }
        }
    }

    #[test]
    fn fwht_matches_dense_hadamard_small() {
        // n = 4: H (unnormalized) rows = [+ + + +; + - + -; + + - -; + - - +]
        let mut v = vec![1.0, 2.0, 3.0, 4.0];
        fwht(&mut v);
        let want = [10.0, -2.0, -4.0, 0.0].map(|x: f32| x / 2.0);
        for (a, b) in v.iter().zip(&want) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn block_preserves_norm_approximately() {
        // G normalization makes each block approximately isometric.
        let d = 256;
        let b = FastfoodBlock::generate(7, d);
        let x = rng::normals(3, d);
        let y = b.apply(&x);
        let n0: f64 = x.iter().map(|a| (a * a) as f64).sum();
        let n1: f64 = y.iter().map(|a| (a * a) as f64).sum();
        let ratio = (n1 / n0).sqrt();
        assert!((0.5..2.0).contains(&ratio), "ratio {ratio}");
    }

    /// `<B x, y> == <x, B^T y>` per block, on random probes.
    #[test]
    fn apply_t_is_adjoint_of_apply() {
        let d = 128;
        for seed in 0..6u64 {
            let b = FastfoodBlock::generate(seed, d);
            let x = rng::normals(seed + 10, d);
            let y = rng::normals(seed + 20, d);
            let lhs: f64 = b.apply(&x).iter().zip(&y).map(|(a, c)| (a * c) as f64).sum();
            let rhs: f64 = x.iter().zip(&b.apply_t(&y)).map(|(a, c)| (a * c) as f64).sum();
            assert!((lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0), "seed {seed}: {lhs} {rhs}");
        }
    }

    /// Adjoint identity through the truncating multi-block projection.
    #[test]
    fn project_t_is_adjoint_of_project() {
        let d = 64;
        let out_len = 130; // exercises the zero-padded truncated tail
        let blocks: Vec<_> = (0..3).map(|i| FastfoodBlock::generate(i, d)).collect();
        let x = rng::normals(31, d);
        let y = rng::normals(32, out_len);
        let px = project(&blocks, &x, out_len);
        let pty = project_t(&blocks, &y, d);
        let lhs: f64 = px.iter().zip(&y).map(|(a, c)| (a * c) as f64).sum();
        let rhs: f64 = x.iter().zip(&pty).map(|(a, c)| (a * c) as f64).sum();
        assert!((lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0), "{lhs} vs {rhs}");
    }

    #[test]
    fn project_truncates() {
        let d = 64;
        let blocks: Vec<_> = (0..3).map(|i| FastfoodBlock::generate(i, d)).collect();
        let theta = rng::normals(1, d);
        let out = project(&blocks, &theta, 130);
        assert_eq!(out.len(), 130);
        // first block output is a prefix
        let b0 = blocks[0].apply(&theta);
        assert_eq!(&out[..64], &b0[..]);
    }
}
