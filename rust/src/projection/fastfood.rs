//! Fastfood projection baseline — O(D log d) against Uni-LoRA's O(D)
//! (paper §3.4 and Table 6). Forward chain: S * H(G_hat * Pi(H(B*x))).

use crate::rng;

/// In-place orthonormal fast Walsh-Hadamard transform (len power of 2).
pub fn fwht(v: &mut [f32]) {
    let n = v.len();
    assert!(n.is_power_of_two(), "FWHT length must be a power of two");
    let mut h = 1;
    while h < n {
        let mut i = 0;
        while i < n {
            for j in i..i + h {
                let (a, b) = (v[j], v[j + h]);
                v[j] = a + b;
                v[j + h] = a - b;
            }
            i += 2 * h;
        }
        h *= 2;
    }
    let scale = 1.0 / (n as f32).sqrt();
    for x in v.iter_mut() {
        *x *= scale;
    }
}

/// Frozen per-block statics for one Fastfood block.
#[derive(Debug, Clone)]
pub struct FastfoodBlock {
    pub sgn_b: Vec<f32>,
    pub gauss: Vec<f32>,
    pub perm: Vec<i32>,
    pub sgn_s: Vec<f32>,
}

impl FastfoodBlock {
    /// Same stream derivation as methods.gen_statics: base seed is the
    /// per-(module, block) child; components are children 1..4 of it.
    pub fn generate(base_seed: u64, d: usize) -> FastfoodBlock {
        FastfoodBlock {
            sgn_b: rng::signs(rng::child_seed(base_seed, 1), d),
            gauss: rng::normals(rng::child_seed(base_seed, 2), d),
            perm: rng::permutation(rng::child_seed(base_seed, 3), d),
            sgn_s: rng::signs(rng::child_seed(base_seed, 4), d),
        }
    }

    /// Apply the block: theta [d] -> out [d]. O(d log d).
    pub fn apply(&self, theta: &[f32]) -> Vec<f32> {
        let d = theta.len();
        let norm: f32 = self.gauss.iter().map(|g| g * g).sum::<f32>().sqrt();
        let gscale = (d as f32).sqrt() / norm;
        let mut v: Vec<f32> = theta
            .iter()
            .zip(&self.sgn_b)
            .map(|(t, s)| t * s)
            .collect();
        fwht(&mut v);
        let mut w = vec![0f32; d];
        for i in 0..d {
            w[i] = v[self.perm[i] as usize] * self.gauss[i] * gscale;
        }
        fwht(&mut w);
        for i in 0..d {
            w[i] *= self.sgn_s[i];
        }
        w
    }
}

/// Full Fastfood projection R^d -> R^out_len: ceil(out_len/d) blocks.
pub fn project(blocks: &[FastfoodBlock], theta: &[f32], out_len: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(out_len);
    for b in blocks {
        out.extend(b.apply(theta));
        if out.len() >= out_len {
            break;
        }
    }
    out.truncate(out_len);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fwht_involution_isometry() {
        for seed in 0..8u64 {
            let x = rng::normals(seed, 128);
            let mut v = x.clone();
            fwht(&mut v);
            let n0: f64 = x.iter().map(|a| (a * a) as f64).sum();
            let n1: f64 = v.iter().map(|a| (a * a) as f64).sum();
            assert!((n0 - n1).abs() < 1e-3 * n0, "isometry {n0} {n1}");
            fwht(&mut v);
            for (a, b) in x.iter().zip(&v) {
                assert!((a - b).abs() < 1e-4, "involution");
            }
        }
    }

    #[test]
    fn fwht_matches_dense_hadamard_small() {
        // n = 4: H (unnormalized) rows = [+ + + +; + - + -; + + - -; + - - +]
        let mut v = vec![1.0, 2.0, 3.0, 4.0];
        fwht(&mut v);
        let want = [10.0, -2.0, -4.0, 0.0].map(|x: f32| x / 2.0);
        for (a, b) in v.iter().zip(&want) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn block_preserves_norm_approximately() {
        // G normalization makes each block approximately isometric.
        let d = 256;
        let b = FastfoodBlock::generate(7, d);
        let x = rng::normals(3, d);
        let y = b.apply(&x);
        let n0: f64 = x.iter().map(|a| (a * a) as f64).sum();
        let n1: f64 = y.iter().map(|a| (a * a) as f64).sum();
        let ratio = (n1 / n0).sqrt();
        assert!((0.5..2.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn project_truncates() {
        let d = 64;
        let blocks: Vec<_> = (0..3).map(|i| FastfoodBlock::generate(i, d)).collect();
        let theta = rng::normals(1, d);
        let out = project(&blocks, &theta, 130);
        assert_eq!(out.len(), 130);
        // first block output is a prefix
        let b0 = blocks[0].apply(&theta);
        assert_eq!(&out[..64], &b0[..]);
    }
}
