//! Uni-LoRA: One Vector is All You Need — system reproduction.
//!
//! Three-layer architecture:
//! - L1/L2 (build time, Python): Pallas projection kernels + JAX transformer,
//!   AOT-lowered to HLO text under `artifacts/`.
//! - L3 (this crate, Rust): training coordinator, projection substrate,
//!   synthetic data pipelines, adapter registry, and a multi-adapter server.
//!
//! Python never runs on the request path: the coordinator loads the HLO
//! artifacts through PJRT (`xla` crate) and drives everything from Rust.

pub mod adapters;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod generation;
pub mod kernels;
pub mod metrics;
pub mod obs;
pub mod projection;
pub mod rng;
pub mod runtime;
pub mod server;
pub mod session;
pub mod util;
