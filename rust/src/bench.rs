//! Hand-rolled micro-bench harness (criterion is unavailable in the
//! offline vendor set). Median-of-runs with warmup; prints
//! criterion-style lines so `cargo bench` output stays readable.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub median_secs: f64,
    pub min_secs: f64,
    pub max_secs: f64,
    pub iters: usize,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<44} median {:>12}  (min {}, max {}, n={})",
            self.name,
            fmt_time(self.median_secs),
            fmt_time(self.min_secs),
            fmt_time(self.max_secs),
            self.iters
        )
    }

    pub fn per_sec(&self, items: f64) -> f64 {
        items / self.median_secs
    }
}

pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

/// Time `f` with `warmup` discarded runs then `iters` measured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let r = BenchResult {
        name: name.to_string(),
        median_secs: times[times.len() / 2],
        min_secs: times[0],
        max_secs: *times.last().unwrap(),
        iters,
    };
    println!("{}", r.line());
    r
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noopish", 1, 5, || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            black_box(acc);
        });
        assert!(r.median_secs >= 0.0);
        assert!(r.min_secs <= r.median_secs && r.median_secs <= r.max_secs);
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2.5e-9).ends_with("ns"));
        assert!(fmt_time(2.5e-5).ends_with("µs"));
        assert!(fmt_time(2.5e-2).ends_with("ms"));
        assert!(fmt_time(2.5).ends_with("s"));
    }
}
