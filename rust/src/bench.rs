//! Hand-rolled micro-bench harness (criterion is unavailable in the
//! offline vendor set). Median-of-runs with warmup; prints
//! criterion-style lines so `cargo bench` output stays readable.
//!
//! Perf trajectory: with `UNI_LORA_BENCH_JSON=1`, benches serialize
//! their results (per-shape GFLOP/s for the scalar vs simd kernel
//! tiers, see `benches/train_step.rs` and `benches/projection.rs`)
//! into a machine-readable `BENCH_kernels.json` at the repo root, each
//! bench merging its own top-level key so the file accumulates one
//! recorded trajectory across benches.

use crate::util::json::{self, Json};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub median_secs: f64,
    pub min_secs: f64,
    pub max_secs: f64,
    pub iters: usize,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<44} median {:>12}  (min {}, max {}, n={})",
            self.name,
            fmt_time(self.median_secs),
            fmt_time(self.min_secs),
            fmt_time(self.max_secs),
            self.iters
        )
    }

    pub fn per_sec(&self, items: f64) -> f64 {
        items / self.median_secs
    }

    /// Machine-readable form for the `BENCH_kernels.json` trajectory.
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("name", json::s(&self.name)),
            ("median_secs", json::n(self.median_secs)),
            ("min_secs", json::n(self.min_secs)),
            ("max_secs", json::n(self.max_secs)),
            ("iters", json::n(self.iters as f64)),
        ])
    }
}

/// Whether the bench run should serialize results: exactly
/// `UNI_LORA_BENCH_JSON=1` enables; anything else (unset, `0`,
/// garbage) degrades to off — the same forgiving-parse convention as
/// the `config` knobs, and no surprise file writes on a typo.
pub fn json_report_enabled() -> bool {
    match std::env::var("UNI_LORA_BENCH_JSON") {
        Ok(v) => v.trim() == "1",
        Err(_) => false,
    }
}

/// The trajectory file: `BENCH_kernels.json` at the repo root (one
/// level above the crate manifest).
pub fn bench_json_path() -> PathBuf {
    named_json_path("kernels")
}

/// A named trajectory file — `BENCH_<name>.json` at the repo root
/// (`BENCH_kernels.json` for the compute tiers, `BENCH_serving.json`
/// for the decode/session numbers; `scripts/bench_snapshot.sh`
/// archives them per commit).
pub fn named_json_path(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join(format!("BENCH_{name}.json"))
}

/// Env-gated write into a named trajectory file (see
/// [`write_json_report`], which this generalizes): no-op unless
/// `UNI_LORA_BENCH_JSON=1`; returns the path written, if any.
pub fn write_named_json_report(
    file: &str,
    source: &str,
    entries: Vec<Json>,
) -> anyhow::Result<Option<PathBuf>> {
    if !json_report_enabled() {
        return Ok(None);
    }
    let path = named_json_path(file);
    write_json_report_at(&path, source, entries)?;
    Ok(Some(path))
}

/// Merge `entries` into the JSON report at `path` under the top-level
/// key `source`, preserving every other bench's key (so train_step and
/// projection accumulate into one file). A missing file starts fresh
/// and a corrupt one is rebuilt from scratch, but a real read error
/// (permissions, I/O) propagates instead of silently clobbering the
/// accumulated trajectory — the same NotFound-vs-error split
/// `adapters::Registry::load_dir` uses. The write itself goes through
/// a temp file + rename, so a bench run killed mid-write can never
/// leave a truncated file that would wipe the trajectory on the next
/// run.
pub fn write_json_report_at(path: &Path, source: &str, entries: Vec<Json>) -> anyhow::Result<()> {
    let mut root: BTreeMap<String, Json> = match std::fs::read_to_string(path) {
        Ok(s) => match Json::parse(&s) {
            Ok(Json::Obj(m)) => m,
            _ => BTreeMap::new(),
        },
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => BTreeMap::new(),
        Err(e) => return Err(anyhow::anyhow!("reading {}: {e}", path.display())),
    };
    root.insert(source.to_string(), Json::Arr(entries));
    let tmp = path.with_extension("json.tmp");
    std::fs::write(&tmp, Json::Obj(root).to_string())
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .map_err(|e| anyhow::anyhow!("renaming {} into place: {e}", tmp.display()))?;
    Ok(())
}

/// Env-gated convenience over [`write_json_report_at`]: no-op unless
/// `UNI_LORA_BENCH_JSON=1`; returns the path written, if any.
pub fn write_json_report(source: &str, entries: Vec<Json>) -> anyhow::Result<Option<PathBuf>> {
    if !json_report_enabled() {
        return Ok(None);
    }
    let path = bench_json_path();
    write_json_report_at(&path, source, entries)?;
    Ok(Some(path))
}

pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

/// Time `f` with `warmup` discarded runs then `iters` measured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let r = BenchResult {
        name: name.to_string(),
        median_secs: times[times.len() / 2],
        min_secs: times[0],
        max_secs: *times.last().unwrap(),
        iters,
    };
    println!("{}", r.line());
    r
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noopish", 1, 5, || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            black_box(acc);
        });
        assert!(r.median_secs >= 0.0);
        assert!(r.min_secs <= r.median_secs && r.median_secs <= r.max_secs);
    }

    #[test]
    fn named_paths_follow_convention() {
        assert!(named_json_path("serving").ends_with("BENCH_serving.json"));
        assert_eq!(bench_json_path(), named_json_path("kernels"));
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2.5e-9).ends_with("ns"));
        assert!(fmt_time(2.5e-5).ends_with("µs"));
        assert!(fmt_time(2.5e-2).ends_with("ms"));
        assert!(fmt_time(2.5).ends_with("s"));
    }

    #[test]
    fn bench_result_serializes() {
        let r = BenchResult {
            name: "gemm_nn/128x128x128".into(),
            median_secs: 1.5e-4,
            min_secs: 1.0e-4,
            max_secs: 2.0e-4,
            iters: 9,
        };
        let j = r.to_json();
        assert_eq!(j.get("name").unwrap().as_str().unwrap(), "gemm_nn/128x128x128");
        assert_eq!(j.get("iters").unwrap().as_usize().unwrap(), 9);
        let back = Json::parse(&j.to_string()).unwrap();
        assert!((back.get("median_secs").unwrap().as_f64().unwrap() - 1.5e-4).abs() < 1e-12);
    }

    #[test]
    fn json_report_merges_sources_and_survives_garbage() {
        let dir = std::env::temp_dir()
            .join(format!("uni_lora_bench_json_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_kernels.json");
        // fresh file
        write_json_report_at(&path, "train_step", vec![json::obj(vec![("a", json::n(1.0))])])
            .unwrap();
        // second source merges, first survives
        write_json_report_at(&path, "projection", vec![json::obj(vec![("b", json::n(2.0))])])
            .unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.get("train_step").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(j.get("projection").unwrap().as_arr().unwrap().len(), 1);
        // re-writing a source replaces only that key
        write_json_report_at(&path, "train_step", vec![]).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.get("train_step").unwrap().as_arr().unwrap().len(), 0);
        assert_eq!(j.get("projection").unwrap().as_arr().unwrap().len(), 1);
        // corrupt file starts fresh instead of erroring
        std::fs::write(&path, "not json").unwrap();
        write_json_report_at(&path, "x", vec![]).unwrap();
        assert!(Json::parse(&std::fs::read_to_string(&path).unwrap()).is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
