//! Eval-time beam search over full `[B, T]` forwards.
//!
//! The serving stack samples or argmaxes one token per step; the
//! math/instruct eval harness (PAPER.md §5 generation tasks) also
//! wants beam search, which needs *alternative* continuations kept
//! alive — a poor fit for decode-session slots (each slot is one
//! committed sequence). So beams run the way the legacy golden decode
//! loop does: ordinary `Backend::run` `lm_logits` executions, beams
//! packed into batch rows, scored by summed log-softmax.
//!
//! Determinism contract: expansion order is total (score descending,
//! then parent beam, then token id, compared with `total_cmp`), and
//! scoring is f64 accumulation in a fixed order — so beam output is
//! bit-stable across runs and thread counts, like everything else in
//! the decode surface. Width 1 degenerates to exactly the legacy
//! greedy stream (same EOS / context-window / budget rules; ties break
//! to the lowest token id, matching `metrics::argmax`'s first-max
//! rule), which `tests/generation.rs` pins.

use crate::config::ModelCfg;
use crate::data::vocab;
use crate::projection::statics::Static;
use crate::runtime::{Backend, TensorIn};
use anyhow::Result;
use std::sync::Arc;

struct Beam {
    /// emitted continuation (prompt excluded)
    toks: Vec<i32>,
    /// summed log-softmax of every emitted step
    score: f64,
    done: bool,
}

/// Beam-search decode of `prompts` (shared adapter theta), `width`
/// beams per prompt, up to `max_new` emitted tokens. Returns the
/// highest-scoring beam's emitted tokens per prompt. The signature
/// mirrors `coordinator::trainer::decode_with` — the eval harness
/// calls it through [`crate::coordinator::trainer::LmTrainer::beam_decode`].
pub fn beam_decode_with(
    exec: &mut dyn Backend,
    art_logits: &str,
    cfg: &ModelCfg,
    theta: &[f32],
    w0: &[f32],
    stats: &[Static],
    prompts: &[Vec<i32>],
    max_new: usize,
    width: usize,
) -> Result<Vec<Vec<i32>>> {
    anyhow::ensure!(width >= 1, "beam width must be >= 1, got {width}");
    // frozen inputs wrapped as shared tensors once (refcount bumps per
    // step, not backbone copies — same hoist as decode_with)
    let theta_in = TensorIn::SharedF32(Arc::new(theta.to_vec()));
    let w0_in = TensorIn::SharedF32(Arc::new(w0.to_vec()));
    let stat_ins: Vec<TensorIn> = stats.iter().map(TensorIn::shared_from).collect();
    let mut out = Vec::with_capacity(prompts.len());
    for p in prompts {
        out.push(beam_one(exec, art_logits, cfg, &theta_in, &w0_in, &stat_ins, p, max_new, width)?);
    }
    Ok(out)
}

fn beam_one(
    exec: &mut dyn Backend,
    art_logits: &str,
    cfg: &ModelCfg,
    theta_in: &TensorIn,
    w0_in: &TensorIn,
    stat_ins: &[TensorIn],
    prompt: &[i32],
    max_new: usize,
    width: usize,
) -> Result<Vec<i32>> {
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    let (bsz, t, vocab_n) = (cfg.batch, cfg.seq, cfg.vocab);
    let plen = prompt.len();
    if plen >= t || max_new == 0 {
        // the legacy loop's stillborn rows: window already full (an
        // over-window prompt truncates to full), or zero budget
        return Ok(Vec::new());
    }
    let mut beams = vec![Beam { toks: Vec::new(), score: 0.0, done: false }];
    for _ in 0..max_new {
        let live: Vec<usize> = (0..beams.len()).filter(|&i| !beams[i].done).collect();
        if live.is_empty() {
            break;
        }
        // one forward per batch-row chunk of live beams
        let mut rows: Vec<Vec<f64>> = (0..beams.len()).map(|_| Vec::new()).collect();
        for chunk in live.chunks(bsz) {
            let mut toks = vec![vocab::PAD; bsz * t];
            for (row, &bi) in chunk.iter().enumerate() {
                let b = &beams[bi];
                toks[row * t..row * t + plen].copy_from_slice(prompt);
                toks[row * t + plen..row * t + plen + b.toks.len()].copy_from_slice(&b.toks);
            }
            let mut inputs = vec![theta_in.clone(), w0_in.clone(), TensorIn::I32(toks)];
            inputs.extend(stat_ins.iter().cloned());
            let outv = exec.run(art_logits, &inputs)?;
            let logits = outv[0].as_f32()?; // [B, T, V]
            for (row, &bi) in chunk.iter().enumerate() {
                let pos = plen + beams[bi].toks.len() - 1;
                let slice = &logits[(row * t + pos) * vocab_n..(row * t + pos + 1) * vocab_n];
                rows[bi] = crate::metrics::log_softmax(slice);
            }
        }
        // expand: finished beams carry over as single candidates, live
        // beams branch on every vocabulary token
        let mut cand: Vec<(f64, usize, Option<i32>)> = Vec::new();
        for (bi, b) in beams.iter().enumerate() {
            if b.done {
                cand.push((b.score, bi, None));
            } else {
                for (tok, lp) in rows[bi].iter().enumerate() {
                    cand.push((b.score + lp, bi, Some(tok as i32)));
                }
            }
        }
        cand.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2)));
        cand.truncate(width);
        beams = cand
            .into_iter()
            .map(|(score, bi, tok)| {
                let parent = &beams[bi];
                match tok {
                    // carried-over finished beam, or EOS: ends without
                    // emitting (the greedy EOS rule)
                    None => Beam { toks: parent.toks.clone(), score, done: true },
                    Some(tk) if tk == vocab::EOS => {
                        Beam { toks: parent.toks.clone(), score, done: true }
                    }
                    Some(tk) => {
                        let mut toks = parent.toks.clone();
                        toks.push(tk);
                        // window fills: the token at the last position
                        // is kept, then the beam is done (legacy
                        // `lens >= t`)
                        let done = plen + toks.len() >= t;
                        Beam { toks, score, done }
                    }
                }
            })
            .collect();
    }
    // best = highest summed log-prob; ties break to the earlier beam
    // (which the selection sort already ordered deterministically)
    let best = beams
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.score.total_cmp(&b.1.score).then(b.0.cmp(&a.0)))
        .map(|(_, b)| b)
        .expect("width >= 1 guarantees at least one beam");
    Ok(best.toks.clone())
}
