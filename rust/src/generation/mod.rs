//! Generation subsystem: everything between a logits row and an
//! emitted token.
//!
//! [`SamplingParams`] is the per-request decoding policy — temperature,
//! top-k / top-p truncation, repetition penalty, stop sequences,
//! per-token logit bias and a replay seed. [`Sampler`] applies it one
//! logits row at a time, drawing from a deterministic
//! [`crate::rng::Stream`] child ([`crate::rng::STREAM_SAMPLE`]), so an
//! identical `(request, seed)` pair replays a bit-identical token
//! stream across runs and thread counts — the same counter-based
//! determinism contract the projection streams already carry.
//!
//! Greedy decoding is the `temperature = 0` special case of this code
//! path, not a separate one: with default params the sampler routes
//! through plain [`crate::metrics::argmax`] and consumes **zero** RNG
//! draws, so temperature-0 streams are bit-equal to the legacy greedy
//! decode by construction (held to it in `tests/decode_parity.rs`).
//! Sampling happens strictly after the logits GEMM, so the fused
//! batched decode step and per-slot stepping stay token-stream
//! identical under any params.
//!
//! [`beam`] adds beam search as an eval-time decode mode over full
//! `[B, T]` forwards (the math/instruct harness); it is not a serving
//! path.

pub mod beam;

use crate::util::json::{n, obj, Json};
use anyhow::{anyhow, ensure, Result};

/// Per-request decoding policy. `Default` is exact greedy: temperature
/// 0, no truncation, no penalty, no stops, no bias — the configuration
/// every pre-existing caller implicitly ran.
#[derive(Debug, Clone, PartialEq)]
pub struct SamplingParams {
    /// Softmax temperature; `0` = greedy argmax (the default). Must be
    /// finite and >= 0.
    pub temperature: f32,
    /// Keep only the `top_k` highest-logit tokens before sampling;
    /// `0` = disabled.
    pub top_k: usize,
    /// Nucleus truncation: keep the smallest prefix of the
    /// probability-sorted vocabulary whose mass reaches `top_p`. Must
    /// be in (0, 1]; `1` = disabled.
    pub top_p: f32,
    /// Divide positive logits (multiply negative ones) of
    /// already-emitted tokens by this factor; `1` = disabled. Must be
    /// finite and > 0.
    pub repetition_penalty: f32,
    /// Replay seed: the sampler draws from
    /// `Stream::child(seed, STREAM_SAMPLE)`.
    pub seed: u64,
    /// Stop sequences over emitted tokens. A sequence ends — without
    /// emitting — when the next token would complete any stop
    /// sequence; earlier tokens of a partial match are already out.
    pub stop: Vec<Vec<i32>>,
    /// Additive per-token logit adjustments, applied before
    /// temperature/truncation. Out-of-vocabulary ids are ignored at
    /// pick time (vocabulary size is an artifact property the wire
    /// layer cannot see).
    pub logit_bias: Vec<(i32, f32)>,
}

impl Default for SamplingParams {
    fn default() -> SamplingParams {
        SamplingParams {
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
            repetition_penalty: 1.0,
            seed: 0,
            stop: Vec::new(),
            logit_bias: Vec::new(),
        }
    }
}

impl SamplingParams {
    /// Temperature-0 requests pick deterministically (argmax after
    /// bias/penalty) and consume no RNG draws.
    pub fn is_greedy(&self) -> bool {
        self.temperature == 0.0
    }

    /// Range-check every field with a typed message (the wire layer
    /// surfaces these verbatim; sessions re-check at admission).
    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.temperature.is_finite() && self.temperature >= 0.0,
            "sampling.temperature must be finite and >= 0, got {}",
            self.temperature
        );
        ensure!(
            self.top_p.is_finite() && self.top_p > 0.0 && self.top_p <= 1.0,
            "sampling.top_p must be in (0, 1], got {}",
            self.top_p
        );
        ensure!(
            self.repetition_penalty.is_finite() && self.repetition_penalty > 0.0,
            "sampling.repetition_penalty must be finite and > 0, got {}",
            self.repetition_penalty
        );
        ensure!(
            self.stop.iter().all(|s| !s.is_empty()),
            "sampling.stop sequences must be non-empty token arrays"
        );
        ensure!(
            self.logit_bias.iter().all(|&(_, b)| b.is_finite()),
            "sampling.logit_bias values must be finite"
        );
        Ok(())
    }

    /// Parse the `sampling` object of a `generate` request. Unknown
    /// keys are an error (satellite: no more silently-accepted
    /// garbage), every field is range-validated via
    /// [`SamplingParams::validate`].
    pub fn from_json(j: &Json) -> Result<SamplingParams> {
        const ALLOWED: [&str; 7] =
            ["temperature", "top_k", "top_p", "repetition_penalty", "seed", "stop", "logit_bias"];
        for k in j.as_obj()?.keys() {
            ensure!(ALLOWED.contains(&k.as_str()), "unknown sampling key {k:?}");
        }
        let d = SamplingParams::default();
        let p = SamplingParams {
            temperature: match j.get("temperature") {
                Some(v) => v.as_f64()? as f32,
                None => d.temperature,
            },
            top_k: match j.get("top_k") {
                Some(v) => non_negative_int(v, "sampling.top_k")? as usize,
                None => d.top_k,
            },
            top_p: match j.get("top_p") {
                Some(v) => v.as_f64()? as f32,
                None => d.top_p,
            },
            repetition_penalty: match j.get("repetition_penalty") {
                Some(v) => v.as_f64()? as f32,
                None => d.repetition_penalty,
            },
            seed: match j.get("seed") {
                Some(v) => non_negative_int(v, "sampling.seed")?,
                None => d.seed,
            },
            stop: match j.get("stop") {
                Some(v) => v
                    .as_arr()?
                    .iter()
                    .map(|seq| {
                        seq.as_arr()?.iter().map(|t| Ok(t.as_i64()? as i32)).collect::<Result<_>>()
                    })
                    .collect::<Result<_>>()?,
                None => Vec::new(),
            },
            logit_bias: match j.get("logit_bias") {
                Some(v) => v
                    .as_arr()?
                    .iter()
                    .map(|pair| {
                        let p = pair.as_arr()?;
                        ensure!(p.len() == 2, "sampling.logit_bias entries are [token, bias] pairs");
                        Ok((p[0].as_i64()? as i32, p[1].as_f64()? as f32))
                    })
                    .collect::<Result<_>>()?,
                None => Vec::new(),
            },
        };
        p.validate()?;
        Ok(p)
    }

    /// Wire form: only non-default fields are emitted, so a default
    /// (greedy) request serializes without a `sampling` object at all.
    pub fn to_json(&self) -> Json {
        let d = SamplingParams::default();
        let mut pairs: Vec<(&str, Json)> = Vec::new();
        if self.temperature != d.temperature {
            pairs.push(("temperature", n(self.temperature as f64)));
        }
        if self.top_k != d.top_k {
            pairs.push(("top_k", n(self.top_k as f64)));
        }
        if self.top_p != d.top_p {
            pairs.push(("top_p", n(self.top_p as f64)));
        }
        if self.repetition_penalty != d.repetition_penalty {
            pairs.push(("repetition_penalty", n(self.repetition_penalty as f64)));
        }
        if self.seed != d.seed {
            pairs.push(("seed", n(self.seed as f64)));
        }
        if !self.stop.is_empty() {
            pairs.push((
                "stop",
                Json::Arr(
                    self.stop
                        .iter()
                        .map(|s| Json::Arr(s.iter().map(|&t| n(t as f64)).collect()))
                        .collect(),
                ),
            ));
        }
        if !self.logit_bias.is_empty() {
            pairs.push((
                "logit_bias",
                Json::Arr(
                    self.logit_bias
                        .iter()
                        .map(|&(t, b)| Json::Arr(vec![n(t as f64), n(b as f64)]))
                        .collect(),
                ),
            ));
        }
        obj(pairs)
    }
}

fn non_negative_int(v: &Json, what: &str) -> Result<u64> {
    let f = v.as_f64()?;
    if f.fract() != 0.0 || !(0.0..=9.007_199_254_740_992e15).contains(&f) {
        return Err(anyhow!("{what} must be a non-negative integer, got {f}"));
    }
    Ok(f as u64)
}

/// Per-sequence sampler state: the params, the seeded draw stream, and
/// the emitted-token history (repetition penalty + stop matching).
/// One lives in each decode-session slot ([`crate::session`]) and is
/// consulted once per emission, strictly after the logits GEMM.
#[derive(Debug, Clone)]
pub struct Sampler {
    params: SamplingParams,
    stream: crate::rng::Stream,
    emitted: Vec<i32>,
}

impl Sampler {
    pub fn new(params: SamplingParams) -> Sampler {
        let stream = crate::rng::Stream::child(params.seed, crate::rng::STREAM_SAMPLE);
        Sampler { params, stream, emitted: Vec::new() }
    }

    pub fn params(&self) -> &SamplingParams {
        &self.params
    }

    /// The pure-greedy fast path: nothing perturbs the logits row, so
    /// the pick IS `metrics::argmax` and no scratch copy or RNG draw
    /// happens — this is what makes temperature-0 requests bit-equal
    /// (and cost-equal) to the legacy greedy decode.
    fn pure_greedy(&self) -> bool {
        self.params.temperature == 0.0
            && self.params.logit_bias.is_empty()
            && self.params.repetition_penalty == 1.0
    }

    /// Pick the next token for one logits row. Temperature-0 picks
    /// argmax (after bias/penalty); otherwise one `next_f64` CDF draw
    /// over the truncated, temperature-scaled softmax — exactly one
    /// draw per emitted token, so streams replay positionally.
    pub fn pick(&mut self, logits: &[f32]) -> i32 {
        if self.pure_greedy() {
            return crate::metrics::argmax(logits) as i32;
        }
        let mut row: Vec<f32> = logits.to_vec();
        for &(tok, bias) in &self.params.logit_bias {
            if let Some(x) = usize::try_from(tok).ok().and_then(|t| row.get_mut(t)) {
                *x += bias;
            }
        }
        if self.params.repetition_penalty != 1.0 {
            for (i, &tok) in self.emitted.iter().enumerate() {
                if self.emitted[..i].contains(&tok) {
                    continue; // penalize each distinct token once
                }
                if let Some(x) = usize::try_from(tok).ok().and_then(|t| row.get_mut(t)) {
                    *x = if *x > 0.0 {
                        *x / self.params.repetition_penalty
                    } else {
                        *x * self.params.repetition_penalty
                    };
                }
            }
        }
        if self.params.temperature == 0.0 {
            return crate::metrics::argmax(&row) as i32;
        }
        // candidate order: logit descending, index ascending — total
        // and deterministic (total_cmp), so truncation and the CDF
        // walk are replayable bit-for-bit
        let mut cand: Vec<(usize, f32)> = row.iter().copied().enumerate().collect();
        cand.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        if self.params.top_k > 0 && self.params.top_k < cand.len() {
            cand.truncate(self.params.top_k);
        }
        let t = self.params.temperature as f64;
        let mx = cand[0].1 as f64;
        let mut probs: Vec<f64> = cand.iter().map(|&(_, l)| ((l as f64 - mx) / t).exp()).collect();
        let mut z: f64 = probs.iter().sum();
        if self.params.top_p < 1.0 {
            let mut cum = 0.0;
            let mut keep = cand.len();
            for (i, p) in probs.iter().enumerate() {
                cum += p / z;
                if cum >= self.params.top_p as f64 {
                    keep = i + 1;
                    break;
                }
            }
            cand.truncate(keep);
            probs.truncate(keep);
            z = probs.iter().sum();
        }
        let u = self.stream.next_f64() * z;
        let mut acc = 0.0;
        for (k, &(idx, _)) in cand.iter().enumerate() {
            acc += probs[k];
            if u < acc {
                return idx as i32;
            }
        }
        cand[cand.len() - 1].0 as i32
    }

    /// Would emitting `next` complete a stop sequence? Checked by the
    /// session BEFORE the token is recorded: the sequence ends without
    /// emitting it (the EOS rule, generalized to arbitrary suffixes).
    pub fn stop_hit(&self, next: i32) -> bool {
        self.params.stop.iter().any(|s| match s.split_last() {
            Some((last, head)) => *last == next && self.emitted.ends_with(head),
            None => false,
        })
    }

    /// Record an emitted token (repetition penalty and stop matching
    /// both read this history).
    pub fn note_emitted(&mut self, tok: i32) {
        self.emitted.push(tok);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_params_are_greedy_and_valid() {
        let d = SamplingParams::default();
        assert!(d.is_greedy());
        d.validate().unwrap();
        // default params never consume RNG draws
        let mut s = Sampler::new(d);
        let pos0 = s.stream.pos;
        let row = vec![0.0, 3.0, 1.0];
        assert_eq!(s.pick(&row), 1);
        assert_eq!(s.pick(&row), 1);
        assert_eq!(s.stream.pos, pos0, "greedy picks must not draw");
    }

    #[test]
    fn validation_rejects_out_of_range_fields() {
        let reject = |p: SamplingParams, what: &str| {
            let err = p.validate().unwrap_err().to_string();
            assert!(err.contains(what), "{what}: {err}");
        };
        reject(SamplingParams { temperature: -1.0, ..Default::default() }, "temperature");
        reject(SamplingParams { temperature: f32::NAN, ..Default::default() }, "temperature");
        reject(SamplingParams { top_p: 0.0, ..Default::default() }, "top_p");
        reject(SamplingParams { top_p: 1.5, ..Default::default() }, "top_p");
        let bad_pen = SamplingParams { repetition_penalty: 0.0, ..Default::default() };
        reject(bad_pen, "repetition_penalty");
        reject(SamplingParams { stop: vec![vec![]], ..Default::default() }, "stop");
        let bias = vec![(1, f32::INFINITY)];
        reject(SamplingParams { logit_bias: bias, ..Default::default() }, "logit_bias");
    }

    #[test]
    fn json_roundtrip_and_unknown_keys() {
        let p = SamplingParams {
            temperature: 0.8,
            top_k: 5,
            top_p: 0.9,
            repetition_penalty: 1.2,
            seed: 7,
            stop: vec![vec![3, 4]],
            logit_bias: vec![(2, -1.5)],
        };
        let back = SamplingParams::from_json(&p.to_json()).unwrap();
        assert_eq!(p, back);
        // default serializes to an empty object and parses back
        assert_eq!(SamplingParams::default().to_json().to_string(), "{}");
        let err = SamplingParams::from_json(&Json::parse(r#"{"temperatur":1.0}"#).unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown sampling key"), "{err}");
        let err = SamplingParams::from_json(&Json::parse(r#"{"top_p":2.0}"#).unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("top_p"), "{err}");
        let err = SamplingParams::from_json(&Json::parse(r#"{"seed":-1}"#).unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("non-negative integer"), "{err}");
    }

    #[test]
    fn seeded_picks_replay_and_diverge_across_seeds() {
        let row: Vec<f32> = (0..32).map(|i| ((i * 7) % 13) as f32 * 0.3).collect();
        let params = |seed| SamplingParams { temperature: 1.0, seed, ..Default::default() };
        let run = |seed| {
            let mut s = Sampler::new(params(seed));
            (0..20)
                .map(|_| {
                    let t = s.pick(&row);
                    s.note_emitted(t);
                    t
                })
                .collect::<Vec<i32>>()
        };
        assert_eq!(run(1), run(1), "same seed must replay bit-identically");
        let a = run(1);
        let b = run(2);
        assert_ne!(a, b, "different seeds should diverge on a 32-token row over 20 draws");
        // exactly one draw per pick: replay from a cloned sampler state
        let mut s = Sampler::new(params(9));
        let before = s.stream.pos;
        s.pick(&row);
        assert_eq!(s.stream.pos, before + 1);
    }

    #[test]
    fn top_k_and_top_p_truncate_support() {
        let mut row = vec![0.0f32; 8];
        row[2] = 10.0;
        row[5] = 9.0;
        row[7] = 8.0;
        // top_k=2: only tokens 2 and 5 can ever appear
        let mut s = Sampler::new(SamplingParams {
            temperature: 1.0,
            top_k: 2,
            seed: 3,
            ..Default::default()
        });
        for _ in 0..50 {
            let t = s.pick(&row);
            assert!(t == 2 || t == 5, "top_k=2 leaked token {t}");
        }
        // top_p tiny: collapses to the single highest-probability token
        let mut s = Sampler::new(SamplingParams {
            temperature: 1.0,
            top_p: 1e-6,
            seed: 3,
            ..Default::default()
        });
        for _ in 0..20 {
            assert_eq!(s.pick(&row), 2);
        }
    }

    #[test]
    fn logit_bias_and_repetition_penalty_shift_the_argmax() {
        let row = vec![0.0, 5.0, 4.0];
        // bias is applied even at temperature 0
        let mut s = Sampler::new(SamplingParams {
            logit_bias: vec![(2, 2.0)],
            ..Default::default()
        });
        assert_eq!(s.pick(&row), 2);
        // out-of-range bias ids are ignored, not a crash
        let mut s = Sampler::new(SamplingParams {
            logit_bias: vec![(-1, 9.0), (99, 9.0)],
            ..Default::default()
        });
        assert_eq!(s.pick(&row), 1);
        // a strong repetition penalty demotes the emitted token
        let mut s = Sampler::new(SamplingParams {
            repetition_penalty: 10.0,
            ..Default::default()
        });
        assert_eq!(s.pick(&row), 1);
        s.note_emitted(1);
        assert_eq!(s.pick(&row), 2, "penalized token 1 must lose to token 2");
    }

    #[test]
    fn stop_sequences_match_on_the_completing_token() {
        let mut s = Sampler::new(SamplingParams {
            stop: vec![vec![4, 5], vec![9]],
            ..Default::default()
        });
        assert!(s.stop_hit(9), "single-token stop fires immediately");
        assert!(!s.stop_hit(5), "multi-token stop needs its prefix emitted");
        s.note_emitted(4);
        assert!(s.stop_hit(5), "prefix [4] + next 5 completes [4, 5]");
        assert!(!s.stop_hit(4));
    }
}
