//! The compute layer: cache-blocked multi-threaded GEMM kernels in two
//! tiers behind one API, plus a shared std-only thread pool. Everything
//! dense in `runtime/native` — forward products, weight/input
//! gradients, attention drivers, elementwise maps — routes through
//! this module, which makes it the single seam where kernel tiers
//! (and, eventually, GPU offload) plug in without touching the model
//! code above.
//!
//! Layout:
//! - `pool`: shared worker pool (`UNI_LORA_THREADS` / `set_threads` /
//!   `ThreadsGuard`), caller-participating so nested fan-outs never
//!   deadlock, plus the `SendPtr` disjoint-write escape hatch for
//!   parallel drivers.
//! - `dispatch`: the kernel-variant vtable (`UNI_LORA_KERNELS=
//!   scalar|simd|auto` resolved once against the CPU feature probe)
//!   and the scalar bodies of the shared hot loops (GELU maps,
//!   LM-softmax row max, FWHT).
//! - `gemm`: `gemm_nn` / `gemm_tn` / `gemm_nt` entry points (acc flag,
//!   validated preconditions, `_with` variants taking an explicit
//!   vtable) and the scalar golden-reference panel bodies; every tier
//!   is bitwise-deterministic across runs and thread counts.
//! - `simd`: the register-tiled lane tier — portable fixed-width
//!   accumulator blocks plus an AVX2+FMA intrinsic path.
//! - `naive`: the retained single-threaded reference kernels the
//!   scalar tier is property-tested bit-equal against.

pub mod dispatch;
pub mod gemm;
pub mod naive;
pub mod pool;
pub mod simd;

pub use dispatch::{ops, set_choice, variant, KernelOps, Variant};
pub use gemm::{gemm_nn, gemm_nn_with, gemm_nt, gemm_nt_with, gemm_tn, gemm_tn_with};
pub use pool::{pool, set_threads, threads, Pool, SendPtr, ThreadsGuard};

/// Below roughly this much work (MAC-scale units) a fan-out costs more
/// than it saves; drivers run inline on the caller instead.
pub const PAR_MIN_WORK: usize = 16 * 1024;

/// Run `body(i)` for i in [0, tasks) across the global pool when
/// `work` is large enough to amortize the fan-out, else inline.
/// `work` must not depend on the thread count (results never do).
pub fn parallel_for_work<F>(work: usize, tasks: usize, body: F)
where
    F: Fn(usize) + Sync,
{
    if tasks <= 1 || work < PAR_MIN_WORK {
        for i in 0..tasks {
            body(i);
        }
        return;
    }
    pool().parallel_for(tasks, &body);
}

/// Split [0, n) into fixed-size chunks of `grain` and run
/// `body(start, end)` for each across the global pool. The partition
/// depends only on (n, grain) — never on the thread count — so
/// order-sensitive per-chunk reductions stay deterministic.
pub fn parallel_chunks<F>(n: usize, grain: usize, body: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let grain = grain.max(1);
    let tasks = (n + grain - 1) / grain;
    parallel_for_work(n, tasks, |t| {
        let s = t * grain;
        body(s, (s + grain).min(n));
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn parallel_chunks_covers_range_with_fixed_grain() {
        let hits: Vec<AtomicUsize> = (0..100_000).map(|_| AtomicUsize::new(0)).collect();
        parallel_chunks(hits.len(), 1024, |s, e| {
            assert!(s < e && e <= hits.len());
            assert_eq!(s % 1024, 0, "partition must be grain-aligned");
            for h in &hits[s..e] {
                h.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn small_work_runs_inline_in_index_order() {
        // work below PAR_MIN_WORK: body runs sequentially on the caller
        let order = std::sync::Mutex::new(Vec::new());
        parallel_for_work(8, 8, |i| order.lock().unwrap().push(i));
        assert_eq!(*order.lock().unwrap(), (0..8).collect::<Vec<_>>());
    }
}
