//! Cache-blocked, multi-threaded GEMM microkernels over flat row-major
//! `&[f32]` buffers — the compute layer every dense matmul in the
//! native backend routes through (`gemm_nn` forward products, `gemm_tn`
//! weight gradients, `gemm_nt` input gradients).
//!
//! Parallel strategy: output row panels. Each task owns a disjoint
//! panel of output rows and accumulates every contribution to its rows
//! in the exact order of the retained naive reference (k ascending for
//! nn/tn, one sequential dot per element for nt), so results are
//! bitwise identical across runs, across thread counts, AND to the
//! pre-kernels loop nests — only wall-clock changes. Blocking keeps
//! the streamed operand (the k-panel of `w`, the i-panel of `b`)
//! resident in cache across the rows of a panel; `gemm_tn` additionally
//! packs the strided column block of `a` into a contiguous scratch
//! tile before the accumulation sweep.
//!
//! Preconditions are validated up front with clear messages (the old
//! free `matmul*` functions only had `debug_assert`s and relied on
//! indexing panics mid-write in release builds).

use super::pool::{self, SendPtr};
use super::PAR_MIN_WORK;

/// k-block height for `gemm_nn`: the w panel (KC x m) stays cache-hot
/// while a row panel of x sweeps over it.
const NN_KC: usize = 128;

/// p-block height for `gemm_nt`: the b panel (PB x m) is reused by
/// every row of the task's output panel.
const NT_PB: usize = 64;

/// i-block height for `gemm_tn`: rows of a/b consumed per packed tile.
const TN_IC: usize = 32;

/// out[n,m] (+)= x[n,k] @ w[k,m]
pub fn gemm_nn(x: &[f32], w: &[f32], out: &mut [f32], n: usize, k: usize, m: usize, acc: bool) {
    assert!(x.len() == n * k, "gemm_nn: x.len() = {}, want n*k = {}*{}", x.len(), n, k);
    assert!(w.len() == k * m, "gemm_nn: w.len() = {}, want k*m = {}*{}", w.len(), k, m);
    assert!(out.len() == n * m, "gemm_nn: out.len() = {}, want n*m = {}*{}", out.len(), n, m);
    if !acc {
        out.fill(0.0);
    }
    if n == 0 || k == 0 || m == 0 {
        return;
    }
    par_row_panels(out, n, m, n * k * m, |i0, i1, panel| nn_panel(x, w, panel, i0, i1, k, m));
}

/// out[k,m] (+)= a[n,k]^T @ b[n,m]   (weight-gradient shape)
pub fn gemm_tn(a: &[f32], b: &[f32], out: &mut [f32], n: usize, k: usize, m: usize, acc: bool) {
    assert!(a.len() == n * k, "gemm_tn: a.len() = {}, want n*k = {}*{}", a.len(), n, k);
    assert!(b.len() == n * m, "gemm_tn: b.len() = {}, want n*m = {}*{}", b.len(), n, m);
    assert!(out.len() == k * m, "gemm_tn: out.len() = {}, want k*m = {}*{}", out.len(), k, m);
    if !acc {
        out.fill(0.0);
    }
    if n == 0 || k == 0 || m == 0 {
        return;
    }
    par_row_panels(out, k, m, n * k * m, |p0, p1, panel| tn_panel(a, b, panel, p0, p1, n, k, m));
}

/// out[n,k] (+)= a[n,m] @ b[k,m]^T   (input-gradient shape)
pub fn gemm_nt(a: &[f32], b: &[f32], out: &mut [f32], n: usize, k: usize, m: usize, acc: bool) {
    assert!(a.len() == n * m, "gemm_nt: a.len() = {}, want n*m = {}*{}", a.len(), n, m);
    assert!(b.len() == k * m, "gemm_nt: b.len() = {}, want k*m = {}*{}", b.len(), k, m);
    assert!(out.len() == n * k, "gemm_nt: out.len() = {}, want n*k = {}*{}", out.len(), n, k);
    if !acc {
        out.fill(0.0);
    }
    if n == 0 || k == 0 || m == 0 {
        return;
    }
    par_row_panels(out, n, k, n * k * m, |i0, i1, panel| nt_panel(a, b, panel, i0, i1, k, m));
}

// ------------------------------------------------------------------
// parallel driver

/// Split `out` ([rows, cols] row-major) into disjoint row panels and
/// run `body(row0, row1, panel)` for each across the pool. Row
/// ownership is exclusive, so any schedule produces the same bits.
fn par_row_panels<F>(out: &mut [f32], rows: usize, cols: usize, macs: usize, body: F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    let p = pool::pool();
    if p.threads() == 1 || macs < PAR_MIN_WORK || rows == 1 {
        body(0, rows, out);
        return;
    }
    let tasks = (p.threads() * 4).min(rows);
    let chunk = (rows + tasks - 1) / tasks;
    let ptr = SendPtr::new(out);
    p.parallel_for(tasks, &|t| {
        let i0 = t * chunk;
        if i0 >= rows {
            return;
        }
        let i1 = (i0 + chunk).min(rows);
        // SAFETY: tasks own disjoint half-open row ranges of `out`.
        let panel = unsafe { ptr.slice(i0 * cols, (i1 - i0) * cols) };
        body(i0, i1, panel);
    });
}

// ------------------------------------------------------------------
// panel kernels (single-threaded, fixed accumulation order)

#[inline]
fn axpy(y: &mut [f32], x: &[f32], a: f32) {
    for (yi, &xi) in y.iter_mut().zip(x.iter()) {
        *yi += a * xi;
    }
}

/// Dot product in strict sequential order — the exact reduction order
/// of the legacy `matmul_nt`, so every gemm kernel is bitwise-identical
/// to the pre-kernels code (training losses reproduce at any thread
/// count). Reassociating for SIMD width belongs to a future SIMD
/// kernel variant behind the same API, where the parity story can be
/// renegotiated explicitly.
#[inline]
fn dot(x: &[f32], y: &[f32]) -> f32 {
    let mut s = 0f32;
    for (a, b) in x.iter().zip(y.iter()) {
        s += a * b;
    }
    s
}

fn nn_panel(x: &[f32], w: &[f32], panel: &mut [f32], i0: usize, i1: usize, k: usize, m: usize) {
    let mut kb = 0;
    while kb < k {
        let ke = (kb + NN_KC).min(k);
        for i in i0..i1 {
            let xrow = &x[i * k + kb..i * k + ke];
            let prow = &mut panel[(i - i0) * m..(i - i0) * m + m];
            for (p, &a) in xrow.iter().enumerate() {
                if a != 0.0 {
                    axpy(prow, &w[(kb + p) * m..(kb + p) * m + m], a);
                }
            }
        }
        kb = ke;
    }
}

fn nt_panel(a: &[f32], b: &[f32], panel: &mut [f32], i0: usize, i1: usize, k: usize, m: usize) {
    let mut pb = 0;
    while pb < k {
        let pe = (pb + NT_PB).min(k);
        for i in i0..i1 {
            let arow = &a[i * m..i * m + m];
            let prow = &mut panel[(i - i0) * k..(i - i0) * k + k];
            for p in pb..pe {
                prow[p] += dot(arow, &b[p * m..p * m + m]);
            }
        }
        pb = pe;
    }
}

fn tn_panel(
    a: &[f32],
    b: &[f32],
    panel: &mut [f32],
    p0: usize,
    p1: usize,
    n: usize,
    k: usize,
    m: usize,
) {
    let pw = p1 - p0;
    let mut pack = vec![0f32; pw * TN_IC];
    let mut ib = 0;
    while ib < n {
        let ie = (ib + TN_IC).min(n);
        let iw = ie - ib;
        // pack a[ib..ie, p0..p1] transposed: pack[(p - p0)*iw + (i - ib)]
        for i in ib..ie {
            let arow = &a[i * k + p0..i * k + p1];
            for (pp, &av) in arow.iter().enumerate() {
                pack[pp * iw + (i - ib)] = av;
            }
        }
        for pp in 0..pw {
            let prow = &mut panel[pp * m..pp * m + m];
            let pcol = &pack[pp * iw..pp * iw + iw];
            for (ii, &av) in pcol.iter().enumerate() {
                if av != 0.0 {
                    axpy(prow, &b[(ib + ii) * m..(ib + ii) * m + m], av);
                }
            }
        }
        ib = ie;
    }
}

#[cfg(test)]
mod tests {
    use super::super::naive::{gemm_nn_ref, gemm_nt_ref, gemm_tn_ref};
    use super::*;
    use crate::config::RuntimeOpts;
    use crate::rng;

    fn seeded(seed: u64, len: usize) -> Vec<f32> {
        let mut v = rng::normals(seed, len);
        // sprinkle exact zeros so the zero-skip paths are exercised
        for (i, x) in v.iter_mut().enumerate() {
            if i % 7 == 3 {
                *x = 0.0;
            }
        }
        v
    }

    /// Satellite: blocked/threaded kernels vs the retained naive
    /// reference over odd shapes, acc on/off, threads in {1, 4};
    /// bitwise-deterministic across runs and across thread counts.
    #[test]
    fn property_blocked_matches_naive_over_odd_shapes() {
        let shapes = [1usize, 3, 17, 64, 129];
        for &n in &shapes {
            for &k in &shapes {
                for &m in &shapes {
                    for acc in [false, true] {
                        check_one(n, k, m, acc);
                    }
                }
            }
        }
        pool::set_threads(RuntimeOpts::from_env().threads);
    }

    fn check_one(n: usize, k: usize, m: usize, acc: bool) {
        let seed = (n * 1_000_003 + k * 1009 + m) as u64;
        let x_nn = seeded(seed, n * k);
        let w_nn = seeded(seed + 1, k * m);
        let a_tn = seeded(seed + 2, n * k);
        let b_tn = seeded(seed + 3, n * m);
        let a_nt = seeded(seed + 4, n * m);
        let b_nt = seeded(seed + 5, k * m);
        let init_nn = seeded(seed + 6, n * m);
        let init_tn = seeded(seed + 7, k * m);
        let init_nt = seeded(seed + 8, n * k);

        let run = |f: &dyn Fn(&mut Vec<f32>), init: &[f32]| -> Vec<f32> {
            let mut out = init.to_vec();
            f(&mut out);
            out
        };

        let want_nn = run(&|o: &mut Vec<f32>| gemm_nn_ref(&x_nn, &w_nn, o, n, k, m, acc), &init_nn);
        let want_tn = run(&|o: &mut Vec<f32>| gemm_tn_ref(&a_tn, &b_tn, o, n, k, m, acc), &init_tn);
        let want_nt = run(&|o: &mut Vec<f32>| gemm_nt_ref(&a_nt, &b_nt, o, n, k, m, acc), &init_nt);

        let mut per_thread_count = Vec::new();
        for threads in [1usize, 4] {
            pool::set_threads(threads);
            let nn = run(&|o: &mut Vec<f32>| gemm_nn(&x_nn, &w_nn, o, n, k, m, acc), &init_nn);
            let tn = run(&|o: &mut Vec<f32>| gemm_tn(&a_tn, &b_tn, o, n, k, m, acc), &init_tn);
            let nt = run(&|o: &mut Vec<f32>| gemm_nt(&a_nt, &b_nt, o, n, k, m, acc), &init_nt);
            // bitwise-deterministic across runs at a fixed thread count
            let nn2 = run(&|o: &mut Vec<f32>| gemm_nn(&x_nn, &w_nn, o, n, k, m, acc), &init_nn);
            assert_eq!(nn, nn2, "gemm_nn not run-deterministic ({n},{k},{m},{acc},{threads})");
            let nt2 = run(&|o: &mut Vec<f32>| gemm_nt(&a_nt, &b_nt, o, n, k, m, acc), &init_nt);
            assert_eq!(nt, nt2, "gemm_nt not run-deterministic ({n},{k},{m},{acc},{threads})");
            // all three keep the reference accumulation order exactly
            assert_eq!(nn, want_nn, "gemm_nn != naive ({n},{k},{m},{acc},{threads})");
            assert_eq!(tn, want_tn, "gemm_tn != naive ({n},{k},{m},{acc},{threads})");
            assert_eq!(nt, want_nt, "gemm_nt != naive ({n},{k},{m},{acc},{threads})");
            per_thread_count.push((nn, tn, nt));
        }
        // bitwise identical across thread counts
        assert_eq!(per_thread_count[0], per_thread_count[1], "thread-count variant ({n},{k},{m})");
    }

    fn panic_msg(e: Box<dyn std::any::Any + Send>) -> String {
        e.downcast_ref::<String>()
            .cloned()
            .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default()
    }

    /// Satellite: slice-length preconditions fail fast with a clear
    /// message instead of an indexing panic mid-write.
    #[test]
    fn preconditions_reject_bad_lengths_up_front() {
        macro_rules! panics_with {
            ($what:expr, $body:expr) => {{
                let err = std::panic::catch_unwind(|| $body).expect_err("no panic");
                let msg = panic_msg(err);
                assert!(msg.contains($what), "panic message {msg:?} missing {:?}", $what);
            }};
        }
        panics_with!("gemm_nn: x.len()", {
            let mut out = vec![0f32; 4];
            gemm_nn(&[0.0; 5], &[0.0; 6], &mut out, 2, 3, 2, false);
        });
        panics_with!("gemm_nn: w.len()", {
            let mut out = vec![0f32; 4];
            gemm_nn(&[0.0; 6], &[0.0; 5], &mut out, 2, 3, 2, false);
        });
        panics_with!("gemm_nn: out.len()", {
            let mut out = vec![0f32; 3];
            gemm_nn(&[0.0; 6], &[0.0; 6], &mut out, 2, 3, 2, false);
        });
        panics_with!("gemm_tn: a.len()", {
            let mut out = vec![0f32; 6];
            gemm_tn(&[0.0; 5], &[0.0; 4], &mut out, 2, 3, 2, false);
        });
        panics_with!("gemm_tn: b.len()", {
            let mut out = vec![0f32; 6];
            gemm_tn(&[0.0; 6], &[0.0; 5], &mut out, 2, 3, 2, false);
        });
        panics_with!("gemm_nt: a.len()", {
            let mut out = vec![0f32; 6];
            gemm_nt(&[0.0; 5], &[0.0; 6], &mut out, 2, 3, 2, false);
        });
        panics_with!("gemm_nt: out.len()", {
            let mut out = vec![0f32; 5];
            gemm_nt(&[0.0; 4], &[0.0; 6], &mut out, 2, 3, 2, false);
        });
    }

    #[test]
    fn zero_dims_are_noops() {
        let mut out = vec![3.0f32; 0];
        gemm_nn(&[], &[], &mut out, 0, 0, 0, false);
        // k == 0 with acc=false still zeroes the output (empty sum)
        let mut out = vec![3.0f32; 4];
        gemm_nn(&[], &[], &mut out, 2, 0, 2, false);
        assert_eq!(out, vec![0.0; 4]);
        // and acc=true leaves it untouched
        let mut out = vec![3.0f32; 4];
        gemm_nn(&[], &[], &mut out, 2, 0, 2, true);
        assert_eq!(out, vec![3.0; 4]);
    }
}
