//! Cache-blocked, multi-threaded GEMM entry points over flat row-major
//! `&[f32]` buffers — the compute layer every dense matmul in the
//! native backend routes through (`gemm_nn` forward products, `gemm_tn`
//! weight gradients, `gemm_nt` input gradients).
//!
//! Parallel strategy: output row panels. Each task owns a disjoint
//! panel of output rows; the panel BODY comes from the kernel-variant
//! vtable resolved by `dispatch` (`UNI_LORA_KERNELS=scalar|simd|auto`).
//! This file keeps the scalar tier: panels that accumulate every
//! contribution in the exact order of the retained naive reference
//! (k ascending for nn/tn, one sequential dot per element for nt), so
//! scalar results are bitwise identical across runs, across thread
//! counts, AND to the pre-kernels loop nests — only wall-clock
//! changes. Blocking keeps the streamed operand (the k-panel of `w`,
//! the i-panel of `b`) resident in cache across the rows of a panel;
//! `gemm_tn` additionally packs the strided column block of `a` into a
//! contiguous scratch tile before the accumulation sweep.
//!
//! The simd tier (`simd.rs`) renegotiates the parity story explicitly:
//! still bitwise-deterministic across runs and thread counts, but only
//! tolerance-equal to this tier (see `dispatch` for the contract and
//! the cross-variant property suite below for the bound).
//!
//! Preconditions are validated up front with clear messages (the old
//! free `matmul*` functions only had `debug_assert`s and relied on
//! indexing panics mid-write in release builds).

use super::dispatch::{self, KernelOps};
use super::pool::{self, SendPtr};
use super::PAR_MIN_WORK;

/// k-block height for `gemm_nn`: the w panel (KC x m) stays cache-hot
/// while a row panel of x sweeps over it.
const NN_KC: usize = 128;

/// p-block height for `gemm_nt`: the b panel (PB x m) is reused by
/// every row of the task's output panel.
const NT_PB: usize = 64;

/// i-block height for `gemm_tn`: rows of a/b consumed per packed tile.
const TN_IC: usize = 32;

/// out[n,m] (+)= x[n,k] @ w[k,m] — active kernel tier.
pub fn gemm_nn(x: &[f32], w: &[f32], out: &mut [f32], n: usize, k: usize, m: usize, acc: bool) {
    gemm_nn_with(dispatch::ops(), x, w, out, n, k, m, acc)
}

/// out[k,m] (+)= a[n,k]^T @ b[n,m]   (weight-gradient shape) — active
/// kernel tier.
pub fn gemm_tn(a: &[f32], b: &[f32], out: &mut [f32], n: usize, k: usize, m: usize, acc: bool) {
    gemm_tn_with(dispatch::ops(), a, b, out, n, k, m, acc)
}

/// out[n,k] (+)= a[n,m] @ b[k,m]^T   (input-gradient shape) — active
/// kernel tier.
pub fn gemm_nt(a: &[f32], b: &[f32], out: &mut [f32], n: usize, k: usize, m: usize, acc: bool) {
    gemm_nt_with(dispatch::ops(), a, b, out, n, k, m, acc)
}

/// [`gemm_nn`] through an explicit kernel vtable. Benches sweep tiers
/// with this, and the property suites pin `&dispatch::SCALAR` /
/// compare `dispatch::simd_ops()` without flipping the process-wide
/// active tier under concurrently running tests.
#[allow(clippy::too_many_arguments)]
pub fn gemm_nn_with(
    ops: &'static KernelOps,
    x: &[f32],
    w: &[f32],
    out: &mut [f32],
    n: usize,
    k: usize,
    m: usize,
    acc: bool,
) {
    assert!(x.len() == n * k, "gemm_nn: x.len() = {}, want n*k = {}*{}", x.len(), n, k);
    assert!(w.len() == k * m, "gemm_nn: w.len() = {}, want k*m = {}*{}", w.len(), k, m);
    assert!(out.len() == n * m, "gemm_nn: out.len() = {}, want n*m = {}*{}", out.len(), n, m);
    if !acc {
        out.fill(0.0);
    }
    if n == 0 || k == 0 || m == 0 {
        return;
    }
    par_row_panels(out, n, m, n * k * m, |i0, i1, panel| {
        (ops.nn_panel)(x, w, panel, i0, i1, k, m)
    });
}

/// [`gemm_tn`] through an explicit kernel vtable.
#[allow(clippy::too_many_arguments)]
pub fn gemm_tn_with(
    ops: &'static KernelOps,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    n: usize,
    k: usize,
    m: usize,
    acc: bool,
) {
    assert!(a.len() == n * k, "gemm_tn: a.len() = {}, want n*k = {}*{}", a.len(), n, k);
    assert!(b.len() == n * m, "gemm_tn: b.len() = {}, want n*m = {}*{}", b.len(), n, m);
    assert!(out.len() == k * m, "gemm_tn: out.len() = {}, want k*m = {}*{}", out.len(), k, m);
    if !acc {
        out.fill(0.0);
    }
    if n == 0 || k == 0 || m == 0 {
        return;
    }
    par_row_panels(out, k, m, n * k * m, |p0, p1, panel| {
        (ops.tn_panel)(a, b, panel, p0, p1, n, k, m)
    });
}

/// [`gemm_nt`] through an explicit kernel vtable.
#[allow(clippy::too_many_arguments)]
pub fn gemm_nt_with(
    ops: &'static KernelOps,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    n: usize,
    k: usize,
    m: usize,
    acc: bool,
) {
    assert!(a.len() == n * m, "gemm_nt: a.len() = {}, want n*m = {}*{}", a.len(), n, m);
    assert!(b.len() == k * m, "gemm_nt: b.len() = {}, want k*m = {}*{}", b.len(), k, m);
    assert!(out.len() == n * k, "gemm_nt: out.len() = {}, want n*k = {}*{}", out.len(), n, k);
    if !acc {
        out.fill(0.0);
    }
    if n == 0 || k == 0 || m == 0 {
        return;
    }
    par_row_panels(out, n, k, n * k * m, |i0, i1, panel| {
        (ops.nt_panel)(a, b, panel, i0, i1, k, m)
    });
}

// ------------------------------------------------------------------
// parallel driver

/// Split `out` ([rows, cols] row-major) into disjoint row panels and
/// run `body(row0, row1, panel)` for each across the pool. Row
/// ownership is exclusive, so any schedule produces the same bits.
fn par_row_panels<F>(out: &mut [f32], rows: usize, cols: usize, macs: usize, body: F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    let p = pool::pool();
    if p.threads() == 1 || macs < PAR_MIN_WORK || rows == 1 {
        body(0, rows, out);
        return;
    }
    let tasks = (p.threads() * 4).min(rows);
    let chunk = (rows + tasks - 1) / tasks;
    let ptr = SendPtr::new(out);
    p.parallel_for(tasks, &|t| {
        let i0 = t * chunk;
        if i0 >= rows {
            return;
        }
        let i1 = (i0 + chunk).min(rows);
        // SAFETY: tasks own disjoint half-open row ranges of `out`.
        let panel = unsafe { ptr.slice(i0 * cols, (i1 - i0) * cols) };
        body(i0, i1, panel);
    });
}

// ------------------------------------------------------------------
// scalar panel kernels (single-threaded, fixed accumulation order —
// the golden-reference tier installed as `dispatch::SCALAR`)

#[inline]
pub(crate) fn axpy(y: &mut [f32], x: &[f32], a: f32) {
    for (yi, &xi) in y.iter_mut().zip(x.iter()) {
        *yi += a * xi;
    }
}

/// Dot product in strict sequential order — the exact reduction order
/// of the legacy `matmul_nt`, so every scalar gemm kernel is
/// bitwise-identical to the pre-kernels code (training losses
/// reproduce at any thread count). The simd tier reassociates this
/// into `LANES` partial sums (`simd::dot8`) — the renegotiated parity
/// the old comment here promised, bounded by the cross-variant
/// property suite below.
#[inline]
pub(crate) fn dot(x: &[f32], y: &[f32]) -> f32 {
    let mut s = 0f32;
    for (a, b) in x.iter().zip(y.iter()) {
        s += a * b;
    }
    s
}

pub(crate) fn nn_panel(
    x: &[f32],
    w: &[f32],
    panel: &mut [f32],
    i0: usize,
    i1: usize,
    k: usize,
    m: usize,
) {
    let mut kb = 0;
    while kb < k {
        let ke = (kb + NN_KC).min(k);
        for i in i0..i1 {
            let xrow = &x[i * k + kb..i * k + ke];
            let prow = &mut panel[(i - i0) * m..(i - i0) * m + m];
            for (p, &a) in xrow.iter().enumerate() {
                if a != 0.0 {
                    axpy(prow, &w[(kb + p) * m..(kb + p) * m + m], a);
                }
            }
        }
        kb = ke;
    }
}

pub(crate) fn nt_panel(
    a: &[f32],
    b: &[f32],
    panel: &mut [f32],
    i0: usize,
    i1: usize,
    k: usize,
    m: usize,
) {
    let mut pb = 0;
    while pb < k {
        let pe = (pb + NT_PB).min(k);
        for i in i0..i1 {
            let arow = &a[i * m..i * m + m];
            let prow = &mut panel[(i - i0) * k..(i - i0) * k + k];
            for p in pb..pe {
                prow[p] += dot(arow, &b[p * m..p * m + m]);
            }
        }
        pb = pe;
    }
}

pub(crate) fn tn_panel(
    a: &[f32],
    b: &[f32],
    panel: &mut [f32],
    p0: usize,
    p1: usize,
    n: usize,
    k: usize,
    m: usize,
) {
    let pw = p1 - p0;
    let mut pack = vec![0f32; pw * TN_IC];
    let mut ib = 0;
    while ib < n {
        let ie = (ib + TN_IC).min(n);
        let iw = ie - ib;
        // pack a[ib..ie, p0..p1] transposed: pack[(p - p0)*iw + (i - ib)]
        for i in ib..ie {
            let arow = &a[i * k + p0..i * k + p1];
            for (pp, &av) in arow.iter().enumerate() {
                pack[pp * iw + (i - ib)] = av;
            }
        }
        for pp in 0..pw {
            let prow = &mut panel[pp * m..pp * m + m];
            let pcol = &pack[pp * iw..pp * iw + iw];
            for (ii, &av) in pcol.iter().enumerate() {
                if av != 0.0 {
                    axpy(prow, &b[(ib + ii) * m..(ib + ii) * m + m], av);
                }
            }
        }
        ib = ie;
    }
}

#[cfg(test)]
mod tests {
    use super::super::naive::{gemm_nn_ref, gemm_nt_ref, gemm_tn_ref};
    use super::*;
    use crate::rng;

    fn seeded(seed: u64, len: usize) -> Vec<f32> {
        let mut v = rng::normals(seed, len);
        // sprinkle exact zeros so the zero-skip paths are exercised
        for (i, x) in v.iter_mut().enumerate() {
            if i % 7 == 3 {
                *x = 0.0;
            }
        }
        v
    }

    /// Satellite: the scalar tier vs the retained naive reference over
    /// odd shapes, acc on/off, threads in {1, 4}; bitwise-deterministic
    /// across runs and across thread counts. Pinned to the scalar
    /// vtable explicitly — the bit-equality contract belongs to that
    /// tier regardless of what `UNI_LORA_KERNELS` selects for the run.
    /// The RAII guard restores the pool width even if an assert fails,
    /// so a red run can't leave `set_threads(4)` applied to every
    /// later test in the process.
    #[test]
    fn property_blocked_matches_naive_over_odd_shapes() {
        let _threads = pool::ThreadsGuard::new();
        let shapes = [1usize, 3, 17, 64, 129];
        for &n in &shapes {
            for &k in &shapes {
                for &m in &shapes {
                    for acc in [false, true] {
                        check_one(n, k, m, acc);
                    }
                }
            }
        }
        // targeted big-k shapes: cross every tier's k-block boundary
        // (scalar NN_KC = 128, simd KC = 256) with a remainder block
        for &(n, k, m) in &BIG_K_SHAPES {
            for acc in [false, true] {
                check_one(n, k, m, acc);
            }
        }
    }

    /// Shapes whose k crosses the largest k-block height (simd KC =
    /// 256; the odd-shape grid tops out at 129): 300 = one full block
    /// + remainder, 515 = two blocks + remainder. Shared by the
    /// scalar-vs-naive and the cross-variant suites so the `kb > 0`
    /// pack/addressing path of every panel body stays covered.
    const BIG_K_SHAPES: [(usize, usize, usize); 2] = [(5, 300, 17), (17, 515, 9)];

    fn check_one(n: usize, k: usize, m: usize, acc: bool) {
        let seed = (n * 1_000_003 + k * 1009 + m) as u64;
        let x_nn = seeded(seed, n * k);
        let w_nn = seeded(seed + 1, k * m);
        let a_tn = seeded(seed + 2, n * k);
        let b_tn = seeded(seed + 3, n * m);
        let a_nt = seeded(seed + 4, n * m);
        let b_nt = seeded(seed + 5, k * m);
        let init_nn = seeded(seed + 6, n * m);
        let init_tn = seeded(seed + 7, k * m);
        let init_nt = seeded(seed + 8, n * k);

        let run = |f: &dyn Fn(&mut Vec<f32>), init: &[f32]| -> Vec<f32> {
            let mut out = init.to_vec();
            f(&mut out);
            out
        };
        let sc = &dispatch::SCALAR;

        let want_nn = run(&|o: &mut Vec<f32>| gemm_nn_ref(&x_nn, &w_nn, o, n, k, m, acc), &init_nn);
        let want_tn = run(&|o: &mut Vec<f32>| gemm_tn_ref(&a_tn, &b_tn, o, n, k, m, acc), &init_tn);
        let want_nt = run(&|o: &mut Vec<f32>| gemm_nt_ref(&a_nt, &b_nt, o, n, k, m, acc), &init_nt);

        let mut per_thread_count = Vec::new();
        for threads in [1usize, 4] {
            pool::set_threads(threads);
            let nn =
                run(&|o: &mut Vec<f32>| gemm_nn_with(sc, &x_nn, &w_nn, o, n, k, m, acc), &init_nn);
            let tn =
                run(&|o: &mut Vec<f32>| gemm_tn_with(sc, &a_tn, &b_tn, o, n, k, m, acc), &init_tn);
            let nt =
                run(&|o: &mut Vec<f32>| gemm_nt_with(sc, &a_nt, &b_nt, o, n, k, m, acc), &init_nt);
            // bitwise-deterministic across runs at a fixed thread count
            let nn2 =
                run(&|o: &mut Vec<f32>| gemm_nn_with(sc, &x_nn, &w_nn, o, n, k, m, acc), &init_nn);
            assert_eq!(nn, nn2, "gemm_nn not run-deterministic ({n},{k},{m},{acc},{threads})");
            let nt2 =
                run(&|o: &mut Vec<f32>| gemm_nt_with(sc, &a_nt, &b_nt, o, n, k, m, acc), &init_nt);
            assert_eq!(nt, nt2, "gemm_nt not run-deterministic ({n},{k},{m},{acc},{threads})");
            // all three keep the reference accumulation order exactly
            assert_eq!(nn, want_nn, "gemm_nn != naive ({n},{k},{m},{acc},{threads})");
            assert_eq!(tn, want_tn, "gemm_tn != naive ({n},{k},{m},{acc},{threads})");
            assert_eq!(nt, want_nt, "gemm_nt != naive ({n},{k},{m},{acc},{threads})");
            per_thread_count.push((nn, tn, nt));
        }
        // bitwise identical across thread counts
        assert_eq!(per_thread_count[0], per_thread_count[1], "thread-count variant ({n},{k},{m})");
    }

    // --------------------------------------------------------------
    // cross-variant property suite (tentpole satellite): the simd tier
    // against the scalar tier under an ULP bound, plus run-determinism
    // and thread-count-invariance asserted for the simd tier itself.

    /// Distance in units-in-the-last-place between two finite floats
    /// (monotone bit-pattern trick; sign-aware).
    fn ulp_dist(a: f32, b: f32) -> u64 {
        fn key(x: f32) -> i64 {
            let i = x.to_bits() as i32 as i64;
            if i < 0 {
                (i32::MIN as i64) - i
            } else {
                i
            }
        }
        (key(a) - key(b)).unsigned_abs()
    }

    /// The renegotiated cross-tier bound: a few hundred ULPs for the
    /// reassociated / fused sums, with an absolute floor for near-zero
    /// results where cancellation makes relative ULPs meaningless (the
    /// floor is sized to the worst-case reassociation drift of a
    /// ~129-term f32 sum over O(1) operands, not to the result). A
    /// real kernel bug (wrong index, missed tile, dropped k-block)
    /// shows up as O(1) absolute error and fails both arms.
    fn ulp_close(a: f32, b: f32) -> bool {
        a.is_finite() && b.is_finite() && (ulp_dist(a, b) <= 512 || (a - b).abs() <= 1.5e-3)
    }

    fn assert_ulp_close(got: &[f32], want: &[f32], what: &str) {
        assert_eq!(got.len(), want.len(), "{what}: length");
        for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
            assert!(
                ulp_close(g, w),
                "{what}[{i}]: simd {g} vs scalar {w} ({} ulps apart)",
                ulp_dist(g, w)
            );
        }
    }

    /// simd vs scalar over the odd-shape grid x acc on/off x threads
    /// {1, 4} within the ULP tolerance; the simd tier is additionally
    /// bitwise run-deterministic and thread-count invariant (per-tier
    /// contract, independent of scalar).
    #[test]
    fn property_simd_matches_scalar_within_ulp_over_odd_shapes() {
        let _threads = pool::ThreadsGuard::new();
        let simd = dispatch::simd_ops();
        let shapes = [1usize, 3, 17, 64, 129];
        for &n in &shapes {
            for &k in &shapes {
                for &m in &shapes {
                    for acc in [false, true] {
                        cross_check(simd, n, k, m, acc);
                    }
                }
            }
        }
        // targeted big-k shapes (see BIG_K_SHAPES): the simd tier's
        // KC = 256 multi-block path — pack offset kb, accumulator
        // round-trip through the panel — is NOT reached by the grid
        for &(n, k, m) in &BIG_K_SHAPES {
            for acc in [false, true] {
                cross_check(simd, n, k, m, acc);
            }
        }
    }

    fn cross_check(simd: &'static KernelOps, n: usize, k: usize, m: usize, acc: bool) {
        let seed = (n * 999_983 + k * 1013 + m) as u64;
        let x_nn = seeded(seed, n * k);
        let w_nn = seeded(seed + 1, k * m);
        let a_tn = seeded(seed + 2, n * k);
        let b_tn = seeded(seed + 3, n * m);
        let a_nt = seeded(seed + 4, n * m);
        let b_nt = seeded(seed + 5, k * m);
        let init_nn = seeded(seed + 6, n * m);
        let init_tn = seeded(seed + 7, k * m);
        let init_nt = seeded(seed + 8, n * k);

        let run = |f: &dyn Fn(&mut Vec<f32>), init: &[f32]| -> Vec<f32> {
            let mut out = init.to_vec();
            f(&mut out);
            out
        };
        let sc = &dispatch::SCALAR;
        let want_nn =
            run(&|o: &mut Vec<f32>| gemm_nn_with(sc, &x_nn, &w_nn, o, n, k, m, acc), &init_nn);
        let want_tn =
            run(&|o: &mut Vec<f32>| gemm_tn_with(sc, &a_tn, &b_tn, o, n, k, m, acc), &init_tn);
        let want_nt =
            run(&|o: &mut Vec<f32>| gemm_nt_with(sc, &a_nt, &b_nt, o, n, k, m, acc), &init_nt);

        let mut per_thread_count = Vec::new();
        for threads in [1usize, 4] {
            pool::set_threads(threads);
            let nn = run(
                &|o: &mut Vec<f32>| gemm_nn_with(simd, &x_nn, &w_nn, o, n, k, m, acc),
                &init_nn,
            );
            let tn = run(
                &|o: &mut Vec<f32>| gemm_tn_with(simd, &a_tn, &b_tn, o, n, k, m, acc),
                &init_tn,
            );
            let nt = run(
                &|o: &mut Vec<f32>| gemm_nt_with(simd, &a_nt, &b_nt, o, n, k, m, acc),
                &init_nt,
            );
            // the simd tier is bitwise run-deterministic
            let nn2 = run(
                &|o: &mut Vec<f32>| gemm_nn_with(simd, &x_nn, &w_nn, o, n, k, m, acc),
                &init_nn,
            );
            assert_eq!(nn, nn2, "simd gemm_nn not run-deterministic ({n},{k},{m},{acc})");
            let nt2 = run(
                &|o: &mut Vec<f32>| gemm_nt_with(simd, &a_nt, &b_nt, o, n, k, m, acc),
                &init_nt,
            );
            assert_eq!(nt, nt2, "simd gemm_nt not run-deterministic ({n},{k},{m},{acc})");
            // ...and tolerance-equal to scalar
            assert_ulp_close(&nn, &want_nn, &format!("nn({n},{k},{m},{acc},{threads})"));
            assert_ulp_close(&tn, &want_tn, &format!("tn({n},{k},{m},{acc},{threads})"));
            assert_ulp_close(&nt, &want_nt, &format!("nt({n},{k},{m},{acc},{threads})"));
            per_thread_count.push((nn, tn, nt));
        }
        // the simd tier is bitwise identical across thread counts
        assert_eq!(
            per_thread_count[0], per_thread_count[1],
            "simd thread-count variant ({n},{k},{m},{acc})"
        );
    }

    fn panic_msg(e: Box<dyn std::any::Any + Send>) -> String {
        e.downcast_ref::<String>()
            .cloned()
            .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default()
    }

    /// Satellite: slice-length preconditions fail fast with a clear
    /// message instead of an indexing panic mid-write.
    #[test]
    fn preconditions_reject_bad_lengths_up_front() {
        macro_rules! panics_with {
            ($what:expr, $body:expr) => {{
                let err = std::panic::catch_unwind(|| $body).expect_err("no panic");
                let msg = panic_msg(err);
                assert!(msg.contains($what), "panic message {msg:?} missing {:?}", $what);
            }};
        }
        panics_with!("gemm_nn: x.len()", {
            let mut out = vec![0f32; 4];
            gemm_nn(&[0.0; 5], &[0.0; 6], &mut out, 2, 3, 2, false);
        });
        panics_with!("gemm_nn: w.len()", {
            let mut out = vec![0f32; 4];
            gemm_nn(&[0.0; 6], &[0.0; 5], &mut out, 2, 3, 2, false);
        });
        panics_with!("gemm_nn: out.len()", {
            let mut out = vec![0f32; 3];
            gemm_nn(&[0.0; 6], &[0.0; 6], &mut out, 2, 3, 2, false);
        });
        panics_with!("gemm_tn: a.len()", {
            let mut out = vec![0f32; 6];
            gemm_tn(&[0.0; 5], &[0.0; 4], &mut out, 2, 3, 2, false);
        });
        panics_with!("gemm_tn: b.len()", {
            let mut out = vec![0f32; 6];
            gemm_tn(&[0.0; 6], &[0.0; 5], &mut out, 2, 3, 2, false);
        });
        panics_with!("gemm_nt: a.len()", {
            let mut out = vec![0f32; 6];
            gemm_nt(&[0.0; 5], &[0.0; 6], &mut out, 2, 3, 2, false);
        });
        panics_with!("gemm_nt: out.len()", {
            let mut out = vec![0f32; 5];
            gemm_nt(&[0.0; 4], &[0.0; 6], &mut out, 2, 3, 2, false);
        });
    }

    #[test]
    fn zero_dims_are_noops() {
        let mut out = vec![3.0f32; 0];
        gemm_nn(&[], &[], &mut out, 0, 0, 0, false);
        // k == 0 with acc=false still zeroes the output (empty sum)
        let mut out = vec![3.0f32; 4];
        gemm_nn(&[], &[], &mut out, 2, 0, 2, false);
        assert_eq!(out, vec![0.0; 4]);
        // and acc=true leaves it untouched
        let mut out = vec![3.0f32; 4];
        gemm_nn(&[], &[], &mut out, 2, 0, 2, true);
        assert_eq!(out, vec![3.0; 4]);
    }
}
