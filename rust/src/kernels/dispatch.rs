//! Kernel-variant dispatch: `UNI_LORA_KERNELS=scalar|simd|auto`
//! (`config::RuntimeOpts::kernels`) is resolved ONCE — against the
//! runtime CPU feature probe — into a variant vtable ([`KernelOps`])
//! that the GEMM entry points, the native runtime's parallel drivers
//! and the projection hot loops all consume. Three tiers exist:
//!
//! | tier            | selected when                              |
//! |-----------------|--------------------------------------------|
//! | `scalar`        | `UNI_LORA_KERNELS=scalar`, or `auto` and the avx2+fma probe fails |
//! | `simd-portable` | `UNI_LORA_KERNELS=simd` on a host without avx2+fma |
//! | `simd-avx2`     | `UNI_LORA_KERNELS=simd` or `auto` on a host with avx2+fma |
//!
//! Determinism contract, renegotiated explicitly from the scalar-only
//! days (`gemm.rs` used to promise bit-equality with the legacy loop
//! nests and defer lane reassociation "to a future SIMD kernel
//! variant"; this module is that variant):
//!
//! - **Per variant**: bitwise-deterministic across runs AND thread
//!   counts. Lane width is fixed per tier, the feature probe is fixed
//!   per process, and per-element accumulation order never depends on
//!   the panel split or the schedule.
//! - **Scalar tier**: additionally bit-identical to the retained naive
//!   reference kernels (`naive.rs`) and therefore to the pre-kernels
//!   loop nests — the golden tier. Its property tests keep running
//!   untouched, pinned to this vtable.
//! - **Across tiers**: only tolerance-equal (reassociated reductions,
//!   fused multiply-adds, no zero-skip). The cross-variant property
//!   suite in `gemm.rs` bounds the divergence.
//!
//! The elementwise maps shared here (GELU forward/grad, LM-softmax row
//! max, fastfood FWHT butterflies) keep identical per-element
//! expressions in every tier, so they are bit-identical across tiers;
//! all cross-tier divergence comes from the GEMM panels and dots.

use super::{gemm, simd};
use crate::config::{KernelChoice, RuntimeOpts};
use std::sync::atomic::{AtomicU8, Ordering};

/// The resolved kernel tier family (the avx2/portable split within
/// `Simd` is a host property, not a contract difference).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    Scalar,
    Simd,
}

impl Variant {
    pub fn name(self) -> &'static str {
        match self {
            Variant::Scalar => "scalar",
            Variant::Simd => "simd",
        }
    }
}

/// Result of the runtime CPU feature probe.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CpuFeatures {
    pub avx2: bool,
    pub fma: bool,
}

impl CpuFeatures {
    /// Can the avx2+fma intrinsic path run here?
    pub fn simd_capable(self) -> bool {
        self.avx2 && self.fma
    }
}

/// Probe the CPU. On non-x86_64 targets both flags are false (the
/// portable lane tier still works there; only `auto` cares).
pub fn detect() -> CpuFeatures {
    #[cfg(target_arch = "x86_64")]
    {
        CpuFeatures {
            avx2: is_x86_feature_detected!("avx2"),
            fma: is_x86_feature_detected!("fma"),
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        CpuFeatures { avx2: false, fma: false }
    }
}

/// Pure resolution rule (unit-tested against fake probes): explicit
/// pins win; `auto` takes the simd tier only when the intrinsic path's
/// feature probe succeeds, and falls back to scalar otherwise.
pub fn resolve(choice: KernelChoice, feats: CpuFeatures) -> Variant {
    match choice {
        KernelChoice::Scalar => Variant::Scalar,
        KernelChoice::Simd => Variant::Simd,
        KernelChoice::Auto => {
            if feats.simd_capable() {
                Variant::Simd
            } else {
                Variant::Scalar
            }
        }
    }
}

// ------------------------------------------------------------------
// the vtable

/// One kernel tier: GEMM panel bodies consumed by the parallel panel
/// driver in `gemm.rs`, plus the shared hot maps the native runtime
/// and the projection layer route through. All entries are plain `fn`
/// pointers so a tier is one `static` and dispatch is one atomic load.
///
/// Deliberately NO per-element primitives (`axpy`/`dot`) in this
/// table: dispatch happens at panel / whole-map granularity, where an
/// indirect call amortizes over thousands of FLOPs. The tiny head-dim
/// loops in attention stay inlined in the model, and each tier's
/// panel bodies call their own lane primitives (`simd::dot8`,
/// `gemm::dot`) directly.
pub struct KernelOps {
    pub variant: Variant,
    /// Human-readable tier name: `scalar`, `simd-portable`, `simd-avx2`.
    pub path: &'static str,
    /// `out[n,m] (+)= x[n,k] @ w[k,m]` panel body: `(x, w, panel, i0, i1, k, m)`.
    pub nn_panel: fn(&[f32], &[f32], &mut [f32], usize, usize, usize, usize),
    /// `out[k,m] (+)= a[n,k]^T @ b[n,m]` panel body: `(a, b, panel, p0, p1, n, k, m)`.
    pub tn_panel: fn(&[f32], &[f32], &mut [f32], usize, usize, usize, usize, usize),
    /// `out[n,k] (+)= a[n,m] @ b[k,m]^T` panel body: `(a, b, panel, i0, i1, k, m)`.
    pub nt_panel: fn(&[f32], &[f32], &mut [f32], usize, usize, usize, usize),
    /// `dst = gelu(src)` — bit-identical across tiers.
    pub gelu_map: fn(&mut [f32], &[f32]),
    /// `g *= gelu'(src)` — bit-identical across tiers.
    pub gelu_grad_mul: fn(&mut [f32], &[f32]),
    /// Row maximum (the LM-softmax hot reduction) — bit-identical
    /// across tiers for non-NaN inputs.
    pub row_max: fn(&[f32]) -> f32,
    /// In-place orthonormal fast Walsh-Hadamard transform —
    /// bit-identical across tiers.
    pub fwht: fn(&mut [f32]),
}

/// The retained golden-reference tier.
pub static SCALAR: KernelOps = KernelOps {
    variant: Variant::Scalar,
    path: "scalar",
    nn_panel: gemm::nn_panel,
    tn_panel: gemm::tn_panel,
    nt_panel: gemm::nt_panel,
    gelu_map: gelu_map_scalar,
    gelu_grad_mul: gelu_grad_mul_scalar,
    row_max: row_max_scalar,
    fwht: fwht_scalar,
};

/// The stable-Rust lane tier (autovectorized fixed-width blocks).
pub static SIMD_PORTABLE: KernelOps = KernelOps {
    variant: Variant::Simd,
    path: "simd-portable",
    nn_panel: simd::nn_panel,
    tn_panel: simd::tn_panel,
    nt_panel: simd::nt_panel,
    gelu_map: simd::gelu_map8,
    gelu_grad_mul: simd::gelu_grad_mul8,
    row_max: simd::row_max8,
    fwht: simd::fwht8,
};

/// The avx2+fma intrinsic tier. Crate-private on purpose: its panel
/// bodies execute AVX2/FMA instructions behind safe wrappers, so the
/// only paths to it are `ops()`/`simd_ops()`/`set_choice`, all of
/// which gate on the runtime feature probe (see the safety note in
/// `simd::avx2`) — no safe public route can run the intrinsics on a
/// host without the features. The elementwise maps reuse the portable
/// lane bodies, which are already bit-identical across tiers.
#[cfg(target_arch = "x86_64")]
pub(crate) static SIMD_AVX2: KernelOps = KernelOps {
    variant: Variant::Simd,
    path: "simd-avx2",
    nn_panel: simd::avx2::nn_panel,
    tn_panel: simd::avx2::tn_panel,
    nt_panel: simd::avx2::nt_panel,
    gelu_map: simd::gelu_map8,
    gelu_grad_mul: simd::gelu_grad_mul8,
    row_max: simd::row_max8,
    fwht: simd::fwht8,
};

// ------------------------------------------------------------------
// the active tier

const IDX_SCALAR: u8 = 0;
const IDX_SIMD_PORTABLE: u8 = 1;
#[cfg(target_arch = "x86_64")]
const IDX_SIMD_AVX2: u8 = 2;
const IDX_UNSET: u8 = 0xff;

static ACTIVE: AtomicU8 = AtomicU8::new(IDX_UNSET);

/// Index of the tier `Variant::Simd` resolves to on this host.
fn simd_tier_index() -> u8 {
    #[cfg(target_arch = "x86_64")]
    {
        if detect().simd_capable() {
            return IDX_SIMD_AVX2;
        }
    }
    IDX_SIMD_PORTABLE
}

fn tier_index(choice: KernelChoice) -> u8 {
    match resolve(choice, detect()) {
        Variant::Scalar => IDX_SCALAR,
        Variant::Simd => simd_tier_index(),
    }
}

fn by_index(i: u8) -> &'static KernelOps {
    match i {
        IDX_SCALAR => &SCALAR,
        #[cfg(target_arch = "x86_64")]
        IDX_SIMD_AVX2 => &SIMD_AVX2,
        _ => &SIMD_PORTABLE,
    }
}

/// The active tier, resolved once from `UNI_LORA_KERNELS` + the CPU
/// probe on first use (racing first uses compute the same index, so
/// the relaxed init is benign).
pub fn ops() -> &'static KernelOps {
    let mut i = ACTIVE.load(Ordering::Relaxed);
    if i == IDX_UNSET {
        i = tier_index(RuntimeOpts::from_env().kernels);
        ACTIVE.store(i, Ordering::Relaxed);
    }
    by_index(i)
}

/// The tier an explicit `simd` choice resolves to on this host —
/// benches and the cross-variant property suite compare this against
/// [`SCALAR`] without touching the process-wide active tier.
pub fn simd_ops() -> &'static KernelOps {
    by_index(simd_tier_index())
}

/// Re-resolve the active tier. NUMERICS-AFFECTING for subsequent
/// kernel calls: intended for single-flow callers (benches sweeping
/// scalar vs simd, the CLI) — concurrent tests must pass an explicit
/// vtable to `gemm_*_with` instead of flipping the process-wide tier.
pub fn set_choice(choice: KernelChoice) {
    ACTIVE.store(tier_index(choice), Ordering::Relaxed);
}

/// Active tier family.
pub fn variant() -> Variant {
    ops().variant
}

/// Active tier name (`scalar` / `simd-portable` / `simd-avx2`).
pub fn path() -> &'static str {
    ops().path
}

// ------------------------------------------------------------------
// scalar elementwise bodies (shared hot loops, golden tier)

pub(crate) const GELU_C: f32 = 0.797_884_56; // sqrt(2/pi)
pub(crate) const GELU_A: f32 = 0.044_715;

/// Tanh-approximation GELU (the model's activation; moved here from
/// the native model so every tier shares one definition).
pub fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (GELU_C * (x + GELU_A * x * x * x)).tanh())
}

/// Derivative of [`gelu`].
pub fn gelu_grad(x: f32) -> f32 {
    let u = GELU_C * (x + GELU_A * x * x * x);
    let t = u.tanh();
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * GELU_C * (1.0 + 3.0 * GELU_A * x * x)
}

fn gelu_map_scalar(dst: &mut [f32], src: &[f32]) {
    for (d, &z) in dst.iter_mut().zip(src) {
        *d = gelu(z);
    }
}

fn gelu_grad_mul_scalar(g: &mut [f32], src: &[f32]) {
    for (gi, &z) in g.iter_mut().zip(src) {
        *gi *= gelu_grad(z);
    }
}

fn row_max_scalar(x: &[f32]) -> f32 {
    x.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
}

/// In-place orthonormal fast Walsh-Hadamard transform (len a power of
/// two) — the scalar butterfly chain, moved verbatim from
/// `projection::fastfood` so the lane tier can renegotiate only the
/// chunking, never the arithmetic.
pub(crate) fn fwht_scalar(v: &mut [f32]) {
    let n = v.len();
    assert!(n.is_power_of_two(), "FWHT length must be a power of two");
    let mut h = 1;
    while h < n {
        let mut i = 0;
        while i < n {
            for j in i..i + h {
                let (a, b) = (v[j], v[j + h]);
                v[j] = a + b;
                v[j + h] = a - b;
            }
            i += 2 * h;
        }
        h *= 2;
    }
    let scale = 1.0 / (n as f32).sqrt();
    for x in v.iter_mut() {
        *x *= scale;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_pins_and_probes() {
        let none = CpuFeatures { avx2: false, fma: false };
        let some = CpuFeatures { avx2: true, fma: false };
        let full = CpuFeatures { avx2: true, fma: true };
        // explicit pins ignore the probe
        for f in [none, some, full] {
            assert_eq!(resolve(KernelChoice::Scalar, f), Variant::Scalar);
            assert_eq!(resolve(KernelChoice::Simd, f), Variant::Simd);
        }
        // auto needs the FULL probe; any missing feature falls back to
        // scalar (the dispatch satellite's acceptance case)
        assert_eq!(resolve(KernelChoice::Auto, full), Variant::Simd);
        assert_eq!(resolve(KernelChoice::Auto, some), Variant::Scalar);
        assert_eq!(resolve(KernelChoice::Auto, none), Variant::Scalar);
    }

    #[test]
    fn vtables_are_coherent() {
        assert_eq!(SCALAR.variant, Variant::Scalar);
        assert_eq!(SCALAR.path, "scalar");
        assert_eq!(SIMD_PORTABLE.variant, Variant::Simd);
        // the host's simd tier is some simd vtable
        let s = simd_ops();
        assert_eq!(s.variant, Variant::Simd);
        assert!(s.path.starts_with("simd-"), "{}", s.path);
        // the active tier is consistent with the env choice
        let active = ops();
        match RuntimeOpts::from_env().kernels {
            KernelChoice::Scalar => assert_eq!(active.variant, Variant::Scalar),
            KernelChoice::Simd => assert_eq!(active.variant, Variant::Simd),
            KernelChoice::Auto => {
                let want =
                    if detect().simd_capable() { Variant::Simd } else { Variant::Scalar };
                assert_eq!(active.variant, want);
            }
        }
        assert_eq!(variant(), active.variant);
        assert_eq!(path(), active.path);
    }

    #[test]
    fn detect_is_stable_within_process() {
        assert_eq!(detect(), detect());
    }

    #[test]
    fn gelu_grad_matches_finite_difference() {
        for &x in &[-2.0f32, -0.5, 0.0, 0.3, 1.7] {
            let eps = 1e-3;
            let num = (gelu(x + eps) - gelu(x - eps)) / (2.0 * eps);
            assert!((num - gelu_grad(x)).abs() < 1e-3, "x={x}");
        }
    }

    #[test]
    fn fwht_scalar_matches_dense_hadamard_small() {
        let mut v = vec![1.0, 2.0, 3.0, 4.0];
        fwht_scalar(&mut v);
        let want = [10.0, -2.0, -4.0, 0.0].map(|x: f32| x / 2.0);
        for (a, b) in v.iter().zip(&want) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
