//! Naive reference GEMMs: the original single-threaded loop nests that
//! used to live inline in `runtime/native/model.rs`. Retained as the
//! ground truth the blocked/threaded kernels in `gemm.rs` are
//! property-tested against — never called on a hot path.

/// out[n,m] (+)= x[n,k] @ w[k,m]
pub fn gemm_nn_ref(x: &[f32], w: &[f32], out: &mut [f32], n: usize, k: usize, m: usize, acc: bool) {
    assert_eq!(x.len(), n * k);
    assert_eq!(w.len(), k * m);
    assert_eq!(out.len(), n * m);
    if !acc {
        out.fill(0.0);
    }
    for i in 0..n {
        let xrow = &x[i * k..(i + 1) * k];
        let orow = &mut out[i * m..(i + 1) * m];
        for (p, &a) in xrow.iter().enumerate() {
            if a != 0.0 {
                let wrow = &w[p * m..(p + 1) * m];
                for j in 0..m {
                    orow[j] += a * wrow[j];
                }
            }
        }
    }
}

/// out[k,m] (+)= a[n,k]^T @ b[n,m]   (weight-gradient shape)
pub fn gemm_tn_ref(a: &[f32], b: &[f32], out: &mut [f32], n: usize, k: usize, m: usize, acc: bool) {
    assert_eq!(a.len(), n * k);
    assert_eq!(b.len(), n * m);
    assert_eq!(out.len(), k * m);
    if !acc {
        out.fill(0.0);
    }
    for i in 0..n {
        let arow = &a[i * k..(i + 1) * k];
        let brow = &b[i * m..(i + 1) * m];
        for (p, &av) in arow.iter().enumerate() {
            if av != 0.0 {
                let orow = &mut out[p * m..(p + 1) * m];
                for j in 0..m {
                    orow[j] += av * brow[j];
                }
            }
        }
    }
}

/// out[n,k] (+)= a[n,m] @ b[k,m]^T   (input-gradient shape)
pub fn gemm_nt_ref(a: &[f32], b: &[f32], out: &mut [f32], n: usize, k: usize, m: usize, acc: bool) {
    assert_eq!(a.len(), n * m);
    assert_eq!(b.len(), k * m);
    assert_eq!(out.len(), n * k);
    if !acc {
        out.fill(0.0);
    }
    for i in 0..n {
        let arow = &a[i * m..(i + 1) * m];
        let orow = &mut out[i * k..(i + 1) * k];
        for p in 0..k {
            let brow = &b[p * m..(p + 1) * m];
            let mut s = 0f32;
            for j in 0..m {
                s += arow[j] * brow[j];
            }
            orow[p] += s;
        }
    }
}
