//! The SIMD kernel tier: register-tiled, lane-reassociated panel
//! bodies behind the same `gemm_nn/tn/nt` panel API as the scalar
//! tier, plus lane-width variants of the shared hot loops (`axpy8` /
//! `dot8`, the GELU maps, the LM-softmax row max, the fastfood FWHT
//! butterflies).
//!
//! Two sub-paths, selected once by `dispatch::resolve`:
//! - **portable**: fixed-width `LANES`-chunk accumulator blocks on
//!   stable Rust — no intrinsics, no `unsafe`; the chunked loop bodies
//!   are shaped so LLVM's autovectorizer turns them into vector code
//!   on any target.
//! - **avx2**: the same tiling with explicit AVX2+FMA intrinsics
//!   (`_mm256_fmadd_ps` microkernels), gated at dispatch time on
//!   `is_x86_feature_detected!`.
//!
//! Determinism contract (renegotiated from the scalar tier, see
//! `dispatch`): every function here is bitwise-deterministic across
//! runs AND thread counts — per-element accumulation order is a pure
//! function of the problem shape (k ascending for nn/tn, a fixed lane
//! partial + reduction tree for dots), never of panel boundaries or
//! the schedule — but results are only tolerance-equal to the scalar
//! tier: dense panels drop the per-element `a != 0.0` zero-skip branch
//! in favour of packed operand tiles, dot products reassociate into
//! `LANES` partial sums, and the avx2 path fuses multiply-adds.
//! The elementwise maps (GELU, row max, FWHT) keep the scalar
//! per-element expressions exactly and are bit-identical across tiers.

use super::dispatch::{gelu, gelu_grad};

/// Fixed lane width of the portable tier (f32 lanes of one AVX2
/// register). Part of the determinism contract: baked in, never probed.
pub const LANES: usize = 8;

/// Output rows per register tile in the nn/tn microkernels.
const MR: usize = 4;

/// k-block height: one packed `MR x KC` operand tile is swept over the
/// output tile per block; accumulators round-trip through the panel
/// between blocks, which preserves the exact k-ascending per-element
/// order (store + reload does not change the value).
const KC: usize = 256;

/// i-block height for the tn panel's packed transposed tile.
const TN_IC: usize = 32;

/// p-block height for the nt panel (mirrors the scalar tier).
const NT_PB: usize = 64;

// ------------------------------------------------------------------
// lane-width shared hot loops (portable)

/// `y += a * x`, chunked by `LANES` so the body autovectorizes.
/// Element-wise (no reassociation), so it is bit-identical to the
/// scalar `axpy` and safe to call from ANY tier — the native model's
/// residual/gradient accumulates (`add_into`, `a = 1.0`) use it
/// directly rather than through the vtable.
pub fn axpy8(y: &mut [f32], x: &[f32], a: f32) {
    let n = y.len().min(x.len());
    let chunks = n / LANES;
    for c in 0..chunks {
        let ys = &mut y[c * LANES..(c + 1) * LANES];
        let xs = &x[c * LANES..(c + 1) * LANES];
        for l in 0..LANES {
            ys[l] += a * xs[l];
        }
    }
    for i in chunks * LANES..n {
        y[i] += a * x[i];
    }
}

/// Dot product with `LANES` partial sums and a fixed reduction tree —
/// the lane-reassociated variant of the scalar strictly-sequential
/// `dot`. The partial-sum assignment and the tree depend only on the
/// length, so the result is bitwise-deterministic.
pub fn dot8(x: &[f32], y: &[f32]) -> f32 {
    let n = x.len().min(y.len());
    let chunks = n / LANES;
    let mut acc = [0f32; LANES];
    for c in 0..chunks {
        let xs = &x[c * LANES..(c + 1) * LANES];
        let ys = &y[c * LANES..(c + 1) * LANES];
        for l in 0..LANES {
            acc[l] += xs[l] * ys[l];
        }
    }
    for l in 0..n - chunks * LANES {
        acc[l] += x[chunks * LANES + l] * y[chunks * LANES + l];
    }
    let s01 = acc[0] + acc[1];
    let s23 = acc[2] + acc[3];
    let s45 = acc[4] + acc[5];
    let s67 = acc[6] + acc[7];
    (s01 + s23) + (s45 + s67)
}

/// `dst = gelu(src)`, staged per chunk (polynomial / tanh / combine)
/// so the non-transcendental stages autovectorize. Per-element
/// expressions match the scalar `gelu` token for token, so the output
/// is bit-identical to the scalar tier.
pub(crate) fn gelu_map8(dst: &mut [f32], src: &[f32]) {
    use super::dispatch::{GELU_A, GELU_C};
    let n = dst.len().min(src.len());
    let chunks = n / LANES;
    let mut u = [0f32; LANES];
    for c in 0..chunks {
        let xs = &src[c * LANES..(c + 1) * LANES];
        for l in 0..LANES {
            u[l] = GELU_C * (xs[l] + GELU_A * xs[l] * xs[l] * xs[l]);
        }
        for ul in u.iter_mut() {
            *ul = ul.tanh();
        }
        let ds = &mut dst[c * LANES..(c + 1) * LANES];
        for l in 0..LANES {
            ds[l] = 0.5 * xs[l] * (1.0 + u[l]);
        }
    }
    for i in chunks * LANES..n {
        dst[i] = gelu(src[i]);
    }
}

/// `g *= gelu'(u)`, staged like [`gelu_map8`]; bit-identical to the
/// scalar `gelu_grad` per element.
pub(crate) fn gelu_grad_mul8(g: &mut [f32], src: &[f32]) {
    use super::dispatch::{GELU_A, GELU_C};
    let n = g.len().min(src.len());
    let chunks = n / LANES;
    let mut u = [0f32; LANES];
    for c in 0..chunks {
        let xs = &src[c * LANES..(c + 1) * LANES];
        for l in 0..LANES {
            u[l] = GELU_C * (xs[l] + GELU_A * xs[l] * xs[l] * xs[l]);
        }
        for ul in u.iter_mut() {
            *ul = ul.tanh();
        }
        let gs = &mut g[c * LANES..(c + 1) * LANES];
        for l in 0..LANES {
            let t = u[l];
            let x = xs[l];
            gs[l] *= 0.5 * (1.0 + t)
                + 0.5 * x * (1.0 - t * t) * GELU_C * (1.0 + 3.0 * GELU_A * x * x);
        }
    }
    for i in chunks * LANES..n {
        g[i] *= gelu_grad(src[i]);
    }
}

/// Row max with `LANES` running maxima and a fixed tree. `max` is
/// associative and commutative for non-NaN floats, so this is
/// bit-identical to the scalar sequential fold on real inputs.
pub(crate) fn row_max8(x: &[f32]) -> f32 {
    let n = x.len();
    let chunks = n / LANES;
    let mut acc = [f32::NEG_INFINITY; LANES];
    for c in 0..chunks {
        let xs = &x[c * LANES..(c + 1) * LANES];
        for l in 0..LANES {
            acc[l] = acc[l].max(xs[l]);
        }
    }
    for l in 0..n - chunks * LANES {
        acc[l] = acc[l].max(x[chunks * LANES + l]);
    }
    let m01 = acc[0].max(acc[1]);
    let m23 = acc[2].max(acc[3]);
    let m45 = acc[4].max(acc[5]);
    let m67 = acc[6].max(acc[7]);
    m01.max(m23).max(m45.max(m67))
}

/// Orthonormal FWHT with `LANES`-chunked butterflies for stage widths
/// `h >= LANES` (the `(a + b, a - b)` pair update is element-wise, so
/// chunking only helps the vectorizer — bits match the scalar tier).
pub(crate) fn fwht8(v: &mut [f32]) {
    let n = v.len();
    assert!(n.is_power_of_two(), "FWHT length must be a power of two");
    let mut h = 1;
    while h < n {
        let mut i = 0;
        while i < n {
            let (top, bot) = v[i..i + 2 * h].split_at_mut(h);
            if h >= LANES {
                for c in 0..h / LANES {
                    let ts = &mut top[c * LANES..(c + 1) * LANES];
                    let bs = &mut bot[c * LANES..(c + 1) * LANES];
                    for l in 0..LANES {
                        let (a, b) = (ts[l], bs[l]);
                        ts[l] = a + b;
                        bs[l] = a - b;
                    }
                }
            } else {
                for l in 0..h {
                    let (a, b) = (top[l], bot[l]);
                    top[l] = a + b;
                    bot[l] = a - b;
                }
            }
            i += 2 * h;
        }
        h *= 2;
    }
    let scale = 1.0 / (n as f32).sqrt();
    for x in v.iter_mut() {
        *x *= scale;
    }
}

// ------------------------------------------------------------------
// portable GEMM panels

/// Pack `mr` rows of `x` (columns `kb..kb + kc`) interleaved:
/// `apack[kk * mr + rr]` — the packed operand tile that replaces the
/// scalar tier's per-element zero-skip branch with stride-1 loads.
fn pack_a(x: &[f32], apack: &mut [f32], row0: usize, mr: usize, k: usize, kb: usize, kc: usize) {
    for rr in 0..mr {
        let xrow = &x[(row0 + rr) * k + kb..(row0 + rr) * k + kb + kc];
        for (kk, &v) in xrow.iter().enumerate() {
            apack[kk * mr + rr] = v;
        }
    }
}

// The outer blocking loops are shared between the portable and avx2
// sub-paths (ONE copy of the k-block / i-block / MR-tile logic and of
// the accumulation-order contract); only the register microkernel a
// tier plugs in differs. The indirect `micro` call is per TILE — it
// amortizes over `kc * m` FLOPs.

/// Shared nn outer blocking: k-blocks x `MR`-row packed operand tiles;
/// `micro(mr, apack, sub, kb, kc)` runs one register tile.
fn nn_drive(
    x: &[f32],
    panel: &mut [f32],
    i0: usize,
    i1: usize,
    k: usize,
    m: usize,
    micro: &dyn Fn(usize, &[f32], &mut [f32], usize, usize),
) {
    let rows = i1 - i0;
    let mut apack = vec![0f32; MR * KC];
    let mut kb = 0usize;
    while kb < k {
        let ke = (kb + KC).min(k);
        let kc = ke - kb;
        let mut r = 0usize;
        while r < rows {
            let mr = (rows - r).min(MR);
            pack_a(x, &mut apack, i0 + r, mr, k, kb, kc);
            micro(mr, &apack, &mut panel[r * m..], kb, kc);
            r += mr;
        }
        kb = ke;
    }
}

/// Shared tn outer blocking: global `TN_IC` i-blocks with a row-major
/// packed transposed tile, `MR`-row output tiles;
/// `micro(mp, pack, sub, pt, pw, ib, iw)` runs one register tile.
#[allow(clippy::too_many_arguments)]
fn tn_drive(
    a: &[f32],
    panel: &mut [f32],
    p0: usize,
    p1: usize,
    n: usize,
    k: usize,
    m: usize,
    micro: &dyn Fn(usize, &[f32], &mut [f32], usize, usize, usize, usize),
) {
    let pw = p1 - p0;
    let mut pack = vec![0f32; TN_IC * pw];
    let mut ib = 0usize;
    while ib < n {
        let ie = (ib + TN_IC).min(n);
        let iw = ie - ib;
        for ii in 0..iw {
            pack[ii * pw..ii * pw + pw].copy_from_slice(&a[(ib + ii) * k + p0..(ib + ii) * k + p1]);
        }
        let mut pt = 0usize;
        while pt < pw {
            let mp = (pw - pt).min(MR);
            micro(mp, &pack, &mut panel[pt * m..], pt, pw, ib, iw);
            pt += mp;
        }
        ib = ie;
    }
}

/// Shared nt outer blocking: the scalar tier's p-blocked sweep with a
/// pluggable whole-row dot (the indirect call amortizes over `m`
/// FLOPs).
#[allow(clippy::too_many_arguments)]
fn nt_drive(
    a: &[f32],
    b: &[f32],
    panel: &mut [f32],
    i0: usize,
    i1: usize,
    k: usize,
    m: usize,
    dot: &dyn Fn(&[f32], &[f32]) -> f32,
) {
    let mut pb = 0usize;
    while pb < k {
        let pe = (pb + NT_PB).min(k);
        for i in i0..i1 {
            let arow = &a[i * m..i * m + m];
            let prow = &mut panel[(i - i0) * k..(i - i0) * k + k];
            for p in pb..pe {
                prow[p] += dot(arow, &b[p * m..p * m + m]);
            }
        }
        pb = pe;
    }
}

/// `MR_` output rows of `panel` (row stride `m`) x all `m` columns,
/// accumulating the k-block `[kb, kb + kc)` from the packed tile.
/// Register-tiled: one `[f32; LANES]` accumulator per row per column
/// chunk; the per-element sum stays k-ascending (same order as the
/// scalar tier), so tile membership — which depends on the panel split
/// — never changes the bits.
fn nn_micro<const MR_: usize>(
    apack: &[f32],
    w: &[f32],
    panel: &mut [f32],
    kb: usize,
    kc: usize,
    m: usize,
) {
    let mut j = 0usize;
    while j + LANES <= m {
        let mut acc = [[0f32; LANES]; MR_];
        for rr in 0..MR_ {
            acc[rr].copy_from_slice(&panel[rr * m + j..rr * m + j + LANES]);
        }
        for kk in 0..kc {
            let wrow = &w[(kb + kk) * m + j..(kb + kk) * m + j + LANES];
            for rr in 0..MR_ {
                let a = apack[kk * MR_ + rr];
                for l in 0..LANES {
                    acc[rr][l] += a * wrow[l];
                }
            }
        }
        for rr in 0..MR_ {
            panel[rr * m + j..rr * m + j + LANES].copy_from_slice(&acc[rr]);
        }
        j += LANES;
    }
    while j < m {
        let mut acc = [0f32; MR_];
        for rr in 0..MR_ {
            acc[rr] = panel[rr * m + j];
        }
        for kk in 0..kc {
            let wv = w[(kb + kk) * m + j];
            for rr in 0..MR_ {
                acc[rr] += apack[kk * MR_ + rr] * wv;
            }
        }
        for rr in 0..MR_ {
            panel[rr * m + j] = acc[rr];
        }
        j += 1;
    }
}

/// Portable simd `out[n,m] (+)= x[n,k] @ w[k,m]` panel body (rows
/// `i0..i1`, panel row 0 = global row `i0`).
pub(crate) fn nn_panel(
    x: &[f32],
    w: &[f32],
    panel: &mut [f32],
    i0: usize,
    i1: usize,
    k: usize,
    m: usize,
) {
    nn_drive(x, panel, i0, i1, k, m, &|mr, apack, sub, kb, kc| match mr {
        4 => nn_micro::<4>(apack, w, sub, kb, kc, m),
        3 => nn_micro::<3>(apack, w, sub, kb, kc, m),
        2 => nn_micro::<2>(apack, w, sub, kb, kc, m),
        _ => nn_micro::<1>(apack, w, sub, kb, kc, m),
    });
}

/// `MP_` rows of the tn output tile x all `m` columns, accumulating
/// rows `0..iw` of the packed `a` tile (`pack[ii * pw + pp]`) against
/// `b` rows `ib..ib + iw`. Accumulation is i-ascending per element —
/// the scalar tier's order.
fn tn_micro<const MP_: usize>(
    pack: &[f32],
    b: &[f32],
    panel: &mut [f32],
    pt: usize,
    pw: usize,
    ib: usize,
    iw: usize,
    m: usize,
) {
    let mut j = 0usize;
    while j + LANES <= m {
        let mut acc = [[0f32; LANES]; MP_];
        for rr in 0..MP_ {
            acc[rr].copy_from_slice(&panel[rr * m + j..rr * m + j + LANES]);
        }
        for ii in 0..iw {
            let brow = &b[(ib + ii) * m + j..(ib + ii) * m + j + LANES];
            for rr in 0..MP_ {
                let av = pack[ii * pw + pt + rr];
                for l in 0..LANES {
                    acc[rr][l] += av * brow[l];
                }
            }
        }
        for rr in 0..MP_ {
            panel[rr * m + j..rr * m + j + LANES].copy_from_slice(&acc[rr]);
        }
        j += LANES;
    }
    while j < m {
        let mut acc = [0f32; MP_];
        for rr in 0..MP_ {
            acc[rr] = panel[rr * m + j];
        }
        for ii in 0..iw {
            let bv = b[(ib + ii) * m + j];
            for rr in 0..MP_ {
                acc[rr] += pack[ii * pw + pt + rr] * bv;
            }
        }
        for rr in 0..MP_ {
            panel[rr * m + j] = acc[rr];
        }
        j += 1;
    }
}

/// Portable simd `out[k,m] (+)= a[n,k]^T @ b[n,m]` panel body (output
/// rows `p0..p1`). The strided column block of `a` is packed row-major
/// per i-block; i-blocks start at multiples of `TN_IC` regardless of
/// the panel split, so the per-element i-ascending order is schedule-
/// independent.
pub(crate) fn tn_panel(
    a: &[f32],
    b: &[f32],
    panel: &mut [f32],
    p0: usize,
    p1: usize,
    n: usize,
    k: usize,
    m: usize,
) {
    tn_drive(a, panel, p0, p1, n, k, m, &|mp, pack, sub, pt, pw, ib, iw| match mp {
        4 => tn_micro::<4>(pack, b, sub, pt, pw, ib, iw, m),
        3 => tn_micro::<3>(pack, b, sub, pt, pw, ib, iw, m),
        2 => tn_micro::<2>(pack, b, sub, pt, pw, ib, iw, m),
        _ => tn_micro::<1>(pack, b, sub, pt, pw, ib, iw, m),
    });
}

/// Portable simd `out[n,k] (+)= a[n,m] @ b[k,m]^T` panel body: the
/// scalar tier's p-blocked sweep with the lane-reassociated [`dot8`].
pub(crate) fn nt_panel(
    a: &[f32],
    b: &[f32],
    panel: &mut [f32],
    i0: usize,
    i1: usize,
    k: usize,
    m: usize,
) {
    nt_drive(a, b, panel, i0, i1, k, m, &dot8);
}

// ------------------------------------------------------------------
// AVX2+FMA intrinsic path (x86_64 only; installed by dispatch only
// after the runtime feature probe succeeds)

#[cfg(target_arch = "x86_64")]
pub(crate) mod avx2 {
    use super::{nn_drive, nt_drive, tn_drive, LANES};
    use std::arch::x86_64::*;

    // SAFETY (whole module): every `unsafe fn` below requires AVX2 and
    // FMA. The safe wrappers are only ever installed in the dispatch
    // vtable after `is_x86_feature_detected!("avx2") && ("fma")`
    // succeeded (`dispatch::simd_tier_index`; the vtable static is
    // crate-private so no safe public path can bypass the probe), and
    // the debug assertions re-check that invariant.

    fn check_features() {
        debug_assert!(
            is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma"),
            "avx2 kernel tier selected without avx2+fma support"
        );
    }

    pub(crate) fn nn_panel(
        x: &[f32],
        w: &[f32],
        panel: &mut [f32],
        i0: usize,
        i1: usize,
        k: usize,
        m: usize,
    ) {
        check_features();
        // SAFETY: avx2+fma guaranteed by the dispatch install
        // invariant (debug-checked above); same for the tn/nt panels.
        nn_drive(x, panel, i0, i1, k, m, &|mr, apack, sub, kb, kc| match mr {
            4 => unsafe { nn_micro::<4>(apack, w, sub, kb, kc, m) },
            3 => unsafe { nn_micro::<3>(apack, w, sub, kb, kc, m) },
            2 => unsafe { nn_micro::<2>(apack, w, sub, kb, kc, m) },
            _ => unsafe { nn_micro::<1>(apack, w, sub, kb, kc, m) },
        });
    }

    pub(crate) fn tn_panel(
        a: &[f32],
        b: &[f32],
        panel: &mut [f32],
        p0: usize,
        p1: usize,
        n: usize,
        k: usize,
        m: usize,
    ) {
        check_features();
        tn_drive(a, panel, p0, p1, n, k, m, &|mp, pack, sub, pt, pw, ib, iw| match mp {
            4 => unsafe { tn_micro::<4>(pack, b, sub, pt, pw, ib, iw, m) },
            3 => unsafe { tn_micro::<3>(pack, b, sub, pt, pw, ib, iw, m) },
            2 => unsafe { tn_micro::<2>(pack, b, sub, pt, pw, ib, iw, m) },
            _ => unsafe { tn_micro::<1>(pack, b, sub, pt, pw, ib, iw, m) },
        });
    }

    pub(crate) fn nt_panel(
        a: &[f32],
        b: &[f32],
        panel: &mut [f32],
        i0: usize,
        i1: usize,
        k: usize,
        m: usize,
    ) {
        check_features();
        nt_drive(a, b, panel, i0, i1, k, m, &|x, y| unsafe { dot_impl(x, y) });
    }

    /// Test-only safe wrappers: the vtable dispatches at panel
    /// granularity, so these exist purely for the avx2-vs-portable
    /// helper comparison in the test module.
    #[cfg(test)]
    pub(crate) fn axpy(y: &mut [f32], x: &[f32], a: f32) {
        check_features();
        unsafe { axpy_impl(y, x, a) }
    }

    #[cfg(test)]
    pub(crate) fn dot(x: &[f32], y: &[f32]) -> f32 {
        check_features();
        unsafe { dot_impl(x, y) }
    }

    /// Fixed-order horizontal sum of one 8-lane register (lo half +
    /// hi half, then a 4-to-1 shuffle tree).
    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    unsafe fn hsum256(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps::<1>(v);
        let s = _mm_add_ps(lo, hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps::<0x1>(s, s));
        _mm_cvtss_f32(s)
    }

    #[cfg(test)]
    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    unsafe fn axpy_impl(y: &mut [f32], x: &[f32], a: f32) {
        let n = y.len().min(x.len());
        let chunks = n / LANES;
        let av = _mm256_set1_ps(a);
        for c in 0..chunks {
            let yv = _mm256_loadu_ps(y.as_ptr().add(c * LANES));
            let xv = _mm256_loadu_ps(x.as_ptr().add(c * LANES));
            _mm256_storeu_ps(y.as_mut_ptr().add(c * LANES), _mm256_fmadd_ps(av, xv, yv));
        }
        for i in chunks * LANES..n {
            y[i] = a.mul_add(x[i], y[i]);
        }
    }

    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    unsafe fn dot_impl(x: &[f32], y: &[f32]) -> f32 {
        let n = x.len().min(y.len());
        let chunks = n / LANES;
        let mut acc = _mm256_setzero_ps();
        for c in 0..chunks {
            let xv = _mm256_loadu_ps(x.as_ptr().add(c * LANES));
            let yv = _mm256_loadu_ps(y.as_ptr().add(c * LANES));
            acc = _mm256_fmadd_ps(xv, yv, acc);
        }
        let mut s = hsum256(acc);
        for i in chunks * LANES..n {
            s = x[i].mul_add(y[i], s);
        }
        s
    }

    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    unsafe fn nn_micro<const MR_: usize>(
        apack: &[f32],
        w: &[f32],
        panel: &mut [f32],
        kb: usize,
        kc: usize,
        m: usize,
    ) {
        let mut j = 0usize;
        while j + LANES <= m {
            let mut acc = [_mm256_setzero_ps(); MR_];
            for rr in 0..MR_ {
                acc[rr] = _mm256_loadu_ps(panel.as_ptr().add(rr * m + j));
            }
            for kk in 0..kc {
                let wv = _mm256_loadu_ps(w.as_ptr().add((kb + kk) * m + j));
                for rr in 0..MR_ {
                    let av = _mm256_set1_ps(apack[kk * MR_ + rr]);
                    acc[rr] = _mm256_fmadd_ps(av, wv, acc[rr]);
                }
            }
            for rr in 0..MR_ {
                _mm256_storeu_ps(panel.as_mut_ptr().add(rr * m + j), acc[rr]);
            }
            j += LANES;
        }
        while j < m {
            for rr in 0..MR_ {
                let mut s = panel[rr * m + j];
                for kk in 0..kc {
                    s = apack[kk * MR_ + rr].mul_add(w[(kb + kk) * m + j], s);
                }
                panel[rr * m + j] = s;
            }
            j += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    unsafe fn tn_micro<const MP_: usize>(
        pack: &[f32],
        b: &[f32],
        panel: &mut [f32],
        pt: usize,
        pw: usize,
        ib: usize,
        iw: usize,
        m: usize,
    ) {
        let mut j = 0usize;
        while j + LANES <= m {
            let mut acc = [_mm256_setzero_ps(); MP_];
            for rr in 0..MP_ {
                acc[rr] = _mm256_loadu_ps(panel.as_ptr().add(rr * m + j));
            }
            for ii in 0..iw {
                let bv = _mm256_loadu_ps(b.as_ptr().add((ib + ii) * m + j));
                for rr in 0..MP_ {
                    let av = _mm256_set1_ps(pack[ii * pw + pt + rr]);
                    acc[rr] = _mm256_fmadd_ps(av, bv, acc[rr]);
                }
            }
            for rr in 0..MP_ {
                _mm256_storeu_ps(panel.as_mut_ptr().add(rr * m + j), acc[rr]);
            }
            j += LANES;
        }
        while j < m {
            for rr in 0..MP_ {
                let mut s = panel[rr * m + j];
                for ii in 0..iw {
                    s = pack[ii * pw + pt + rr].mul_add(b[(ib + ii) * m + j], s);
                }
                panel[rr * m + j] = s;
            }
            j += 1;
        }
    }

}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng;

    #[test]
    fn axpy8_matches_scalar_axpy_bitwise() {
        for n in [0usize, 1, 7, 8, 9, 64, 129] {
            let x = rng::normals(1, n);
            let y0 = rng::normals(2, n);
            let mut y_lane = y0.clone();
            axpy8(&mut y_lane, &x, 0.37);
            let mut y_scalar = y0.clone();
            for (yi, &xi) in y_scalar.iter_mut().zip(&x) {
                *yi += 0.37 * xi;
            }
            assert_eq!(y_lane, y_scalar, "n = {n}");
        }
    }

    #[test]
    fn dot8_close_to_sequential_dot_and_deterministic() {
        for n in [0usize, 1, 7, 8, 9, 64, 129, 1000] {
            let x = rng::normals(3, n);
            let y = rng::normals(4, n);
            let lane = dot8(&x, &y);
            assert_eq!(lane, dot8(&x, &y), "dot8 not run-deterministic (n = {n})");
            let seq: f64 = x.iter().zip(&y).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
            // scale the bound by the term mass, not the (possibly
            // cancelled) sum — f32 accumulation error grows with
            // sum of magnitudes, while an indexing bug shows up at the
            // magnitude scale itself
            let mass: f64 =
                x.iter().zip(&y).map(|(a, b)| ((*a as f64) * (*b as f64)).abs()).sum();
            assert!(
                (lane as f64 - seq).abs() <= 1e-5 * mass.max(1.0),
                "n = {n}: lane {lane} vs f64 {seq}"
            );
        }
    }

    #[test]
    fn gelu_maps_are_bit_identical_to_scalar() {
        let x = rng::normals(5, 1003);
        let mut lane = vec![0f32; x.len()];
        gelu_map8(&mut lane, &x);
        let scalar: Vec<f32> = x.iter().map(|&v| gelu(v)).collect();
        assert_eq!(lane, scalar);

        let g0 = rng::normals(6, x.len());
        let mut g_lane = g0.clone();
        gelu_grad_mul8(&mut g_lane, &x);
        let g_scalar: Vec<f32> = g0.iter().zip(&x).map(|(g, &v)| g * gelu_grad(v)).collect();
        assert_eq!(g_lane, g_scalar);
    }

    #[test]
    fn row_max8_matches_sequential_fold() {
        for n in [1usize, 7, 8, 9, 100, 513] {
            let x = rng::normals(7, n);
            let want = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            assert_eq!(row_max8(&x), want, "n = {n}");
        }
        assert_eq!(row_max8(&[]), f32::NEG_INFINITY);
    }

    #[test]
    fn fwht8_is_bit_identical_to_scalar_fwht() {
        for logn in [0usize, 1, 2, 3, 4, 7] {
            let n = 1usize << logn;
            let x = rng::normals(8, n);
            let mut lane = x.clone();
            fwht8(&mut lane);
            let mut scalar = x.clone();
            crate::kernels::dispatch::fwht_scalar(&mut scalar);
            assert_eq!(lane, scalar, "n = {n}");
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_helpers_match_portable_within_tolerance() {
        if !(is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")) {
            return; // nothing to check on this host
        }
        for n in [1usize, 8, 9, 129, 1000] {
            let x = rng::normals(9, n);
            let y = rng::normals(10, n);
            let d_avx = avx2::dot(&x, &y);
            assert_eq!(d_avx, avx2::dot(&x, &y), "avx2 dot not run-deterministic");
            let seq: f64 = x.iter().zip(&y).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
            let mass: f64 =
                x.iter().zip(&y).map(|(a, b)| ((*a as f64) * (*b as f64)).abs()).sum();
            assert!(
                (d_avx as f64 - seq).abs() <= 1e-5 * mass.max(1.0),
                "n = {n}: avx2 {d_avx} vs f64 {seq}"
            );
            let mut y_avx = y.clone();
            avx2::axpy(&mut y_avx, &x, 0.37);
            let mut y_lane = y.clone();
            axpy8(&mut y_lane, &x, 0.37);
            for (a, b) in y_avx.iter().zip(&y_lane) {
                assert!((a - b).abs() <= 1e-6 * a.abs().max(1.0), "{a} vs {b}");
            }
        }
    }
}
