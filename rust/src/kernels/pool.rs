//! A small shared thread pool for data-parallel kernels. std-only (no
//! rayon/crossbeam in the offline vendor set): long-lived workers park
//! on a condvar and drain jobs from a shared queue, and the submitting
//! thread always participates in its own job, so progress never depends
//! on pool capacity (nested or concurrent `parallel_for` calls cannot
//! deadlock — worst case they degrade to sequential execution on the
//! caller).
//!
//! Determinism contract: a job is a set of independent index-addressed
//! tasks. Which thread runs a task never changes what the task computes
//! or where it writes, so results are bitwise identical across runs AND
//! across thread counts; the pool only changes wall-clock time. The
//! kernels built on top (gemm, attention drivers) preserve this by
//! giving each task exclusive ownership of an output region and a fixed
//! intra-task reduction order.
//!
//! Worker count comes from `config::RuntimeOpts` (`UNI_LORA_THREADS`,
//! default = available parallelism); `set_threads` swaps the global
//! pool at runtime (benches sweep threads=1 vs threads=N).

use crate::config::RuntimeOpts;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock, RwLock};
use std::thread::JoinHandle;

// ------------------------------------------------------------------
// jobs

/// One fan-out: an index space [0, total) and a lifetime-erased body.
///
/// `body` is a raw pointer (not a reference) because pool workers may
/// legitimately hold the `Arc<Job>` after the submitting `parallel_for`
/// frame — and the closure it points into — are gone; they only ever
/// *dereference* it for a claimed task (`i < total`), and the submitter
/// does not return (even on panic) until `done == total`, i.e. until
/// every claimed task has finished executing.
struct Job {
    body: *const (dyn Fn(usize) + Sync),
    next: AtomicUsize,
    total: usize,
    done: AtomicUsize,
    panicked: AtomicBool,
    lock: Mutex<()>,
    cv: Condvar,
}

// SAFETY: the raw `body` pointer is only dereferenced under the
// claimed-task protocol documented on `Job`; everything else in the
// struct is already Send + Sync.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

impl Job {
    /// Claim and run tasks until the index space is exhausted.
    fn run(&self) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.total {
                return;
            }
            // SAFETY: task i was claimed before the index space drained,
            // so the submitter is still blocked in wait() and the
            // pointee is alive for the duration of this call.
            let body = unsafe { &*self.body };
            if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(i))).is_err() {
                self.panicked.store(true, Ordering::Relaxed);
            }
            if self.done.fetch_add(1, Ordering::AcqRel) + 1 == self.total {
                // hold the lock while notifying so a waiter that just
                // checked `done` cannot miss the wakeup
                let _g = self.lock.lock().unwrap();
                self.cv.notify_all();
            }
        }
    }

    /// Block until every task has finished (not merely been claimed).
    fn wait(&self) {
        let mut g = self.lock.lock().unwrap();
        while self.done.load(Ordering::Acquire) < self.total {
            g = self.cv.wait(g).unwrap();
        }
    }
}

struct Shared {
    queue: Mutex<VecDeque<Arc<Job>>>,
    cv: Condvar,
    stop: AtomicBool,
}

impl Shared {
    /// Drop a fully-claimed job from the queue (idempotent).
    fn retire(&self, job: &Arc<Job>) {
        let mut q = self.queue.lock().unwrap();
        if let Some(pos) = q.iter().position(|j| Arc::ptr_eq(j, job)) {
            q.remove(pos);
        }
    }
}

fn worker(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if shared.stop.load(Ordering::Acquire) {
                    return;
                }
                if let Some(j) = q.front() {
                    break Arc::clone(j);
                }
                q = shared.cv.wait(q).unwrap();
            }
        };
        job.run();
        shared.retire(&job);
    }
}

// ------------------------------------------------------------------
// pool

pub struct Pool {
    /// None when threads == 1: pure sequential fast path.
    shared: Option<Arc<Shared>>,
    threads: usize,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl Pool {
    /// A pool that executes with `threads` total threads (the caller
    /// counts as one, so `threads - 1` workers are spawned).
    pub fn new(threads: usize) -> Pool {
        let threads = threads.max(1);
        if threads == 1 {
            return Pool { shared: None, threads: 1, handles: Mutex::new(Vec::new()) };
        }
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            stop: AtomicBool::new(false),
        });
        let mut handles = Vec::with_capacity(threads - 1);
        for i in 0..threads - 1 {
            let sh = shared.clone();
            let h = std::thread::Builder::new()
                .name(format!("uni-lora-kernel-{i}"))
                .spawn(move || worker(sh))
                .expect("spawning kernel pool worker");
            handles.push(h);
        }
        Pool { shared: Some(shared), threads, handles: Mutex::new(handles) }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `body(i)` for every i in [0, total), fanned across the pool.
    /// Returns after ALL tasks have completed. Panics (after the whole
    /// index space has drained) if any task panicked.
    pub fn parallel_for(&self, total: usize, body: &(dyn Fn(usize) + Sync)) {
        if total == 0 {
            return;
        }
        let shared = match &self.shared {
            Some(s) if total > 1 => s,
            _ => {
                for i in 0..total {
                    body(i);
                }
                return;
            }
        };
        // The pointee outlives every dereference: this function only
        // returns after `job.wait()` observes done == total, and tasks
        // are claimed before being run — no thread can start a task
        // after that point (see the SAFETY notes on `Job`).
        let job = Arc::new(Job {
            body: body as *const (dyn Fn(usize) + Sync),
            next: AtomicUsize::new(0),
            total,
            done: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
            lock: Mutex::new(()),
            cv: Condvar::new(),
        });
        shared.queue.lock().unwrap().push_back(job.clone());
        shared.cv.notify_all();
        job.run();
        shared.retire(&job);
        job.wait();
        if job.panicked.load(Ordering::Relaxed) {
            panic!("kernels::parallel_for: a task panicked");
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        if let Some(shared) = &self.shared {
            {
                // hold the condvar's mutex while flipping the flag so a
                // worker between its stop-check and cv.wait cannot miss
                // the wakeup (it holds this lock for that whole window)
                let _q = shared.queue.lock().unwrap();
                shared.stop.store(true, Ordering::Release);
                shared.cv.notify_all();
            }
            for h in self.handles.lock().unwrap().drain(..) {
                let _ = h.join();
            }
        }
    }
}

// ------------------------------------------------------------------
// global pool

static GLOBAL: OnceLock<RwLock<Arc<Pool>>> = OnceLock::new();

fn global() -> &'static RwLock<Arc<Pool>> {
    GLOBAL.get_or_init(|| RwLock::new(Arc::new(Pool::new(RuntimeOpts::from_env().threads))))
}

/// The process-wide kernel pool (lazily built from `UNI_LORA_THREADS`).
pub fn pool() -> Arc<Pool> {
    global().read().unwrap().clone()
}

/// Replace the global pool with one of `threads` threads. In-flight
/// `parallel_for` calls keep their own handle on the old pool and
/// complete normally; the old workers shut down when the last handle
/// drops. Results are thread-count invariant, so this only affects
/// speed — benches use it to sweep threads=1 vs threads=N.
pub fn set_threads(threads: usize) {
    let next = Arc::new(Pool::new(threads.max(1)));
    *global().write().unwrap() = next;
}

/// Current global pool width.
pub fn threads() -> usize {
    pool().threads()
}

/// RAII reset for tests and benches that sweep `set_threads`: on drop
/// — success OR panic — the global pool is restored to the env-derived
/// default width, so a failing assert mid-sweep can't leave a pinned
/// width applied to every later test in the process. (Width only
/// affects wall-clock, never results, so a racing guard in another
/// test is benign.)
#[must_use = "the guard restores the pool width when dropped"]
pub struct ThreadsGuard(());

impl ThreadsGuard {
    /// Start a guarded section; callers then `set_threads` freely.
    pub fn new() -> ThreadsGuard {
        ThreadsGuard(())
    }

    /// Convenience: guard AND pin the width in one call.
    pub fn pin(threads: usize) -> ThreadsGuard {
        let g = ThreadsGuard::new();
        set_threads(threads);
        g
    }
}

impl Default for ThreadsGuard {
    fn default() -> ThreadsGuard {
        ThreadsGuard::new()
    }
}

impl Drop for ThreadsGuard {
    fn drop(&mut self) {
        set_threads(RuntimeOpts::from_env().threads);
    }
}

// ------------------------------------------------------------------
// disjoint-write escape hatch

/// A raw, Send+Sync base pointer into a mutable buffer, for kernels
/// whose tasks write to provably disjoint regions of one allocation
/// (GEMM row panels, per-(batch, head) attention slabs). Rust's borrow
/// checker cannot see that disjointness through a `Fn` task body, so
/// the drivers carve per-task `&mut` views out of this instead.
#[derive(Clone, Copy)]
pub struct SendPtr<T>(*mut T);

unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    pub fn new(buf: &mut [T]) -> SendPtr<T> {
        SendPtr(buf.as_mut_ptr())
    }

    /// Reborrow `buf[off..off + len]` as `&mut`.
    ///
    /// # Safety
    /// `[off, off + len)` must lie inside the original buffer, and no
    /// other live reference (from any thread) may overlap it.
    pub unsafe fn slice<'a>(&self, off: usize, len: usize) -> &'a mut [T] {
        std::slice::from_raw_parts_mut(self.0.add(off), len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_every_index_exactly_once() {
        let p = Pool::new(4);
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        p.parallel_for(1000, &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn sequential_pool_runs_inline() {
        let p = Pool::new(1);
        let mut seen = Vec::new();
        // threads == 1 runs on the caller, so a non-Sync-hostile
        // mutation through a RefCell-free pattern is fine via atomics
        let n = AtomicUsize::new(0);
        p.parallel_for(17, &|_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        seen.push(n.load(Ordering::Relaxed));
        assert_eq!(seen, vec![17]);
    }

    #[test]
    fn nested_and_concurrent_jobs_complete() {
        let p = Arc::new(Pool::new(3));
        let outer = AtomicUsize::new(0);
        let p2 = p.clone();
        p.parallel_for(8, &|_| {
            // nested fan-out from inside a task: caller participation
            // guarantees progress even with all workers busy
            let inner = AtomicUsize::new(0);
            p2.parallel_for(8, &|_| {
                inner.fetch_add(1, Ordering::Relaxed);
            });
            outer.fetch_add(inner.load(Ordering::Relaxed), Ordering::Relaxed);
        });
        assert_eq!(outer.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn task_panic_propagates_and_pool_survives() {
        let p = Pool::new(2);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            p.parallel_for(8, &|i| {
                if i == 3 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err());
        // pool still functional afterwards
        let n = AtomicUsize::new(0);
        p.parallel_for(8, &|_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn set_threads_swaps_global_pool() {
        // NOTE: no assert on `threads()` — other tests (the gemm
        // property suite) legitimately race on the global width, and
        // results are width-invariant by contract anyway.
        set_threads(2);
        let n = AtomicUsize::new(0);
        pool().parallel_for(32, &|_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 32);
        set_threads(RuntimeOpts::from_env().threads);
        assert!(threads() >= 1);
    }

    #[test]
    fn threads_guard_restores_on_panic() {
        // a panicking guarded section must restore the env width —
        // the old pattern (restore as the last statement of the test)
        // poisoned every later test in the process on failure.
        // NOTE: no exact-width assert — sibling tests legitimately
        // race the global width (see set_threads_swaps_global_pool);
        // we assert the guard's Drop ran through the unwind and the
        // pool is functional afterwards.
        let r = std::panic::catch_unwind(|| {
            let _g = ThreadsGuard::pin(2);
            panic!("boom");
        });
        assert!(r.is_err());
        assert!(threads() >= 1);
        let n = AtomicUsize::new(0);
        pool().parallel_for(8, &|_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn send_ptr_disjoint_writes() {
        let p = Pool::new(4);
        let mut buf = vec![0usize; 64];
        let ptr = SendPtr::new(&mut buf);
        p.parallel_for(8, &|t| {
            // SAFETY: task t owns rows [t*8, t*8 + 8)
            let chunk = unsafe { ptr.slice(t * 8, 8) };
            for (j, c) in chunk.iter_mut().enumerate() {
                *c = t * 8 + j;
            }
        });
        assert_eq!(buf, (0..64).collect::<Vec<_>>());
    }
}
