//! Rust mirror of `python/compile/configs.ModelCfg`. Parsed from the
//! artifact manifest (the Python side is the source of truth; the Rust
//! side never invents a config that has no artifact behind it).

use crate::util::json::Json;
use anyhow::Result;

#[derive(Debug, Clone, PartialEq)]
pub struct ModelCfg {
    pub name: String,
    pub vocab: usize,
    pub seq: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub ffn: usize,
    pub method: String,
    pub rank: usize,
    pub d: usize,
    pub scale: f32,
    pub n_classes: usize,
    pub batch: usize,
    pub vb_b: usize,
    pub vb_k: usize,
    pub vb_bank: usize,
    pub n_coef: usize,
}

impl ModelCfg {
    pub fn from_json(j: &Json) -> Result<ModelCfg> {
        Ok(ModelCfg {
            name: j.req("name")?.as_str()?.to_string(),
            vocab: j.req("vocab")?.as_usize()?,
            seq: j.req("seq")?.as_usize()?,
            hidden: j.req("hidden")?.as_usize()?,
            layers: j.req("layers")?.as_usize()?,
            heads: j.req("heads")?.as_usize()?,
            ffn: j.req("ffn")?.as_usize()?,
            method: j.req("method")?.as_str()?.to_string(),
            rank: j.req("rank")?.as_usize()?,
            d: j.req("d")?.as_usize()?,
            scale: j.req("scale")?.as_f64()? as f32,
            n_classes: j.req("n_classes")?.as_usize()?,
            batch: j.req("batch")?.as_usize()?,
            vb_b: j.req("vb_b")?.as_usize()?,
            vb_k: j.req("vb_k")?.as_usize()?,
            vb_bank: j.req("vb_bank")?.as_usize()?,
            n_coef: j.req("n_coef")?.as_usize()?,
        })
    }

    /// Adapted modules: q and v per layer.
    pub fn n_modules(&self) -> usize {
        2 * self.layers
    }

    /// Per-module LoRA params: A [h, r] + B [r, h].
    pub fn module_len(&self) -> usize {
        2 * self.hidden * self.rank
    }

    /// D = total LoRA parameter count across adapted modules.
    pub fn d_full(&self) -> usize {
        self.n_modules() * self.module_len()
    }

    /// Test/bench constructor matching python configs.BASE.
    pub fn test_base(method: &str) -> ModelCfg {
        ModelCfg {
            name: "base".into(),
            vocab: 512,
            seq: 32,
            hidden: 64,
            layers: 2,
            heads: 4,
            ffn: 128,
            method: method.into(),
            rank: 4,
            d: 256,
            scale: 2.0,
            n_classes: 2,
            batch: 32,
            vb_b: 64,
            vb_k: 2,
            vb_bank: 24,
            n_coef: 96,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_dims_match_python() {
        let c = ModelCfg::test_base("uni");
        assert_eq!(c.n_modules(), 4);
        assert_eq!(c.module_len(), 512);
        assert_eq!(c.d_full(), 2048);
    }

    #[test]
    fn from_json_roundtrip() {
        let j = Json::parse(
            r#"{"name":"base","vocab":512,"seq":32,"hidden":64,"layers":2,
                "heads":4,"ffn":128,"method":"uni","rank":4,"d":256,
                "scale":2.0,"n_classes":2,"batch":32,"vb_b":64,"vb_k":2,
                "vb_bank":24,"n_coef":96,"use_pallas":true}"#,
        )
        .unwrap();
        let c = ModelCfg::from_json(&j).unwrap();
        assert_eq!(c, ModelCfg::test_base("uni"));
    }
}
