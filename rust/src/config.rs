//! Rust mirror of `python/compile/configs.ModelCfg`. Parsed from the
//! artifact manifest (the Python side is the source of truth; the Rust
//! side never invents a config that has no artifact behind it).

use crate::util::json::Json;
use anyhow::Result;

#[derive(Debug, Clone, PartialEq)]
pub struct ModelCfg {
    pub name: String,
    pub vocab: usize,
    pub seq: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub ffn: usize,
    pub method: String,
    pub rank: usize,
    pub d: usize,
    pub scale: f32,
    pub n_classes: usize,
    pub batch: usize,
    pub vb_b: usize,
    pub vb_k: usize,
    pub vb_bank: usize,
    pub n_coef: usize,
}

impl ModelCfg {
    pub fn from_json(j: &Json) -> Result<ModelCfg> {
        Ok(ModelCfg {
            name: j.req("name")?.as_str()?.to_string(),
            vocab: j.req("vocab")?.as_usize()?,
            seq: j.req("seq")?.as_usize()?,
            hidden: j.req("hidden")?.as_usize()?,
            layers: j.req("layers")?.as_usize()?,
            heads: j.req("heads")?.as_usize()?,
            ffn: j.req("ffn")?.as_usize()?,
            method: j.req("method")?.as_str()?.to_string(),
            rank: j.req("rank")?.as_usize()?,
            d: j.req("d")?.as_usize()?,
            scale: j.req("scale")?.as_f64()? as f32,
            n_classes: j.req("n_classes")?.as_usize()?,
            batch: j.req("batch")?.as_usize()?,
            vb_b: j.req("vb_b")?.as_usize()?,
            vb_k: j.req("vb_k")?.as_usize()?,
            vb_bank: j.req("vb_bank")?.as_usize()?,
            n_coef: j.req("n_coef")?.as_usize()?,
        })
    }

    /// Adapted modules: q and v per layer.
    pub fn n_modules(&self) -> usize {
        2 * self.layers
    }

    /// Per-module LoRA params: A [h, r] + B [r, h].
    pub fn module_len(&self) -> usize {
        2 * self.hidden * self.rank
    }

    /// D = total LoRA parameter count across adapted modules.
    pub fn d_full(&self) -> usize {
        self.n_modules() * self.module_len()
    }

    /// Structural invariants shared by every consumer. In particular
    /// the uni-family subspace dimension must satisfy d <= D: with
    /// d > D no row assignment can give every column support, and the
    /// full-support patching loop in projection::uni would never
    /// terminate.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.vocab > 0, "cfg {}: vocab must be > 0", self.name);
        anyhow::ensure!(self.seq > 0, "cfg {}: seq must be > 0", self.name);
        anyhow::ensure!(self.batch > 0, "cfg {}: batch must be > 0", self.name);
        anyhow::ensure!(
            self.heads > 0 && self.hidden % self.heads == 0,
            "cfg {}: heads ({}) must divide hidden ({})",
            self.name,
            self.heads,
            self.hidden
        );
        if matches!(self.method.as_str(), "uni" | "local" | "nonuniform" | "fastfood") {
            anyhow::ensure!(self.d > 0, "cfg {}: d must be > 0", self.name);
            anyhow::ensure!(
                self.d <= self.d_full(),
                "cfg {}: subspace dim d = {} exceeds D = {} — no projection \
                 with full column support exists (method {})",
                self.name,
                self.d,
                self.d_full(),
                self.method
            );
        }
        Ok(())
    }

    /// Per-head dimension.
    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    /// Mirror of python configs.BASE.
    pub fn base() -> ModelCfg {
        ModelCfg::test_base("uni")
    }

    /// Mirror of python configs.LARGE.
    pub fn large() -> ModelCfg {
        ModelCfg { name: "large".into(), hidden: 96, layers: 3, ffn: 192, ..ModelCfg::base() }
    }

    /// Mirror of python configs.LM.
    pub fn lm() -> ModelCfg {
        ModelCfg {
            name: "lm".into(),
            hidden: 128,
            layers: 4,
            ffn: 256,
            seq: 64,
            n_classes: 0,
            batch: 16,
            d: 1024,
            ..ModelCfg::base()
        }
    }

    /// Mirror of python configs.E2E.
    pub fn e2e() -> ModelCfg {
        ModelCfg {
            name: "e2e".into(),
            hidden: 256,
            layers: 8,
            ffn: 1024,
            heads: 8,
            seq: 64,
            vocab: 2048,
            n_classes: 0,
            batch: 8,
            d: 4096,
            ..ModelCfg::base()
        }
    }

    /// Mirror of python configs.with_method (builder style).
    pub fn with_method(&self, method: &str) -> ModelCfg {
        ModelCfg { method: method.into(), ..self.clone() }
    }

    pub fn with_classes(mut self, n_classes: usize) -> ModelCfg {
        self.n_classes = n_classes;
        self
    }

    pub fn with_d(mut self, d: usize) -> ModelCfg {
        self.d = d;
        self
    }

    pub fn with_rank(mut self, rank: usize) -> ModelCfg {
        self.rank = rank;
        self
    }

    /// Test/bench constructor matching python configs.BASE.
    pub fn test_base(method: &str) -> ModelCfg {
        ModelCfg {
            name: "base".into(),
            vocab: 512,
            seq: 32,
            hidden: 64,
            layers: 2,
            heads: 4,
            ffn: 128,
            method: method.into(),
            rank: 4,
            d: 256,
            scale: 2.0,
            n_classes: 2,
            batch: 32,
            vb_b: 64,
            vb_k: 2,
            vb_bank: 24,
            n_coef: 96,
        }
    }
}

// ------------------------------------------------------------------
// runtime knobs

/// Which kernel tier the compute layer should run (`UNI_LORA_KERNELS`).
///
/// `Scalar` is the retained golden-reference tier (bit-identical to the
/// pre-kernels loop nests); `Simd` is the register-tiled,
/// lane-reassociated tier (AVX2+FMA intrinsics where the CPU has them,
/// a portable fixed-lane path otherwise); `Auto` picks `Simd` when the
/// CPU feature probe succeeds and falls back to `Scalar` when it
/// doesn't. Resolution lives in `kernels::dispatch::resolve`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelChoice {
    Scalar,
    Simd,
    Auto,
}

/// Execution-runtime knobs, deliberately separate from `ModelCfg`:
/// these never change the artifact contract, only how the work is
/// scheduled on the host. (`threads` never changes numerics at all;
/// `kernels` keeps every variant run- and thread-count-deterministic,
/// but the simd tier is only tolerance-equal to scalar — see
/// `kernels::dispatch` for the contract.)
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RuntimeOpts {
    /// Kernel-pool width and default serving-worker count
    /// (`UNI_LORA_THREADS`; default = available parallelism).
    pub threads: usize,
    /// Kernel-tier selection (`UNI_LORA_KERNELS=scalar|simd|auto`;
    /// default auto).
    pub kernels: KernelChoice,
    /// Decode slots (concurrent sequences) per serving session
    /// (`UNI_LORA_DECODE_SLOTS`; 0 = auto: the artifact batch size).
    pub decode_slots: usize,
    /// Adapter-reconstruction cache capacity, in resident adapters
    /// (`UNI_LORA_RECON_CACHE`; default [`DEFAULT_RECON_CACHE`]).
    pub recon_cache: usize,
    /// Dense-densification crossover for the session cost model
    /// (`UNI_LORA_DENSE_THRESHOLD`; default
    /// [`DEFAULT_DENSE_THRESHOLD`]). An adapter occupying at least
    /// this many of a session's slots is densified (one reconstruction
    /// amortized over its slots); below it, slots run the factored
    /// rank-r path. `1` = always densify (the legacy behavior); a huge
    /// value = always factored.
    pub dense_threshold: usize,
    /// K/V arena token budget, in pages of [`KV_PAGE_TOKENS`]
    /// positions (`UNI_LORA_KV_PAGES`; 0 = auto: the per-slot
    /// worst case `slots * ceil(seq / KV_PAGE_TOKENS)`, i.e. exactly
    /// the capacity the old per-slot preallocation guaranteed).
    pub kv_pages: usize,
    /// Fused batched decode step (`UNI_LORA_FUSED_STEP`; default on).
    /// Scheduling-only: the fused step is bit-equal per kernel tier to
    /// per-slot stepping, so the knob exists for A/B benching and
    /// bisection, not correctness.
    pub fused_step: bool,
    /// Beam width the eval harness resolves when a caller asks for
    /// beam search without pinning a width
    /// (`UNI_LORA_BEAM_WIDTH`; default [`DEFAULT_BEAM_WIDTH`]).
    pub beam_width: usize,
}

/// Positions per K/V arena page. One page holds every layer's keys and
/// values for this many consecutive positions
/// (`layers * 2 * KV_PAGE_TOKENS * hidden` floats). 16 keeps partial-
/// page waste under one-quarter of the `lm` window while page tables
/// stay a handful of entries.
pub const KV_PAGE_TOKENS: usize = 16;

/// Default adapter-reconstruction cache capacity. Reconstructions are
/// `2 * layers * hidden^2` floats each (~512 KiB on the `lm` shape),
/// so 64 residents ≈ 32 MiB — small next to the backbone, large
/// enough that a steady multi-tenant mix rarely misses.
pub const DEFAULT_RECON_CACHE: usize = 64;

/// Default dense-densification crossover. Factored execution adds two
/// rank-r GEMVs per adapted module per step (~`4*h*r` FLOPs on top of
/// the base `h^2` GEMV — a few percent at r=4, h=128), while a dense
/// reconstruction costs `2 * layers * h^2` resident floats amortized
/// over however many slots share the adapter. Around 4 same-adapter
/// slots the residency is paid back quickly enough to be worth it;
/// below that, factored keeps per-adapter state at rank-r factors.
pub const DEFAULT_DENSE_THRESHOLD: usize = 4;

/// Default eval-harness beam width. 4 is the conventional
/// small-model sweet spot: wide enough to recover from a first-token
/// argmax mistake, narrow enough that eval cost stays ~width× greedy.
pub const DEFAULT_BEAM_WIDTH: usize = 4;

/// Default graceful-drain deadline for `ServerHandle::shutdown`,
/// milliseconds. Long enough for any in-flight sequence on the tiny
/// reference shapes to finish its budget; a production deployment
/// sizes it to p99 request latency.
pub const DEFAULT_DRAIN_MS: u64 = 5_000;

/// Default cap on one request line, bytes (1 MiB). A `generate`
/// request is a prompt plus a few scalar fields — a line this long is
/// either a protocol bug or an attack, and the old unbounded
/// `read_line` would buffer it all before parsing.
pub const DEFAULT_MAX_REQUEST_BYTES: usize = 1 << 20;

/// Default socket read/write timeout, milliseconds. Bounds how long a
/// connection handler thread can sit in a blocking read (slow-loris)
/// or write (stalled receiver) before the connection is dropped.
pub const DEFAULT_SOCK_TIMEOUT_MS: u64 = 30_000;

/// Default trace-ring capacity, events. A request's full timeline is
/// a few events plus one per generated token, so 4096 holds the last
/// ~100 small requests — enough to reconstruct any recent failure —
/// at well under a megabyte of ring.
pub const DEFAULT_TRACE_RING: usize = 4096;

impl RuntimeOpts {
    pub fn from_env() -> RuntimeOpts {
        RuntimeOpts {
            threads: parse_threads(std::env::var("UNI_LORA_THREADS").ok().as_deref()),
            kernels: parse_kernels(std::env::var("UNI_LORA_KERNELS").ok().as_deref()),
            decode_slots: parse_decode_slots(
                std::env::var("UNI_LORA_DECODE_SLOTS").ok().as_deref(),
            ),
            recon_cache: parse_recon_cache(std::env::var("UNI_LORA_RECON_CACHE").ok().as_deref()),
            dense_threshold: parse_dense_threshold(
                std::env::var("UNI_LORA_DENSE_THRESHOLD").ok().as_deref(),
            ),
            kv_pages: parse_kv_pages(std::env::var("UNI_LORA_KV_PAGES").ok().as_deref()),
            fused_step: parse_fused_step(std::env::var("UNI_LORA_FUSED_STEP").ok().as_deref()),
            beam_width: parse_beam_width(std::env::var("UNI_LORA_BEAM_WIDTH").ok().as_deref()),
        }
    }
}

/// `UNI_LORA_THREADS` parsing: a positive integer wins; anything else
/// (unset, garbage, 0) falls back to available parallelism.
pub fn parse_threads(raw: Option<&str>) -> usize {
    raw.and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&t| t >= 1)
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

/// `UNI_LORA_KERNELS` parsing: `scalar` and `simd` are explicit pins,
/// unset or `auto` is `Auto`. UNLIKE `parse_threads`, an unrecognized
/// value does NOT fall through to the probed default: this knob
/// changes numerics, and a typo'd `scalar` pin silently resolving to
/// the simd tier would diverge results at ULP level with no signal.
/// Garbage pins the fail-safe golden tier (`Scalar`) and warns.
pub fn parse_kernels(raw: Option<&str>) -> KernelChoice {
    match raw.map(|s| s.trim().to_ascii_lowercase()).as_deref() {
        Some("scalar") => KernelChoice::Scalar,
        Some("simd") => KernelChoice::Simd,
        None | Some("auto") | Some("") => KernelChoice::Auto,
        Some(other) => {
            eprintln!(
                "warning: UNI_LORA_KERNELS={other:?} not recognized \
                 (want scalar|simd|auto); pinning the scalar tier"
            );
            KernelChoice::Scalar
        }
    }
}

/// `UNI_LORA_DECODE_SLOTS` parsing: a positive integer wins; anything
/// else (unset, garbage, 0) is 0 = auto — sessions fall back to the
/// artifact batch size. Scheduling-only (like `threads`): the knob
/// never changes what any sequence generates, only how many decode
/// concurrently.
pub fn parse_decode_slots(raw: Option<&str>) -> usize {
    raw.and_then(|s| s.trim().parse::<usize>().ok()).unwrap_or(0)
}

/// `UNI_LORA_RECON_CACHE` parsing: a positive integer wins; anything
/// else (unset, garbage, 0 — an adapter cache of zero would thrash
/// every admission) falls back to [`DEFAULT_RECON_CACHE`].
pub fn parse_recon_cache(raw: Option<&str>) -> usize {
    raw.and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(DEFAULT_RECON_CACHE)
}

/// `UNI_LORA_DENSE_THRESHOLD` parsing: a positive integer wins;
/// anything else (unset, garbage, 0 — a crossover of zero is
/// meaningless) falls back to [`DEFAULT_DENSE_THRESHOLD`].
/// Scheduling-only: both execution modes are token-stream identical,
/// so the knob trades per-step FLOPs against resident bytes without
/// changing what any sequence generates.
pub fn parse_dense_threshold(raw: Option<&str>) -> usize {
    raw.and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(DEFAULT_DENSE_THRESHOLD)
}

/// `UNI_LORA_KV_PAGES` parsing: a positive integer wins; anything else
/// (unset, garbage, 0) is 0 = auto — sessions reserve the per-slot
/// worst case, so paging is opt-out-safe: the default budget admits
/// exactly what per-slot preallocation admitted.
pub fn parse_kv_pages(raw: Option<&str>) -> usize {
    raw.and_then(|s| s.trim().parse::<usize>().ok()).unwrap_or(0)
}

/// `UNI_LORA_FUSED_STEP` parsing: `0|false|off|no` disables the fused
/// batched decode step; everything else (unset, `1`, garbage) keeps it
/// on. Scheduling-only — fused and per-slot stepping are bit-equal per
/// kernel tier — so garbage safely takes the default.
pub fn parse_fused_step(raw: Option<&str>) -> bool {
    !matches!(
        raw.map(|s| s.trim().to_ascii_lowercase()).as_deref(),
        Some("0") | Some("false") | Some("off") | Some("no")
    )
}

/// `UNI_LORA_BEAM_WIDTH` parsing: a positive integer wins; anything
/// else (unset, garbage, 0 — a width of zero keeps no beams) falls
/// back to [`DEFAULT_BEAM_WIDTH`]. Width 1 is exactly greedy.
pub fn parse_beam_width(raw: Option<&str>) -> usize {
    raw.and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(DEFAULT_BEAM_WIDTH)
}

/// `UNI_LORA_REQUEST_TIMEOUT_MS` parsing: a non-negative integer wins;
/// anything else (unset, garbage) is 0 = no default deadline. Requests
/// can still pin their own `timeout_ms` on the wire.
pub fn parse_request_timeout_ms(raw: Option<&str>) -> u64 {
    raw.and_then(|s| s.trim().parse::<u64>().ok()).unwrap_or(0)
}

/// `UNI_LORA_DRAIN_MS` parsing: a non-negative integer wins (0 =
/// hard-stop immediately, no grace); anything else falls back to
/// [`DEFAULT_DRAIN_MS`].
pub fn parse_drain_ms(raw: Option<&str>) -> u64 {
    raw.and_then(|s| s.trim().parse::<u64>().ok()).unwrap_or(DEFAULT_DRAIN_MS)
}

/// `UNI_LORA_MAX_CONNS` parsing: a positive integer wins; anything
/// else (unset, garbage, 0) is 0 = unlimited. Each live connection
/// holds one handler thread, so a deployment sizes this to its thread
/// budget.
pub fn parse_max_conns(raw: Option<&str>) -> usize {
    raw.and_then(|s| s.trim().parse::<usize>().ok()).unwrap_or(0)
}

/// `UNI_LORA_MAX_REQUEST_BYTES` parsing: a positive integer wins;
/// anything else (unset, garbage, 0 — the cap must stay on) falls back
/// to [`DEFAULT_MAX_REQUEST_BYTES`]. There is deliberately no
/// "unlimited" spelling; pick a huge value instead.
pub fn parse_max_request_bytes(raw: Option<&str>) -> usize {
    raw.and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(DEFAULT_MAX_REQUEST_BYTES)
}

/// `UNI_LORA_SOCK_TIMEOUT_MS` parsing: a non-negative integer wins
/// (0 = no socket timeouts); anything else falls back to
/// [`DEFAULT_SOCK_TIMEOUT_MS`].
pub fn parse_sock_timeout_ms(raw: Option<&str>) -> u64 {
    raw.and_then(|s| s.trim().parse::<u64>().ok()).unwrap_or(DEFAULT_SOCK_TIMEOUT_MS)
}

/// `UNI_LORA_TRACE_RING` parsing: a non-negative integer wins (0 is a
/// meaningful pin — it disables the in-memory trace ring entirely);
/// anything else (unset, garbage) falls back to
/// [`DEFAULT_TRACE_RING`]. Observation-only, so garbage safely takes
/// the default.
pub fn parse_trace_ring(raw: Option<&str>) -> usize {
    raw.and_then(|s| s.trim().parse::<usize>().ok()).unwrap_or(DEFAULT_TRACE_RING)
}

/// `UNI_LORA_TRACE` parsing: a non-empty value is the JSONL append
/// path for the trace file sink; unset or empty disables it. (A path
/// that fails to open at serve time warns and degrades to ring-only —
/// see `obs::trace::Tracer::from_cfg`.)
pub fn parse_trace_path(raw: Option<&str>) -> Option<String> {
    raw.map(str::trim).filter(|s| !s.is_empty()).map(str::to_string)
}

/// `UNI_LORA_PROFILE` parsing: `1|true|on|yes` enables the decode
/// profiling hooks; everything else (unset, `0`, garbage) keeps them
/// off. Opt-in-only spelling — profiling reads the clock inside the
/// decode step, so it should never latch on from a typo.
pub fn parse_profile(raw: Option<&str>) -> bool {
    matches!(
        raw.map(|s| s.trim().to_ascii_lowercase()).as_deref(),
        Some("1") | Some("true") | Some("on") | Some("yes")
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_dims_match_python() {
        let c = ModelCfg::test_base("uni");
        assert_eq!(c.n_modules(), 4);
        assert_eq!(c.module_len(), 512);
        assert_eq!(c.d_full(), 2048);
    }

    #[test]
    fn family_constructors_match_python() {
        assert_eq!(ModelCfg::base().hidden, 64);
        let lg = ModelCfg::large();
        assert_eq!((lg.hidden, lg.layers, lg.ffn, lg.seq), (96, 3, 192, 32));
        let lm = ModelCfg::lm();
        assert_eq!((lm.hidden, lm.layers, lm.seq, lm.batch, lm.d), (128, 4, 64, 16, 1024));
        assert_eq!(lm.n_classes, 0);
        let e2e = ModelCfg::e2e();
        assert_eq!((e2e.hidden, e2e.layers, e2e.vocab, e2e.d), (256, 8, 2048, 4096));
        let m = ModelCfg::base().with_method("lora").with_classes(10).with_rank(8);
        assert_eq!((m.method.as_str(), m.n_classes, m.rank), ("lora", 10, 8));
    }

    #[test]
    fn validate_rejects_oversized_subspace() {
        let ok = ModelCfg::test_base("uni");
        assert!(ok.validate().is_ok());
        let mut bad = ModelCfg::test_base("uni");
        bad.d = bad.d_full() + 1;
        let err = bad.validate().unwrap_err().to_string();
        assert!(err.contains("exceeds"), "{err}");
        // non-subspace methods don't care about d vs D
        let mut lora = ModelCfg::test_base("lora");
        lora.d = lora.d_full() + 1;
        assert!(lora.validate().is_ok());
    }

    #[test]
    fn threads_knob_parses_and_defaults() {
        assert_eq!(parse_threads(Some("3")), 3);
        assert_eq!(parse_threads(Some(" 8 ")), 8);
        let auto = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        assert_eq!(parse_threads(None), auto);
        assert_eq!(parse_threads(Some("0")), auto);
        assert_eq!(parse_threads(Some("lots")), auto);
        // from_env never yields 0 (tests must not mutate the env)
        assert!(RuntimeOpts::from_env().threads >= 1);
    }

    #[test]
    fn kernels_knob_parses_and_defaults() {
        assert_eq!(parse_kernels(Some("scalar")), KernelChoice::Scalar);
        assert_eq!(parse_kernels(Some(" SIMD ")), KernelChoice::Simd);
        assert_eq!(parse_kernels(Some("auto")), KernelChoice::Auto);
        assert_eq!(parse_kernels(Some("")), KernelChoice::Auto);
        assert_eq!(parse_kernels(None), KernelChoice::Auto);
        // a numerics-affecting knob must not let a typo silently pick
        // a different tier: garbage pins the golden scalar tier
        assert_eq!(parse_kernels(Some("turbo")), KernelChoice::Scalar);
        assert_eq!(parse_kernels(Some("sclar")), KernelChoice::Scalar);
    }

    #[test]
    fn session_knobs_parse_and_default() {
        assert_eq!(parse_decode_slots(Some("8")), 8);
        assert_eq!(parse_decode_slots(Some(" 2 ")), 2);
        assert_eq!(parse_decode_slots(Some("0")), 0);
        assert_eq!(parse_decode_slots(Some("many")), 0);
        assert_eq!(parse_decode_slots(None), 0);
        assert_eq!(parse_recon_cache(Some("16")), 16);
        assert_eq!(parse_recon_cache(Some("0")), DEFAULT_RECON_CACHE);
        assert_eq!(parse_recon_cache(Some("big")), DEFAULT_RECON_CACHE);
        assert_eq!(parse_recon_cache(None), DEFAULT_RECON_CACHE);
        assert_eq!(parse_dense_threshold(Some("2")), 2);
        assert_eq!(parse_dense_threshold(Some(" 9 ")), 9);
        assert_eq!(parse_dense_threshold(Some("0")), DEFAULT_DENSE_THRESHOLD);
        assert_eq!(parse_dense_threshold(Some("never")), DEFAULT_DENSE_THRESHOLD);
        assert_eq!(parse_dense_threshold(None), DEFAULT_DENSE_THRESHOLD);
        assert_eq!(parse_kv_pages(Some("128")), 128);
        assert_eq!(parse_kv_pages(Some(" 7 ")), 7);
        assert_eq!(parse_kv_pages(Some("0")), 0);
        assert_eq!(parse_kv_pages(Some("unlimited")), 0);
        assert_eq!(parse_kv_pages(None), 0);
        assert!(parse_fused_step(None));
        assert!(parse_fused_step(Some("1")));
        assert!(parse_fused_step(Some("yes")));
        assert!(parse_fused_step(Some("garbage")));
        assert!(!parse_fused_step(Some("0")));
        assert!(!parse_fused_step(Some(" OFF ")));
        assert!(!parse_fused_step(Some("false")));
        assert!(!parse_fused_step(Some("no")));
        assert_eq!(parse_beam_width(Some("6")), 6);
        assert_eq!(parse_beam_width(Some(" 1 ")), 1);
        assert_eq!(parse_beam_width(Some("0")), DEFAULT_BEAM_WIDTH);
        assert_eq!(parse_beam_width(Some("wide")), DEFAULT_BEAM_WIDTH);
        assert_eq!(parse_beam_width(None), DEFAULT_BEAM_WIDTH);
    }

    #[test]
    fn lifecycle_knobs_parse_and_default() {
        // request timeout: 0/unset/garbage = no default deadline
        assert_eq!(parse_request_timeout_ms(Some("2500")), 2500);
        assert_eq!(parse_request_timeout_ms(Some(" 0 ")), 0);
        assert_eq!(parse_request_timeout_ms(Some("fast")), 0);
        assert_eq!(parse_request_timeout_ms(None), 0);
        // drain: 0 is a meaningful pin (immediate hard-stop), garbage
        // falls back to the default grace
        assert_eq!(parse_drain_ms(Some("250")), 250);
        assert_eq!(parse_drain_ms(Some("0")), 0);
        assert_eq!(parse_drain_ms(Some("forever")), DEFAULT_DRAIN_MS);
        assert_eq!(parse_drain_ms(None), DEFAULT_DRAIN_MS);
        // conns: 0/unset/garbage = unlimited
        assert_eq!(parse_max_conns(Some("64")), 64);
        assert_eq!(parse_max_conns(Some("0")), 0);
        assert_eq!(parse_max_conns(Some("many")), 0);
        assert_eq!(parse_max_conns(None), 0);
        // request-line cap: never off — 0/garbage take the default
        assert_eq!(parse_max_request_bytes(Some("4096")), 4096);
        assert_eq!(parse_max_request_bytes(Some("0")), DEFAULT_MAX_REQUEST_BYTES);
        assert_eq!(parse_max_request_bytes(Some("big")), DEFAULT_MAX_REQUEST_BYTES);
        assert_eq!(parse_max_request_bytes(None), DEFAULT_MAX_REQUEST_BYTES);
        // socket timeout: 0 is a meaningful pin (no timeouts)
        assert_eq!(parse_sock_timeout_ms(Some("100")), 100);
        assert_eq!(parse_sock_timeout_ms(Some("0")), 0);
        assert_eq!(parse_sock_timeout_ms(Some("slow")), DEFAULT_SOCK_TIMEOUT_MS);
        assert_eq!(parse_sock_timeout_ms(None), DEFAULT_SOCK_TIMEOUT_MS);
        // from_env stays total (tests must not mutate the env)
        let o = RuntimeOpts::from_env();
        assert!(o.recon_cache >= 1);
        assert!(o.dense_threshold >= 1);
        assert!(o.beam_width >= 1);
    }

    #[test]
    fn obs_knobs_parse_and_default() {
        // trace ring: 0 is a meaningful pin (ring off), garbage
        // defaults
        assert_eq!(parse_trace_ring(Some("128")), 128);
        assert_eq!(parse_trace_ring(Some(" 0 ")), 0);
        assert_eq!(parse_trace_ring(Some("lots")), DEFAULT_TRACE_RING);
        assert_eq!(parse_trace_ring(None), DEFAULT_TRACE_RING);
        // trace path: non-empty wins, unset/empty = no file sink
        assert_eq!(parse_trace_path(Some("/tmp/t.jsonl")), Some("/tmp/t.jsonl".to_string()));
        assert_eq!(parse_trace_path(Some(" /tmp/t.jsonl ")), Some("/tmp/t.jsonl".to_string()));
        assert_eq!(parse_trace_path(Some("")), None);
        assert_eq!(parse_trace_path(Some("   ")), None);
        assert_eq!(parse_trace_path(None), None);
        // profile: opt-in spellings only — garbage stays off
        assert!(parse_profile(Some("1")));
        assert!(parse_profile(Some(" TRUE ")));
        assert!(parse_profile(Some("on")));
        assert!(parse_profile(Some("yes")));
        assert!(!parse_profile(Some("0")));
        assert!(!parse_profile(Some("off")));
        assert!(!parse_profile(Some("garbage")));
        assert!(!parse_profile(None));
    }

    #[test]
    fn from_json_roundtrip() {
        let j = Json::parse(
            r#"{"name":"base","vocab":512,"seq":32,"hidden":64,"layers":2,
                "heads":4,"ffn":128,"method":"uni","rank":4,"d":256,
                "scale":2.0,"n_classes":2,"batch":32,"vb_b":64,"vb_k":2,
                "vb_bank":24,"n_coef":96,"use_pallas":true}"#,
        )
        .unwrap();
        let c = ModelCfg::from_json(&j).unwrap();
        assert_eq!(c, ModelCfg::test_base("uni"));
    }
}
