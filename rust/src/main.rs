//! `uni-lora` — the L3 launcher. Subcommands:
//!
//! ```text
//! pretrain  --size base|large|lm|e2e --steps N [--seed S]
//! finetune  --task sst2|...|math|instruct --method uni|lora|... [--size base|large]
//!           [--seed S] [--epochs N] [--lr-theta X] [--lr-head X] [--out adapter.uni1]
//! eval      --adapter adapter.uni1 --task <task>
//! serve     --addr 127.0.0.1:7401 --adapters <dir> [--base lm_uni]
//!           [--workers N (0 = auto)] [--queue-depth N]
//! inspect   --adapter adapter.uni1       (print metadata + expansion norms)
//! props     --method uni|vera|...        (Table-1 property analysis)
//! methods   (the ProjectionOp registry's method-support matrix)
//! kernels   (detected CPU features + the resolved kernel variant)
//! list      (artifacts in the active backend's registry)
//! ```
//!
//! Every subcommand takes `--backend native|pjrt` (default: native, or
//! `$UNI_LORA_BACKEND`). The native backend needs no artifacts and no
//! Python; the PJRT backend requires `--features pjrt` + `make artifacts`.

use anyhow::{bail, Context, Result};
use std::sync::Arc;
use uni_lora::adapters::{AdapterCheckpoint, Registry};
use uni_lora::config::ModelCfg;
use uni_lora::coordinator::{evaluator, pretrain_backbone, ClsTrainer, Hyper, LmTrainer};
use uni_lora::data::{glue, instruct, math_tasks};
use uni_lora::projection::properties;
use uni_lora::runtime::Backend;
use uni_lora::server::{serve, ServerConfig};
use uni_lora::util::cli::Args;
use uni_lora::util::fmt_params;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().cloned().unwrap_or_else(|| "help".to_string());
    if let Err(e) = dispatch(&cmd, &args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn make_backend(args: &Args) -> Result<Box<dyn Backend>> {
    match args.get("backend") {
        Some(name) => uni_lora::runtime::backend_by_name(name),
        None => uni_lora::runtime::default_backend(),
    }
}

fn dispatch(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "pretrain" => cmd_pretrain(args),
        "finetune" => cmd_finetune(args),
        "eval" => cmd_eval(args),
        "serve" => cmd_serve(args),
        "inspect" => cmd_inspect(args),
        "props" => cmd_props(args),
        "methods" => cmd_methods(),
        "kernels" => cmd_kernels(),
        "list" => cmd_list(args),
        _ => {
            println!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "uni-lora — Uni-LoRA system reproduction
  pretrain --size base|large|lm|e2e [--steps N] [--seed S]
  finetune --task <task> [--method uni] [--size base] [--seed 42]
           [--epochs 2] [--lr-theta 5e-3] [--lr-head 5e-2] [--out a.uni1]
  eval     --adapter a.uni1 --task <task>
  serve    [--addr 127.0.0.1:7401] [--adapters dir] [--base lm_uni]
           [--workers 0 (auto)] [--queue-depth 256]
  inspect  --adapter a.uni1
  props    [--method uni]
  methods  (method-support matrix from the projection registry)
  kernels  (detected CPU features + resolved kernel variant)
  list
options: --backend native|pjrt (default native)
tasks: sst2 mrpc cola qnli rte stsb | math | instruct";

fn cmd_pretrain(args: &Args) -> Result<()> {
    let size = args.get_or("size", "base");
    let steps = args.usize_or("steps", 300);
    let seed = args.u64_or("seed", 42);
    let mut exec = make_backend(args)?;
    let (w0, losses) = pretrain_backbone(exec.as_mut(), &size, seed, steps)?;
    if losses.is_empty() {
        println!("backbone '{size}' loaded from cache ({} params)", fmt_params(w0.len()));
    } else {
        println!(
            "pretrained '{size}' ({} params, {steps} steps): loss {:.3} -> {:.3}",
            fmt_params(w0.len()),
            losses[0],
            losses.last().unwrap()
        );
    }
    Ok(())
}

fn artifact_base(task: &str, size: &str, method: &str) -> Result<String> {
    Ok(match task {
        "math" | "instruct" => format!("lm_{method}"),
        t if glue::TASKS.contains(&t) => {
            let c = if t == "stsb" { 1 } else { 2 };
            format!("glue_{size}_{method}_c{c}")
        }
        other => bail!("unknown task {other:?}"),
    })
}

fn cmd_finetune(args: &Args) -> Result<()> {
    let task = args.get_or("task", "sst2");
    let method = args.get_or("method", "uni");
    let size = args.get_or("size", "base");
    let seed = args.u64_or("seed", 42);
    let hp = Hyper {
        lr_theta: args.f32_or("lr-theta", 5e-3),
        lr_head: args.f32_or("lr-head", 5e-2),
        wd: args.f32_or("wd", 0.0),
        epochs: args.usize_or("epochs", 2),
    };
    let mut exec = make_backend(args)?;
    let base = artifact_base(&task, &size, &method)?;

    if task == "math" || task == "instruct" {
        let (w0, _) = pretrain_backbone(
            exec.as_mut(),
            "lm",
            42,
            uni_lora::coordinator::backbone::default_steps(),
        )?;
        let meta = exec.meta(&format!("{base}_lm_train"))?.clone();
        let mut tr = LmTrainer::new(exec.as_ref(), &base, seed, w0)?;
        let (split, extra) = if task == "math" {
            math_tasks::generate(seed, meta.cfg.seq, 600, 80)
        } else {
            instruct::generate(seed, meta.cfg.seq, 600, 60)
        };
        let rr = tr.train(exec.as_mut(), &split.train, &hp)?;
        println!(
            "trained {} ({}, d={}): loss {:.3} -> {:.3} in {:.1}s / {} steps",
            base, method, fmt_params(meta.d),
            rr.losses[0], rr.losses.last().unwrap(), rr.train_secs, rr.steps
        );
        if task == "math" {
            let gsm = evaluator::exact_match_accuracy(&mut tr, exec.as_mut(), &split.dev, 8)?;
            let mth = evaluator::exact_match_accuracy(&mut tr, exec.as_mut(), &extra, 8)?;
            println!("GSM8K-like: {gsm:.2}%   MATH-like: {mth:.2}%");
        } else {
            let s1 = evaluator::rubric_score(&mut tr, exec.as_mut(), &split.dev, 10)?;
            let s2 = evaluator::rubric_score(&mut tr, exec.as_mut(), &extra, 10)?;
            println!("Score1 (single-turn): {s1:.2}   Score2 (multi-turn): {s2:.2}");
        }
        if let Some(out) = args.get("out") {
            AdapterCheckpoint {
                seed,
                method: method.clone(),
                artifact: format!("{base}_lm_logits"),
                theta: tr.theta.clone(),
                head: vec![],
            }
            .save(out)?;
            println!("adapter saved to {out}");
        }
    } else {
        let (w0, _) = pretrain_backbone(
            exec.as_mut(),
            &size,
            42,
            uni_lora::coordinator::backbone::default_steps(),
        )?;
        let meta = exec.meta(&format!("{base}_cls_train"))?.clone();
        let mut tr = ClsTrainer::new(exec.as_ref(), &base, seed, w0)?;
        let split = glue::generate(&task, seed, meta.cfg.seq, meta.cfg.vocab);
        let (score, rr) =
            tr.run_and_score(exec.as_mut(), &split.train, &split.dev, split.metric, &hp)?;
        println!(
            "{task} [{method}, d={}]: {} = {:.4} ({} steps, {:.1}s)",
            fmt_params(meta.d), split.metric, score, rr.steps, rr.train_secs
        );
        if let Some(out) = args.get("out") {
            AdapterCheckpoint {
                seed,
                method: method.clone(),
                artifact: format!("{base}_cls_eval"),
                theta: tr.theta.clone(),
                head: tr.head.clone(),
            }
            .save(out)?;
            println!("adapter saved to {out}");
        }
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let path = args.required("adapter")?;
    let task = args.get_or("task", "sst2");
    let ckpt = AdapterCheckpoint::load(path)?;
    let mut exec = make_backend(args)?;
    let meta = exec.meta(&ckpt.artifact)?.clone();
    let cfg = meta.cfg.clone();
    if ckpt.artifact.ends_with("_cls_eval") {
        let base = ckpt.artifact.trim_end_matches("_cls_eval").to_string();
        let size = cfg.name.clone();
        let (w0, _) = pretrain_backbone(
            exec.as_mut(),
            &size,
            42,
            uni_lora::coordinator::backbone::default_steps(),
        )?;
        let mut tr = ClsTrainer::new(exec.as_ref(), &base, ckpt.seed, w0)?;
        tr.theta = ckpt.theta.clone();
        tr.head = ckpt.head.clone();
        let split = glue::generate(&task, ckpt.seed, cfg.seq, cfg.vocab);
        let order = uni_lora::data::batcher::shuffled_indices(split.dev.len(), 0, 0);
        let labels: Vec<f32> = order.iter().map(|&i| split.dev[i].label).collect();
        let logits = tr.eval_logits(exec.as_mut(), &split.dev)?;
        println!(
            "{task}: {} = {:.4}",
            split.metric,
            uni_lora::metrics::compute(split.metric, &logits, &labels)
        );
    } else {
        bail!("eval for artifact kind of {:?} not wired in CLI; see examples/", ckpt.artifact);
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7401");
    let base = args.get_or("base", "lm_uni");
    let dir = args.get_or("adapters", "adapters");
    let mut exec = make_backend(args)?;
    let (w0, _) = pretrain_backbone(
        exec.as_mut(),
        "lm",
        42,
        uni_lora::coordinator::backbone::default_steps(),
    )?;
    let art = format!("{base}_lm_logits");
    let cfg: ModelCfg = exec.meta(&art)?.cfg.clone();
    exec.prepare(&art)?;
    let registry = Arc::new(Registry::load_dir(&dir)?);
    println!(
        "serving {} adapters from {dir} on {addr} [{} backend]",
        registry.len(),
        exec.name()
    );
    let handle = serve(
        ServerConfig::new(addr.clone(), art)
            .with_workers(args.usize_or("workers", 0))
            .with_queue_depth(
                args.usize_or("queue-depth", uni_lora::server::router::DEFAULT_QUEUE_DEPTH),
            ),
        exec,
        registry,
        cfg,
        w0,
    )?;
    println!(
        "listening on {} with {} execution worker(s), {} kernel thread(s)",
        handle.addr,
        handle.workers,
        uni_lora::kernels::threads()
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let path = args.required("adapter")?;
    let ckpt = AdapterCheckpoint::load(path)?;
    println!(
        "adapter: method={} artifact={} seed={} d={} head={} bytes={}",
        ckpt.method,
        ckpt.artifact,
        ckpt.seed,
        ckpt.d(),
        ckpt.head.len(),
        ckpt.byte_size()
    );
    let exec = make_backend(args)?;
    let cfg = exec.meta(&ckpt.artifact)?.cfg.clone();
    let deltas = ckpt.expand(&cfg)?;
    for (i, d) in deltas.iter().enumerate() {
        let dw = d.to_dense(cfg.hidden, cfg.rank);
        let norm: f32 = dw.iter().map(|x| x * x).sum::<f32>().sqrt();
        println!("  module {i}: ||DeltaW||_F = {norm:.4}");
    }
    Ok(())
}

fn cmd_props(args: &Args) -> Result<()> {
    let method = args.get_or("method", "uni");
    let mut cfg = ModelCfg::test_base(&method);
    cfg.hidden = 16;
    cfg.layers = 2;
    cfg.rank = 2;
    cfg.d = 32;
    cfg.vb_b = 16;
    cfg.vb_bank = 8;
    cfg.n_coef = 12;
    let p = properties::analyze(&cfg, args.u64_or("seed", 42)).context("property analysis")?;
    println!("{p:#?}");
    Ok(())
}

/// The method-support matrix, generated from the `ProjectionOp`
/// registry (the same source README.md's table is produced from:
/// `uni-lora methods`). Method names, learned-P and native-train come
/// from the registry; native-eval and pjrt are uniform across all
/// registered methods today (every method has eval/logits kinds and an
/// AOT artifact family), so those two columns are constants here.
fn cmd_methods() -> Result<()> {
    println!(
        "{:<12} {:<10} {:<13} {:<12} {}",
        "method", "learned-P", "native-train", "native-eval", "pjrt"
    );
    for op in uni_lora::projection::op::registry() {
        let m = op.method();
        println!(
            "{:<12} {:<10} {:<13} {:<12} {}",
            m,
            if op.learned_p() { "yes" } else { "no" },
            if uni_lora::runtime::native::can_train(m) { "yes" } else { "no" },
            "yes",
            "train+eval (artifacts)",
        );
    }
    Ok(())
}

/// The kernel-variant matrix, mirroring `uni-lora methods`: detected
/// CPU features, the `UNI_LORA_KERNELS` choice, and the variant the
/// dispatch layer resolved it to (the same table README.md documents).
fn cmd_kernels() -> Result<()> {
    use uni_lora::config::KernelChoice;
    use uni_lora::kernels::dispatch;
    let feats = dispatch::detect();
    println!("cpu features: avx2 = {}, fma = {}", feats.avx2, feats.fma);
    let choice = uni_lora::config::RuntimeOpts::from_env().kernels;
    let choice_str = match choice {
        KernelChoice::Scalar => "scalar",
        KernelChoice::Simd => "simd",
        KernelChoice::Auto => "auto",
    };
    println!(
        "UNI_LORA_KERNELS = {choice_str} -> variant {} (tier {})",
        dispatch::resolve(choice, feats).name(),
        dispatch::path()
    );
    println!("threads = {} (UNI_LORA_THREADS)", uni_lora::kernels::threads());
    println!();
    println!("{:<9} {:<34} {}", "variant", "selected when", "determinism");
    println!(
        "{:<9} {:<34} {}",
        "scalar",
        "UNI_LORA_KERNELS=scalar, or auto",
        "bitwise: runs, thread counts, naive reference"
    );
    println!("{:<9} {:<34} {}", "", "  without avx2+fma", "");
    println!(
        "{:<9} {:<34} {}",
        "simd",
        "UNI_LORA_KERNELS=simd, or auto",
        "bitwise: runs, thread counts; ULP-tolerance vs scalar"
    );
    println!("{:<9} {:<34} {}", "", "  with avx2+fma", "");
    Ok(())
}

fn cmd_list(args: &Args) -> Result<()> {
    let exec = make_backend(args)?;
    for name in exec.artifact_names() {
        let a = exec.meta(&name)?;
        println!(
            "{name:<44} {:<14} d={:<8} D={:<8} P={}",
            a.kind,
            a.d,
            a.big_d,
            fmt_params(a.base_params)
        );
    }
    Ok(())
}
