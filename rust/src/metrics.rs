//! Evaluation metrics for every table: accuracy, Matthews correlation
//! (CoLA), Pearson correlation (STS-B), F1, exact-match, and the
//! MT-Bench-style 0-10 rubric scorer (the deterministic stand-in for
//! the paper's GPT-4 judge).

/// argmax over a logits row.
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, v) in row.iter().enumerate() {
        if *v > row[best] {
            best = i;
        }
    }
    best
}

/// Row-wise log-softmax in f64. Beam search accumulates sums of these
/// as beam scores; f64 with a fixed accumulation order keeps the
/// scores (and therefore beam selection) bit-stable across runs.
pub fn log_softmax(row: &[f32]) -> Vec<f64> {
    let mx = row.iter().fold(f64::NEG_INFINITY, |a, &b| a.max(b as f64));
    let z: f64 = row.iter().map(|&x| (x as f64 - mx).exp()).sum();
    let lz = z.ln() + mx;
    row.iter().map(|&x| x as f64 - lz).collect()
}

pub fn accuracy(pred: &[usize], gold: &[usize]) -> f64 {
    assert_eq!(pred.len(), gold.len());
    if pred.is_empty() {
        return 0.0;
    }
    let hit = pred.iter().zip(gold).filter(|(p, g)| p == g).count();
    hit as f64 / pred.len() as f64
}

/// Matthews correlation coefficient for binary labels.
pub fn matthews(pred: &[usize], gold: &[usize]) -> f64 {
    let (mut tp, mut tn, mut fp, mut fne) = (0f64, 0f64, 0f64, 0f64);
    for (&p, &g) in pred.iter().zip(gold) {
        match (p, g) {
            (1, 1) => tp += 1.0,
            (0, 0) => tn += 1.0,
            (1, 0) => fp += 1.0,
            (0, 1) => fne += 1.0,
            _ => {}
        }
    }
    let denom = ((tp + fp) * (tp + fne) * (tn + fp) * (tn + fne)).sqrt();
    if denom == 0.0 {
        0.0
    } else {
        (tp * tn - fp * fne) / denom
    }
}

/// Pearson correlation between two real-valued series.
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let mut sxy = 0f64;
    let mut sxx2 = 0f64;
    let mut syy2 = 0f64;
    for (a, b) in x.iter().zip(y) {
        sxy += (a - mx) * (b - my);
        sxx2 += (a - mx) * (a - mx);
        syy2 += (b - my) * (b - my);
    }
    if sxx2 == 0.0 || syy2 == 0.0 {
        0.0
    } else {
        sxy / (sxx2 * syy2).sqrt()
    }
}

/// Binary F1 (positive class = 1).
pub fn f1(pred: &[usize], gold: &[usize]) -> f64 {
    let tp = pred.iter().zip(gold).filter(|(&p, &g)| p == 1 && g == 1).count() as f64;
    let fp = pred.iter().zip(gold).filter(|(&p, &g)| p == 1 && g == 0).count() as f64;
    let fne = pred.iter().zip(gold).filter(|(&p, &g)| p == 0 && g == 1).count() as f64;
    if tp == 0.0 {
        return 0.0;
    }
    let prec = tp / (tp + fp);
    let rec = tp / (tp + fne);
    2.0 * prec * rec / (prec + rec)
}

/// Exact-match of a generated answer against the reference.
pub fn exact_match(generated: &[i32], reference: &[i32]) -> bool {
    generated.len() >= reference.len() && &generated[..reference.len()] == reference
}

/// MT-Bench-style rubric: 10 for exact match, else up to 8 by longest
/// common prefix fraction, plus 1 if the length matches — a fixed,
/// deterministic judge so *relative* method ordering is meaningful
/// (which is all Table 4 uses).
pub fn rubric_score(generated: &[i32], reference: &[i32]) -> f64 {
    if exact_match(generated, reference) {
        return 10.0;
    }
    if reference.is_empty() {
        return 0.0;
    }
    let prefix = generated
        .iter()
        .zip(reference)
        .take_while(|(a, b)| a == b)
        .count();
    let mut score = 8.0 * prefix as f64 / reference.len() as f64;
    if generated.len() >= reference.len() {
        // right length, partially wrong content
        let overlap = generated[..reference.len()]
            .iter()
            .zip(reference)
            .filter(|(a, b)| a == b)
            .count();
        score = score.max(6.0 * overlap as f64 / reference.len() as f64);
        score += 1.0;
    }
    score.min(9.5)
}

/// Dispatch a named metric over logits rows + float labels.
pub fn compute(metric: &str, logits: &[Vec<f32>], labels: &[f32]) -> f64 {
    match metric {
        "pearson" => {
            let x: Vec<f64> = logits.iter().map(|r| r[0] as f64).collect();
            let y: Vec<f64> = labels.iter().map(|&l| l as f64).collect();
            pearson(&x, &y)
        }
        _ => {
            let pred: Vec<usize> = logits.iter().map(|r| argmax(r)).collect();
            let gold: Vec<usize> = labels.iter().map(|&l| l as usize).collect();
            match metric {
                "acc" => accuracy(&pred, &gold),
                "matthews" => matthews(&pred, &gold),
                "f1" => f1(&pred, &gold),
                other => panic!("unknown metric {other:?}"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_softmax_normalizes_and_preserves_order() {
        let row = vec![1.0f32, 3.0, 2.0, -1.0];
        let lp = log_softmax(&row);
        let total: f64 = lp.iter().map(|&x| x.exp()).sum();
        assert!((total - 1.0).abs() < 1e-12, "probabilities must sum to 1: {total}");
        assert!(lp[1] > lp[2] && lp[2] > lp[0] && lp[0] > lp[3], "order preserved");
        // argmax of the logits row and of its log-softmax agree
        assert_eq!(argmax(&row), 1);
    }

    #[test]
    fn accuracy_basic() {
        assert_eq!(accuracy(&[1, 0, 1], &[1, 1, 1]), 2.0 / 3.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn matthews_known_values() {
        // perfect prediction -> 1; inverted -> -1; constant -> 0
        assert!((matthews(&[1, 0, 1, 0], &[1, 0, 1, 0]) - 1.0).abs() < 1e-12);
        assert!((matthews(&[0, 1, 0, 1], &[1, 0, 1, 0]) + 1.0).abs() < 1e-12);
        assert_eq!(matthews(&[1, 1, 1, 1], &[1, 0, 1, 0]), 0.0);
    }

    #[test]
    fn pearson_known_values() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let z = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&x, &z) + 1.0).abs() < 1e-12);
        assert_eq!(pearson(&x, &[1.0, 1.0, 1.0, 1.0]), 0.0);
    }

    #[test]
    fn f1_known() {
        // pred [1,1,0,0] gold [1,0,1,0]: tp=1 fp=1 fn=1 -> P=R=0.5 -> F1=0.5
        assert!((f1(&[1, 1, 0, 0], &[1, 0, 1, 0]) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn rubric_ordering() {
        let reference = [5, 6, 7];
        assert_eq!(rubric_score(&[5, 6, 7], &reference), 10.0);
        let close = rubric_score(&[5, 6, 9], &reference);
        let far = rubric_score(&[9, 9, 9], &reference);
        let empty = rubric_score(&[], &reference);
        assert!(close > far, "{close} vs {far}");
        assert!(far >= empty);
        assert!(close < 10.0);
    }

    #[test]
    fn exact_match_allows_trailing() {
        assert!(exact_match(&[1, 2, 3, 0], &[1, 2, 3]));
        assert!(!exact_match(&[1, 2], &[1, 2, 3]));
    }

    #[test]
    fn compute_dispatch() {
        let logits = vec![vec![0.1, 0.9], vec![0.8, 0.2]];
        assert_eq!(compute("acc", &logits, &[1.0, 0.0]), 1.0);
        let reg = vec![vec![1.0], vec![2.0], vec![3.0]];
        assert!((compute("pearson", &reg, &[2.0, 4.0, 6.0]) - 1.0).abs() < 1e-9);
    }
}
