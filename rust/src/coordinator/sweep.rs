//! Multi-seed / grid-search protocol: the paper reports the median over
//! 5 seeds with std (Table 2) after a per-task learning-rate grid
//! search (Appendix A.2). This module encodes that protocol once so
//! every table driver uses the same procedure.

use crate::util::{median, stddev};

/// Summary over seeds.
#[derive(Debug, Clone)]
pub struct SeedSummary {
    pub median: f64,
    pub std: f64,
    pub values: Vec<f64>,
}

/// Run `f(seed)` over seeds and summarize (median ± std, paper style).
pub fn over_seeds<F: FnMut(u64) -> anyhow::Result<f64>>(
    seeds: &[u64],
    mut f: F,
) -> anyhow::Result<SeedSummary> {
    let mut values = Vec::with_capacity(seeds.len());
    for &s in seeds {
        values.push(f(s)?);
    }
    Ok(SeedSummary { median: median(&values), std: stddev(&values), values })
}

/// Grid search: evaluate `f(lr)` on a holdout criterion and return the
/// best (lr, score).
pub fn grid_search<F: FnMut(f32) -> anyhow::Result<f64>>(
    grid: &[f32],
    mut f: F,
) -> anyhow::Result<(f32, f64)> {
    let mut best = (grid[0], f64::NEG_INFINITY);
    for &lr in grid {
        let v = f(lr)?;
        if v > best.1 {
            best = (lr, v);
        }
    }
    Ok(best)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeds_summary() {
        let s = over_seeds(&[1, 2, 3], |seed| Ok(seed as f64)).unwrap();
        assert_eq!(s.median, 2.0);
        assert!(s.std > 0.9 && s.std < 1.1);
    }

    #[test]
    fn grid_picks_max() {
        let (lr, v) = grid_search(&[1e-3, 1e-2, 1e-1], |lr| Ok(-((lr - 1e-2) as f64).abs())).unwrap();
        assert_eq!(lr, 1e-2);
        assert_eq!(v, 0.0);
    }
}
