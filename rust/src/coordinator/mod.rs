//! L3 coordinator: the training/evaluation orchestrator that drives the
//! AOT artifacts. Owns parameter/optimizer state between steps, feeds
//! synthetic data batches, runs evaluation + metrics, caches pretrained
//! backbones, and provides the multi-seed / grid-search protocol every
//! paper table uses.

pub mod backbone;
pub mod evaluator;
pub mod sweep;
pub mod trainer;

pub use backbone::pretrain_backbone;
pub use trainer::{ClsTrainer, Hyper, LmTrainer};

use crate::projection::statics::init_array;
use crate::rng;
use crate::runtime::ArtifactMeta;

/// Initialize the frozen backbone weights from the manifest layout.
pub fn init_base(meta: &ArtifactMeta, seed: u64) -> Vec<f32> {
    let mut w0 = Vec::with_capacity(meta.base_params);
    for (i, seg) in meta.base_segments.iter().enumerate() {
        let s = rng::child_seed(seed, rng::STREAM_BASE_INIT + 1000 * i as u64);
        w0.extend(init_array(&seg.init, seg.numel(), s).expect("init spec"));
    }
    debug_assert_eq!(w0.len(), meta.base_params);
    w0
}
