//! In-system "foundation models": pretrain each backbone size once on
//! the synthetic corpus (`pretrain_<size>` artifact) and cache the weights
//! under artifacts/backbones/. Every fine-tuning experiment then starts
//! from the same pretrained checkpoint — the stand-in for downloading
//! RoBERTa/Mistral (DESIGN.md §4).

use crate::coordinator::init_base;
use crate::data::corpus::CorpusBatches;
use crate::runtime::{Backend, TensorIn};
use anyhow::{Context, Result};
use std::path::PathBuf;

fn cache_path(exec: &dyn Backend, size: &str, seed: u64, steps: usize) -> PathBuf {
    exec.cache_dir()
        .join("backbones")
        .join(format!("{size}_s{seed}_n{steps}.f32"))
}

fn save_f32(path: &PathBuf, v: &[f32]) -> Result<()> {
    std::fs::create_dir_all(path.parent().unwrap())?;
    let mut bytes = Vec::with_capacity(4 * v.len());
    for x in v {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    std::fs::write(path, bytes).context("writing backbone cache")
}

fn load_f32(path: &PathBuf, n: usize) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path)?;
    anyhow::ensure!(bytes.len() == 4 * n, "backbone cache size mismatch");
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Pretrain (or load from cache) the `size` backbone. Returns
/// (weights, loss curve — empty when loaded from cache).
pub fn pretrain_backbone(
    exec: &mut dyn Backend,
    size: &str,
    seed: u64,
    steps: usize,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let art = format!("pretrain_{size}_pretrain_lm");
    let meta = exec.meta(&art)?.clone();
    let path = cache_path(exec, size, seed, steps);
    if path.exists() {
        return Ok((load_f32(&path, meta.base_params)?, Vec::new()));
    }
    let cfg = meta.cfg.clone();
    let mut w0 = init_base(&meta, seed);
    let mut m = vec![0f32; meta.base_params];
    let mut v = vec![0f32; meta.base_params];
    let mut corpus = CorpusBatches::new(seed.wrapping_add(17), cfg.batch, cfg.seq, cfg.vocab);
    let mut losses = Vec::with_capacity(steps);
    // linear warmup to 3e-3 then constant — a simple, stable recipe at
    // this scale; the e2e example logs this curve into EXPERIMENTS.md
    for step in 1..=steps {
        let (toks, labs) = corpus.next_batch();
        let lr = 3e-3f32 * (step as f32 / (steps as f32 * 0.1).max(1.0)).min(1.0);
        let out = exec.run(
            &art,
            &[
                TensorIn::F32(w0),
                TensorIn::F32(m),
                TensorIn::F32(v),
                TensorIn::ScalarI32(step as i32),
                TensorIn::ScalarF32(lr),
                TensorIn::ScalarF32(0.01),
                TensorIn::I32(toks),
                TensorIn::I32(labs),
            ],
        )?;
        let mut it = out.into_iter();
        w0 = it.next().unwrap().f32()?;
        m = it.next().unwrap().f32()?;
        v = it.next().unwrap().f32()?;
        losses.push(it.next().unwrap().scalar_f32()?);
    }
    save_f32(&path, &w0)?;
    Ok((w0, losses))
}

/// Default pretraining length: env UNI_LORA_PRETRAIN_STEPS or 300.
pub fn default_steps() -> usize {
    std::env::var("UNI_LORA_PRETRAIN_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(300)
}
