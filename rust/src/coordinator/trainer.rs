//! Trainers: drive the cls_train / lm_train artifacts step by step,
//! owning theta/optimizer/head state between executions.

use crate::config::ModelCfg;
use crate::data::batcher::{cls_batches, lm_batches, ClsBatch, LmBatch};
use crate::data::{ClsExample, LmExample};
use crate::projection::statics::{gen_statics, init_theta, Static};
use crate::runtime::{Backend, TensorIn};
use anyhow::{Context, Result};
use std::sync::Arc;
use std::time::Instant;

/// Hyperparameters for one run (paper Appendix A.2 analogues).
#[derive(Debug, Clone, Copy)]
pub struct Hyper {
    pub lr_theta: f32,
    pub lr_head: f32,
    pub wd: f32,
    pub epochs: usize,
}

impl Default for Hyper {
    fn default() -> Hyper {
        Hyper { lr_theta: 5e-3, lr_head: 5e-2, wd: 0.0, epochs: 3 }
    }
}

/// Result of one training run.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub losses: Vec<f32>,
    pub train_secs: f64,
    pub steps: usize,
}

/// Classification fine-tuning driver.
pub struct ClsTrainer {
    pub art_train: String,
    pub art_eval: String,
    pub cfg: ModelCfg,
    pub seed: u64,
    pub theta: Vec<f32>,
    pub head: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    hm: Vec<f32>,
    hv: Vec<f32>,
    pub w0: Vec<f32>,
    stats: Vec<Static>,
    step: i32,
    /// frozen inputs (w0, statics) uploaded once as device buffers
    pinned: bool,
}

impl ClsTrainer {
    /// `base`: artifact family name without the `_cls_train` suffix.
    pub fn new(exec: &dyn Backend, base: &str, seed: u64, w0: Vec<f32>) -> Result<ClsTrainer> {
        let art_train = format!("{base}_cls_train");
        let art_eval = format!("{base}_cls_eval");
        let meta = exec.meta(&art_train)?.clone();
        let cfg = meta.cfg.clone();
        let theta = init_theta(&cfg, seed)?;
        let stats = gen_statics(&cfg, seed)?;
        anyhow::ensure!(w0.len() == meta.base_params, "w0 size mismatch");
        Ok(ClsTrainer {
            art_train,
            art_eval,
            seed,
            theta: theta.clone(),
            head: vec![0f32; meta.head_params],
            m: vec![0f32; theta.len()],
            v: vec![0f32; theta.len()],
            hm: vec![0f32; meta.head_params],
            hv: vec![0f32; meta.head_params],
            w0,
            stats,
            step: 0,
            pinned: false,
            cfg,
        })
    }

    /// §Perf: upload the frozen inputs (w0 + statics) to the device once;
    /// every subsequent train step passes resident buffers instead of
    /// re-transferring them.
    pub fn pin_frozen(&mut self, exec: &mut dyn Backend) -> Result<()> {
        exec.prepare(&self.art_train)?;
        exec.pin(&self.art_train, "w0", &TensorIn::F32(self.w0.clone()))?;
        for s in &self.stats {
            exec.pin(&self.art_train, &s.name, &TensorIn::from(s))?;
        }
        self.pinned = true;
        Ok(())
    }

    pub fn train_step(&mut self, exec: &mut dyn Backend, b: &ClsBatch, hp: &Hyper) -> Result<f32> {
        self.step += 1;
        let labels = if self.cfg.n_classes == 1 {
            TensorIn::F32(b.labels_f.clone())
        } else {
            TensorIn::I32(b.labels_i.clone())
        };
        let mut inputs = vec![
            TensorIn::F32(std::mem::take(&mut self.theta)),
            TensorIn::F32(std::mem::take(&mut self.m)),
            TensorIn::F32(std::mem::take(&mut self.v)),
            TensorIn::F32(std::mem::take(&mut self.head)),
            TensorIn::F32(std::mem::take(&mut self.hm)),
            TensorIn::F32(std::mem::take(&mut self.hv)),
            TensorIn::ScalarI32(self.step),
            TensorIn::ScalarF32(hp.lr_theta),
            TensorIn::ScalarF32(hp.lr_head),
            TensorIn::ScalarF32(hp.wd),
            if self.pinned { TensorIn::Pinned } else { TensorIn::F32(self.w0.clone()) },
            TensorIn::I32(b.tokens.clone()),
            TensorIn::I32(b.attn_len.clone()),
            labels,
        ];
        if self.pinned {
            inputs.extend(self.stats.iter().map(|_| TensorIn::Pinned));
        } else {
            inputs.extend(self.stats.iter().map(TensorIn::from));
        }
        let mut out = exec
            .run(&self.art_train, &inputs)
            .with_context(|| format!("train step {}", self.step))?;
        let loss = out[6].scalar_f32()?;
        self.hv = out.remove(5).f32()?;
        self.hm = out.remove(4).f32()?;
        self.head = out.remove(3).f32()?;
        self.v = out.remove(2).f32()?;
        self.m = out.remove(1).f32()?;
        self.theta = out.remove(0).f32()?;
        Ok(loss)
    }

    /// Full training run over epochs of seeded-shuffled batches.
    pub fn train(
        &mut self,
        exec: &mut dyn Backend,
        examples: &[ClsExample],
        hp: &Hyper,
    ) -> Result<RunResult> {
        let t0 = Instant::now();
        let mut losses = Vec::new();
        for epoch in 0..hp.epochs {
            for b in cls_batches(examples, self.cfg.batch, self.seed, epoch as u64) {
                losses.push(self.train_step(exec, &b, hp)?);
            }
        }
        Ok(RunResult { steps: losses.len(), losses, train_secs: t0.elapsed().as_secs_f64() })
    }

    /// Dev-set logits (only `real` rows of each batch are kept).
    pub fn eval_logits(
        &mut self,
        exec: &mut dyn Backend,
        examples: &[ClsExample],
    ) -> Result<Vec<Vec<f32>>> {
        let c = self.cfg.n_classes.max(1);
        let mut rows = Vec::with_capacity(examples.len());
        for b in cls_batches(examples, self.cfg.batch, 0, 0) {
            let mut inputs = vec![
                TensorIn::F32(self.theta.clone()),
                TensorIn::F32(self.head.clone()),
                TensorIn::F32(self.w0.clone()),
                TensorIn::I32(b.tokens.clone()),
                TensorIn::I32(b.attn_len.clone()),
            ];
            inputs.extend(self.stats.iter().map(TensorIn::from));
            let out = exec.run(&self.art_eval, &inputs)?;
            let logits = out[0].as_f32()?;
            for k in 0..b.real {
                rows.push(logits[k * c..(k + 1) * c].to_vec());
            }
        }
        Ok(rows)
    }

    /// Train + evaluate one metric value.
    pub fn run_and_score(
        &mut self,
        exec: &mut dyn Backend,
        train: &[ClsExample],
        dev: &[ClsExample],
        metric: &str,
        hp: &Hyper,
    ) -> Result<(f64, RunResult)> {
        let rr = self.train(exec, train, hp)?;
        // eval-batch shuffling is seeded 0 — recover gold labels the same way
        let order = crate::data::batcher::shuffled_indices(dev.len(), 0, 0);
        let labels: Vec<f32> = order.iter().map(|&i| dev[i].label).collect();
        let logits = self.eval_logits(exec, dev)?;
        Ok((crate::metrics::compute(metric, &logits, &labels), rr))
    }
}

/// Full fine-tuning driver (Table 5 "FF"): the backbone itself is the
/// trainable vector; drives the full_cls_train artifact.
pub struct FullClsTrainer {
    pub art_train: String,
    pub art_eval: String,
    pub cfg: ModelCfg,
    pub seed: u64,
    pub w0: Vec<f32>,
    pub head: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    hm: Vec<f32>,
    hv: Vec<f32>,
    step: i32,
}

impl FullClsTrainer {
    /// `base`: e.g. "vit_base_full"; eval reuses the matching "none"
    /// adapter eval artifact (same signature, theta unused).
    pub fn new(exec: &dyn Backend, base: &str, eval_art: &str, seed: u64, w0: Vec<f32>) -> Result<FullClsTrainer> {
        let art_train = format!("{base}_full_cls_train");
        let meta = exec.meta(&art_train)?.clone();
        anyhow::ensure!(w0.len() == meta.base_params, "w0 size mismatch");
        Ok(FullClsTrainer {
            art_train,
            art_eval: eval_art.to_string(),
            cfg: meta.cfg.clone(),
            seed,
            m: vec![0f32; w0.len()],
            v: vec![0f32; w0.len()],
            hm: vec![0f32; meta.head_params],
            hv: vec![0f32; meta.head_params],
            head: vec![0f32; meta.head_params],
            w0,
            step: 0,
        })
    }

    pub fn train(
        &mut self,
        exec: &mut dyn Backend,
        examples: &[ClsExample],
        hp: &Hyper,
    ) -> Result<RunResult> {
        let t0 = Instant::now();
        let mut losses = Vec::new();
        for epoch in 0..hp.epochs {
            for b in cls_batches(examples, self.cfg.batch, self.seed, epoch as u64) {
                self.step += 1;
                let labels = if self.cfg.n_classes == 1 {
                    TensorIn::F32(b.labels_f.clone())
                } else {
                    TensorIn::I32(b.labels_i.clone())
                };
                let inputs = vec![
                    TensorIn::F32(std::mem::take(&mut self.w0)),
                    TensorIn::F32(std::mem::take(&mut self.m)),
                    TensorIn::F32(std::mem::take(&mut self.v)),
                    TensorIn::F32(std::mem::take(&mut self.head)),
                    TensorIn::F32(std::mem::take(&mut self.hm)),
                    TensorIn::F32(std::mem::take(&mut self.hv)),
                    TensorIn::ScalarI32(self.step),
                    TensorIn::ScalarF32(hp.lr_theta),
                    TensorIn::ScalarF32(hp.lr_head),
                    TensorIn::ScalarF32(hp.wd),
                    TensorIn::I32(b.tokens.clone()),
                    TensorIn::I32(b.attn_len.clone()),
                    labels,
                ];
                let mut out = exec.run(&self.art_train, &inputs)?;
                losses.push(out[6].scalar_f32()?);
                self.hv = out.remove(5).f32()?;
                self.hm = out.remove(4).f32()?;
                self.head = out.remove(3).f32()?;
                self.v = out.remove(2).f32()?;
                self.m = out.remove(1).f32()?;
                self.w0 = out.remove(0).f32()?;
            }
        }
        Ok(RunResult { steps: losses.len(), losses, train_secs: t0.elapsed().as_secs_f64() })
    }

    /// Evaluate via the paired "none"-method eval artifact (theta dummy).
    pub fn run_and_score(
        &mut self,
        exec: &mut dyn Backend,
        train: &[ClsExample],
        dev: &[ClsExample],
        metric: &str,
        hp: &Hyper,
    ) -> Result<(f64, RunResult)> {
        let rr = self.train(exec, train, hp)?;
        let c = self.cfg.n_classes.max(1);
        let mut rows = Vec::with_capacity(dev.len());
        for b in cls_batches(dev, self.cfg.batch, 0, 0) {
            let inputs = vec![
                TensorIn::F32(vec![0f32]), // dummy theta for method "none"
                TensorIn::F32(self.head.clone()),
                TensorIn::F32(self.w0.clone()),
                TensorIn::I32(b.tokens.clone()),
                TensorIn::I32(b.attn_len.clone()),
            ];
            let out = exec.run(&self.art_eval, &inputs)?;
            let logits = out[0].as_f32()?;
            for k in 0..b.real {
                rows.push(logits[k * c..(k + 1) * c].to_vec());
            }
        }
        let order = crate::data::batcher::shuffled_indices(dev.len(), 0, 0);
        let labels: Vec<f32> = order.iter().map(|&i| dev[i].label).collect();
        Ok((crate::metrics::compute(metric, &rows, &labels), rr))
    }
}

/// LM fine-tuning + greedy decoding driver.
pub struct LmTrainer {
    pub art_train: String,
    pub art_logits: String,
    pub cfg: ModelCfg,
    pub seed: u64,
    pub theta: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    /// Frozen backbone, Arc'd: decode sessions share it by refcount
    /// (stable identity keeps the reconstruction cache warm across
    /// `greedy_decode` calls) and the unpinned train path stops
    /// re-copying it every step.
    pub w0: Arc<Vec<f32>>,
    stats: Arc<Vec<Static>>,
    step: i32,
    pinned: bool,
}

impl LmTrainer {
    /// `base`: artifact family name without the `_lm_train` suffix.
    pub fn new(exec: &dyn Backend, base: &str, seed: u64, w0: Vec<f32>) -> Result<LmTrainer> {
        let art_train = format!("{base}_lm_train");
        let art_logits = format!("{base}_lm_logits");
        let meta = exec.meta(&art_train)?.clone();
        let cfg = meta.cfg.clone();
        let theta = init_theta(&cfg, seed)?;
        let stats = gen_statics(&cfg, seed)?;
        anyhow::ensure!(w0.len() == meta.base_params, "w0 size mismatch");
        Ok(LmTrainer {
            art_train,
            art_logits,
            seed,
            m: vec![0f32; theta.len()],
            v: vec![0f32; theta.len()],
            theta,
            w0: Arc::new(w0),
            stats: Arc::new(stats),
            step: 0,
            pinned: false,
            cfg,
        })
    }

    /// §Perf: see ClsTrainer::pin_frozen.
    pub fn pin_frozen(&mut self, exec: &mut dyn Backend) -> Result<()> {
        exec.prepare(&self.art_train)?;
        exec.pin(&self.art_train, "w0", &TensorIn::SharedF32(self.w0.clone()))?;
        for s in self.stats.iter() {
            exec.pin(&self.art_train, &s.name, &TensorIn::from(s))?;
        }
        self.pinned = true;
        Ok(())
    }

    pub fn train_step(&mut self, exec: &mut dyn Backend, b: &LmBatch, hp: &Hyper) -> Result<f32> {
        self.step += 1;
        let mut inputs = vec![
            TensorIn::F32(std::mem::take(&mut self.theta)),
            TensorIn::F32(std::mem::take(&mut self.m)),
            TensorIn::F32(std::mem::take(&mut self.v)),
            TensorIn::ScalarI32(self.step),
            TensorIn::ScalarF32(hp.lr_theta),
            TensorIn::ScalarF32(hp.wd),
            if self.pinned { TensorIn::Pinned } else { TensorIn::SharedF32(self.w0.clone()) },
            TensorIn::I32(b.tokens.clone()),
            TensorIn::I32(b.labels.clone()),
        ];
        if self.pinned {
            inputs.extend(self.stats.iter().map(|_| TensorIn::Pinned));
        } else {
            inputs.extend(self.stats.iter().map(TensorIn::from));
        }
        let mut out = exec.run(&self.art_train, &inputs)?;
        let loss = out[3].scalar_f32()?;
        self.v = out.remove(2).f32()?;
        self.m = out.remove(1).f32()?;
        self.theta = out.remove(0).f32()?;
        Ok(loss)
    }

    pub fn train(
        &mut self,
        exec: &mut dyn Backend,
        examples: &[LmExample],
        hp: &Hyper,
    ) -> Result<RunResult> {
        let t0 = Instant::now();
        let mut losses = Vec::new();
        for epoch in 0..hp.epochs {
            for b in lm_batches(examples, self.cfg.batch, self.seed, epoch as u64) {
                losses.push(self.train_step(exec, &b, hp)?);
            }
        }
        Ok(RunResult { steps: losses.len(), losses, train_secs: t0.elapsed().as_secs_f64() })
    }

    /// Batched greedy decoding: prompts (token prefixes) -> generations
    /// of up to `max_new` tokens (stopping per-sequence at EOS).
    /// Routed through the decode-session subsystem: on the native
    /// backend this runs KV-cache incremental steps (O(model) per
    /// token); other backends fall back to full forwards via
    /// `Backend::run`. Token streams match the legacy full-forward
    /// loop exactly (`tests/decode_parity.rs`).
    pub fn greedy_decode(
        &mut self,
        exec: &mut dyn Backend,
        prompts: &[Vec<i32>],
        max_new: usize,
    ) -> Result<Vec<Vec<i32>>> {
        self.sampled_decode(exec, prompts, max_new, &crate::generation::SamplingParams::default())
    }

    /// [`LmTrainer::greedy_decode`] generalized to any
    /// [`crate::generation::SamplingParams`] — greedy is the
    /// default-params special case of the same session path. Prompt `k`
    /// samples from `child_seed(sampling.seed, k)`, so a given
    /// (prompts, params) pair replays bit-identical streams.
    pub fn sampled_decode(
        &mut self,
        exec: &mut dyn Backend,
        prompts: &[Vec<i32>],
        max_new: usize,
        sampling: &crate::generation::SamplingParams,
    ) -> Result<Vec<Vec<i32>>> {
        crate::session::decode_sampled(
            exec,
            &self.art_logits,
            &format!("{}#seed{}", self.art_logits, self.seed),
            Arc::new(self.theta.clone()),
            self.w0.clone(),
            self.stats.clone(),
            prompts,
            max_new,
            sampling,
            &crate::session::SessionOpts::from_env(),
        )
    }

    /// Eval-time beam search (`width` beams per prompt) over full
    /// forwards — see [`crate::generation::beam`]. Width 1 reproduces
    /// the greedy stream exactly.
    pub fn beam_decode(
        &mut self,
        exec: &mut dyn Backend,
        prompts: &[Vec<i32>],
        max_new: usize,
        width: usize,
    ) -> Result<Vec<Vec<i32>>> {
        crate::generation::beam::beam_decode_with(
            exec,
            &self.art_logits,
            &self.cfg,
            &self.theta,
            &self.w0,
            &self.stats,
            prompts,
            max_new,
            width,
        )
    }
}

/// Greedy decode via one full `[B, T]` forward per token — the legacy
/// pre-session loop, retained as the golden reference the parity suite
/// (`tests/decode_parity.rs`) holds the session implementations to,
/// and as the measured baseline in `benches/serving.rs`.
#[allow(clippy::too_many_arguments)]
pub fn decode_with(
    exec: &mut dyn Backend,
    art_logits: &str,
    cfg: &ModelCfg,
    theta: &[f32],
    w0: &[f32],
    stats: &[Static],
    prompts: &[Vec<i32>],
    max_new: usize,
) -> Result<Vec<Vec<i32>>> {
    use crate::data::vocab;
    let (bsz, t, vocab_n) = (cfg.batch, cfg.seq, cfg.vocab);
    let mut outputs: Vec<Vec<i32>> = vec![Vec::new(); prompts.len()];
    // §Perf: the frozen inputs are wrapped as shared tensors ONCE —
    // the per-step `clone()` below bumps refcounts instead of
    // re-copying theta, the whole backbone and the statics for every
    // generated token (the old `to_vec()`-per-step allocation bug).
    let theta_in = TensorIn::SharedF32(Arc::new(theta.to_vec()));
    let w0_in = TensorIn::SharedF32(Arc::new(w0.to_vec()));
    let stat_ins: Vec<TensorIn> = stats.iter().map(TensorIn::shared_from).collect();
    for group in (0..prompts.len()).collect::<Vec<_>>().chunks(bsz) {
        let mut toks = vec![vocab::PAD; bsz * t];
        let mut lens = vec![0usize; bsz];
        for (row, &pi) in group.iter().enumerate() {
            let p = &prompts[pi];
            let l = p.len().min(t);
            toks[row * t..row * t + l].copy_from_slice(&p[..l]);
            lens[row] = l;
        }
        let mut done = vec![false; group.len()];
        for _ in 0..max_new {
            if done.iter().all(|&d| d) {
                break;
            }
            let mut inputs = vec![theta_in.clone(), w0_in.clone(), TensorIn::I32(toks.clone())];
            inputs.extend(stat_ins.iter().cloned());
            let out = exec.run(art_logits, &inputs)?;
            let logits = out[0].as_f32()?; // [B, T, V]
            for (row, &pi) in group.iter().enumerate() {
                if done[row] || lens[row] >= t {
                    done[row] = true;
                    continue;
                }
                let pos = lens[row] - 1;
                let slice = &logits[(row * t + pos) * vocab_n..(row * t + pos + 1) * vocab_n];
                let next = crate::metrics::argmax(slice) as i32;
                if next == vocab::EOS {
                    done[row] = true;
                    continue;
                }
                toks[row * t + lens[row]] = next;
                lens[row] += 1;
                outputs[pi].push(next);
            }
        }
    }
    Ok(outputs)
}
