//! Generation-based evaluation: exact-match accuracy (GSM8K/MATH-like)
//! and MT-Bench-style rubric scores, via batched decoding under a
//! selectable [`DecodeMode`] — greedy (the default and the paper's
//! protocol), seeded sampling, or beam search.

use crate::coordinator::trainer::LmTrainer;
use crate::data::LmExample;
use crate::generation::SamplingParams;
use crate::metrics;
use crate::runtime::Backend;
use anyhow::Result;

/// How the eval harness decodes. Every mode is deterministic: greedy
/// and beam by construction, sampling through the seeded draw streams.
#[derive(Debug, Clone, PartialEq)]
pub enum DecodeMode {
    /// Batched greedy decoding (the paper's protocol).
    Greedy,
    /// Seeded sampling; prompt `k` draws from `child_seed(seed, k)`.
    Sampled(SamplingParams),
    /// Beam search with this width; `0` = resolve from
    /// `UNI_LORA_BEAM_WIDTH` (default
    /// [`crate::config::DEFAULT_BEAM_WIDTH`]).
    Beam(usize),
}

impl DecodeMode {
    fn decode(
        &self,
        trainer: &mut LmTrainer,
        exec: &mut dyn Backend,
        prompts: &[Vec<i32>],
        max_new: usize,
    ) -> Result<Vec<Vec<i32>>> {
        match self {
            DecodeMode::Greedy => trainer.greedy_decode(exec, prompts, max_new),
            DecodeMode::Sampled(p) => trainer.sampled_decode(exec, prompts, max_new, p),
            DecodeMode::Beam(w) => {
                let w = if *w == 0 { crate::config::RuntimeOpts::from_env().beam_width } else { *w };
                trainer.beam_decode(exec, prompts, max_new, w)
            }
        }
    }
}

/// Exact-match accuracy over a dev split: decode from each prompt and
/// require the full reference answer as a prefix of the generation.
pub fn exact_match_accuracy(
    trainer: &mut LmTrainer,
    exec: &mut dyn Backend,
    dev: &[LmExample],
    max_new: usize,
) -> Result<f64> {
    exact_match_accuracy_with(trainer, exec, dev, max_new, &DecodeMode::Greedy)
}

/// [`exact_match_accuracy`] under an explicit [`DecodeMode`] (beam
/// search for the math harness, sampled for robustness sweeps).
pub fn exact_match_accuracy_with(
    trainer: &mut LmTrainer,
    exec: &mut dyn Backend,
    dev: &[LmExample],
    max_new: usize,
    mode: &DecodeMode,
) -> Result<f64> {
    let prompts: Vec<Vec<i32>> = dev.iter().map(|e| e.tokens[..e.prompt_len].to_vec()).collect();
    let gens = mode.decode(trainer, exec, &prompts, max_new)?;
    let hits = gens
        .iter()
        .zip(dev)
        .filter(|(g, e)| metrics::exact_match(g, &e.answer))
        .count();
    Ok(100.0 * hits as f64 / dev.len().max(1) as f64)
}

/// Mean rubric score (0-10) over a dev split — the Table 4 judge.
pub fn rubric_score(
    trainer: &mut LmTrainer,
    exec: &mut dyn Backend,
    dev: &[LmExample],
    max_new: usize,
) -> Result<f64> {
    rubric_score_with(trainer, exec, dev, max_new, &DecodeMode::Greedy)
}

/// [`rubric_score`] under an explicit [`DecodeMode`].
pub fn rubric_score_with(
    trainer: &mut LmTrainer,
    exec: &mut dyn Backend,
    dev: &[LmExample],
    max_new: usize,
    mode: &DecodeMode,
) -> Result<f64> {
    let prompts: Vec<Vec<i32>> = dev.iter().map(|e| e.tokens[..e.prompt_len].to_vec()).collect();
    let gens = mode.decode(trainer, exec, &prompts, max_new)?;
    let total: f64 = gens
        .iter()
        .zip(dev)
        .map(|(g, e)| metrics::rubric_score(g, &e.answer))
        .sum();
    Ok(total / dev.len().max(1) as f64)
}
