//! Generation-based evaluation: exact-match accuracy (GSM8K/MATH-like)
//! and MT-Bench-style rubric scores, via batched greedy decoding.

use crate::coordinator::trainer::LmTrainer;
use crate::data::LmExample;
use crate::metrics;
use crate::runtime::Backend;
use anyhow::Result;

/// Exact-match accuracy over a dev split: decode from each prompt and
/// require the full reference answer as a prefix of the generation.
pub fn exact_match_accuracy(
    trainer: &mut LmTrainer,
    exec: &mut dyn Backend,
    dev: &[LmExample],
    max_new: usize,
) -> Result<f64> {
    let prompts: Vec<Vec<i32>> = dev.iter().map(|e| e.tokens[..e.prompt_len].to_vec()).collect();
    let gens = trainer.greedy_decode(exec, &prompts, max_new)?;
    let hits = gens
        .iter()
        .zip(dev)
        .filter(|(g, e)| metrics::exact_match(g, &e.answer))
        .count();
    Ok(100.0 * hits as f64 / dev.len().max(1) as f64)
}

/// Mean rubric score (0-10) over a dev split — the Table 4 judge.
pub fn rubric_score(
    trainer: &mut LmTrainer,
    exec: &mut dyn Backend,
    dev: &[LmExample],
    max_new: usize,
) -> Result<f64> {
    let prompts: Vec<Vec<i32>> = dev.iter().map(|e| e.tokens[..e.prompt_len].to_vec()).collect();
    let gens = trainer.greedy_decode(exec, &prompts, max_new)?;
    let total: f64 = gens
        .iter()
        .zip(dev)
        .map(|(g, e)| metrics::rubric_score(g, &e.answer))
        .sum();
    Ok(total / dev.len().max(1) as f64)
}
