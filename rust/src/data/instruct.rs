//! Instruction-tuning tasks (Table 4 substitution for Cleaned-Alpaca /
//! MT-Bench). Each example is an instruction opcode applied to an
//! argument list; the reference answer is computable, so the Rust-side
//! rubric scorer (metrics::rubric_score) plays the role of the GPT-4
//! judge with a deterministic 0-10 scale.
//!
//! Single-turn tasks exercise instruction following; two-turn tasks
//! (OP_MAP then OP_PICK) require carrying context across turns — the
//! Score_2 column.

use super::vocab;
use super::{LmExample, LmSplit};
use crate::rng::{self, Stream};

/// Single-turn instruction: `BOS op args ARROW answer EOS`.
pub fn single_turn(s: &mut Stream, seq: usize) -> LmExample {
    let n_args = 3 + s.next_index(4);
    let args: Vec<i32> = (0..n_args)
        .map(|_| vocab::WORD0 + s.next_index(64) as i32)
        .collect();
    let op = [vocab::OP_COPY, vocab::OP_REVERSE, vocab::OP_LAST,
              vocab::OP_SORT, vocab::OP_COUNT, vocab::OP_MATH][s.next_index(6)];
    let (prompt_args, answer): (Vec<i32>, Vec<i32>) = match op {
        vocab::OP_COPY => (args.clone(), args.clone()),
        vocab::OP_REVERSE => (args.clone(), args.iter().rev().cloned().collect()),
        vocab::OP_LAST => (args.clone(), vec![*args.last().unwrap()]),
        vocab::OP_SORT => {
            let mut a = args.clone();
            a.sort();
            (args.clone(), a)
        }
        vocab::OP_COUNT => {
            // count occurrences of the first arg in the rest
            let target = args[0];
            let rest: Vec<i32> = (0..5)
                .map(|_| if s.next_f64() < 0.4 { target } else { vocab::WORD0 + s.next_index(64) as i32 })
                .collect();
            let cnt = rest.iter().filter(|&&x| x == target).count() as u64;
            let mut p = vec![target, vocab::COLON];
            p.extend(&rest);
            (p, vocab::encode_number(cnt))
        }
        _ => {
            // OP_MATH: a + b
            let a = s.next_index(50) as u64;
            let b = s.next_index(50) as u64;
            let mut p = vocab::encode_number(a);
            p.push(vocab::PLUS);
            p.extend(vocab::encode_number(b));
            (p, vocab::encode_number(a + b))
        }
    };
    build_example(&[(op, prompt_args, answer)], seq)
}

/// Two-turn dialogue: turn 1 defines a key->value map, turn 2 queries a
/// key. The answer to turn 2 depends on turn-1 context.
pub fn two_turn(s: &mut Stream, seq: usize) -> LmExample {
    let n_pairs = 2 + s.next_index(2);
    let keys: Vec<i32> = (0..n_pairs).map(|i| vocab::WORD0 + 2 * i as i32).collect();
    let vals: Vec<i32> = (0..n_pairs)
        .map(|_| vocab::WORD0 + 64 + s.next_index(64) as i32)
        .collect();
    let mut t1_args = Vec::new();
    for i in 0..n_pairs {
        t1_args.push(keys[i]);
        t1_args.push(vocab::COLON);
        t1_args.push(vals[i]);
    }
    let q = s.next_index(n_pairs);
    // turn 1 answer: acknowledge by repeating the values
    let t1_answer = vals.clone();
    let t2_answer = vec![vals[q]];
    build_example(
        &[
            (vocab::OP_MAP, t1_args, t1_answer),
            (vocab::OP_PICK, vec![keys[q]], t2_answer),
        ],
        seq,
    )
}

/// Assemble turns into tokens/labels. Labels cover each turn's answer
/// (+EOS); `answer` holds the final turn's reference; prompt_len is the
/// position right after the final ARROW (generation start for eval).
fn build_example(turns: &[(i32, Vec<i32>, Vec<i32>)], seq: usize) -> LmExample {
    let mut toks = vec![vocab::BOS];
    let mut spans = Vec::new(); // (answer_start, answer_end) per turn
    for (k, (op, args, answer)) in turns.iter().enumerate() {
        if k > 0 {
            toks.push(vocab::TURN);
        }
        toks.push(*op);
        toks.extend(args);
        toks.push(vocab::ARROW);
        let start = toks.len();
        toks.extend(answer);
        toks.push(vocab::EOS);
        spans.push((start, toks.len()));
    }
    let (final_start, _) = *spans.last().unwrap();
    let prompt_len = final_start;
    let answer = turns.last().unwrap().2.clone();

    toks.truncate(seq);
    let attn = toks.len();
    toks.resize(seq, vocab::PAD);
    let mut labels = vec![-1i32; seq];
    for (start, end) in spans {
        let end = end.min(attn);
        if start == 0 || start > end {
            continue;
        }
        for pos in (start - 1)..(end - 1).min(seq - 1) {
            labels[pos] = toks[pos + 1];
        }
    }
    LmExample { tokens: toks, labels, prompt_len, answer }
}

/// Training set mixes single- and two-turn; dev is split by turn count
/// (Score_1 = single, Score_2 = multi).
pub fn generate(seed: u64, seq: usize, n_train: usize, n_dev: usize) -> (LmSplit, Vec<LmExample>) {
    let mut s = Stream::child(rng::child_seed(seed, rng::STREAM_DATA), 60);
    let train = (0..n_train)
        .map(|i| if i % 3 == 2 { two_turn(&mut s, seq) } else { single_turn(&mut s, seq) })
        .collect();
    let dev1: Vec<LmExample> = (0..n_dev).map(|_| single_turn(&mut s, seq)).collect();
    let dev2: Vec<LmExample> = (0..n_dev).map(|_| two_turn(&mut s, seq)).collect();
    (LmSplit { train, dev: dev1 }, dev2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_turn_valid() {
        let mut s = Stream::new(1);
        for _ in 0..100 {
            let ex = single_turn(&mut s, 64);
            assert_eq!(ex.tokens.len(), 64);
            assert!(!ex.answer.is_empty());
            assert_eq!(ex.tokens[ex.prompt_len - 1], vocab::ARROW);
            // answer tokens appear right after prompt
            for (i, &a) in ex.answer.iter().enumerate() {
                assert_eq!(ex.tokens[ex.prompt_len + i], a);
            }
        }
    }

    #[test]
    fn two_turn_has_turn_marker_and_context_dependence() {
        let mut s = Stream::new(2);
        for _ in 0..50 {
            let ex = two_turn(&mut s, 64);
            assert!(ex.tokens.contains(&vocab::TURN));
            assert_eq!(ex.answer.len(), 1);
            // the queried value must occur in turn 1
            let t1: Vec<i32> = ex.tokens[..ex.prompt_len].to_vec();
            assert!(t1.contains(&ex.answer[0]));
        }
    }

    #[test]
    fn labels_only_on_answers() {
        let mut s = Stream::new(3);
        let ex = single_turn(&mut s, 64);
        // positions before ARROW-1 must be masked
        assert!(ex.labels[..ex.prompt_len - 1].iter().all(|&l| l == -1));
        assert!(ex.labels.iter().any(|&l| l >= 0));
    }

    #[test]
    fn generate_deterministic() {
        let (a, a2) = generate(5, 64, 30, 10);
        let (b, b2) = generate(5, 64, 30, 10);
        assert_eq!(a.train[0].tokens, b.train[0].tokens);
        assert_eq!(a2[0].tokens, b2[0].tokens);
    }
}
