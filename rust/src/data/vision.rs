//! Vision tasks (Table 5 substitution): 8 synthetic "datasets" of
//! patch-token images. An image is a 4x4 grid of patch tokens (a
//! VQ-style tokenization of a ViT's patch embedding); each class has a
//! signature set of patch tokens placed at class-dependent positions,
//! with dataset-specific noise/distractor levels that induce the same
//! difficulty ordering as the paper's suite (CIFAR10/EuroSAT easy,
//! StanfordCars/FGVC hard).

use super::vocab;
use super::{ClsExample, ClsSplit};
use crate::rng::{self, Stream};

pub const DATASETS: [&str; 8] = [
    "oxford_pets", "stanford_cars", "cifar10", "dtd",
    "eurosat", "fgvc", "resisc45", "cifar100",
];

/// (n_classes, noise, signature_patches, train_size)
fn spec(ds: &str) -> (usize, f64, usize, usize) {
    match ds {
        "oxford_pets" => (10, 0.30, 5, 1200),
        "stanford_cars" => (10, 0.55, 3, 1200),
        "cifar10" => (10, 0.15, 6, 2000),
        "dtd" => (10, 0.40, 4, 1000),
        "eurosat" => (10, 0.15, 6, 1600),
        "fgvc" => (10, 0.65, 3, 1000),
        "resisc45" => (10, 0.30, 5, 1600),
        "cifar100" => (10, 0.35, 4, 2000),
        _ => panic!("unknown vision dataset {ds:?}"),
    }
}

const GRID: usize = 16; // 4x4 patches

pub fn generate(ds: &str, seed: u64, seq: usize, vocab_size: usize) -> ClsSplit {
    let (n_classes, noise, sig, n_train) = spec(ds);
    let ds_id = DATASETS.iter().position(|d| *d == ds).unwrap() as u64;
    let mut s = Stream::child(rng::child_seed(seed, rng::STREAM_DATA), 70 + ds_id);
    // class signatures: per class, `sig` (position, token) pairs
    let n_patch_tokens = vocab_size - vocab::WORD0 as usize;
    let sigs: Vec<Vec<(usize, i32)>> = (0..n_classes)
        .map(|_| {
            (0..sig)
                .map(|_| {
                    (
                        s.next_index(GRID),
                        vocab::WORD0 + s.next_index(n_patch_tokens) as i32,
                    )
                })
                .collect()
        })
        .collect();
    let gen = |s: &mut Stream| -> ClsExample {
        let label = s.next_index(n_classes);
        let mut patches: Vec<i32> = (0..GRID)
            .map(|_| vocab::WORD0 + s.next_index(n_patch_tokens) as i32)
            .collect();
        for &(pos, tok) in &sigs[label] {
            if s.next_f64() >= noise {
                patches[pos] = tok;
            }
        }
        let mut toks = vec![vocab::BOS];
        toks.extend(&patches);
        toks.truncate(seq);
        let attn = toks.len();
        toks.resize(seq, vocab::PAD);
        ClsExample { tokens: toks, attn_len: attn, label: label as f32 }
    };
    let train = (0..n_train).map(|_| gen(&mut s)).collect();
    let dev = (0..300).map(|_| gen(&mut s)).collect();
    ClsSplit { train, dev, metric: "acc", n_classes }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_datasets_generate() {
        for ds in DATASETS {
            let split = generate(ds, 42, 32, 512);
            assert!(split.train.len() >= 1000, "{ds}");
            assert_eq!(split.dev.len(), 300);
            for ex in split.train.iter().take(20) {
                assert_eq!(ex.tokens.len(), 32);
                assert_eq!(ex.attn_len, 1 + GRID);
                assert!((ex.label as usize) < split.n_classes);
            }
        }
    }

    #[test]
    fn difficulty_ordering_easy_vs_hard() {
        // easy dataset images carry more intact signature patches
        let count_sig = |ds: &str| -> f64 {
            let (_, noise, sig, _) = spec(ds);
            (1.0 - noise) * sig as f64
        };
        assert!(count_sig("cifar10") > count_sig("fgvc"));
        assert!(count_sig("eurosat") > count_sig("stanford_cars"));
    }

    #[test]
    fn class_balance() {
        let split = generate("cifar10", 3, 32, 512);
        let mut counts = vec![0usize; split.n_classes];
        for ex in &split.train {
            counts[ex.label as usize] += 1;
        }
        let mean = split.train.len() / split.n_classes;
        assert!(counts.iter().all(|&c| c > mean / 2 && c < mean * 2));
    }
}
