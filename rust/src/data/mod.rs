//! Synthetic data substrate (DESIGN.md §4 substitutions).
//!
//! Everything is generated deterministically from SplitMix64 streams, so
//! every experiment is exactly reproducible from its seed. Token space
//! is shared across tasks (`vocab`): a small structured "language" with
//! word clusters, digits and operator symbols, so that one pretrained
//! backbone transfers to all downstream tasks — mirroring how the
//! paper's RoBERTa/Mistral backbones serve GLUE/math/instruct.

pub mod batcher;
pub mod corpus;
pub mod glue;
pub mod instruct;
pub mod math_tasks;
pub mod vision;
pub mod vocab;

/// A classification / regression example.
#[derive(Debug, Clone)]
pub struct ClsExample {
    pub tokens: Vec<i32>,
    pub attn_len: usize,
    /// class id for C>=2 tasks; graded score for regression tasks
    pub label: f32,
}

/// An LM example: full token sequence + per-position labels
/// (-1 = masked / prompt / padding).
#[derive(Debug, Clone)]
pub struct LmExample {
    pub tokens: Vec<i32>,
    pub labels: Vec<i32>,
    /// prompt prefix length (for generation-style eval)
    pub prompt_len: usize,
    /// reference answer tokens (for exact-match scoring)
    pub answer: Vec<i32>,
}

/// A labelled dataset split.
#[derive(Debug, Clone)]
pub struct ClsSplit {
    pub train: Vec<ClsExample>,
    pub dev: Vec<ClsExample>,
    /// metric to report: "acc" | "matthews" | "pearson" | "f1"
    pub metric: &'static str,
    pub n_classes: usize,
}

#[derive(Debug, Clone)]
pub struct LmSplit {
    pub train: Vec<LmExample>,
    pub dev: Vec<LmExample>,
}
