//! GLUE-like synthetic task suite (DESIGN.md §4): six tasks matching the
//! *shape* of the paper's GLUE subset — single vs paired sentences,
//! binary vs graded labels, large vs tiny training sets, and the
//! metric each reports (Table 2).

use super::vocab;
use super::{ClsExample, ClsSplit};
use crate::rng::{self, Stream};

/// The six tasks of Table 2.
pub const TASKS: [&str; 6] = ["sst2", "mrpc", "cola", "qnli", "rte", "stsb"];

/// Per-task metric (paper: Matthews for CoLA, Pearson for STS-B,
/// accuracy otherwise).
pub fn metric_for(task: &str) -> &'static str {
    match task {
        "cola" => "matthews",
        "stsb" => "pearson",
        _ => "acc",
    }
}

pub fn n_classes_for(task: &str) -> usize {
    if task == "stsb" { 1 } else { 2 }
}

/// Dataset sizes mirror GLUE's relative scale (RTE/MRPC small -> higher
/// variance, exactly the effect the paper notes on RTE).
fn sizes(task: &str) -> (usize, usize) {
    match task {
        "sst2" => (4000, 400),
        "mrpc" => (600, 200),
        "cola" => (1600, 400),
        "qnli" => (4000, 400),
        "rte" => (400, 150),
        "stsb" => (1200, 300),
        _ => (1000, 200),
    }
}

/// Label-noise rate per task: sets a Bayes ceiling below 100% so scores
/// land in the paper's range and methods can separate (harder tasks =
/// more noise, mirroring GLUE's difficulty spread).
fn label_noise(task: &str) -> f64 {
    match task {
        "sst2" => 0.03,
        "mrpc" => 0.07,
        "cola" => 0.10,
        "qnli" => 0.05,
        "rte" => 0.12,
        "stsb" => 0.0, // stsb gets additive score noise instead
        _ => 0.05,
    }
}

pub fn generate(task: &str, seed: u64, seq: usize, vocab_size: usize) -> ClsSplit {
    let (n_train, n_dev) = sizes(task);
    let mut s = Stream::child(rng::child_seed(seed, rng::STREAM_DATA), task_id(task));
    let p_noise = label_noise(task);
    let gen = |s: &mut Stream| {
        let mut ex = example(task, s, seq, vocab_size);
        if task == "stsb" {
            ex.label = (ex.label + (s.next_f64() as f32 - 0.5) * 0.8).clamp(0.0, 4.0);
        } else if s.next_f64() < p_noise {
            ex.label = 1.0 - ex.label; // binary flip
        }
        ex
    };
    let train = (0..n_train).map(|_| gen(&mut s)).collect();
    let dev = (0..n_dev).map(|_| gen(&mut s)).collect();
    ClsSplit { train, dev, metric: metric_for(task), n_classes: n_classes_for(task) }
}

fn task_id(task: &str) -> u64 {
    1 + TASKS.iter().position(|t| *t == task).expect("unknown task") as u64
}

fn pad_to(mut toks: Vec<i32>, seq: usize) -> (Vec<i32>, usize) {
    toks.truncate(seq);
    let attn = toks.len();
    toks.resize(seq, vocab::PAD);
    (toks, attn)
}

fn words_from(s: &mut Stream, cluster: usize, n: usize) -> Vec<i32> {
    (0..n)
        .map(|_| vocab::cluster_base(cluster) + s.next_index(vocab::CLUSTER as usize) as i32)
        .collect()
}

fn example(task: &str, s: &mut Stream, seq: usize, vocab_size: usize) -> ClsExample {
    let nc = vocab::n_clusters(vocab_size);
    match task {
        // Sentiment: positive cluster (0/1) vs negative cluster (2/3)
        // words dominate a noisy sentence.
        "sst2" => {
            let label = s.next_index(2);
            let len = 8 + s.next_index(8);
            let mut toks = vec![vocab::BOS];
            for _ in 0..len {
                let signal = s.next_f64() < 0.65;
                let c = if signal {
                    2 * label + s.next_index(2)
                } else {
                    4 + s.next_index(nc - 4) // neutral clusters
                };
                toks.extend(words_from(s, c, 1));
            }
            let (tokens, attn_len) = pad_to(toks, seq);
            ClsExample { tokens, attn_len, label: label as f32 }
        }
        // Paraphrase: paraphrase pairs share the same lexical register
        // ("side"): clusters are split into two registers; paraphrases
        // draw both sentences from one register, non-paraphrases mix
        // registers. Register mass is a pooled-linear signal the MiniLM
        // backbone can exploit (DESIGN.md §4).
        "mrpc" => {
            let label = s.next_index(2);
            let len = 6 + s.next_index(5);
            let half = nc / 2;
            let side1 = 0; // premise register is fixed ("formal side"):
                           // the label is then linear in s2's register mass
            let _ = s.next_index(2); // keep stream alignment
            let k1 = s.next_index(half);
            let c1 = side1 * half + k1;
            let side2 = if label == 1 { side1 } else { 1 - side1 };
            let k2 = s.next_index(half);
            let c2 = side2 * half + k2;
            let s1 = words_from(s, c1, len);
            let s2 = words_from(s, c2, len);
            let mut toks = vec![vocab::BOS];
            toks.extend(&s1);
            toks.push(vocab::SEP);
            toks.extend(&s2);
            let (tokens, attn_len) = pad_to(toks, seq);
            ClsExample { tokens, attn_len, label: label as f32 }
        }
        // Acceptability: "grammatical" sentences alternate the two fixed
        // function-word clusters evenly; violations replace a third of
        // the odd-position words, skewing the cluster balance.
        "cola" => {
            let label = s.next_index(2);
            let len = 9 + s.next_index(6);
            let mut toks = vec![vocab::BOS];
            for i in 0..len {
                let c = 12 + (i % 2);
                toks.extend(words_from(s, c, 1));
            }
            if label == 0 {
                for k in 1..len {
                    if k % 3 == 0 {
                        toks[k + 1] =
                            vocab::cluster_base(12) + s.next_index(vocab::CLUSTER as usize) as i32;
                    }
                }
            }
            let (tokens, attn_len) = pad_to(toks, seq);
            ClsExample { tokens, attn_len, label: label as f32 }
        }
        // QA inference: entailed passages carry the answer span — the
        // query word flanked by the A_MARKER token; non-entailed
        // passages mention related words but no answer span.
        "qnli" => {
            let label = s.next_index(2);
            let topic = s.next_index(nc);
            let plen = 10 + s.next_index(8);
            let mut passage = words_from(s, topic, plen);
            let query = vocab::cluster_base(topic) + s.next_index(vocab::CLUSTER as usize) as i32;
            if label == 1 {
                let pos = s.next_index(plen - 1);
                passage[pos] = vocab::A_MARKER;
                passage[pos + 1] = query;
            }
            let mut toks = vec![vocab::BOS, query, vocab::QMARK, vocab::SEP];
            toks.extend(&passage);
            let (tokens, attn_len) = pad_to(toks, seq);
            ClsExample { tokens, attn_len, label: label as f32 }
        }
        // Entailment: entailed hypotheses stay in the premise's register
        // (same cluster side); non-entailed hypotheses jump register.
        "rte" => {
            let label = s.next_index(2);
            let half = nc / 2;
            let side_p = 0; // fixed premise register (see mrpc comment)
            let _ = s.next_index(2);
            let cp = side_p * half + s.next_index(half);
            let lp = 8 + s.next_index(6);
            let premise = words_from(s, cp, lp);
            let side_h = if label == 1 { side_p } else { 1 - side_p };
            let ch = side_h * half + s.next_index(half);
            let lh = 3 + s.next_index(3);
            let hypothesis = words_from(s, ch, lh);
            let mut toks = vec![vocab::BOS];
            toks.extend(&premise);
            toks.push(vocab::SEP);
            toks.extend(&hypothesis);
            let (tokens, attn_len) = pad_to(toks, seq);
            ClsExample { tokens, attn_len, label: label as f32 }
        }
        // Similarity regression: score = 4 * (shared-register fraction):
        // k of the 8 second-sentence words stay in s1's register, the
        // rest come from the opposite register.
        "stsb" => {
            let half = nc / 2;
            let side = 0; // fixed register for s1 (see mrpc comment)
            let _ = s.next_index(2);
            let len = 8;
            let c1 = side * half + s.next_index(half);
            let s1 = words_from(s, c1, len);
            let k = s.next_index(len + 1);
            let mut s2 = Vec::with_capacity(len);
            for i in 0..len {
                let sd = if i < k { side } else { 1 - side };
                let c = sd * half + s.next_index(half);
                s2.push(vocab::cluster_base(c) + s.next_index(vocab::CLUSTER as usize) as i32);
            }
            let mut toks = vec![vocab::BOS];
            toks.extend(&s1);
            toks.push(vocab::SEP);
            toks.extend(&s2);
            let (tokens, attn_len) = pad_to(toks, seq);
            ClsExample { tokens, attn_len, label: 4.0 * k as f32 / len as f32 }
        }
        other => panic!("unknown GLUE-like task {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_generate_valid_examples() {
        for task in TASKS {
            let split = generate(task, 42, 32, 512);
            assert!(!split.train.is_empty() && !split.dev.is_empty(), "{task}");
            for ex in split.train.iter().take(50).chain(split.dev.iter().take(20)) {
                assert_eq!(ex.tokens.len(), 32, "{task}");
                assert!(ex.attn_len > 0 && ex.attn_len <= 32, "{task}");
                assert!(ex.tokens.iter().all(|&t| (0..512).contains(&t)), "{task}");
                if task == "stsb" {
                    assert!((0.0..=4.0).contains(&ex.label), "{task}");
                } else {
                    assert!(ex.label == 0.0 || ex.label == 1.0, "{task}");
                }
            }
        }
    }

    #[test]
    fn labels_roughly_balanced() {
        for task in ["sst2", "mrpc", "cola", "qnli", "rte"] {
            let split = generate(task, 1, 32, 512);
            let pos: usize = split.train.iter().filter(|e| e.label == 1.0).count();
            let frac = pos as f64 / split.train.len() as f64;
            assert!((0.35..0.65).contains(&frac), "{task}: {frac}");
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate("sst2", 5, 32, 512);
        let b = generate("sst2", 5, 32, 512);
        assert_eq!(a.train[0].tokens, b.train[0].tokens);
        let c = generate("sst2", 6, 32, 512);
        assert_ne!(a.train[0].tokens, c.train[0].tokens);
    }

    #[test]
    fn rte_is_small_data() {
        let (rte, _) = super::sizes("rte");
        let (sst, _) = super::sizes("sst2");
        assert!(rte * 5 <= sst);
    }

    #[test]
    fn qnli_answer_span_is_the_signal() {
        // pre-noise semantics: A_MARKER followed by the query <=> label 1
        let mut s = Stream::child(rng::child_seed(3, rng::STREAM_DATA), task_id("qnli"));
        for _ in 0..200 {
            let ex = example("qnli", &mut s, 32, 512);
            let query = ex.tokens[1];
            let passage = &ex.tokens[4..ex.attn_len];
            let has_span = passage
                .windows(2)
                .any(|w| w[0] == vocab::A_MARKER && w[1] == query);
            assert_eq!(has_span, ex.label == 1.0);
        }
    }

    #[test]
    fn label_noise_applied() {
        // with noise, generate() labels disagree with the clean signal
        // at roughly the configured rate
        let split = generate("rte", 9, 32, 512);
        assert!(!split.train.is_empty());
        // stsb score noise keeps range
        let st = generate("stsb", 9, 32, 512);
        assert!(st.train.iter().all(|e| (0.0..=4.0).contains(&e.label)));
    }
}
