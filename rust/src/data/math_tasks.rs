//! Mathematical-reasoning LM tasks (Table 3 substitution).
//!
//! MetaMathQA -> synthetic arithmetic training set; GSM8K-like dev =
//! 2-step chains over small numbers; MATH-like dev = deeper chains with
//! larger operands and multiplication (strictly harder, so every method
//! scores lower on it — matching the paper's GSM8K >> MATH gap).

use super::vocab;
use super::{LmExample, LmSplit};
use crate::rng::{self, Stream};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Difficulty {
    /// 2-step, operands < 20 (GSM8K-like)
    Gsm,
    /// 3-step, operands < 50, multiplication-heavy (MATH-like)
    Math,
}

/// Build one chained-arithmetic example:
/// `Q a1 OP b1 = c1 ; c1 OP b2 = c2 [; ...] A <answer> EOS`.
/// The prompt ends right after A_MARKER; labels cover answer + EOS.
pub fn example(s: &mut Stream, diff: Difficulty, seq: usize) -> LmExample {
    let (steps, max_op) = match diff {
        Difficulty::Gsm => (2, 20u64),
        Difficulty::Math => (3, 50u64),
    };
    let mut toks = vec![vocab::BOS, vocab::Q_MARKER];
    let mut acc = 1 + s.next_index(max_op as usize) as u64;
    toks.extend(vocab::encode_number(acc));
    for step in 0..steps {
        let b = 1 + s.next_index(max_op as usize) as u64;
        let mul_bias = matches!(diff, Difficulty::Math) && step > 0;
        let (op, val) = match s.next_index(if mul_bias { 4 } else { 3 }) {
            0 => (vocab::PLUS, acc + b),
            1 => (vocab::MINUS, acc.max(b) - acc.min(b)),
            _ => (vocab::TIMES, acc.saturating_mul(b).min(9999)),
        };
        toks.push(op);
        toks.extend(vocab::encode_number(b));
        toks.push(vocab::EQUALS);
        acc = val;
        if step + 1 < steps {
            toks.extend(vocab::encode_number(acc));
            toks.push(vocab::COLON);
        }
    }
    toks.push(vocab::A_MARKER);
    let prompt_len = toks.len();
    let answer = vocab::encode_number(acc);
    toks.extend(&answer);
    toks.push(vocab::EOS);
    toks.truncate(seq);
    let attn = toks.len();
    toks.resize(seq, vocab::PAD);

    // labels: next-token targets only over the answer span (incl. EOS)
    let mut labels = vec![-1i32; seq];
    for pos in (prompt_len - 1)..(attn - 1) {
        labels[pos] = toks[pos + 1];
    }
    LmExample { tokens: toks, labels, prompt_len, answer }
}

/// Training mixes both difficulties (like MetaMathQA mixes sources);
/// dev splits are per-benchmark.
pub fn generate(seed: u64, seq: usize, n_train: usize, n_dev: usize) -> (LmSplit, Vec<LmExample>) {
    let mut s = Stream::child(rng::child_seed(seed, rng::STREAM_DATA), 50);
    let train = (0..n_train)
        .map(|i| {
            let d = if i % 2 == 0 { Difficulty::Gsm } else { Difficulty::Math };
            example(&mut s, d, seq)
        })
        .collect();
    let dev_gsm: Vec<LmExample> = (0..n_dev).map(|_| example(&mut s, Difficulty::Gsm, seq)).collect();
    let dev_math: Vec<LmExample> = (0..n_dev).map(|_| example(&mut s, Difficulty::Math, seq)).collect();
    (LmSplit { train, dev: dev_gsm }, dev_math)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn example_structure() {
        let mut s = Stream::new(1);
        for _ in 0..100 {
            let ex = example(&mut s, Difficulty::Gsm, 64);
            assert_eq!(ex.tokens.len(), 64);
            assert_eq!(ex.labels.len(), 64);
            assert_eq!(ex.tokens[1], vocab::Q_MARKER);
            assert_eq!(ex.tokens[ex.prompt_len - 1], vocab::A_MARKER);
            // labels masked over prompt except the A_MARKER position
            assert!(ex.labels[..ex.prompt_len - 1].iter().all(|&l| l == -1));
            assert_eq!(ex.labels[ex.prompt_len - 1], ex.answer[0]);
            assert!(!ex.answer.is_empty());
        }
    }

    #[test]
    fn answers_are_correct_chains() {
        // re-evaluate the chain from the surface tokens and compare
        let mut s = Stream::new(9);
        for _ in 0..200 {
            let ex = example(&mut s, Difficulty::Math, 64);
            let toks = &ex.tokens[2..ex.prompt_len - 1]; // strip BOS Q .. A
            let mut acc: Option<u64> = None;
            let mut i = 0;
            // parse: n (OP n =[ n ;])*
            let mut cur = Vec::new();
            let mut pending_op: Option<i32> = None;
            while i < toks.len() {
                let t = toks[i];
                if vocab::is_digit(t) {
                    cur.push(t);
                } else {
                    if !cur.is_empty() {
                        let n = vocab::decode_number(&cur).unwrap();
                        cur.clear();
                        acc = Some(match (acc, pending_op) {
                            (None, _) => n,
                            (Some(a), Some(vocab::PLUS)) => a + n,
                            (Some(a), Some(vocab::MINUS)) => a.max(n) - a.min(n),
                            (Some(a), Some(vocab::TIMES)) => (a * n).min(9999),
                            (Some(_), _) => n, // intermediate restated value
                        });
                        pending_op = None;
                    }
                    if matches!(t, vocab::PLUS | vocab::MINUS | vocab::TIMES) {
                        pending_op = Some(t);
                    }
                }
                i += 1;
            }
            if !cur.is_empty() {
                let n = vocab::decode_number(&cur).unwrap();
                acc = Some(match (acc, pending_op) {
                    (Some(a), Some(vocab::PLUS)) => a + n,
                    (Some(a), Some(vocab::MINUS)) => a.max(n) - a.min(n),
                    (Some(a), Some(vocab::TIMES)) => (a * n).min(9999),
                    _ => n,
                });
            }
            let want = vocab::decode_number(&ex.answer).unwrap();
            assert_eq!(acc, Some(want), "tokens {toks:?}");
        }
    }

    #[test]
    fn math_is_harder_than_gsm() {
        let mut s = Stream::new(2);
        let avg_len = |d: Difficulty, s: &mut Stream| -> f64 {
            (0..100).map(|_| example(s, d, 64).prompt_len as f64).sum::<f64>() / 100.0
        };
        let g = avg_len(Difficulty::Gsm, &mut s);
        let m = avg_len(Difficulty::Math, &mut s);
        assert!(m > g, "math {m} vs gsm {g}");
    }

    #[test]
    fn generate_splits() {
        let (split, dev_math) = generate(3, 64, 50, 20);
        assert_eq!(split.train.len(), 50);
        assert_eq!(split.dev.len(), 20);
        assert_eq!(dev_math.len(), 20);
    }
}
