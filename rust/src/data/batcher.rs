//! Deterministic batching: seeded shuffling, padding of ragged final
//! batches, and flattening into the artifact's [B*T] input layout.

use super::{ClsExample, LmExample};
use crate::rng::Stream;

/// A classification batch in artifact input layout.
#[derive(Debug, Clone)]
pub struct ClsBatch {
    pub tokens: Vec<i32>,   // [B*T]
    pub attn_len: Vec<i32>, // [B]
    pub labels_i: Vec<i32>, // [B] (class ids)
    pub labels_f: Vec<f32>, // [B] (regression targets)
    /// number of real (non-repeated-pad) examples in this batch
    pub real: usize,
}

/// An LM batch in artifact input layout.
#[derive(Debug, Clone)]
pub struct LmBatch {
    pub tokens: Vec<i32>, // [B*T]
    pub labels: Vec<i32>, // [B*T]
    pub real: usize,
}

/// Seeded epoch shuffler over example indices.
pub fn shuffled_indices(n: usize, seed: u64, epoch: u64) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..n).collect();
    let mut s = Stream::new(seed.wrapping_add(epoch.wrapping_mul(0x9E37)));
    for i in (1..n).rev() {
        let j = s.next_index(i + 1);
        idx.swap(i, j);
    }
    idx
}

pub fn cls_batches(examples: &[ClsExample], batch: usize, seed: u64, epoch: u64) -> Vec<ClsBatch> {
    if examples.is_empty() {
        // the cyclic-repeat padding below indexes examples[0]
        return Vec::new();
    }
    let order = shuffled_indices(examples.len(), seed, epoch);
    order
        .chunks(batch)
        .map(|chunk| {
            let mut b = ClsBatch {
                tokens: Vec::with_capacity(batch * examples[0].tokens.len()),
                attn_len: Vec::with_capacity(batch),
                labels_i: Vec::with_capacity(batch),
                labels_f: Vec::with_capacity(batch),
                real: chunk.len(),
            };
            for k in 0..batch {
                // ragged final batch: repeat examples cyclically (they are
                // excluded from metrics via `real`)
                let ex = &examples[chunk[k % chunk.len()]];
                b.tokens.extend(&ex.tokens);
                b.attn_len.push(ex.attn_len as i32);
                b.labels_i.push(ex.label as i32);
                b.labels_f.push(ex.label);
            }
            b
        })
        .collect()
}

pub fn lm_batches(examples: &[LmExample], batch: usize, seed: u64, epoch: u64) -> Vec<LmBatch> {
    if examples.is_empty() {
        return Vec::new();
    }
    let order = shuffled_indices(examples.len(), seed, epoch);
    order
        .chunks(batch)
        .map(|chunk| {
            let mut b = LmBatch {
                tokens: Vec::with_capacity(batch * examples[0].tokens.len()),
                labels: Vec::with_capacity(batch * examples[0].tokens.len()),
                real: chunk.len(),
            };
            for k in 0..batch {
                let ex = &examples[chunk[k % chunk.len()]];
                b.tokens.extend(&ex.tokens);
                b.labels.extend(&ex.labels);
            }
            b
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_cls(n: usize) -> Vec<ClsExample> {
        (0..n)
            .map(|i| ClsExample {
                tokens: vec![i as i32; 8],
                attn_len: 8,
                label: (i % 2) as f32,
            })
            .collect()
    }

    #[test]
    fn batches_cover_all_examples_once() {
        let ex = mk_cls(10);
        let bs = cls_batches(&ex, 4, 1, 0);
        assert_eq!(bs.len(), 3);
        assert_eq!(bs[2].real, 2);
        let mut seen: Vec<i32> = bs
            .iter()
            .flat_map(|b| (0..b.real).map(|k| b.tokens[k * 8]))
            .collect();
        seen.sort();
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn shuffle_depends_on_epoch_not_call() {
        let a = shuffled_indices(50, 3, 0);
        let b = shuffled_indices(50, 3, 0);
        let c = shuffled_indices(50, 3, 1);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn ragged_batch_padded_cyclically() {
        let ex = mk_cls(5);
        let bs = cls_batches(&ex, 4, 1, 0);
        assert_eq!(bs[1].real, 1);
        assert_eq!(bs[1].tokens.len(), 4 * 8);
        // repeated example fills the rest
        assert_eq!(bs[1].tokens[0], bs[1].tokens[8]);
    }

    fn mk_lm(n: usize) -> Vec<LmExample> {
        (0..n)
            .map(|i| LmExample {
                tokens: vec![i as i32; 8],
                labels: vec![-1; 8],
                prompt_len: 4,
                answer: vec![1],
            })
            .collect()
    }

    #[test]
    fn empty_corpus_yields_no_batches() {
        // used to panic on examples[0] / chunk-cycling over zero items
        assert!(cls_batches(&[], 4, 1, 0).is_empty());
        assert!(lm_batches(&[], 4, 1, 0).is_empty());
    }

    #[test]
    fn lm_ragged_final_batch_padded_cyclically() {
        let ex = mk_lm(5);
        let bs = lm_batches(&ex, 4, 1, 0);
        assert_eq!(bs.len(), 2);
        assert_eq!(bs[1].real, 1);
        assert_eq!(bs[1].tokens.len(), 4 * 8);
        assert_eq!(bs[1].labels.len(), 4 * 8);
        assert_eq!(bs[1].tokens[0], bs[1].tokens[8]);
    }

    #[test]
    fn single_example_fills_whole_batch() {
        let ex = mk_cls(1);
        let bs = cls_batches(&ex, 4, 9, 0);
        assert_eq!(bs.len(), 1);
        assert_eq!(bs[0].real, 1);
        assert_eq!(bs[0].attn_len.len(), 4);
    }
}
