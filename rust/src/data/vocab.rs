//! Shared token space for all synthetic tasks.
//!
//! Layout (vocab = 512 for base/large/lm backbones):
//!   0       PAD
//!   1       BOS
//!   2       SEP
//!   3       EOS
//!   4..=13  digits 0-9
//!   14..=23 operators / markers (+, -, *, =, ?, :, ARROW, Q, A, TURN)
//!   24..=31 task-tag tokens (instruction opcodes)
//!   32..    content "words", organized in clusters of 16

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const SEP: i32 = 2;
pub const EOS: i32 = 3;

pub const DIGIT0: i32 = 4;

pub const PLUS: i32 = 14;
pub const MINUS: i32 = 15;
pub const TIMES: i32 = 16;
pub const EQUALS: i32 = 17;
pub const QMARK: i32 = 18;
pub const COLON: i32 = 19;
pub const ARROW: i32 = 20;
pub const Q_MARKER: i32 = 21;
pub const A_MARKER: i32 = 22;
pub const TURN: i32 = 23;

/// Instruction opcodes (data::instruct).
pub const OP_COPY: i32 = 24;
pub const OP_REVERSE: i32 = 25;
pub const OP_LAST: i32 = 26;
pub const OP_SORT: i32 = 27;
pub const OP_COUNT: i32 = 28;
pub const OP_MAP: i32 = 29;
pub const OP_PICK: i32 = 30;
pub const OP_MATH: i32 = 31;

pub const WORD0: i32 = 32;
pub const CLUSTER: i32 = 16;

/// First token id of word-cluster `c`.
pub fn cluster_base(c: usize) -> i32 {
    WORD0 + (c as i32) * CLUSTER
}

/// Number of word clusters available under a vocab size.
pub fn n_clusters(vocab: usize) -> usize {
    (vocab - WORD0 as usize) / CLUSTER as usize
}

pub fn digit(d: u32) -> i32 {
    DIGIT0 + d as i32
}

pub fn is_digit(t: i32) -> bool {
    (DIGIT0..DIGIT0 + 10).contains(&t)
}

pub fn digit_value(t: i32) -> Option<u32> {
    is_digit(t).then_some((t - DIGIT0) as u32)
}

/// Encode a non-negative integer as digit tokens (decimal, no leading +).
pub fn encode_number(mut n: u64) -> Vec<i32> {
    if n == 0 {
        return vec![digit(0)];
    }
    let mut ds = Vec::new();
    while n > 0 {
        ds.push(digit((n % 10) as u32));
        n /= 10;
    }
    ds.reverse();
    ds
}

/// Decode digit tokens back to an integer (stops at first non-digit).
pub fn decode_number(toks: &[i32]) -> Option<u64> {
    let mut n: u64 = 0;
    let mut seen = false;
    for &t in toks {
        match digit_value(t) {
            Some(d) => {
                n = n * 10 + d as u64;
                seen = true;
            }
            None => break,
        }
    }
    seen.then_some(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn number_roundtrip() {
        for n in [0u64, 7, 10, 99, 1234, 98765] {
            assert_eq!(decode_number(&encode_number(n)), Some(n));
        }
    }

    #[test]
    fn decode_stops_at_non_digit() {
        let mut toks = encode_number(42);
        toks.push(EOS);
        toks.extend(encode_number(9));
        assert_eq!(decode_number(&toks), Some(42));
        assert_eq!(decode_number(&[EOS]), None);
    }

    #[test]
    fn clusters_fit_vocab() {
        assert!(n_clusters(512) >= 16);
        assert_eq!(cluster_base(0), WORD0);
        assert_eq!(cluster_base(2), WORD0 + 32);
    }
}
