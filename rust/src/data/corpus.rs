//! Synthetic pretraining corpus: a structured "language" over the shared
//! token space. Sentences are topic-coherent word sequences with cluster
//! bigram structure, interleaved with arithmetic snippets so a
//! pretrained backbone carries both lexical-cluster features (used by
//! the GLUE-like suite) and digit/operator features (used by the
//! math/instruct suites) — the stand-in for web-scale pretraining.

use super::vocab;
use crate::rng::Stream;

/// One pretraining sequence of exactly `seq` tokens with next-token
/// labels (shifted by one; last position masked).
pub fn sample_sequence(stream: &mut Stream, seq: usize, vocab_size: usize) -> (Vec<i32>, Vec<i32>) {
    let mut toks = Vec::with_capacity(seq);
    toks.push(vocab::BOS);
    while toks.len() < seq {
        if stream.next_f64() < 0.25 {
            arithmetic_snippet(stream, &mut toks);
        } else {
            sentence(stream, &mut toks, vocab_size);
        }
        toks.push(vocab::SEP);
    }
    toks.truncate(seq);
    let mut labels: Vec<i32> = toks[1..].to_vec();
    labels.push(-1);
    (toks, labels)
}

/// Topic-coherent sentence: pick a topic cluster, walk a bigram chain
/// inside it with occasional hops to a "related" cluster (topic+1).
fn sentence(stream: &mut Stream, out: &mut Vec<i32>, vocab_size: usize) {
    let nc = vocab::n_clusters(vocab_size);
    let topic = stream.next_index(nc);
    let len = 4 + stream.next_index(8);
    let mut word = stream.next_index(vocab::CLUSTER as usize);
    for _ in 0..len {
        let c = if stream.next_f64() < 0.15 { (topic + 1) % nc } else { topic };
        out.push(vocab::cluster_base(c) + word as i32);
        // bigram structure: next word id = f(current) + small noise
        word = (word * 5 + 3 + stream.next_index(3)) % vocab::CLUSTER as usize;
    }
}

/// `a OP b = c` with single-digit operands (and correct answers, so the
/// LM can actually learn arithmetic features).
fn arithmetic_snippet(stream: &mut Stream, out: &mut Vec<i32>) {
    let a = stream.next_index(10) as u64;
    let b = stream.next_index(10) as u64;
    let (op, val) = match stream.next_index(3) {
        0 => (vocab::PLUS, a + b),
        1 => (vocab::MINUS, a.max(b) - a.min(b)),
        _ => (vocab::TIMES, a * b),
    };
    out.extend(vocab::encode_number(a.max(b)));
    out.push(op);
    out.extend(vocab::encode_number(a.min(b)));
    out.push(vocab::EQUALS);
    out.extend(vocab::encode_number(val));
}

/// A batch iterator for pretraining: returns (tokens, labels) flattened
/// [batch*seq] for the pretrain_lm artifact.
pub struct CorpusBatches {
    stream: Stream,
    pub batch: usize,
    pub seq: usize,
    pub vocab_size: usize,
}

impl CorpusBatches {
    pub fn new(seed: u64, batch: usize, seq: usize, vocab_size: usize) -> CorpusBatches {
        CorpusBatches { stream: Stream::new(seed), batch, seq, vocab_size }
    }

    pub fn next_batch(&mut self) -> (Vec<i32>, Vec<i32>) {
        let mut toks = Vec::with_capacity(self.batch * self.seq);
        let mut labs = Vec::with_capacity(self.batch * self.seq);
        for _ in 0..self.batch {
            let (t, l) = sample_sequence(&mut self.stream, self.seq, self.vocab_size);
            toks.extend(t);
            labs.extend(l);
        }
        (toks, labs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequence_shape_and_labels() {
        let mut s = Stream::new(1);
        let (t, l) = sample_sequence(&mut s, 64, 512);
        assert_eq!(t.len(), 64);
        assert_eq!(l.len(), 64);
        assert_eq!(l[62], t[63]);
        assert_eq!(l[63], -1);
        assert!(t.iter().all(|&x| (0..512).contains(&x)));
    }

    #[test]
    fn batches_deterministic() {
        let mut a = CorpusBatches::new(7, 4, 32, 512);
        let mut b = CorpusBatches::new(7, 4, 32, 512);
        assert_eq!(a.next_batch(), b.next_batch());
        assert_eq!(a.next_batch(), b.next_batch());
        let mut c = CorpusBatches::new(8, 4, 32, 512);
        assert_ne!(a.next_batch().0, c.next_batch().0);
    }

    #[test]
    fn corpus_mixes_words_and_digits() {
        let mut s = Stream::new(3);
        let mut digits = 0;
        let mut words = 0;
        for _ in 0..50 {
            let (t, _) = sample_sequence(&mut s, 64, 512);
            digits += t.iter().filter(|&&x| vocab::is_digit(x)).count();
            words += t.iter().filter(|&&x| x >= vocab::WORD0).count();
        }
        assert!(digits > 100, "digits {digits}");
        assert!(words > 1000, "words {words}");
    }
}
