//! Decode-session subsystem: the stateful serving lifecycle between
//! the runtime and the server.
//!
//! The legacy serving hot path re-ran a full `[B, T]` forward — and
//! re-reconstructed θ → ΔW — for EVERY generated token, making
//! per-token cost O(seq · model). A `DecodeSession` owns what that
//! loop recomputed: per-sequence K/V caches (one prefill over the
//! prompt, then single-position incremental steps) and, through the
//! shared [`ReconCache`], the per-adapter reconstructed weights
//! (adapters are one tiny vector; reconstructions are not — build them
//! once per adapter, not once per token).
//!
//! Lifecycle: [`crate::runtime::Backend::begin_decode`] → [`DecodeSession::admit`]
//! (occupy a free slot) / [`DecodeSession::step`] (advance EVERY active
//! sequence by one iteration, retiring finished ones) →
//! [`DecodeSession::finish`]. Slots progress independently — each has
//! its own adapter, prompt and budget — which is what lets the server
//! router run *continuous batching*: new requests are admitted into
//! free slots at step boundaries instead of waiting for a whole greedy
//! batch to drain.
//!
//! Two implementations:
//! - [`NativeDecodeSession`]: per-layer K/V caches over
//!   `runtime::native::model::incr_forward` — O(model) per token.
//! - [`FallbackSession`]: drives ordinary `Backend::run` full forwards,
//!   so ANY backend (PJRT included) keeps working with zero extra
//!   code; it is the `Backend::begin_decode` default.
//!
//! Emission semantics are shared through the crate-internal
//! `SeqState`, which replays the legacy `decode_with` loop row-for-row
//! (same EOS / context-window / budget rules in the same order) with a
//! per-slot [`crate::generation::Sampler`] picking the token: default
//! [`SamplingParams`] is exact greedy (argmax, zero RNG draws), so
//! incremental and full-forward decode produce identical greedy token
//! streams by construction, and the parity suite in
//! `tests/decode_parity.rs` holds both implementations to that.
//! Non-default params add seeded sampling, stop sequences and logit
//! bias on the same rules — applied strictly after the logits GEMM, so
//! fused and per-slot stepping stay token-stream identical under any
//! params.

pub mod cache;
pub mod fallback;
pub mod native;

pub use cache::ReconCache;
pub use fallback::FallbackSession;
pub use native::NativeDecodeSession;

use crate::config;
use crate::generation::{Sampler, SamplingParams};
use crate::projection::statics::Static;
use crate::runtime::Backend;
use anyhow::Result;
use std::sync::Arc;

/// Session scheduling knobs.
#[derive(Debug, Clone, Copy)]
pub struct SessionOpts {
    /// Decode slots (concurrent sequences) per session; 0 = auto
    /// (`UNI_LORA_DECODE_SLOTS`, else the artifact batch size).
    pub slots: usize,
    /// Dense-densification crossover for the admission cost model;
    /// 0 = auto (`UNI_LORA_DENSE_THRESHOLD`, else
    /// [`config::DEFAULT_DENSE_THRESHOLD`]). An adapter occupying at
    /// least this many of the session's slots runs densified; below
    /// it, slots run the factored rank-r path.
    pub dense_threshold: usize,
    /// K/V arena token budget in pages of [`config::KV_PAGE_TOKENS`]
    /// positions; 0 = auto (`UNI_LORA_KV_PAGES`, else the per-slot
    /// worst case — exactly what per-slot preallocation guaranteed, so
    /// the paged default is opt-out-safe).
    pub kv_pages: usize,
    /// Fuse the native decode step: all active single-position slots
    /// advance through one `[active, h]` GEMM per layer weight instead
    /// of per-slot GEMVs. Scheduling-only (bit-equal per kernel tier
    /// to per-slot stepping); `UNI_LORA_FUSED_STEP=0` disables it for
    /// A/B benching.
    pub fused_step: bool,
}

impl SessionOpts {
    /// Knobs from the environment (`UNI_LORA_DECODE_SLOTS`,
    /// `UNI_LORA_DENSE_THRESHOLD`, `UNI_LORA_KV_PAGES`,
    /// `UNI_LORA_FUSED_STEP`).
    pub fn from_env() -> SessionOpts {
        let ro = config::RuntimeOpts::from_env();
        SessionOpts {
            slots: ro.decode_slots,
            dense_threshold: ro.dense_threshold,
            kv_pages: ro.kv_pages,
            fused_step: ro.fused_step,
        }
    }

    /// An explicit slot count (tests, benches); every other knob stays
    /// on its default. The fused-step default follows
    /// `UNI_LORA_FUSED_STEP` (not a pinned `true`) so CI can re-run
    /// whole parity suites under per-slot stepping; pin it explicitly
    /// with [`SessionOpts::with_fused_step`] when a test A/Bs the two
    /// schedules itself.
    pub fn with_slots(slots: usize) -> SessionOpts {
        SessionOpts {
            slots,
            dense_threshold: 0,
            kv_pages: 0,
            fused_step: crate::config::parse_fused_step(
                std::env::var("UNI_LORA_FUSED_STEP").ok().as_deref(),
            ),
        }
    }

    /// Pin the dense-densification crossover (tests, benches): `1`
    /// forces every admission dense (the legacy path), `usize::MAX`
    /// forces every low-rank adapter factored.
    pub fn with_dense_threshold(mut self, dense_threshold: usize) -> SessionOpts {
        self.dense_threshold = dense_threshold;
        self
    }

    /// Pin the K/V arena budget, in pages (tests, benches).
    pub fn with_kv_pages(mut self, kv_pages: usize) -> SessionOpts {
        self.kv_pages = kv_pages;
        self
    }

    /// Toggle the fused batched decode step (benches, bisection).
    pub fn with_fused_step(mut self, fused_step: bool) -> SessionOpts {
        self.fused_step = fused_step;
        self
    }

    /// Resolve the slot count against the artifact's batch size.
    pub fn resolve_slots(&self, artifact_batch: usize) -> usize {
        if self.slots > 0 {
            self.slots
        } else {
            artifact_batch.max(1)
        }
    }

    /// Resolve the cost-model crossover (0 = compiled default).
    pub fn resolve_dense_threshold(&self) -> usize {
        if self.dense_threshold > 0 {
            self.dense_threshold
        } else {
            config::DEFAULT_DENSE_THRESHOLD
        }
    }

    /// Resolve the K/V arena page budget for a session of
    /// `slots` slots over a `seq`-position window. 0 = the per-slot
    /// worst case: every slot can hold a full window simultaneously,
    /// so the arena never refuses an admission the old per-slot
    /// preallocation would have accepted.
    pub fn resolve_kv_pages(&self, slots: usize, seq: usize) -> usize {
        if self.kv_pages > 0 {
            self.kv_pages
        } else {
            slots * seq.div_ceil(config::KV_PAGE_TOKENS)
        }
    }
}

/// What [`DecodeSession::admit`] did with a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Admission {
    /// Slot the sequence occupies until it retires.
    pub slot: usize,
    /// The prompt exceeded the context window and was truncated to it.
    /// Historically this happened silently; callers that care (the
    /// router, API clients) can now surface it. A truncated prompt
    /// fills the window, so the sequence is stillborn: it admits,
    /// occupies the slot for one step, and emits nothing — the same
    /// stream the legacy full-forward loop produced for over-window
    /// rows.
    pub truncated: bool,
}

/// One sequence to decode: the adapter identity plus everything the
/// session needs to reconstruct and run it.
#[derive(Debug, Clone)]
pub struct SeqRequest {
    /// Caller-chosen request identity, echoed back on every
    /// [`SeqEvent`] this sequence emits. The router threads its
    /// trace-assigned id through here so a drained span timeline is
    /// attributable across session replays; callers without tracing
    /// pass 0. Observation-only — no decode path reads it.
    pub request_id: u64,
    /// Reconstruction-cache key (adapter name). The cache additionally
    /// fingerprints theta, so a re-registered adapter under the same
    /// name can never serve a stale reconstruction.
    pub adapter: String,
    pub theta: Arc<Vec<f32>>,
    pub statics: Arc<Vec<Static>>,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    /// Decoding policy for this sequence; `SamplingParams::default()`
    /// is exact greedy. Sessions validate it at admission and seed a
    /// per-slot sampler from it.
    pub sampling: SamplingParams,
}

/// What one sequence did during a [`DecodeSession::step`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SeqEvent {
    pub slot: usize,
    /// The [`SeqRequest::request_id`] this slot was admitted with —
    /// lets the router assert events land on the request it thinks
    /// owns the slot.
    pub req: u64,
    /// Token emitted this step (`None`: the step ended the sequence
    /// without emitting — EOS, exhausted context window, zero budget).
    pub token: Option<i32>,
    /// The sequence finished; its slot is free again.
    pub done: bool,
}

/// Cumulative session counters (the router folds these into its
/// serving-quality stats).
#[derive(Debug, Default, Clone, Copy)]
pub struct SessionStats {
    pub admitted: u64,
    pub steps: u64,
    pub generated: u64,
    pub recon_hits: u64,
    pub recon_misses: u64,
    /// admissions the cost model routed to the factored rank-r path
    pub factored_admits: u64,
    /// admissions the cost model densified (hot adapters, FourierFT)
    pub dense_admits: u64,
    /// dense reconstructions the `ReconCache` evicted on behalf of
    /// this session's admissions
    pub recon_evictions: u64,
    /// admissions whose prompt was truncated to the context window
    pub truncated_admits: u64,
    /// admissions decoding with non-greedy params (temperature > 0)
    pub sampled_admits: u64,
    /// admissions decoding greedy (temperature 0 — the default)
    pub greedy_admits: u64,
    /// K/V bytes currently held by resident pages (a gauge, not a
    /// counter: it tracks tokens actually in flight, rising on
    /// grow/admission and falling on retirement)
    pub kv_bytes_in_flight: u64,
    /// K/V pages recycled through the arena free list (counter)
    pub kv_page_churn: u64,
    /// sequences retired mid-flight via [`DecodeSession::cancel`]
    /// (deadline expiries, client disconnects) — their pages and slot
    /// were recycled before the sequence finished
    pub cancelled: u64,
}

/// A stateful decoding session over one `lm_logits`-kind artifact.
pub trait DecodeSession: Send {
    /// Admit a sequence into a free slot; errors when none is free
    /// (callers check [`DecodeSession::free_slots`] first), the
    /// request is malformed (empty prompt, unknown reconstruction), or
    /// — native sessions only — the K/V token budget cannot cover the
    /// sequence (the error carries a [`runtime::native::kv_arena::KvBudgetExhausted`]
    /// so callers can distinguish transient pressure from oversized
    /// requests).
    ///
    /// [`runtime::native::kv_arena::KvBudgetExhausted`]: crate::runtime::native::kv_arena::KvBudgetExhausted
    fn admit(&mut self, req: SeqRequest) -> Result<Admission>;

    /// Advance every active sequence by one greedy iteration (newly
    /// admitted slots run their prefill first). Finished sequences are
    /// retired and their slots freed before this returns.
    fn step(&mut self, exec: &mut dyn Backend) -> Result<Vec<SeqEvent>>;

    /// Retire one in-flight sequence before it finishes: the caller
    /// decided nobody will read its tokens (client disconnected) or it
    /// ran out of wall-clock (deadline). K/V pages and the slot free
    /// immediately, no event is ever emitted for it, and
    /// [`SessionStats::cancelled`] increments. Cancelling a free slot
    /// is a no-op.
    fn cancel(&mut self, slot: usize);

    /// Release all slots (in-flight sequences are abandoned).
    fn finish(&mut self);

    fn slots(&self) -> usize;

    fn active(&self) -> usize;

    fn free_slots(&self) -> usize {
        self.slots() - self.active()
    }

    fn stats(&self) -> SessionStats;
}

/// FNV-1a over the raw f32 bits of a theta vector — cheap (one pass
/// over a d-sized vector, once per admission, not per token). The
/// reconstruction cache uses it to reject stale entries, and the
/// fallback session uses it to group slots: two slots batch into one
/// forward only when name AND weights agree, so a re-registered
/// adapter can never decode with another request's theta.
pub(crate) fn theta_fingerprint(theta: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &x in theta {
        let b = x.to_bits();
        for shift in [0, 8, 16, 24] {
            h ^= ((b >> shift) & 0xff) as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h ^ (theta.len() as u64)
}

/// Per-slot emission state shared by every session implementation —
/// one instance replays exactly one row of the legacy full-forward
/// decode loop (`coordinator::trainer::decode_with`): same EOS,
/// context-window and budget rules, applied in the same order, with
/// the slot's [`Sampler`] picking the token. Default params pick plain
/// argmax (zero RNG draws), so every implementation emits the legacy
/// greedy streams by construction; non-default params add seeded
/// sampling, stop-sequence termination (the EOS rule generalized to
/// suffixes: the completing token is never emitted) and logit bias on
/// the same rules.
#[derive(Debug, Clone)]
pub(crate) struct SeqState {
    /// tokens placed in the context window (prompt + emitted)
    pub placed: usize,
    /// remaining decode iterations (the max_new budget)
    pub budget: usize,
    /// context-window length (cfg.seq)
    pub limit: usize,
    /// per-sequence decoding policy + seeded draw stream
    pub sampler: Sampler,
}

impl SeqState {
    pub fn new(
        prompt_len: usize,
        max_new: usize,
        limit: usize,
        sampling: SamplingParams,
    ) -> SeqState {
        SeqState {
            placed: prompt_len.min(limit),
            budget: max_new,
            limit,
            sampler: Sampler::new(sampling),
        }
    }

    /// A sequence that can never emit: the prompt already fills the
    /// context window, or the budget is zero — the legacy loop's
    /// `lens >= t` / `max_new == 0` rows, which generate nothing.
    pub fn stillborn(&self) -> bool {
        self.placed >= self.limit || self.budget == 0
    }

    /// Apply one emission given this iteration's logits row (the row
    /// at position `placed - 1`). Returns `(token, done)`. Rule order
    /// matches the legacy loop: pick, spend budget, EOS ends without
    /// emitting, a completed stop sequence ends without emitting, else
    /// place the token and check window/budget.
    pub fn emit(&mut self, logits: &[f32]) -> (Option<i32>, bool) {
        let next = self.sampler.pick(logits);
        self.budget -= 1;
        if next == crate::data::vocab::EOS {
            return (None, true);
        }
        if self.sampler.stop_hit(next) {
            return (None, true);
        }
        self.sampler.note_emitted(next);
        self.placed += 1;
        let done = self.placed >= self.limit || self.budget == 0;
        (Some(next), done)
    }
}

/// Drive a complete greedy decode of `prompts` through a session the
/// backend picks — the session-subsystem replacement for the legacy
/// `decode_with` helper. All prompts share one adapter (trainer-style
/// decoding); the serving router admits heterogeneous adapters itself.
pub fn decode_greedy(
    exec: &mut dyn Backend,
    art_logits: &str,
    adapter: &str,
    theta: Arc<Vec<f32>>,
    w0: Arc<Vec<f32>>,
    statics: Arc<Vec<Static>>,
    prompts: &[Vec<i32>],
    max_new: usize,
    opts: &SessionOpts,
) -> Result<Vec<Vec<i32>>> {
    decode_sampled(
        exec,
        art_logits,
        adapter,
        theta,
        w0,
        statics,
        prompts,
        max_new,
        &SamplingParams::default(),
        opts,
    )
}

/// [`decode_greedy`] generalized to any [`SamplingParams`] (greedy is
/// the default-params special case of the same path).
pub fn decode_sampled(
    exec: &mut dyn Backend,
    art_logits: &str,
    adapter: &str,
    theta: Arc<Vec<f32>>,
    w0: Arc<Vec<f32>>,
    statics: Arc<Vec<Static>>,
    prompts: &[Vec<i32>],
    max_new: usize,
    sampling: &SamplingParams,
    opts: &SessionOpts,
) -> Result<Vec<Vec<i32>>> {
    let mut sess = exec.begin_decode(art_logits, w0, opts)?;
    let out =
        drive_sampled(sess.as_mut(), exec, adapter, theta, statics, prompts, max_new, sampling)?;
    sess.finish();
    Ok(out)
}

/// Drive an already-begun session to completion over `prompts` (shared
/// adapter), greedy. Split out so benches/tests can drive a specific
/// session implementation.
pub fn drive_greedy(
    sess: &mut dyn DecodeSession,
    exec: &mut dyn Backend,
    adapter: &str,
    theta: Arc<Vec<f32>>,
    statics: Arc<Vec<Static>>,
    prompts: &[Vec<i32>],
    max_new: usize,
) -> Result<Vec<Vec<i32>>> {
    drive_sampled(
        sess,
        exec,
        adapter,
        theta,
        statics,
        prompts,
        max_new,
        &SamplingParams::default(),
    )
}

/// Drive an already-begun session over `prompts` under one shared
/// [`SamplingParams`]. Prompt `k` draws from the child seed
/// `child_seed(sampling.seed, k)` so batch rows never sample in
/// lockstep; re-driving the same (prompts, params) replays identical
/// streams. (The serving router passes each request's params verbatim
/// instead — its replay unit is the single request.)
pub fn drive_sampled(
    sess: &mut dyn DecodeSession,
    exec: &mut dyn Backend,
    adapter: &str,
    theta: Arc<Vec<f32>>,
    statics: Arc<Vec<Static>>,
    prompts: &[Vec<i32>],
    max_new: usize,
    sampling: &SamplingParams,
) -> Result<Vec<Vec<i32>>> {
    sampling.validate()?;
    let mut out: Vec<Vec<i32>> = vec![Vec::new(); prompts.len()];
    let mut owner: Vec<Option<usize>> = vec![None; sess.slots()];
    let mut next = 0usize;
    while next < prompts.len() || sess.active() > 0 {
        while sess.free_slots() > 0 && next < prompts.len() {
            let mut params = sampling.clone();
            params.seed = crate::rng::child_seed(sampling.seed, next as u64);
            let slot = sess
                .admit(SeqRequest {
                    request_id: next as u64,
                    adapter: adapter.to_string(),
                    theta: theta.clone(),
                    statics: statics.clone(),
                    prompt: prompts[next].clone(),
                    max_new,
                    sampling: params,
                })?
                .slot;
            anyhow::ensure!(owner[slot].is_none(), "session reused an occupied slot {slot}");
            owner[slot] = Some(next);
            next += 1;
        }
        if sess.active() == 0 {
            break;
        }
        for ev in sess.step(exec)? {
            let pi = owner[ev.slot].ok_or_else(|| anyhow::anyhow!("event for unowned slot"))?;
            if let Some(t) = ev.token {
                out[pi].push(t);
            }
            if ev.done {
                owner[ev.slot] = None;
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::vocab;

    fn greedy_state(prompt_len: usize, max_new: usize, limit: usize) -> SeqState {
        SeqState::new(prompt_len, max_new, limit, SamplingParams::default())
    }

    #[test]
    fn seq_state_replays_legacy_row_semantics() {
        // normal emission: argmax token placed, budget spent
        let mut s = greedy_state(3, 2, 8);
        assert!(!s.stillborn());
        let logits = vec![0.0, 9.0, 0.0, 0.0, 1.0];
        let (tok, done) = s.emit(&logits);
        assert_eq!(tok, Some(1));
        assert!(!done);
        assert_eq!((s.placed, s.budget), (4, 1));
        // budget exhausts: emits, then done
        let (tok, done) = s.emit(&logits);
        assert_eq!(tok, Some(1));
        assert!(done);

        // EOS ends without emitting
        let mut s = greedy_state(3, 4, 8);
        let mut eos_row = vec![0.0f32; 8];
        eos_row[vocab::EOS as usize] = 5.0;
        assert_eq!(s.emit(&eos_row), (None, true));

        // context window fills: the token placed at the last position
        // is emitted, then the row is done (legacy `lens >= t`)
        let mut s = greedy_state(7, 10, 8);
        let (tok, done) = s.emit(&logits);
        assert_eq!(tok, Some(1));
        assert!(done);

        // stillborn rows: prompt >= window, or zero budget
        assert!(greedy_state(8, 4, 8).stillborn());
        assert!(greedy_state(12, 4, 8).stillborn());
        assert!(greedy_state(3, 0, 8).stillborn());
    }

    #[test]
    fn seq_state_stop_sequences_end_without_emitting() {
        // token 1 argmaxes every step; stop [1, 1] fires on the step
        // that would emit the SECOND 1 — the first is already out
        let logits = vec![0.0, 9.0, 0.0, 0.0, 1.0];
        let sp = SamplingParams { stop: vec![vec![1, 1]], ..Default::default() };
        let mut s = SeqState::new(2, 8, 16, sp);
        assert_eq!(s.emit(&logits), (Some(1), false));
        assert_eq!(s.emit(&logits), (None, true), "completing token is not emitted");

        // a single-token stop behaves like a second EOS
        let sp = SamplingParams { stop: vec![vec![1]], ..Default::default() };
        let mut s = SeqState::new(2, 8, 16, sp);
        assert_eq!(s.emit(&logits), (None, true));

        // stop still spends budget (it replaces the emission, not the
        // iteration), and EOS keeps priority over stop matching
        let sp = SamplingParams { stop: vec![vec![vocab::EOS]], ..Default::default() };
        let mut s = SeqState::new(2, 3, 16, sp);
        let mut eos_row = vec![0.0f32; 8];
        eos_row[vocab::EOS as usize] = 5.0;
        assert_eq!(s.emit(&eos_row), (None, true));
        assert_eq!(s.budget, 2);
    }

    #[test]
    fn session_opts_resolution() {
        assert_eq!(SessionOpts::with_slots(5).resolve_slots(16), 5);
        assert_eq!(SessionOpts::with_slots(0).resolve_slots(16), 16);
        assert_eq!(SessionOpts::with_slots(0).resolve_slots(0), 1);
        assert_eq!(
            SessionOpts::with_slots(4).resolve_dense_threshold(),
            crate::config::DEFAULT_DENSE_THRESHOLD
        );
        assert_eq!(SessionOpts::with_slots(4).with_dense_threshold(1).resolve_dense_threshold(), 1);
        assert_eq!(
            SessionOpts::with_slots(4).with_dense_threshold(usize::MAX).resolve_dense_threshold(),
            usize::MAX
        );

        // kv budget: explicit wins; 0 = per-slot worst case in pages
        let pp = crate::config::KV_PAGE_TOKENS;
        assert_eq!(SessionOpts::with_slots(4).with_kv_pages(9).resolve_kv_pages(4, 64), 9);
        assert_eq!(SessionOpts::with_slots(4).resolve_kv_pages(4, 64), 4 * 64usize.div_ceil(pp));
        assert_eq!(SessionOpts::with_slots(2).resolve_kv_pages(2, pp + 1), 2 * 2);
        // fused step follows the env default (on unless disabled), and
        // the builder pins it either way
        let env_fused =
            crate::config::parse_fused_step(std::env::var("UNI_LORA_FUSED_STEP").ok().as_deref());
        assert_eq!(SessionOpts::with_slots(4).fused_step, env_fused);
        assert!(SessionOpts::with_slots(4).with_fused_step(true).fused_step);
        assert!(!SessionOpts::with_slots(4).with_fused_step(false).fused_step);
    }
}
