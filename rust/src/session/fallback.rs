//! Full-forward fallback session: drives ordinary `Backend::run`
//! `lm_logits` executions, so ANY backend — PJRT included — supports
//! the session lifecycle with zero backend code. Per-token cost stays
//! O(seq · model) (this is exactly the legacy decode loop, slot-ified),
//! but the frozen inputs are hoisted: theta, w0 and the statics are
//! wrapped as shared tensors once per admission, so each step clones
//! refcounts instead of re-copying the backbone.
//!
//! Slots sharing an adapter are coalesced into one `[B, T]` forward
//! per step (the same same-adapter batching the legacy router did);
//! heterogeneous slots cost one forward per adapter group.
//!
//! Execution-mode-free by construction: theta ships to the backend as
//! an artifact input and the adapter is reconstructed inside the
//! forward, so no dense weights (and no factored factors) are ever
//! resident host-side. The factored/dense admission counters in
//! [`SessionStats`] therefore stay 0 here — the cost model is a
//! native-session concern.

use super::{Admission, DecodeSession, SeqEvent, SeqRequest, SeqState, SessionOpts, SessionStats};
use crate::data::vocab;
use crate::runtime::artifact::ArtifactMeta;
use crate::runtime::{Backend, TensorIn};
use anyhow::{anyhow, ensure, Result};
use std::sync::Arc;

struct Slot {
    /// [`SeqRequest::request_id`], echoed on every event this slot
    /// emits (observation-only)
    request_id: u64,
    /// adapter name — half of the grouping key
    key: String,
    /// theta content fingerprint — the other half: slots batch into
    /// one forward only when name AND weights agree, so a
    /// re-registered adapter mid-flight can never decode another
    /// request's sequence with its theta
    theta_fp: u64,
    theta: TensorIn,
    statics: Vec<TensorIn>,
    /// `[seq]` context window, PAD-filled past `state.placed`
    toks: Vec<i32>,
    state: SeqState,
    fresh: bool,
}

pub struct FallbackSession {
    meta: ArtifactMeta,
    w0: TensorIn,
    slots: Vec<Option<Slot>>,
    active: usize,
    stats: SessionStats,
}

impl FallbackSession {
    pub fn new(
        meta: ArtifactMeta,
        w0: Arc<Vec<f32>>,
        opts: &SessionOpts,
    ) -> Result<FallbackSession> {
        ensure!(
            meta.kind == "lm_logits",
            "decode sessions need an lm_logits artifact; {} has kind {:?}",
            meta.name,
            meta.kind
        );
        ensure!(
            w0.len() == meta.base_params,
            "w0 size mismatch: got {}, artifact wants {}",
            w0.len(),
            meta.base_params
        );
        let n = opts.resolve_slots(meta.cfg.batch);
        Ok(FallbackSession {
            w0: TensorIn::SharedF32(w0),
            slots: (0..n).map(|_| None).collect(),
            active: 0,
            stats: SessionStats::default(),
            meta,
        })
    }
}

impl DecodeSession for FallbackSession {
    fn cancel(&mut self, slot: usize) {
        if slot < self.slots.len() && self.slots[slot].take().is_some() {
            self.active -= 1;
            self.stats.cancelled += 1;
        }
    }

    fn admit(&mut self, req: SeqRequest) -> Result<Admission> {
        ensure!(!req.prompt.is_empty(), "empty prompt");
        req.sampling.validate()?;
        let si = self
            .slots
            .iter()
            .position(|s| s.is_none())
            .ok_or_else(|| anyhow!("no free decode slot"))?;
        let t = self.meta.cfg.seq;
        let mut toks = vec![vocab::PAD; t];
        let l = req.prompt.len().min(t);
        let truncated = req.prompt.len() > t;
        if truncated {
            self.stats.truncated_admits += 1;
        }
        toks[..l].copy_from_slice(&req.prompt[..l]);
        let statics: Vec<TensorIn> = req.statics.iter().map(TensorIn::shared_from).collect();
        if req.sampling.is_greedy() {
            self.stats.greedy_admits += 1;
        } else {
            self.stats.sampled_admits += 1;
        }
        self.slots[si] = Some(Slot {
            request_id: req.request_id,
            key: req.adapter,
            theta_fp: super::theta_fingerprint(&req.theta),
            theta: TensorIn::SharedF32(req.theta),
            statics,
            toks,
            state: SeqState::new(l, req.max_new, t, req.sampling),
            fresh: true,
        });
        self.active += 1;
        self.stats.admitted += 1;
        Ok(Admission { slot: si, truncated })
    }

    fn step(&mut self, exec: &mut dyn Backend) -> Result<Vec<SeqEvent>> {
        let (b, t, vocab_n) = (self.meta.cfg.batch, self.meta.cfg.seq, self.meta.cfg.vocab);
        let art = self.meta.name.clone();
        let mut events = Vec::new();

        // retire stillborn fresh slots first: they never run a forward
        for si in 0..self.slots.len() {
            if let Some(s) = &self.slots[si] {
                if s.fresh && s.state.stillborn() {
                    // read the id before the slot is freed
                    let req = s.request_id;
                    events.push(SeqEvent { slot: si, req, token: None, done: true });
                    self.slots[si] = None;
                    self.active -= 1;
                }
            }
        }

        // group the active slots by (adapter, theta fingerprint),
        // preserving slot order
        let mut groups: Vec<((String, u64), Vec<usize>)> = Vec::new();
        for si in 0..self.slots.len() {
            if let Some(s) = &self.slots[si] {
                match groups.iter_mut().find(|(k, _)| k.0 == s.key && k.1 == s.theta_fp) {
                    Some((_, v)) => v.push(si),
                    None => groups.push(((s.key.clone(), s.theta_fp), vec![si])),
                }
            }
        }

        for (_, members) in &groups {
            for chunk in members.chunks(b) {
                let mut toks = vec![vocab::PAD; b * t];
                for (row, &si) in chunk.iter().enumerate() {
                    let s = self.slots[si].as_ref().expect("grouped slot is live");
                    toks[row * t..(row + 1) * t].copy_from_slice(&s.toks);
                }
                let inputs = {
                    let first = self.slots[chunk[0]].as_ref().expect("grouped slot is live");
                    let mut v = vec![first.theta.clone(), self.w0.clone(), TensorIn::I32(toks)];
                    v.extend(first.statics.iter().cloned());
                    v
                };
                let out = exec.run(&art, &inputs)?;
                let logits = out[0].as_f32()?; // [B, T, V]
                for (row, &si) in chunk.iter().enumerate() {
                    let s = self.slots[si].as_mut().expect("grouped slot is live");
                    s.fresh = false;
                    let pos = s.state.placed - 1;
                    let rowl = &logits[(row * t + pos) * vocab_n..(row * t + pos + 1) * vocab_n];
                    let (token, done) = s.state.emit(rowl);
                    if let Some(tok) = token {
                        // emit() advanced `placed`; the token lands at
                        // the previous position
                        s.toks[s.state.placed - 1] = tok;
                        self.stats.generated += 1;
                    }
                    events.push(SeqEvent { slot: si, req: s.request_id, token, done });
                    if done {
                        self.slots[si] = None;
                        self.active -= 1;
                    }
                }
            }
        }
        self.stats.steps += 1;
        Ok(events)
    }

    fn finish(&mut self) {
        for s in self.slots.iter_mut() {
            *s = None;
        }
        self.active = 0;
    }

    fn slots(&self) -> usize {
        self.slots.len()
    }

    fn active(&self) -> usize {
        self.active
    }

    fn stats(&self) -> SessionStats {
        self.stats
    }
}
